//! Mini strong-scaling sweep on BTIO (Figure 3(c) shape at laptop scale):
//! P ∈ {16, 64, 256}, TAM(P_L=256 clamped) vs two-phase.
//!
//! ```sh
//! cargo run --release --example btio_scaling
//! ```

use tamio::config::RunConfig;
use tamio::experiments::fig3_series;
use tamio::metrics::scaling_table;
use tamio::workloads::WorkloadKind;

fn main() -> tamio::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.ppn = 16;
    cfg.workload = WorkloadKind::Btio;

    let procs = [16usize, 64, 256];
    println!("BTIO strong scaling (ppn={}, budget 100k reqs/run):", cfg.ppn);
    let series = fig3_series(&cfg, WorkloadKind::Btio, &procs, 100_000)?;
    print!("{}", scaling_table("btio", &series));

    // The paper's qualitative claim: two-phase degrades with P while TAM
    // holds (Figure 3c-d).
    let tam = &series[0].points;
    let two = &series[1].points;
    let tam_trend = tam.last().unwrap().1 / tam.first().unwrap().1;
    let two_trend = two.last().unwrap().1 / two.first().unwrap().1;
    println!("bandwidth trend P=16 -> P=256:  TAM {tam_trend:.2}x   two-phase {two_trend:.2}x");
    println!(
        "TAM / two-phase at P=256: {:.2}x",
        tam.last().unwrap().1 / two.last().unwrap().1
    );
    Ok(())
}
