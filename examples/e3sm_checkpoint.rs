//! End-to-end validation driver (DESIGN.md §5): an E3SM-G-like checkpoint
//! write at run scale — 8 nodes × 16 ranks, ~600k noncontiguous requests,
//! ~300 MiB — through the full three-layer stack:
//!
//! * the workload generator builds the production-style decomposition,
//! * TAM runs intra-node + inter-node aggregation with the **XLA engine**
//!   (the AOT-compiled JAX/Pallas sort+coalesce pipeline via PJRT) when
//!   artifacts are present, falling back to the native engine otherwise,
//! * the simulated Lustre file is read back and verified byte-by-byte,
//! * the headline metric (write bandwidth, TAM vs two-phase) is reported.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```sh
//! make artifacts && cargo run --release --example e3sm_checkpoint
//! ```

use std::time::Instant;

use tamio::config::RunConfig;
use tamio::coordinator::collective::Algorithm;
use tamio::coordinator::tam::TamConfig;
use tamio::experiments::{run_once_with_engine, build_engine_for};
use tamio::metrics::breakdown_table;
use tamio::runtime::engine::EngineKind;
use tamio::util::human_bytes;
use tamio::workloads::WorkloadKind;

fn main() -> tamio::Result<()> {
    // P = 1024: the smallest paper configuration where the all-to-many
    // congestion at the global aggregators is visible (at P ≤ 256 the
    // paper's Figure 3 shows TAM ≡ two-phase).
    let mut cfg = RunConfig::default();
    cfg.nodes = 16;
    cfg.ppn = 64;
    cfg.workload = WorkloadKind::E3smG;
    cfg.scale = 512; // ~340k requests, ~170 MiB
    cfg.verify = true;
    cfg.engine = EngineKind::Xla;

    let engine = match build_engine_for(&cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("[e3sm_checkpoint] XLA engine unavailable ({e}); using native");
            cfg.engine = EngineKind::Native;
            build_engine_for(&cfg)?
        }
    };
    println!(
        "e3sm checkpoint: P={} ({}x{}), scale 1/{}, engine={}",
        cfg.topology().nprocs(),
        cfg.nodes,
        cfg.ppn,
        cfg.scale,
        engine.name()
    );

    let mut runs = Vec::new();
    let mut bandwidths = Vec::new();
    for algo in [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 256 }),
    ] {
        cfg.algorithm = algo;
        let t0 = Instant::now();
        let (run, verify) = run_once_with_engine(&cfg, engine.as_ref())?.remove(0);
        let wall = t0.elapsed();
        let v = verify.expect("verify on");
        assert!(v.passed(), "verification failed for {}", run.label);
        let bw = run.breakdown.bandwidth(run.counters.bytes);
        println!(
            "{:<16} sim {:>9.3} ms  bandwidth {:>10}/s  reqs {} -> {} -> {}  (wall {wall:.1?}, verified {}/{})",
            run.label,
            run.breakdown.total() * 1e3,
            human_bytes(bw as u64),
            run.counters.reqs_posted,
            run.counters.reqs_after_intra,
            run.counters.reqs_at_io,
            v.ok,
            v.total,
        );
        bandwidths.push(bw);
        runs.push(run);
    }

    println!("\nBreakdown (simulated, paper Figure 4 shape):");
    print!("{}", breakdown_table(&runs));
    println!(
        "headline: TAM / two-phase bandwidth = {:.2}x (paper band at scale: 3-29x at P=16384)",
        bandwidths[1] / bandwidths[0]
    );
    Ok(())
}
