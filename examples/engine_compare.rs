//! Native vs XLA engine on identical aggregation batches: asserts
//! bit-identical output and reports throughput of the hot path.
//!
//! ```sh
//! make artifacts && cargo run --release --example engine_compare
//! ```

use std::time::Instant;

use tamio::runtime::engine::{NativeEngine, SortEngine, XlaEngine};
use tamio::util::SplitMix64;

fn workload(n: usize, seed: u64) -> Vec<(u64, u64)> {
    // A realistic aggregator batch: k interleaved sorted streams with
    // coalescible neighbours and gaps.
    let mut rng = SplitMix64::new(seed);
    let mut pairs = Vec::with_capacity(n);
    let mut cursor = 0u64;
    for _ in 0..n {
        let len = 8 + rng.gen_range(120);
        let gap = if rng.gen_bool(0.4) { 0 } else { rng.gen_range(256) };
        cursor += gap;
        pairs.push((cursor, len));
        cursor += len;
    }
    rng.shuffle(&mut pairs);
    pairs
}

fn main() -> tamio::Result<()> {
    let native = NativeEngine;
    let xla = match XlaEngine::load_default() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("XLA engine unavailable ({e}); run `make artifacts` first");
            std::process::exit(2);
        }
    };
    println!("xla engine batch sizes: {:?}", xla.batch_sizes());

    for &n in &[100usize, 1000, 4096, 20_000] {
        let pairs = workload(n, n as u64);
        let a = native.merge_coalesce(pairs.clone())?;
        let t0 = Instant::now();
        let b = xla.merge_coalesce(pairs.clone())?;
        let xla_t = t0.elapsed();
        let t0 = Instant::now();
        let _ = native.merge_coalesce(pairs)?;
        let native_t = t0.elapsed();
        assert_eq!(a, b, "engines disagree at n={n}");
        println!(
            "n={n:>6}: identical ({} coalesced)  native {:>10.1?}  xla {:>10.1?}  ({:.0}x)",
            a.len(),
            native_t,
            xla_t,
            xla_t.as_secs_f64() / native_t.as_secs_f64().max(1e-9),
        );
    }
    println!("engines agree bit-for-bit on all batches");
    Ok(())
}
