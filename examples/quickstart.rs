//! Quickstart: a collective write on 2 nodes × 8 ranks with a strided
//! file view, run with both two-phase I/O and TAM, verified byte-by-byte
//! against the expected file image.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tamio::config::RunConfig;
use tamio::coordinator::collective::Algorithm;
use tamio::coordinator::tam::TamConfig;
use tamio::experiments::run_once;
use tamio::lustre::LustreConfig;
use tamio::metrics::breakdown_table;
use tamio::workloads::WorkloadKind;

fn main() -> tamio::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.nodes = 2;
    cfg.ppn = 8;
    cfg.workload = WorkloadKind::Strided;
    cfg.lustre = LustreConfig::new(1 << 16, 4);
    cfg.verify = true;

    let mut runs = Vec::new();
    for algo in [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
    ] {
        cfg.algorithm = algo;
        let (run, verify) = run_once(&cfg)?.remove(0);
        let v = verify.expect("verification enabled");
        println!(
            "{:<14} end-to-end {:>10.3} ms   verify {}/{} ranks {}",
            run.label,
            run.breakdown.total() * 1e3,
            v.ok,
            v.total,
            if v.passed() { "OK" } else { "FAILED" }
        );
        assert!(v.passed(), "byte verification failed");
        runs.push(run);
    }

    println!("\nComponent breakdown (simulated time):");
    print!("{}", breakdown_table(&runs));

    let speedup = runs[0].breakdown.total() / runs[1].breakdown.total();
    println!("TAM speedup over two-phase on this toy run: {speedup:.2}x");
    Ok(())
}
