"""AOT-lower the L2 aggregation pipeline to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser on the Rust side reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py and /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts --sizes "256 1024 4096"
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import aggregate, example_args  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust's to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_aggregate(n: int) -> str:
    lowered = jax.jit(aggregate).lower(*example_args(n))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default="256 1024 4096",
        help="space-separated power-of-two batch sizes to lower",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split()]
    os.makedirs(args.out_dir, exist_ok=True)
    for n in sizes:
        if n & (n - 1):
            raise SystemExit(f"batch size must be a power of two, got {n}")
        path = os.path.join(args.out_dir, f"agg_{n}.hlo.txt")
        text = lower_aggregate(n)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(f"agg_{n}.hlo.txt {n}" for n in sizes) + "\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
