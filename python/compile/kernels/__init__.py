"""L1 Pallas kernels for the TAM aggregator hot path.

The compute hot-spot of the two-layer aggregation method (TAM) is the
per-aggregator *merge-sort + coalesce* of file-access requests, each request a
``(file offset, length)`` pair.  These kernels implement that hot path as
Pallas kernels (``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls, see /opt/xla-example/README.md):

* :mod:`.bitonic`  — branch-free bitonic sort network over (offset, length)
  pairs, keyed lexicographically by (offset, length).
* :mod:`.coalesce` — contiguity mask + segment-id scan over a sorted request
  list; two requests coalesce when ``off[i] == off[i-1] + len[i-1]``.
* :mod:`.ref`      — pure-jnp oracle used by pytest/hypothesis.

All kernels operate on fixed power-of-two sizes; shorter batches are padded
with ``SENTINEL`` offsets (i64 max) which sort to the end and form a single
zero-length trailing segment.
"""

from .bitonic import SENTINEL, bitonic_sort_pairs
from .coalesce import coalesce_segments

__all__ = ["SENTINEL", "bitonic_sort_pairs", "coalesce_segments"]
