"""Bitonic sort of (offset, length) pairs as a Pallas kernel.

Why bitonic: the per-aggregator merge step of TAM must sort the union of many
already-sorted request lists.  On a branchless SIMD target (the TPU VPU's
8x128 lanes — see DESIGN.md §Hardware-Adaptation) a data-independent sorting
network beats a heap merge: every stage is a vectorized compare-exchange with
no control-flow divergence, and the whole network for a VMEM-resident block of
N = 4096 pairs is O(N log^2 N) lane-parallel ops.

The kernel sorts lexicographically by ``(key, val)`` so the output is fully
deterministic (ties on offset are broken by length), which lets the pytest
oracle compare exact arrays rather than multisets.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Padding sentinel for unused slots: sorts after every real file offset.
SENTINEL = jnp.iinfo(jnp.int64).max


def _compare_exchange(keys, vals, stage_bit, substage_bit):
    """One vectorized compare-exchange stage of the bitonic network."""
    n = keys.shape[0]
    idx = jax.lax.iota(jnp.int32, n)
    partner = idx ^ substage_bit
    keys_p = keys[partner]
    vals_p = vals[partner]
    # Ascending block iff the stage bit of the index is 0.
    take_min = ((idx & stage_bit) == 0) == (idx < partner)
    # Lexicographic (key, val) <= (key_p, val_p).
    le = (keys < keys_p) | ((keys == keys_p) & (vals <= vals_p))
    keep = jnp.where(take_min, le, ~le)
    new_keys = jnp.where(keep, keys, keys_p)
    new_vals = jnp.where(keep, vals, vals_p)
    return new_keys, new_vals


def _bitonic_kernel(keys_ref, vals_ref, out_keys_ref, out_vals_ref, *, n):
    keys = keys_ref[...]
    vals = vals_ref[...]
    stage_bit = 2
    while stage_bit <= n:
        substage_bit = stage_bit >> 1
        while substage_bit >= 1:
            keys, vals = _compare_exchange(keys, vals, stage_bit, substage_bit)
            substage_bit >>= 1
        stage_bit <<= 1
    out_keys_ref[...] = keys
    out_vals_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_pairs(keys, vals, interpret=True):
    """Sort ``(keys, vals)`` pairs ascending by (key, val).

    Both arrays must be 1-D int64 of the same power-of-two length.
    Returns the sorted ``(keys, vals)``.
    """
    n = keys.shape[0]
    if n & (n - 1):
        raise ValueError(f"bitonic_sort_pairs requires power-of-two n, got {n}")
    kernel = functools.partial(_bitonic_kernel, n=n)
    out_shape = [
        jax.ShapeDtypeStruct((n,), keys.dtype),
        jax.ShapeDtypeStruct((n,), vals.dtype),
    ]
    sorted_keys, sorted_vals = pl.pallas_call(
        kernel,
        out_shape=out_shape,
        interpret=interpret,
    )(keys, vals)
    return sorted_keys, sorted_vals
