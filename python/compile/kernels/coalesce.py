"""Request-coalescing scan as a Pallas kernel.

Input: a request list sorted ascending by file offset (the bitonic kernel's
output).  Two adjacent requests coalesce when the second starts exactly where
the first ends: ``off[i] == off[i-1] + len[i-1]``.  The kernel emits, per
element, the id of the coalesced segment it belongs to (a prefix-sum over the
"starts a new segment" mask) plus the total segment count.

Padding slots (offset == SENTINEL) all share one trailing segment: the first
sentinel breaks contiguity with the last real request (a real offset plus its
length can never reach i64 max — MPI file offsets are < 2^63), and
sentinel[i] == sentinel[i-1] + 0 keeps subsequent sentinels merged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _coalesce_kernel(off_ref, len_ref, seg_ref, nseg_ref):
    off = off_ref[...]
    length = len_ref[...]
    prev_end = jnp.concatenate(
        [jnp.full((1,), -1, dtype=off.dtype), off[:-1] + length[:-1]]
    )
    # new_segment[i] == 1 iff request i does NOT extend request i-1.
    new_segment = (off != prev_end).astype(off.dtype)
    # Element 0 always starts segment 0 (off[0] != -1 for any valid offset),
    # so the inclusive scan minus one yields 0-based segment ids.
    seg = jnp.cumsum(new_segment) - 1
    seg_ref[...] = seg
    nseg_ref[...] = seg[-1:] + 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def coalesce_segments(sorted_off, sorted_len, interpret=True):
    """Segment ids + segment count for a sorted request list.

    Returns ``(seg_ids, nseg)`` where ``seg_ids`` is int64[n] of 0-based
    coalesced-segment ids (nondecreasing, steps of 1) and ``nseg`` is
    int64[1], the total number of segments including the sentinel segment
    if any padding is present.
    """
    n = sorted_off.shape[0]
    out_shape = [
        jax.ShapeDtypeStruct((n,), sorted_off.dtype),
        jax.ShapeDtypeStruct((1,), sorted_off.dtype),
    ]
    seg, nseg = pl.pallas_call(
        _coalesce_kernel,
        out_shape=out_shape,
        interpret=interpret,
    )(sorted_off, sorted_len)
    return seg, nseg
