"""Pure-jnp / pure-python oracles for the L1 kernels.

``ref_*`` functions are the ground truth the Pallas kernels are tested
against (pytest + hypothesis, exact integer equality).  ``py_aggregate`` is a
plain-python re-statement used to cross-check the jnp oracle itself.
"""

import jax.numpy as jnp
import numpy as np

from .bitonic import SENTINEL


def ref_sort_pairs(keys, vals):
    """Lexicographic (key, val) ascending sort via jnp.lexsort."""
    order = jnp.lexsort((vals, keys))
    return keys[order], vals[order]


def ref_coalesce(sorted_off, sorted_len):
    """Segment ids + count for a sorted request list (jnp oracle)."""
    off = jnp.asarray(sorted_off)
    length = jnp.asarray(sorted_len)
    prev_end = jnp.concatenate(
        [jnp.full((1,), -1, dtype=off.dtype), off[:-1] + length[:-1]]
    )
    new_segment = (off != prev_end).astype(off.dtype)
    seg = jnp.cumsum(new_segment) - 1
    return seg, seg[-1:] + 1


def ref_aggregate(offsets, lengths):
    """Full pipeline oracle: sort, coalesce, compact.

    Returns (coal_off, coal_len, nseg) with the same padded layout as the
    L2 model: arrays of the input length, entries past nseg-1 set to
    SENTINEL / 0.
    """
    n = offsets.shape[0]
    sk, sv = ref_sort_pairs(offsets, lengths)
    seg, nseg = ref_coalesce(sk, sv)
    coal_off = jnp.full((n,), SENTINEL, dtype=sk.dtype)
    coal_len = jnp.zeros((n,), dtype=sv.dtype)
    # Segment start offset: minimum offset in segment == first element.
    coal_off = coal_off.at[seg].min(sk)
    coal_len = coal_len.at[seg].add(sv)
    return coal_off, coal_len, nseg


def py_aggregate(pairs):
    """Plain-python ground truth over a list of (offset, length) pairs.

    Sentinel-padded entries must not be included.  Returns the coalesced
    list of (offset, length) pairs.
    """
    out = []
    for off, ln in sorted(pairs):
        if out and out[-1][0] + out[-1][1] == off:
            out[-1] = (out[-1][0], out[-1][1] + ln)
        else:
            out.append((off, ln))
    return out


def np_pad(pairs, n):
    """Pad a python pair list to (offsets, lengths) int64 arrays of size n."""
    off = np.full(n, int(SENTINEL), dtype=np.int64)
    ln = np.zeros(n, dtype=np.int64)
    for i, (o, l) in enumerate(pairs):
        off[i] = o
        ln[i] = l
    return off, ln
