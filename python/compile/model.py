"""L2: the TAM aggregator compute graph.

The paper's per-aggregator hot path — merge-sort the gathered offset/length
pairs, then coalesce adjacent contiguous requests (§IV-A/B) — expressed as a
jax function that calls the L1 Pallas kernels.  ``aggregate`` is what
``aot.py`` lowers to the HLO-text artifacts the Rust coordinator executes via
PJRT on the request path.

Layout contract with the Rust side (see rust/src/runtime/):

* inputs:  ``offsets: i64[N]``, ``lengths: i64[N]`` — a batch of up to N
  requests, padded with ``SENTINEL`` offsets (length 0).
* outputs: ``(coal_off: i64[N], coal_len: i64[N], nseg: i64[1])`` — the
  coalesced request list, ascending, padded with SENTINEL/0; ``nseg`` counts
  all segments *including* the single sentinel segment when padding exists
  (the consumer drops the trailing entry whose offset == SENTINEL).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import SENTINEL, bitonic_sort_pairs, coalesce_segments  # noqa: E402


def aggregate(offsets, lengths):
    """Sort + coalesce one padded batch of (offset, length) requests."""
    n = offsets.shape[0]
    sorted_off, sorted_len = bitonic_sort_pairs(offsets, lengths)
    seg, nseg = coalesce_segments(sorted_off, sorted_len)
    # Compact each coalesced segment: start offset = first (minimum) offset
    # in the segment, length = sum of member lengths.  Sentinel padding forms
    # one trailing segment with offset SENTINEL and length 0.
    coal_off = jnp.full((n,), SENTINEL, dtype=sorted_off.dtype).at[seg].min(sorted_off)
    coal_len = jnp.zeros((n,), dtype=sorted_len.dtype).at[seg].add(sorted_len)
    return coal_off, coal_len, nseg


def example_args(n):
    """Abstract input signature for AOT lowering at batch size n."""
    spec = jax.ShapeDtypeStruct((n,), jnp.int64)
    return spec, spec
