"""AOT lowering tests: the HLO-text artifacts parse, and the compiled
pipeline (via jax itself) agrees with the oracle — guarding the exact
artifact the Rust runtime loads."""

import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_aggregate
from compile.kernels.ref import np_pad, py_aggregate
from compile.model import aggregate


@pytest.mark.parametrize("n", [16, 256])
def test_hlo_text_structure(n):
    text = lower_aggregate(n)
    assert "HloModule" in text
    assert f"s64[{n}]" in text
    # Entry computation must return a 3-tuple (coal_off, coal_len, nseg).
    assert f"(s64[{n}]" in text and "s64[1]" in text


def test_hlo_text_deterministic():
    assert lower_aggregate(16) == lower_aggregate(16)


def test_cli_writes_artifacts(tmp_path):
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--sizes", "16"],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr
    assert (tmp_path / "agg_16.hlo.txt").exists()
    assert (tmp_path / "manifest.txt").read_text().startswith("agg_16.hlo.txt 16")


def test_cli_rejects_non_power_of_two(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--sizes", "12"],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode != 0


def test_jit_pipeline_agrees_with_python_oracle_large():
    rng = np.random.default_rng(11)
    pairs = []
    cursor = 0
    for _ in range(900):
        gap = int(rng.integers(0, 3)) * int(rng.integers(0, 64))
        ln = int(rng.integers(1, 32))
        cursor += gap
        pairs.append((cursor, ln))
        cursor += ln
    rng.shuffle(pairs)
    off, ln = np_pad(pairs, 1024)
    co, cl, nseg = aggregate(jnp.asarray(off), jnp.asarray(ln))
    co, cl, nseg = np.asarray(co), np.asarray(cl), int(nseg[0])
    got = []
    for i in range(nseg):
        if co[i] == np.iinfo(np.int64).max:
            break
        got.append((int(co[i]), int(cl[i])))
    assert got == py_aggregate(pairs)
