"""Kernel vs ref correctness — the CORE signal for the L1 Pallas kernels.

Exact integer equality everywhere (the pipeline is pure int64 data movement);
hypothesis sweeps sizes, value ranges and contiguity structure.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import SENTINEL, bitonic_sort_pairs, coalesce_segments
from compile.kernels.ref import ref_coalesce, ref_sort_pairs


def _np(a):
    return np.asarray(a)


# ---------------------------------------------------------------- bitonic


@pytest.mark.parametrize("n", [2, 4, 16, 64, 256])
def test_bitonic_sorts_random(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 1 << 40, n, dtype=np.int64)
    vals = rng.integers(1, 1 << 20, n, dtype=np.int64)
    sk, sv = bitonic_sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
    rk, rv = ref_sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
    np.testing.assert_array_equal(_np(sk), _np(rk))
    np.testing.assert_array_equal(_np(sv), _np(rv))


def test_bitonic_rejects_non_power_of_two():
    a = jnp.zeros(6, dtype=jnp.int64)
    with pytest.raises(ValueError):
        bitonic_sort_pairs(a, a)


def test_bitonic_sorts_with_sentinel_padding():
    keys = jnp.asarray([int(SENTINEL), 10, int(SENTINEL), 4], dtype=jnp.int64)
    vals = jnp.asarray([0, 5, 0, 2], dtype=jnp.int64)
    sk, sv = bitonic_sort_pairs(keys, vals)
    np.testing.assert_array_equal(_np(sk)[:2], [4, 10])
    assert _np(sk)[2] == SENTINEL and _np(sk)[3] == SENTINEL


def test_bitonic_already_sorted_identity():
    keys = jnp.arange(64, dtype=jnp.int64) * 7
    vals = jnp.ones(64, dtype=jnp.int64)
    sk, sv = bitonic_sort_pairs(keys, vals)
    np.testing.assert_array_equal(_np(sk), _np(keys))
    np.testing.assert_array_equal(_np(sv), _np(vals))


def test_bitonic_reverse_sorted():
    keys = jnp.arange(128, dtype=jnp.int64)[::-1]
    vals = keys * 2
    sk, sv = bitonic_sort_pairs(keys, vals)
    np.testing.assert_array_equal(_np(sk), np.arange(128))
    np.testing.assert_array_equal(_np(sv), np.arange(128) * 2)


def test_bitonic_duplicate_keys_tie_break_on_vals():
    keys = jnp.asarray([5, 5, 5, 5], dtype=jnp.int64)
    vals = jnp.asarray([9, 1, 7, 3], dtype=jnp.int64)
    _, sv = bitonic_sort_pairs(keys, vals)
    np.testing.assert_array_equal(_np(sv), [1, 3, 7, 9])


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 2**50), st.integers(0, 2**30)
        ),
        min_size=1,
        max_size=128,
    )
)
def test_bitonic_matches_ref_hypothesis(pairs):
    n = 1 << (len(pairs) - 1).bit_length() if len(pairs) > 1 else 2
    keys = np.full(n, int(SENTINEL), dtype=np.int64)
    vals = np.zeros(n, dtype=np.int64)
    for i, (k, v) in enumerate(pairs):
        keys[i], vals[i] = k, v
    sk, sv = bitonic_sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
    rk, rv = ref_sort_pairs(jnp.asarray(keys), jnp.asarray(vals))
    np.testing.assert_array_equal(_np(sk), _np(rk))
    np.testing.assert_array_equal(_np(sv), _np(rv))


# ---------------------------------------------------------------- coalesce


def test_coalesce_all_contiguous():
    off = jnp.asarray([0, 4, 8, 12], dtype=jnp.int64)
    ln = jnp.asarray([4, 4, 4, 4], dtype=jnp.int64)
    seg, nseg = coalesce_segments(off, ln)
    np.testing.assert_array_equal(_np(seg), [0, 0, 0, 0])
    assert int(nseg[0]) == 1


def test_coalesce_none_contiguous():
    off = jnp.asarray([0, 5, 11, 100], dtype=jnp.int64)
    ln = jnp.asarray([4, 4, 4, 4], dtype=jnp.int64)
    seg, nseg = coalesce_segments(off, ln)
    np.testing.assert_array_equal(_np(seg), [0, 1, 2, 3])
    assert int(nseg[0]) == 4


def test_coalesce_mixed():
    off = jnp.asarray([0, 2, 10, 12, 12, 20, 21, 22], dtype=jnp.int64)
    ln = jnp.asarray([2, 2, 2, 0, 2, 1, 1, 1], dtype=jnp.int64)
    seg, nseg = coalesce_segments(off, ln)
    # [0,2)+[2,4) | [10,12)+[12,12)+[12,14) | [20,21)+[21,22)+[22,23)
    np.testing.assert_array_equal(_np(seg), [0, 0, 1, 1, 1, 2, 2, 2])
    assert int(nseg[0]) == 3


def test_coalesce_sentinel_padding_single_trailing_segment():
    off = jnp.asarray([0, 4, int(SENTINEL), int(SENTINEL)], dtype=jnp.int64)
    ln = jnp.asarray([4, 4, 0, 0], dtype=jnp.int64)
    seg, nseg = coalesce_segments(off, ln)
    np.testing.assert_array_equal(_np(seg), [0, 0, 1, 1])
    assert int(nseg[0]) == 2


def test_coalesce_overlapping_requests_not_merged():
    # Overlap (off[i] < off[i-1]+len[i-1]) must NOT coalesce: the I/O phase
    # handles overlapping writes by order, merging would corrupt lengths.
    off = jnp.asarray([0, 2, 8, 9], dtype=jnp.int64)
    ln = jnp.asarray([4, 2, 4, 1], dtype=jnp.int64)
    seg, nseg = coalesce_segments(off, ln)
    np.testing.assert_array_equal(_np(seg), [0, 1, 2, 3])
    assert int(nseg[0]) == 4


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.tuples(st.integers(0, 2**20), st.integers(0, 64)), min_size=2, max_size=64)
)
def test_coalesce_matches_ref_hypothesis(pairs):
    pairs = sorted(pairs)
    off = np.asarray([p[0] for p in pairs], dtype=np.int64)
    ln = np.asarray([p[1] for p in pairs], dtype=np.int64)
    seg, nseg = coalesce_segments(jnp.asarray(off), jnp.asarray(ln))
    rseg, rnseg = ref_coalesce(off, ln)
    np.testing.assert_array_equal(_np(seg), _np(rseg))
    np.testing.assert_array_equal(_np(nseg), _np(rnseg))


def test_coalesce_segment_ids_are_monotone_steps_of_one():
    rng = np.random.default_rng(7)
    off = np.sort(rng.integers(0, 1000, 32, dtype=np.int64))
    ln = rng.integers(0, 8, 32, dtype=np.int64)
    seg, nseg = coalesce_segments(jnp.asarray(off), jnp.asarray(ln))
    s = _np(seg)
    assert s[0] == 0
    d = np.diff(s)
    assert ((d == 0) | (d == 1)).all()
    assert int(nseg[0]) == s[-1] + 1
