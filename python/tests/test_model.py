"""L2 pipeline tests: aggregate() vs oracles, shapes, padding contract."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import SENTINEL
from compile.kernels.ref import np_pad, py_aggregate, ref_aggregate
from compile.model import aggregate, example_args


def _run(pairs, n):
    off, ln = np_pad(pairs, n)
    co, cl, nseg = aggregate(jnp.asarray(off), jnp.asarray(ln))
    return np.asarray(co), np.asarray(cl), int(nseg[0])


def _unpack(co, cl, nseg):
    """Drop the trailing sentinel segment — the Rust-side consumption rule."""
    out = []
    for i in range(nseg):
        if co[i] == SENTINEL:
            break
        out.append((int(co[i]), int(cl[i])))
    return out


def test_shapes_and_dtypes():
    spec_off, spec_len = example_args(256)
    assert spec_off.shape == (256,) and spec_off.dtype == jnp.int64
    co, cl, nseg = _run([(0, 4), (4, 4)], 256)
    assert co.shape == (256,) and cl.shape == (256,)


def test_simple_merge():
    co, cl, nseg = _run([(0, 4), (4, 4), (100, 2)], 8)
    assert _unpack(co, cl, nseg) == [(0, 8), (100, 2)]


def test_unsorted_input_is_sorted_first():
    co, cl, nseg = _run([(100, 2), (4, 4), (0, 4)], 8)
    assert _unpack(co, cl, nseg) == [(0, 8), (100, 2)]


def test_all_padding_batch():
    co, cl, nseg = _run([], 8)
    assert _unpack(co, cl, nseg) == []
    assert nseg == 1  # single sentinel segment


def test_full_batch_no_padding():
    pairs = [(i * 10, 5) for i in range(8)]
    co, cl, nseg = _run(pairs, 8)
    assert _unpack(co, cl, nseg) == pairs
    assert nseg == 8  # no sentinel segment when batch is exactly full


def test_matches_jnp_oracle():
    rng = np.random.default_rng(3)
    pairs = [(int(o), int(l)) for o, l in zip(
        rng.integers(0, 4096, 100), rng.integers(1, 16, 100))]
    off, ln = np_pad(pairs, 128)
    co, cl, nseg = aggregate(jnp.asarray(off), jnp.asarray(ln))
    ro, rl, rn = ref_aggregate(jnp.asarray(off), jnp.asarray(ln))
    np.testing.assert_array_equal(np.asarray(co), np.asarray(ro))
    np.testing.assert_array_equal(np.asarray(cl), np.asarray(rl))
    assert int(nseg[0]) == int(rn[0])


@settings(max_examples=30, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 2**30), st.integers(0, 1024)),
        min_size=0,
        max_size=60,
    )
)
def test_matches_python_oracle_hypothesis(pairs):
    co, cl, nseg = _run(pairs, 64)
    got = _unpack(co, cl, nseg)
    want_raw = py_aggregate(pairs)
    # py_aggregate keeps zero-length leading entries distinct when offsets
    # differ; the pipeline behaves identically because coalescing is exact.
    assert got == want_raw


@pytest.mark.parametrize("n", [2, 8, 64, 256])
def test_interleaved_two_writers(n):
    # The archetypal collective-I/O pattern: two ranks interleave blocks.
    # After aggregation the whole range is one contiguous segment.
    block = 16
    pairs = [(i * block, block) for i in range(n // 2)]
    co, cl, nseg = _run(pairs, n)
    assert _unpack(co, cl, nseg) == [(0, block * (n // 2))] if pairs else []
