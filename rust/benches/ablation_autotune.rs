//! Auto-tuner validation panel: for each of the four paper workloads at
//! two topology points (a flat 4-node machine and a hierarchical
//! 16-node, 4-sockets-per-node, 4-nodes-per-switch machine — both with
//! square P so BTIO's `P = q²` constraint holds), run the top-4
//! predicted candidates for real and check that the metadata-only cost
//! predictor's winner lands in the measured top-2.
//!
//! `cargo bench --bench ablation_autotune`
//! Env: TAMIO_BENCH_BUDGET=N requests (default 60k);
//!      TAMIO_BENCH_DIRECTION=write|read|both (default both).

use tamio::config::RunConfig;
use tamio::coordinator::collective::Algorithm;
use tamio::experiments::{auto_scale, bench_direction_from_env, validate_tuner};
use tamio::metrics::tuner_validation_table;
use tamio::workloads::WorkloadKind;

fn main() {
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60_000);
    let direction = bench_direction_from_env();

    // (nodes, ppn, sockets_per_node, nodes_per_switch); every P is a
    // perfect square because BTIO refuses non-square process counts.
    let points = [(4usize, 16usize, 1usize, 0usize), (16, 16, 4, 4)];

    let mut panels = 0usize;
    for kind in WorkloadKind::paper_set() {
        for (nodes, ppn, spn, nps) in points {
            let p = nodes * ppn;
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.ppn = ppn;
            cfg.sockets_per_node = spn;
            cfg.nodes_per_switch = nps;
            cfg.workload = kind;
            cfg.scale = auto_scale(kind, p, budget);
            cfg.algorithm = Algorithm::Auto;
            cfg.direction = direction;
            // Reads always verify; writes verify by vectored read-back.
            // validate_tuner() asserts every candidate run passed, so a
            // panel that prints is a panel whose bytes round-tripped.
            cfg.verify = true;
            println!(
                "Auto-tune validation: {kind} @ {nodes} nodes x {ppn} ppn (P={p}), \
                 {spn} sockets/node, {nps} nodes/switch, scale 1/{}, direction {direction}",
                cfg.scale
            );
            let reports = validate_tuner(&cfg, 4).expect("tuner validation");
            print!("{}", tuner_validation_table(&reports));
            for rep in &reports {
                assert!(
                    rep.winner_in_top2,
                    "{kind} P={p} [{}]: predicted winner not in measured top-2",
                    rep.direction
                );
            }
            panels += reports.len();
        }
    }
    println!(
        "ablation_autotune: predicted winner in measured top-2 across {panels} panels ok"
    );
}
