//! Hierarchy-depth ablation at the paper's 16384-rank × 256-node point:
//! the same E3SM G-case collective driven through aggregation trees of
//! increasing depth — two-phase (depth 0), TAM / `tree:node=1` (depth 1,
//! bit-identical by construction), a socket+node tree (depth 2) and a
//! socket+node+switch tree (depth 3) — on a 4-sockets-per-node,
//! 16-nodes-per-switch topology priced by the per-tier link table.
//!
//! `cargo bench --bench ablation_depth`
//! Env: TAMIO_BENCH_BUDGET=N requests (default 150k);
//!      TAMIO_BENCH_DIRECTION=write|read|both (default both).

use tamio::config::RunConfig;
use tamio::coordinator::collective::{Algorithm, ExchangeArena};
use tamio::experiments::{
    auto_scale, bench_direction_from_env, build_engine_for, run_direction_with_arena,
};
use tamio::metrics::breakdown_panels;
use tamio::workloads::WorkloadKind;

fn main() {
    const NODES: usize = 256;
    const PPN: usize = 64;
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    let direction = bench_direction_from_env();

    let mut base = RunConfig::default();
    base.nodes = NODES;
    base.ppn = PPN;
    base.sockets_per_node = 4;
    base.nodes_per_switch = 16;
    base.workload = WorkloadKind::E3smG;
    base.scale = auto_scale(WorkloadKind::E3smG, NODES * PPN, budget);
    base.direction = direction;
    // Write bars verify by vectored read-back (reads always verify) so
    // the assert below gates BOTH directions — a panel that prints is a
    // panel whose bytes round-tripped.
    base.verify = true;
    println!(
        "Depth ablation: e3sm-g @ {NODES} nodes x {PPN} ppn (P={}), \
         4 sockets/node, 16 nodes/switch, scale 1/{}, direction {direction}",
        NODES * PPN,
        base.scale
    );

    // Depth 0 → 3.  `tree:node=1` is the depth-1 plan TAM(P_L=256)
    // resolves to on 256 nodes — the bit-identity the panel asserts.
    let algos = [
        "two-phase",
        "tam:256",
        "tree:node=1",
        "tree:socket=2,node=2",
        "tree:socket=4,node=2,switch=1",
    ];
    let engine = build_engine_for(&base).expect("engine");
    let mut arena = ExchangeArena::default();
    let mut runs = Vec::new();
    for &dir in direction.runs() {
        for name in algos {
            let mut cfg = base.clone();
            cfg.algorithm = name.parse::<Algorithm>().expect("algorithm");
            let (mut run, verify) =
                run_direction_with_arena(&cfg, engine.as_ref(), dir, &mut arena)
                    .expect("ablation run");
            if let Some(v) = verify {
                assert!(v.passed(), "{name} [{dir}]: verify {}/{}", v.ok, v.total);
            }
            run.label = name.to_string();
            runs.push(run);
        }
    }
    print!("{}", breakdown_panels(&runs));

    // Self-check: the depth-1 tree and TAM are the same plan.
    let per_dir = algos.len();
    for (d, dir) in direction.runs().iter().enumerate() {
        let tam = &runs[d * per_dir + 1];
        let tree1 = &runs[d * per_dir + 2];
        assert_eq!(
            tam.breakdown.total(),
            tree1.breakdown.total(),
            "[{dir}] depth-1 tree must be bit-identical to tam:256"
        );
        assert_eq!(tam.counters.msgs_intra, tree1.counters.msgs_intra, "[{dir}]");
        assert_eq!(tam.counters.msgs_inter, tree1.counters.msgs_inter, "[{dir}]");
    }
    println!("ablation_depth: tree:node=1 == tam:256 (bit-identical) ok");
}
