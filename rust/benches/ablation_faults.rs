//! Fault-tolerance ablation at the paper's 16384-rank × 256-node point:
//! the degradation curve (simulated slowdown × injected fault count) for
//! aggregation depths 0–2 — two-phase, TAM(P_L=256) and a socket+node
//! tree — under a cumulative fault schedule: a transient OST failure
//! (absorbed by retry-with-backoff), a quarter of the OSTs serving at
//! half rate, and an aggregator dropout repaired mid-collective.  Every
//! bar is byte-verified, so the curve charts *degraded completions*, not
//! silent corruption.
//!
//! Panel results are spliced into `BENCH_hotpath.json` under an
//! `"ablation_faults"` key (replaced on re-run; the `hotpath` bench's own
//! entries survive).
//!
//! `cargo bench --bench ablation_faults`
//! Env: TAMIO_BENCH_BUDGET=N requests (default 150k);
//!      TAMIO_BENCH_DIRECTION=write|read|both (default both).

use tamio::benchkit::JsonReport;
use tamio::config::RunConfig;
use tamio::coordinator::collective::{Algorithm, ExchangeArena};
use tamio::experiments::{
    auto_scale, bench_direction_from_env, build_engine_for, plan_cache_for,
    run_direction_cached,
};
use tamio::faults::FaultPlan;
use tamio::metrics::{breakdown_panels, degraded_summary};
use tamio::workloads::WorkloadKind;

/// Splice this bench's entries into `BENCH_hotpath.json` under an
/// `"ablation_faults"` key (same idiom as `engine_micro`: the `hotpath`
/// bench owns the `"benches"` array, so each side bench replaces only its
/// own key and both stay re-runnable in any order).
fn emit_json(report: &JsonReport) {
    const PATH: &str = "BENCH_hotpath.json";
    const KEY: &str = ", \"ablation_faults\": [";
    let mine = report.to_json();
    let body = mine
        .strip_prefix("{\"benches\": [")
        .and_then(|s| s.strip_suffix("]}"))
        .expect("JsonReport shape");
    let head = match std::fs::read_to_string(PATH) {
        Ok(s) if s.starts_with('{') && s.ends_with('}') => match s.find(KEY) {
            Some(cut) => s[..cut].to_string(),
            None => s[..s.len() - 1].to_string(),
        },
        _ => String::from("{\"benches\": []"),
    };
    let merged = format!("{head}{KEY}{body}]}}");
    std::fs::write(PATH, merged).expect("write BENCH_hotpath.json");
    println!("\nspliced ablation_faults panels into {PATH}");
}

fn main() {
    const NODES: usize = 256;
    const PPN: usize = 64;
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    let direction = bench_direction_from_env();

    let mut base = RunConfig::default();
    base.nodes = NODES;
    base.ppn = PPN;
    base.sockets_per_node = 4;
    base.nodes_per_switch = 16;
    base.workload = WorkloadKind::E3smG;
    base.scale = auto_scale(WorkloadKind::E3smG, NODES * PPN, budget);
    base.direction = direction;
    base.verify = true;
    base.fault_seed = 42;
    // The transient countdown can concentrate on one call site, so the
    // retry bound must cover it with headroom.
    base.max_retries = 8;
    println!(
        "Fault ablation: e3sm-g @ {NODES} nodes x {PPN} ppn (P={}), \
         4 sockets/node, 16 nodes/switch, scale 1/{}, direction {direction}, seed {}",
        NODES * PPN,
        base.scale,
        base.fault_seed
    );

    // Depths 0-2.
    let algos = ["two-phase", "tam:256", "tree:socket=2,node=2"];
    // Cumulative schedules: 0 faults (baseline), then +1 clause each.
    let schedules: [Option<&str>; 4] = [
        None,
        Some("ost_fail=?@transient:6"),
        Some("ost_fail=?@transient:6,ost_slow=0.5x:0-13"),
        Some("ost_fail=?@transient:6,ost_slow=0.5x:0-13,agg_drop=?"),
    ];

    let engine = build_engine_for(&base).expect("engine");
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(&base).expect("plan cache");
    let mut report = JsonReport::new();
    let mut runs = Vec::new();
    for &dir in direction.runs() {
        for name in algos {
            let mut baseline_total = 0.0f64;
            for (n_faults, spec) in schedules.iter().enumerate() {
                let mut cfg = base.clone();
                cfg.algorithm = name.parse::<Algorithm>().expect("algorithm");
                cfg.faults = spec.map(|s| s.parse::<FaultPlan>().expect("fault schedule"));
                let (mut run, verify) =
                    run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)
                        .expect("ablation run");
                if let Some(v) = verify {
                    assert!(
                        v.passed(),
                        "{name} [{dir}] f{n_faults}: verify {}/{}",
                        v.ok,
                        v.total
                    );
                }
                let total = run.breakdown.total();
                if n_faults == 0 {
                    baseline_total = total;
                }
                let slowdown = total / baseline_total.max(f64::MIN_POSITIVE);
                assert!(
                    slowdown >= 1.0 - 1e-9,
                    "{name} [{dir}] f{n_faults}: degraded run faster than baseline ({slowdown})"
                );
                println!(
                    "{name} [{dir}] faults={n_faults}: {:.3} ms  slowdown {slowdown:.3}x  {}",
                    total * 1e3,
                    degraded_summary(&run.counters)
                );
                report.add_value(
                    &format!("faults_slowdown/{name}/{dir}/f{n_faults}"),
                    slowdown,
                );
                run.label = format!("{name} f{n_faults}");
                runs.push(run);
            }
        }
    }
    print!("{}", breakdown_panels(&runs));

    // The full schedule includes a half-rate OST range, so every depth's
    // curve must end strictly above 1x.
    for &dir in direction.runs() {
        for name in algos {
            let label = format!("{name} f{}", schedules.len() - 1);
            let full = runs
                .iter()
                .find(|r| r.direction == dir && r.label == label)
                .expect("full-schedule bar");
            let base_bar = runs
                .iter()
                .find(|r| r.direction == dir && r.label == format!("{name} f0"))
                .expect("baseline bar");
            assert!(
                full.breakdown.total() > base_bar.breakdown.total(),
                "{name} [{dir}]: full fault schedule must degrade the run"
            );
            assert_eq!(full.counters.repaired_plans, 1, "{name} [{dir}]");
        }
    }
    emit_json(&report);
    println!("ablation_faults: all degraded bars byte-verified ok");
}
