//! Ablation A1 (§V) — the Isend → Issend ROMIO adjustment: with plain
//! `MPI_Isend`, non-aggregators race ahead through rounds and pending
//! sends pile up in the aggregators' match queues; `MPI_Issend`
//! synchronizes each round.  The paper made this change to make its
//! two-phase baseline competitive with Cray MPI.
//!
//! `cargo bench --bench ablation_issend`

use tamio::config::RunConfig;
use tamio::experiments::run_once;
use tamio::metrics::render_table;
use tamio::netmodel::SendMode;
use tamio::workloads::WorkloadKind;

fn main() {
    println!("Ablation: Isend vs Issend on multi-round two-phase I/O (E3SM F)");
    let mut rows = Vec::new();
    for (nodes, ppn) in [(4usize, 32usize), (16, 64)] {
        for mode in [SendMode::Isend, SendMode::Issend] {
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.ppn = ppn;
            cfg.workload = WorkloadKind::E3smF;
            cfg.scale =
                tamio::experiments::auto_scale(WorkloadKind::E3smF, nodes * ppn, 300_000);
            cfg.net.send_mode = mode;
            // Small stripes + few OSTs -> many rounds -> the pending
            // unmatched-send queue builds up under Isend (§V).
            cfg.lustre.stripe_size = 1 << 12;
            cfg.lustre.stripe_count = 8;
            let (run, _) = run_once(&cfg).expect("run").remove(0);
            rows.push(vec![
                format!("P={}", nodes * ppn),
                mode.to_string(),
                format!("{}", run.counters.rounds),
                format!("{:.3} ms", run.breakdown.inter_comm * 1e3),
                format!("{:.3} ms", run.breakdown.total() * 1e3),
            ]);
        }
    }
    let headers: Vec<String> = ["procs", "send mode", "rounds", "inter comm", "end-to-end"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    print!("{}", render_table(&headers, &rows));
    println!("paper shape: Issend strictly cheaper once rounds > 1 (pending-queue effect).");
}
