//! Round-pipelining ablation at the paper's 16384-rank × 256-node point:
//! `--overlap on` vs `off` for aggregation depths 0–2 — two-phase,
//! TAM(P_L=256) and a socket+node tree — under both send semantics
//! (Issend, the default, bounds the achievable overlap by the §V
//! receiver-posting constraint; Isend does not).  The pipeline is a
//! schedule-only transform, so every pipelined bar must be byte-verified
//! with the exact volume counters of its serial twin, charge a strictly
//! positive `overlap_saved` credit, and total no more than serial —
//! steady-state rounds cost `max(exchange, io)` instead of the sum.
//!
//! Panel results are spliced into `BENCH_hotpath.json` under an
//! `"ablation_overlap"` key (replaced on re-run; the `hotpath` bench's
//! own entries survive).
//!
//! `cargo bench --bench ablation_overlap`
//! Env: TAMIO_BENCH_BUDGET=N requests (default 150k);
//!      TAMIO_BENCH_DIRECTION=write|read|both (default both).

use tamio::benchkit::JsonReport;
use tamio::config::RunConfig;
use tamio::coordinator::collective::{Algorithm, ExchangeArena, OverlapMode};
use tamio::experiments::{
    auto_scale, bench_direction_from_env, build_engine_for, plan_cache_for,
    run_direction_cached,
};
use tamio::metrics::breakdown_panels;
use tamio::netmodel::SendMode;
use tamio::workloads::WorkloadKind;

/// Splice this bench's entries into `BENCH_hotpath.json` under an
/// `"ablation_overlap"` key (same idiom as `engine_micro`: the `hotpath`
/// bench owns the `"benches"` array, so each side bench replaces only its
/// own key and both stay re-runnable in any order).
fn emit_json(report: &JsonReport) {
    const PATH: &str = "BENCH_hotpath.json";
    const KEY: &str = ", \"ablation_overlap\": [";
    let mine = report.to_json();
    let body = mine
        .strip_prefix("{\"benches\": [")
        .and_then(|s| s.strip_suffix("]}"))
        .expect("JsonReport shape");
    let head = match std::fs::read_to_string(PATH) {
        Ok(s) if s.starts_with('{') && s.ends_with('}') => match s.find(KEY) {
            Some(cut) => s[..cut].to_string(),
            None => s[..s.len() - 1].to_string(),
        },
        _ => String::from("{\"benches\": []"),
    };
    let merged = format!("{head}{KEY}{body}]}}");
    std::fs::write(PATH, merged).expect("write BENCH_hotpath.json");
    println!("\nspliced ablation_overlap panels into {PATH}");
}

fn main() {
    const NODES: usize = 256;
    const PPN: usize = 64;
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    let direction = bench_direction_from_env();

    let mut base = RunConfig::default();
    base.nodes = NODES;
    base.ppn = PPN;
    base.sockets_per_node = 4;
    base.nodes_per_switch = 16;
    base.workload = WorkloadKind::E3smG;
    base.scale = auto_scale(WorkloadKind::E3smG, NODES * PPN, budget);
    base.direction = direction;
    base.verify = true;
    println!(
        "Overlap ablation: e3sm-g @ {NODES} nodes x {PPN} ppn (P={}), \
         4 sockets/node, 16 nodes/switch, scale 1/{}, direction {direction}",
        NODES * PPN,
        base.scale,
    );

    // Depths 0-2.
    let algos = ["two-phase", "tam:256", "tree:socket=2,node=2"];
    let modes = [("issend", SendMode::Issend), ("isend", SendMode::Isend)];

    let engine = build_engine_for(&base).expect("engine");
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(&base).expect("plan cache");
    let mut report = JsonReport::new();
    let mut runs = Vec::new();
    for &dir in direction.runs() {
        for (mode_tag, mode) in modes {
            for name in algos {
                // Serial baseline, then its pipelined twin through the
                // same arena + plan cache (overlap is execution-time
                // only, so the pipelined leg must hit the cached plan).
                let mut run_leg = |overlap: OverlapMode| {
                    let mut cfg = base.clone();
                    cfg.algorithm = name.parse::<Algorithm>().expect("algorithm");
                    cfg.net.send_mode = mode;
                    cfg.overlap = overlap;
                    let (run, verify) = run_direction_cached(
                        &cfg,
                        engine.as_ref(),
                        dir,
                        &mut arena,
                        &mut cache,
                    )
                    .expect("ablation run");
                    let v = verify.expect("verified bar");
                    assert!(
                        v.passed(),
                        "{name}/{mode_tag} [{dir}] overlap={overlap}: verify {}/{}",
                        v.ok,
                        v.total
                    );
                    run
                };
                let serial = run_leg(OverlapMode::Off);
                let piped = run_leg(OverlapMode::On);

                // Schedule-only transform: identical bytes and volume
                // counters, a positive hidden-I/O credit, and a modeled
                // total that can only shrink.
                let s = &serial.counters;
                let p = &piped.counters;
                assert_eq!(
                    (s.bytes, s.rounds, s.reqs_posted, s.reqs_at_io),
                    (p.bytes, p.rounds, p.reqs_posted, p.reqs_at_io),
                    "{name}/{mode_tag} [{dir}]: pipelined volume diverged from serial"
                );
                assert_eq!(
                    serial.breakdown.overlap_saved, 0.0,
                    "{name}/{mode_tag} [{dir}]: serial run must not claim overlap credit"
                );
                assert!(
                    p.rounds >= 2,
                    "{name}/{mode_tag} [{dir}]: paper-scale point must be multi-round"
                );
                assert!(
                    piped.breakdown.overlap_saved > 0.0,
                    "{name}/{mode_tag} [{dir}]: pipelined steady rounds hid no I/O"
                );
                assert!(
                    piped.breakdown.total() <= serial.breakdown.total(),
                    "{name}/{mode_tag} [{dir}]: overlap made the modeled run slower"
                );
                let speedup = serial.breakdown.total() / piped.breakdown.total();
                println!(
                    "{name}/{mode_tag} [{dir}]: serial {:.3} ms -> overlap {:.3} ms \
                     (saved {:.3} ms, {speedup:.3}x)",
                    serial.breakdown.total() * 1e3,
                    piped.breakdown.total() * 1e3,
                    piped.breakdown.overlap_saved * 1e3,
                );
                report.add_value(
                    &format!("overlap_saved_ms/{name}/{mode_tag}/{dir}"),
                    piped.breakdown.overlap_saved * 1e3,
                );
                report
                    .add_value(&format!("overlap_speedup/{name}/{mode_tag}/{dir}"), speedup);
                for (tag, mut run) in [("serial", serial), ("overlap", piped)] {
                    run.label = format!("{name} {mode_tag} {tag}");
                    runs.push(run);
                }
            }
        }
    }
    print!("{}", breakdown_panels(&runs));
    emit_json(&report);
    println!("ablation_overlap: every pipelined bar byte-verified, bit-identical volume");
}
