//! Ablation A2 (§V) — global-aggregator placement: ROMIO spread-across-
//! nodes vs Cray MPI's node round-robin (ranks 0, ppn, 1, ppn+1, …).
//! Round-robin stacks several aggregators on few nodes when P_G is
//! small, concentrating inter-node traffic.
//!
//! `cargo bench --bench ablation_placement`

use tamio::config::RunConfig;
use tamio::coordinator::placement::GlobalPlacement;
use tamio::experiments::run_once;
use tamio::metrics::render_table;
use tamio::workloads::WorkloadKind;

fn main() {
    println!("Ablation: global-aggregator placement policy (two-phase, E3SM G)");
    let mut rows = Vec::new();
    for (nodes, ppn) in [(8usize, 32usize), (16, 64)] {
        for (name, policy) in [
            ("spread", GlobalPlacement::Spread),
            ("cray-rr", GlobalPlacement::CrayRoundRobin),
        ] {
            let mut cfg = RunConfig::default();
            cfg.nodes = nodes;
            cfg.ppn = ppn;
            cfg.workload = WorkloadKind::E3smG;
            cfg.scale =
                tamio::experiments::auto_scale(WorkloadKind::E3smG, nodes * ppn, 150_000);
            cfg.placement = policy;
            // Fewer global aggregators than nodes: round-robin stacks
            // them on the first nodes, spreading puts one per node —
            // the per-node NIC bound separates the two policies.
            cfg.lustre.stripe_count = nodes / 2;
            let (run, _) = run_once(&cfg).expect("run").remove(0);
            rows.push(vec![
                format!("P={}", nodes * ppn),
                name.to_string(),
                format!("{}", run.counters.max_in_degree),
                format!("{:.3} ms", run.breakdown.inter_comm * 1e3),
                format!("{:.3} ms", run.breakdown.total() * 1e3),
            ]);
        }
    }
    let headers: Vec<String> =
        ["procs", "placement", "max in-degree", "inter comm", "end-to-end"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    print!("{}", render_table(&headers, &rows));
    println!(
        "expected shape: when both policies balance aggregators across nodes the\n\
         bounds coincide (tuned ROMIO ~ Cray MPI, §V); imbalanced stacking is\n\
         punished by the per-node NIC term (netmodel::phase::nic_bound)."
    );
}
