//! P1 — hot-path microbenchmark: the aggregator merge+coalesce step as
//! (a) native sort_unstable+scan, (b) k-way heap merge over pre-sorted
//! streams, (c) the AOT XLA pipeline (when artifacts exist); plus the
//! §Perf kernel panels (chunked vs per-entry merge advance, run-batched
//! vs per-request scatter/gather at 1k/16k/128k entries) and a
//! thread-scaling panel for the worker pool (1/2/4/all threads at the
//! paper's 16384-rank × 256-node point, tree depths 0–2).  Wall-clock
//! (not simulated) — this is the §Perf measurement harness.
//!
//! Every kernel panel asserts chunked == reference before timing, so a
//! bench run doubles as an equivalence check at bench scale.  The panel
//! results are spliced into `BENCH_hotpath.json` under an
//! `"engine_micro"` key (replaced on re-run, so the `hotpath` bench's
//! own entries survive).
//!
//! `cargo bench --bench engine_micro`

use std::time::Duration;

use tamio::benchkit::{bench, black_box, section, JsonReport};
use tamio::cluster::{RankPlacement, Topology};
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{run_collective_write_with, Algorithm, ExchangeArena};
use tamio::coordinator::merge::{
    gather_slices_from_buf, gather_slices_from_buf_reference, merge_csr_into,
    merge_csr_into_reference, merge_views, scatter_csr_into_buf, scatter_csr_into_buf_reference,
    sort_coalesce_pairs, MergeScratch, ReqBatch,
};
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::FlatView;
use tamio::netmodel::NetParams;
use tamio::runtime::engine::{NativeEngine, SortEngine, XlaEngine};
use tamio::util::runtime::{default_threads, with_runtime, Runtime};
use tamio::util::SplitMix64;

/// k sorted, mutually disjoint streams with cross-stream coalescible
/// structure: one global request sequence dealt round-robin to streams
/// (overlapping writers are MPI-undefined, so the bench avoids them).
fn make_streams(k: usize, per: usize, seed: u64) -> Vec<FlatView> {
    let mut rng = SplitMix64::new(seed);
    let mut cursor = 0u64;
    let mut streams: Vec<Vec<(u64, u64)>> = vec![Vec::with_capacity(per); k];
    for i in 0..k * per {
        let len = 8 + rng.gen_range(56);
        cursor += if rng.gen_bool(0.5) { 0 } else { rng.gen_range(512) };
        streams[i % k].push((cursor, len));
        cursor += len;
    }
    streams
        .into_iter()
        .map(|pairs| {
            FlatView::from_pairs_unchecked(
                pairs.iter().map(|p| p.0).collect(),
                pairs.iter().map(|p| p.1).collect(),
            )
        })
        .collect()
}

/// Flatten per-stream views into the CSR slab layout the round loop
/// stages in (`RoundScratch`): stream `s` is rows `starts[s]..starts[s+1]`.
fn csr_of(streams: &[FlatView]) -> (Vec<u64>, Vec<u64>, Vec<usize>) {
    let mut offsets = Vec::new();
    let mut lengths = Vec::new();
    let mut starts = vec![0usize];
    for v in streams {
        offsets.extend_from_slice(v.offsets());
        lengths.extend_from_slice(v.lengths());
        starts.push(offsets.len());
    }
    (offsets, lengths, starts)
}

/// Chunked vs per-entry merge advance, and run-batched vs per-request
/// scatter/gather, at 1k/16k/128k staged entries (§Perf kernel panels).
fn bench_kernels(report: &mut JsonReport, budget: Duration) {
    for (k, per) in [(8usize, 128usize), (16, 1024), (32, 4096)] {
        let n = k * per;
        section(&format!(
            "kernel panel: {n} entries from {k} streams (simd feature {})",
            if cfg!(feature = "simd") { "ON" } else { "off" }
        ));
        let streams = make_streams(k, per, 0xC0FFEE ^ n as u64);
        let (offsets, lengths, starts) = csr_of(&streams);
        let mut scratch = MergeScratch::default();

        // ---- merge advance: chunked gallop vs per-entry heap pops.
        let mut merged = FlatView::empty();
        merge_csr_into(&offsets, &lengths, &starts, &mut scratch, &mut merged);
        let mut merged_ref = FlatView::empty();
        merge_csr_into_reference(&offsets, &lengths, &starts, &mut scratch, &mut merged_ref);
        assert_eq!(merged, merged_ref, "chunked merge diverged from reference at n={n}");

        let mut out = FlatView::empty();
        let r_chunk = bench(&format!("kernel_merge_chunked/{n}"), budget, || {
            merge_csr_into(
                black_box(&offsets),
                black_box(&lengths),
                black_box(&starts),
                &mut scratch,
                &mut out,
            );
            black_box(out.len());
        });
        println!("{r_chunk}   ({:.1} Mentries/s)", r_chunk.per_second(n as u64) / 1e6);
        report.add(&r_chunk);
        let r_ref = bench(&format!("kernel_merge_reference/{n}"), budget, || {
            merge_csr_into_reference(
                black_box(&offsets),
                black_box(&lengths),
                black_box(&starts),
                &mut scratch,
                &mut out,
            );
            black_box(out.len());
        });
        println!("{r_ref}   ({:.1} Mentries/s)", r_ref.per_second(n as u64) / 1e6);
        report.add(&r_ref);
        let speedup = r_ref.median.as_secs_f64() / r_chunk.median.as_secs_f64();
        println!("merge chunked speedup: {speedup:.2}x");
        report.add_value(&format!("kernel_merge_speedup/{n}"), speedup);

        // ---- scatter: run-batched memcpys vs one memcpy per request.
        let pay_starts: Vec<usize> = starts
            .iter()
            .map(|&row| lengths[..row].iter().sum::<u64>() as usize)
            .collect();
        let total_bytes = *pay_starts.last().unwrap();
        let payload = deterministic_payload(0xBE9C, 0, total_bytes as u64);

        let mut buf = Vec::new();
        let moved =
            scatter_csr_into_buf(&merged, &offsets, &lengths, &starts, &pay_starts, &payload, &mut buf);
        let mut buf_ref = Vec::new();
        let moved_ref = scatter_csr_into_buf_reference(
            &merged, &offsets, &lengths, &starts, &pay_starts, &payload, &mut buf_ref,
        );
        assert_eq!(moved, moved_ref, "scatter moved-bytes diverged at n={n}");
        assert_eq!(buf, buf_ref, "batched scatter diverged from reference at n={n}");

        let r_batch = bench(&format!("kernel_scatter_batched/{n}"), budget, || {
            black_box(scatter_csr_into_buf(
                black_box(&merged),
                black_box(&offsets),
                black_box(&lengths),
                black_box(&starts),
                black_box(&pay_starts),
                black_box(&payload),
                &mut buf,
            ));
        });
        println!("{r_batch}   ({:.1} Mentries/s)", r_batch.per_second(n as u64) / 1e6);
        report.add(&r_batch);
        let r_per = bench(&format!("kernel_scatter_reference/{n}"), budget, || {
            black_box(scatter_csr_into_buf_reference(
                black_box(&merged),
                black_box(&offsets),
                black_box(&lengths),
                black_box(&starts),
                black_box(&pay_starts),
                black_box(&payload),
                &mut buf,
            ));
        });
        println!("{r_per}   ({:.1} Mentries/s)", r_per.per_second(n as u64) / 1e6);
        report.add(&r_per);
        let speedup = r_per.median.as_secs_f64() / r_batch.median.as_secs_f64();
        println!("scatter batched speedup: {speedup:.2}x");
        report.add_value(&format!("kernel_scatter_speedup/{n}"), speedup);

        // ---- gather (read-direction reply assembly): the scattered
        // buffer gathered back per stream must reproduce the payload.
        let mut got = vec![0u8; total_bytes];
        for s in 0..k {
            let (lo, hi) = (starts[s], starts[s + 1]);
            gather_slices_from_buf(
                &merged,
                &buf,
                &offsets[lo..hi],
                &lengths[lo..hi],
                &mut got[pay_starts[s]..pay_starts[s + 1]],
            );
        }
        assert_eq!(got, payload, "batched gather round-trip diverged at n={n}");
        let mut got_ref = vec![0u8; total_bytes];
        for s in 0..k {
            let (lo, hi) = (starts[s], starts[s + 1]);
            gather_slices_from_buf_reference(
                &merged,
                &buf,
                &offsets[lo..hi],
                &lengths[lo..hi],
                &mut got_ref[pay_starts[s]..pay_starts[s + 1]],
            );
        }
        assert_eq!(got_ref, payload, "reference gather round-trip diverged at n={n}");

        let r_gather = bench(&format!("kernel_gather_batched/{n}"), budget, || {
            for s in 0..k {
                let (lo, hi) = (starts[s], starts[s + 1]);
                gather_slices_from_buf(
                    black_box(&merged),
                    black_box(&buf),
                    &offsets[lo..hi],
                    &lengths[lo..hi],
                    &mut got[pay_starts[s]..pay_starts[s + 1]],
                );
            }
            black_box(&got);
        });
        println!("{r_gather}   ({:.1} Mentries/s)", r_gather.per_second(n as u64) / 1e6);
        report.add(&r_gather);
        let r_gref = bench(&format!("kernel_gather_reference/{n}"), budget, || {
            for s in 0..k {
                let (lo, hi) = (starts[s], starts[s + 1]);
                gather_slices_from_buf_reference(
                    black_box(&merged),
                    black_box(&buf),
                    &offsets[lo..hi],
                    &lengths[lo..hi],
                    &mut got[pay_starts[s]..pay_starts[s + 1]],
                );
            }
            black_box(&got);
        });
        println!("{r_gref}   ({:.1} Mentries/s)", r_gref.per_second(n as u64) / 1e6);
        report.add(&r_gref);
        let speedup = r_gref.median.as_secs_f64() / r_gather.median.as_secs_f64();
        println!("gather batched speedup: {speedup:.2}x");
        report.add_value(&format!("kernel_gather_speedup/{n}"), speedup);
    }
}

/// Worker-pool thread scaling at the paper's headline scale point:
/// 16384 ranks on 256 nodes, one 512-byte block per rank in 8 pieces
/// (the per-rank-machinery regime `hotpath.rs` uses), collective write
/// end-to-end with a warm arena, at pool widths 1/2/4/all for tree
/// depths 0 (two-phase), 1 (node aggregators), and 2 (socket + node).
fn bench_thread_scaling(report: &mut JsonReport, budget: Duration) {
    const NODES: usize = 256;
    const PPN: usize = 64;
    const N_AGG: usize = 64;
    const BLOCK: u64 = 512;
    const PIECES: u64 = 8;
    let all = default_threads();
    let mut widths = vec![1usize, 2, 4, all];
    widths.sort_unstable();
    widths.dedup();

    let flat = Topology::new(NODES, PPN);
    let hier = Topology::hierarchical(NODES, PPN, 2, 0, RankPlacement::Block);
    let depths: [(&str, Algorithm, &Topology); 3] = [
        ("depth0_two_phase", Algorithm::TwoPhase, &flat),
        ("depth1_node", Algorithm::Tree("node=2".parse().unwrap()), &flat),
        ("depth2_socket_node", Algorithm::Tree("socket=2,node=1".parse().unwrap()), &hier),
    ];

    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    for (label, algo, topo) in depths {
        let p = topo.nprocs();
        let total_reqs = (p as u64) * PIECES;
        section(&format!(
            "thread scaling: {label}, P={p} ({NODES} nodes x {PPN} ppn), widths {widths:?}"
        ));
        let ctx = CollectiveCtx {
            topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: N_AGG,
        };
        let ranks: Vec<(usize, ReqBatch)> = (0..p)
            .map(|r| {
                let base = r as u64 * BLOCK;
                let q = BLOCK / PIECES;
                let view = FlatView::from_pairs((0..PIECES).map(|i| (base + i * q, q)).collect())
                    .unwrap();
                (r, ReqBatch::new(view, deterministic_payload(43, r, BLOCK)))
            })
            .collect();

        let mut serial_median = None;
        for &w in &widths {
            let rt = Runtime::new(w);
            let r = with_runtime(&rt, || {
                let mut arena = ExchangeArena::default();
                let mut file = LustreFile::new(LustreConfig::new(4096, N_AGG));
                // Warm-up: overwrite regime, warm arena, warm pool lanes.
                run_collective_write_with(&ctx, algo, ranks.clone(), &mut file, &mut arena)
                    .expect("warm-up");
                bench(&format!("thread_scaling/{label}/w{w}"), budget, || {
                    black_box(
                        run_collective_write_with(
                            black_box(&ctx),
                            black_box(algo),
                            black_box(ranks.clone()),
                            black_box(&mut file),
                            black_box(&mut arena),
                        )
                        .expect("write"),
                    );
                })
            });
            println!("{r}   ({:.2} Mreqs/s)", r.per_second(total_reqs) / 1e6);
            report.add(&r);
            let med = r.median.as_secs_f64();
            match serial_median {
                None => serial_median = Some(med),
                Some(t1) => {
                    let speedup = t1 / med;
                    println!("  speedup over width 1: {speedup:.2}x");
                    report.add_value(&format!("thread_scaling_speedup/{label}/w{w}"), speedup);
                }
            }
        }
    }
}

/// Splice this bench's entries into `BENCH_hotpath.json` under an
/// `"engine_micro"` key: the `hotpath` bench owns (and rewrites) the
/// `"benches"` array, so appending there would be clobbered; a separate
/// key that this bench replaces wholesale keeps both re-runnable in any
/// order without duplicating entries.
fn emit_json(report: &JsonReport) {
    const PATH: &str = "BENCH_hotpath.json";
    const KEY: &str = ", \"engine_micro\": [";
    let mine = report.to_json();
    let body = mine
        .strip_prefix("{\"benches\": [")
        .and_then(|s| s.strip_suffix("]}"))
        .expect("JsonReport shape");
    let head = match std::fs::read_to_string(PATH) {
        Ok(s) if s.starts_with('{') && s.ends_with('}') => match s.find(KEY) {
            Some(cut) => s[..cut].to_string(),
            None => s[..s.len() - 1].to_string(),
        },
        _ => String::from("{\"benches\": []"),
    };
    let merged = format!("{head}{KEY}{body}]}}");
    std::fs::write(PATH, merged).expect("write BENCH_hotpath.json");
    println!("\nspliced engine_micro panels into {PATH}");
}

fn main() {
    let budget = Duration::from_millis(400);
    let mut report = JsonReport::new();
    for (k, per) in [(16usize, 1_000usize), (64, 4_000), (256, 4_000)] {
        let n = k * per;
        section(&format!("merge+coalesce of {n} pairs from {k} streams"));
        let streams = make_streams(k, per, 7);
        let concat: Vec<(u64, u64)> =
            streams.iter().flat_map(|v| v.iter()).collect();

        let r = bench("native sort+scan", budget, || {
            black_box(sort_coalesce_pairs(black_box(concat.clone())));
        });
        println!("{r}   ({:.1} Mpairs/s)", r.per_second(n as u64) / 1e6);

        let refs: Vec<&FlatView> = streams.iter().collect();
        let r = bench("native k-way heap merge", budget, || {
            black_box(merge_views(black_box(&refs)));
        });
        println!("{r}   ({:.1} Mpairs/s)", r.per_second(n as u64) / 1e6);
    }

    report.add_value("simd_feature_enabled", if cfg!(feature = "simd") { 1.0 } else { 0.0 });
    bench_kernels(&mut report, budget);
    bench_thread_scaling(&mut report, budget);

    match XlaEngine::load_default() {
        Ok(xla) => {
            for n in [256usize, 4096, 16384] {
                section(&format!("xla AOT pipeline, {n} pairs"));
                let streams = make_streams(8, n / 8, 11);
                let concat: Vec<(u64, u64)> =
                    streams.iter().flat_map(|v| v.iter()).collect();
                let native_out = sort_coalesce_pairs(concat.clone());
                let xla_out = xla.merge_coalesce(concat.clone()).expect("xla");
                assert_eq!(native_out, xla_out, "engine mismatch at n={n}");
                let r = bench("xla merge_coalesce", budget, || {
                    black_box(xla.merge_coalesce(black_box(concat.clone())).unwrap());
                });
                println!("{r}   ({:.2} Mpairs/s)", r.per_second(n as u64) / 1e6);
            }
        }
        Err(e) => println!("\nxla engine skipped: {e}"),
    }

    emit_json(&report);
}
