//! P1 — hot-path microbenchmark: the aggregator merge+coalesce step as
//! (a) native sort_unstable+scan, (b) k-way heap merge over pre-sorted
//! streams, (c) the AOT XLA pipeline (when artifacts exist).  Wall-clock
//! (not simulated) — this is the §Perf measurement harness.
//!
//! `cargo bench --bench engine_micro`

use std::time::Duration;

use tamio::benchkit::{bench, black_box, section};
use tamio::coordinator::merge::{merge_views, sort_coalesce_pairs};
use tamio::mpisim::FlatView;
use tamio::runtime::engine::{SortEngine, XlaEngine};
use tamio::util::SplitMix64;

/// k sorted, mutually disjoint streams with cross-stream coalescible
/// structure: one global request sequence dealt round-robin to streams
/// (overlapping writers are MPI-undefined, so the bench avoids them).
fn make_streams(k: usize, per: usize, seed: u64) -> Vec<FlatView> {
    let mut rng = SplitMix64::new(seed);
    let mut cursor = 0u64;
    let mut streams: Vec<Vec<(u64, u64)>> = vec![Vec::with_capacity(per); k];
    for i in 0..k * per {
        let len = 8 + rng.gen_range(56);
        cursor += if rng.gen_bool(0.5) { 0 } else { rng.gen_range(512) };
        streams[i % k].push((cursor, len));
        cursor += len;
    }
    streams
        .into_iter()
        .map(|pairs| {
            FlatView::from_pairs_unchecked(
                pairs.iter().map(|p| p.0).collect(),
                pairs.iter().map(|p| p.1).collect(),
            )
        })
        .collect()
}

fn main() {
    let budget = Duration::from_millis(400);
    for (k, per) in [(16usize, 1_000usize), (64, 4_000), (256, 4_000)] {
        let n = k * per;
        section(&format!("merge+coalesce of {n} pairs from {k} streams"));
        let streams = make_streams(k, per, 7);
        let concat: Vec<(u64, u64)> =
            streams.iter().flat_map(|v| v.iter()).collect();

        let r = bench("native sort+scan", budget, || {
            black_box(sort_coalesce_pairs(black_box(concat.clone())));
        });
        println!("{r}   ({:.1} Mpairs/s)", r.per_second(n as u64) / 1e6);

        let refs: Vec<&FlatView> = streams.iter().collect();
        let r = bench("native k-way heap merge", budget, || {
            black_box(merge_views(black_box(&refs)));
        });
        println!("{r}   ({:.1} Mpairs/s)", r.per_second(n as u64) / 1e6);
    }

    match XlaEngine::load_default() {
        Ok(xla) => {
            for n in [256usize, 4096, 16384] {
                section(&format!("xla AOT pipeline, {n} pairs"));
                let streams = make_streams(8, n / 8, 11);
                let concat: Vec<(u64, u64)> =
                    streams.iter().flat_map(|v| v.iter()).collect();
                let native_out = sort_coalesce_pairs(concat.clone());
                let xla_out = xla.merge_coalesce(concat.clone()).expect("xla");
                assert_eq!(native_out, xla_out, "engine mismatch at n={n}");
                let r = bench("xla merge_coalesce", budget, || {
                    black_box(xla.merge_coalesce(black_box(concat.clone())).unwrap());
                });
                println!("{r}   ({:.2} Mpairs/s)", r.per_second(n as u64) / 1e6);
            }
        }
        Err(e) => println!("\nxla engine skipped: {e}"),
    }
}
