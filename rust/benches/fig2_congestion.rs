//! Figure 2 — communication-pattern congestion at the global aggregators:
//! per-aggregator in-degree for two-phase vs TAM, plus the Figure 1
//! aggregator-placement examples.
//!
//! `cargo bench --bench fig2_congestion`

use tamio::cluster::Topology;
use tamio::config::RunConfig;
use tamio::coordinator::placement::{
    select_global_aggregators, select_local_aggregators, GlobalPlacement,
};
use tamio::experiments::fig2_congestion;
use tamio::metrics::render_table;
use tamio::workloads::WorkloadKind;

fn main() {
    // --- Figure 1 placement illustration (exact paper example). ---
    println!("Figure 1(a): 3 nodes x 8 ppn, c=4 local aggs, 3 global aggs");
    let topo = Topology::new(3, 8);
    let locals = select_local_aggregators(&topo, 4);
    let globals = select_global_aggregators(&topo, 3, GlobalPlacement::Spread);
    println!("  local aggregators:  {:?}", locals.ranks);
    println!("  global aggregators: {globals:?}");
    println!("Figure 1(b): 6 nodes x 8 ppn, c=4, 3 global aggs");
    let topo_b = Topology::new(6, 8);
    let globals_b = select_global_aggregators(&topo_b, 3, GlobalPlacement::Spread);
    println!(
        "  global aggregators: {globals_b:?} (nodes {:?})",
        globals_b.iter().map(|&r| topo_b.node_of(r)).collect::<Vec<_>>()
    );

    // --- Figure 2 congestion comparison. ---
    for (nodes, ppn) in [(4usize, 16usize), (16, 64)] {
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        cfg.ppn = ppn;
        cfg.workload = WorkloadKind::E3smG;
        cfg.scale = tamio::experiments::auto_scale(
            WorkloadKind::E3smG,
            nodes * ppn,
            100_000,
        );
        println!("\nFigure 2 @ {} nodes x {} ppn (P={}):", nodes, ppn, nodes * ppn);
        let rows = fig2_congestion(&cfg).expect("fig2");
        let headers: Vec<String> =
            ["algorithm", "max in-degree", "mean msgs/agg", "total inter msgs"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let rows: Vec<Vec<String>> = rows
            .into_iter()
            .map(|(a, max, mean, n)| {
                vec![a, max.to_string(), format!("{mean:.1}"), n.to_string()]
            })
            .collect();
        print!("{}", render_table(&headers, &rows));
    }
    println!("\npaper shape: TAM's per-aggregator in-degree is bounded by P_L/P_G,");
    println!("two-phase grows with P/P_G — the congestion Figure 2 illustrates.");
}
