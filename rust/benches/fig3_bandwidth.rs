//! Figure 3 — strong-scaling write bandwidth, TAM(P_L=256) vs two-phase,
//! for all four paper workloads.
//!
//! `cargo bench --bench fig3_bandwidth`
//! Env: TAMIO_BENCH_FULL=1 for the paper grid P=256..16384 (slow on one
//! core); default grid is P=256..4096.  TAMIO_BENCH_BUDGET sets the
//! request budget per run (default 150000).

use tamio::config::RunConfig;
use tamio::experiments::fig3_series;
use tamio::metrics::scaling_table;
use tamio::workloads::WorkloadKind;

fn main() {
    let full = std::env::var("TAMIO_BENCH_FULL").is_ok_and(|v| v == "1");
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    let procs: Vec<usize> = if full {
        vec![256, 1024, 4096, 16384]
    } else {
        vec![256, 1024, 4096]
    };
    let mut cfg = RunConfig::default();
    cfg.ppn = 64;

    println!(
        "Figure 3: strong scaling, ppn=64, stripes 56 x 1 MiB, budget {budget} reqs/run, procs {procs:?}"
    );
    for kind in WorkloadKind::paper_set() {
        // BTIO needs square P: 256, 1024, 4096, 16384 are all squares. OK.
        let series = match fig3_series(&cfg, kind, &procs, budget) {
            Ok(s) => s,
            Err(e) => {
                println!("\n({kind}) skipped: {e}");
                continue;
            }
        };
        println!("\nFigure 3 ({kind}):");
        print!("{}", scaling_table(&kind.to_string(), &series));
        let tam_last = series[0].points.last().unwrap().1;
        let two_last = series[1].points.last().unwrap().1;
        println!(
            "TAM / two-phase at P={}: {:.1}x (paper: 3x-29x at P=16384)",
            series[0].points.last().unwrap().0,
            tam_last / two_last
        );
    }
}
