//! Figure 4 — E3SM G-case timing breakdown vs number of local
//! aggregators, at increasing node counts (paper panels: 4/16/64/256
//! nodes × 64 ppn; the right-most bar is two-phase I/O).
//!
//! `cargo bench --bench fig4_e3sm_g`
//! Env: TAMIO_BENCH_FULL=1 adds the 64- and 256-node panels.

use tamio::experiments::{bench_direction_from_env, run_breakdown_grid};
use tamio::workloads::WorkloadKind;

fn main() {
    let full = std::env::var("TAMIO_BENCH_FULL").is_ok_and(|v| v == "1");
    let nodes: Vec<usize> = if full { vec![4, 16, 64, 256] } else { vec![4, 16] };
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    // Write and read panels (the paper reports both); override with
    // TAMIO_BENCH_DIRECTION=write|read|both.
    let direction = bench_direction_from_env();
    println!("Figure 4: E3SM G breakdown (intra components ~1/P_L, inter ~P_L)");
    run_breakdown_grid(WorkloadKind::E3smG, &nodes, 64, budget, direction).expect("fig4");
}
