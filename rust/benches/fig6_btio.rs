//! Figure 6 — BTIO timing breakdown vs number of local aggregators.
//! The paper highlights the intra-node coalescing here: 335 M / 671 M /
//! 1.34 G posted requests collapse to 84 M / 43 M / 24 M after
//! aggregation (16/64/256 nodes); the bench prints the same progression
//! at its scale.
//!
//! `cargo bench --bench fig6_btio`

use tamio::experiments::{bench_direction_from_env, run_breakdown_grid};
use tamio::workloads::WorkloadKind;

fn main() {
    let full = std::env::var("TAMIO_BENCH_FULL").is_ok_and(|v| v == "1");
    // BTIO needs square P = (nodes*64): nodes 4 -> P=256, 16 -> 1024, ...
    let nodes: Vec<usize> = if full { vec![4, 16, 64, 256] } else { vec![4, 16] };
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    // Write and read panels (the paper reports both); override with
    // TAMIO_BENCH_DIRECTION=write|read|both.
    let direction = bench_direction_from_env();
    println!("Figure 6: BTIO breakdown (block-tridiagonal, high coalesce ratio)");
    run_breakdown_grid(WorkloadKind::Btio, &nodes, 64, budget, direction).expect("fig6");
}
