//! Figure 7 — S3D-IO timing breakdown vs number of local aggregators
//! (block³ checkpoint; most requests coalesce at the local aggregators).
//!
//! `cargo bench --bench fig7_s3d`

use tamio::experiments::{bench_direction_from_env, run_breakdown_grid};
use tamio::workloads::WorkloadKind;

fn main() {
    let full = std::env::var("TAMIO_BENCH_FULL").is_ok_and(|v| v == "1");
    let nodes: Vec<usize> = if full { vec![4, 16, 64, 256] } else { vec![4, 16] };
    let budget: u64 = std::env::var("TAMIO_BENCH_BUDGET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150_000);
    // Write and read panels (the paper reports both); override with
    // TAMIO_BENCH_DIRECTION=write|read|both.
    let direction = bench_direction_from_env();
    println!("Figure 7: S3D-IO breakdown (inter-node aggregation dominates)");
    run_breakdown_grid(WorkloadKind::S3d, &nodes, 64, budget, direction).expect("fig7");
}
