//! Aggregator hot-path microbenchmarks (§Perf tentpole): the streaming
//! pipeline vs its pre-streaming baselines, wall-clock, at 1k/16k/128k
//! requests.
//!
//! * merge — `SortEngine::merge_sorted` (O(n log k) gallop heap merge over
//!   k already-sorted peer streams) vs the flatten + full re-sort baseline
//!   (`sort_coalesce_pairs` of the concatenation, what the round loop did
//!   before).
//! * scatter — two-pointer payload scatter into a reused buffer vs the
//!   per-request binary-search reference.
//! * cost_phase — dense-rank accumulators on a 16384-rank topology.
//! * calc_my_req — dense destination accumulators (single open batch +
//!   CSR round index; the old per-destination `HashMap` path).
//! * read_view — vectored read into a reused buffer vs the per-request
//!   `read_at` loop (one `Vec` allocation per request, what
//!   `run_collective_read` did before the streaming treatment).
//! * collective_write — `run_collective_write` end-to-end, both
//!   algorithms (the write panel twin of the read cases below; both
//!   drive the same direction-generic `run_exchange` loop).
//! * collective_read — `run_collective_read` end-to-end, both algorithms.
//! * plan_cache — cold plan construction vs warm fingerprint+LRU hit
//!   (the plan-oracle panels), at 64 ranks and the 16384-rank point.
//!
//! Writes `BENCH_hotpath.json` (median wall times + speedups) in the
//! working directory.
//!
//! `cargo bench --bench hotpath`

use std::time::Duration;

use tamio::benchkit::{bench, black_box, section, JsonReport};
use tamio::cluster::Topology;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{
    run_collective_read, run_collective_read_with, run_collective_write,
    run_collective_write_with, Algorithm, Direction, ExchangeArena,
};
use tamio::coordinator::filedomain::FileDomains;
use tamio::coordinator::merge::{
    scatter_into_binary_search, scatter_into_buf, sort_coalesce_pairs, ReqBatch,
};
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::plancache::{
    build_collective_plan, encode_plan, fingerprint_collective, PlanCache,
};
use tamio::coordinator::reqcalc::calc_my_req;
use tamio::coordinator::tam::TamConfig;
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::lustre::{IoModel, LustreConfig, LustreFile, OstStats};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::FlatView;
use tamio::netmodel::phase::{cost_phase, Message};
use tamio::netmodel::NetParams;
use tamio::runtime::engine::{NativeEngine, SortEngine};
use tamio::util::{par_map, SplitMix64};

/// Request counts per experiment (the ISSUE's 1k/16k/128k grid).
const SIZES: [usize; 3] = [1_000, 16_000, 128_000];
/// Sorted peer streams per merge (the acceptance floor is ≥ 8).
const K: usize = 8;
/// Consecutive requests per stream before the deal rotates — the
/// block-partitioned adjacency real MPI file views exhibit (§V-C).
const RUN: usize = 8;

/// One global sorted, disjoint request sequence dealt to `k` streams in
/// runs of `RUN`.
fn make_streams(k: usize, total: usize, seed: u64) -> Vec<FlatView> {
    let mut rng = SplitMix64::new(seed);
    let mut streams: Vec<Vec<(u64, u64)>> = vec![Vec::with_capacity(total / k + RUN); k];
    let mut cursor = 0u64;
    for i in 0..total {
        let s = (i / RUN) % k;
        let len = 8 + rng.gen_range(56);
        if rng.gen_bool(0.5) {
            cursor += rng.gen_range(512);
        }
        streams[s].push((cursor, len));
        cursor += len;
    }
    streams
        .into_iter()
        .map(|pairs| FlatView::from_pairs(pairs).expect("generator emits sorted views"))
        .collect()
}

fn bench_merge(report: &mut JsonReport, budget: Duration) {
    let engine = NativeEngine;
    for &n in &SIZES {
        section(&format!("merge: {n} requests from {K} sorted streams"));
        let streams = make_streams(K, n, 0xB0B + n as u64);
        let refs: Vec<&FlatView> = streams.iter().collect();

        // Correctness pin before timing anything.
        let concat: Vec<(u64, u64)> = streams.iter().flat_map(|v| v.iter()).collect();
        let want = sort_coalesce_pairs(concat);
        let got = engine.merge_sorted(&refs).expect("native merge");
        assert_eq!(
            got.iter().collect::<Vec<_>>(),
            want,
            "merge_sorted != flatten+re-sort at n={n}"
        );

        let base = bench(&format!("flatten+re-sort/{n}"), budget, || {
            let concat: Vec<(u64, u64)> = streams.iter().flat_map(|v| v.iter()).collect();
            black_box(sort_coalesce_pairs(black_box(concat)));
        });
        println!("{base}");
        let kway = bench(&format!("merge_sorted/{n}"), budget, || {
            black_box(engine.merge_sorted(black_box(&refs)).unwrap());
        });
        println!("{kway}");
        let speedup = base.median.as_secs_f64() / kway.median.as_secs_f64().max(1e-12);
        println!(
            "merge_sorted speedup over flatten+re-sort at n={n}: {speedup:.2}x {}",
            if speedup > 1.0 { "(k-way wins)" } else { "(baseline wins)" }
        );
        report.add(&base);
        report.add(&kway);
        report.add_value(&format!("merge_speedup/{n}"), speedup);
    }
}

fn bench_scatter(report: &mut JsonReport, budget: Duration) {
    let engine = NativeEngine;
    for &n in &SIZES {
        section(&format!("scatter: {n} requests, {K} payload batches"));
        let streams = make_streams(K, n, 0x5CA7 + n as u64);
        let batches: Vec<ReqBatch> = streams
            .into_iter()
            .enumerate()
            .map(|(i, v)| {
                let payload = vec![(i as u8).wrapping_mul(37); v.total_bytes() as usize];
                ReqBatch::new(v, payload)
            })
            .collect();
        let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
        let merged = engine.merge_sorted(&views).expect("merge");

        // Correctness pin.
        let mut buf = Vec::new();
        let moved = scatter_into_buf(&merged, &batches, &mut buf);
        let (want, want_moved) = scatter_into_binary_search(&merged, &batches);
        assert_eq!(buf, want, "scatter mismatch at n={n}");
        assert_eq!(moved, want_moved);

        let base = bench(&format!("scatter_binary_search/{n}"), budget, || {
            black_box(scatter_into_binary_search(black_box(&merged), black_box(&batches)));
        });
        println!("{base}");
        let two = bench(&format!("scatter_two_pointer/{n}"), budget, || {
            black_box(scatter_into_buf(
                black_box(&merged),
                black_box(&batches),
                black_box(&mut buf),
            ));
        });
        println!("{two}");
        let speedup = base.median.as_secs_f64() / two.median.as_secs_f64().max(1e-12);
        println!("two-pointer scatter speedup at n={n}: {speedup:.2}x");
        report.add(&base);
        report.add(&two);
        report.add_value(&format!("scatter_speedup/{n}"), speedup);
    }
}

fn bench_cost_phase(report: &mut JsonReport, budget: Duration) {
    // The ROADMAP north-star topology: 16384 ranks on 256 nodes, with the
    // all-to-many pattern that stresses the receiver accumulators.
    let topo = Topology::new(256, 64);
    let params = NetParams::default();
    let n_agg = 64usize;
    let spacing = topo.nprocs() / n_agg;
    for &n in &SIZES {
        section(&format!("cost_phase: {n} messages, P={} (dense-rank)", topo.nprocs()));
        let mut rng = SplitMix64::new(0xC057 + n as u64);
        let msgs: Vec<Message> = (0..n)
            .map(|i| {
                Message::new(
                    rng.gen_range(topo.nprocs() as u64) as usize,
                    (i % n_agg) * spacing,
                    1024 + rng.gen_range(1 << 14),
                )
            })
            .collect();
        let r = bench(&format!("cost_phase/{n}"), budget, || {
            black_box(cost_phase(black_box(&params), black_box(&topo), black_box(&msgs)));
        });
        println!("{r}   ({:.2} Mmsgs/s)", r.per_second(n as u64) / 1e6);
        report.add(&r);
    }
}

fn bench_reqcalc(report: &mut JsonReport, budget: Duration) {
    // Dense calc_my_req (single open accumulator + CSR round index) on a
    // single sorted view classified against a 64-aggregator domain set —
    // the per-requester work of both exchange directions.
    for &n in &SIZES {
        section(&format!("calc_my_req: {n} requests, 64 aggregators (dense)"));
        let view = make_streams(1, n, 0xCA1C + n as u64).remove(0);
        let lo = view.min_offset().unwrap_or(0);
        let hi = view.max_end().unwrap_or(0);
        // Stripe sized so a fraction of requests straddles a boundary.
        let domains = FileDomains::new(LustreConfig::new(4096, 64), lo, hi, 64);
        let batch = ReqBatch::new(view, Vec::new()); // metadata-only (read side)
        let r = bench(&format!("calc_my_req/{n}"), budget, || {
            black_box(calc_my_req(black_box(&domains), black_box(&batch)).expect("calc_my_req"));
        });
        println!("{r}   ({:.2} Mreqs/s)", r.per_second(n as u64) / 1e6);
        report.add(&r);
    }
}

fn bench_read_view(report: &mut JsonReport, budget: Duration) {
    for &n in &SIZES {
        section(&format!("read_view: {n} segments, vectored vs read_at loop"));
        let view = make_streams(1, n, 0x4EAD + n as u64).remove(0);
        let payload = deterministic_payload(17, 0, view.total_bytes());
        let mut file = LustreFile::new(LustreConfig::new(1 << 16, 8));
        file.begin_round();
        file.write_view(0, &view, &payload).expect("seed write");

        // Correctness pin before timing anything.
        let mut buf = Vec::new();
        let mut stats = vec![OstStats::default(); file.config().stripe_count];
        file.read_view(&view, &mut buf, &mut stats).expect("read_view");
        let mut want = Vec::with_capacity(buf.len());
        for (off, len) in view.iter() {
            want.extend_from_slice(&file.read_at(off, len));
        }
        assert_eq!(buf, want, "read_view != read_at loop at n={n}");

        let base = bench(&format!("read_at_loop/{n}"), budget, || {
            let mut sum = 0usize;
            for (off, len) in view.iter() {
                sum += black_box(file.read_at(off, len)).len();
            }
            black_box(sum);
        });
        println!("{base}");
        let vectored = bench(&format!("read_view/{n}"), budget, || {
            file.read_view(black_box(&view), black_box(&mut buf), black_box(&mut stats))
                .expect("read_view");
        });
        println!("{vectored}");
        let speedup = base.median.as_secs_f64() / vectored.median.as_secs_f64().max(1e-12);
        println!("vectored read_view speedup at n={n}: {speedup:.2}x");
        report.add(&base);
        report.add(&vectored);
        report.add_value(&format!("read_view_speedup/{n}"), speedup);
    }
}

fn bench_collective_write(report: &mut JsonReport, budget: Duration) {
    // End-to-end write path on 64 ranks — the write panel alongside the
    // read panel below, through the same direction-generic exchange loop.
    let topo = Topology::new(4, 16);
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 8,
    };
    for &n in &SIZES {
        section(&format!("collective_write: {n} requests over {} ranks", topo.nprocs()));
        let streams = make_streams(topo.nprocs(), n, 0xC0DE + n as u64);
        let ranks: Vec<(usize, ReqBatch)> = streams
            .into_iter()
            .enumerate()
            .map(|(r, v)| {
                let payload = deterministic_payload(29, r, v.total_bytes());
                (r, ReqBatch::new(v, payload))
            })
            .collect();

        // Correctness pin: rank 0's bytes must land exactly.
        let mut file = LustreFile::new(LustreConfig::new(1 << 14, 8));
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file)
            .expect("pin write");
        let (r0, b0) = &ranks[0];
        let mut got = Vec::new();
        for (off, len) in b0.view.iter() {
            got.extend_from_slice(&file.read_at(off, len));
        }
        assert_eq!(&got, &b0.payload, "rank {r0} write pin mismatch at n={n}");

        // run_collective_write consumes its batches, so the timed closures
        // clone them each iteration; measure the clone alone so readers
        // can subtract it from the collective medians.
        let clone_cost = bench(&format!("ranks_clone/{n}"), budget, || {
            black_box(ranks.clone());
        });
        println!("{clone_cost}");
        report.add(&clone_cost);

        for (label, algo) in [
            ("collective_write_2p", Algorithm::TwoPhase),
            ("collective_write_tam", Algorithm::Tam(TamConfig { total_local_aggregators: 16 })),
        ] {
            // One untimed write first so every timed iteration runs in the
            // warm-overwrite regime (stripe blocks already allocated) —
            // the steady state, matching how the read cases time a
            // pre-populated file.
            let mut file = LustreFile::new(LustreConfig::new(1 << 14, 8));
            run_collective_write(&ctx, algo, ranks.clone(), &mut file).expect("warm-up");
            let r = bench(&format!("{label}/{n}"), budget, || {
                black_box(
                    run_collective_write(
                        black_box(&ctx),
                        black_box(algo),
                        black_box(ranks.clone()),
                        black_box(&mut file),
                    )
                    .expect("write"),
                );
            });
            println!("{r}   ({:.2} Mreqs/s)", r.per_second(n as u64) / 1e6);
            report.add(&r);
        }
    }
}

fn bench_collective_read(report: &mut JsonReport, budget: Duration) {
    // End-to-end read path on 64 ranks: write once, then time
    // run_collective_read for both algorithms at n total requests.
    let topo = Topology::new(4, 16);
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 8,
    };
    for &n in &SIZES {
        section(&format!("collective_read: {n} requests over {} ranks", topo.nprocs()));
        let streams = make_streams(topo.nprocs(), n, 0xC011 + n as u64);
        let ranks: Vec<(usize, ReqBatch)> = streams
            .into_iter()
            .enumerate()
            .map(|(r, v)| {
                let payload = deterministic_payload(23, r, v.total_bytes());
                (r, ReqBatch::new(v, payload))
            })
            .collect();
        let mut file = LustreFile::new(LustreConfig::new(1 << 14, 8));
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file)
            .expect("seed write");
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();

        // run_collective_read consumes its views, so the timed closures
        // below clone them each iteration; measure the clone alone so the
        // report lets readers subtract it from the collective medians.
        let clone_cost = bench(&format!("views_clone/{n}"), budget, || {
            black_box(views.clone());
        });
        println!("{clone_cost}");
        report.add(&clone_cost);

        for (label, algo) in [
            ("collective_read_2p", Algorithm::TwoPhase),
            ("collective_read_tam", Algorithm::Tam(TamConfig { total_local_aggregators: 16 })),
        ] {
            // Correctness pin: read-back must be bit-identical.
            let (got, _) =
                run_collective_read(&ctx, algo, views.clone(), &file).expect("read");
            for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
                assert_eq!(payload, &want.payload, "{label} rank {r} mismatch at n={n}");
            }
            let r = bench(&format!("{label}/{n}"), budget, || {
                black_box(
                    run_collective_read(
                        black_box(&ctx),
                        black_box(algo),
                        black_box(views.clone()),
                        black_box(&file),
                    )
                    .expect("read"),
                );
            });
            println!("{r}   ({:.2} Mreqs/s)", r.per_second(n as u64) / 1e6);
            report.add(&r);
        }
    }
}

/// The paper's headline scale point: 16384 ranks on 256 nodes (§V, the
/// 29× configuration).  One contiguous 512-byte block per rank (8 pieces)
/// keeps the byte volume at 8 MiB so the cases measure the *per-rank
/// machinery* — CSR-slab `calc_my_req` across all ranks, and the
/// arena-backed round loop end-to-end in both directions with a
/// persistent `ExchangeArena` (the steady state a sweep runs in).
fn bench_scale_16k(report: &mut JsonReport, budget: Duration) {
    const NODES: usize = 256;
    const PPN: usize = 64;
    const N_AGG: usize = 64;
    const BLOCK: u64 = 512;
    const PIECES: u64 = 8;
    let topo = Topology::new(NODES, PPN);
    let p = topo.nprocs();
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: N_AGG,
    };
    let ranks: Vec<(usize, ReqBatch)> = (0..p)
        .map(|r| {
            let base = r as u64 * BLOCK;
            let q = BLOCK / PIECES;
            let view =
                FlatView::from_pairs((0..PIECES).map(|i| (base + i * q, q)).collect()).unwrap();
            (r, ReqBatch::new(view, deterministic_payload(43, r, BLOCK)))
        })
        .collect();
    let total_reqs = (p as u64) * PIECES;

    section(&format!("scale point: P={p} ({NODES} nodes x {PPN} ppn), {total_reqs} requests"));

    // calc_my_req across every rank (the setup stage the CSR slab + par
    // classify target), stripe sized so requests straddle boundaries.
    let domains = FileDomains::new(
        LustreConfig::new(4096, N_AGG),
        0,
        p as u64 * BLOCK,
        N_AGG,
    );
    let meta_batches: Vec<ReqBatch> = ranks
        .iter()
        .map(|(_, b)| ReqBatch::new(b.view.clone(), Vec::new()))
        .collect();
    let r = bench(&format!("calc_my_req_16k/{total_reqs}"), budget, || {
        let reqs = par_map(
            meta_batches.iter().collect::<Vec<_>>(),
            |b| calc_my_req(black_box(&domains), b).expect("calc_my_req"),
        );
        black_box(reqs.iter().map(|mr| mr.pieces).sum::<u64>());
    });
    println!("{r}   ({:.2} Mreqs/s)", r.per_second(total_reqs) / 1e6);
    report.add(&r);

    // End-to-end, both directions, with the clone cost reported so
    // readers can subtract it from the collective medians.
    let clone_cost = bench(&format!("ranks_clone_16k/{total_reqs}"), budget, || {
        black_box(ranks.clone());
    });
    println!("{clone_cost}");
    report.add(&clone_cost);

    for (label, algo) in [
        ("collective_write_2p_16k", Algorithm::TwoPhase),
        (
            "collective_write_tam_16k",
            Algorithm::Tam(TamConfig { total_local_aggregators: 256 }),
        ),
    ] {
        let mut arena = ExchangeArena::default();
        let mut file = LustreFile::new(LustreConfig::new(4096, N_AGG));
        // Warm-up: overwrite regime + warm arena (the sweep steady state).
        run_collective_write_with(&ctx, algo, ranks.clone(), &mut file, &mut arena)
            .expect("warm-up");
        let r = bench(&format!("{label}/{total_reqs}"), budget, || {
            black_box(
                run_collective_write_with(
                    black_box(&ctx),
                    black_box(algo),
                    black_box(ranks.clone()),
                    black_box(&mut file),
                    black_box(&mut arena),
                )
                .expect("write"),
            );
        });
        println!("{r}   ({:.2} Mreqs/s)", r.per_second(total_reqs) / 1e6);
        report.add(&r);
    }

    let mut file = LustreFile::new(LustreConfig::new(4096, N_AGG));
    run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file)
        .expect("seed write");
    let views: Vec<(usize, FlatView)> =
        ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
    let views_clone = bench(&format!("views_clone_16k/{total_reqs}"), budget, || {
        black_box(views.clone());
    });
    println!("{views_clone}");
    report.add(&views_clone);
    for (label, algo) in [
        ("collective_read_2p_16k", Algorithm::TwoPhase),
        (
            "collective_read_tam_16k",
            Algorithm::Tam(TamConfig { total_local_aggregators: 256 }),
        ),
    ] {
        let mut arena = ExchangeArena::default();
        // Correctness pin + arena warm-up in one pass.
        let (got, _) = run_collective_read_with(&ctx, algo, views.clone(), &file, &mut arena)
            .expect("pin read");
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "{label} rank {r} mismatch");
        }
        let r = bench(&format!("{label}/{total_reqs}"), budget, || {
            black_box(
                run_collective_read_with(
                    black_box(&ctx),
                    black_box(algo),
                    black_box(views.clone()),
                    black_box(&file),
                    black_box(&mut arena),
                )
                .expect("read"),
            );
        });
        println!("{r}   ({:.2} Mreqs/s)", r.per_second(total_reqs) / 1e6);
        report.add(&r);
    }
}

/// Plan-oracle panels: cold (fingerprint + full plan construction) vs
/// warm (fingerprint + LRU hit) — the setup cost a cache hit deletes.
/// One small point (64 ranks, 16k requests) and the 16384-rank scale
/// point from [`bench_scale_16k`].
fn bench_plan_cache(report: &mut JsonReport, budget: Duration) {
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let small = Topology::new(4, 16);
    let big = Topology::new(256, 64);
    let cases: Vec<(&str, &Topology, usize, LustreConfig, Vec<(usize, FlatView)>)> = vec![
        (
            "64r",
            &small,
            8,
            LustreConfig::new(1 << 14, 8),
            make_streams(small.nprocs(), 16_000, 0x9A11)
                .into_iter()
                .enumerate()
                .collect(),
        ),
        (
            "16k",
            &big,
            64,
            LustreConfig::new(4096, 64),
            (0..big.nprocs())
                .map(|r| {
                    let base = r as u64 * 512;
                    let view = FlatView::from_pairs(
                        (0..8u64).map(|i| (base + i * 64, 64)).collect(),
                    )
                    .unwrap();
                    (r, view)
                })
                .collect(),
        ),
    ];
    for (tag, topo, n_agg, file_cfg, views) in cases {
        let ctx = CollectiveCtx {
            topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: n_agg,
        };
        let algo = Algorithm::Tam(TamConfig { total_local_aggregators: 256.min(topo.nprocs()) });
        section(&format!(
            "plan_cache: P={} ({tag}), cold build vs warm hit",
            topo.nprocs()
        ));
        let fp = fingerprint_collective(
            &ctx,
            &algo,
            Direction::Write,
            &file_cfg,
            views.iter().map(|(r, v)| (*r, v)),
        );

        // Correctness pin before timing: a warm lookup must return a plan
        // byte-identical to an independent cold build.
        let cold_plan = build_collective_plan(&ctx, &algo, Direction::Write, &views, &file_cfg, fp)
            .expect("cold build");
        let mut cache = PlanCache::in_memory(4);
        let warm_plan = cache
            .get_or_build(fp, || {
                build_collective_plan(&ctx, &algo, Direction::Write, &views, &file_cfg, fp)
            })
            .expect("prime cache");
        assert_eq!(
            encode_plan(&cold_plan),
            encode_plan(warm_plan),
            "warm plan != cold plan at {tag}"
        );

        let cold = bench(&format!("plan_cold_build/{tag}"), budget, || {
            let fp = fingerprint_collective(
                black_box(&ctx),
                &algo,
                Direction::Write,
                &file_cfg,
                views.iter().map(|(r, v)| (*r, v)),
            );
            black_box(
                build_collective_plan(
                    &ctx,
                    &algo,
                    Direction::Write,
                    black_box(&views),
                    &file_cfg,
                    fp,
                )
                .expect("build"),
            );
        });
        println!("{cold}");
        let warm = bench(&format!("plan_warm_hit/{tag}"), budget, || {
            let fp = fingerprint_collective(
                black_box(&ctx),
                &algo,
                Direction::Write,
                &file_cfg,
                views.iter().map(|(r, v)| (*r, v)),
            );
            let plan = cache
                .get_or_build(fp, || unreachable!("warm lookup must hit"))
                .expect("hit");
            black_box(plan.exchange.n_rounds);
        });
        println!("{warm}");
        let speedup = cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12);
        println!("plan-cache hit speedup at {tag}: {speedup:.1}x");
        report.add(&cold);
        report.add(&warm);
        report.add_value(&format!("plan_cache_speedup/{tag}"), speedup);
    }
}

fn main() {
    let budget = Duration::from_millis(300);
    let mut report = JsonReport::new();
    bench_merge(&mut report, budget);
    bench_scatter(&mut report, budget);
    bench_cost_phase(&mut report, budget);
    bench_reqcalc(&mut report, budget);
    bench_read_view(&mut report, budget);
    bench_collective_write(&mut report, budget);
    bench_collective_read(&mut report, budget);
    bench_scale_16k(&mut report, budget);
    bench_plan_cache(&mut report, budget);
    report.write("BENCH_hotpath.json").expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json");
}
