//! Table I — dataset request counts and write amounts: paper-scale
//! analytic figures next to this run's scaled, *measured* numbers.
//!
//! `cargo bench --bench table1`
//! Env: TAMIO_BENCH_P (default 1024), TAMIO_BENCH_BUDGET (default 200000).

use tamio::cluster::Topology;
use tamio::experiments::table1_rows;
use tamio::metrics::render_table;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let p = env_usize("TAMIO_BENCH_P", 1024);
    let ppn = env_usize("TAMIO_BENCH_PPN", 64);
    let budget = env_usize("TAMIO_BENCH_BUDGET", 200_000) as u64;
    let topo = Topology::new(p / ppn, ppn);
    println!("Table I @ P={p} ({} nodes x {ppn} ppn), budget {budget} requests", p / ppn);

    let rows = table1_rows(&topo, budget).expect("table1");
    let headers: Vec<String> = [
        "dataset",
        "paper #reqs",
        "paper bytes",
        "run #reqs",
        "run bytes",
        "scale",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    print!("{}", render_table(&headers, &rows));

    println!("paper Table I reference:");
    println!("  E3SM G  1.72e8..1.76e8 reqs   85 GiB");
    println!("  E3SM F  1.35e9..1.37e9 reqs   14 GiB");
    println!("  BTIO    512^2*40*sqrt(P) reqs 200 GiB");
    println!("  S3D-IO  800^2*y*z reqs        61 GiB");
}
