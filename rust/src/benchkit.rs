//! Minimal micro-benchmark harness (criterion is not in the image).
//!
//! Benches are `harness = false` binaries; they call [`bench`] for
//! wall-time measurements (engine microbenches, perf pass) and otherwise
//! print simulated-time tables from the experiment drivers.

use std::time::{Duration, Instant};

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Bench label.
    pub name: String,
    /// Number of measured iterations.
    pub iters: usize,
    /// Minimum iteration time.
    pub min: Duration,
    /// Median iteration time.
    pub median: Duration,
    /// Mean iteration time.
    pub mean: Duration,
    /// Maximum iteration time.
    pub max: Duration,
}

impl BenchResult {
    /// Throughput given items processed per iteration.
    pub fn per_second(&self, items: u64) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} iters={:<4} min={:>10.3?} median={:>10.3?} mean={:>10.3?} max={:>10.3?}",
            self.name, self.iters, self.min, self.median, self.mean, self.max
        )
    }
}

/// Measure `f` with warmup; iteration count adapts to hit ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().max(Duration::from_nanos(1));
    let iters = (budget.as_secs_f64() / first.as_secs_f64()).clamp(1.0, 1000.0) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let sum: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[samples.len() / 2],
        mean: sum / samples.len() as u32,
        max: *samples.last().unwrap(),
    }
}

/// Prevent the optimizer from discarding a value (std::hint wrapper).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Accumulates [`BenchResult`]s (plus derived scalars such as speedup
/// ratios) and writes them as a JSON report, e.g. `BENCH_hotpath.json` —
/// the machine-readable twin of the printed tables for CI trend tracking.
#[derive(Debug, Default)]
pub struct JsonReport {
    entries: Vec<String>,
}

impl JsonReport {
    /// New empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one measured result (times in microseconds).
    pub fn add(&mut self, r: &BenchResult) {
        self.entries.push(
            crate::metrics::report::JsonWriter::new()
                .str("name", &r.name)
                .int("iters", r.iters as u64)
                .num("min_us", r.min.as_secs_f64() * 1e6)
                .num("median_us", r.median.as_secs_f64() * 1e6)
                .num("mean_us", r.mean.as_secs_f64() * 1e6)
                .num("max_us", r.max.as_secs_f64() * 1e6)
                .finish(),
        );
    }

    /// Append a derived scalar (e.g. a speedup ratio).
    pub fn add_value(&mut self, name: &str, value: f64) {
        self.entries.push(
            crate::metrics::report::JsonWriter::new()
                .str("name", name)
                .num("value", value)
                .finish(),
        );
    }

    /// Serialize the report object.
    pub fn to_json(&self) -> String {
        format!("{{\"benches\": [{}]}}", self.entries.join(", "))
    }

    /// Write the report to `path`.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> crate::error::Result<()> {
        std::fs::write(path, self.to_json())?;
        Ok(())
    }
}

/// Print a bench-section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", Duration::from_millis(20), || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(r.iters >= 1);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.per_second(10_000) > 0.0);
    }

    #[test]
    fn json_report_serializes_results_and_values() {
        let r = bench("tiny", Duration::from_millis(1), || {
            black_box(1 + 1);
        });
        let mut rep = JsonReport::new();
        rep.add(&r);
        rep.add_value("speedup/16k", 2.5);
        let j = rep.to_json();
        assert!(j.starts_with("{\"benches\": ["));
        assert!(j.contains("\"name\": \"tiny\""));
        assert!(j.contains("median_us"));
        assert!(j.contains("\"name\": \"speedup/16k\""));
        assert!(j.contains("\"value\": 2.5"));
    }
}
