//! Compute-node topology: ranks ↔ (node, local rank).
//!
//! The paper's testbed is `nodes × ppn` MPI ranks with contiguous rank ids
//! per node (block placement, the ALPS/aprun default on the Cray XC40).
//! All aggregator-selection policies and the intra-/inter-node distinction
//! in the network model are defined in terms of this mapping.

/// Cluster topology: `nodes` compute nodes, `ppn` MPI processes per node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Number of compute nodes.
    pub nodes: usize,
    /// MPI processes per node (`q` in the paper).
    pub ppn: usize,
}

impl Topology {
    /// Create a topology; panics on zero sizes (a config-layer invariant).
    pub fn new(nodes: usize, ppn: usize) -> Self {
        assert!(nodes > 0 && ppn > 0, "topology must be non-empty");
        Self { nodes, ppn }
    }

    /// Total number of MPI processes `P`.
    pub fn nprocs(&self) -> usize {
        self.nodes * self.ppn
    }

    /// Node hosting `rank` (block placement: ranks 0..ppn on node 0, …).
    pub fn node_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.nprocs());
        rank / self.ppn
    }

    /// Rank's index within its node.
    pub fn local_rank(&self, rank: usize) -> usize {
        rank % self.ppn
    }

    /// Global rank of `(node, local)`.
    pub fn rank_of(&self, node: usize, local: usize) -> usize {
        debug_assert!(node < self.nodes && local < self.ppn);
        node * self.ppn + local
    }

    /// Whether two ranks share a compute node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// All ranks on `node`, ascending.
    pub fn ranks_on_node(&self, node: usize) -> std::ops::Range<usize> {
        (node * self.ppn)..((node + 1) * self.ppn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_round_trips() {
        let t = Topology::new(4, 8);
        assert_eq!(t.nprocs(), 32);
        for r in 0..t.nprocs() {
            assert_eq!(t.rank_of(t.node_of(r), t.local_rank(r)), r);
        }
    }

    #[test]
    fn block_placement() {
        let t = Topology::new(3, 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.local_rank(17), 1);
    }

    #[test]
    fn same_node_predicate() {
        let t = Topology::new(2, 4);
        assert!(t.same_node(0, 3));
        assert!(!t.same_node(3, 4));
    }

    #[test]
    fn ranks_on_node_range() {
        let t = Topology::new(3, 4);
        assert_eq!(t.ranks_on_node(1).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
    }

    #[test]
    #[should_panic]
    fn zero_topology_panics() {
        Topology::new(0, 4);
    }
}
