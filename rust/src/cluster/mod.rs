//! Compute-machine topology: ranks ↔ (node, local rank) plus the machine
//! hierarchy the aggregation tree is built over.
//!
//! The paper's testbed is `nodes × ppn` MPI ranks with contiguous rank ids
//! per node (block placement, the ALPS/aprun default on the Cray XC40).
//! All aggregator-selection policies are defined in terms of this mapping.
//!
//! On top of the flat node grid the topology can expose two further
//! hierarchy levels (DESIGN.md §Aggregation tree):
//!
//! * **sockets** — `sockets_per_node` NUMA domains inside each node, with
//!   [`RankPlacement::Block`] (contiguous local ranks per socket) or
//!   [`RankPlacement::RoundRobin`] (strided) rank placement;
//! * **switch groups** — `nodes_per_switch` nodes behind one leaf switch,
//!   again block or round-robin over node ids.
//!
//! The default `Topology::new(nodes, ppn)` is the 2-level degenerate form
//! (1 socket per node, a single switch tier): every existing flat-topology
//! call site behaves exactly as before.  The network model prices each
//! message by its [`LinkTier`] — the innermost hierarchy level containing
//! both endpoints — so cost attribution follows the aggregation tree.

/// Named machine-hierarchy levels, innermost (closest to a rank) first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelKind {
    /// NUMA domain / socket inside a node.
    Socket,
    /// Compute node.
    Node,
    /// Leaf-switch group of nodes.
    Switch,
}

impl LevelKind {
    /// Short label for plans, metrics rows and CLI syntax.
    pub fn label(self) -> &'static str {
        match self {
            LevelKind::Socket => "socket",
            LevelKind::Node => "node",
            LevelKind::Switch => "switch",
        }
    }
}

impl std::fmt::Display for LevelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// How ranks (or nodes) are dealt into the groups of a hierarchy level.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RankPlacement {
    /// Contiguous ids per group (the ALPS/aprun default).
    #[default]
    Block,
    /// Strided ids (`id % groups`), the cyclic launcher layout.
    RoundRobin,
}

impl std::fmt::Display for RankPlacement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RankPlacement::Block => write!(f, "block"),
            RankPlacement::RoundRobin => write!(f, "round-robin"),
        }
    }
}

/// Link tier of one message: the innermost hierarchy level containing both
/// endpoints.  The network model holds one α–β row per tier
/// ([`crate::netmodel::NetParams::msg_cost_tier`]); on a flat topology only
/// [`LinkTier::Node`] and [`LinkTier::Global`] occur, reproducing the old
/// binary intra/inter split bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LinkTier {
    /// Same node, same socket (shared L3 / NUMA-local memory).
    Socket,
    /// Same node, cross-socket (shared memory over the inter-socket bus).
    Node,
    /// Different nodes behind the same leaf switch.
    Switch,
    /// Different switch groups (full network traversal).
    Global,
}

impl LinkTier {
    /// Whether the message never leaves the node (no NIC involvement).
    pub fn is_local(self) -> bool {
        matches!(self, LinkTier::Socket | LinkTier::Node)
    }
}

/// Cluster topology: `nodes` compute nodes, `ppn` MPI processes per node,
/// plus the optional socket and switch hierarchy levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Number of compute nodes.
    pub nodes: usize,
    /// MPI processes per node (`q` in the paper).
    pub ppn: usize,
    /// NUMA domains per node (1 = no sub-node level).
    pub sockets_per_node: usize,
    /// Nodes per leaf-switch group (0 = single flat switch tier).
    pub nodes_per_switch: usize,
    /// Rank→socket and node→switch placement within the hierarchy levels
    /// (node placement itself is always block — rank ids are contiguous
    /// per node, the invariant every dense accumulator relies on).
    pub placement: RankPlacement,
}

impl Topology {
    /// Create a flat topology; panics on zero sizes (a config-layer
    /// invariant).  The degenerate hierarchy: one socket per node, one
    /// switch tier.
    pub fn new(nodes: usize, ppn: usize) -> Self {
        Self::hierarchical(nodes, ppn, 1, 0, RankPlacement::Block)
    }

    /// Create a topology with explicit hierarchy levels.
    ///
    /// `sockets_per_node == 1` disables the socket level;
    /// `nodes_per_switch == 0` (or `>= nodes`) disables the switch level.
    pub fn hierarchical(
        nodes: usize,
        ppn: usize,
        sockets_per_node: usize,
        nodes_per_switch: usize,
        placement: RankPlacement,
    ) -> Self {
        assert!(nodes > 0 && ppn > 0, "topology must be non-empty");
        assert!(
            sockets_per_node >= 1 && sockets_per_node <= ppn,
            "sockets_per_node must be in 1..=ppn"
        );
        Self { nodes, ppn, sockets_per_node, nodes_per_switch, placement }
    }

    /// Total number of MPI processes `P`.
    pub fn nprocs(&self) -> usize {
        self.nodes * self.ppn
    }

    /// Node hosting `rank` (block placement: ranks 0..ppn on node 0, …).
    pub fn node_of(&self, rank: usize) -> usize {
        debug_assert!(rank < self.nprocs());
        rank / self.ppn
    }

    /// Rank's index within its node.
    pub fn local_rank(&self, rank: usize) -> usize {
        rank % self.ppn
    }

    /// Global rank of `(node, local)`.
    pub fn rank_of(&self, node: usize, local: usize) -> usize {
        debug_assert!(node < self.nodes && local < self.ppn);
        node * self.ppn + local
    }

    /// Whether two ranks share a compute node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// All ranks on `node`, ascending.
    pub fn ranks_on_node(&self, node: usize) -> std::ops::Range<usize> {
        (node * self.ppn)..((node + 1) * self.ppn)
    }

    // ---- socket level ----

    /// Socket index of `rank` within its node.
    pub fn socket_in_node(&self, rank: usize) -> usize {
        let l = self.local_rank(rank);
        match self.placement {
            // Balanced contiguous split: the first `ppn % spn` sockets get
            // one extra local rank.
            RankPlacement::Block => l * self.sockets_per_node / self.ppn,
            RankPlacement::RoundRobin => l % self.sockets_per_node,
        }
    }

    /// Global socket id of `rank` (node-major).
    pub fn socket_of(&self, rank: usize) -> usize {
        self.node_of(rank) * self.sockets_per_node + self.socket_in_node(rank)
    }

    /// Total socket groups across the machine.
    pub fn n_sockets(&self) -> usize {
        self.nodes * self.sockets_per_node
    }

    /// Whether two ranks share a socket (implies sharing a node).
    pub fn same_socket(&self, a: usize, b: usize) -> bool {
        self.socket_of(a) == self.socket_of(b)
    }

    // ---- switch level ----

    /// Number of leaf-switch groups (1 = flat switch tier).
    pub fn n_switches(&self) -> usize {
        if self.nodes_per_switch == 0 || self.nodes_per_switch >= self.nodes {
            1
        } else {
            self.nodes.div_ceil(self.nodes_per_switch)
        }
    }

    /// Switch group of a node.
    pub fn switch_of_node(&self, node: usize) -> usize {
        let n_sw = self.n_switches();
        if n_sw == 1 {
            return 0;
        }
        match self.placement {
            RankPlacement::Block => node / self.nodes_per_switch,
            RankPlacement::RoundRobin => node % n_sw,
        }
    }

    /// Switch group of `rank`.
    pub fn switch_of(&self, rank: usize) -> usize {
        self.switch_of_node(self.node_of(rank))
    }

    /// Whether two ranks sit behind the same leaf switch.
    pub fn same_switch(&self, a: usize, b: usize) -> bool {
        self.switch_of(a) == self.switch_of(b)
    }

    // ---- generic level access (the aggregation tree's view) ----

    /// Number of groups at a hierarchy level.
    pub fn n_groups(&self, kind: LevelKind) -> usize {
        match kind {
            LevelKind::Socket => self.n_sockets(),
            LevelKind::Node => self.nodes,
            LevelKind::Switch => self.n_switches(),
        }
    }

    /// Group id of `rank` at a hierarchy level.
    pub fn group_of(&self, kind: LevelKind, rank: usize) -> usize {
        match kind {
            LevelKind::Socket => self.socket_of(rank),
            LevelKind::Node => self.node_of(rank),
            LevelKind::Switch => self.switch_of(rank),
        }
    }

    /// Link tier of a message between two ranks: the innermost level
    /// containing both.  Flat topologies produce only `Node`/`Global`,
    /// matching the pre-hierarchy intra/inter split exactly.
    pub fn tier_of(&self, a: usize, b: usize) -> LinkTier {
        if self.same_node(a, b) {
            if self.sockets_per_node > 1 && self.socket_in_node(a) == self.socket_in_node(b) {
                LinkTier::Socket
            } else {
                LinkTier::Node
            }
        } else if self.n_switches() > 1 && self.same_switch(a, b) {
            LinkTier::Switch
        } else {
            LinkTier::Global
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_round_trips() {
        let t = Topology::new(4, 8);
        assert_eq!(t.nprocs(), 32);
        for r in 0..t.nprocs() {
            assert_eq!(t.rank_of(t.node_of(r), t.local_rank(r)), r);
        }
    }

    #[test]
    fn block_placement() {
        let t = Topology::new(3, 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.local_rank(17), 1);
    }

    #[test]
    fn same_node_predicate() {
        let t = Topology::new(2, 4);
        assert!(t.same_node(0, 3));
        assert!(!t.same_node(3, 4));
    }

    #[test]
    fn ranks_on_node_range() {
        let t = Topology::new(3, 4);
        assert_eq!(t.ranks_on_node(1).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
    }

    #[test]
    #[should_panic]
    fn zero_topology_panics() {
        Topology::new(0, 4);
    }

    #[test]
    fn flat_topology_degenerates_to_node_and_global_tiers() {
        let t = Topology::new(2, 4);
        assert_eq!(t.n_sockets(), 2);
        assert_eq!(t.n_switches(), 1);
        assert_eq!(t.tier_of(0, 3), LinkTier::Node);
        assert_eq!(t.tier_of(0, 4), LinkTier::Global);
        // Every rank pair hits exactly the old binary split.
        for a in 0..t.nprocs() {
            for b in 0..t.nprocs() {
                let tier = t.tier_of(a, b);
                if t.same_node(a, b) {
                    assert_eq!(tier, LinkTier::Node);
                } else {
                    assert_eq!(tier, LinkTier::Global);
                }
            }
        }
    }

    #[test]
    fn socket_block_placement_splits_contiguously() {
        let t = Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block);
        // Local ranks 0..4 → socket 0, 4..8 → socket 1, on each node.
        assert_eq!(t.socket_in_node(0), 0);
        assert_eq!(t.socket_in_node(3), 0);
        assert_eq!(t.socket_in_node(4), 1);
        assert_eq!(t.socket_of(8), 2); // node 1, socket 0
        assert_eq!(t.socket_of(12), 3);
        assert!(t.same_socket(0, 3));
        assert!(!t.same_socket(3, 4));
        assert!(!t.same_socket(0, 8)); // same local socket id, other node
        assert_eq!(t.tier_of(0, 3), LinkTier::Socket);
        assert_eq!(t.tier_of(3, 4), LinkTier::Node);
    }

    #[test]
    fn socket_block_placement_uneven_ppn() {
        // 5 local ranks over 2 sockets: balanced split 3 + 2.
        let t = Topology::hierarchical(1, 5, 2, 0, RankPlacement::Block);
        let sockets: Vec<usize> = (0..5).map(|r| t.socket_in_node(r)).collect();
        assert_eq!(sockets, vec![0, 0, 0, 1, 1]);
    }

    #[test]
    fn socket_round_robin_placement_strides() {
        let t = Topology::hierarchical(1, 8, 2, 0, RankPlacement::RoundRobin);
        let sockets: Vec<usize> = (0..8).map(|r| t.socket_in_node(r)).collect();
        assert_eq!(sockets, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn switch_groups_block_and_round_robin() {
        let tb = Topology::hierarchical(6, 2, 1, 2, RankPlacement::Block);
        assert_eq!(tb.n_switches(), 3);
        let groups: Vec<usize> = (0..6).map(|n| tb.switch_of_node(n)).collect();
        assert_eq!(groups, vec![0, 0, 1, 1, 2, 2]);
        assert_eq!(tb.tier_of(0, 2), LinkTier::Switch); // nodes 0,1 share switch 0
        assert_eq!(tb.tier_of(0, 4), LinkTier::Global); // nodes 0,2 do not

        let tr = Topology::hierarchical(6, 2, 1, 2, RankPlacement::RoundRobin);
        let groups: Vec<usize> = (0..6).map(|n| tr.switch_of_node(n)).collect();
        assert_eq!(groups, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn switch_group_counts_partial_last_group() {
        let t = Topology::hierarchical(5, 1, 1, 2, RankPlacement::Block);
        assert_eq!(t.n_switches(), 3);
        assert_eq!(t.switch_of_node(4), 2);
        // nodes_per_switch >= nodes collapses to one switch.
        let flat = Topology::hierarchical(5, 1, 1, 8, RankPlacement::Block);
        assert_eq!(flat.n_switches(), 1);
    }

    #[test]
    fn generic_level_access_matches_specific() {
        let t = Topology::hierarchical(4, 6, 3, 2, RankPlacement::Block);
        assert_eq!(t.n_groups(LevelKind::Socket), 12);
        assert_eq!(t.n_groups(LevelKind::Node), 4);
        assert_eq!(t.n_groups(LevelKind::Switch), 2);
        for r in 0..t.nprocs() {
            assert_eq!(t.group_of(LevelKind::Socket, r), t.socket_of(r));
            assert_eq!(t.group_of(LevelKind::Node, r), t.node_of(r));
            assert_eq!(t.group_of(LevelKind::Switch, r), t.switch_of(r));
        }
    }

    #[test]
    fn levels_nest_socket_in_node_in_switch() {
        for placement in [RankPlacement::Block, RankPlacement::RoundRobin] {
            let t = Topology::hierarchical(6, 8, 4, 2, placement);
            for a in 0..t.nprocs() {
                for b in 0..t.nprocs() {
                    if t.same_socket(a, b) {
                        assert!(t.same_node(a, b), "socket level must nest in node");
                    }
                    if t.same_node(a, b) {
                        assert!(t.same_switch(a, b), "node level must nest in switch");
                    }
                }
            }
        }
    }

    #[test]
    fn level_kind_labels() {
        assert_eq!(LevelKind::Socket.label(), "socket");
        assert_eq!(LevelKind::Node.to_string(), "node");
        assert_eq!(LevelKind::Switch.to_string(), "switch");
        assert!(LinkTier::Socket.is_local() && LinkTier::Node.is_local());
        assert!(!LinkTier::Switch.is_local() && !LinkTier::Global.is_local());
    }
}
