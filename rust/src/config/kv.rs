//! Key-value parsing: a TOML-subset config-file reader and `--key value`
//! CLI argument splitting.
//!
//! Supported file syntax: `key = value` lines, `[section]` headers
//! (flattened to `section.key`), `#` comments, blank lines, and quoted
//! string values.

use crate::error::{Error, Result};

/// Ordered key-value map (insertion order preserved so later keys
/// override earlier ones when applied sequentially).
#[derive(Clone, Debug, Default)]
pub struct KvMap {
    pairs: Vec<(String, String)>,
}

impl KvMap {
    /// Build from explicit pairs.
    pub fn from_pairs(pairs: Vec<(String, String)>) -> Self {
        KvMap { pairs }
    }

    /// Parse a TOML-subset config file.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_str_contents(&text)
    }

    /// Parse TOML-subset text.
    pub fn from_str_contents(text: &str) -> Result<Self> {
        let mut pairs = Vec::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(Error::config(format!(
                    "config line {}: expected 'key = value', got '{raw}'",
                    lineno + 1
                )));
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = v.trim().trim_matches('"').to_string();
            pairs.push((key, value));
        }
        Ok(KvMap { pairs })
    }

    /// Parse CLI arguments of the form `--key value` / `--key=value` /
    /// bare `--flag` (value "true").  Returns the map and any positional
    /// (non-flag) arguments.
    pub fn from_cli(args: &[String]) -> Result<(Self, Vec<String>)> {
        let mut pairs = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    pairs.push((k.to_string(), v.to_string()));
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    pairs.push((flag.to_string(), args[i + 1].clone()));
                    i += 1;
                } else {
                    pairs.push((flag.to_string(), "true".to_string()));
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok((KvMap { pairs }, positional))
    }

    /// Iterate pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.pairs.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Last value for `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Remove all entries for `key`, returning the last value.
    pub fn take(&mut self, key: &str) -> Option<String> {
        let last = self.get(key).map(str::to_string);
        self.pairs.retain(|(k, _)| k != key);
        last
    }

    /// Number of pairs.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    /// Whether the map is empty.
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_quotes() {
        let text = r#"
            # a comment
            nodes = 4
            workload = "btio"

            [net]
            alpha_inter = 2e-6   # inline comment
        "#;
        let kv = KvMap::from_str_contents(text).unwrap();
        assert_eq!(kv.get("nodes"), Some("4"));
        assert_eq!(kv.get("workload"), Some("btio"));
        assert_eq!(kv.get("net.alpha_inter"), Some("2e-6"));
    }

    #[test]
    fn rejects_garbage_lines() {
        assert!(KvMap::from_str_contents("what even is this").is_err());
    }

    #[test]
    fn cli_forms() {
        let args: Vec<String> = ["run", "--nodes", "8", "--verify", "--scale=64"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let (kv, pos) = KvMap::from_cli(&args).unwrap();
        assert_eq!(pos, vec!["run".to_string()]);
        assert_eq!(kv.get("nodes"), Some("8"));
        assert_eq!(kv.get("verify"), Some("true"));
        assert_eq!(kv.get("scale"), Some("64"));
    }

    #[test]
    fn take_removes() {
        let mut kv = KvMap::from_pairs(vec![("a".into(), "1".into()), ("a".into(), "2".into())]);
        assert_eq!(kv.take("a"), Some("2".to_string()));
        assert!(kv.is_empty());
    }
}
