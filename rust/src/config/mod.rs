//! Run configuration: defaults, a TOML-subset config-file parser, and
//! CLI-style `--key value` overrides (clap/serde are not in the image).

pub mod kv;

use crate::cluster::{RankPlacement, Topology};
use crate::coordinator::breakdown::CpuModel;
use crate::coordinator::collective::{Algorithm, DirectionSpec, OverlapMode};
use crate::coordinator::placement::GlobalPlacement;
use crate::error::{Error, Result};
use crate::faults::{self, FaultPlan};
use crate::lustre::{IoModel, LustreConfig};
use crate::netmodel::{NetParams, SendMode};
use crate::runtime::engine::EngineKind;
use crate::workloads::WorkloadKind;

pub use kv::KvMap;

/// Complete configuration of one simulated collective-I/O run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Compute nodes.
    pub nodes: usize,
    /// MPI processes per node.
    pub ppn: usize,
    /// NUMA/socket domains per node (1 = flat; enables `tree:socket=...`).
    pub sockets_per_node: usize,
    /// Nodes per leaf-switch group (0 = flat; enables `tree:switch=...`).
    pub nodes_per_switch: usize,
    /// Rank→socket and node→switch placement within hierarchy groups.
    pub rank_placement: RankPlacement,
    /// Workload.
    pub workload: WorkloadKind,
    /// Workload scale divisor (1 = paper scale).
    pub scale: u64,
    /// Collective algorithm.
    pub algorithm: Algorithm,
    /// Collective direction(s) the drivers run: write, read, or both
    /// (read runs pre-populate the file and verify the gathered bytes).
    pub direction: DirectionSpec,
    /// Aggregator hot-path engine.
    pub engine: EngineKind,
    /// Global-aggregator placement policy.
    pub placement: GlobalPlacement,
    /// Lustre stripe geometry.
    pub lustre: LustreConfig,
    /// Network model parameters.
    pub net: NetParams,
    /// CPU cost model.
    pub cpu: CpuModel,
    /// I/O cost model.
    pub io: IoModel,
    /// Payload seed.
    pub seed: u64,
    /// Verify written bytes by reading back after the collective.
    pub verify: bool,
    /// Directory for persisted collective plans (`--plan-cache`); `None`
    /// keeps the plan cache memory-only.
    pub plan_cache: Option<String>,
    /// Warm plans the in-memory LRU holds (`--plan-cache-size`).
    pub plan_cache_size: usize,
    /// Worker-pool width (`--threads`); `None` defers to `TAMIO_THREADS`
    /// and then `available_parallelism()` (resolved in
    /// [`crate::util::runtime::default_threads`]).
    pub threads: Option<usize>,
    /// Seeded fault schedule (`--faults`); `None` runs fault-free.
    pub faults: Option<FaultPlan>,
    /// Seed resolving `?` selectors in the fault schedule (`--fault-seed`).
    pub fault_seed: u64,
    /// Retry bound per storage call site under transient faults
    /// (`--max-retries`).
    pub max_retries: u32,
    /// Double-buffered round pipelining (`--overlap on|off|auto`).
    /// Execution-time property only: plans and their cache fingerprints
    /// are identical across modes.
    pub overlap: OverlapMode,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            nodes: 4,
            ppn: 16,
            sockets_per_node: 1,
            nodes_per_switch: 0,
            rank_placement: RankPlacement::Block,
            workload: WorkloadKind::E3smG,
            scale: 4096,
            algorithm: Algorithm::TwoPhase,
            direction: DirectionSpec::Write,
            engine: EngineKind::Native,
            placement: GlobalPlacement::Spread,
            lustre: LustreConfig::default(),
            net: NetParams::default(),
            cpu: CpuModel::default(),
            io: IoModel::default(),
            seed: 42,
            verify: false,
            plan_cache: None,
            plan_cache_size: 8,
            threads: None,
            faults: None,
            fault_seed: 0,
            max_retries: faults::DEFAULT_MAX_RETRIES,
            overlap: OverlapMode::Off,
        }
    }
}

impl RunConfig {
    /// Cluster topology (including the socket/switch hierarchy levels).
    ///
    /// # Panics
    ///
    /// An out-of-range `sockets_per_node` (0 or > `ppn`) panics in the
    /// [`Topology::hierarchical`] constructor with a message naming the
    /// constraint — the same config-layer treatment as zero `nodes`/`ppn`
    /// (silently clamping would report costs for a different NUMA
    /// geometry than the one requested).
    pub fn topology(&self) -> Topology {
        Topology::hierarchical(
            self.nodes,
            self.ppn,
            self.sockets_per_node,
            self.nodes_per_switch,
            self.rank_placement,
        )
    }

    /// Apply `--key value` overrides (also used for config-file keys).
    pub fn apply(&mut self, kv: &KvMap) -> Result<()> {
        for (key, value) in kv.iter() {
            self.apply_one(key, value)?;
        }
        Ok(())
    }

    fn apply_one(&mut self, key: &str, value: &str) -> Result<()> {
        let parse_f64 = |v: &str| -> Result<f64> {
            v.parse()
                .map_err(|_| Error::config(format!("bad float for {key}: {v}")))
        };
        let parse_u64 = |v: &str| -> Result<u64> {
            v.parse()
                .map_err(|_| Error::config(format!("bad integer for {key}: {v}")))
        };
        match key {
            "nodes" => self.nodes = parse_u64(value)? as usize,
            "ppn" => self.ppn = parse_u64(value)? as usize,
            "sockets_per_node" | "spn" => self.sockets_per_node = parse_u64(value)? as usize,
            "nodes_per_switch" | "nps" => self.nodes_per_switch = parse_u64(value)? as usize,
            "rank_placement" => {
                self.rank_placement = match value {
                    "block" => RankPlacement::Block,
                    "rr" | "round-robin" | "roundrobin" => RankPlacement::RoundRobin,
                    _ => {
                        return Err(Error::config(format!(
                            "bad rank_placement '{value}' (block|round-robin)"
                        )))
                    }
                }
            }
            "workload" => self.workload = value.parse()?,
            "scale" => self.scale = parse_u64(value)?,
            "algorithm" | "algo" => self.algorithm = value.parse()?,
            "direction" | "dir" => self.direction = value.parse()?,
            "overlap" => self.overlap = value.parse()?,
            "engine" => self.engine = value.parse()?,
            "placement" => {
                self.placement = match value {
                    "spread" => GlobalPlacement::Spread,
                    "cray" | "round-robin" => GlobalPlacement::CrayRoundRobin,
                    _ => {
                        return Err(Error::config(format!(
                            "bad placement '{value}' (spread|cray)"
                        )))
                    }
                }
            }
            "stripe_size" => self.lustre.stripe_size = parse_u64(value)?,
            "stripe_count" => self.lustre.stripe_count = parse_u64(value)? as usize,
            "send_mode" => {
                self.net.send_mode = match value {
                    "isend" => SendMode::Isend,
                    "issend" => SendMode::Issend,
                    _ => {
                        return Err(Error::config(format!(
                            "bad send_mode '{value}' (isend|issend)"
                        )))
                    }
                }
            }
            "net.alpha_inter" => self.net.alpha_inter = parse_f64(value)?,
            "net.alpha_intra" => self.net.alpha_intra = parse_f64(value)?,
            "net.alpha_socket" => self.net.alpha_socket = parse_f64(value)?,
            "net.alpha_switch" => self.net.alpha_switch = parse_f64(value)?,
            "net.beta_inter" => self.net.beta_inter = parse_f64(value)?,
            "net.beta_intra" => self.net.beta_intra = parse_f64(value)?,
            "net.beta_socket" => self.net.beta_socket = parse_f64(value)?,
            "net.beta_switch" => self.net.beta_switch = parse_f64(value)?,
            "net.recv_overhead" => self.net.recv_overhead = parse_f64(value)?,
            "net.send_overhead" => self.net.send_overhead = parse_f64(value)?,
            "net.pending_penalty" => self.net.pending_penalty = parse_f64(value)?,
            "net.nic_ingest" => self.net.nic_ingest = parse_f64(value)?,
            "io.seek" => self.io.seek = parse_f64(value)?,
            "io.ost_bandwidth" => self.io.ost_bandwidth = parse_f64(value)?,
            "io.lock_penalty" => self.io.lock_penalty = parse_f64(value)?,
            "cpu.per_req_calc" => self.cpu.per_req_calc = parse_f64(value)?,
            "cpu.per_cmp_sort" => self.cpu.per_cmp_sort = parse_f64(value)?,
            "cpu.per_byte_memcpy" => self.cpu.per_byte_memcpy = parse_f64(value)?,
            "seed" => self.seed = parse_u64(value)?,
            "verify" => self.verify = value == "true" || value == "1",
            "plan-cache" | "plan_cache" => self.plan_cache = Some(value.to_string()),
            "plan-cache-size" | "plan_cache_size" => {
                let n = parse_u64(value)? as usize;
                if n == 0 {
                    return Err(Error::config(
                        "plan-cache-size must be at least 1 (omit --plan-cache to \
                         disable persistence; the in-memory cache is always on)"
                            .to_string(),
                    ));
                }
                self.plan_cache_size = n;
            }
            "threads" => {
                let n = parse_u64(value)? as usize;
                if n == 0 {
                    return Err(Error::config(
                        "threads must be at least 1 (omit --threads to use \
                         TAMIO_THREADS or all available cores)"
                            .to_string(),
                    ));
                }
                self.threads = Some(n);
            }
            "faults" => self.faults = Some(value.parse()?),
            "fault-seed" | "fault_seed" => self.fault_seed = parse_u64(value)?,
            "max-retries" | "max_retries" => {
                self.max_retries = parse_u64(value)?.try_into().map_err(|_| {
                    Error::config(format!("max-retries {value} exceeds u32 range"))
                })?;
            }
            other => {
                return Err(Error::config(format!("unknown config key '{other}'")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.topology().nprocs(), 64);
        assert_eq!(c.lustre.stripe_count, 56);
    }

    #[test]
    fn apply_overrides() {
        let mut c = RunConfig::default();
        let kv = KvMap::from_pairs(vec![
            ("nodes".into(), "8".into()),
            ("workload".into(), "btio".into()),
            ("algorithm".into(), "tam:128".into()),
            ("direction".into(), "both".into()),
            ("send_mode".into(), "isend".into()),
            ("net.alpha_inter".into(), "5e-6".into()),
            ("verify".into(), "true".into()),
        ]);
        c.apply(&kv).unwrap();
        assert_eq!(c.nodes, 8);
        assert_eq!(c.workload, WorkloadKind::Btio);
        assert!(matches!(c.algorithm, Algorithm::Tam(t) if t.total_local_aggregators == 128));
        assert_eq!(c.direction, DirectionSpec::Both);
        assert_eq!(c.net.send_mode, SendMode::Isend);
        assert_eq!(c.net.alpha_inter, 5e-6);
        assert!(c.verify);
    }

    #[test]
    fn direction_defaults_to_write_and_rejects_garbage() {
        let mut c = RunConfig::default();
        assert_eq!(c.direction, DirectionSpec::Write);
        let kv = KvMap::from_pairs(vec![("direction".into(), "read".into())]);
        c.apply(&kv).unwrap();
        assert_eq!(c.direction, DirectionSpec::Read);
        let bad = KvMap::from_pairs(vec![("direction".into(), "sideways".into())]);
        assert!(c.apply(&bad).is_err());
    }

    #[test]
    fn hierarchy_keys_build_hierarchical_topology() {
        let mut c = RunConfig::default();
        let kv = KvMap::from_pairs(vec![
            ("nodes".into(), "4".into()),
            ("ppn".into(), "8".into()),
            ("sockets_per_node".into(), "2".into()),
            ("nodes_per_switch".into(), "2".into()),
            ("rank_placement".into(), "round-robin".into()),
            ("algorithm".into(), "tree:socket=2,node=1".into()),
            ("net.alpha_socket".into(), "1e-7".into()),
            ("net.beta_switch".into(), "2e-10".into()),
        ]);
        c.apply(&kv).unwrap();
        let topo = c.topology();
        assert_eq!(topo.sockets_per_node, 2);
        assert_eq!(topo.n_switches(), 2);
        assert_eq!(topo.placement, RankPlacement::RoundRobin);
        assert!(matches!(c.algorithm, Algorithm::Tree(s) if s.depth() == 2));
        assert_eq!(c.net.alpha_socket, 1e-7);
        assert_eq!(c.net.beta_switch, 2e-10);
        // Bad placement rejected.
        let bad = KvMap::from_pairs(vec![("rank_placement".into(), "spiral".into())]);
        assert!(c.apply(&bad).is_err());
        // Defaults stay flat: the degenerate 2-level topology.
        let d = RunConfig::default();
        assert_eq!(d.topology(), Topology::new(d.nodes, d.ppn));
    }

    #[test]
    #[should_panic(expected = "sockets_per_node")]
    fn out_of_range_sockets_per_node_panics_not_clamps() {
        // More sockets than ranks per node must fail loudly — a silent
        // clamp would price a different NUMA geometry than requested.
        let mut c = RunConfig::default();
        c.ppn = 4;
        c.sockets_per_node = 8;
        let _ = c.topology();
    }

    #[test]
    fn plan_cache_keys_apply_and_reject_zero_size() {
        let mut c = RunConfig::default();
        assert_eq!(c.plan_cache, None);
        assert_eq!(c.plan_cache_size, 8);
        let kv = KvMap::from_pairs(vec![
            ("plan-cache".into(), "/tmp/tamio-plans".into()),
            ("plan-cache-size".into(), "4".into()),
        ]);
        c.apply(&kv).unwrap();
        assert_eq!(c.plan_cache.as_deref(), Some("/tmp/tamio-plans"));
        assert_eq!(c.plan_cache_size, 4);
        let bad = KvMap::from_pairs(vec![("plan-cache-size".into(), "0".into())]);
        let err = c.apply(&bad).unwrap_err().to_string();
        assert!(err.contains("at least 1"), "{err}");
    }

    #[test]
    fn threads_key_applies_and_rejects_zero() {
        let mut c = RunConfig::default();
        assert_eq!(c.threads, None);
        let kv = KvMap::from_pairs(vec![("threads".into(), "4".into())]);
        c.apply(&kv).unwrap();
        assert_eq!(c.threads, Some(4));
        let bad = KvMap::from_pairs(vec![("threads".into(), "0".into())]);
        let err = c.apply(&bad).unwrap_err().to_string();
        assert!(err.contains("at least 1"), "{err}");
        let garbage = KvMap::from_pairs(vec![("threads".into(), "many".into())]);
        assert!(c.apply(&garbage).is_err(), "non-numeric threads must hard-error");
    }

    #[test]
    fn fault_keys_apply_and_reject_garbage() {
        use crate::faults::{FaultClause, Sel};
        let mut c = RunConfig::default();
        assert_eq!(c.faults, None);
        assert_eq!(c.fault_seed, 0);
        assert_eq!(c.max_retries, faults::DEFAULT_MAX_RETRIES);
        let kv = KvMap::from_pairs(vec![
            ("faults".into(), "ost_fail=?@transient:3,agg_drop=?@level:0".into()),
            ("fault-seed".into(), "42".into()),
            ("max-retries".into(), "6".into()),
        ]);
        c.apply(&kv).unwrap();
        let plan = c.faults.as_ref().unwrap();
        assert_eq!(plan.clauses.len(), 2);
        assert!(matches!(
            plan.clauses[0],
            FaultClause::OstFail { ost: Sel::Random, round: None, transient: Some(3) }
        ));
        assert!(plan.has_drops());
        assert_eq!(c.fault_seed, 42);
        assert_eq!(c.max_retries, 6);
        // Malformed schedules hard-error at apply time, not at run time.
        let bad = KvMap::from_pairs(vec![("faults".into(), "quake=7".into())]);
        assert!(c.apply(&bad).is_err());
        let bad = KvMap::from_pairs(vec![("max_retries".into(), "lots".into())]);
        assert!(c.apply(&bad).is_err());
    }

    #[test]
    fn overlap_key_applies_and_rejects_garbage() {
        let mut c = RunConfig::default();
        // Default off: pipelining never engages unless asked for.
        assert_eq!(c.overlap, OverlapMode::Off);
        for (v, want) in
            [("on", OverlapMode::On), ("auto", OverlapMode::Auto), ("off", OverlapMode::Off)]
        {
            let kv = KvMap::from_pairs(vec![("overlap".into(), v.into())]);
            c.apply(&kv).unwrap();
            assert_eq!(c.overlap, want);
        }
        // Hard error, not silent default substitution (PR 7 policy).
        let bad = KvMap::from_pairs(vec![("overlap".into(), "sideways".into())]);
        let err = c.apply(&bad).unwrap_err().to_string();
        assert!(err.contains("sideways") && err.contains("on|off|auto"), "{err}");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::default();
        let kv = KvMap::from_pairs(vec![("bogus".into(), "1".into())]);
        assert!(c.apply(&kv).is_err());
    }
}
