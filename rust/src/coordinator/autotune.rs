//! `--algorithm auto`: cost-model-driven plan auto-tuner (ROADMAP item 4).
//!
//! PR 5's N-level trees made the paper's §IV-A aggregator-selection rule
//! one point in a combinatorial space — depth × per-level aggregator
//! counts × rank placement — and the per-tier α–β link table already
//! prices any candidate.  This module turns that pricing model into a
//! searcher:
//!
//! * [`candidate_specs`] — a bounded, deterministic [`TreeSpec`] grid:
//!   depth 0 (flat ≡ two-phase) always, the node level from a
//!   divisor/power-of-two ladder over `ppn`, and the socket/switch
//!   levels only where the topology actually has them
//!   (`sockets_per_node > 1`, `n_switches() > 1`).
//! * [`predict_spec_cost`] — a *metadata-only* predictor: build the
//!   candidate's full collective plan (level fold via
//!   [`aggregate_level_read_views`] + [`build_exchange_plan`]) and walk
//!   the exchange rounds pricing metadata-sized and payload-*shaped*
//!   messages through [`cost_phase`] / [`PendingQueue`] — no payload is
//!   staged and no I/O executes.  The same α–β/CPU/IO models the
//!   executor charges at run time price the prediction, so predicted
//!   and measured totals share units and, more importantly, ordering.
//! * [`tune_collective`] — score both [`RankPlacement`]s × the grid and
//!   return the strictly-min-predicted-cost candidate (first in
//!   enumeration order on ties → fully deterministic).
//! * [`fingerprint_autotune`] — the memo key: the collective's
//!   structural fingerprint *minus* the tuned axes (algorithm and rank
//!   placement), under its own domain tag.  [`PlanCache`] keeps a small
//!   side table of winners keyed by it (see
//!   [`PlanCache::tuner_choice`]), so repeated auto runs skip the
//!   search; the winner's executable plan then warms through the normal
//!   plan-fingerprint path.
//!
//! The honest half lives in `experiments::validate_tuner` /
//! `benches/ablation_autotune.rs`: the top-k predicted candidates run
//! for real and the report carries per-candidate relative error plus a
//! Spearman rank correlation — a tuner whose predictions are never
//! validated is a toy.  DESIGN.md §Auto-tuner documents the grid, the
//! predictor and the validation methodology.
//!
//! [`PlanCache`]: crate::coordinator::plancache::PlanCache
//! [`PlanCache::tuner_choice`]: crate::coordinator::plancache::PlanCache::tuner_choice

use crate::cluster::{RankPlacement, Topology};
use crate::coordinator::collective::{build_exchange_plan, Direction, OverlapMode};
use crate::coordinator::merge::RoundScratch;
use crate::coordinator::placement::GlobalPlacement;
use crate::coordinator::plancache::{Fp128, FpHasher};
use crate::coordinator::reqcalc::metadata_bytes;
use crate::coordinator::tree::{aggregate_level_read_views, AggregationPlan, TreeSpec};
use crate::coordinator::twophase::CollectiveCtx;
use crate::error::Result;
use crate::lustre::{LustreConfig, OstStats};
use crate::mpisim::FlatView;
use crate::netmodel::phase::{cost_phase, Message, OverlapAccount, PendingQueue};

// ---------------------------------------------------------------------------
// Candidate grid
// ---------------------------------------------------------------------------

/// Per-level aggregator-count ladder: powers of two up to `limit` plus
/// `limit`'s divisors, deduplicated, then downsampled to at most four
/// rungs keeping both endpoints.  Deterministic in `limit` alone, so
/// the candidate grid (and therefore the tuner's choice) never depends
/// on enumeration order or host state.
pub fn count_ladder(limit: usize) -> Vec<usize> {
    let limit = limit.max(1);
    let mut rungs: Vec<usize> = Vec::new();
    let mut p = 1usize;
    loop {
        rungs.push(p);
        match p.checked_mul(2) {
            Some(n) if n <= limit => p = n,
            _ => break,
        }
    }
    if limit <= 4096 {
        for d in 1..=limit {
            if limit % d == 0 {
                rungs.push(d);
            }
        }
    } else {
        // Degenerate configs only; the power ladder already covers it.
        rungs.push(limit);
    }
    rungs.sort_unstable();
    rungs.dedup();
    if rungs.len() > 4 {
        let n = rungs.len();
        let mut out: Vec<usize> =
            [0, n / 3, (2 * n) / 3, n - 1].iter().map(|&i| rungs[i]).collect();
        out.dedup();
        return out;
    }
    rungs
}

fn push_unique(out: &mut Vec<TreeSpec>, s: TreeSpec) {
    if !out.contains(&s) {
        out.push(s);
    }
}

/// The bounded candidate grid for one topology (placement-independent;
/// both [`RankPlacement`]s score the same grid).  Depth 0 is always the
/// first entry; a hierarchy level appears only when the topology has
/// more than one group of it, so flat machines never pay for phantom
/// levels.  Order is deterministic — the tuner's tie-break is
/// first-in-grid.
pub fn candidate_specs(topo: &Topology) -> Vec<TreeSpec> {
    let mut out: Vec<TreeSpec> = vec![TreeSpec::flat()];
    let node_rungs = count_ladder(topo.ppn);
    for &pn in &node_rungs {
        push_unique(&mut out, TreeSpec { per_socket: 0, per_node: pn, per_switch: 0 });
    }
    let socket_rungs = if topo.sockets_per_node > 1 {
        count_ladder(topo.ppn.div_ceil(topo.sockets_per_node))
    } else {
        Vec::new()
    };
    for &ps in &socket_rungs {
        for pn in [1usize, 2] {
            push_unique(&mut out, TreeSpec { per_socket: ps, per_node: pn, per_switch: 0 });
        }
    }
    if topo.n_switches() > 1 {
        for &pn in &node_rungs {
            push_unique(&mut out, TreeSpec { per_socket: 0, per_node: pn, per_switch: 1 });
        }
        for &ps in &socket_rungs {
            push_unique(&mut out, TreeSpec { per_socket: ps, per_node: 1, per_switch: 1 });
        }
    }
    out
}

// ---------------------------------------------------------------------------
// The metadata-only predictor
// ---------------------------------------------------------------------------

/// Predicted per-phase costs of one candidate — the same components the
/// executor's `Breakdown` charges, computed from plan structure alone.
#[derive(Clone, Copy, Debug, Default)]
pub struct PredictedCost {
    /// Intra-level request-metadata exchange (all tree levels summed).
    pub intra_comm: f64,
    /// Intra-level merge/sort of forwarded request lists.
    pub intra_sort: f64,
    /// Intra-level payload staging (write) / reply scatter (read),
    /// approximated as the busiest aggregator's memcpy per level.
    pub intra_memcpy: f64,
    /// `calc_my_req` — slowest requester's request classification.
    pub calc_my_req: f64,
    /// Plan-construction time charged by the CPU model.
    pub plan: f64,
    /// Request-metadata redistribution to the global aggregators.
    pub meta_comm: f64,
    /// Payload-shaped round exchange (the congestion-bearing phase).
    pub round_comm: f64,
    /// Per-round k-way merge at the global aggregators (max per round).
    pub inter_sort: f64,
    /// Per-round datatype build at the global aggregators.
    pub inter_datatype: f64,
    /// I/O phase, assuming the uniform OST spread striping enforces.
    pub io_phase: f64,
    /// Critical-path credit of the double-buffered round pipeline
    /// (`--overlap on|auto`): per steady round, the I/O hidden behind
    /// the next round's exchange, bounded by the Issend synchronization
    /// rule.  Zero when the candidate was priced with overlap off.
    pub overlap_saved: f64,
}

impl PredictedCost {
    /// End-to-end predicted time — the tuner's objective.  Mirrors
    /// `Breakdown::total`: the phase sum minus the pipeline credit.
    pub fn total(&self) -> f64 {
        self.intra_comm
            + self.intra_sort
            + self.intra_memcpy
            + self.calc_my_req
            + self.plan
            + self.meta_comm
            + self.round_comm
            + self.inter_sort
            + self.inter_datatype
            + self.io_phase
            - self.overlap_saved
    }
}

/// Price one candidate spec on `ctx.topo` without staging payload or
/// touching storage: fold the member views up the candidate's tree
/// (metadata-only merges), build the top-tier exchange plan, then walk
/// its rounds pricing message lists through the α–β phase model exactly
/// where the executor would — `Message` sizes come from the plan's CSR
/// slabs (`ReqSlice::bytes`), not from any staged buffer.
pub fn predict_spec_cost(
    ctx: &CollectiveCtx,
    spec: TreeSpec,
    direction: Direction,
    views: &[(usize, FlatView)],
    file_cfg: &LustreConfig,
    overlap: OverlapMode,
) -> Result<PredictedCost> {
    let agg = AggregationPlan::from_spec(ctx.topo, &spec);
    let mut cost = PredictedCost::default();

    // Intra levels: the same metadata fold plan construction performs,
    // accumulating each level's comm + sort, plus a staging-memcpy
    // estimate from the bytes each aggregator would receive.
    let mut tier: Vec<(usize, FlatView)> = views.to_vec();
    let mut slots: Vec<RoundScratch> = Vec::new();
    for level in &agg.levels {
        let mut staged = vec![0u64; level.ranks.len()];
        for (rank, v) in &tier {
            let a = level.assignment[*rank];
            if a != usize::MAX {
                if let Ok(i) = level.ranks.binary_search(&a) {
                    staged[i] += v.total_bytes();
                }
            }
        }
        cost.intra_memcpy += staged
            .iter()
            .map(|&b| ctx.cpu.memcpy_time(b))
            .fold(0.0, f64::max);
        let stage = aggregate_level_read_views(ctx, level, &tier, &mut slots)?;
        cost.intra_comm += stage.comm;
        cost.intra_sort += stage.sort;
        tier = stage.agg_views;
    }
    if direction == Direction::Read {
        for (_, v) in tier.iter_mut() {
            if v.has_overlap() {
                *v = v.disjoint_union();
            }
        }
    }
    let refs: Vec<(usize, &FlatView)> = tier.iter().map(|(r, v)| (*r, v)).collect();
    let x = build_exchange_plan(ctx, &refs, file_cfg)?;
    let n_agg = x.domains.n_agg;

    let mut total_pieces = 0u64;
    for pr in &x.reqs {
        cost.calc_my_req = cost.calc_my_req.max(ctx.cpu.calc_req_time(pr.reqs.pieces));
        total_pieces += pr.reqs.pieces;
    }
    cost.plan = ctx.cpu.plan_time(x.reqs.len() as u64, total_pieces, n_agg as u64, x.n_rounds);

    // Metadata redistribution: each requester posts its (offset, length)
    // records to every aggregator it targets.
    let mut meta_reqs = vec![0u64; n_agg];
    let mut msgs: Vec<Message> = Vec::new();
    for pr in &x.reqs {
        meta_reqs.iter_mut().for_each(|c| *c = 0);
        pr.reqs.reqs_per_agg_into(&mut meta_reqs);
        for (a, &n) in meta_reqs.iter().enumerate() {
            if n > 0 && x.agg_ranks[a] != pr.rank {
                msgs.push(Message::new(pr.rank, x.agg_ranks[a], metadata_bytes(n)));
            }
        }
    }
    cost.meta_comm = cost_phase(ctx.net, ctx.topo, &msgs).time;

    // Round loop: payload-shaped messages (sizes from the CSR slabs, no
    // payload slab attached) through the pending-queue model, plus the
    // per-round merge/datatype maxima at the aggregators.
    let mut queue = PendingQueue::default();
    let mut agg_items = vec![0u64; n_agg];
    let mut agg_slices = vec![0usize; n_agg];
    let mut acct = OverlapAccount::default();
    for round in 0..x.n_rounds {
        msgs.clear();
        agg_items.iter_mut().for_each(|c| *c = 0);
        agg_slices.iter_mut().for_each(|c| *c = 0);
        let mut round_bytes = 0u64;
        for pr in &x.reqs {
            for (a, s) in pr.reqs.slices_in_round_with(round, &[]) {
                if s.len() == 0 {
                    continue;
                }
                agg_items[a] += s.len() as u64;
                agg_slices[a] += 1;
                round_bytes += s.bytes;
                if x.agg_ranks[a] != pr.rank {
                    msgs.push(match direction {
                        Direction::Write => Message::new(pr.rank, x.agg_ranks[a], s.bytes),
                        Direction::Read => Message::new(x.agg_ranks[a], pr.rank, s.bytes),
                    });
                }
            }
        }
        let comm = queue.cost_round(ctx.net, ctx.topo, &msgs);
        cost.round_comm += comm.time;
        let mut sort_max = 0.0f64;
        let mut dt_max = 0.0f64;
        for a in 0..n_agg {
            if agg_slices[a] > 0 {
                sort_max = sort_max.max(ctx.cpu.merge_time(agg_items[a], agg_slices[a]));
                dt_max = dt_max.max(ctx.cpu.datatype_time(agg_items[a], agg_slices[a]));
            }
        }
        cost.inter_sort += sort_max;
        cost.inter_datatype += dt_max;
        // Same per-round triple the executor feeds its account: the full
        // exchange (comm + merge + datatype), the send-mode sync bound
        // at this round's busiest receiver, and the round's I/O weight.
        acct.push_round(
            comm.time + sort_max + dt_max,
            ctx.net.overlap_sync_bound(comm.max_in_degree),
            round_bytes as f64,
        );
    }

    // I/O phase: striping spreads the same bytes over the same OSTs for
    // every candidate, so a uniform estimate suffices — it keeps totals
    // honest without affecting the ranking.
    let total_bytes: u64 = x.reqs.iter().map(|pr| pr.view_bytes).sum();
    let osts = file_cfg.stripe_count.max(1);
    let extents = (total_pieces / osts as u64).max(u64::from(total_bytes > 0));
    let per_ost = OstStats {
        bytes: total_bytes / osts as u64,
        extents,
        lock_acquisitions: extents,
        lock_conflicts: 0,
    };
    cost.io_phase = ctx.io.phase_time(&vec![per_ost; osts]);
    if overlap.pipelines(x.n_rounds) {
        cost.overlap_saved = acct.finish(cost.io_phase);
    }
    Ok(cost)
}

// ---------------------------------------------------------------------------
// The search
// ---------------------------------------------------------------------------

/// One scored candidate: a spec, the rank placement it was priced
/// under, and its predicted per-phase costs.
#[derive(Clone, Copy, Debug)]
pub struct ScoredCandidate {
    /// The candidate tree spec.
    pub spec: TreeSpec,
    /// Rank placement the candidate's topology used.
    pub placement: RankPlacement,
    /// Predicted per-phase costs.
    pub cost: PredictedCost,
}

/// The tuner's verdict: the min-predicted-cost candidate.
#[derive(Clone, Copy, Debug)]
pub struct AutoChoice {
    /// Winning tree spec (execute as `Algorithm::Tree(spec)`).
    pub spec: TreeSpec,
    /// Winning rank placement (rebuild the topology with it).
    pub placement: RankPlacement,
    /// The winner's predicted costs.
    pub cost: PredictedCost,
}

/// Score every candidate of both rank placements in deterministic grid
/// order.  `ctx.topo` supplies the machine *shape*; each placement gets
/// its own [`Topology`] because placement changes which ranks share a
/// socket/node — exactly the axis being tuned.
pub fn score_candidates(
    ctx: &CollectiveCtx,
    direction: Direction,
    views: &[(usize, FlatView)],
    file_cfg: &LustreConfig,
    overlap: OverlapMode,
) -> Result<Vec<ScoredCandidate>> {
    let mut out = Vec::new();
    for placement in [RankPlacement::Block, RankPlacement::RoundRobin] {
        let topo = Topology::hierarchical(
            ctx.topo.nodes,
            ctx.topo.ppn,
            ctx.topo.sockets_per_node,
            ctx.topo.nodes_per_switch,
            placement,
        );
        let pctx = CollectiveCtx { topo: &topo, ..*ctx };
        for spec in candidate_specs(&topo) {
            let cost = predict_spec_cost(&pctx, spec, direction, views, file_cfg, overlap)?;
            out.push(ScoredCandidate { spec, placement, cost });
        }
    }
    Ok(out)
}

/// Pick the min-predicted-cost candidate.  Strictly-less comparison in
/// enumeration order makes ties resolve to the earliest (and simplest)
/// candidate — the choice is a pure function of (views, topology shape,
/// striping, direction, cost models).
pub fn tune_collective(
    ctx: &CollectiveCtx,
    direction: Direction,
    views: &[(usize, FlatView)],
    file_cfg: &LustreConfig,
    overlap: OverlapMode,
) -> Result<AutoChoice> {
    let scored = score_candidates(ctx, direction, views, file_cfg, overlap)?;
    let mut best = scored[0];
    for c in &scored[1..] {
        if c.cost.total() < best.cost.total() {
            best = *c;
        }
    }
    Ok(AutoChoice { spec: best.spec, placement: best.placement, cost: best.cost })
}

// ---------------------------------------------------------------------------
// Memo fingerprint
// ---------------------------------------------------------------------------

/// The tuner's memo key: the collective's structural fingerprint
/// *minus the tuned axes*.  Hashes topology shape (but not rank
/// placement), global-aggregator policy/count, striping, direction,
/// the overlap mode (pipelining changes which candidate wins, so memos
/// are per-mode — note plan fingerprints deliberately do NOT include
/// it) and the requester views — never the algorithm, which is the
/// output.  Its own domain tag keeps it disjoint from plan
/// fingerprints sharing a [`PlanCache`] directory namespace.
pub fn fingerprint_autotune<'a>(
    ctx: &CollectiveCtx,
    direction: Direction,
    file_cfg: &LustreConfig,
    overlap: OverlapMode,
    views: impl Iterator<Item = (usize, &'a FlatView)>,
) -> Fp128 {
    let mut h = FpHasher::new("tamio-autotune-v2");
    h.write_u64(ctx.topo.nodes as u64);
    h.write_u64(ctx.topo.ppn as u64);
    h.write_u64(ctx.topo.sockets_per_node as u64);
    h.write_u64(ctx.topo.nodes_per_switch as u64);
    h.write_u64(match ctx.placement {
        GlobalPlacement::Spread => 0,
        GlobalPlacement::CrayRoundRobin => 1,
    });
    h.write_u64(ctx.n_global_agg as u64);
    h.write_u64(file_cfg.stripe_size);
    h.write_u64(file_cfg.stripe_count as u64);
    h.write_u64(match direction {
        Direction::Write => 0,
        Direction::Read => 1,
    });
    h.write_u64(match overlap {
        OverlapMode::Off => 0,
        OverlapMode::On => 1,
        OverlapMode::Auto => 2,
    });
    for (rank, view) in views {
        h.write_u64(rank as u64);
        h.write_u64(view.len() as u64);
        h.write_u64s(view.offsets());
        h.write_u64s(view.lengths());
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::breakdown::CpuModel;
    use crate::lustre::IoModel;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    fn views(nprocs: usize) -> Vec<(usize, FlatView)> {
        (0..nprocs)
            .map(|r| {
                let base = r as u64 * 4096;
                (
                    r,
                    FlatView::from_pairs((0..4).map(|i| (base + i * 512, 300)).collect())
                        .unwrap(),
                )
            })
            .collect()
    }

    struct Fx {
        net: NetParams,
        cpu: CpuModel,
        io: IoModel,
        eng: NativeEngine,
    }

    impl Fx {
        fn new() -> Self {
            Fx {
                net: NetParams::default(),
                cpu: CpuModel::default(),
                io: IoModel::default(),
                eng: NativeEngine,
            }
        }

        fn ctx<'a>(&'a self, topo: &'a Topology) -> CollectiveCtx<'a> {
            CollectiveCtx {
                topo,
                net: &self.net,
                cpu: &self.cpu,
                io: &self.io,
                engine: &self.eng,
                placement: GlobalPlacement::Spread,
                n_global_agg: 4,
            }
        }
    }

    #[test]
    fn ladder_is_bounded_sorted_and_keeps_endpoints() {
        assert_eq!(count_ladder(1), vec![1]);
        for limit in [2usize, 3, 4, 8, 12, 16, 24, 64, 100] {
            let l = count_ladder(limit);
            assert!(l.len() <= 4, "limit {limit}: {l:?}");
            assert!(!l.is_empty());
            assert_eq!(l[0], 1, "limit {limit}: {l:?}");
            assert_eq!(*l.last().unwrap(), limit, "limit {limit}: {l:?}");
            assert!(l.windows(2).all(|w| w[0] < w[1]), "limit {limit}: {l:?}");
        }
    }

    #[test]
    fn grid_matches_topology_shape() {
        // Flat machine: no socket or switch level ever appears.
        let flat = Topology::new(4, 8);
        let specs = candidate_specs(&flat);
        assert_eq!(specs[0], TreeSpec::flat(), "depth 0 leads the grid");
        assert!(specs.iter().all(|s| s.per_socket == 0 && s.per_switch == 0), "{specs:?}");
        assert!(specs.iter().any(|s| s.per_node > 0));

        // Hierarchical machine: both extra levels join the grid.
        let hier = Topology::hierarchical(4, 8, 2, 2, RankPlacement::Block);
        let specs = candidate_specs(&hier);
        assert!(specs.iter().any(|s| s.per_socket > 0), "{specs:?}");
        assert!(specs.iter().any(|s| s.per_switch > 0), "{specs:?}");
        assert!(specs.iter().any(|s| s.per_socket > 0 && s.per_switch > 0), "depth 3");
        assert!(specs.len() <= 32, "grid must stay bounded: {}", specs.len());

        // No duplicates, depth bounded by the machine's levels.
        for (i, a) in specs.iter().enumerate() {
            assert!(a.depth() <= 3);
            assert!(!specs[i + 1..].contains(a), "duplicate candidate {a}");
        }
    }

    #[test]
    fn predictor_prices_every_candidate_finitely() {
        let fx = Fx::new();
        let topo = Topology::hierarchical(2, 4, 2, 1, RankPlacement::Block);
        let ctx = fx.ctx(&topo);
        let vs = views(topo.nprocs());
        let cfg = LustreConfig::new(1024, 4);
        for dir in [Direction::Write, Direction::Read] {
            for spec in candidate_specs(&topo) {
                let c = predict_spec_cost(&ctx, spec, dir, &vs, &cfg, OverlapMode::Off).unwrap();
                assert!(c.total().is_finite(), "{spec} [{dir:?}]");
                assert!(c.total() > 0.0, "{spec} [{dir:?}]: {c:?}");
                assert!(c.round_comm > 0.0, "{spec} [{dir:?}]: rounds must cost");
                assert!(c.io_phase > 0.0, "{spec} [{dir:?}]");
                assert_eq!(c.overlap_saved, 0.0, "{spec} [{dir:?}]: off prices serially");
            }
        }
    }

    #[test]
    fn predictor_prices_overlap_as_a_bounded_credit() {
        let fx = Fx::new();
        let topo = Topology::hierarchical(2, 4, 2, 1, RankPlacement::Block);
        let ctx = fx.ctx(&topo);
        let vs = views(topo.nprocs());
        let cfg = LustreConfig::new(1024, 4);
        for dir in [Direction::Write, Direction::Read] {
            for spec in candidate_specs(&topo) {
                let off = predict_spec_cost(&ctx, spec, dir, &vs, &cfg, OverlapMode::Off).unwrap();
                let on = predict_spec_cost(&ctx, spec, dir, &vs, &cfg, OverlapMode::On).unwrap();
                // Overlap only subtracts hidden I/O — every other phase
                // component is identical to the serial pricing.
                assert!(on.overlap_saved >= 0.0);
                assert!(on.overlap_saved <= on.io_phase, "{spec} [{dir:?}]");
                assert!(
                    (off.total() - on.total() - on.overlap_saved).abs() < 1e-12,
                    "{spec} [{dir:?}]: {off:?} vs {on:?}"
                );
            }
            // The multi-round workload must show a real pipelining win for
            // at least the flat candidate, else `auto` can never prefer it.
            let on =
                predict_spec_cost(&ctx, TreeSpec::flat(), dir, &vs, &cfg, OverlapMode::On)
                    .unwrap();
            assert!(on.overlap_saved > 0.0, "[{dir:?}]: {on:?}");
        }
    }

    #[test]
    fn tuner_is_deterministic_and_picks_the_scored_minimum() {
        let fx = Fx::new();
        let topo = Topology::hierarchical(2, 4, 2, 1, RankPlacement::Block);
        let ctx = fx.ctx(&topo);
        let vs = views(topo.nprocs());
        let cfg = LustreConfig::new(1024, 4);
        let a = tune_collective(&ctx, Direction::Write, &vs, &cfg, OverlapMode::Off).unwrap();
        let b = tune_collective(&ctx, Direction::Write, &vs, &cfg, OverlapMode::Off).unwrap();
        assert_eq!(a.spec, b.spec);
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.cost.total(), b.cost.total());

        let scored = score_candidates(&ctx, Direction::Write, &vs, &cfg, OverlapMode::Off).unwrap();
        let min = scored
            .iter()
            .map(|c| c.cost.total())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(a.cost.total(), min, "tuner must return the scored minimum");
        // The winner is the FIRST candidate attaining the minimum.
        let first = scored.iter().find(|c| c.cost.total() == min).unwrap();
        assert_eq!(a.spec, first.spec);
        assert_eq!(a.placement, first.placement);
    }

    #[test]
    fn memo_fingerprint_excludes_the_tuned_axes_only() {
        let fx = Fx::new();
        let cfg = LustreConfig::new(1024, 4);
        let block = Topology::hierarchical(2, 4, 2, 1, RankPlacement::Block);
        let rr = Topology::hierarchical(2, 4, 2, 1, RankPlacement::RoundRobin);
        let vs = views(block.nprocs());
        let fp = |topo: &Topology, dir, vs: &[(usize, FlatView)], cfg: &LustreConfig| {
            let t = fx.ctx(topo);
            fingerprint_autotune(&t, dir, cfg, OverlapMode::Off, vs.iter().map(|(r, v)| (*r, v)))
        };
        // Rank placement is a tuned axis — it must NOT key the memo.
        assert_eq!(
            fp(&block, Direction::Write, &vs, &cfg),
            fp(&rr, Direction::Write, &vs, &cfg)
        );
        // Everything structural still does.
        assert_ne!(
            fp(&block, Direction::Write, &vs, &cfg),
            fp(&block, Direction::Read, &vs, &cfg)
        );
        assert_ne!(
            fp(&block, Direction::Write, &vs, &cfg),
            fp(&block, Direction::Write, &vs, &LustreConfig::new(2048, 4))
        );
        let mut vs2 = vs.clone();
        vs2[0].1 = FlatView::from_pairs(vec![(0, 64)]).unwrap();
        assert_ne!(
            fp(&block, Direction::Write, &vs, &cfg),
            fp(&block, Direction::Write, &vs2, &cfg)
        );
        let other = Topology::hierarchical(4, 2, 1, 0, RankPlacement::Block);
        assert_ne!(
            fp(&block, Direction::Write, &vs, &cfg),
            fp(&other, Direction::Write, &vs, &cfg)
        );
        // Overlap mode keys the memo (the winner can differ per mode) —
        // unlike plan fingerprints, which must NOT see it.
        let t = fx.ctx(&block);
        let fp_on = fingerprint_autotune(
            &t,
            Direction::Write,
            &cfg,
            OverlapMode::On,
            vs.iter().map(|(r, v)| (*r, v)),
        );
        assert_ne!(fp(&block, Direction::Write, &vs, &cfg), fp_on);
    }
}
