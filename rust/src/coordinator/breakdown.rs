//! Per-phase timing breakdowns matching the paper's Figures 4–7, plus the
//! CPU cost model for the computation components.
//!
//! The simulator executes every algorithmic step for real (sorts, merges,
//! byte movement) and *accounts* simulated time analytically so results are
//! deterministic and independent of host load: communication comes from
//! [`crate::netmodel`], I/O from [`crate::lustre::IoModel`], and the
//! computation components (request calculation, offset sorting, datatype
//! construction, memory movement) from [`CpuModel`] — per-item constants
//! calibrated to KNL-class cores (EXPERIMENTS.md §Calibration).

/// Per-item CPU cost constants (seconds) for the computation components.
#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    /// `ADIOI_LUSTRE_Calc_my_req`: per flattened request classified.
    pub per_req_calc: f64,
    /// Heap-merge comparison cost: multiplied by `n · log2(k)`.
    pub per_cmp_sort: f64,
    /// Memory movement, seconds per byte (intra-node copy bandwidth).
    pub per_byte_memcpy: f64,
    /// MPI derived-datatype construction, per offset-length entry.
    pub per_item_datatype: f64,
    /// Fixed cost per datatype (one per peer message).
    pub per_datatype: f64,
    /// Plan construction: per structural item touched while partitioning
    /// file domains, selecting aggregators, and indexing rounds.
    pub per_plan_item: f64,
}

impl Default for CpuModel {
    /// KNL-class core: ~1.3 GHz, modest IPC; memcpy ~4 GB/s per core.
    fn default() -> Self {
        CpuModel {
            per_req_calc: 8.0e-8,
            per_cmp_sort: 6.0e-8,
            per_byte_memcpy: 1.0 / 4.0e9,
            per_item_datatype: 4.0e-8,
            per_datatype: 2.0e-6,
            per_plan_item: 5.0e-8,
        }
    }
}

impl CpuModel {
    /// Heap k-way merge of `n` total items from `k` lists.
    pub fn merge_time(&self, n: u64, k: usize) -> f64 {
        if n == 0 || k == 0 {
            return 0.0;
        }
        let logk = (k.max(2) as f64).log2();
        n as f64 * logk * self.per_cmp_sort
    }

    /// Moving `bytes` through memory once.
    pub fn memcpy_time(&self, bytes: u64) -> f64 {
        bytes as f64 * self.per_byte_memcpy
    }

    /// Building `k` derived datatypes over `n` total entries.
    pub fn datatype_time(&self, n: u64, k: usize) -> f64 {
        k as f64 * self.per_datatype + n as f64 * self.per_item_datatype
    }

    /// Classifying `n` requests against file domains.
    pub fn calc_req_time(&self, n: u64) -> f64 {
        n as f64 * self.per_req_calc
    }

    /// Constructing the structural exchange plan: file-domain
    /// partitioning, aggregator selection, and the per-round CSR index
    /// over every classified piece.  Zero when no rank participates (an
    /// empty collective constructs nothing).
    pub fn plan_time(&self, requesters: u64, pieces: u64, n_agg: u64, n_rounds: u64) -> f64 {
        if requesters == 0 {
            return 0.0;
        }
        (requesters + pieces + n_agg + n_rounds) as f64 * self.per_plan_item
    }
}

/// Intra-aggregation time of one tree level (innermost level first in
/// [`Breakdown::levels`]): the per-level split of the `intra_*` sums, so
/// reports can attribute cost to the socket/node/switch tier it accrued
/// at.  For reads, `comm` covers both the gather (metadata up) and the
/// scatter (replies down) of the level.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LevelTime {
    /// Level label (`socket` / `node` / `switch`).
    pub label: &'static str,
    /// Gather (+ reply scatter on reads) communication at this level.
    pub comm: f64,
    /// Merge-sort time at this level's aggregators.
    pub sort: f64,
    /// Contiguous-buffer movement at this level.
    pub memcpy: f64,
}

impl LevelTime {
    /// Total time attributed to this level.
    pub fn total(&self) -> f64 {
        self.comm + self.sort + self.memcpy
    }
}

/// Simulated-time breakdown of one collective operation, with the exact
/// component set the paper plots.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Breakdown {
    // ---- intra-node aggregation (Figures 4–7 panels a–d) ----
    /// Gathering requests + data to local aggregators (many-to-one comm).
    pub intra_comm: f64,
    /// Merge-sorting gathered offsets at local aggregators.
    pub intra_sort: f64,
    /// Moving request data into contiguous buffers at local aggregators.
    pub intra_memcpy: f64,

    // ---- inter-node aggregation (Figures 4–7 panels e–h) ----
    /// `ADIOI_LUSTRE_Calc_my_req`: classifying requests by file domain.
    pub calc_my_req: f64,
    /// `ADIOI_Calc_others_req`: metadata exchange with global aggregators.
    pub calc_others_req: f64,
    /// Merge-sorting offsets at global aggregators.
    pub inter_sort: f64,
    /// MPI derived-datatype construction at global aggregators.
    pub inter_datatype: f64,
    /// Request-data exchange to global aggregators (many-to-many comm).
    pub inter_comm: f64,

    // ---- I/O phase ----
    /// File-system time at the global aggregators.
    pub io_phase: f64,

    // ---- plan construction ----
    /// Structural plan construction (file-domain partitioning, aggregator
    /// selection, round indexing).  Reported separately from the exchange
    /// components so the plan-cache win is visible in sweep tables; on a
    /// plan-cache hit this cost is *not* paid in wall-clock, but the
    /// simulated value is identical for hit and miss so cached runs stay
    /// bit-identical to cold runs.
    pub plan: f64,

    // ---- round pipelining ----
    /// Simulated time the double-buffered round pipeline (`--overlap
    /// on|auto`) removes from the critical path: per steady round, the
    /// part of round r's I/O phase hidden behind round r+1's exchange,
    /// bounded by the send-mode synchronization rule
    /// ([`crate::netmodel::NetParams::overlap_sync_bound`] — under
    /// `Issend` round r+1's sends cannot complete before round r's
    /// receivers post).  Zero on serial runs, so `total()` reduces to
    /// the classic phase sum.
    pub overlap_saved: f64,

    /// Per-tree-level split of the `intra_*` sums, innermost level first
    /// (empty for depth-0 plans / plain two-phase).  The sums above remain
    /// the totals; this is reporting detail, not a separate cost.
    pub levels: Vec<LevelTime>,
}

impl Breakdown {
    /// Intra-node aggregation total.
    pub fn intra_total(&self) -> f64 {
        self.intra_comm + self.intra_sort + self.intra_memcpy
    }

    /// Inter-node aggregation total.
    pub fn inter_total(&self) -> f64 {
        self.calc_my_req + self.calc_others_req + self.inter_sort + self.inter_datatype
            + self.inter_comm
    }

    /// End-to-end collective time: the phase sum minus whatever the
    /// round pipeline overlapped away (`overlap_saved` is bounded by
    /// `io_phase`, so the total never goes negative).
    pub fn total(&self) -> f64 {
        self.intra_total() + self.inter_total() + self.io_phase + self.plan
            - self.overlap_saved
    }

    /// Achieved bandwidth for `bytes` moved end-to-end.
    pub fn bandwidth(&self, bytes: u64) -> f64 {
        let t = self.total();
        if t <= 0.0 { 0.0 } else { bytes as f64 / t }
    }

    /// Component (label, seconds) rows in the paper's plotting order.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("intra_comm", self.intra_comm),
            ("intra_sort", self.intra_sort),
            ("intra_memcpy", self.intra_memcpy),
            ("calc_my_req", self.calc_my_req),
            ("calc_others_req", self.calc_others_req),
            ("inter_sort", self.inter_sort),
            ("inter_datatype", self.inter_datatype),
            ("inter_comm", self.inter_comm),
            ("io_phase", self.io_phase),
            ("plan", self.plan),
            ("overlap_saved", self.overlap_saved),
        ]
    }
}

/// Volume / congestion counters for one collective operation.
#[derive(Clone, Debug, Default)]
pub struct Counters {
    /// Total noncontiguous requests posted by all ranks.
    pub reqs_posted: u64,
    /// Requests remaining after intra-node coalescing (== posted for 2PIO).
    pub reqs_after_intra: u64,
    /// Total coalesced segments written by global aggregators.
    pub reqs_at_io: u64,
    /// Messages in the intra-node gather.
    pub msgs_intra: usize,
    /// Messages in the inter-node exchange (all rounds).
    pub msgs_inter: usize,
    /// Max per-global-aggregator in-degree in any round.
    pub max_in_degree: usize,
    /// Bytes written by the collective.
    pub bytes: u64,
    /// Two-phase rounds executed.
    pub rounds: u64,
    /// Extent-lock conflicts at the OSTs.
    pub lock_conflicts: u64,
    /// Storage retries performed under degraded execution (transient OST
    /// faults absorbed by the bounded retry-with-backoff policy; zero on
    /// fault-free runs).
    pub retries: u64,
    /// Exponential-backoff units paid across all retries (each unit costs
    /// [`crate::faults::RETRY_BACKOFF_BASE`] simulated seconds, folded
    /// into `io_phase`).
    pub backoff_units: u64,
    /// Collective plans rewritten by the aggregator-dropout repair pass.
    pub repaired_plans: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let b = Breakdown {
            intra_comm: 1.0,
            intra_sort: 2.0,
            intra_memcpy: 3.0,
            calc_my_req: 4.0,
            calc_others_req: 5.0,
            inter_sort: 6.0,
            inter_datatype: 7.0,
            inter_comm: 8.0,
            io_phase: 9.0,
            plan: 10.0,
            overlap_saved: 0.5,
            levels: Vec::new(),
        };
        assert_eq!(b.intra_total(), 6.0);
        assert_eq!(b.inter_total(), 30.0);
        // Pipelined overlap is a critical-path credit, not a phase.
        assert_eq!(b.total(), 54.5);
        assert_eq!(b.rows().len(), 11);
    }

    #[test]
    fn plan_time_is_zero_for_empty_collectives() {
        let c = CpuModel::default();
        assert_eq!(c.plan_time(0, 0, 8, 4), 0.0);
        assert!(c.plan_time(2, 100, 8, 4) > 0.0);
    }

    #[test]
    fn level_times_are_reporting_detail_not_extra_cost() {
        let mut b = Breakdown { intra_comm: 1.0, intra_sort: 0.5, ..Default::default() };
        b.levels.push(LevelTime { label: "socket", comm: 0.6, sort: 0.3, memcpy: 0.0 });
        b.levels.push(LevelTime { label: "node", comm: 0.4, sort: 0.2, memcpy: 0.0 });
        // The per-level split sums to the intra totals; total() ignores it.
        let split: f64 = b.levels.iter().map(LevelTime::total).sum();
        assert!((split - b.intra_total()).abs() < 1e-12);
        assert_eq!(b.total(), 1.5);
        assert_eq!(b.levels[0].label, "socket");
    }

    #[test]
    fn bandwidth_zero_time() {
        assert_eq!(Breakdown::default().bandwidth(100), 0.0);
    }

    #[test]
    fn merge_time_scales_with_log_k() {
        let c = CpuModel::default();
        let t2 = c.merge_time(1000, 2);
        let t16 = c.merge_time(1000, 16);
        assert!((t16 / t2 - 4.0).abs() < 1e-9); // log2(16)/log2(2) = 4
        assert_eq!(c.merge_time(0, 5), 0.0);
    }

    #[test]
    fn datatype_time_has_fixed_and_variable_parts() {
        let c = CpuModel::default();
        let base = c.datatype_time(0, 3);
        assert!(base > 0.0);
        assert!(c.datatype_time(100, 3) > base);
    }
}
