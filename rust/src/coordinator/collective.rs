//! Public collective-I/O entry points: algorithm dispatch, write + read.
//!
//! The read path performs the write path in reverse (§IV: "the collective
//! read operation performs simply in reverse order"): global aggregators
//! read their file domains and scatter pieces back to the requesters
//! (directly for two-phase; via the local aggregators for TAM).

use crate::coordinator::breakdown::{Breakdown, Counters};
use crate::coordinator::merge::ReqBatch;
use crate::coordinator::reqcalc::{calc_my_req, metadata_bytes};
use crate::coordinator::tam::{tam_write, TamConfig};
use crate::coordinator::twophase::{two_phase_write, CollectiveCtx};
use crate::coordinator::filedomain::FileDomains;
use crate::coordinator::placement::{
    per_node_count_for_total, select_global_aggregators, select_local_aggregators,
};
use crate::error::Result;
use crate::lustre::LustreFile;
use crate::mpisim::FlatView;
use crate::netmodel::phase::{cost_phase, Message};

/// Collective-I/O algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// ROMIO's classic two-phase I/O (baseline).
    TwoPhase,
    /// The paper's two-layer aggregation method.
    Tam(TamConfig),
}

impl Algorithm {
    /// Short name for reports.
    pub fn name(&self) -> String {
        match self {
            Algorithm::TwoPhase => "two-phase".into(),
            Algorithm::Tam(t) => format!("tam(P_L={})", t.total_local_aggregators),
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s == "two-phase" || s == "twophase" || s == "2p" {
            return Ok(Algorithm::TwoPhase);
        }
        if s == "tam" {
            return Ok(Algorithm::Tam(TamConfig::default()));
        }
        if let Some(pl) = s.strip_prefix("tam:") {
            let total = pl
                .parse()
                .map_err(|_| crate::Error::config(format!("bad P_L in '{s}'")))?;
            return Ok(Algorithm::Tam(TamConfig { total_local_aggregators: total }));
        }
        Err(crate::Error::config(format!(
            "unknown algorithm '{s}' (expected two-phase|tam|tam:<P_L>)"
        )))
    }
}

/// Result of one collective operation.
#[derive(Clone, Debug)]
pub struct CollectiveOutcome {
    /// Per-component simulated times.
    pub breakdown: Breakdown,
    /// Volume/congestion counters.
    pub counters: Counters,
}

/// Run a collective write with the selected algorithm.
pub fn run_collective_write(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
) -> Result<CollectiveOutcome> {
    let out = match algo {
        Algorithm::TwoPhase => two_phase_write(ctx, ranks, file)?,
        Algorithm::Tam(tam) => tam_write(ctx, &tam, ranks, file)?,
    };
    Ok(CollectiveOutcome { breakdown: out.breakdown, counters: out.counters })
}

/// Run a collective read: each requester's `view` is filled from `file`.
///
/// Returns the per-rank payloads (view order) and the outcome.  The
/// communication structure mirrors the write in reverse; the I/O phase
/// reads whole file domains.
pub fn run_collective_read(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    let mut bd = Breakdown::default();
    let mut counters = Counters::default();

    // Aggregate region + domains, as in the write path.
    let lo = views.iter().filter_map(|(_, v)| v.min_offset()).min().unwrap_or(0);
    let hi = views.iter().filter_map(|(_, v)| v.max_end()).max().unwrap_or(0);
    let n_agg = ctx.n_global_agg.min(ctx.topo.nprocs()).max(1);
    let domains = FileDomains::new(*file.config(), lo, hi, n_agg);
    let agg_ranks = select_global_aggregators(ctx.topo, n_agg, ctx.placement);

    counters.reqs_posted = views.iter().map(|(_, v)| v.len() as u64).sum();
    counters.bytes = views.iter().map(|(_, v)| v.total_bytes()).sum();
    counters.rounds = domains.n_rounds();

    // For TAM, reads flow file → global aggs → local aggs → ranks; the
    // local aggregators aggregate their members' views first (metadata
    // only — no payload on the request side of a read).
    let (requesters, scatter_plan): (Vec<(usize, FlatView)>, Option<Vec<(usize, usize)>>) =
        match algo {
            Algorithm::TwoPhase => (views.clone(), None),
            Algorithm::Tam(tam) => {
                let c = per_node_count_for_total(ctx.topo, tam.total_local_aggregators);
                let locals = select_local_aggregators(ctx.topo, c);
                let mut gather_msgs = Vec::new();
                let mut per_agg: std::collections::HashMap<usize, Vec<&FlatView>> =
                    Default::default();
                for (rank, v) in &views {
                    let agg = locals.assignment[*rank];
                    if *rank != agg {
                        gather_msgs.push(Message::new(*rank, agg, metadata_bytes(v.len() as u64)));
                    }
                    per_agg.entry(agg).or_default().push(v);
                }
                bd.intra_comm = cost_phase(ctx.net, ctx.topo, &gather_msgs).time;
                counters.msgs_intra = gather_msgs.len();
                let mut agg_views: Vec<(usize, FlatView)> = per_agg
                    .into_iter()
                    .map(|(agg, vs)| {
                        let merged = crate::coordinator::merge::merge_views(&vs);
                        (agg, merged)
                    })
                    .collect();
                agg_views.sort_unstable_by_key(|(a, _)| *a);
                let plan = views
                    .iter()
                    .map(|(rank, _)| (*rank, locals.assignment[*rank]))
                    .collect();
                (agg_views, Some(plan))
            }
        };

    // Metadata to global aggregators (who needs what), once.
    let mut meta_msgs = Vec::new();
    for (rank, view) in &requesters {
        let batch = ReqBatch::new(view.clone(), Vec::new());
        let mr = calc_my_req(&domains, &batch);
        let mut per_agg: std::collections::HashMap<usize, u64> = Default::default();
        for ((_, agg), b) in &mr.by_dest {
            *per_agg.entry(*agg).or_default() += b.view.len() as u64;
        }
        for (agg, n) in per_agg {
            meta_msgs.push(Message::new(*rank, agg_ranks[agg], metadata_bytes(n)));
        }
    }
    let meta_cost = cost_phase(ctx.net, ctx.topo, &meta_msgs);
    bd.calc_others_req = meta_cost.time;
    counters.msgs_inter += meta_msgs.len();
    counters.max_in_degree = meta_cost.max_in_degree;

    // I/O phase: aggregators read their domains (extent-accurate
    // accounting happens through read cost only — reads take the same
    // seek+bandwidth shape).
    let mut ost_bytes = vec![0u64; file.config().stripe_count];
    let mut ost_extents = vec![0u64; file.config().stripe_count];

    // Reply data: aggregator → requester, then (TAM) local agg → rank.
    let mut reply_msgs: Vec<Message> = Vec::new();
    let mut filled: Vec<(usize, Vec<u8>)> = Vec::new();
    for (rank, view) in &requesters {
        let mut payload = vec![0u8; view.total_bytes() as usize];
        let mut cursor = 0usize;
        for (off, len) in view.iter() {
            let bytes = file.read_at(off, len);
            payload[cursor..cursor + len as usize].copy_from_slice(&bytes);
            cursor += len as usize;
            for (ost, _piece_off, piece_len) in file.config().split_by_stripe(off, len) {
                ost_bytes[ost] += piece_len;
                ost_extents[ost] += 1;
            }
            let agg = domains.aggregator_of(off);
            reply_msgs.push(Message::new(agg_ranks[agg], *rank, len));
        }
        filled.push((*rank, payload));
    }
    let reply_cost = cost_phase(ctx.net, ctx.topo, &reply_msgs);
    bd.inter_comm = reply_cost.time;
    counters.msgs_inter += reply_msgs.len();

    let stats: Vec<crate::lustre::OstStats> = ost_bytes
        .iter()
        .zip(&ost_extents)
        .map(|(&bytes, &extents)| crate::lustre::OstStats {
            bytes,
            extents,
            lock_acquisitions: 0,
            lock_conflicts: 0,
        })
        .collect();
    bd.io_phase = ctx.io.phase_time(&stats);

    // TAM: scatter from local aggregators back to member ranks.
    if let Some(plan) = scatter_plan {
        let agg_payloads: std::collections::HashMap<usize, (FlatView, Vec<u8>)> = filled
            .into_iter()
            .zip(requesters.iter())
            .map(|((agg, payload), (_, view))| (agg, (view.clone(), payload)))
            .collect();
        let mut scatter_msgs = Vec::new();
        let mut out: Vec<(usize, Vec<u8>)> = Vec::new();
        for (rank, view) in &views {
            let agg = plan
                .iter()
                .find(|(r, _)| r == rank)
                .map(|(_, a)| *a)
                .expect("rank in plan");
            let (aview, apayload) = &agg_payloads[&agg];
            // Slice the member's bytes out of the aggregated buffer.
            let mut payload = Vec::with_capacity(view.total_bytes() as usize);
            for (off, len) in view.iter() {
                let pos = locate(aview, off);
                payload.extend_from_slice(&apayload[pos..pos + len as usize]);
            }
            if *rank != agg {
                scatter_msgs.push(Message::new(agg, *rank, view.total_bytes()));
            }
            out.push((*rank, payload));
        }
        bd.intra_memcpy = ctx.cpu.memcpy_time(out.iter().map(|(_, p)| p.len() as u64).sum());
        bd.intra_comm += cost_phase(ctx.net, ctx.topo, &scatter_msgs).time;
        counters.msgs_intra += scatter_msgs.len();
        return Ok((out, CollectiveOutcome { breakdown: bd, counters }));
    }

    Ok((filled, CollectiveOutcome { breakdown: bd, counters }))
}

/// Byte position of absolute file offset `off` within the payload of the
/// sorted, coalesced `view` (panics if `off` is not covered — a protocol
/// violation caught in tests).
fn locate(view: &FlatView, off: u64) -> usize {
    let offsets = view.offsets();
    let idx = match offsets.binary_search(&off) {
        Ok(i) => i,
        Err(i) => i - 1,
    };
    let mut pos = 0u64;
    for l in &view.lengths()[..idx] {
        pos += l;
    }
    (pos + (off - offsets[idx])) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::breakdown::CpuModel;
    use crate::coordinator::placement::GlobalPlacement;
    use crate::lustre::{IoModel, LustreConfig};
    use crate::mpisim::rank::deterministic_payload;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    fn fixture() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
        (
            Topology::new(2, 4),
            NetParams::default(),
            CpuModel::default(),
            IoModel::default(),
            NativeEngine,
        )
    }

    fn make_ranks(topo: &Topology) -> Vec<(usize, ReqBatch)> {
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * 100;
                let view =
                    FlatView::from_pairs(vec![(base, 30), (base + 50, 20)]).unwrap();
                let payload = deterministic_payload(5, r, 50);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn algorithm_parses() {
        assert_eq!("two-phase".parse::<Algorithm>().unwrap(), Algorithm::TwoPhase);
        assert!(matches!("tam".parse::<Algorithm>().unwrap(), Algorithm::Tam(_)));
        match "tam:64".parse::<Algorithm>().unwrap() {
            Algorithm::Tam(t) => assert_eq!(t.total_local_aggregators, 64),
            _ => panic!(),
        }
        assert!("bogus".parse::<Algorithm>().is_err());
    }

    #[test]
    fn write_then_read_round_trip_two_phase() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) =
            run_collective_read(&ctx, Algorithm::TwoPhase, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} read-back mismatch");
        }
        assert!(outcome.breakdown.total() > 0.0);
    }

    #[test]
    fn write_then_read_round_trip_tam() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        let algo = Algorithm::Tam(TamConfig { total_local_aggregators: 2 });
        run_collective_write(&ctx, algo, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) = run_collective_read(&ctx, algo, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} TAM read-back mismatch");
        }
        assert!(outcome.breakdown.intra_comm > 0.0, "TAM read has intra traffic");
    }

    #[test]
    fn locate_positions() {
        let v = FlatView::from_pairs(vec![(10, 5), (20, 5)]).unwrap();
        assert_eq!(locate(&v, 10), 0);
        assert_eq!(locate(&v, 12), 2);
        assert_eq!(locate(&v, 20), 5);
        assert_eq!(locate(&v, 24), 9);
    }
}
