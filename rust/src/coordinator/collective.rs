//! Public collective-I/O entry points: algorithm dispatch, write + read.
//!
//! The read path performs the write path in reverse (§IV: "the collective
//! read operation performs simply in reverse order"): global aggregators
//! read their round domains and scatter pieces back to the requesters
//! (directly for two-phase; via the local aggregators for TAM).  Like the
//! write exchange, the read is round-structured and arena-backed: each
//! aggregator owns a [`ReadScratch`] whose staging and payload buffers
//! keep their capacity across rounds, the peer-view merge runs through
//! [`crate::runtime::engine::SortEngine::merge_sorted`], and the file is
//! read with one vectored [`LustreFile::read_view`] call per aggregator
//! per round (DESIGN.md §Read path).

use crate::coordinator::breakdown::{Breakdown, Counters};
use crate::coordinator::filedomain::FileDomains;
use crate::coordinator::merge::{gather_from_buf, ReadScratch, ReqBatch};
use crate::coordinator::placement::select_global_aggregators;
use crate::coordinator::reqcalc::{calc_my_req, metadata_bytes, MyReqs};
use crate::coordinator::tam::{intra_node_read_views, tam_write, TamConfig};
use crate::coordinator::twophase::{two_phase_write, CollectiveCtx, ExchangeOutcome};
use crate::error::Result;
use crate::lustre::{LustreFile, OstStats};
use crate::mpisim::FlatView;
use crate::netmodel::phase::{cost_phase, Message, PendingQueue};
use crate::util::par_map;

/// Collective-I/O algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// ROMIO's classic two-phase I/O (baseline).
    TwoPhase,
    /// The paper's two-layer aggregation method.
    Tam(TamConfig),
}

impl Algorithm {
    /// Short name for reports.
    pub fn name(&self) -> String {
        match self {
            Algorithm::TwoPhase => "two-phase".into(),
            Algorithm::Tam(t) => format!("tam(P_L={})", t.total_local_aggregators),
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s == "two-phase" || s == "twophase" || s == "2p" {
            return Ok(Algorithm::TwoPhase);
        }
        if s == "tam" {
            return Ok(Algorithm::Tam(TamConfig::default()));
        }
        if let Some(pl) = s.strip_prefix("tam:") {
            let total = pl
                .parse()
                .map_err(|_| crate::Error::config(format!("bad P_L in '{s}'")))?;
            return Ok(Algorithm::Tam(TamConfig { total_local_aggregators: total }));
        }
        Err(crate::Error::config(format!(
            "unknown algorithm '{s}' (expected two-phase|tam|tam:<P_L>)"
        )))
    }
}

/// Result of one collective operation.
#[derive(Clone, Debug)]
pub struct CollectiveOutcome {
    /// Per-component simulated times.
    pub breakdown: Breakdown,
    /// Volume/congestion counters.
    pub counters: Counters,
}

/// Run a collective write with the selected algorithm.
pub fn run_collective_write(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
) -> Result<CollectiveOutcome> {
    let out = match algo {
        Algorithm::TwoPhase => two_phase_write(ctx, ranks, file)?,
        Algorithm::Tam(tam) => tam_write(ctx, &tam, ranks, file)?,
    };
    Ok(CollectiveOutcome { breakdown: out.breakdown, counters: out.counters })
}

/// Run a collective read: each requester's `view` is filled from `file`.
///
/// Returns the per-rank payloads (view order) and the outcome.  The
/// communication structure mirrors the write in reverse: for TAM, reads
/// flow file → global aggregators → local aggregators → ranks, with the
/// local aggregators merging their members' view metadata first
/// ([`intra_node_read_views`]) and scattering the reply bytes back last.
pub fn run_collective_read(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    let posted: u64 = views.iter().map(|(_, v)| v.len() as u64).sum();
    match algo {
        Algorithm::TwoPhase => {
            let (filled, out) = read_exchange(ctx, views, file)?;
            let mut counters = out.counters;
            counters.reqs_posted = posted;
            Ok((
                filled.into_iter().map(|(rank, _, payload)| (rank, payload)).collect(),
                CollectiveOutcome { breakdown: out.breakdown, counters },
            ))
        }
        Algorithm::Tam(tam) => {
            let intra = intra_node_read_views(ctx, &tam, &views)?;
            let assignment = intra.assignment;
            let (agg_filled, out) = read_exchange(ctx, intra.agg_views, file)?;
            let mut bd = out.breakdown;
            let mut counters = out.counters;
            bd.intra_sort = intra.sort;
            counters.reqs_posted = posted;

            // Scatter from local aggregators back to member ranks: each
            // member's bytes are gathered out of its aggregator's
            // contiguous reply buffer with the same two-pointer walk the
            // write path scatters with (both views are sorted).  Members
            // are independent (each reads only its aggregator's immutable
            // buffer), so the gathers run concurrently like every other
            // per-rank stage of the read path.
            let mut slot_of = vec![usize::MAX; ctx.topo.nprocs()];
            for (i, (agg, _, _)) in agg_filled.iter().enumerate() {
                slot_of[*agg] = i;
            }
            let gathered: Vec<(usize, Vec<u8>, u64, Option<Message>)> =
                par_map(views, |(rank, view)| {
                    let agg = assignment[rank];
                    let mut payload = vec![0u8; view.total_bytes() as usize];
                    if !view.is_empty() {
                        let slot = slot_of[agg];
                        debug_assert_ne!(slot, usize::MAX, "member view without aggregator");
                        let (_, aview, apayload) = &agg_filled[slot];
                        gather_from_buf(aview, apayload, &view, &mut payload);
                    }
                    let msg = if rank != agg {
                        Some(Message::new(agg, rank, view.total_bytes()))
                    } else {
                        None
                    };
                    (rank, payload, view.total_bytes(), msg)
                });
            let scatter_msgs: Vec<Message> =
                gathered.iter().filter_map(|(_, _, _, m)| *m).collect();
            let scattered_bytes: u64 = gathered.iter().map(|(_, _, b, _)| *b).sum();
            let filled: Vec<(usize, Vec<u8>)> =
                gathered.into_iter().map(|(rank, payload, _, _)| (rank, payload)).collect();
            bd.intra_comm = intra.comm + cost_phase(ctx.net, ctx.topo, &scatter_msgs).time;
            bd.intra_memcpy = ctx.cpu.memcpy_time(scattered_bytes);
            counters.msgs_intra = intra.msgs + scatter_msgs.len();
            Ok((filled, CollectiveOutcome { breakdown: bd, counters }))
        }
    }
}

/// Inter-node stage of the collective read — the write exchange in
/// reverse, round-structured and arena-backed:
///
/// * requesters classify their views against the file domains
///   (`calc_my_req`, metadata only — no payload travels on the request
///   side of a read) and send per-aggregator metadata once;
/// * per round, each global aggregator merges the peer views addressed to
///   it through the engine, reads the merged segments from `file` in one
///   vectored [`LustreFile::read_view`] call into its reusable
///   [`ReadScratch`] buffer, and replies with each peer's bytes
///   ([`gather_from_buf`]);
/// * requesters append replies directly into their output payloads: a
///   sorted view's pieces carry nondecreasing `(round, aggregator)` keys,
///   so concatenation in drain order reproduces view order with no
///   reorder pass (self-overlapping views go through their disjoint
///   union first — see the `prepared` step).
///
/// Returns per-requester `(rank, view, payload)` in input order, plus the
/// outcome.  Engine and storage failures propagate as `Err` out of the
/// parallel per-aggregator maps instead of aborting a worker thread.
fn read_exchange(
    ctx: &CollectiveCtx,
    requesters: Vec<(usize, FlatView)>,
    file: &LustreFile,
) -> Result<(Vec<(usize, FlatView, Vec<u8>)>, ExchangeOutcome)> {
    let mut bd = Breakdown::default();
    let mut counters = Counters::default();

    // Aggregate region + domains, as in the write path.
    let lo = requesters.iter().filter_map(|(_, v)| v.min_offset()).min().unwrap_or(0);
    let hi = requesters.iter().filter_map(|(_, v)| v.max_end()).max().unwrap_or(0);
    let n_agg = ctx.n_global_agg.min(ctx.topo.nprocs()).max(1);
    let domains = FileDomains::new(*file.config(), lo, hi, n_agg);
    let agg_ranks = select_global_aggregators(ctx.topo, n_agg, ctx.placement);

    counters.reqs_after_intra = requesters.iter().map(|(_, v)| v.len() as u64).sum();
    counters.bytes = requesters.iter().map(|(_, v)| v.total_bytes()).sum();

    // Self-overlapping requester views (legal for reads — MPI only
    // forbids overlapping filetypes for writes; a TAM aggregator view can
    // also overlap when two members read the same region) are exchanged
    // as their disjoint union: classification order and reply-assembly
    // order agree only for non-overlapping views.  The original view's
    // bytes are gathered back out of the union payload at the end; the
    // common disjoint case pays nothing.
    let prepared: Vec<(usize, FlatView, Option<FlatView>)> = requesters
        .into_iter()
        .map(|(rank, v)| {
            if v.has_overlap() {
                let union = v.disjoint_union();
                (rank, union, Some(v))
            } else {
                (rank, v, None)
            }
        })
        .collect();

    // ---- Calc_my_req on the requester views, concurrent across
    // requesters → simulated time is the max.
    let mut my_reqs: Vec<(usize, FlatView, Option<FlatView>, MyReqs)> =
        par_map(prepared, |(rank, view, original)| {
            let batch = ReqBatch::new(view, Vec::new());
            let mr = calc_my_req(&domains, &batch);
            (rank, batch.view, original, mr)
        });
    bd.calc_my_req = my_reqs
        .iter()
        .map(|(_, _, _, mr)| ctx.cpu.calc_req_time(mr.pieces))
        .fold(0.0, f64::max);

    // ---- Metadata to the aggregators (who needs what), once, covering
    // all rounds.
    let mut meta_msgs: Vec<Message> = Vec::new();
    for (rank, _, _, mr) in &my_reqs {
        for (agg, n) in mr.reqs_per_agg() {
            meta_msgs.push(Message::new(*rank, agg_ranks[agg], metadata_bytes(n)));
        }
    }
    let meta_cost = cost_phase(ctx.net, ctx.topo, &meta_msgs);
    bd.calc_others_req = meta_cost.time;
    counters.msgs_inter += meta_msgs.len();
    counters.max_in_degree = counters.max_in_degree.max(meta_cost.max_in_degree);

    let n_rounds = domains.n_rounds();
    counters.rounds = n_rounds;

    // ---- Rounds: aggregator merge + vectored read + reply assembly.
    let mut payloads: Vec<Vec<u8>> =
        my_reqs.iter().map(|(_, v, _, _)| vec![0u8; v.total_bytes() as usize]).collect();
    let mut cursors = vec![0usize; my_reqs.len()];
    let mut pending = PendingQueue::new();
    let mut scratch: Vec<ReadScratch> = (0..n_agg).map(|_| ReadScratch::default()).collect();
    for slot in scratch.iter_mut() {
        slot.stats.resize(file.config().stripe_count, OstStats::default());
    }
    let mut reply_msgs: Vec<Message> = Vec::new();
    for round in 0..n_rounds {
        reply_msgs.clear();
        for slot in scratch.iter_mut() {
            slot.reset_round();
        }
        for (i, (rank, _, _, mr)) in my_reqs.iter_mut().enumerate() {
            for (agg, b) in mr.take_round(round) {
                // The reply travels aggregator → requester; the request
                // metadata already went in the metadata phase.
                reply_msgs.push(Message::new(agg_ranks[agg], *rank, b.view.total_bytes()));
                scratch[agg].batches.push((i, b.view));
            }
        }
        let comm = pending.cost_round(ctx.net, ctx.topo, &reply_msgs);
        bd.inter_comm += comm.time;
        counters.msgs_inter += reply_msgs.len();
        counters.max_in_degree = counters.max_in_degree.max(comm.max_in_degree);

        // Aggregator-side merge + vectored read, concurrent across
        // aggregators (reads take `&file`).
        let merged: Vec<Result<ReadScratch>> =
            par_map(std::mem::take(&mut scratch), |mut slot| {
                slot.merge_with(ctx.engine)?;
                if !slot.merged.is_empty() {
                    file.read_view(&slot.merged, &mut slot.payload, &mut slot.stats)?;
                }
                Ok(slot)
            });
        scratch = merged.into_iter().collect::<Result<Vec<_>>>()?;

        let mut sort_t: f64 = 0.0;
        let mut dt_t: f64 = 0.0;
        for slot in &scratch {
            if slot.k == 0 {
                continue;
            }
            sort_t = sort_t.max(ctx.cpu.merge_time(slot.n_items, slot.k));
            dt_t = dt_t.max(ctx.cpu.datatype_time(slot.n_items, slot.k));
            counters.reqs_at_io += slot.merged.len() as u64;
            // Requester-side assembly: ascending aggregator within the
            // round, ascending rounds overall ⇒ straight concatenation.
            for (i, view) in &slot.batches {
                let n = view.total_bytes() as usize;
                let dst = &mut payloads[*i][cursors[*i]..cursors[*i] + n];
                gather_from_buf(&slot.merged, &slot.payload, view, dst);
                cursors[*i] += n;
            }
        }
        bd.inter_sort += sort_t;
        bd.inter_datatype += dt_t;
    }
    debug_assert!(
        cursors.iter().zip(&payloads).all(|(c, p)| *c == p.len()),
        "reply assembly must fill every requester payload exactly"
    );

    // ---- I/O phase time from the accumulated per-OST read stats.
    let mut stats = vec![OstStats::default(); file.config().stripe_count];
    for slot in &scratch {
        for (acc, s) in stats.iter_mut().zip(&slot.stats) {
            acc.bytes += s.bytes;
            acc.extents += s.extents;
        }
    }
    bd.io_phase = ctx.io.phase_time(&stats);

    let filled = my_reqs
        .into_iter()
        .zip(payloads)
        .map(|((rank, view, original, _), payload)| match original {
            None => (rank, view, payload),
            Some(orig) => {
                // Expand the union payload back to the overlapping
                // original view (duplicated bytes are copied per request).
                let mut out = vec![0u8; orig.total_bytes() as usize];
                gather_from_buf(&view, &payload, &orig, &mut out);
                (rank, orig, out)
            }
        })
        .collect();
    Ok((filled, ExchangeOutcome { breakdown: bd, counters }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::breakdown::CpuModel;
    use crate::coordinator::placement::GlobalPlacement;
    use crate::lustre::{IoModel, LustreConfig};
    use crate::mpisim::rank::deterministic_payload;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    fn fixture() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
        (
            Topology::new(2, 4),
            NetParams::default(),
            CpuModel::default(),
            IoModel::default(),
            NativeEngine,
        )
    }

    fn make_ranks(topo: &Topology) -> Vec<(usize, ReqBatch)> {
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * 100;
                let view =
                    FlatView::from_pairs(vec![(base, 30), (base + 50, 20)]).unwrap();
                let payload = deterministic_payload(5, r, 50);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn algorithm_parses() {
        assert_eq!("two-phase".parse::<Algorithm>().unwrap(), Algorithm::TwoPhase);
        assert!(matches!("tam".parse::<Algorithm>().unwrap(), Algorithm::Tam(_)));
        match "tam:64".parse::<Algorithm>().unwrap() {
            Algorithm::Tam(t) => assert_eq!(t.total_local_aggregators, 64),
            _ => panic!(),
        }
        assert!("bogus".parse::<Algorithm>().is_err());
    }

    #[test]
    fn write_then_read_round_trip_two_phase() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) =
            run_collective_read(&ctx, Algorithm::TwoPhase, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} read-back mismatch");
        }
        assert!(outcome.breakdown.total() > 0.0);
    }

    #[test]
    fn write_then_read_round_trip_tam() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        let algo = Algorithm::Tam(TamConfig { total_local_aggregators: 2 });
        run_collective_write(&ctx, algo, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) = run_collective_read(&ctx, algo, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} TAM read-back mismatch");
        }
        assert!(outcome.breakdown.intra_comm > 0.0, "TAM read has intra traffic");
    }

    #[test]
    fn read_accounts_rounds_and_computation() {
        // Multi-round read: the round structure and the new computation
        // components (calc_my_req, inter_sort, inter_datatype) must show
        // up in the outcome, and reqs_at_io must reflect coalescing.
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        // 8 ranks × 256 contiguous bytes = 32 stripes over 4 aggs → 8 rounds.
        let ranks: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
            .map(|r| {
                let view = FlatView::from_pairs(vec![(r as u64 * 256, 256)]).unwrap();
                (r, ReqBatch::new(view, deterministic_payload(3, r, 256)))
            })
            .collect();
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) =
            run_collective_read(&ctx, Algorithm::TwoPhase, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r}");
        }
        assert_eq!(outcome.counters.rounds, 8);
        assert_eq!(outcome.counters.bytes, 2048);
        assert!(outcome.breakdown.calc_my_req > 0.0);
        assert!(outcome.breakdown.inter_sort > 0.0);
        assert!(outcome.breakdown.inter_datatype > 0.0);
        assert!(outcome.breakdown.io_phase > 0.0);
        // Each rank's 256B request splits into 4 stripes, but adjacent
        // ranks coalesce at the aggregators: at most one segment per
        // aggregator per round reaches the I/O layer.
        assert!(outcome.counters.reqs_at_io <= 32);
        assert!(outcome.counters.msgs_inter > 0);
    }

    #[test]
    fn read_supports_overlapping_views() {
        // Overlap is legal for reads: ranks 0 and 1 read shared bytes,
        // rank 1's view overlaps itself, rank 2's view nests a request
        // inside a bigger one.  With TAM the merged aggregator view then
        // overlaps too (the disjoint-union exchange path).
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let img = deterministic_payload(9, 0, 256);
        run_collective_write(
            &ctx,
            Algorithm::TwoPhase,
            vec![(
                0usize,
                ReqBatch::new(FlatView::from_pairs(vec![(0, 256)]).unwrap(), img.clone()),
            )],
            &mut file,
        )
        .unwrap();
        let views = vec![
            (0usize, FlatView::from_pairs(vec![(0, 128)]).unwrap()),
            (1usize, FlatView::from_pairs(vec![(64, 64), (96, 32)]).unwrap()),
            (2usize, FlatView::from_pairs(vec![(0, 200), (50, 10)]).unwrap()),
        ];
        let want: Vec<Vec<u8>> = views
            .iter()
            .map(|(_, v)| {
                let mut p = Vec::new();
                for (off, len) in v.iter() {
                    p.extend_from_slice(&img[off as usize..(off + len) as usize]);
                }
                p
            })
            .collect();
        for algo in
            [Algorithm::TwoPhase, Algorithm::Tam(TamConfig { total_local_aggregators: 2 })]
        {
            let (got, _) = run_collective_read(&ctx, algo, views.clone(), &file).unwrap();
            for (i, (r, payload)) in got.iter().enumerate() {
                assert_eq!(payload, &want[i], "{} rank {r}", algo.name());
            }
        }
    }

    #[test]
    fn read_of_empty_and_zero_length_views() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        run_collective_write(
            &ctx,
            Algorithm::TwoPhase,
            vec![(
                0usize,
                ReqBatch::new(FlatView::from_pairs(vec![(0, 64)]).unwrap(), vec![7u8; 64]),
            )],
            &mut file,
        )
        .unwrap();
        let views = vec![
            (0usize, FlatView::from_pairs(vec![(0, 32), (40, 0), (48, 16)]).unwrap()),
            (1usize, FlatView::empty()),
            (2usize, FlatView::from_pairs(vec![(10, 0)]).unwrap()),
        ];
        for algo in
            [Algorithm::TwoPhase, Algorithm::Tam(TamConfig { total_local_aggregators: 2 })]
        {
            let (got, _) = run_collective_read(&ctx, algo, views.clone(), &file).unwrap();
            assert_eq!(got[0].1, vec![7u8; 48], "{}", algo.name());
            assert!(got[1].1.is_empty());
            assert!(got[2].1.is_empty());
        }
    }
}
