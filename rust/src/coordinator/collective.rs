//! Public collective-I/O entry points: algorithm dispatch and the
//! direction-generic round-exchange engine.
//!
//! Reads and writes share one two-phase skeleton (§IV; "the collective
//! read operation performs simply in reverse order"): classify requester
//! views against the file domains (`calc_my_req`), exchange metadata
//! once, then run round-sliced peer exchanges in which each global
//! aggregator merges the peer views addressed to it through the engine
//! and performs one vectored storage call per round.  [`run_exchange`] is
//! that skeleton, written once; the [`Direction`] axis — bound by
//! [`ExchangeIo`] — specializes only the genuinely divergent steps:
//! which way the payload messages point, payload scatter
//! ([`crate::coordinator::merge::RoundScratch::merge_scatter`]) vs reply
//! gather ([`gather_from_buf`]), `LustreFile::write_view` vs
//! `read_view`, and where the I/O-phase statistics accumulate
//! (DESIGN.md §Direction-generic exchange).
//!
//! Every algorithm drives the same loop through an N-level
//! [`AggregationPlan`]: two-phase is the depth-0 plan (every rank is a
//! requester), TAM the depth-1 node-level plan, and `tree:` specs stack
//! arbitrary socket/node/switch levels on top — in either direction
//! ([`crate::coordinator::tree`]).

use crate::coordinator::breakdown::{Breakdown, Counters};
use crate::coordinator::filedomain::FileDomains;
use crate::coordinator::merge::{gather_from_buf, gather_slices_from_buf, ReqBatch, RoundScratch};
use crate::coordinator::placement::select_global_aggregators;
use crate::coordinator::reqcalc::{calc_my_req_structure, metadata_bytes, MyReqs};
use crate::coordinator::tam::{tam_write, TamConfig};
use crate::coordinator::tree::{tree_read, tree_write, AggregationPlan, TreeSpec};
use crate::coordinator::twophase::{two_phase_write, CollectiveCtx, ExchangeOutcome};
use crate::error::{Error, Result};
use crate::faults;
use crate::lustre::{LustreConfig, LustreFile, OstStats};
use crate::mpisim::FlatView;
use crate::netmodel::phase::{cost_phase, Message, OverlapAccount, PendingQueue, PhaseCost};
use crate::util::par_map;
use crate::util::runtime;
use std::sync::Mutex;

/// Persistent buffers of the exchange round loop, owned by the caller so
/// their capacity survives across rounds *and* across `run_*` invocations
/// within a sweep (DESIGN.md §Memory layout): the per-aggregator
/// [`RoundScratch`] slots (staging slabs, merged-view arena, payload
/// buffer, merge-heap storage), the per-round message list, the
/// [`PendingQueue`] (with its sharded phase-cost scratch) and the dense
/// metadata-phase accumulator.  A
/// steady-state round allocates (near-)zero — enforced by the
/// counting-allocator test `tests/alloc_steady_state.rs` — which is what
/// makes the paper's 16384-rank/256-node sweep point tractable.
///
/// `Default::default()` is an empty arena; every `run_*` entry point that
/// does not take one constructs its own (one-shot callers pay only the
/// warm-up they always paid).
#[derive(Debug, Default)]
pub struct ExchangeArena {
    /// Per-aggregator round scratch (grown to the exchange's `n_agg` on
    /// demand; surplus slots from a larger previous exchange stay warm
    /// and idle).
    pub scratch: Vec<RoundScratch>,
    /// Second ping/pong bank of per-aggregator round scratch for the
    /// double-buffered pipeline (`overlap` on/auto): while one bank's
    /// round is in its storage call, the next round stages and merges
    /// into the other (DESIGN.md §Round pipelining).  Empty until the
    /// first pipelined exchange; serial exchanges never touch it.
    pub scratch2: Vec<RoundScratch>,
    /// Per-round exchange message list.
    pub data_msgs: Vec<Message>,
    /// Pending-send queue (Isend model) + sharded phase-cost scratch.
    pub pending: PendingQueue,
    /// Dense per-aggregator request totals for the metadata phase.
    pub meta_reqs: Vec<u64>,
    /// Per-(tree level, aggregator) scratch slots for the aggregation
    /// tree's intra stages (`levels[ℓ][slot]`; empty for depth-0 plans).
    pub levels: Vec<Vec<RoundScratch>>,
    /// Pooled read-reply slab keyed by requester — the read direction's
    /// per-requester reply payloads, one warm allocation instead of one
    /// `Vec` per requester per exchange (the last per-exchange allocation
    /// that scaled with `P`).  Valid until the next read exchange through
    /// this arena.
    pub reply: ReplySlab,
    /// Per-requester payload buffers staged into destination-slab order
    /// by [`execute_exchange`] (capacity-warm across exchanges) — the
    /// write path's payload home now that cached structural plans carry
    /// no payload slab of their own.
    pub staged: Vec<Vec<u8>>,
    /// Round-pipelining mode of exchanges run through this arena.
    /// Drivers copy `RunConfig::overlap` here
    /// (`experiments::run_direction_*`); the default is
    /// [`OverlapMode::Off`], so raw entry-point callers keep the serial
    /// schedule bit-identically.  Execution-time property only: plan
    /// fingerprints, output bytes and verification never depend on it.
    pub overlap: OverlapMode,
    /// Per-round critical-path ledger of the last pipelined exchange
    /// (capacity reused across exchanges; feeds the `overlap_saved`
    /// breakdown row).
    pub overlap_acct: OverlapAccount,
}

/// Pooled reply storage of one read exchange: requester `i`'s reply bytes
/// occupy `bytes[starts[i]..starts[i + 1]]`, assembled in round order
/// through per-requester cursors.  All three vectors keep their capacity
/// across exchanges (the slab lives in the [`ExchangeArena`]).
#[derive(Debug, Default)]
pub struct ReplySlab {
    /// Reply bytes, all requesters concatenated.
    bytes: Vec<u8>,
    /// Requester span boundaries (`R + 1` entries once reset).
    starts: Vec<usize>,
    /// Per-requester assembly cursor (bytes written so far).
    cursors: Vec<usize>,
}

impl ReplySlab {
    /// Lay the slab out for a new exchange: one span per requester byte
    /// total, zero-filled, cursors rewound.  Capacity is reused.
    pub fn reset(&mut self, totals: impl Iterator<Item = usize>) {
        self.starts.clear();
        self.starts.push(0);
        let mut acc = 0usize;
        for t in totals {
            acc += t;
            self.starts.push(acc);
        }
        self.cursors.clear();
        self.cursors.resize(self.starts.len() - 1, 0);
        self.bytes.clear();
        self.bytes.resize(acc, 0);
    }

    /// Number of requester spans.
    pub fn len(&self) -> usize {
        self.starts.len().saturating_sub(1)
    }

    /// True when the slab holds no spans.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Requester `i`'s (fully or partially assembled) reply bytes.
    pub fn of(&self, i: usize) -> &[u8] {
        &self.bytes[self.starts[i]..self.starts[i + 1]]
    }

    /// The next `n` unwritten bytes of requester `i`'s span, advancing
    /// its cursor — the assembly target of one staged round stream.
    ///
    /// # Panics
    ///
    /// Panics (in release builds too) when the write would overrun
    /// requester `i`'s span: the slab is shared, so an accounting bug
    /// upstream would otherwise silently corrupt the *next* requester's
    /// reply instead of crashing the way the old per-requester `Vec`s
    /// did.  One compare per staged stream — not per byte.
    pub fn append_slot(&mut self, i: usize, n: usize) -> &mut [u8] {
        let lo = self.starts[i] + self.cursors[i];
        self.cursors[i] += n;
        assert!(
            self.starts[i] + self.cursors[i] <= self.starts[i + 1],
            "reply span overflow for requester {i}"
        );
        &mut self.bytes[lo..lo + n]
    }

    /// Whether every span has been assembled exactly (the end-of-exchange
    /// invariant of the read direction).
    pub fn fully_assembled(&self) -> bool {
        self.cursors
            .iter()
            .enumerate()
            .all(|(i, &c)| self.starts[i] + c == self.starts[i + 1])
    }
}

/// Where one requester's read-exchange reply lives: in the arena's pooled
/// slab (the common, non-overlapping case) or in an owned buffer (views
/// that had to be exchanged as their disjoint union).  Resolve with
/// [`ReadReply::bytes`].
#[derive(Debug)]
pub enum ReadReply {
    /// Requester index into [`ExchangeArena::reply`].
    Slab(usize),
    /// Overlap-expanded bytes (self-overlapping views only).
    Owned(Vec<u8>),
}

impl ReadReply {
    /// The reply bytes, wherever they live.
    pub fn bytes<'a>(&'a self, arena: &'a ExchangeArena) -> &'a [u8] {
        match self {
            ReadReply::Slab(i) => arena.reply.of(*i),
            ReadReply::Owned(v) => v,
        }
    }
}

/// Direction axis of the collective pipeline: one round-exchange engine
/// ([`run_exchange`]) serves both directions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Requesters push payloads to the aggregators, which persist them.
    Write,
    /// Aggregators read the file and reply with each requester's bytes.
    Read,
}

impl std::fmt::Display for Direction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Direction::Write => write!(f, "write"),
            Direction::Read => write!(f, "read"),
        }
    }
}

impl std::str::FromStr for Direction {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "write" | "w" => Ok(Direction::Write),
            "read" | "r" => Ok(Direction::Read),
            other => Err(crate::Error::config(format!(
                "unknown direction '{other}' (expected write|read)"
            ))),
        }
    }
}

/// Driver-level direction selector (`RunConfig::direction`, the CLI's
/// `--direction` flag): one direction or both, write first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DirectionSpec {
    /// Write collectives only (the pre-direction-axis behaviour).
    #[default]
    Write,
    /// Read collectives only (the driver pre-populates the file).
    Read,
    /// The write panel first, then the read panel.
    Both,
}

impl DirectionSpec {
    /// The directions a run covers, in execution order.
    pub fn runs(self) -> &'static [Direction] {
        match self {
            DirectionSpec::Write => &[Direction::Write],
            DirectionSpec::Read => &[Direction::Read],
            DirectionSpec::Both => &[Direction::Write, Direction::Read],
        }
    }
}

impl std::fmt::Display for DirectionSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DirectionSpec::Write => write!(f, "write"),
            DirectionSpec::Read => write!(f, "read"),
            DirectionSpec::Both => write!(f, "both"),
        }
    }
}

impl std::str::FromStr for DirectionSpec {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "write" | "w" => Ok(DirectionSpec::Write),
            "read" | "r" => Ok(DirectionSpec::Read),
            "both" | "rw" | "wr" => Ok(DirectionSpec::Both),
            other => Err(crate::Error::config(format!(
                "unknown direction '{other}' (expected write|read|both)"
            ))),
        }
    }
}

/// Round-pipelining selector (`RunConfig::overlap`, the CLI's
/// `--overlap` flag): whether [`execute_exchange`] double-buffers its
/// round loop so round r+1's staging + merge overlaps round r's storage
/// call (DESIGN.md §Round pipelining).  An execution-schedule property
/// only — plan fingerprints, file bytes, reply payloads and every
/// volume counter are bit-identical in all three modes, at any pool
/// width; only the `overlap_saved` breakdown credit differs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverlapMode {
    /// Strictly serial rounds — the classic two-phase schedule, and the
    /// default so existing runs stay bit-identical.
    #[default]
    Off,
    /// Pipeline whenever the exchange has at least two rounds.
    On,
    /// Let the engine decide per exchange.  Today identical to `On`
    /// (every multi-round exchange benefits under the cost model); a
    /// distinct mode so drivers can defer to future cost-model gating
    /// without a flag change.
    Auto,
}

impl OverlapMode {
    /// Whether an exchange of `n_rounds` rounds runs the double-buffered
    /// pipeline.  Single-round exchanges degenerate to the serial loop —
    /// there is no next round to overlap with.
    pub fn pipelines(self, n_rounds: u64) -> bool {
        match self {
            OverlapMode::Off => false,
            OverlapMode::On | OverlapMode::Auto => n_rounds >= 2,
        }
    }
}

impl std::fmt::Display for OverlapMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OverlapMode::Off => write!(f, "off"),
            OverlapMode::On => write!(f, "on"),
            OverlapMode::Auto => write!(f, "auto"),
        }
    }
}

impl std::str::FromStr for OverlapMode {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(OverlapMode::Off),
            "on" => Ok(OverlapMode::On),
            "auto" => Ok(OverlapMode::Auto),
            other => Err(crate::Error::config(format!(
                "unknown overlap mode '{other}' (expected on|off|auto)"
            ))),
        }
    }
}

/// Collective-I/O algorithm selector.  All three are depths of the same
/// hierarchical pipeline ([`AggregationPlan`]): two-phase is depth 0, TAM
/// is the depth-1 node-level tree, `Tree` is the general N-level form.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// ROMIO's classic two-phase I/O (baseline; the depth-0 tree).
    TwoPhase,
    /// The paper's two-layer aggregation method (the depth-1 node tree).
    Tam(TamConfig),
    /// An N-level aggregation tree over the machine hierarchy
    /// (`tree:socket=4,node=2,switch=1`).
    Tree(TreeSpec),
    /// Cost-model-driven auto-tuning: search the [`TreeSpec`] × rank
    /// placement space with the metadata-only predictor
    /// ([`crate::coordinator::autotune`]) and run the min-predicted-cost
    /// candidate.  Drivers resolve this to `Tree(spec)` *before*
    /// dispatch (`experiments::run_direction_*`); the raw entry points
    /// reject it rather than guess a tree.
    Auto,
}

impl Algorithm {
    /// Short name for reports.
    pub fn name(&self) -> String {
        match self {
            Algorithm::TwoPhase => "two-phase".into(),
            Algorithm::Tam(t) => format!("tam(P_L={})", t.total_local_aggregators),
            Algorithm::Tree(spec) => format!("tree({spec})"),
            Algorithm::Auto => "auto".into(),
        }
    }
}

impl std::str::FromStr for Algorithm {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        if s == "two-phase" || s == "twophase" || s == "2p" {
            return Ok(Algorithm::TwoPhase);
        }
        if s == "tam" {
            return Ok(Algorithm::Tam(TamConfig::default()));
        }
        if let Some(pl) = s.strip_prefix("tam:") {
            let total = pl
                .parse()
                .map_err(|_| crate::Error::config(format!("bad P_L in '{s}'")))?;
            return Ok(Algorithm::Tam(TamConfig { total_local_aggregators: total }));
        }
        if s == "tree" {
            return Ok(Algorithm::Tree(TreeSpec::default()));
        }
        if let Some(spec) = s.strip_prefix("tree:") {
            return Ok(Algorithm::Tree(spec.parse()?));
        }
        if s == "auto" {
            return Ok(Algorithm::Auto);
        }
        Err(crate::Error::config(format!(
            "unknown algorithm '{s}' (expected two-phase|tam|tam:<P_L>|tree:<levels>|auto)"
        )))
    }
}

/// Result of one collective operation.
#[derive(Clone, Debug)]
pub struct CollectiveOutcome {
    /// Per-component simulated times.
    pub breakdown: Breakdown,
    /// Volume/congestion counters.
    pub counters: Counters,
}

/// Run a collective write with the selected algorithm (one-shot arena;
/// sweeps use [`run_collective_write_with`]).
pub fn run_collective_write(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
) -> Result<CollectiveOutcome> {
    run_collective_write_with(ctx, algo, ranks, file, &mut ExchangeArena::default())
}

/// [`run_collective_write`] with a caller-owned [`ExchangeArena`], so
/// repeated collectives (sweeps, benches) reuse every exchange buffer.
pub fn run_collective_write_with(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<CollectiveOutcome> {
    let out = match algo {
        Algorithm::TwoPhase => two_phase_write(ctx, ranks, file, arena)?,
        Algorithm::Tam(tam) => tam_write(ctx, &tam, ranks, file, arena)?,
        Algorithm::Tree(spec) => {
            let plan = AggregationPlan::from_spec(ctx.topo, &spec);
            tree_write(ctx, &plan, ranks, file, arena)?
        }
        Algorithm::Auto => {
            return Err(crate::Error::config(
                "--algorithm auto must be resolved by the driver (experiments::run_direction_*) \
                 before dispatch; call tune_collective and pass the chosen Tree spec",
            ))
        }
    };
    Ok(CollectiveOutcome { breakdown: out.breakdown, counters: out.counters })
}

/// Run a collective read: each requester's `view` is filled from `file`.
///
/// Returns the per-rank payloads (view order) and the outcome.  The
/// communication structure mirrors the write in reverse through the
/// algorithm's [`AggregationPlan`]: reads flow file → global aggregators →
/// down the aggregation tree → ranks, with each level's aggregators
/// merging their members' view metadata on the way up and scattering the
/// reply bytes back on the way down
/// ([`crate::coordinator::tree::tree_read`]).
pub fn run_collective_read(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    run_collective_read_with(ctx, algo, views, file, &mut ExchangeArena::default())
}

/// [`run_collective_read`] with a caller-owned [`ExchangeArena`] (the
/// write twin is [`run_collective_write_with`]).
pub fn run_collective_read_with(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    if algo == Algorithm::Auto {
        return Err(crate::Error::config(
            "--algorithm auto must be resolved by the driver (experiments::run_direction_*) \
             before dispatch; call tune_collective and pass the chosen Tree spec",
        ));
    }
    let plan = AggregationPlan::for_algorithm(ctx.topo, &algo);
    tree_read(ctx, &plan, views, file, arena)
}

/// Per-direction storage binding of one exchange: writes mutate the file,
/// reads share it (per-OST read statistics accumulate in the scratch
/// slots instead, since the file is immutable on reads).
pub enum ExchangeIo<'f> {
    /// Write direction: aggregators persist merged batches.
    Write(&'f mut LustreFile),
    /// Read direction: aggregators fill their buffers from the file.
    Read(&'f LustreFile),
}

impl ExchangeIo<'_> {
    /// The direction this binding drives.
    pub fn direction(&self) -> Direction {
        match self {
            ExchangeIo::Write(_) => Direction::Write,
            ExchangeIo::Read(_) => Direction::Read,
        }
    }

    fn file_config(&self) -> &LustreConfig {
        match self {
            ExchangeIo::Write(f) => f.config(),
            ExchangeIo::Read(f) => f.config(),
        }
    }
}

/// The direction-generic inter-node exchange + I/O phase — the single
/// round loop shared by collective writes and reads, for both TwoPhase
/// (every rank is a requester) and TAM (the local aggregators are):
///
/// * requesters classify their views against the file domains
///   (`calc_my_req`; payload travels with the pieces only on writes) and
///   send per-aggregator metadata once, covering all rounds;
/// * per round, requesters and aggregators exchange — batches move
///   requester → aggregator on writes, replies move aggregator →
///   requester on reads — costed through the same [`PendingQueue`]
///   model; each global aggregator merges the peer views addressed to it
///   through the engine into its reusable [`RoundScratch`] arena and
///   performs one vectored storage call ([`LustreFile::write_view`] /
///   [`LustreFile::read_view`]);
/// * on reads, requesters append replies directly into their spans of the
///   arena's pooled [`ReplySlab`]: a sorted view's pieces carry
///   nondecreasing `(round, aggregator)` keys, so concatenation in drain
///   order reproduces view order with no reorder pass (self-overlapping
///   read views go through [`exchange_read`]'s disjoint-union step first).
///
/// Returns per-requester `(rank, view)` in input order, plus the outcome;
/// on reads, requester `i`'s reply bytes are `arena.reply.of(i)` (valid
/// until the next read exchange through this arena — the slab replaces
/// the per-requester `Vec` allocations that scaled with `P`).  Engine and
/// storage failures propagate as `Err` out of the parallel per-aggregator
/// maps instead of aborting a worker thread (on that error path the
/// arena's scratch slots are dropped and re-grown by the next exchange —
/// capacity, never correctness, is lost).
pub fn run_exchange(
    ctx: &CollectiveCtx,
    requesters: Vec<(usize, ReqBatch)>,
    io: ExchangeIo<'_>,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, FlatView)>, ExchangeOutcome)> {
    let plan = {
        let views: Vec<(usize, &FlatView)> =
            requesters.iter().map(|(rank, b)| (*rank, &b.view)).collect();
        build_exchange_plan(ctx, &views, io.file_config())?
    };
    execute_exchange(ctx, &plan, requesters, io, arena)
}

/// One requester of an [`ExchangePlan`]: its rank, the shape of the view
/// the plan was built for (validated against the call's batch by
/// [`execute_exchange`]), and the classified CSR slabs (structure only —
/// no payload).
#[derive(Debug)]
pub struct PlannedRequester {
    /// Requesting rank.
    pub rank: usize,
    /// Number of offset-length entries in the planned view.
    pub view_len: usize,
    /// Total bytes of the planned view.
    pub view_bytes: u64,
    /// The classified request structure ([`calc_my_req_structure`]).
    pub reqs: MyReqs,
}

/// Immutable structural plan of one inter-node exchange: every artifact
/// [`run_exchange`] used to rebuild per call — the file-domain partition,
/// the selected global-aggregator ranks, the round count, and each
/// requester's classified CSR slabs.  Built once by
/// [`build_exchange_plan`], executed any number of times by
/// [`execute_exchange`] (which validates the call against the plan and
/// re-stages payload), and cached/persisted by
/// [`crate::coordinator::plancache::PlanCache`].
#[derive(Debug)]
pub struct ExchangePlan {
    /// The file-domain partition (striping + access region + round grid).
    pub domains: FileDomains,
    /// Global aggregator ranks, one per domain.
    pub agg_ranks: Vec<usize>,
    /// Rounds the exchange runs (`domains.n_rounds()`, denormalized).
    pub n_rounds: u64,
    /// Per-requester classified structure, in requester order.
    pub reqs: Vec<PlannedRequester>,
}

/// Construct the structural plan of one exchange from requester views:
/// file-domain partitioning, global-aggregator selection, and the
/// parallel `ADIOI_LUSTRE_Calc_my_req` classification of every view
/// (structure only — payload never enters the plan).  This is exactly the
/// per-call setup work a plan-cache hit skips.
pub fn build_exchange_plan(
    ctx: &CollectiveCtx,
    views: &[(usize, &FlatView)],
    file_cfg: &LustreConfig,
) -> Result<ExchangePlan> {
    // Aggregate access region across requesters.
    let lo = views.iter().filter_map(|(_, v)| v.min_offset()).min().unwrap_or(0);
    let hi = views.iter().filter_map(|(_, v)| v.max_end()).max().unwrap_or(0);
    let n_agg = ctx.n_global_agg.min(ctx.topo.nprocs()).max(1);
    let domains = FileDomains::new(*file_cfg, lo, hi, n_agg);
    let agg_ranks = select_global_aggregators(ctx.topo, n_agg, ctx.placement);
    // Runs concurrently on all requesters (the same par_map machinery the
    // aggregator merge uses — at 16384 ranks the serial per-rank request
    // build dominated setup).
    let reqs: Vec<PlannedRequester> = par_map(views.to_vec(), |(rank, view)| {
        let mr = calc_my_req_structure(&domains, view)?;
        Ok(PlannedRequester {
            rank,
            view_len: view.len(),
            view_bytes: view.total_bytes(),
            reqs: mr,
        })
    })
    .into_iter()
    .collect::<Result<_>>()?;
    let n_rounds = domains.n_rounds();
    Ok(ExchangePlan { domains, agg_ranks, n_rounds, reqs })
}

/// Stage round `round`'s requests into one scratch bank and cost the
/// exchange through the pending queue: per-round slot state is re-zeroed,
/// slab slices out of each requester's `MyReqs` are memcpy'd into the
/// bank's staging slabs (capacity-warm after round 0), and the message
/// list is rebuilt for the [`PendingQueue`] — which MUST be driven in
/// ascending round order (the Isend pending counts evolve round to
/// round), which is why the pipelined schedule keeps staging on the
/// driver thread.  Shared verbatim by the serial and pipelined loops so
/// their byte movement and accounting cannot drift apart.
#[allow(clippy::too_many_arguments)]
fn stage_round_into(
    ctx: &CollectiveCtx,
    plan: &ExchangePlan,
    direction: Direction,
    staged: &[Vec<u8>],
    data_msgs: &mut Vec<Message>,
    pending: &mut PendingQueue,
    bank: &mut [RoundScratch],
    round: u64,
) -> PhaseCost {
    data_msgs.clear();
    for slot in bank.iter_mut() {
        slot.reset_round();
    }
    for (i, pr) in plan.reqs.iter().enumerate() {
        for (agg, s) in pr.reqs.slices_in_round_with(round, &staged[i]) {
            data_msgs.push(match direction {
                Direction::Write => Message::new(pr.rank, plan.agg_ranks[agg], s.bytes),
                Direction::Read => Message::new(plan.agg_ranks[agg], pr.rank, s.bytes),
            });
            bank[agg].stage(i, s.offsets, s.lengths, s.payload, s.bytes);
        }
    }
    pending.cost_round(ctx.net, ctx.topo, data_msgs)
}

/// Lowest-index error collection for heterogeneous pooled batches — the
/// [`runtime::Runtime::try_for_each_mut`] determinism rule, replicated
/// for `for_each_index` submissions whose tasks mix roles (the pipelined
/// I/O + next-round-merge batch).  Whichever lane errors first, the
/// surviving error is the one with the smallest task index.
fn record_first_err(slot: &Mutex<Option<(usize, Error)>>, i: usize, e: Error) {
    let mut slot = slot.lock().unwrap();
    match &*slot {
        Some((prev, _)) if *prev <= i => {}
        _ => *slot = Some((i, e)),
    }
}

/// Raw-pointer wrapper so disjoint `&mut` projections can cross a pooled
/// closure's `Sync` bound (the `util::runtime` idiom, replicated here for
/// the pipelined batch: the I/O task's `&mut LustreFile` and each merge
/// task's bank slot).  Soundness arguments live at the use sites.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Execute one exchange over a borrowed [`ExchangePlan`] — the pure
/// executor half of the construct-once/execute-many split.  Performs zero
/// plan construction: the call's requesters are validated against the
/// plan (count, rank, view shape — a stale or corrupt plan fails as
/// [`Error::Protocol`], never as corruption), each write payload is
/// staged into destination-slab order through the plan's recorded source
/// positions, and the round loop drains the plan's CSR slabs.  All
/// simulated times (including `Breakdown::plan`) are computed here from
/// `ctx`, so a cached execution is bit-identical to a cold one.
///
/// With `arena.overlap` on (and ≥ 2 rounds) the round loop runs the
/// double-buffered pipeline — prologue (round 0 stages + merges),
/// steady state (round r's storage call and round r+1's staging + merge
/// in one pooled batch over disjoint ping/pong banks), epilogue (the
/// last round has nothing left to stage) — with file operations in
/// exactly the serial order, so results are bit-identical to the serial
/// schedule at any thread width and only the `overlap_saved` breakdown
/// credit differs (DESIGN.md §Round pipelining).
pub fn execute_exchange(
    ctx: &CollectiveCtx,
    plan: &ExchangePlan,
    requesters: Vec<(usize, ReqBatch)>,
    mut io: ExchangeIo<'_>,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, FlatView)>, ExchangeOutcome)> {
    let direction = io.direction();
    let mut bd = Breakdown::default();
    let mut counters = Counters::default();

    let n_agg = plan.domains.n_agg;
    let agg_ranks = &plan.agg_ranks;
    if requesters.len() != plan.reqs.len() {
        return Err(Error::Protocol(format!(
            "exchange plan covers {} requesters but the call has {}",
            plan.reqs.len(),
            requesters.len()
        )));
    }
    if agg_ranks.len() != n_agg {
        return Err(Error::Protocol(format!(
            "exchange plan has {} aggregator ranks for {n_agg} domains",
            agg_ranks.len()
        )));
    }

    // Stage each requester's fresh payload into destination-slab order
    // through the plan's recorded source positions (a straight memcpy
    // pass — no reclassification; reads stage nothing).  Buffers keep
    // their capacity across exchanges.
    if arena.staged.len() < requesters.len() {
        arena.staged.resize_with(requesters.len(), Vec::new);
    }
    for (i, ((rank, batch), pr)) in requesters.iter().zip(&plan.reqs).enumerate() {
        if *rank != pr.rank
            || batch.view.len() != pr.view_len
            || batch.view.total_bytes() != pr.view_bytes
        {
            return Err(Error::Protocol(format!(
                "exchange plan does not match requester {i}: plan has rank {} \
                 ({} entries, {} bytes), call has rank {rank} ({} entries, {} bytes)",
                pr.rank,
                pr.view_len,
                pr.view_bytes,
                batch.view.len(),
                batch.view.total_bytes()
            )));
        }
        pr.reqs.stage_payload(&batch.payload, &mut arena.staged[i]);
    }
    // Past validation + staging only the views are needed.
    let views: Vec<(usize, FlatView)> =
        requesters.into_iter().map(|(rank, b)| (rank, b.view)).collect();

    counters.reqs_after_intra = views.iter().map(|(_, v)| v.len() as u64).sum();
    counters.bytes = views.iter().map(|(_, v)| v.total_bytes()).sum();

    // Simulated plan-construction cost: identical whether this execution
    // came from a cache hit or a cold build (determinism), reported in
    // its own breakdown row so sweeps can see what a warm plan amortizes.
    bd.calc_my_req = plan
        .reqs
        .iter()
        .map(|pr| ctx.cpu.calc_req_time(pr.reqs.pieces))
        .fold(0.0, f64::max);
    let total_pieces: u64 = plan.reqs.iter().map(|pr| pr.reqs.pieces).sum();
    bd.plan =
        ctx.cpu.plan_time(plan.reqs.len() as u64, total_pieces, n_agg as u64, plan.n_rounds);

    // ---- ADIOI_Calc_others_req: metadata to the aggregators (who needs
    // what), once, covering all rounds.  Per-agg totals accumulate into
    // the arena's dense counter instead of a fresh Vec per rank.
    let mut meta_msgs: Vec<Message> = Vec::new();
    for pr in &plan.reqs {
        arena.meta_reqs.clear();
        arena.meta_reqs.resize(n_agg, 0);
        pr.reqs.reqs_per_agg_into(&mut arena.meta_reqs);
        for (agg, &n) in arena.meta_reqs.iter().enumerate() {
            if n > 0 {
                meta_msgs.push(Message::new(pr.rank, agg_ranks[agg], metadata_bytes(n)));
            }
        }
    }
    let meta_cost = cost_phase(ctx.net, ctx.topo, &meta_msgs);
    bd.calc_others_req = meta_cost.time;
    counters.msgs_inter += meta_msgs.len();
    counters.max_in_degree = counters.max_in_degree.max(meta_cost.max_in_degree);

    let n_rounds = plan.n_rounds;
    counters.rounds = n_rounds;

    // ---- Rounds: peer exchange, aggregator merge, vectored storage op.
    // Reply spans exist only on the read side (writes return no bytes):
    // the arena's pooled slab replaces one zero-filled `Vec` per
    // requester — the last per-exchange allocation that scaled with `P`.
    if direction == Direction::Read {
        arena.reply.reset(views.iter().map(|(_, v)| v.total_bytes() as usize));
    }
    // Arena slots: grow to n_agg, re-zero per-exchange state (stats slots
    // exist on reads only), keep all capacity.
    arena.pending.reset();
    if arena.scratch.len() < n_agg {
        arena.scratch.resize_with(n_agg, RoundScratch::default);
    }
    let n_osts = match direction {
        Direction::Read => io.file_config().stripe_count,
        Direction::Write => 0,
    };
    for slot in arena.scratch.iter_mut() {
        slot.reset_exchange(n_osts);
    }
    // Double-buffered pipelining is a schedule property: same plan, same
    // bytes, same file-operation order — only who computes what *when*
    // (and the `overlap_saved` accounting credit) differs.  Single-round
    // exchanges have nothing to overlap and take the serial path.
    let pipelined = arena.overlap.pipelines(n_rounds);
    if pipelined {
        if arena.scratch2.len() < n_agg {
            arena.scratch2.resize_with(n_agg, RoundScratch::default);
        }
        for slot in arena.scratch2.iter_mut() {
            slot.reset_exchange(n_osts);
        }
    }
    arena.overlap_acct.reset();
    let mut scratch = std::mem::take(&mut arena.scratch);
    // Bank B stays empty on the serial path, so the end-of-exchange
    // stats sweep (which covers both banks) sees exactly the serial
    // state; a stale bank from an earlier pipelined exchange is left
    // untouched in the arena.
    let mut scratch2 =
        if pipelined { std::mem::take(&mut arena.scratch2) } else { Vec::new() };
    let rt = runtime::current();
    // Degraded-execution accounting: transient storage faults are absorbed
    // by a bounded retry-with-backoff at each storage call site (atomics
    // because the read sites run on the worker pool).  Fault-free runs
    // never touch the retry path and stay bit-identical.
    use std::sync::atomic::{AtomicU64, Ordering};
    let retries_ctr = AtomicU64::new(0);
    let backoff_ctr = AtomicU64::new(0);
    if !pipelined {
        // ---- Serial schedule: each round's exchange, merge and storage
        // call run strictly back-to-back.
        for round in 0..n_rounds {
            // Stage this round's requests per aggregator: slab slices out
            // of the requester's MyReqs are memcpy'd into the aggregator's
            // staging slab (capacity-warm after round 0 — the simulator's
            // stand-in for the message landing in a receive buffer); on
            // reads the slice is metadata only and the matching bytes
            // travel back as the reply.
            let comm = stage_round_into(
                ctx,
                plan,
                direction,
                &arena.staged,
                &mut arena.data_msgs,
                &mut arena.pending,
                &mut scratch,
                round,
            );
            bd.inter_comm += comm.time;
            counters.msgs_inter += comm.n_messages;
            counters.max_in_degree = counters.max_in_degree.max(comm.max_in_degree);

            // Aggregator-side merge (+ payload scatter on writes, vectored
            // file read on reads), concurrent across aggregators → max for
            // time, real bytes either way.  One fine-grained `(round,
            // aggregator)` task per slot on the persistent pool: slots are
            // mutated IN PLACE (no per-round drain/rebuild, so the arena
            // capacity stays put), workers steal tasks but each task owns
            // exactly its pre-assigned slot (determinism), and an engine or
            // storage failure — or a panic — surfaces with the failing
            // task's round + aggregator identity.
            match &io {
                ExchangeIo::Write(_) => rt.try_for_each_mut(
                    &mut scratch,
                    &|agg| format!("write exchange round {round}, aggregator {agg}"),
                    |_, slot| {
                        slot.merge_scatter(ctx.engine)?;
                        Ok(())
                    },
                )?,
                ExchangeIo::Read(f) => {
                    let file = *f;
                    // Reads never pass through `begin_round` (the file is
                    // shared immutably), so round-armed faults tick here.
                    file.tick_fault_round();
                    let (retries_ctr, backoff_ctr) = (&retries_ctr, &backoff_ctr);
                    rt.try_for_each_mut(
                        &mut scratch,
                        &|agg| format!("read exchange round {round}, aggregator {agg}"),
                        |_, slot| {
                            slot.merge_meta(ctx.engine)?;
                            if !slot.merged.is_empty() {
                                let (merged, payload, stats) =
                                    (&slot.merged, &mut slot.payload, &mut slot.stats);
                                let (out, r) = faults::retrying(file.max_retries(), || {
                                    file.read_view(merged, payload, stats)
                                });
                                if r > 0 {
                                    retries_ctr.fetch_add(r as u64, Ordering::Relaxed);
                                    backoff_ctr
                                        .fetch_add(faults::backoff_units(r), Ordering::Relaxed);
                                }
                                out?;
                            }
                            Ok(())
                        },
                    )?;
                }
            }

            let mut sort_t: f64 = 0.0;
            let mut dt_t: f64 = 0.0;
            if let ExchangeIo::Write(file) = &mut io {
                file.begin_round();
            }
            for (agg, slot) in scratch.iter().enumerate() {
                if slot.k == 0 {
                    continue;
                }
                sort_t = sort_t.max(ctx.cpu.merge_time(slot.n_items, slot.k));
                dt_t = dt_t.max(ctx.cpu.datatype_time(slot.n_items, slot.k));
                counters.reqs_at_io += slot.merged.len() as u64;
                match &mut io {
                    ExchangeIo::Write(file) => {
                        // The merged batch lies inside this aggregator's round
                        // domain by construction; land the whole coalesced
                        // batch in one vectored call.  Transient OST faults are
                        // retried with backoff (byte-idempotent: a partial
                        // write before the fault is simply overwritten); the
                        // surfaced error carries the failing task's identity
                        // like the pooled read tasks already do.
                        let (out, r) = faults::retrying(file.max_retries(), || {
                            file.write_view(agg_ranks[agg], &slot.merged, &slot.payload)
                        });
                        if r > 0 {
                            retries_ctr.fetch_add(r as u64, Ordering::Relaxed);
                            backoff_ctr.fetch_add(faults::backoff_units(r), Ordering::Relaxed);
                        }
                        out.map_err(|e| {
                            e.with_context(format!(
                                "write exchange round {round}, aggregator {agg}"
                            ))
                        })?;
                    }
                    ExchangeIo::Read(_) => {
                        // Requester-side assembly: ascending aggregator within
                        // the round, ascending rounds overall ⇒ straight
                        // concatenation into each requester's slab span,
                        // gathered per staged stream slice.
                        for s in 0..slot.k {
                            let i = slot.owners[s];
                            let (vo, vl) = slot.stream(s);
                            let n = slot.stream_bytes(s);
                            let dst = arena.reply.append_slot(i, n);
                            gather_slices_from_buf(&slot.merged, &slot.payload, vo, vl, dst);
                        }
                    }
                }
            }
            bd.inter_sort += sort_t;
            bd.inter_datatype += dt_t;
        }
    } else {
        // ---- Double-buffered pipeline (DESIGN.md §Round pipelining).
        // Invariant at the top of steady iteration r: `scratch` (bank A)
        // holds round r staged AND merged; `scratch2` (bank B) is free.
        // The iteration stages round r+1 on the driver (ascending round
        // order — the pending queue and every accounting row evolve
        // exactly as in the serial loop), then runs round r's storage
        // call and round r+1's merges in ONE pooled batch over the
        // disjoint banks, so a transient-OST retry in round r can never
        // touch round r+1's already-staged bank.  File operations keep
        // the serial order: begin_round(r)/tick(r) → round-r views in
        // ascending aggregator order → round r+1's.  Rolling per-round
        // communication rows (time, in-degree) feed the `overlap_saved`
        // ledger.
        let mut comm_info = [(0.0f64, 0usize); 2];

        // Prologue: round 0 stages and merges with no pipeline depth yet.
        let comm = stage_round_into(
            ctx,
            plan,
            direction,
            &arena.staged,
            &mut arena.data_msgs,
            &mut arena.pending,
            &mut scratch,
            0,
        );
        bd.inter_comm += comm.time;
        counters.msgs_inter += comm.n_messages;
        counters.max_in_degree = counters.max_in_degree.max(comm.max_in_degree);
        comm_info[0] = (comm.time, comm.max_in_degree);
        match &io {
            ExchangeIo::Write(_) => rt.try_for_each_mut(
                &mut scratch,
                &|agg| format!("write exchange round 0, aggregator {agg}"),
                |_, slot| {
                    slot.merge_scatter(ctx.engine)?;
                    Ok(())
                },
            )?,
            ExchangeIo::Read(_) => rt.try_for_each_mut(
                &mut scratch,
                &|agg| format!("read exchange round 0, aggregator {agg}"),
                |_, slot| {
                    slot.merge_meta(ctx.engine)?;
                    Ok(())
                },
            )?,
        }

        for round in 0..n_rounds {
            let have_next = round + 1 < n_rounds;
            if have_next {
                let comm = stage_round_into(
                    ctx,
                    plan,
                    direction,
                    &arena.staged,
                    &mut arena.data_msgs,
                    &mut arena.pending,
                    &mut scratch2,
                    round + 1,
                );
                bd.inter_comm += comm.time;
                counters.msgs_inter += comm.n_messages;
                counters.max_in_degree = counters.max_in_degree.max(comm.max_in_degree);
                comm_info[((round + 1) % 2) as usize] = (comm.time, comm.max_in_degree);
            }
            // One heterogeneous pooled batch: the round-r I/O task plus
            // round r+1's per-slot merges (absent on the epilogue
            // round).  Lowest-index error wins, and the I/O task's index
            // sorts before every merge index — exactly the order the
            // serial loop surfaces errors in.
            let first_err: Mutex<Option<(usize, Error)>> = Mutex::new(None);
            match &mut io {
                ExchangeIo::Write(file) => {
                    // Round r's lock epoch opens before its writes, which
                    // all precede round r+1's (serial file-op order).
                    file.begin_round();
                    let fp = SendPtr(&mut **file as *mut LustreFile);
                    let bank_a = &scratch[..];
                    let next = SendPtr(scratch2.as_mut_ptr());
                    let n_jobs = 1 + if have_next { n_agg } else { 0 };
                    rt.for_each_index(
                        n_jobs,
                        &|i| {
                            if i == 0 {
                                format!("write exchange round {round}, I/O stage")
                            } else {
                                format!(
                                    "write exchange round {}, aggregator {}",
                                    round + 1,
                                    i - 1
                                )
                            }
                        },
                        |i| {
                            if i == 0 {
                                // SAFETY: index 0 is handed out exactly once
                                // and the driver does not touch the file
                                // while the batch runs, so this is the only
                                // live `&mut` to the file.
                                let file = unsafe { &mut *fp.0 };
                                for (agg, slot) in bank_a.iter().enumerate() {
                                    if slot.k == 0 {
                                        continue;
                                    }
                                    let (out, r) = faults::retrying(file.max_retries(), || {
                                        file.write_view(
                                            agg_ranks[agg],
                                            &slot.merged,
                                            &slot.payload,
                                        )
                                    });
                                    if r > 0 {
                                        retries_ctr.fetch_add(r as u64, Ordering::Relaxed);
                                        backoff_ctr.fetch_add(
                                            faults::backoff_units(r),
                                            Ordering::Relaxed,
                                        );
                                    }
                                    if let Err(e) = out {
                                        record_first_err(
                                            &first_err,
                                            0,
                                            e.with_context(format!(
                                                "write exchange round {round}, \
                                                 aggregator {agg}"
                                            )),
                                        );
                                        break;
                                    }
                                }
                            } else {
                                // SAFETY: merge index i owns exactly bank-B
                                // slot i-1; indices are handed out once and
                                // the driver does not touch bank B during
                                // the batch.
                                let slot = unsafe { &mut *next.0.add(i - 1) };
                                if let Err(e) = slot.merge_scatter(ctx.engine) {
                                    record_first_err(
                                        &first_err,
                                        i,
                                        e.with_context(format!(
                                            "write exchange round {}, aggregator {}",
                                            round + 1,
                                            i - 1
                                        )),
                                    );
                                }
                            }
                        },
                    );
                }
                ExchangeIo::Read(f) => {
                    let file: &LustreFile = f;
                    // Round-armed faults tick for round r before its
                    // vectored reads, after round r-1's — serial order.
                    file.tick_fault_round();
                    let bank_a = SendPtr(scratch.as_mut_ptr());
                    let next = SendPtr(scratch2.as_mut_ptr());
                    let n_jobs = n_agg + if have_next { n_agg } else { 0 };
                    let (retries_ctr, backoff_ctr) = (&retries_ctr, &backoff_ctr);
                    rt.for_each_index(
                        n_jobs,
                        &|i| {
                            if i < n_agg {
                                format!("read exchange round {round}, aggregator {i}")
                            } else {
                                format!(
                                    "read exchange round {}, aggregator {}",
                                    round + 1,
                                    i - n_agg
                                )
                            }
                        },
                        |i| {
                            if i < n_agg {
                                // SAFETY: read index i owns bank-A slot i
                                // (merged last iteration; only `payload`
                                // and `stats` are written here).
                                let slot = unsafe { &mut *bank_a.0.add(i) };
                                if slot.merged.is_empty() {
                                    return;
                                }
                                let (merged, payload, stats) =
                                    (&slot.merged, &mut slot.payload, &mut slot.stats);
                                let (out, r) = faults::retrying(file.max_retries(), || {
                                    file.read_view(merged, payload, stats)
                                });
                                if r > 0 {
                                    retries_ctr.fetch_add(r as u64, Ordering::Relaxed);
                                    backoff_ctr
                                        .fetch_add(faults::backoff_units(r), Ordering::Relaxed);
                                }
                                if let Err(e) = out {
                                    record_first_err(
                                        &first_err,
                                        i,
                                        e.with_context(format!(
                                            "read exchange round {round}, aggregator {i}"
                                        )),
                                    );
                                }
                            } else {
                                // SAFETY: merge index i owns exactly bank-B
                                // slot i-n_agg.
                                let slot = unsafe { &mut *next.0.add(i - n_agg) };
                                if let Err(e) = slot.merge_meta(ctx.engine) {
                                    record_first_err(
                                        &first_err,
                                        i,
                                        e.with_context(format!(
                                            "read exchange round {}, aggregator {}",
                                            round + 1,
                                            i - n_agg
                                        )),
                                    );
                                }
                            }
                        },
                    );
                }
            }
            if let Some((_, e)) = first_err.into_inner().unwrap() {
                return Err(e);
            }

            // Round r's CPU accounting and (on reads) reply assembly —
            // driver-side, ascending aggregator order, identical to the
            // serial schedule.  `round_bytes` apportions the exchange's
            // I/O phase across rounds for the overlap ledger.
            let mut sort_t: f64 = 0.0;
            let mut dt_t: f64 = 0.0;
            let mut round_bytes: u64 = 0;
            for slot in scratch.iter() {
                if slot.k == 0 {
                    continue;
                }
                sort_t = sort_t.max(ctx.cpu.merge_time(slot.n_items, slot.k));
                dt_t = dt_t.max(ctx.cpu.datatype_time(slot.n_items, slot.k));
                counters.reqs_at_io += slot.merged.len() as u64;
                round_bytes += slot.merged.total_bytes();
                if direction == Direction::Read {
                    for s in 0..slot.k {
                        let i = slot.owners[s];
                        let (vo, vl) = slot.stream(s);
                        let n = slot.stream_bytes(s);
                        let dst = arena.reply.append_slot(i, n);
                        gather_slices_from_buf(&slot.merged, &slot.payload, vo, vl, dst);
                    }
                }
            }
            bd.inter_sort += sort_t;
            bd.inter_datatype += dt_t;
            let (comm_t, in_deg) = comm_info[(round % 2) as usize];
            arena.overlap_acct.push_round(
                comm_t + sort_t + dt_t,
                ctx.net.overlap_sync_bound(in_deg),
                round_bytes as f64,
            );

            // Hand the banks over: bank B (round r+1, staged + merged)
            // becomes next iteration's bank A.
            std::mem::swap(&mut scratch, &mut scratch2);
        }
    }

    // ---- I/O phase time: writes account in the file's OST stats, reads
    // in the per-aggregator scratch stats accumulated across rounds.
    match &io {
        ExchangeIo::Write(file) => {
            bd.io_phase = ctx.io.phase_time_skewed(file.stats(), file.ost_rates());
            counters.lock_conflicts = file.total_lock_conflicts();
        }
        ExchangeIo::Read(f) => {
            debug_assert!(
                arena.reply.fully_assembled(),
                "reply assembly must fill every requester span exactly"
            );
            // Pipelined reads alternate banks round to round, so the
            // per-OST accumulation lives across both; bank B is empty on
            // the serial path and contributes nothing.
            let mut stats = vec![OstStats::default(); io.file_config().stripe_count];
            for slot in scratch.iter().chain(scratch2.iter()) {
                for (acc, s) in stats.iter_mut().zip(&slot.stats) {
                    acc.bytes += s.bytes;
                    acc.extents += s.extents;
                }
            }
            bd.io_phase = ctx.io.phase_time_skewed(&stats, f.ost_rates());
        }
    }
    // The overlap credit is taken against the fault-free I/O phase:
    // retry backoff (below) is synchronization the pipeline can never
    // hide, so it still charges `io_phase` in full.
    if pipelined {
        bd.overlap_saved = arena.overlap_acct.finish(bd.io_phase);
    }
    counters.retries = retries_ctr.into_inner();
    counters.backoff_units = backoff_ctr.into_inner();
    if counters.backoff_units > 0 {
        bd.io_phase += faults::backoff_penalty(counters.backoff_units);
    }

    // Hand the (still warm) slots back to the arena for the next exchange.
    arena.scratch = scratch;
    if pipelined {
        arena.scratch2 = scratch2;
    }

    Ok((views, ExchangeOutcome { breakdown: bd, counters }))
}

/// Read-side driver of [`run_exchange`]: self-overlapping requester views
/// (legal for reads — MPI only forbids overlapping filetypes for writes;
/// an aggregation-tree view can also overlap when two members read the
/// same region) are exchanged as their disjoint union, because
/// classification order and reply-assembly order agree only for
/// non-overlapping views.  The original view's bytes are gathered back
/// out of the union payload at the end; the common disjoint case pays
/// nothing and its reply stays in the arena's pooled slab
/// ([`ReadReply::Slab`]).
pub(crate) fn exchange_read(
    ctx: &CollectiveCtx,
    requesters: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, FlatView, ReadReply)>, ExchangeOutcome)> {
    exchange_read_with_plan(ctx, None, requesters, file, arena)
}

/// [`exchange_read`] over an optional cached [`ExchangePlan`]: with
/// `Some`, the plan (which was built over the same overlap-prepared
/// views — [`crate::coordinator::plancache::build_collective_plan`]
/// applies the identical disjoint-union step) is executed directly;
/// with `None`, a fresh plan is built inline.
pub(crate) fn exchange_read_with_plan(
    ctx: &CollectiveCtx,
    xplan: Option<&ExchangePlan>,
    requesters: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, FlatView, ReadReply)>, ExchangeOutcome)> {
    // Volume counters reflect the views as posted, not their unions.
    let posted_reqs: u64 = requesters.iter().map(|(_, v)| v.len() as u64).sum();
    let posted_bytes: u64 = requesters.iter().map(|(_, v)| v.total_bytes()).sum();
    let mut originals: Vec<Option<FlatView>> = Vec::with_capacity(requesters.len());
    let prepared: Vec<(usize, ReqBatch)> = requesters
        .into_iter()
        .map(|(rank, v)| {
            if v.has_overlap() {
                let union = v.disjoint_union();
                originals.push(Some(v));
                (rank, ReqBatch::new(union, Vec::new()))
            } else {
                originals.push(None);
                (rank, ReqBatch::new(v, Vec::new()))
            }
        })
        .collect();
    let (filled, mut out) = match xplan {
        Some(plan) => execute_exchange(ctx, plan, prepared, ExchangeIo::Read(file), arena)?,
        None => run_exchange(ctx, prepared, ExchangeIo::Read(file), arena)?,
    };
    out.counters.reqs_after_intra = posted_reqs;
    out.counters.bytes = posted_bytes;
    let reply_slab = &arena.reply;
    let filled = filled
        .into_iter()
        .zip(originals)
        .enumerate()
        .map(|(i, ((rank, view), original))| match original {
            None => (rank, view, ReadReply::Slab(i)),
            Some(orig) => {
                // Expand the union payload back to the overlapping
                // original view (duplicated bytes are copied per request).
                let mut expanded = vec![0u8; orig.total_bytes() as usize];
                gather_from_buf(&view, reply_slab.of(i), &orig, &mut expanded);
                (rank, orig, ReadReply::Owned(expanded))
            }
        })
        .collect();
    Ok((filled, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::breakdown::CpuModel;
    use crate::coordinator::placement::GlobalPlacement;
    use crate::lustre::{IoModel, LustreConfig};
    use crate::mpisim::rank::deterministic_payload;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    fn fixture() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
        (
            Topology::new(2, 4),
            NetParams::default(),
            CpuModel::default(),
            IoModel::default(),
            NativeEngine,
        )
    }

    fn make_ranks(topo: &Topology) -> Vec<(usize, ReqBatch)> {
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * 100;
                let view =
                    FlatView::from_pairs(vec![(base, 30), (base + 50, 20)]).unwrap();
                let payload = deterministic_payload(5, r, 50);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn algorithm_parses() {
        assert_eq!("two-phase".parse::<Algorithm>().unwrap(), Algorithm::TwoPhase);
        assert!(matches!("tam".parse::<Algorithm>().unwrap(), Algorithm::Tam(_)));
        match "tam:64".parse::<Algorithm>().unwrap() {
            Algorithm::Tam(t) => assert_eq!(t.total_local_aggregators, 64),
            _ => panic!(),
        }
        assert_eq!("auto".parse::<Algorithm>().unwrap(), Algorithm::Auto);
        assert_eq!(Algorithm::Auto.name(), "auto");
        assert!("bogus".parse::<Algorithm>().is_err());
        let err = "bogus".parse::<Algorithm>().unwrap_err().to_string();
        assert!(err.contains("auto"), "error must list auto: {err}");
    }

    #[test]
    fn auto_is_rejected_by_the_raw_entry_points() {
        // `auto` is a driver-level directive: the raw collective entry
        // points must refuse it with an actionable error instead of
        // silently running some default tree.
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let ranks = make_ranks(&topo);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let err = run_collective_write(&ctx, Algorithm::Auto, ranks.clone(), &mut file)
            .unwrap_err()
            .to_string();
        assert!(err.contains("auto") && err.contains("driver"), "{err}");
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let err = run_collective_read(&ctx, Algorithm::Auto, views, &file)
            .unwrap_err()
            .to_string();
        assert!(err.contains("auto") && err.contains("driver"), "{err}");
    }

    #[test]
    fn direction_parses_and_expands() {
        assert_eq!("write".parse::<Direction>().unwrap(), Direction::Write);
        assert_eq!("read".parse::<Direction>().unwrap(), Direction::Read);
        assert!("sideways".parse::<Direction>().is_err());
        assert_eq!("write".parse::<DirectionSpec>().unwrap(), DirectionSpec::Write);
        assert_eq!("read".parse::<DirectionSpec>().unwrap(), DirectionSpec::Read);
        assert_eq!("both".parse::<DirectionSpec>().unwrap(), DirectionSpec::Both);
        assert!("neither".parse::<DirectionSpec>().is_err());
        assert_eq!(DirectionSpec::Write.runs(), &[Direction::Write]);
        assert_eq!(DirectionSpec::Read.runs(), &[Direction::Read]);
        assert_eq!(DirectionSpec::Both.runs(), &[Direction::Write, Direction::Read]);
        assert_eq!(DirectionSpec::default(), DirectionSpec::Write);
        let shown = format!("{} {} {}", Direction::Write, Direction::Read, DirectionSpec::Both);
        assert_eq!(shown, "write read both");
    }

    #[test]
    fn write_then_read_round_trip_two_phase() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) =
            run_collective_read(&ctx, Algorithm::TwoPhase, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} read-back mismatch");
        }
        assert!(outcome.breakdown.total() > 0.0);
    }

    #[test]
    fn write_then_read_round_trip_tam() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        let algo = Algorithm::Tam(TamConfig { total_local_aggregators: 2 });
        run_collective_write(&ctx, algo, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) = run_collective_read(&ctx, algo, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} TAM read-back mismatch");
        }
        assert!(outcome.breakdown.intra_comm > 0.0, "TAM read has intra traffic");
    }

    #[test]
    fn read_accounts_rounds_and_computation() {
        // Multi-round read: the round structure and the computation
        // components (calc_my_req, inter_sort, inter_datatype) must show
        // up in the outcome, and reqs_at_io must reflect coalescing.
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        // 8 ranks × 256 contiguous bytes = 32 stripes over 4 aggs → 8 rounds.
        let ranks: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
            .map(|r| {
                let view = FlatView::from_pairs(vec![(r as u64 * 256, 256)]).unwrap();
                (r, ReqBatch::new(view, deterministic_payload(3, r, 256)))
            })
            .collect();
        run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) =
            run_collective_read(&ctx, Algorithm::TwoPhase, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r}");
        }
        assert_eq!(outcome.counters.rounds, 8);
        assert_eq!(outcome.counters.bytes, 2048);
        assert!(outcome.breakdown.calc_my_req > 0.0);
        assert!(outcome.breakdown.inter_sort > 0.0);
        assert!(outcome.breakdown.inter_datatype > 0.0);
        assert!(outcome.breakdown.io_phase > 0.0);
        // Each rank's 256B request splits into 4 stripes, but adjacent
        // ranks coalesce at the aggregators: at most one segment per
        // aggregator per round reaches the I/O layer.
        assert!(outcome.counters.reqs_at_io <= 32);
        assert!(outcome.counters.msgs_inter > 0);
    }

    #[test]
    fn read_supports_overlapping_views() {
        // Overlap is legal for reads: ranks 0 and 1 read shared bytes,
        // rank 1's view overlaps itself, rank 2's view nests a request
        // inside a bigger one.  With TAM the merged aggregator view then
        // overlaps too (the disjoint-union exchange path).
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let img = deterministic_payload(9, 0, 256);
        run_collective_write(
            &ctx,
            Algorithm::TwoPhase,
            vec![(
                0usize,
                ReqBatch::new(FlatView::from_pairs(vec![(0, 256)]).unwrap(), img.clone()),
            )],
            &mut file,
        )
        .unwrap();
        let views = vec![
            (0usize, FlatView::from_pairs(vec![(0, 128)]).unwrap()),
            (1usize, FlatView::from_pairs(vec![(64, 64), (96, 32)]).unwrap()),
            (2usize, FlatView::from_pairs(vec![(0, 200), (50, 10)]).unwrap()),
        ];
        let want: Vec<Vec<u8>> = views
            .iter()
            .map(|(_, v)| {
                let mut p = Vec::new();
                for (off, len) in v.iter() {
                    p.extend_from_slice(&img[off as usize..(off + len) as usize]);
                }
                p
            })
            .collect();
        for algo in
            [Algorithm::TwoPhase, Algorithm::Tam(TamConfig { total_local_aggregators: 2 })]
        {
            let (got, _) = run_collective_read(&ctx, algo, views.clone(), &file).unwrap();
            for (i, (r, payload)) in got.iter().enumerate() {
                assert_eq!(payload, &want[i], "{} rank {r}", algo.name());
            }
        }
    }

    #[test]
    fn read_of_empty_and_zero_length_views() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        run_collective_write(
            &ctx,
            Algorithm::TwoPhase,
            vec![(
                0usize,
                ReqBatch::new(FlatView::from_pairs(vec![(0, 64)]).unwrap(), vec![7u8; 64]),
            )],
            &mut file,
        )
        .unwrap();
        let views = vec![
            (0usize, FlatView::from_pairs(vec![(0, 32), (40, 0), (48, 16)]).unwrap()),
            (1usize, FlatView::empty()),
            (2usize, FlatView::from_pairs(vec![(10, 0)]).unwrap()),
        ];
        for algo in
            [Algorithm::TwoPhase, Algorithm::Tam(TamConfig { total_local_aggregators: 2 })]
        {
            let (got, _) = run_collective_read(&ctx, algo, views.clone(), &file).unwrap();
            assert_eq!(got[0].1, vec![7u8; 48], "{}", algo.name());
            assert!(got[1].1.is_empty());
            assert!(got[2].1.is_empty());
        }
    }

    #[test]
    fn one_exchange_loop_drives_both_directions_identically() {
        // The same requester set driven through run_exchange in both
        // directions: the round structure, metadata phase and coalescing
        // counters must agree exactly (the loop is shared), and the read
        // must return the bytes the write persisted.
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        // ONE arena across both directions: write-exchange state (staging
        // payloads, pending counts) must not leak into the read.
        let mut arena = ExchangeArena::default();
        let (_, wrote) =
            run_exchange(&ctx, ranks.clone(), ExchangeIo::Write(&mut file), &mut arena)
                .unwrap();
        let readers: Vec<(usize, ReqBatch)> = ranks
            .iter()
            .map(|(r, b)| (*r, ReqBatch::new(b.view.clone(), Vec::new())))
            .collect();
        let (filled, read) =
            run_exchange(&ctx, readers, ExchangeIo::Read(&file), &mut arena).unwrap();
        assert_eq!(wrote.counters.rounds, read.counters.rounds);
        assert_eq!(wrote.counters.msgs_inter, read.counters.msgs_inter);
        assert_eq!(wrote.counters.reqs_at_io, read.counters.reqs_at_io);
        assert_eq!(wrote.counters.bytes, read.counters.bytes);
        // Replies live in the arena's pooled slab, keyed by requester
        // position.
        assert_eq!(arena.reply.len(), filled.len());
        assert!(arena.reply.fully_assembled());
        for (i, ((rank, _), (_, want))) in filled.iter().zip(ranks.iter()).enumerate() {
            assert_eq!(arena.reply.of(i), &want.payload[..], "rank {rank}");
        }
    }

    #[test]
    fn reply_slab_lays_out_spans_and_reuses_capacity() {
        let mut slab = ReplySlab::default();
        slab.reset([4usize, 0, 2].into_iter());
        assert_eq!(slab.len(), 3);
        assert!(!slab.is_empty());
        assert!(!slab.fully_assembled());
        slab.append_slot(0, 3).copy_from_slice(&[1, 2, 3]);
        slab.append_slot(0, 1).copy_from_slice(&[4]);
        slab.append_slot(2, 2).copy_from_slice(&[9, 8]);
        assert!(slab.fully_assembled());
        assert_eq!(slab.of(0), &[1, 2, 3, 4]);
        assert_eq!(slab.of(1), &[] as &[u8]);
        assert_eq!(slab.of(2), &[9, 8]);
        // Re-laid-out slab starts zeroed with rewound cursors.
        slab.reset([2usize].into_iter());
        assert_eq!(slab.len(), 1);
        assert_eq!(slab.of(0), &[0, 0]);
        assert!(!slab.fully_assembled());
    }

    #[test]
    #[should_panic(expected = "reply span overflow")]
    fn reply_slab_span_overflow_panics_in_release_too() {
        // The slab is shared across requesters: an overrun must crash
        // loudly (like the old per-requester Vecs did), never bleed into
        // the next requester's span.
        let mut slab = ReplySlab::default();
        slab.reset([4usize, 2].into_iter());
        slab.append_slot(0, 4);
        slab.append_slot(0, 1);
    }

    #[test]
    fn tree_algorithm_parses_and_round_trips() {
        assert!(matches!("tree".parse::<Algorithm>().unwrap(), Algorithm::Tree(_)));
        match "tree:node=2".parse::<Algorithm>().unwrap() {
            Algorithm::Tree(spec) => {
                assert_eq!(spec, crate::coordinator::tree::TreeSpec {
                    per_socket: 0,
                    per_node: 2,
                    per_switch: 0,
                });
                assert_eq!(Algorithm::Tree(spec).name(), "tree(node=2)");
            }
            other => panic!("expected tree, got {other:?}"),
        }
        assert!("tree:rack=9".parse::<Algorithm>().is_err());

        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let ranks = make_ranks(&topo);
        let algo = "tree:node=2".parse::<Algorithm>().unwrap();
        run_collective_write(&ctx, algo, ranks.clone(), &mut file).unwrap();
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, outcome) = run_collective_read(&ctx, algo, views, &file).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} tree read-back");
        }
        assert!(outcome.breakdown.intra_comm > 0.0, "tree read has intra traffic");
        assert_eq!(outcome.breakdown.levels.len(), 1);
        assert_eq!(outcome.breakdown.levels[0].label, "node");
    }

    #[test]
    fn overlap_mode_parses_and_gates() {
        assert_eq!("off".parse::<OverlapMode>().unwrap(), OverlapMode::Off);
        assert_eq!("on".parse::<OverlapMode>().unwrap(), OverlapMode::On);
        assert_eq!("auto".parse::<OverlapMode>().unwrap(), OverlapMode::Auto);
        assert_eq!(OverlapMode::default(), OverlapMode::Off);
        // PR 7 policy: bad values hard-error, naming the bad input and
        // the accepted set — never silently substitute the default.
        let err = "sideways".parse::<OverlapMode>().unwrap_err().to_string();
        assert!(err.contains("sideways") && err.contains("on|off|auto"), "{err}");
        assert!(!OverlapMode::Off.pipelines(8));
        assert!(OverlapMode::On.pipelines(2));
        assert!(OverlapMode::Auto.pipelines(2));
        // Single-round exchanges have nothing to overlap with.
        assert!(!OverlapMode::On.pipelines(1));
        assert!(!OverlapMode::Auto.pipelines(0));
        let shown =
            format!("{} {} {}", OverlapMode::Off, OverlapMode::On, OverlapMode::Auto);
        assert_eq!(shown, "off on auto");
    }

    #[test]
    fn pipelined_exchange_is_bit_identical_to_serial() {
        // The same multi-round exchange driven serially and through the
        // double-buffered pipeline: file bytes, reply payloads and every
        // counter/phase row must agree exactly — only the pipeline's
        // `overlap_saved` credit (and thus the total) differs.
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        // 8 ranks × 256 contiguous bytes = 32 stripes over 4 aggs → 8 rounds.
        let ranks: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
            .map(|r| {
                let view = FlatView::from_pairs(vec![(r as u64 * 256, 256)]).unwrap();
                (r, ReqBatch::new(view, deterministic_payload(3, r, 256)))
            })
            .collect();
        let mut f_serial = LustreFile::new(LustreConfig::new(64, 4));
        let mut a_serial = ExchangeArena::default();
        let (_, w_serial) =
            run_exchange(&ctx, ranks.clone(), ExchangeIo::Write(&mut f_serial), &mut a_serial)
                .unwrap();
        let mut f_pipe = LustreFile::new(LustreConfig::new(64, 4));
        let mut a_pipe = ExchangeArena::default();
        a_pipe.overlap = OverlapMode::On;
        let (_, w_pipe) =
            run_exchange(&ctx, ranks.clone(), ExchangeIo::Write(&mut f_pipe), &mut a_pipe)
                .unwrap();
        let total = topo.nprocs() as u64 * 256;
        assert_eq!(f_serial.read_at(0, total), f_pipe.read_at(0, total));
        assert_eq!(w_serial.counters.rounds, w_pipe.counters.rounds);
        assert_eq!(w_serial.counters.msgs_inter, w_pipe.counters.msgs_inter);
        assert_eq!(w_serial.counters.reqs_at_io, w_pipe.counters.reqs_at_io);
        assert_eq!(w_serial.counters.max_in_degree, w_pipe.counters.max_in_degree);
        assert_eq!(w_serial.counters.lock_conflicts, w_pipe.counters.lock_conflicts);
        assert_eq!(w_serial.breakdown.inter_comm, w_pipe.breakdown.inter_comm);
        assert_eq!(w_serial.breakdown.inter_sort, w_pipe.breakdown.inter_sort);
        assert_eq!(w_serial.breakdown.inter_datatype, w_pipe.breakdown.inter_datatype);
        assert_eq!(w_serial.breakdown.io_phase, w_pipe.breakdown.io_phase);
        assert_eq!(w_serial.breakdown.overlap_saved, 0.0);
        assert!(
            w_pipe.breakdown.overlap_saved > 0.0,
            "multi-round pipelined write must credit overlap"
        );
        assert!(w_pipe.breakdown.overlap_saved <= w_pipe.breakdown.io_phase);
        assert!(w_pipe.breakdown.total() < w_serial.breakdown.total());
        // Read direction through the same (now warm) arenas.
        let readers: Vec<(usize, ReqBatch)> = ranks
            .iter()
            .map(|(r, b)| (*r, ReqBatch::new(b.view.clone(), Vec::new())))
            .collect();
        let (_, r_serial) =
            run_exchange(&ctx, readers.clone(), ExchangeIo::Read(&f_serial), &mut a_serial)
                .unwrap();
        let serial_replies: Vec<Vec<u8>> =
            (0..ranks.len()).map(|i| a_serial.reply.of(i).to_vec()).collect();
        let (_, r_pipe) =
            run_exchange(&ctx, readers, ExchangeIo::Read(&f_pipe), &mut a_pipe).unwrap();
        for (i, (_, want)) in ranks.iter().enumerate() {
            assert_eq!(a_pipe.reply.of(i), &want.payload[..], "pipelined read rank {i}");
            assert_eq!(a_pipe.reply.of(i), &serial_replies[i][..]);
        }
        assert_eq!(r_serial.counters.rounds, r_pipe.counters.rounds);
        assert_eq!(r_serial.counters.msgs_inter, r_pipe.counters.msgs_inter);
        assert_eq!(r_serial.counters.reqs_at_io, r_pipe.counters.reqs_at_io);
        assert_eq!(r_serial.breakdown.inter_comm, r_pipe.breakdown.inter_comm);
        assert_eq!(r_serial.breakdown.io_phase, r_pipe.breakdown.io_phase);
        assert!(r_pipe.breakdown.overlap_saved > 0.0, "pipelined read credits overlap");
        // One-round exchanges degenerate to the serial schedule even
        // with overlap on: nothing to pipeline, zero credit.
        let one: Vec<(usize, ReqBatch)> = vec![(
            0usize,
            ReqBatch::new(
                FlatView::from_pairs(vec![(0, 64)]).unwrap(),
                deterministic_payload(7, 0, 64),
            ),
        )];
        let mut f_one = LustreFile::new(LustreConfig::new(64, 4));
        let (_, w_one) =
            run_exchange(&ctx, one, ExchangeIo::Write(&mut f_one), &mut a_pipe).unwrap();
        assert_eq!(w_one.counters.rounds, 1);
        assert_eq!(w_one.breakdown.overlap_saved, 0.0);
    }

    #[test]
    fn reused_arena_matches_fresh_arena_exactly() {
        // A warm arena (sized by a bigger earlier exchange, pending queue
        // exercised under Isend) must reproduce the fresh-arena outcome
        // bit-for-bit — the cross-invocation reuse contract of the sweep
        // drivers.
        let (topo, mut net, cpu, io, eng) = fixture();
        net.send_mode = crate::netmodel::SendMode::Isend;
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let ranks = make_ranks(&topo);
        // Fresh-arena reference.
        let mut f1 = LustreFile::new(LustreConfig::new(64, 4));
        let fresh = run_collective_write(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut f1)
            .unwrap();
        // Warm the arena on a different-shaped exchange (more bytes, more
        // rounds), then rerun the reference exchange through it.
        let mut arena = ExchangeArena::default();
        let big: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
            .map(|r| {
                let view = FlatView::from_pairs(vec![(r as u64 * 512, 512)]).unwrap();
                (r, ReqBatch::new(view, deterministic_payload(31, r, 512)))
            })
            .collect();
        let mut fwarm = LustreFile::new(LustreConfig::new(64, 4));
        run_collective_write_with(&ctx, Algorithm::TwoPhase, big, &mut fwarm, &mut arena)
            .unwrap();
        let mut f2 = LustreFile::new(LustreConfig::new(64, 4));
        let warm =
            run_collective_write_with(&ctx, Algorithm::TwoPhase, ranks.clone(), &mut f2, &mut arena)
                .unwrap();
        assert_eq!(fresh.counters.rounds, warm.counters.rounds);
        assert_eq!(fresh.counters.msgs_inter, warm.counters.msgs_inter);
        assert_eq!(fresh.counters.reqs_at_io, warm.counters.reqs_at_io);
        assert_eq!(fresh.counters.max_in_degree, warm.counters.max_in_degree);
        assert_eq!(fresh.breakdown.inter_comm, warm.breakdown.inter_comm);
        assert_eq!(fresh.breakdown.inter_sort, warm.breakdown.inter_sort);
        assert_eq!(fresh.breakdown.io_phase, warm.breakdown.io_phase);
        let total = topo.nprocs() as u64 * 100;
        assert_eq!(f1.read_at(0, total), f2.read_at(0, total));
        // Read direction through the same (now twice-warmed) arena.
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, _) =
            run_collective_read_with(&ctx, Algorithm::TwoPhase, views, &f2, &mut arena).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} warm-arena read");
        }
    }
}
