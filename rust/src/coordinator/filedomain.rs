//! Stripe-aligned file-domain partitioning (ROMIO-on-Lustre style).
//!
//! The aggregate access region of a collective call is divided among the
//! `P_G` global aggregators.  On Lustre, ROMIO aligns domains to stripes
//! and assigns stripes round-robin so aggregator `i` exclusively serves
//! OST `i` — the one-to-one aggregator↔OST mapping that avoids extent-lock
//! conflicts (§II).  When the aggregate region exceeds
//! `P_G · stripe_size`, the collective proceeds in multiple rounds; in
//! round `r` aggregator `i` handles stripe `r · P_G + i`.

use crate::lustre::LustreConfig;

/// File-domain assignment for one collective operation.
#[derive(Clone, Debug)]
pub struct FileDomains {
    /// Stripe geometry.
    pub lustre: LustreConfig,
    /// First stripe of the aggregate access region.
    pub first_stripe: u64,
    /// One past the last stripe of the region.
    pub end_stripe: u64,
    /// Number of global aggregators `P_G`.
    pub n_agg: usize,
}

impl FileDomains {
    /// Partition the aggregate byte range `[lo, hi)` among `n_agg`
    /// aggregators.  Empty ranges yield zero rounds.
    pub fn new(lustre: LustreConfig, lo: u64, hi: u64, n_agg: usize) -> Self {
        assert!(n_agg > 0);
        let (first_stripe, end_stripe) = if hi <= lo {
            (0, 0)
        } else {
            (lustre.stripe_of(lo), lustre.stripe_of(hi - 1) + 1)
        };
        FileDomains { lustre, first_stripe, end_stripe, n_agg }
    }

    /// Total stripes in the aggregate region.
    pub fn n_stripes(&self) -> u64 {
        self.end_stripe - self.first_stripe
    }

    /// Number of two-phase rounds: each round covers one stripe per
    /// aggregator (ROMIO's Lustre driver writes ≤ stripe_size per
    /// aggregator per round, §II).
    pub fn n_rounds(&self) -> u64 {
        self.n_stripes().div_ceil(self.n_agg as u64)
    }

    /// Aggregator index owning a byte offset.
    ///
    /// Stripes are distributed round-robin from the first stripe so that
    /// aggregator `i` always touches OST `(first_stripe + i) mod
    /// stripe_count`; with `n_agg == stripe_count` (ROMIO's Lustre
    /// default) this is the one-to-one OST mapping.
    pub fn aggregator_of(&self, offset: u64) -> usize {
        debug_assert!(self.n_stripes() > 0);
        let stripe = self.lustre.stripe_of(offset);
        ((stripe - self.first_stripe) % self.n_agg as u64) as usize
    }

    /// Round in which a byte offset is serviced.
    pub fn round_of(&self, offset: u64) -> u64 {
        (self.lustre.stripe_of(offset) - self.first_stripe) / self.n_agg as u64
    }

    /// Byte range `[lo, hi)` served by aggregator `agg` in `round`
    /// (`None` when that slot is past the end of the region).
    pub fn domain_of(&self, agg: usize, round: u64) -> Option<(u64, u64)> {
        let stripe = self.first_stripe + round * self.n_agg as u64 + agg as u64;
        if stripe >= self.end_stripe {
            return None;
        }
        Some(self.lustre.stripe_range(stripe))
    }

    /// Total bytes aggregator `agg` is responsible for across all rounds,
    /// clipped to the aggregate region `[lo, hi)` given at construction
    /// is *not* retained — callers clip per their views.
    pub fn stripes_of(&self, agg: usize) -> impl Iterator<Item = u64> + '_ {
        (0..self.n_rounds()).filter_map(move |r| {
            let s = self.first_stripe + r * self.n_agg as u64 + agg as u64;
            (s < self.end_stripe).then_some(s)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd(lo: u64, hi: u64, n_agg: usize) -> FileDomains {
        FileDomains::new(LustreConfig::new(100, 4), lo, hi, n_agg)
    }

    #[test]
    fn partition_covers_every_offset_exactly_once() {
        let d = fd(50, 1050, 4);
        for off in (50..1050).step_by(7) {
            let a = d.aggregator_of(off);
            let r = d.round_of(off);
            let (lo, hi) = d.domain_of(a, r).unwrap();
            assert!(off >= lo && off < hi, "offset {off} not in domain [{lo},{hi})");
            // No other aggregator may own it.
            for other in 0..4 {
                if other != a {
                    if let Some((olo, ohi)) = d.domain_of(other, r) {
                        assert!(off < olo || off >= ohi);
                    }
                }
            }
        }
    }

    #[test]
    fn rounds_math() {
        // 10 stripes (offsets 0..1000), 4 aggregators → 3 rounds.
        let d = fd(0, 1000, 4);
        assert_eq!(d.n_stripes(), 10);
        assert_eq!(d.n_rounds(), 3);
        // Stripe 9 is aggregator 1, round 2.
        assert_eq!(d.aggregator_of(950), 1);
        assert_eq!(d.round_of(950), 2);
        // Aggregator 2 in round 2 is stripe 10 — past end.
        assert!(d.domain_of(2, 2).is_none());
    }

    #[test]
    fn one_to_one_ost_mapping_when_nagg_eq_stripe_count() {
        let lustre = LustreConfig::new(100, 4);
        let d = FileDomains::new(lustre, 0, 1600, 4);
        for agg in 0..4 {
            let osts: Vec<usize> = d
                .stripes_of(agg)
                .map(|s| lustre.ost_of(s * 100))
                .collect();
            assert!(!osts.is_empty());
            assert!(osts.iter().all(|&o| o == osts[0]), "agg {agg} hits OSTs {osts:?}");
        }
    }

    #[test]
    fn unaligned_region_start() {
        let d = fd(250, 460, 2);
        assert_eq!(d.first_stripe, 2);
        assert_eq!(d.end_stripe, 5);
        assert_eq!(d.aggregator_of(250), 0);
        assert_eq!(d.aggregator_of(399), 1);
        assert_eq!(d.aggregator_of(400), 0);
        assert_eq!(d.round_of(400), 1);
    }

    #[test]
    fn empty_region_zero_rounds() {
        let d = fd(10, 10, 4);
        assert_eq!(d.n_rounds(), 0);
        assert_eq!(d.n_stripes(), 0);
    }

    #[test]
    fn more_aggs_than_stripes_single_round() {
        let d = fd(0, 250, 8);
        assert_eq!(d.n_stripes(), 3);
        assert_eq!(d.n_rounds(), 1);
        assert!(d.domain_of(3, 0).is_none());
        assert!(d.domain_of(2, 0).is_some());
    }
}
