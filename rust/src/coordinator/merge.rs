//! K-way heap merge and coalescing of sorted request streams — the native
//! implementation of the aggregator hot path (§IV-A/B).
//!
//! Each incoming stream is one peer's already-sorted request list (the MPI
//! file-view guarantee) together with its payload bytes in view order.  The
//! merge produces a single ascending, coalesced request list; payload
//! scatter into the aggregated contiguous buffer is a separate pass so the
//! metadata step can also be executed by the XLA engine
//! ([`crate::runtime::engine`]) interchangeably.
//!
//! The streaming pipeline (DESIGN.md §Hot path, §Memory layout) is:
//! [`crate::runtime::engine::SortEngine::merge_sorted_csr_into`] →
//! [`merge_csr_into`] (`O(n log k)`, gallop-accelerated on runs, merged
//! view built in a reused arena, heap storage reused via
//! [`MergeScratch`]) → [`scatter_csr_into_buf`] (linear two-pointer
//! payload scatter into a reusable buffer).  [`RoundScratch`] owns the
//! per-aggregator buffers that survive across exchange rounds *and
//! exchanges* (its slots live in the `ExchangeArena`) — for **both
//! directions** of the collective — so the steady state allocates
//! nothing: writes merge + scatter peer payloads and hand the buffer to
//! storage, reads merge peer metadata, let storage fill the buffer, and
//! [`gather_slices_from_buf`] copies each peer's bytes back out.  The
//! slice-per-stream twins ([`merge_views_into`], [`scatter_into_buf`],
//! [`gather_from_buf`]) remain the off-hot-path and reference forms.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::error::Result;
use crate::mpisim::FlatView;
use crate::runtime::engine::SortEngine;

/// One peer's aggregated requests: sorted view + payload in view order.
#[derive(Clone, Debug, Default)]
pub struct ReqBatch {
    /// Sorted noncontiguous requests.
    pub view: FlatView,
    /// Payload bytes, concatenated in view order (empty for reads).
    pub payload: Vec<u8>,
}

impl ReqBatch {
    /// Empty batch.
    pub fn new(view: FlatView, payload: Vec<u8>) -> Self {
        debug_assert!(payload.is_empty() || payload.len() as u64 == view.total_bytes());
        ReqBatch { view, payload }
    }
}

/// Fold `(off, len)` into the running coalesce state, emitting the
/// previous segment when contiguity breaks (the paper's exact rule).
#[inline]
fn absorb(last: &mut Option<(u64, u64)>, out: &mut FlatView, off: u64, len: u64) {
    match *last {
        Some((lo, ll)) if lo + ll == off => *last = Some((lo, ll + len)),
        Some((lo, ll)) => {
            out.push(lo, ll);
            *last = Some((off, len));
        }
        None => *last = Some((off, len)),
    }
}

// ---------------------------------------------------------------------------
// Chunked, branchless kernel primitives.
//
// The merge/scatter inner loops spend their time on two questions asked
// once per *entry*: "may this stream keep galloping past the heap top?"
// and "does the coalesced run break here?".  Both are answered over
// fixed-width chunks of `CHUNK` u64 lanes instead — a branchless
// compare-and-count per chunk with a scalar tail — which the compiler
// autovectorizes on the default build and which maps 1:1 onto
// `std::simd` mask ops under `--features simd`.  The `*_scalar` forms
// are ALWAYS compiled (and oracle-tested against the SIMD forms when the
// feature is on), so the scalar fallback cannot rot.
// ---------------------------------------------------------------------------

/// Lane width of the chunked kernels (u64x8 under `simd`).
const CHUNK: usize = 8;

/// Count lanes of `xs[..CHUNK]` strictly below `bound` — branchless
/// sum-of-compares.  For nondecreasing `xs` (the file-view guarantee)
/// this is the in-chunk lower bound of `bound`.
#[inline]
fn count_lt_chunk_scalar(xs: &[u64], bound: u64) -> usize {
    let mut c = 0usize;
    for t in 0..CHUNK {
        c += (xs[t] < bound) as usize;
    }
    c
}

/// Bitmask of coalescing breaks over `CHUNK` adjacencies: bit `t` set
/// iff `offsets[t] + lengths[t] != offsets[t + 1]` (needs `CHUNK + 1`
/// offsets).  Branchless compare-accumulate.
#[inline]
fn break_mask_chunk_scalar(offsets: &[u64], lengths: &[u64]) -> u64 {
    let mut m = 0u64;
    for t in 0..CHUNK {
        m |= ((offsets[t] + lengths[t] != offsets[t + 1]) as u64) << t;
    }
    m
}

#[cfg(feature = "simd")]
#[inline]
fn count_lt_chunk_simd(xs: &[u64], bound: u64) -> usize {
    use std::simd::prelude::*;
    let v = u64x8::from_slice(&xs[..CHUNK]);
    v.simd_lt(u64x8::splat(bound)).to_bitmask().count_ones() as usize
}

#[cfg(feature = "simd")]
#[inline]
fn break_mask_chunk_simd(offsets: &[u64], lengths: &[u64]) -> u64 {
    use std::simd::prelude::*;
    let off = u64x8::from_slice(&offsets[..CHUNK]);
    let len = u64x8::from_slice(&lengths[..CHUNK]);
    let next = u64x8::from_slice(&offsets[1..CHUNK + 1]);
    (off + len).simd_ne(next).to_bitmask()
}

#[inline]
fn count_lt_chunk(xs: &[u64], bound: u64) -> usize {
    #[cfg(feature = "simd")]
    {
        count_lt_chunk_simd(xs, bound)
    }
    #[cfg(not(feature = "simd"))]
    {
        count_lt_chunk_scalar(xs, bound)
    }
}

#[inline]
fn break_mask_chunk(offsets: &[u64], lengths: &[u64]) -> u64 {
    #[cfg(feature = "simd")]
    {
        break_mask_chunk_simd(offsets, lengths)
    }
    #[cfg(not(feature = "simd"))]
    {
        break_mask_chunk_scalar(offsets, lengths)
    }
}

/// How many entries of `offsets/lengths[lo..hi]` (stream `s`, slab row
/// = index) the gallop may consume against a FIXED heap top: the length
/// of the maximal prefix with `(offsets[j], lengths[j], s, j) <= top`.
///
/// Offsets are nondecreasing within a stream, so every entry with
/// `offsets[j] < top.0` is consumed unconditionally — counted in
/// branchless chunks — and only the `offsets[j] == top.0` boundary zone
/// needs the full scalar tuple compare (which stops exactly where the
/// per-entry reference loop stops).
#[inline]
fn gallop_len(
    offsets: &[u64],
    lengths: &[u64],
    lo: usize,
    hi: usize,
    s: usize,
    top: (u64, u64, usize, usize),
) -> usize {
    let bound = top.0;
    let mut j = lo;
    while j + CHUNK <= hi {
        let c = count_lt_chunk(&offsets[j..], bound);
        j += c;
        if c < CHUNK {
            break;
        }
    }
    if j + CHUNK > hi {
        while j < hi && offsets[j] < bound {
            j += 1;
        }
    }
    // Boundary zone: equal offsets decided by the full tuple order.
    while j < hi && offsets[j] == bound && (offsets[j], lengths[j], s, j) <= top {
        j += 1;
    }
    j - lo
}

/// Index of the first coalescing break at or after `a` (the run
/// `a..=break` is contiguous); `n - 1` when the rest is one run.
/// Chunked scan over `CHUNK` adjacencies at a time.
#[inline]
fn next_break(offsets: &[u64], lengths: &[u64], a: usize) -> usize {
    let n = offsets.len();
    debug_assert!(a < n);
    let mut j = a;
    while j + CHUNK < n {
        let m = break_mask_chunk(&offsets[j..], &lengths[j..]);
        if m != 0 {
            return j + m.trailing_zeros() as usize;
        }
        j += CHUNK;
    }
    while j + 1 < n {
        if offsets[j] + lengths[j] != offsets[j + 1] {
            return j;
        }
        j += 1;
    }
    n - 1
}

/// Absorb the already-claimed run `offsets/lengths[..n]` into the
/// coalesce state: chunked break detection splits it into contiguous
/// sub-runs, and each sub-run enters [`absorb`] as ONE aggregated pair
/// (`end - start` bytes) instead of entry by entry.  Bit-identical to
/// the per-entry loop: within a contiguous sub-run the per-entry fold
/// only ever extends, so folding the precomputed total is the same
/// arithmetic.
#[inline]
fn absorb_run(offsets: &[u64], lengths: &[u64], last: &mut Option<(u64, u64)>, out: &mut FlatView) {
    let n = offsets.len();
    let mut a = 0usize;
    while a < n {
        let b = next_break(offsets, lengths, a);
        let seg_len = offsets[b] + lengths[b] - offsets[a];
        absorb(last, out, offsets[a], seg_len);
        a = b + 1;
    }
}

/// K-way heap merge of sorted views into one sorted, coalesced view.
///
/// Allocating convenience wrapper over [`merge_views_into`].
pub fn merge_views(views: &[&FlatView]) -> FlatView {
    let mut out = FlatView::empty();
    merge_views_into(views, &mut out);
    out
}

/// K-way heap merge of sorted views into a caller-owned view (cleared
/// first; capacity reused across calls — the merged-view arena of the
/// exchange round loops).
///
/// Time `O(n log k)` via a binary heap keyed on `(offset, length, stream)`
/// — the deterministic tie-break mirrors the L1 bitonic kernel's
/// lexicographic ordering so both engines produce identical output.
///
/// After each pop the winning stream *gallops*: as long as its next entry
/// would win the very next heap comparison anyway (full-tuple order against
/// the current heap top), it is consumed directly without a push/pop pair.
/// Real file views interleave in block-sized runs (§V-C), so this
/// collapses most heap traffic while popping in the exact same order as
/// the plain heap algorithm.
pub fn merge_views_into(views: &[&FlatView], out: &mut FlatView) {
    out.clear();
    let mut heap: BinaryHeap<Reverse<(u64, u64, usize, usize)>> = views
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_empty())
        .map(|(s, v)| Reverse((v.offsets()[0], v.lengths()[0], s, 0usize)))
        .collect();
    let mut last: Option<(u64, u64)> = None;
    while let Some(Reverse((_, _, s, i))) = heap.pop() {
        let v = views[s];
        let (offsets, lengths) = (v.offsets(), v.lengths());
        let hi = v.len();
        // The heap is untouched while one stream gallops, so the top is
        // a FIXED bound: claim the whole run in one chunked scan, then
        // absorb it with chunked break detection.
        let take = match heap.peek() {
            None => hi - i,
            Some(&Reverse(top)) => 1 + gallop_len(offsets, lengths, i + 1, hi, s, top),
        };
        absorb_run(&offsets[i..i + take], &lengths[i..i + take], &mut last, out);
        if i + take < hi {
            heap.push(Reverse((offsets[i + take], lengths[i + take], s, i + take)));
        }
    }
    if let Some((lo, ll)) = last {
        out.push(lo, ll);
    }
}

/// Per-entry reference implementation of [`merge_views_into`] (the
/// pre-chunking hot path).  Kept compiled as the equivalence oracle for
/// the chunked/SIMD kernels and as the bench baseline.
pub fn merge_views_into_reference(views: &[&FlatView], out: &mut FlatView) {
    out.clear();
    let mut heap: BinaryHeap<Reverse<(u64, u64, usize, usize)>> = views
        .iter()
        .enumerate()
        .filter(|(_, v)| !v.is_empty())
        .map(|(s, v)| Reverse((v.offsets()[0], v.lengths()[0], s, 0usize)))
        .collect();
    let mut last: Option<(u64, u64)> = None;
    while let Some(Reverse((off, len, s, i))) = heap.pop() {
        absorb(&mut last, out, off, len);
        let v = views[s];
        let mut i = i;
        loop {
            if i + 1 >= v.len() {
                break;
            }
            let next = (v.offsets()[i + 1], v.lengths()[i + 1], s, i + 1);
            match heap.peek() {
                Some(&Reverse(top)) if next > top => {
                    heap.push(Reverse(next));
                    break;
                }
                // Heap empty, or this stream still holds the minimum:
                // consume directly (identical pop order to the pure heap).
                _ => {
                    absorb(&mut last, out, next.0, next.1);
                    i += 1;
                }
            }
        }
    }
    if let Some((lo, ll)) = last {
        out.push(lo, ll);
    }
}

/// Reusable backing storage for the CSR heap merge — the heap's `Vec` is
/// borrowed out, heapified in place, and handed back after the merge, so
/// a steady-state round performs no allocation at all (the last per-call
/// allocation of the pre-arena merge path).
#[derive(Debug, Default)]
pub struct MergeScratch {
    heap: Vec<Reverse<(u64, u64, usize, usize)>>,
}

/// [`merge_views_into`] over CSR-staged streams: stream `s` is rows
/// `starts[s]..starts[s + 1]` of the `offsets`/`lengths` slab (the
/// [`RoundScratch`] staging layout).  Pops in the exact order of the
/// slice-per-stream algorithm — heap entries carry absolute slab rows,
/// and two entries of the same stream never coexist in the heap, so the
/// `(offset, length, stream)` tie-break is untouched.
pub fn merge_csr_into(
    offsets: &[u64],
    lengths: &[u64],
    starts: &[usize],
    scratch: &mut MergeScratch,
    out: &mut FlatView,
) {
    out.clear();
    let k = starts.len().saturating_sub(1);
    scratch.heap.clear();
    for s in 0..k {
        let lo = starts[s];
        if lo < starts[s + 1] {
            scratch.heap.push(Reverse((offsets[lo], lengths[lo], s, lo)));
        }
    }
    // Heapify in place (no allocation); the Vec is recovered at the end.
    let mut heap = BinaryHeap::from(std::mem::take(&mut scratch.heap));
    let mut last: Option<(u64, u64)> = None;
    while let Some(Reverse((_, _, s, i))) = heap.pop() {
        let hi = starts[s + 1];
        // Fixed heap top while this stream gallops: chunked claim of the
        // whole run, then chunked-coalesce absorb (see merge_views_into).
        let take = match heap.peek() {
            None => hi - i,
            Some(&Reverse(top)) => 1 + gallop_len(offsets, lengths, i + 1, hi, s, top),
        };
        absorb_run(&offsets[i..i + take], &lengths[i..i + take], &mut last, out);
        if i + take < hi {
            heap.push(Reverse((offsets[i + take], lengths[i + take], s, i + take)));
        }
    }
    if let Some((lo, ll)) = last {
        out.push(lo, ll);
    }
    scratch.heap = heap.into_vec();
    scratch.heap.clear();
}

/// Per-entry reference implementation of [`merge_csr_into`] — the
/// equivalence oracle and bench baseline for the chunked CSR merge.
pub fn merge_csr_into_reference(
    offsets: &[u64],
    lengths: &[u64],
    starts: &[usize],
    scratch: &mut MergeScratch,
    out: &mut FlatView,
) {
    out.clear();
    let k = starts.len().saturating_sub(1);
    scratch.heap.clear();
    for s in 0..k {
        let lo = starts[s];
        if lo < starts[s + 1] {
            scratch.heap.push(Reverse((offsets[lo], lengths[lo], s, lo)));
        }
    }
    let mut heap = BinaryHeap::from(std::mem::take(&mut scratch.heap));
    let mut last: Option<(u64, u64)> = None;
    while let Some(Reverse((off, len, s, i))) = heap.pop() {
        absorb(&mut last, out, off, len);
        let hi = starts[s + 1];
        let mut i = i;
        loop {
            if i + 1 >= hi {
                break;
            }
            let next = (offsets[i + 1], lengths[i + 1], s, i + 1);
            match heap.peek() {
                Some(&Reverse(top)) if next > top => {
                    heap.push(Reverse(next));
                    break;
                }
                _ => {
                    absorb(&mut last, out, next.0, next.1);
                    i += 1;
                }
            }
        }
    }
    if let Some((lo, ll)) = last {
        out.push(lo, ll);
    }
    scratch.heap = heap.into_vec();
    scratch.heap.clear();
}

/// Merge request batches: metadata via [`merge_views`], then payload
/// scatter into one contiguous buffer ordered by the merged view.
///
/// Returns the merged batch and the number of bytes moved (for the
/// memcpy-time component).  Payloads of distinct batches must not overlap
/// in file space for bytes to be well-defined; overlapping writers are
/// resolved "later batch wins" (matching aggregator receive order).
pub fn merge_batches(batches: &[ReqBatch]) -> (ReqBatch, u64) {
    let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
    let merged = merge_views(&views);
    let (payload, moved) = scatter_into(&merged, batches);
    (ReqBatch { view: merged, payload }, moved)
}

/// Scatter batch payloads into one contiguous buffer laid out by `merged`
/// (which must cover every batch request — e.g. produced by
/// [`merge_views`] or an [`crate::runtime::engine::SortEngine`]).
///
/// Returns the buffer and the bytes moved (memcpy-time accounting).
pub fn scatter_into(merged: &FlatView, batches: &[ReqBatch]) -> (Vec<u8>, u64) {
    let mut payload = Vec::new();
    let moved = scatter_into_buf(merged, batches, &mut payload);
    (payload, moved)
}

/// [`scatter_into`] into a caller-owned buffer (cleared, zero-filled and
/// resized to `merged.total_bytes()`; capacity is reused across calls —
/// the scratch-arena hot path).
///
/// Both `merged` and each batch view are ascending, so the containing
/// merged segment is found with a linear two-pointer walk instead of a
/// per-request binary search, and the segment's payload start is carried
/// as a running sum — `O(reqs + segments)` per batch, no index tables.
pub fn scatter_into_buf(merged: &FlatView, batches: &[ReqBatch], payload: &mut Vec<u8>) -> u64 {
    let total = merged.total_bytes() as usize;
    payload.clear();
    payload.resize(total, 0);
    let seg_offsets = merged.offsets();
    let seg_lengths = merged.lengths();

    let mut moved = 0u64;
    for b in batches {
        if b.payload.is_empty() {
            continue;
        }
        let mut cursor = 0usize;
        let mut seg = 0usize;
        // Payload position of segment `seg` within the merged buffer.
        let mut seg_start = 0u64;
        for (off, len) in b.view.iter() {
            // Advance to the last segment starting at or before `off`
            // (batch offsets are nondecreasing, so `seg` never rewinds).
            while seg + 1 < seg_offsets.len() && seg_offsets[seg + 1] <= off {
                seg_start += seg_lengths[seg];
                seg += 1;
            }
            let within = off - seg_offsets[seg];
            debug_assert!(within + len <= seg_lengths[seg]);
            let dst = (seg_start + within) as usize;
            payload[dst..dst + len as usize]
                .copy_from_slice(&b.payload[cursor..cursor + len as usize]);
            cursor += len as usize;
            moved += len;
        }
    }
    moved
}

/// [`scatter_into_buf`] over CSR-staged streams (the [`RoundScratch`]
/// staging layout): stream `s` is slab rows `starts[s]..starts[s + 1]`
/// with shipped payload bytes `pay_starts[s]..pay_starts[s + 1]` of
/// `in_payload`.  A metadata-only stream (empty payload span) is
/// skipped, its region staying zero-filled — exactly
/// [`scatter_into_buf`]'s treatment of empty-payload batches.  Returns
/// the bytes moved.
#[allow(clippy::too_many_arguments)]
pub fn scatter_csr_into_buf(
    merged: &FlatView,
    in_offsets: &[u64],
    in_lengths: &[u64],
    starts: &[usize],
    pay_starts: &[usize],
    in_payload: &[u8],
    payload_out: &mut Vec<u8>,
) -> u64 {
    let total = merged.total_bytes() as usize;
    payload_out.clear();
    payload_out.resize(total, 0);
    if in_payload.is_empty() {
        return 0;
    }
    let seg_offsets = merged.offsets();
    let seg_lengths = merged.lengths();
    let mut moved = 0u64;
    let k = starts.len().saturating_sub(1);
    for s in 0..k {
        let mut cursor = pay_starts[s];
        if cursor == pay_starts[s + 1] {
            // Metadata-only stream: no bytes shipped, region stays zero.
            continue;
        }
        let mut seg = 0usize;
        // Payload position of segment `seg` within the merged buffer.
        let mut seg_start = 0u64;
        let (lo, hi) = (starts[s], starts[s + 1]);
        let mut i = lo;
        while i < hi {
            let (off, len) = (in_offsets[i], in_lengths[i]);
            while seg + 1 < seg_offsets.len() && seg_offsets[seg + 1] <= off {
                seg_start += seg_lengths[seg];
                seg += 1;
            }
            // Batch the file-contiguous run that stays inside the
            // current merged segment: the source is contiguous in
            // `in_payload` by construction (payload travels in view
            // order) and the destination is contiguous because no `seg`
            // advance happens, so the whole run is ONE memcpy.
            let next_seg_off =
                if seg + 1 < seg_offsets.len() { seg_offsets[seg + 1] } else { u64::MAX };
            let mut end = off + len;
            let mut run = len;
            let mut j = i + 1;
            while j < hi && in_offsets[j] == end && in_offsets[j] < next_seg_off {
                run += in_lengths[j];
                end += in_lengths[j];
                j += 1;
            }
            let within = off - seg_offsets[seg];
            debug_assert!(within + run <= seg_lengths[seg]);
            let dst = (seg_start + within) as usize;
            payload_out[dst..dst + run as usize]
                .copy_from_slice(&in_payload[cursor..cursor + run as usize]);
            cursor += run as usize;
            moved += run;
            i = j;
        }
        debug_assert_eq!(cursor, pay_starts[s + 1], "stream payload span fully consumed");
    }
    moved
}

/// Per-request reference implementation of [`scatter_csr_into_buf`]
/// (one `copy_from_slice` per staged request) — the equivalence oracle
/// and bench baseline for the run-batched scatter.
#[allow(clippy::too_many_arguments)]
pub fn scatter_csr_into_buf_reference(
    merged: &FlatView,
    in_offsets: &[u64],
    in_lengths: &[u64],
    starts: &[usize],
    pay_starts: &[usize],
    in_payload: &[u8],
    payload_out: &mut Vec<u8>,
) -> u64 {
    let total = merged.total_bytes() as usize;
    payload_out.clear();
    payload_out.resize(total, 0);
    if in_payload.is_empty() {
        return 0;
    }
    let seg_offsets = merged.offsets();
    let seg_lengths = merged.lengths();
    let mut moved = 0u64;
    let k = starts.len().saturating_sub(1);
    for s in 0..k {
        let mut cursor = pay_starts[s];
        if cursor == pay_starts[s + 1] {
            continue;
        }
        let mut seg = 0usize;
        let mut seg_start = 0u64;
        for i in starts[s]..starts[s + 1] {
            let (off, len) = (in_offsets[i], in_lengths[i]);
            while seg + 1 < seg_offsets.len() && seg_offsets[seg + 1] <= off {
                seg_start += seg_lengths[seg];
                seg += 1;
            }
            let within = off - seg_offsets[seg];
            debug_assert!(within + len <= seg_lengths[seg]);
            let dst = (seg_start + within) as usize;
            payload_out[dst..dst + len as usize]
                .copy_from_slice(&in_payload[cursor..cursor + len as usize]);
            cursor += len as usize;
            moved += len;
        }
        debug_assert_eq!(cursor, pay_starts[s + 1], "stream payload span fully consumed");
    }
    moved
}

/// Reverse of [`scatter_into_buf`]: copy the bytes of each request of
/// `view` *out of* the contiguous buffer `payload` laid out by `merged`
/// into `out` (view order) — the requester-side reply assembly of the
/// collective-read path and the TAM read scatter.
///
/// Both `merged` and `view` are ascending, so the containing merged
/// segment is found with the same linear two-pointer walk as the scatter;
/// `merged` must cover every nonzero request of `view` (it is the engine
/// merge of the peer views, which include `view`).  Returns bytes moved.
pub fn gather_from_buf(merged: &FlatView, payload: &[u8], view: &FlatView, out: &mut [u8]) -> u64 {
    debug_assert_eq!(out.len() as u64, view.total_bytes());
    gather_slices_from_buf(merged, payload, view.offsets(), view.lengths(), out)
}

/// [`gather_from_buf`] over a raw `(offsets, lengths)` request slice —
/// the form the CSR-staged read path holds its streams in (no `FlatView`
/// is materialized per stream on the hot path).
pub fn gather_slices_from_buf(
    merged: &FlatView,
    payload: &[u8],
    offsets: &[u64],
    lengths: &[u64],
    out: &mut [u8],
) -> u64 {
    debug_assert_eq!(payload.len() as u64, merged.total_bytes());
    debug_assert_eq!(offsets.len(), lengths.len());
    let seg_offsets = merged.offsets();
    let seg_lengths = merged.lengths();
    let n = offsets.len();
    let mut cursor = 0usize;
    let mut seg = 0usize;
    // Payload position of segment `seg` within the merged buffer.
    let mut seg_start = 0u64;
    let mut moved = 0u64;
    let mut i = 0usize;
    while i < n {
        let (off, len) = (offsets[i], lengths[i]);
        // Zero-length requests occupy no bytes on either side — and,
        // matching the per-request reference, never advance `seg`.
        if len == 0 {
            i += 1;
            continue;
        }
        while seg + 1 < seg_offsets.len() && seg_offsets[seg + 1] <= off {
            seg_start += seg_lengths[seg];
            seg += 1;
        }
        // Batch the file-contiguous run staying inside this merged
        // segment into ONE memcpy: destination (`out`, view order) is
        // contiguous by construction, source is contiguous because no
        // `seg` advance happens.  Zero-length requests at the running
        // end join the run (they contribute no bytes either way).
        let next_seg_off =
            if seg + 1 < seg_offsets.len() { seg_offsets[seg + 1] } else { u64::MAX };
        let mut end = off + len;
        let mut run = len;
        let mut j = i + 1;
        while j < n && offsets[j] == end && offsets[j] < next_seg_off {
            run += lengths[j];
            end += lengths[j];
            j += 1;
        }
        let within = off - seg_offsets[seg];
        debug_assert!(within + run <= seg_lengths[seg], "request not covered by merged view");
        let src = (seg_start + within) as usize;
        out[cursor..cursor + run as usize]
            .copy_from_slice(&payload[src..src + run as usize]);
        cursor += run as usize;
        moved += run;
        i = j;
    }
    moved
}

/// Per-request reference implementation of [`gather_slices_from_buf`]
/// (one `copy_from_slice` per view request) — the equivalence oracle
/// and bench baseline for the run-batched gather.
pub fn gather_slices_from_buf_reference(
    merged: &FlatView,
    payload: &[u8],
    offsets: &[u64],
    lengths: &[u64],
    out: &mut [u8],
) -> u64 {
    debug_assert_eq!(payload.len() as u64, merged.total_bytes());
    debug_assert_eq!(offsets.len(), lengths.len());
    let seg_offsets = merged.offsets();
    let seg_lengths = merged.lengths();
    let mut cursor = 0usize;
    let mut seg = 0usize;
    let mut seg_start = 0u64;
    let mut moved = 0u64;
    for (&off, &len) in offsets.iter().zip(lengths) {
        // Zero-length requests occupy no bytes on either side.
        if len == 0 {
            continue;
        }
        while seg + 1 < seg_offsets.len() && seg_offsets[seg + 1] <= off {
            seg_start += seg_lengths[seg];
            seg += 1;
        }
        let within = off - seg_offsets[seg];
        debug_assert!(within + len <= seg_lengths[seg], "request not covered by merged view");
        let src = (seg_start + within) as usize;
        out[cursor..cursor + len as usize]
            .copy_from_slice(&payload[src..src + len as usize]);
        cursor += len as usize;
        moved += len;
    }
    moved
}

/// Reference implementation of [`scatter_into`] using a per-request binary
/// search over the merged offsets (the pre-streaming hot path).  Kept for
/// the equivalence regression tests and the hot-path benchmark baseline.
pub fn scatter_into_binary_search(merged: &FlatView, batches: &[ReqBatch]) -> (Vec<u8>, u64) {
    let total = merged.total_bytes();
    let mut payload = vec![0u8; total as usize];

    // Prefix sums of merged segment payload positions for binary search.
    let seg_offsets = merged.offsets();
    let mut seg_payload_start = Vec::with_capacity(merged.len());
    let mut acc = 0u64;
    for l in merged.lengths() {
        seg_payload_start.push(acc);
        acc += l;
    }

    let mut moved = 0u64;
    for b in batches {
        if b.payload.is_empty() {
            continue;
        }
        let mut cursor = 0usize;
        for (off, len) in b.view.iter() {
            // Find the merged segment containing `off`.
            let seg = match seg_offsets.binary_search(&off) {
                Ok(i) => i,
                Err(i) => i - 1, // off falls inside segment i-1
            };
            let within = off - seg_offsets[seg];
            debug_assert!(within + len <= merged.lengths()[seg]);
            let dst = (seg_payload_start[seg] + within) as usize;
            payload[dst..dst + len as usize]
                .copy_from_slice(&b.payload[cursor..cursor + len as usize]);
            cursor += len as usize;
            moved += len;
        }
    }
    (payload, moved)
}

/// Reusable per-aggregator scratch for one slot of the direction-generic
/// exchange round loop (`coordinator/collective.rs::run_exchange`): the
/// staging slabs, the merged view and the contiguous payload buffer —
/// every per-round allocation of the pre-arena paths — survive across
/// rounds *and across exchanges* with their capacity intact (the slots
/// live in `collective.rs::ExchangeArena`; ownership contract in
/// DESIGN.md §Memory layout).
///
/// Staging is CSR, not per-batch: peer requests land in one flat
/// `in_offsets`/`in_lengths`/`in_payload` slab via [`Self::stage`]
/// (`extend_from_slice` of the requester's [`MyReqs` slab
/// spans](crate::coordinator::reqcalc::ReqSlice) — a memcpy into warm
/// capacity, the simulator's stand-in for the message landing in the
/// receiver's staging buffer), with stream boundaries in
/// `starts`/`byte_starts`.  The pre-slab `Vec<ReqBatch>` staging moved
/// one three-`Vec` batch per peer per round.
///
/// The two directions specialize only what the buffers *mean*:
///
/// * **write** — staged streams carry peer payloads;
///   [`Self::merge_scatter`] merges the views through the engine and
///   scatters the payloads into `payload`, which storage then persists
///   ([`crate::lustre::LustreFile::write_view`]);
/// * **read** — staged streams are metadata only (a read carries no
///   payload on the request side); [`Self::merge_meta`] merges the views,
///   storage fills `payload` ([`crate::lustre::LustreFile::read_view`])
///   and the requester-side [`gather_slices_from_buf`] copies each peer's
///   bytes back out.  `stats` (per-OST read accounting) keeps its
///   *contents* across rounds, since the file itself is immutable on
///   reads.
#[derive(Debug, Default)]
pub struct RoundScratch {
    /// Staged request offsets, all peers concatenated (stream `s` is rows
    /// `starts[s]..starts[s + 1]`).
    pub in_offsets: Vec<u64>,
    /// Staged request lengths, parallel to `in_offsets`.
    pub in_lengths: Vec<u64>,
    /// Staged payload bytes in slab order (empty on reads).
    pub in_payload: Vec<u8>,
    /// Stream row boundaries (`k + 1` entries once staged).
    pub starts: Vec<usize>,
    /// Stream *view*-byte boundaries (`k + 1` entries; maintained for
    /// reads too, where they size the reply spans).
    pub byte_starts: Vec<usize>,
    /// Stream boundaries into `in_payload` (`k + 1` entries) — the
    /// bytes a stream actually shipped.  Equal to `byte_starts` when
    /// every stream carries payload; a metadata-only stream contributes
    /// an empty span here while still advancing `byte_starts`, so mixed
    /// staging scatters correctly (the empty-payload stream's region
    /// stays zero-filled, matching [`scatter_into_buf`]'s skip).
    pub pay_starts: Vec<usize>,
    /// Requester index of each staged stream (parallel to streams) —
    /// the read direction's reply-assembly plan.
    pub owners: Vec<usize>,
    /// Merged, coalesced view (engine output arena, capacity reused).
    pub merged: FlatView,
    /// Contiguous bytes laid out by `merged` (capacity reused).
    pub payload: Vec<u8>,
    /// Per-OST read accounting, accumulated across rounds (read
    /// direction; empty for writes, which account in the file itself).
    pub stats: Vec<crate::lustre::OstStats>,
    /// Reused heap storage for the CSR merge.
    pub merge_scratch: MergeScratch,
    /// Total input requests staged this round (cost accounting).
    pub n_items: u64,
    /// Number of contributing peer streams this round (cost accounting).
    pub k: usize,
}

impl RoundScratch {
    /// Reset the per-round state, keeping allocated capacity (and the
    /// cross-round `stats` accumulation of the read direction).
    pub fn reset_round(&mut self) {
        self.in_offsets.clear();
        self.in_lengths.clear();
        self.in_payload.clear();
        self.starts.clear();
        self.starts.push(0);
        self.byte_starts.clear();
        self.byte_starts.push(0);
        self.pay_starts.clear();
        self.pay_starts.push(0);
        self.owners.clear();
        self.merged.clear();
        self.payload.clear();
        self.n_items = 0;
        self.k = 0;
    }

    /// Reset for a fresh exchange: per-round state plus the cross-round
    /// `stats` accumulation (`n_osts` slots; 0 for writes) — the arena
    /// persists across `run_exchange` invocations, so per-exchange state
    /// must be re-zeroed here, never in the constructor.
    pub fn reset_exchange(&mut self, n_osts: usize) {
        self.reset_round();
        self.stats.clear();
        self.stats.resize(n_osts, crate::lustre::OstStats::default());
    }

    /// Stage one peer stream for this round on behalf of requester
    /// `owner`: append its rows (and payload, when present) to the slabs.
    /// `bytes` is the stream's byte total (known `O(1)` by the caller;
    /// equals `payload.len()` when a payload travels).
    pub fn stage(
        &mut self,
        owner: usize,
        offsets: &[u64],
        lengths: &[u64],
        payload: &[u8],
        bytes: u64,
    ) {
        debug_assert_eq!(offsets.len(), lengths.len());
        debug_assert!(payload.is_empty() || payload.len() as u64 == bytes);
        if self.starts.is_empty() {
            self.starts.push(0);
            self.byte_starts.push(0);
            self.pay_starts.push(0);
        }
        self.owners.push(owner);
        self.in_offsets.extend_from_slice(offsets);
        self.in_lengths.extend_from_slice(lengths);
        self.in_payload.extend_from_slice(payload);
        self.starts.push(self.in_offsets.len());
        let prev = *self.byte_starts.last().expect("byte_starts seeded above");
        self.byte_starts.push(prev + bytes as usize);
        self.pay_starts.push(self.in_payload.len());
    }

    /// [`Self::stage`] from an owned/borrowed batch (tests, benches and
    /// the intra-node layer — the exchange loop stages slab slices).
    pub fn stage_batch(&mut self, owner: usize, b: &ReqBatch) {
        self.stage(owner, b.view.offsets(), b.view.lengths(), &b.payload, b.view.total_bytes());
    }

    /// Row range of staged stream `s` — `(offsets, lengths)` slices.
    pub fn stream(&self, s: usize) -> (&[u64], &[u64]) {
        let (lo, hi) = (self.starts[s], self.starts[s + 1]);
        (&self.in_offsets[lo..hi], &self.in_lengths[lo..hi])
    }

    /// Byte total of staged stream `s`.
    pub fn stream_bytes(&self, s: usize) -> usize {
        self.byte_starts[s + 1] - self.byte_starts[s]
    }

    /// Merge the staged views into the `merged` arena; returns whether
    /// anything was staged.
    fn merge_into(&mut self, engine: &dyn SortEngine) -> Result<bool> {
        self.k = self.owners.len();
        self.n_items = self.in_offsets.len() as u64;
        if self.k == 0 {
            self.merged.clear();
            self.payload.clear();
            return Ok(false);
        }
        engine.merge_sorted_csr_into(
            &self.in_offsets,
            &self.in_lengths,
            &self.starts,
            &mut self.merge_scratch,
            &mut self.merged,
        )?;
        Ok(true)
    }

    /// Write direction: merge the staged streams through `engine` and
    /// scatter their payloads into the reusable buffer.  Returns the
    /// bytes moved.
    pub fn merge_scatter(&mut self, engine: &dyn SortEngine) -> Result<u64> {
        if !self.merge_into(engine)? {
            return Ok(0);
        }
        Ok(scatter_csr_into_buf(
            &self.merged,
            &self.in_offsets,
            &self.in_lengths,
            &self.starts,
            &self.pay_starts,
            &self.in_payload,
            &mut self.payload,
        ))
    }

    /// Read direction: merge the staged peer views (metadata only —
    /// storage fills `payload` afterwards).
    pub fn merge_meta(&mut self, engine: &dyn SortEngine) -> Result<()> {
        self.merge_into(engine)?;
        Ok(())
    }
}

/// Sort-then-coalesce for *unsorted* pair lists (the native twin of the
/// XLA `aggregate` pipeline; used by the engine abstraction).
pub fn sort_coalesce_pairs(mut pairs: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    pairs.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(pairs.len());
    for (off, len) in pairs {
        match out.last_mut() {
            Some((lo, ll)) if *lo + *ll == off => *ll += len,
            _ => out.push((off, len)),
        }
    }
    out
}

/// Combine already-coalesced partial results (e.g. per-chunk outputs of
/// the XLA engine) into the global coalesced list.
///
/// This must merge a segment that starts *at or inside* the running
/// segment's range, not just exactly at its end: a zero-length request
/// processed in one chunk can land strictly inside a segment another
/// chunk already coalesced (it occupies no bytes, so this is not an
/// overlap), and plain end-contiguity would leave it splitting the
/// global result.  For disjoint inputs this reproduces
/// [`sort_coalesce_pairs`] of the original concatenation exactly.
pub fn combine_coalesced_partials(mut partials: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    partials.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(partials.len());
    for (off, len) in partials {
        match out.last_mut() {
            Some((lo, ll)) if off <= *lo + *ll => {
                *ll = (*ll).max(off + len - *lo);
            }
            _ => out.push((off, len)),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fv(pairs: &[(u64, u64)]) -> FlatView {
        FlatView::from_pairs(pairs.to_vec()).unwrap()
    }

    #[test]
    fn merge_two_interleaved_streams_coalesces_fully() {
        let a = fv(&[(0, 4), (8, 4)]);
        let b = fv(&[(4, 4), (12, 4)]);
        let m = merge_views(&[&a, &b]);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![(0, 16)]);
    }

    #[test]
    fn merge_disjoint_streams_keeps_gaps() {
        let a = fv(&[(0, 4)]);
        let b = fv(&[(100, 4)]);
        let m = merge_views(&[&a, &b]);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![(0, 4), (100, 4)]);
    }

    #[test]
    fn merge_empty_inputs() {
        assert!(merge_views(&[]).is_empty());
        let e = FlatView::empty();
        let a = fv(&[(5, 5)]);
        let m = merge_views(&[&e, &a, &e]);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![(5, 5)]);
    }

    #[test]
    fn merge_single_stream_gallops_to_the_end() {
        // With one stream the heap is empty after the first pop; the
        // gallop path must still emit (and coalesce) every entry.
        let a = fv(&[(0, 4), (4, 4), (10, 2), (20, 4)]);
        let m = merge_views(&[&a]);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![(0, 8), (10, 2), (20, 4)]);
    }

    #[test]
    fn merge_run_structured_streams() {
        // Long per-stream runs (the gallop fast path) interleaved at run
        // granularity across streams.
        let a = fv(&[(0, 10), (10, 10), (40, 10), (50, 10)]);
        let b = fv(&[(20, 10), (30, 10), (60, 10), (75, 5)]);
        let m = merge_views(&[&a, &b]);
        assert_eq!(m.iter().collect::<Vec<_>>(), vec![(0, 70), (75, 5)]);
    }

    #[test]
    fn merge_matches_sort_coalesce_reference() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(99);
        for _ in 0..50 {
            let k = 1 + rng.gen_range(6) as usize;
            let mut streams = Vec::new();
            let mut all = Vec::new();
            for _ in 0..k {
                let n = rng.gen_range(20) as usize;
                let mut pairs = Vec::new();
                let mut cur = rng.gen_range(64);
                for _ in 0..n {
                    let len = 1 + rng.gen_range(8);
                    pairs.push((cur, len));
                    all.push((cur, len));
                    cur += len + rng.gen_range(3) * rng.gen_range(16);
                }
                streams.push(fv(&pairs));
            }
            let refs: Vec<&FlatView> = streams.iter().collect();
            let merged = merge_views(&refs);
            let want = sort_coalesce_pairs(all);
            assert_eq!(merged.iter().collect::<Vec<_>>(), want);
        }
    }

    #[test]
    fn merge_batches_moves_payload_correctly() {
        let a = ReqBatch::new(fv(&[(0, 2), (6, 2)]), vec![1, 2, 7, 8]);
        let b = ReqBatch::new(fv(&[(2, 2)]), vec![3, 4]);
        let (m, moved) = merge_batches(&[a, b]);
        assert_eq!(m.view.iter().collect::<Vec<_>>(), vec![(0, 4), (6, 2)]);
        assert_eq!(m.payload, vec![1, 2, 3, 4, 7, 8]);
        assert_eq!(moved, 6);
    }

    #[test]
    fn merge_batches_metadata_only_when_no_payload() {
        let a = ReqBatch::new(fv(&[(0, 2)]), vec![]);
        let b = ReqBatch::new(fv(&[(2, 2)]), vec![]);
        let (m, moved) = merge_batches(&[a, b]);
        assert_eq!(m.view.iter().collect::<Vec<_>>(), vec![(0, 4)]);
        assert_eq!(moved, 0);
        assert_eq!(m.payload, vec![0u8; 4]);
    }

    #[test]
    fn scatter_two_pointer_matches_binary_search() {
        // Zero-length requests and a batch landing mid-segment.
        let a = ReqBatch::new(fv(&[(0, 2), (4, 0), (6, 2)]), vec![1, 2, 7, 8]);
        let b = ReqBatch::new(fv(&[(2, 2), (8, 1)]), vec![3, 4, 9]);
        let views: Vec<&FlatView> = [&a, &b].iter().map(|x| &x.view).collect();
        let merged = merge_views(&views);
        let batches = [a, b];
        let (p1, m1) = scatter_into(&merged, &batches);
        let (p2, m2) = scatter_into_binary_search(&merged, &batches);
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn scatter_into_buf_reuses_and_zero_fills() {
        let a = ReqBatch::new(fv(&[(0, 2)]), vec![5, 6]);
        let mut buf = vec![0xFFu8; 64];
        let moved = scatter_into_buf(&a.view.clone(), std::slice::from_ref(&a), &mut buf);
        assert_eq!(moved, 2);
        assert_eq!(buf, vec![5, 6]);
        // A second use with a smaller layout must not leak stale bytes.
        let b = ReqBatch::new(fv(&[(10, 1)]), vec![9]);
        let moved = scatter_into_buf(&b.view.clone(), std::slice::from_ref(&b), &mut buf);
        assert_eq!(moved, 1);
        assert_eq!(buf, vec![9]);
    }

    #[test]
    fn round_scratch_merges_scatters_and_resets() {
        use crate::runtime::engine::NativeEngine;
        let mut s = RoundScratch::default();
        s.stage_batch(0, &ReqBatch::new(fv(&[(0, 2), (6, 2)]), vec![1, 2, 7, 8]));
        s.stage_batch(1, &ReqBatch::new(fv(&[(2, 2)]), vec![3, 4]));
        let moved = s.merge_scatter(&NativeEngine).unwrap();
        assert_eq!(moved, 6);
        assert_eq!(s.k, 2);
        assert_eq!(s.n_items, 3);
        assert_eq!(s.owners, vec![0, 1]);
        assert_eq!(s.starts, vec![0, 2, 3]);
        assert_eq!(s.byte_starts, vec![0, 4, 6]);
        assert_eq!(s.pay_starts, vec![0, 4, 6]);
        assert_eq!(s.stream(1), (&[2u64][..], &[2u64][..]));
        assert_eq!(s.stream_bytes(0), 4);
        assert_eq!(s.merged.iter().collect::<Vec<_>>(), vec![(0, 4), (6, 2)]);
        assert_eq!(s.payload, vec![1, 2, 3, 4, 7, 8]);
        s.reset_round();
        assert!(s.in_offsets.is_empty() && s.owners.is_empty());
        assert!(s.merged.is_empty() && s.payload.is_empty());
        assert_eq!(s.starts, vec![0]);
        // Empty round: merge_scatter is a cheap no-op.
        assert_eq!(s.merge_scatter(&NativeEngine).unwrap(), 0);
        assert_eq!(s.k, 0);
        // Re-staged round after reset: the reused arena must not leak
        // stale segments or payload bytes.
        s.stage_batch(2, &ReqBatch::new(fv(&[(10, 1)]), vec![9]));
        assert_eq!(s.merge_scatter(&NativeEngine).unwrap(), 1);
        assert_eq!(s.merged.iter().collect::<Vec<_>>(), vec![(10, 1)]);
        assert_eq!(s.payload, vec![9]);
        // reset_exchange additionally re-zeroes the stats slots.
        s.stats.resize(3, crate::lustre::OstStats::default());
        s.stats[1].bytes = 7;
        s.reset_exchange(3);
        assert!(s.stats.iter().all(|st| st.bytes == 0 && st.extents == 0));
        s.reset_exchange(0);
        assert!(s.stats.is_empty());
    }

    #[test]
    fn csr_merge_and_scatter_match_batch_path() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xC5_12AB);
        let mut scratch = MergeScratch::default();
        let mut csr_out = FlatView::empty();
        for case in 0..60 {
            let k = 1 + rng.gen_range(7) as usize;
            let mut batches = Vec::new();
            for tag in 0..k {
                let n = rng.gen_range(25) as usize;
                let mut pairs = Vec::new();
                let mut cursor = rng.gen_range(64);
                for _ in 0..n {
                    let len = rng.gen_range(9); // includes zero-length
                    if rng.gen_bool(0.5) {
                        cursor += rng.gen_range(40);
                    }
                    pairs.push((cursor, len));
                    cursor += len;
                }
                let view = fv(&pairs);
                // Occasionally a metadata-only batch mixed among payload
                // batches: the scatter must skip it (zeros land in its
                // region), exactly like the batch reference path.
                let payload: Vec<u8> = if rng.gen_bool(0.2) {
                    Vec::new()
                } else {
                    (0..view.total_bytes())
                        .map(|i| (i as u8).wrapping_mul(31) ^ tag as u8)
                        .collect()
                };
                batches.push(ReqBatch::new(view, payload));
            }
            // Stage the batches into the CSR slabs.
            let mut s = RoundScratch::default();
            s.reset_round();
            for (i, b) in batches.iter().enumerate() {
                s.stage_batch(i, b);
            }
            // Merge: CSR vs the slice-per-stream algorithm.
            let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
            let want = merge_views(&views);
            merge_csr_into(&s.in_offsets, &s.in_lengths, &s.starts, &mut scratch, &mut csr_out);
            assert_eq!(csr_out, want, "case {case}: merge mismatch");
            // Scatter: CSR vs the batch two-pointer path.
            let mut want_buf = Vec::new();
            let want_moved = scatter_into_buf(&want, &batches, &mut want_buf);
            let mut got_buf = Vec::new();
            let got_moved = scatter_csr_into_buf(
                &want,
                &s.in_offsets,
                &s.in_lengths,
                &s.starts,
                &s.pay_starts,
                &s.in_payload,
                &mut got_buf,
            );
            assert_eq!(got_buf, want_buf, "case {case}: scatter mismatch");
            assert_eq!(got_moved, want_moved, "case {case}");
            // Gather: slice form vs FlatView form, per stream.
            for (i, b) in batches.iter().enumerate() {
                let mut out_a = vec![0u8; b.view.total_bytes() as usize];
                let mut out_b = vec![0u8; b.view.total_bytes() as usize];
                gather_from_buf(&want, &want_buf, &b.view, &mut out_a);
                let (vo, vl) = s.stream(i);
                gather_slices_from_buf(&want, &want_buf, vo, vl, &mut out_b);
                assert_eq!(out_a, out_b, "case {case} stream {i}");
                assert_eq!(s.stream_bytes(i) as u64, b.view.total_bytes());
            }
        }
    }

    #[test]
    fn csr_merge_empty_and_single_stream() {
        let mut scratch = MergeScratch::default();
        let mut out = fv(&[(9, 9)]);
        merge_csr_into(&[], &[], &[], &mut scratch, &mut out);
        assert!(out.is_empty());
        merge_csr_into(&[], &[], &[0], &mut scratch, &mut out);
        assert!(out.is_empty());
        // Single stream gallops to the end with an empty heap.
        merge_csr_into(&[0, 4, 10], &[4, 4, 2], &[0, 3], &mut scratch, &mut out);
        assert_eq!(out.iter().collect::<Vec<_>>(), vec![(0, 8), (10, 2)]);
        // Reused scratch across calls stays clean.
        merge_csr_into(&[5, 7], &[2, 1], &[0, 1, 2], &mut scratch, &mut out);
        assert_eq!(out.iter().collect::<Vec<_>>(), vec![(5, 3)]);
    }

    #[test]
    fn gather_inverts_scatter() {
        // scatter batches into the merged buffer, then gather each batch
        // back out: bytes must round-trip exactly.
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0x6A7);
        for _ in 0..50 {
            let k = 1 + rng.gen_range(6) as usize;
            let mut batches = Vec::new();
            let mut cursor = rng.gen_range(64);
            for tag in 0..k {
                let n = rng.gen_range(30) as usize;
                let mut pairs = Vec::new();
                for _ in 0..n {
                    let len = rng.gen_range(9); // includes zero-length
                    if rng.gen_bool(0.5) {
                        cursor += rng.gen_range(40);
                    }
                    pairs.push((cursor, len));
                    cursor += len;
                }
                let view = fv(&pairs);
                let payload: Vec<u8> = (0..view.total_bytes())
                    .map(|i| (i as u8).wrapping_mul(13) ^ tag as u8)
                    .collect();
                batches.push(ReqBatch::new(view, payload));
            }
            let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
            let merged = merge_views(&views);
            let (buf, _) = scatter_into(&merged, &batches);
            for b in &batches {
                let mut out = vec![0u8; b.view.total_bytes() as usize];
                let moved = gather_from_buf(&merged, &buf, &b.view, &mut out);
                assert_eq!(out, b.payload);
                assert_eq!(moved, b.view.total_bytes());
            }
        }
    }

    #[test]
    fn gather_handles_overlapping_reads() {
        // Two readers over the same bytes: the merged view keeps the
        // overlapping segments distinct and each gather sees its own.
        let a = fv(&[(0, 8)]);
        let b = fv(&[(2, 4)]);
        let merged = merge_views(&[&a, &b]);
        assert_eq!(merged.iter().collect::<Vec<_>>(), vec![(0, 8), (2, 4)]);
        // Buffer laid out by `merged`: bytes of (0,8) then bytes of (2,4).
        let file: Vec<u8> = (10..18).collect();
        let mut payload = vec![0u8; merged.total_bytes() as usize];
        // Simulate the aggregator read: each merged segment filled from
        // the "file" image.
        let mut cur = 0usize;
        for (off, len) in merged.iter() {
            payload[cur..cur + len as usize]
                .copy_from_slice(&file[off as usize..(off + len) as usize]);
            cur += len as usize;
        }
        let mut out_a = vec![0u8; 8];
        let mut out_b = vec![0u8; 4];
        gather_from_buf(&merged, &payload, &a, &mut out_a);
        gather_from_buf(&merged, &payload, &b, &mut out_b);
        assert_eq!(out_a, (10..18).collect::<Vec<u8>>());
        assert_eq!(out_b, (12..16).collect::<Vec<u8>>());
    }

    #[test]
    fn round_scratch_metadata_only_read_rounds() {
        use crate::runtime::engine::NativeEngine;
        let mut s = RoundScratch::default();
        s.stage_batch(0, &ReqBatch::new(fv(&[(0, 2), (6, 2)]), Vec::new()));
        s.stage_batch(1, &ReqBatch::new(fv(&[(2, 2)]), Vec::new()));
        s.merge_meta(&NativeEngine).unwrap();
        assert_eq!(s.k, 2);
        assert_eq!(s.n_items, 3);
        assert_eq!(s.merged.iter().collect::<Vec<_>>(), vec![(0, 4), (6, 2)]);
        // Metadata staging still tracks view-byte spans (reply sizing)
        // while shipping no payload bytes.
        assert_eq!(s.stream_bytes(0), 4);
        assert_eq!(s.stream_bytes(1), 2);
        assert!(s.in_payload.is_empty());
        assert_eq!(s.pay_starts, vec![0, 0, 0]);
        s.reset_round();
        assert!(s.in_offsets.is_empty() && s.merged.is_empty() && s.payload.is_empty());
        // Empty round: merge_meta is a cheap no-op.
        s.merge_meta(&NativeEngine).unwrap();
        assert_eq!(s.k, 0);
        assert!(s.merged.is_empty());
    }

    #[test]
    fn merge_views_into_reuses_arena_without_stale_state() {
        let a = fv(&[(0, 4), (8, 4)]);
        let b = fv(&[(4, 4), (100, 2)]);
        let mut out = fv(&[(500, 7), (600, 1), (700, 1)]);
        merge_views_into(&[&a, &b], &mut out);
        assert_eq!(out, merge_views(&[&a, &b]));
        // Second merge into the same arena, smaller result.
        let c = fv(&[(3, 1)]);
        merge_views_into(&[&c], &mut out);
        assert_eq!(out.iter().collect::<Vec<_>>(), vec![(3, 1)]);
    }

    #[test]
    fn sort_coalesce_pairs_basic() {
        let out = sort_coalesce_pairs(vec![(8, 4), (0, 4), (4, 4), (100, 1)]);
        assert_eq!(out, vec![(0, 12), (100, 1)]);
        assert!(sort_coalesce_pairs(vec![]).is_empty());
    }

    #[test]
    fn combine_partials_absorbs_interior_zero_length() {
        // Regression: a zero-length request processed in another chunk
        // lands strictly inside an already-coalesced segment.
        let partials = vec![(90089, 34), (90112, 0), (90123, 21)];
        assert_eq!(combine_coalesced_partials(partials), vec![(90089, 55)]);
    }

    #[test]
    fn combine_partials_matches_global_sort_coalesce() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(4242);
        for _ in 0..100 {
            // Disjoint requests incl. zero-lengths, shuffled, chunked.
            let mut cursor = 0u64;
            let mut pairs = Vec::new();
            for _ in 0..200 {
                let len = rng.gen_range(8);
                if rng.gen_bool(0.5) {
                    cursor += rng.gen_range(32);
                }
                pairs.push((cursor, len));
                cursor += len;
            }
            rng.shuffle(&mut pairs);
            let want = sort_coalesce_pairs(pairs.clone());
            let chunk_size = 1 + rng.gen_range(64) as usize;
            let partials: Vec<(u64, u64)> = pairs
                .chunks(chunk_size)
                .flat_map(|c| sort_coalesce_pairs(c.to_vec()))
                .collect();
            assert_eq!(combine_coalesced_partials(partials), want);
        }
    }

    /// Randomized CSR staging shared by the chunked-kernel oracles:
    /// returns staged scratch + the batches it was staged from.
    /// `runs` picks run-structured streams (long contiguous stretches —
    /// the chunked gallop/run-detection fast path) over scattered ones;
    /// both regimes mix in zero-length requests and payload-less
    /// (metadata-only) streams.
    fn random_staging(rng: &mut crate::util::SplitMix64, runs: bool) -> (RoundScratch, Vec<ReqBatch>) {
        let k = 1 + rng.gen_range(7) as usize;
        let mut batches = Vec::new();
        for tag in 0..k {
            let n = rng.gen_range(120) as usize;
            let mut pairs = Vec::new();
            let mut cursor = rng.gen_range(64);
            for _ in 0..n {
                let len = rng.gen_range(9); // includes zero-length
                // Run-structured: mostly contiguous, occasional jumps —
                // the regime the chunked advance is built for.
                let jump = if runs { rng.gen_bool(0.08) } else { rng.gen_bool(0.5) };
                if jump {
                    cursor += 1 + rng.gen_range(40);
                }
                pairs.push((cursor, len));
                cursor += len;
            }
            let view = fv(&pairs);
            let payload: Vec<u8> = if rng.gen_bool(0.2) {
                Vec::new()
            } else {
                (0..view.total_bytes()).map(|i| (i as u8).wrapping_mul(31) ^ tag as u8).collect()
            };
            batches.push(ReqBatch::new(view, payload));
        }
        let mut s = RoundScratch::default();
        s.reset_round();
        for (i, b) in batches.iter().enumerate() {
            s.stage_batch(i, b);
        }
        (s, batches)
    }

    #[test]
    fn chunked_merge_matches_reference_kernels() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xCAFE_0001);
        let mut scratch = MergeScratch::default();
        let mut ref_scratch = MergeScratch::default();
        let mut got = FlatView::empty();
        let mut want = FlatView::empty();
        for case in 0..80 {
            let runs = case % 2 == 0;
            let (s, batches) = random_staging(&mut rng, runs);
            // CSR form: chunked vs per-entry reference.
            merge_csr_into(&s.in_offsets, &s.in_lengths, &s.starts, &mut scratch, &mut got);
            merge_csr_into_reference(
                &s.in_offsets,
                &s.in_lengths,
                &s.starts,
                &mut ref_scratch,
                &mut want,
            );
            assert_eq!(got, want, "case {case}: CSR merge diverged from reference");
            // Slice-per-stream form: chunked vs per-entry reference.
            let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
            merge_views_into(&views, &mut got);
            merge_views_into_reference(&views, &mut want);
            assert_eq!(got, want, "case {case}: view merge diverged from reference");
        }
    }

    #[test]
    fn batched_scatter_gather_match_reference_kernels() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xCAFE_0002);
        let mut scratch = MergeScratch::default();
        let mut merged = FlatView::empty();
        for case in 0..80 {
            let runs = case % 2 == 0;
            let (s, batches) = random_staging(&mut rng, runs);
            merge_csr_into(&s.in_offsets, &s.in_lengths, &s.starts, &mut scratch, &mut merged);
            let mut got_buf = Vec::new();
            let mut want_buf = Vec::new();
            let got_moved = scatter_csr_into_buf(
                &merged,
                &s.in_offsets,
                &s.in_lengths,
                &s.starts,
                &s.pay_starts,
                &s.in_payload,
                &mut got_buf,
            );
            let want_moved = scatter_csr_into_buf_reference(
                &merged,
                &s.in_offsets,
                &s.in_lengths,
                &s.starts,
                &s.pay_starts,
                &s.in_payload,
                &mut want_buf,
            );
            assert_eq!(got_buf, want_buf, "case {case}: scatter diverged from reference");
            assert_eq!(got_moved, want_moved, "case {case}");
            for (i, b) in batches.iter().enumerate() {
                let nbytes = b.view.total_bytes() as usize;
                let mut got_out = vec![0u8; nbytes];
                let mut want_out = vec![0u8; nbytes];
                let (vo, vl) = s.stream(i);
                let gm = gather_slices_from_buf(&merged, &got_buf, vo, vl, &mut got_out);
                let wm = gather_slices_from_buf_reference(&merged, &want_buf, vo, vl, &mut want_out);
                assert_eq!(got_out, want_out, "case {case} stream {i}: gather diverged");
                assert_eq!(gm, wm, "case {case} stream {i}");
            }
        }
    }

    #[test]
    fn chunk_primitives_match_naive() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xCAFE_0003);
        for _ in 0..200 {
            // Sorted offsets with plateaus; lengths with zeros.
            let mut offsets = Vec::with_capacity(CHUNK + 1);
            let mut lengths = Vec::with_capacity(CHUNK + 1);
            let mut cur = rng.gen_range(16);
            for _ in 0..CHUNK + 1 {
                offsets.push(cur);
                let len = rng.gen_range(4);
                lengths.push(len);
                // ~half the adjacencies contiguous, rest break.
                cur += len + if rng.gen_bool(0.5) { 0 } else { 1 + rng.gen_range(8) };
            }
            let bound = offsets[rng.gen_range(CHUNK as u64 + 1) as usize] + rng.gen_range(2);
            let naive_count =
                offsets[..CHUNK].iter().filter(|&&x| x < bound).count();
            assert_eq!(count_lt_chunk_scalar(&offsets, bound), naive_count);
            assert_eq!(count_lt_chunk(&offsets, bound), naive_count);
            let mut naive_mask = 0u64;
            for t in 0..CHUNK {
                naive_mask |= ((offsets[t] + lengths[t] != offsets[t + 1]) as u64) << t;
            }
            assert_eq!(break_mask_chunk_scalar(&offsets, &lengths), naive_mask);
            assert_eq!(break_mask_chunk(&offsets, &lengths), naive_mask);
        }
    }

    /// The scalar-fallback guarantee: when the `simd` feature is on,
    /// both lane implementations are compiled and must agree bit-for-bit
    /// on every input (the default build compiles only the scalar form,
    /// where agreement is definitional).
    #[cfg(feature = "simd")]
    #[test]
    fn simd_chunks_match_scalar_chunks() {
        use crate::util::SplitMix64;
        let mut rng = SplitMix64::new(0xCAFE_0004);
        for _ in 0..500 {
            let mut offsets = Vec::with_capacity(CHUNK + 1);
            let mut lengths = Vec::with_capacity(CHUNK + 1);
            let mut cur = rng.gen_range(1 << 40);
            for _ in 0..CHUNK + 1 {
                offsets.push(cur);
                let len = rng.gen_range(1 << 20);
                lengths.push(len);
                cur += len + if rng.gen_bool(0.5) { 0 } else { rng.gen_range(1 << 20) };
            }
            let bound = offsets[4].wrapping_add(rng.gen_range(3)).wrapping_sub(1);
            assert_eq!(
                count_lt_chunk_simd(&offsets, bound),
                count_lt_chunk_scalar(&offsets, bound)
            );
            assert_eq!(
                break_mask_chunk_simd(&offsets, &lengths),
                break_mask_chunk_scalar(&offsets, &lengths)
            );
        }
    }

    #[test]
    fn gallop_len_stops_where_reference_stops() {
        // Boundary zone: entries sharing the top's offset are decided by
        // the full (off, len, stream, row) tuple, exactly like the
        // per-entry loop.
        let offsets = [10, 20, 30, 30, 30, 40, 50, 60, 70, 80, 90, 95];
        let lengths = [5, 5, 0, 4, 9, 5, 5, 5, 5, 5, 5, 1];
        let n = offsets.len();
        // Top stream is 1; galloping streams 0 and 3 sit on either side
        // of it in the tie-break order (two entries of one stream never
        // coexist in the heap, so s == 1 cannot occur).
        for s in [0usize, 3] {
            for ti in [0usize, 7] {
                for top_off in [5u64, 25, 30, 31, 100, 200] {
                    for top_len in [0u64, 4, 6] {
                        let top = (top_off, top_len, 1usize, ti);
                        let got = gallop_len(&offsets, &lengths, 0, n, s, top);
                        // Per-entry reference: maximal prefix <= top.
                        let mut want = 0usize;
                        while want < n
                            && (offsets[want], lengths[want], s, want) <= top
                        {
                            want += 1;
                        }
                        assert_eq!(
                            got, want,
                            "top {top:?} stream {s}: chunked gallop diverged"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn coalesce_ratio_for_block_pattern() {
        // §V-C: block-partitioned patterns coalesce almost entirely when
        // adjacent ranks land on the same aggregator.
        let streams: Vec<FlatView> = (0..8)
            .map(|r| fv(&[(r * 100, 50), (r * 100 + 50, 50)]))
            .collect();
        let refs: Vec<&FlatView> = streams.iter().collect();
        let merged = merge_views(&refs);
        assert_eq!(merged.len(), 1);
        assert_eq!(merged.total_bytes(), 800);
    }
}
