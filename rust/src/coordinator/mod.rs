//! The paper's contribution: collective-I/O coordination.
//!
//! * [`placement`] — global/local aggregator selection (§IV-A, Fig 1),
//!   including the Cray round-robin policy used as an ablation (§V).
//! * [`filedomain`] — stripe-aligned file-domain partitioning with the
//!   one-aggregator-per-OST mapping (§II, §IV-C).
//! * [`reqcalc`] — `ADIOI_LUSTRE_Calc_my_req` / `ADIOI_Calc_others_req`
//!   equivalents: who sends what to which aggregator in which round.
//! * [`merge`] — k-way heap merge + coalescing of sorted request lists
//!   (the §IV-A/B sort step; native twin of the L1 Pallas kernels).
//! * [`breakdown`] — per-phase timing records matching Figures 4–7.
//! * [`twophase`] — ROMIO's two-phase collective write/read (baseline);
//!   a thin binding of the depth-0 aggregation plan.
//! * [`tam`] — the two-layer aggregation method; a thin binding of the
//!   depth-1 (node-level) aggregation plan.
//! * [`tree`] — N-level aggregation trees over the machine hierarchy
//!   (socket → node → switch group), the generic pipeline both of the
//!   above are special cases of.
//! * [`collective`] — the public entry points dispatching on algorithm.
//! * [`plancache`] — the plan oracle: fingerprint, LRU-cache, and
//!   persist [`plancache::CollectivePlan`]s so repeated collectives
//!   skip setup entirely (construct-once/execute-many).
//! * [`autotune`] — `--algorithm auto`: enumerate a bounded
//!   [`tree::TreeSpec`] × placement candidate grid, price each with a
//!   metadata-only predictor over the same α–β/CPU/IO models the
//!   executor charges, and pick the minimum.

pub mod autotune;
pub mod breakdown;
pub mod collective;
pub mod filedomain;
pub mod merge;
pub mod placement;
pub mod plancache;
pub mod reqcalc;
pub mod tam;
pub mod tree;
pub mod twophase;
