//! Aggregator selection and placement policies.
//!
//! Global aggregators: ROMIO-on-Lustre picks `P_G = stripe_count`
//! aggregators; when there are at least `P_G` nodes they are spread one per
//! node (evenly across nodes), otherwise nodes receive them round-robin.
//! The paper additionally describes (and we implement as an ablation) the
//! Cray MPI policy that round-robins *across* nodes picking successive
//! local slots (ranks 0, 64, 1, 65 in their 2-node/64-ppn example).
//!
//! Local aggregators (§IV-A): on a node with `q` processes and `c` local
//! aggregators, with `e = q mod c`, the chosen local rank ids are
//! `ceil(q/c)·i` for `i in 0..e` and `ceil(q/c)·e + floor(q/c)·(i-e)` for
//! `i in e..c` — evenly spread.  Each local aggregator serves the ranks
//! from itself up to (not including) the next local aggregator.

use crate::cluster::Topology;

/// Global-aggregator placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalPlacement {
    /// ROMIO: spread aggregators evenly across nodes (the paper's tuned
    /// baseline).
    Spread,
    /// Cray MPI: round-robin across nodes, successive local slots
    /// (0, ppn, 1, ppn+1, … in rank terms).
    CrayRoundRobin,
}

/// Select the `n_agg` global aggregator ranks under a policy.
pub fn select_global_aggregators(
    topo: &Topology,
    n_agg: usize,
    policy: GlobalPlacement,
) -> Vec<usize> {
    let p = topo.nprocs();
    let n_agg = n_agg.min(p);
    match policy {
        GlobalPlacement::Spread => {
            if n_agg <= topo.nodes {
                // One aggregator on a subset of nodes, nodes evenly spaced,
                // first local rank of each chosen node.
                (0..n_agg)
                    .map(|i| topo.rank_of(i * topo.nodes / n_agg, 0))
                    .collect()
            } else {
                // More aggregators than nodes: distribute per node, local
                // slots evenly spread within each node.
                let base = n_agg / topo.nodes;
                let extra = n_agg % topo.nodes;
                let mut out = Vec::with_capacity(n_agg);
                for node in 0..topo.nodes {
                    let c = base + usize::from(node < extra);
                    for local in select_local_aggregators_on_node(topo.ppn, c) {
                        out.push(topo.rank_of(node, local));
                    }
                }
                out.sort_unstable();
                out
            }
        }
        GlobalPlacement::CrayRoundRobin => {
            // slot-major round robin: (node 0, slot 0), (node 1, slot 0), …
            // then slot 1, matching "0, 64, 1, 65".
            let mut out = Vec::with_capacity(n_agg);
            let mut slot = 0;
            'outer: loop {
                for node in 0..topo.nodes {
                    if out.len() == n_agg {
                        break 'outer;
                    }
                    out.push(topo.rank_of(node, slot));
                }
                slot += 1;
                if slot >= topo.ppn {
                    break;
                }
            }
            out
        }
    }
}

/// §IV-A local-aggregator selection on one node: local rank ids of the
/// `c` local aggregators among `q` processes.
pub fn select_local_aggregators_on_node(q: usize, c: usize) -> Vec<usize> {
    let c = c.clamp(1, q);
    let e = q % c;
    let ceil = q.div_ceil(c);
    let floor = q / c;
    (0..c)
        .map(|i| if i < e { ceil * i } else { ceil * e + floor * (i - e) })
        .collect()
}

/// Complete local-aggregator layout across the cluster.
#[derive(Clone, Debug)]
pub struct LocalAggregators {
    /// Global ranks of all local aggregators, ascending.
    pub ranks: Vec<usize>,
    /// For every rank, the local aggregator it sends to.
    pub assignment: Vec<usize>,
}

/// Select `c` local aggregators per node and assign every rank to one.
///
/// A local aggregator serves ranks from itself up to (not including) the
/// next local aggregator on the node (§IV-A's `c=2, q=5 → {r0,r1,r2},
/// {r3,r4}` example).
pub fn select_local_aggregators(topo: &Topology, c: usize) -> LocalAggregators {
    let locals = select_local_aggregators_on_node(topo.ppn, c);
    let mut ranks = Vec::with_capacity(topo.nodes * locals.len());
    let mut assignment = vec![0usize; topo.nprocs()];
    for node in 0..topo.nodes {
        for (i, &l) in locals.iter().enumerate() {
            let agg_rank = topo.rank_of(node, l);
            ranks.push(agg_rank);
            let next = locals.get(i + 1).copied().unwrap_or(topo.ppn);
            for local in l..next {
                assignment[topo.rank_of(node, local)] = agg_rank;
            }
        }
        // Ranks before the first local aggregator (possible only when the
        // formula's first id > 0 — it never is, ceil*0 == 0) — guarded by
        // debug assert.
        debug_assert_eq!(locals[0], 0);
    }
    LocalAggregators { ranks, assignment }
}

impl LocalAggregators {
    /// Number of local aggregators `P_L`.
    pub fn count(&self) -> usize {
        self.ranks.len()
    }

    /// Ranks served by aggregator `agg` (including itself).
    pub fn members_of(&self, agg: usize) -> Vec<usize> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|&(_, &a)| a == agg)
            .map(|(r, _)| r)
            .collect()
    }
}

/// Derive the per-node local aggregator count `c` from a target total
/// `P_L` (the paper tunes total `P_L`, e.g. 256, across all nodes).
pub fn per_node_count_for_total(topo: &Topology, total_pl: usize) -> usize {
    (total_pl.div_ceil(topo.nodes)).clamp(1, topo.ppn)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_c2_q5() {
        // §IV-A: c=2, q=5 → aggregators r0 and r3; groups {0,1,2}, {3,4}.
        assert_eq!(select_local_aggregators_on_node(5, 2), vec![0, 3]);
        let topo = Topology::new(1, 5);
        let la = select_local_aggregators(&topo, 2);
        assert_eq!(la.ranks, vec![0, 3]);
        assert_eq!(la.members_of(0), vec![0, 1, 2]);
        assert_eq!(la.members_of(3), vec![3, 4]);
    }

    #[test]
    fn paper_fig1a_four_locals_of_eight() {
        // Fig 1(a): 8 procs/node, 4 local aggregators per node → evenly
        // spread: local ids 0, 2, 4, 6.
        assert_eq!(select_local_aggregators_on_node(8, 4), vec![0, 2, 4, 6]);
    }

    #[test]
    fn local_selection_degenerate_cases() {
        assert_eq!(select_local_aggregators_on_node(4, 1), vec![0]);
        assert_eq!(select_local_aggregators_on_node(4, 4), vec![0, 1, 2, 3]);
        // c > q clamps to q.
        assert_eq!(select_local_aggregators_on_node(3, 7), vec![0, 1, 2]);
    }

    #[test]
    fn local_ids_strictly_increasing_and_in_range() {
        for q in 1..40 {
            for c in 1..=q {
                let ids = select_local_aggregators_on_node(q, c);
                assert_eq!(ids.len(), c);
                assert!(ids.windows(2).all(|w| w[0] < w[1]), "q={q} c={c} ids={ids:?}");
                assert!(ids.iter().all(|&i| i < q));
                assert_eq!(ids[0], 0);
            }
        }
    }

    #[test]
    fn every_rank_assigned_to_its_nodes_aggregator() {
        let topo = Topology::new(3, 8);
        let la = select_local_aggregators(&topo, 3);
        assert_eq!(la.count(), 9);
        for r in 0..topo.nprocs() {
            let a = la.assignment[r];
            assert!(topo.same_node(r, a), "rank {r} assigned off-node agg {a}");
            assert!(a <= r, "aggregator must not have higher rank than member");
        }
    }

    #[test]
    fn spread_one_per_node_when_enough_nodes() {
        let topo = Topology::new(8, 4);
        let g = select_global_aggregators(&topo, 4, GlobalPlacement::Spread);
        assert_eq!(g, vec![0, 8, 16, 24]); // nodes 0, 2, 4, 6
        let nodes: Vec<usize> = g.iter().map(|&r| topo.node_of(r)).collect();
        assert_eq!(nodes, vec![0, 2, 4, 6]);
    }

    #[test]
    fn spread_multiple_per_node_when_fewer_nodes() {
        let topo = Topology::new(2, 8);
        let g = select_global_aggregators(&topo, 4, GlobalPlacement::Spread);
        assert_eq!(g.len(), 4);
        // Two per node, spread within node.
        assert_eq!(g, vec![0, 4, 8, 12]);
    }

    #[test]
    fn cray_round_robin_matches_paper_example() {
        // 2 nodes × 64 ppn, 4 aggregators → ranks 0, 64, 1, 65.
        let topo = Topology::new(2, 64);
        let g = select_global_aggregators(&topo, 4, GlobalPlacement::CrayRoundRobin);
        assert_eq!(g, vec![0, 64, 1, 65]);
    }

    #[test]
    fn global_count_clamped_to_p() {
        let topo = Topology::new(2, 2);
        let g = select_global_aggregators(&topo, 100, GlobalPlacement::Spread);
        assert_eq!(g.len(), 4);
    }

    #[test]
    fn per_node_count_from_total() {
        let topo = Topology::new(256, 64);
        assert_eq!(per_node_count_for_total(&topo, 256), 1);
        let topo4 = Topology::new(4, 64);
        assert_eq!(per_node_count_for_total(&topo4, 256), 64);
        // Clamped to ppn.
        let topo2 = Topology::new(2, 4);
        assert_eq!(per_node_count_for_total(&topo2, 1000), 4);
    }
}
