//! Aggregator selection and placement policies.
//!
//! Global aggregators: ROMIO-on-Lustre picks `P_G = stripe_count`
//! aggregators; when there are at least `P_G` nodes they are spread one per
//! node (evenly across nodes), otherwise nodes receive them round-robin.
//! The paper additionally describes (and we implement as an ablation) the
//! Cray MPI policy that round-robins *across* nodes picking successive
//! local slots (ranks 0, 64, 1, 65 in their 2-node/64-ppn example).
//!
//! Local aggregators (§IV-A): on a node with `q` processes and `c` local
//! aggregators, with `e = q mod c`, the chosen local rank ids are
//! `ceil(q/c)·i` for `i in 0..e` and `ceil(q/c)·e + floor(q/c)·(i-e)` for
//! `i in e..c` — evenly spread.  Each local aggregator serves the ranks
//! from itself up to (not including) the next local aggregator.
//!
//! The same §IV-A selection rule generalizes to every level of the machine
//! hierarchy ([`select_level_aggregators`]): within each group of a level
//! (socket, node, or switch group), the members participating at that
//! level — all ranks at the innermost level, the previous level's
//! aggregators above it — elect evenly-spread aggregators by *position* in
//! the ascending member list.  A chain of [`LevelAggregators`] is an
//! N-level aggregation tree; the node-only chain is exactly the paper's
//! TAM selection, and the empty chain is two-phase I/O.

use crate::cluster::{LevelKind, Topology};

/// Global-aggregator placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlobalPlacement {
    /// ROMIO: spread aggregators evenly across nodes (the paper's tuned
    /// baseline).
    Spread,
    /// Cray MPI: round-robin across nodes, successive local slots
    /// (0, ppn, 1, ppn+1, … in rank terms).
    CrayRoundRobin,
}

/// Select the `n_agg` global aggregator ranks under a policy.
pub fn select_global_aggregators(
    topo: &Topology,
    n_agg: usize,
    policy: GlobalPlacement,
) -> Vec<usize> {
    let p = topo.nprocs();
    let n_agg = n_agg.min(p);
    match policy {
        GlobalPlacement::Spread => {
            if n_agg <= topo.nodes {
                // One aggregator on a subset of nodes, nodes evenly spaced,
                // first local rank of each chosen node.
                (0..n_agg)
                    .map(|i| topo.rank_of(i * topo.nodes / n_agg, 0))
                    .collect()
            } else {
                // More aggregators than nodes: distribute per node, local
                // slots evenly spread within each node.
                let base = n_agg / topo.nodes;
                let extra = n_agg % topo.nodes;
                let mut out = Vec::with_capacity(n_agg);
                for node in 0..topo.nodes {
                    let c = base + usize::from(node < extra);
                    for local in select_local_aggregators_on_node(topo.ppn, c) {
                        out.push(topo.rank_of(node, local));
                    }
                }
                out.sort_unstable();
                out
            }
        }
        GlobalPlacement::CrayRoundRobin => {
            // slot-major round robin: (node 0, slot 0), (node 1, slot 0), …
            // then slot 1, matching "0, 64, 1, 65".
            let mut out = Vec::with_capacity(n_agg);
            let mut slot = 0;
            'outer: loop {
                for node in 0..topo.nodes {
                    if out.len() == n_agg {
                        break 'outer;
                    }
                    out.push(topo.rank_of(node, slot));
                }
                slot += 1;
                if slot >= topo.ppn {
                    break;
                }
            }
            out
        }
    }
}

/// §IV-A local-aggregator selection on one node: local rank ids of the
/// `c` local aggregators among `q` processes.
pub fn select_local_aggregators_on_node(q: usize, c: usize) -> Vec<usize> {
    let c = c.clamp(1, q);
    let e = q % c;
    let ceil = q.div_ceil(c);
    let floor = q / c;
    (0..c)
        .map(|i| if i < e { ceil * i } else { ceil * e + floor * (i - e) })
        .collect()
}

/// Complete local-aggregator layout across the cluster.
#[derive(Clone, Debug)]
pub struct LocalAggregators {
    /// Global ranks of all local aggregators, ascending.
    pub ranks: Vec<usize>,
    /// For every rank, the local aggregator it sends to.
    pub assignment: Vec<usize>,
}

/// Select `c` local aggregators per node and assign every rank to one.
///
/// A local aggregator serves ranks from itself up to (not including) the
/// next local aggregator on the node (§IV-A's `c=2, q=5 → {r0,r1,r2},
/// {r3,r4}` example).  Thin uniform-count binding of the generic
/// [`select_level_aggregators`] at the node level.
pub fn select_local_aggregators(topo: &Topology, c: usize) -> LocalAggregators {
    let members: Vec<usize> = (0..topo.nprocs()).collect();
    let counts = vec![c; topo.nodes];
    let level = select_level_aggregators(topo, LevelKind::Node, &members, &counts);
    LocalAggregators { ranks: level.ranks, assignment: level.assignment }
}

/// Aggregator selection at one level of an aggregation tree: the chosen
/// aggregator ranks plus the member → aggregator assignment.  A chain of
/// these (innermost level first) is an
/// [`AggregationPlan`](crate::coordinator::tree::AggregationPlan).
#[derive(Clone, Debug)]
pub struct LevelAggregators {
    /// Hierarchy level this selection was made at.
    pub kind: LevelKind,
    /// Global ranks of this level's aggregators, ascending.
    pub ranks: Vec<usize>,
    /// For every *member* rank of this level: the aggregator it forwards
    /// to (dense by global rank; non-members hold `usize::MAX`).
    pub assignment: Vec<usize>,
}

impl LevelAggregators {
    /// Number of aggregators at this level.
    pub fn count(&self) -> usize {
        self.ranks.len()
    }

    /// The aggregator serving member `rank` at this level.
    ///
    /// # Panics
    ///
    /// Debug-asserts that `rank` participates at this level.
    pub fn parent_of(&self, rank: usize) -> usize {
        let a = self.assignment[rank];
        debug_assert_ne!(a, usize::MAX, "rank {rank} is not a member at the {} level", self.kind);
        a
    }
}

/// §IV-A selection generalized to any hierarchy level: within each group
/// of `kind`, the participating `members` (ascending global ranks) elect
/// `counts[group]` aggregators — evenly spread by *position* in the
/// group's member list, so the node level over the full rank set
/// reproduces [`select_local_aggregators`] exactly.  Each member is
/// assigned to the chosen member at or below its own position; aggregators
/// of empty groups do not exist (a group only appears in the tree when
/// someone forwards through it).
pub fn select_level_aggregators(
    topo: &Topology,
    kind: LevelKind,
    members: &[usize],
    counts: &[usize],
) -> LevelAggregators {
    let n_groups = topo.n_groups(kind);
    debug_assert_eq!(counts.len(), n_groups, "one aggregator count per {kind} group");
    debug_assert!(members.windows(2).all(|w| w[0] < w[1]), "members must be ascending");
    // Bucket members by group, preserving ascending rank order.
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
    for &r in members {
        groups[topo.group_of(kind, r)].push(r);
    }
    let mut ranks = Vec::new();
    let mut assignment = vec![usize::MAX; topo.nprocs()];
    for (g, ms) in groups.iter().enumerate() {
        if ms.is_empty() {
            continue;
        }
        let chosen = select_local_aggregators_on_node(ms.len(), counts[g]);
        for (i, &pos) in chosen.iter().enumerate() {
            let agg = ms[pos];
            ranks.push(agg);
            let next = chosen.get(i + 1).copied().unwrap_or(ms.len());
            for &m in &ms[pos..next] {
                assignment[m] = agg;
            }
        }
    }
    // Groups are not rank-contiguous under round-robin placement; the
    // ascending-rank invariant is restored here.
    ranks.sort_unstable();
    LevelAggregators { kind, ranks, assignment }
}

impl LocalAggregators {
    /// Number of local aggregators `P_L`.
    pub fn count(&self) -> usize {
        self.ranks.len()
    }

    /// Ranks served by aggregator `agg` (including itself).
    pub fn members_of(&self, agg: usize) -> Vec<usize> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|&(_, &a)| a == agg)
            .map(|(r, _)| r)
            .collect()
    }
}

/// Derive per-node local aggregator counts from a target total `P_L` (the
/// paper tunes total `P_L`, e.g. 256, across all nodes).
///
/// Totals that do not divide evenly are *distributed*: the first
/// `P_L mod nodes` nodes get one extra aggregator, so the counts sum to
/// `P_L` whenever `nodes ≤ P_L ≤ P` (the pre-fix `ceil` rounding silently
/// inflated the total on every node).  Each count is clamped to
/// `1..=ppn` — a node always has at least one aggregator and never more
/// than its ranks.
pub fn per_node_counts_for_total(topo: &Topology, total_pl: usize) -> Vec<usize> {
    let base = total_pl / topo.nodes;
    let extra = total_pl % topo.nodes;
    (0..topo.nodes)
        .map(|n| (base + usize::from(n < extra)).clamp(1, topo.ppn))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_c2_q5() {
        // §IV-A: c=2, q=5 → aggregators r0 and r3; groups {0,1,2}, {3,4}.
        assert_eq!(select_local_aggregators_on_node(5, 2), vec![0, 3]);
        let topo = Topology::new(1, 5);
        let la = select_local_aggregators(&topo, 2);
        assert_eq!(la.ranks, vec![0, 3]);
        assert_eq!(la.members_of(0), vec![0, 1, 2]);
        assert_eq!(la.members_of(3), vec![3, 4]);
    }

    #[test]
    fn paper_fig1a_four_locals_of_eight() {
        // Fig 1(a): 8 procs/node, 4 local aggregators per node → evenly
        // spread: local ids 0, 2, 4, 6.
        assert_eq!(select_local_aggregators_on_node(8, 4), vec![0, 2, 4, 6]);
    }

    #[test]
    fn local_selection_degenerate_cases() {
        assert_eq!(select_local_aggregators_on_node(4, 1), vec![0]);
        assert_eq!(select_local_aggregators_on_node(4, 4), vec![0, 1, 2, 3]);
        // c > q clamps to q.
        assert_eq!(select_local_aggregators_on_node(3, 7), vec![0, 1, 2]);
    }

    #[test]
    fn local_ids_strictly_increasing_and_in_range() {
        for q in 1..40 {
            for c in 1..=q {
                let ids = select_local_aggregators_on_node(q, c);
                assert_eq!(ids.len(), c);
                assert!(ids.windows(2).all(|w| w[0] < w[1]), "q={q} c={c} ids={ids:?}");
                assert!(ids.iter().all(|&i| i < q));
                assert_eq!(ids[0], 0);
            }
        }
    }

    #[test]
    fn every_rank_assigned_to_its_nodes_aggregator() {
        let topo = Topology::new(3, 8);
        let la = select_local_aggregators(&topo, 3);
        assert_eq!(la.count(), 9);
        for r in 0..topo.nprocs() {
            let a = la.assignment[r];
            assert!(topo.same_node(r, a), "rank {r} assigned off-node agg {a}");
            assert!(a <= r, "aggregator must not have higher rank than member");
        }
    }

    #[test]
    fn spread_one_per_node_when_enough_nodes() {
        let topo = Topology::new(8, 4);
        let g = select_global_aggregators(&topo, 4, GlobalPlacement::Spread);
        assert_eq!(g, vec![0, 8, 16, 24]); // nodes 0, 2, 4, 6
        let nodes: Vec<usize> = g.iter().map(|&r| topo.node_of(r)).collect();
        assert_eq!(nodes, vec![0, 2, 4, 6]);
    }

    #[test]
    fn spread_multiple_per_node_when_fewer_nodes() {
        let topo = Topology::new(2, 8);
        let g = select_global_aggregators(&topo, 4, GlobalPlacement::Spread);
        assert_eq!(g.len(), 4);
        // Two per node, spread within node.
        assert_eq!(g, vec![0, 4, 8, 12]);
    }

    #[test]
    fn cray_round_robin_matches_paper_example() {
        // 2 nodes × 64 ppn, 4 aggregators → ranks 0, 64, 1, 65.
        let topo = Topology::new(2, 64);
        let g = select_global_aggregators(&topo, 4, GlobalPlacement::CrayRoundRobin);
        assert_eq!(g, vec![0, 64, 1, 65]);
    }

    #[test]
    fn global_count_clamped_to_p() {
        let topo = Topology::new(2, 2);
        let g = select_global_aggregators(&topo, 100, GlobalPlacement::Spread);
        assert_eq!(g.len(), 4);
    }

    #[test]
    fn per_node_counts_from_total() {
        let topo = Topology::new(256, 64);
        assert_eq!(per_node_counts_for_total(&topo, 256), vec![1; 256]);
        let topo4 = Topology::new(4, 64);
        assert_eq!(per_node_counts_for_total(&topo4, 256), vec![64; 4]);
        // Clamped to ppn.
        let topo2 = Topology::new(2, 4);
        assert_eq!(per_node_counts_for_total(&topo2, 1000), vec![4, 4]);
    }

    #[test]
    fn per_node_counts_distribute_uneven_totals() {
        // Regression (§Satellite): totals that don't divide by `nodes`
        // must be distributed, not ceil-rounded on every node.
        let topo = Topology::new(3, 8);
        // Pre-fix: ceil(7/3) = 3 on every node → 9 total.  Fixed: 3+2+2.
        assert_eq!(per_node_counts_for_total(&topo, 7), vec![3, 2, 2]);
        assert_eq!(per_node_counts_for_total(&topo, 7).iter().sum::<usize>(), 7);
        // The paper's P_L=256 on 3 nodes of 128: 86+85+85 = 256 exactly.
        let big = Topology::new(3, 128);
        let counts = per_node_counts_for_total(&big, 256);
        assert_eq!(counts, vec![86, 85, 85]);
        assert_eq!(counts.iter().sum::<usize>(), 256);
        // Below one per node: clamped up (the floor the tree needs).
        assert_eq!(per_node_counts_for_total(&topo, 2), vec![1, 1, 1]);
    }

    #[test]
    fn level_selection_at_node_level_matches_local_selection() {
        use crate::cluster::LevelKind;
        for (nodes, ppn, c) in [(3usize, 8usize, 3usize), (2, 5, 2), (4, 4, 1)] {
            let topo = Topology::new(nodes, ppn);
            let members: Vec<usize> = (0..topo.nprocs()).collect();
            let level = select_level_aggregators(
                &topo,
                LevelKind::Node,
                &members,
                &vec![c; topo.nodes],
            );
            let local = select_local_aggregators(&topo, c);
            assert_eq!(level.ranks, local.ranks);
            assert_eq!(level.assignment, local.assignment);
            assert_eq!(level.count(), local.count());
            for r in 0..topo.nprocs() {
                assert_eq!(level.parent_of(r), local.assignment[r]);
            }
        }
    }

    #[test]
    fn level_selection_over_sparse_members() {
        use crate::cluster::LevelKind;
        // Second-level selection: only the first-level aggregators
        // participate.  2 nodes × 8 ppn, members = 4 per node.
        let topo = Topology::new(2, 8);
        let members = vec![0usize, 2, 4, 6, 8, 10, 12, 14];
        let level =
            select_level_aggregators(&topo, LevelKind::Node, &members, &[2, 1]);
        // Node 0: positions {0, 2} of [0,2,4,6] → ranks 0 and 4.
        // Node 1: position 0 of [8,10,12,14] → rank 8.
        assert_eq!(level.ranks, vec![0, 4, 8]);
        assert_eq!(level.assignment[0], 0);
        assert_eq!(level.assignment[2], 0);
        assert_eq!(level.assignment[4], 4);
        assert_eq!(level.assignment[6], 4);
        assert_eq!(level.assignment[8], 8);
        assert_eq!(level.assignment[14], 8);
        // Non-members stay unassigned at this level.
        assert_eq!(level.assignment[1], usize::MAX);
        assert_eq!(level.assignment[15], usize::MAX);
    }

    #[test]
    fn level_selection_socket_level_round_robin() {
        use crate::cluster::{LevelKind, RankPlacement};
        // 1 node × 8 ppn, 2 sockets, round-robin: socket 0 = {0,2,4,6},
        // socket 1 = {1,3,5,7}; one aggregator each → ranks 0 and 1.
        let topo = Topology::hierarchical(1, 8, 2, 0, RankPlacement::RoundRobin);
        let members: Vec<usize> = (0..8).collect();
        let level = select_level_aggregators(&topo, LevelKind::Socket, &members, &[1, 1]);
        assert_eq!(level.ranks, vec![0, 1]);
        for r in 0..8 {
            assert_eq!(level.assignment[r], r % 2);
            assert!(topo.same_socket(r, level.assignment[r]));
        }
    }

    #[test]
    fn level_selection_skips_empty_groups() {
        use crate::cluster::LevelKind;
        let topo = Topology::new(3, 4);
        // No members on node 1: it elects no aggregator.
        let members = vec![0usize, 1, 8, 9, 10];
        let level = select_level_aggregators(&topo, LevelKind::Node, &members, &[1, 1, 1]);
        assert_eq!(level.ranks, vec![0, 8]);
        assert!(level.assignment[4..8].iter().all(|&a| a == usize::MAX));
    }
}
