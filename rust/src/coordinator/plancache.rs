//! Plan oracle: fingerprint, cache, and persist collective aggregation
//! plans so steady-state setup cost amortizes to zero.
//!
//! Checkpoint loops repeat the *same* (flatview, topology, striping,
//! tree) collective thousands of times, yet every `run_collective_*`
//! call used to re-run file-domain partitioning, aggregator selection
//! and `calc_my_req`'s two-pass CSR build from scratch.  This module is
//! the construct-once/execute-many split ROMIO pioneered for
//! noncontiguous access handling (Thakur et al.), made first-class:
//!
//! * [`fingerprint_collective`] — a stable 128-bit structural hash over
//!   everything that shapes a plan: requester views (offsets/lengths),
//!   [`Topology`] shape + rank placement, striping, algorithm
//!   (tree spec / `P_L`), global-aggregator policy and count, and
//!   direction.  Cost-model parameters (`NetParams`, `CpuModel`,
//!   `IoModel`) and the sort engine are deliberately *excluded*: they
//!   only affect simulated times, which [`execute_exchange`] computes
//!   from `ctx` at execution time — never plan structure — so one plan
//!   serves every calibration.
//! * [`CollectivePlan`] — the immutable artifact: the resolved
//!   [`AggregationPlan`] level chain plus the top-tier [`ExchangePlan`]
//!   (file domains, global aggregator ranks, round index, per-requester
//!   classified CSR slabs).  No payload lives in a plan.
//! * [`PlanCache`] — an LRU of warm plans living beside the
//!   [`ExchangeArena`]: a hit performs zero plan-construction work (one
//!   fingerprint + a linear probe of at most `capacity` entries).  With
//!   a directory attached (`--plan-cache <dir>`), misses
//!   load-or-build-and-store through a versioned on-disk format;
//!   corrupt, truncated or stale files are rejected gracefully
//!   (counted, logged, rebuilt) — never trusted into a panic.
//!
//! [`run_collective_write_cached`] / [`run_collective_read_cached`] are
//! the drop-in cached twins of the `run_collective_*_with` entry
//! points; DESIGN.md §Plan cache documents the fingerprint fields,
//! invalidation rules and the on-disk format.

use std::path::{Path, PathBuf};

use crate::cluster::{LevelKind, RankPlacement, Topology};
use crate::coordinator::collective::{
    build_exchange_plan, Algorithm, CollectiveOutcome, Direction, ExchangeArena, ExchangePlan,
    PlannedRequester,
};
use crate::coordinator::filedomain::FileDomains;
use crate::coordinator::merge::{ReqBatch, RoundScratch};
use crate::coordinator::placement::{GlobalPlacement, LevelAggregators};
use crate::coordinator::reqcalc::MyReqs;
use crate::coordinator::tree::{
    aggregate_level_read_views, tree_read_with, tree_write_with, AggregationPlan, TreeSpec,
};
use crate::coordinator::twophase::CollectiveCtx;
use crate::error::{Error, Result};
use crate::faults::{FaultPlan, Sel};
use crate::lustre::{LustreConfig, LustreFile};
use crate::mpisim::FlatView;
use crate::util::rng::SplitMix64;

// ---------------------------------------------------------------------------
// Fingerprint
// ---------------------------------------------------------------------------

/// A 128-bit structural fingerprint — the plan-cache key.  Displayed as
/// 32 hex digits (also the on-disk file-name stem).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fp128 {
    /// Low 64 bits.
    pub lo: u64,
    /// High 64 bits.
    pub hi: u64,
}

impl std::fmt::Display for Fp128 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}{:016x}", self.hi, self.lo)
    }
}

impl Fp128 {
    /// Salt the fingerprint with a fault-epoch tag
    /// ([`crate::faults::FaultPlan::cache_salt`]): degraded (repaired)
    /// plans are keyed apart from fault-free ones so neither can serve
    /// the other.  Salt 0 is reserved for "no faults" and is the
    /// identity.
    pub fn salted(self, salt: u64) -> Fp128 {
        if salt == 0 {
            return self;
        }
        Fp128 { lo: self.lo ^ salt, hi: self.hi ^ splitmix_mix(salt) }
    }
}

/// `splitmix64` finalizer: a full-avalanche 64-bit mix.
fn splitmix_mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Two-lane streaming hasher over `u64` words: an FNV-1a lane and a
/// splitmix-mixed accumulator lane, cross-folded at the end.  Both lanes
/// are order-sensitive and the finisher folds the word count, so
/// permuted or truncated streams diverge.  Hand-rolled (no external
/// hashing crates) and stable across runs and platforms — unlike
/// `std::hash`, whose `SipHash` keys are process-random.
#[derive(Clone, Copy, Debug)]
pub struct FpHasher {
    a: u64,
    b: u64,
    len: u64,
}

impl FpHasher {
    /// Start a stream under a domain tag (namespaces unrelated uses).
    pub fn new(tag: &str) -> Self {
        let mut h = FpHasher { a: 0xcbf2_9ce4_8422_2325, b: 0x9E37_79B9_7F4A_7C15, len: 0 };
        for byte in tag.bytes() {
            h.write_u64(byte as u64);
        }
        h
    }

    /// Fold one word into both lanes.
    pub fn write_u64(&mut self, w: u64) {
        self.a = (self.a ^ w).wrapping_mul(0x0000_0100_0000_01B3);
        self.b = self.b.wrapping_add(splitmix_mix(w ^ self.b));
        self.len = self.len.wrapping_add(1);
    }

    /// Fold a word slice.
    pub fn write_u64s(&mut self, ws: &[u64]) {
        for &w in ws {
            self.write_u64(w);
        }
    }

    /// Finish the stream into a 128-bit fingerprint.
    pub fn finish(self) -> Fp128 {
        let lo = splitmix_mix(self.a ^ self.b.rotate_left(32) ^ self.len);
        let hi = splitmix_mix(self.b ^ self.a.rotate_left(17) ^ !self.len);
        Fp128 { lo, hi }
    }
}

fn rank_placement_disc(p: RankPlacement) -> u64 {
    match p {
        RankPlacement::Block => 0,
        RankPlacement::RoundRobin => 1,
    }
}

fn global_placement_disc(p: GlobalPlacement) -> u64 {
    match p {
        GlobalPlacement::Spread => 0,
        GlobalPlacement::CrayRoundRobin => 1,
    }
}

/// Fingerprint one collective: every structural input that shapes the
/// plan, and nothing that doesn't (see the module docs for the
/// exclusion rationale).  Takes the requester views as an iterator so
/// steady-state callers hash straight out of their batch list without
/// collecting — the warm path allocates nothing.
pub fn fingerprint_collective<'a>(
    ctx: &CollectiveCtx,
    algo: &Algorithm,
    direction: Direction,
    file_cfg: &LustreConfig,
    views: impl Iterator<Item = (usize, &'a FlatView)>,
) -> Fp128 {
    let mut h = FpHasher::new("tamio-collective-plan-v1");
    // Topology shape + rank placement.
    h.write_u64(ctx.topo.nodes as u64);
    h.write_u64(ctx.topo.ppn as u64);
    h.write_u64(ctx.topo.sockets_per_node as u64);
    h.write_u64(ctx.topo.nodes_per_switch as u64);
    h.write_u64(rank_placement_disc(ctx.topo.placement));
    // Global-aggregator policy and count; striping.
    h.write_u64(global_placement_disc(ctx.placement));
    h.write_u64(ctx.n_global_agg as u64);
    h.write_u64(file_cfg.stripe_size);
    h.write_u64(file_cfg.stripe_count as u64);
    // Algorithm (discriminant + every structural parameter).
    match algo {
        Algorithm::TwoPhase => h.write_u64(0),
        Algorithm::Tam(t) => {
            h.write_u64(1);
            h.write_u64(t.total_local_aggregators as u64);
        }
        Algorithm::Tree(spec) => {
            h.write_u64(2);
            h.write_u64(spec.per_socket as u64);
            h.write_u64(spec.per_node as u64);
            h.write_u64(spec.per_switch as u64);
        }
        // Auto never reaches plan construction (drivers resolve it to a
        // concrete `Tree` first), but it still needs a distinct
        // discriminant so a hypothetical key can't alias a real one.
        Algorithm::Auto => h.write_u64(3),
    }
    h.write_u64(match direction {
        Direction::Write => 0,
        Direction::Read => 1,
    });
    // Requester views: rank, entry count, then the flattened
    // offset/length words (order-sensitive — views are positional).
    for (rank, view) in views {
        h.write_u64(rank as u64);
        h.write_u64(view.len() as u64);
        h.write_u64s(view.offsets());
        h.write_u64s(view.lengths());
    }
    h.finish()
}

// ---------------------------------------------------------------------------
// The plan artifact
// ---------------------------------------------------------------------------

/// The immutable, executable artifact of one collective's setup: the
/// resolved aggregation-tree level chain and the top-tier exchange plan.
/// Carries no payload and borrows nothing — safe to cache, serialize,
/// and execute any number of times against fresh per-call payloads.
#[derive(Debug)]
pub struct CollectivePlan {
    /// The structural fingerprint this plan was built under.
    pub fingerprint: Fp128,
    /// Rank count of the topology the plan was built for (bounds every
    /// rank index inside; revalidated on load and on execution).
    pub nprocs: usize,
    /// The resolved aggregation-tree level chain.
    pub agg: AggregationPlan,
    /// The top-tier inter-node exchange plan.
    pub exchange: ExchangePlan,
}

/// Build a [`CollectivePlan`] from the original requester views — the
/// full setup work a cache hit skips.
///
/// The member views are folded up the tree with *metadata-only* merges
/// ([`aggregate_level_read_views`]): `merge_meta` and `merge_scatter`
/// share one merge kernel, so the top tier produced here is exactly the
/// tier the write path's payload aggregation produces — for either
/// direction, the exchange plan below it classifies the same views the
/// executor will present.  On reads, self-overlapping top-tier views
/// are replaced by their disjoint union, mirroring the executor's
/// preparation step.
pub fn build_collective_plan(
    ctx: &CollectiveCtx,
    algo: &Algorithm,
    direction: Direction,
    views: &[(usize, FlatView)],
    file_cfg: &LustreConfig,
    fingerprint: Fp128,
) -> Result<CollectivePlan> {
    if matches!(algo, Algorithm::Auto) {
        return Err(Error::config(
            "--algorithm auto must be resolved by the driver (experiments::run_direction_*) \
             before plan construction; call tune_collective and pass the chosen Tree spec",
        ));
    }
    let agg = AggregationPlan::for_algorithm(ctx.topo, algo);
    let mut tier: Vec<(usize, FlatView)> = views.to_vec();
    // Throwaway scratch: plan construction is the cold path by
    // definition; the executor's arena slots stay untouched.
    let mut slots: Vec<RoundScratch> = Vec::new();
    for level in &agg.levels {
        let stage = aggregate_level_read_views(ctx, level, &tier, &mut slots)?;
        tier = stage.agg_views;
    }
    if direction == Direction::Read {
        for (_, v) in tier.iter_mut() {
            if v.has_overlap() {
                *v = v.disjoint_union();
            }
        }
    }
    let refs: Vec<(usize, &FlatView)> = tier.iter().map(|(r, v)| (*r, v)).collect();
    let exchange = build_exchange_plan(ctx, &refs, file_cfg)?;
    Ok(CollectivePlan { fingerprint, nprocs: ctx.topo.nprocs(), agg, exchange })
}

// ---------------------------------------------------------------------------
// The cache
// ---------------------------------------------------------------------------

/// Hit/load/build accounting of one [`PlanCache`].  The three lookup
/// counters partition: every `get_or_build` call increments exactly one
/// of `hits` (warm in memory), `disk_loads` (valid persisted plan) or
/// `builds` (fresh construction), so `hits + disk_loads + builds` is
/// the total lookup count.  `build_nanos` is *wall-clock* construction
/// time — the only place the cache win shows up besides elapsed time,
/// since all simulated costs (including `Breakdown::plan`) are
/// identical for hit and miss by design.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Warm lookups served without any construction work.
    pub hits: u64,
    /// Lookups that constructed a fresh plan (neither memory nor disk
    /// had it).
    pub builds: u64,
    /// Lookups satisfied by a valid persisted plan.
    pub disk_loads: u64,
    /// Freshly built plans persisted to the cache directory.
    pub disk_stores: u64,
    /// Persisted files rejected (corrupt, truncated, wrong version or
    /// fingerprint) — each one fell back to a rebuild.
    pub rejects: u64,
    /// Wall-clock nanoseconds spent constructing plans on misses.
    pub build_nanos: u64,
}

/// LRU cache of warm [`CollectivePlan`]s, optionally backed by a
/// directory of versioned plan files.  Lives beside the
/// [`ExchangeArena`] in long-running drivers; capacities are small
/// (default 8) because one entry per distinct collective pattern is
/// plenty — checkpoint loops have one.
#[derive(Debug, Default)]
pub struct PlanCache {
    /// `(key, last-use tick, plan)` — linear probe; capacities this
    /// small make a map structure slower, not faster.
    entries: Vec<(Fp128, u64, Box<CollectivePlan>)>,
    capacity: usize,
    tick: u64,
    dir: Option<PathBuf>,
    /// Auto-tuner memo: `(workload/topology fingerprint, winning spec,
    /// winning rank placement)`.  Keyed by [`fingerprint_autotune`]
    /// (which excludes the tuned axes), so a repeated `--algorithm
    /// auto` run skips the candidate sweep entirely; the winner's
    /// executable plan then warms through the normal plan path above.
    /// Memory-only — specs are two words, not worth a disk format.
    ///
    /// [`fingerprint_autotune`]: crate::coordinator::autotune::fingerprint_autotune
    tuner_choices: Vec<(Fp128, TreeSpec, RankPlacement)>,
    /// Running hit/load/build accounting.
    pub stats: PlanCacheStats,
}

impl PlanCache {
    /// A memory-only cache holding at most `capacity` warm plans.
    pub fn in_memory(capacity: usize) -> Self {
        PlanCache { capacity: capacity.max(1), ..PlanCache::default() }
    }

    /// A cache persisting plans under `dir` (created if missing).
    pub fn with_dir(capacity: usize, dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(|e| {
            Error::config(format!(
                "cannot create plan-cache directory '{}': {e}",
                dir.display()
            ))
        })?;
        let mut cache = PlanCache::in_memory(capacity);
        cache.dir = Some(dir);
        Ok(cache)
    }

    /// Number of warm plans currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no plan is warm.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Whether a plan for `fp` is warm (no LRU effect).
    pub fn contains(&self, fp: Fp128) -> bool {
        self.entries.iter().any(|(k, _, _)| *k == fp)
    }

    /// The memoized auto-tuner winner for a workload/topology
    /// fingerprint, if one was remembered this session.
    pub fn tuner_choice(&self, fp: Fp128) -> Option<(TreeSpec, RankPlacement)> {
        self.tuner_choices
            .iter()
            .find(|(k, _, _)| *k == fp)
            .map(|(_, spec, placement)| (*spec, *placement))
    }

    /// Remember the auto-tuner's winning `(spec, placement)` for `fp`,
    /// replacing any earlier choice.  Bounded FIFO (64 entries) — the
    /// memo is a convenience, not a correctness surface.
    pub fn remember_tuner_choice(
        &mut self,
        fp: Fp128,
        spec: TreeSpec,
        placement: RankPlacement,
    ) {
        if let Some(entry) = self.tuner_choices.iter_mut().find(|(k, _, _)| *k == fp) {
            entry.1 = spec;
            entry.2 = placement;
            return;
        }
        if self.tuner_choices.len() >= 64 {
            self.tuner_choices.remove(0);
        }
        self.tuner_choices.push((fp, spec, placement));
    }

    /// The cache's fundamental operation: return the warm plan for
    /// `fp`, else load it from the cache directory, else construct it
    /// with `build` (persisting the result).  The hot path — a hit —
    /// performs one linear probe and a tick bump: zero construction,
    /// zero allocation.
    pub fn get_or_build(
        &mut self,
        fp: Fp128,
        build: impl FnOnce() -> Result<CollectivePlan>,
    ) -> Result<&CollectivePlan> {
        self.tick += 1;
        if let Some(i) = self.entries.iter().position(|(k, _, _)| *k == fp) {
            self.entries[i].1 = self.tick;
            self.stats.hits += 1;
            return Ok(&self.entries[i].2);
        }
        let plan = match self.load_from_disk(fp) {
            Some(plan) => plan,
            None => {
                self.stats.builds += 1;
                let t0 = std::time::Instant::now();
                let plan = build()?;
                self.stats.build_nanos =
                    self.stats.build_nanos.saturating_add(t0.elapsed().as_nanos() as u64);
                if plan.fingerprint != fp {
                    return Err(Error::Protocol(format!(
                        "plan builder returned fingerprint {} for key {fp}",
                        plan.fingerprint
                    )));
                }
                self.store_to_disk(&plan);
                plan
            }
        };
        if self.entries.len() >= self.capacity {
            // Evict the least-recently-used entry.
            if let Some(lru) = (0..self.entries.len()).min_by_key(|&i| self.entries[i].1) {
                self.entries.swap_remove(lru);
            }
        }
        self.entries.push((fp, self.tick, Box::new(plan)));
        let last = self.entries.len() - 1;
        Ok(&self.entries[last].2)
    }

    fn load_from_disk(&mut self, fp: Fp128) -> Option<CollectivePlan> {
        let dir = self.dir.as_deref()?;
        let path = plan_path(dir, fp);
        // A missing file is the normal cold-miss case, not a reject.
        let bytes = std::fs::read(&path).ok()?;
        match decode_plan(&bytes, fp) {
            Ok(plan) => {
                self.stats.disk_loads += 1;
                Some(plan)
            }
            Err(e) => {
                self.stats.rejects += 1;
                eprintln!("plan-cache: rejecting '{}': {e} (rebuilding)", path.display());
                None
            }
        }
    }

    /// Best-effort persistence: a full file appears atomically (write
    /// to a sibling tmp file, then rename), and an unwritable directory
    /// degrades to memory-only caching instead of failing the run.
    fn store_to_disk(&mut self, plan: &CollectivePlan) {
        let Some(dir) = self.dir.as_deref() else { return };
        let path = plan_path(dir, plan.fingerprint);
        let bytes = encode_plan(plan);
        let tmp = path.with_extension("plan.tmp");
        let wrote = std::fs::write(&tmp, &bytes)
            .and_then(|()| std::fs::rename(&tmp, &path));
        match wrote {
            Ok(()) => self.stats.disk_stores += 1,
            Err(e) => eprintln!(
                "plan-cache: could not persist '{}': {e} (continuing in memory)",
                path.display()
            ),
        }
    }
}

/// The persisted-plan path for a fingerprint.
fn plan_path(dir: &Path, fp: Fp128) -> PathBuf {
    dir.join(format!("tamio-plan-{fp}.plan"))
}

// ---------------------------------------------------------------------------
// On-disk format (versioned)
// ---------------------------------------------------------------------------
//
//   magic    8 B   b"TAMPLAN\0"
//   version  4 B   u32 LE (currently 1)
//   fp       16 B  lo, hi as u64 LE
//   body_len 8 B   u64 LE
//   body     …     the plan structure, all integers u64/u32/u8 LE
//   checksum 8 B   FNV-1a over the body bytes
//
// Bumping PLAN_FORMAT_VERSION invalidates every persisted plan at once;
// fingerprint mismatch invalidates one file.  Either way the loader
// rejects gracefully and the caller rebuilds.

/// Magic prefix of persisted plan files.
pub const PLAN_MAGIC: [u8; 8] = *b"TAMPLAN\0";
/// Current on-disk format version.
pub const PLAN_FORMAT_VERSION: u32 = 1;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64_slice(out: &mut Vec<u8>, s: &[u64]) {
    put_u64(out, s.len() as u64);
    for &v in s {
        put_u64(out, v);
    }
}

fn put_usize_slice(out: &mut Vec<u8>, s: &[usize]) {
    put_u64(out, s.len() as u64);
    for &v in s {
        put_u64(out, v as u64);
    }
}

fn level_kind_code(kind: LevelKind) -> u8 {
    match kind {
        LevelKind::Socket => 0,
        LevelKind::Node => 1,
        LevelKind::Switch => 2,
    }
}

fn level_kind_from(code: u8) -> Result<LevelKind> {
    match code {
        0 => Ok(LevelKind::Socket),
        1 => Ok(LevelKind::Node),
        2 => Ok(LevelKind::Switch),
        other => Err(Error::Protocol(format!("persisted plan: bad level kind {other}"))),
    }
}

/// Serialize a plan to the versioned on-disk format.  The payload slab
/// of every `MyReqs` is structurally empty by construction
/// (`calc_my_req_structure`) and is not serialized.
pub fn encode_plan(plan: &CollectivePlan) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, plan.nprocs as u64);
    put_u32(&mut body, plan.agg.levels.len() as u32);
    for level in &plan.agg.levels {
        body.push(level_kind_code(level.kind));
        put_usize_slice(&mut body, &level.ranks);
        put_usize_slice(&mut body, &level.assignment);
    }
    let x = &plan.exchange;
    put_u64(&mut body, x.domains.lustre.stripe_size);
    put_u64(&mut body, x.domains.lustre.stripe_count as u64);
    put_u64(&mut body, x.domains.first_stripe);
    put_u64(&mut body, x.domains.end_stripe);
    put_u64(&mut body, x.domains.n_agg as u64);
    put_usize_slice(&mut body, &x.agg_ranks);
    put_u64(&mut body, x.n_rounds);
    put_u64(&mut body, x.reqs.len() as u64);
    for pr in &x.reqs {
        put_u64(&mut body, pr.rank as u64);
        put_u64(&mut body, pr.view_len as u64);
        put_u64(&mut body, pr.view_bytes);
        let r = &pr.reqs;
        put_u64(&mut body, r.pieces);
        put_u64(&mut body, r.n_agg as u64);
        put_u64_slice(&mut body, &r.offsets);
        put_u64_slice(&mut body, &r.lengths);
        put_u64_slice(&mut body, &r.payload_src);
        put_u64_slice(&mut body, &r.dest_round);
        put_usize_slice(&mut body, &r.dest_agg);
        put_usize_slice(&mut body, &r.dest_req_start);
        put_u64_slice(&mut body, &r.dest_byte_start);
        put_usize_slice(&mut body, &r.round_starts);
    }

    let mut out = Vec::with_capacity(8 + 4 + 16 + 8 + body.len() + 8);
    out.extend_from_slice(&PLAN_MAGIC);
    put_u32(&mut out, PLAN_FORMAT_VERSION);
    put_u64(&mut out, plan.fingerprint.lo);
    put_u64(&mut out, plan.fingerprint.hi);
    put_u64(&mut out, body.len() as u64);
    let cks = fnv1a(&body);
    out.extend_from_slice(&body);
    put_u64(&mut out, cks);
    out
}

/// Bounds-checked read cursor over untrusted plan bytes: every length
/// prefix is validated against the remaining input before any
/// allocation or slice, so truncated or hostile files fail with
/// [`Error::Protocol`] instead of panicking or ballooning memory.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.bytes.len()).ok_or_else(
            || Error::Protocol("persisted plan: truncated body".into()),
        )?;
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn len_prefix(&mut self) -> Result<usize> {
        let n = usize::try_from(self.u64()?).ok();
        // The words must actually be present before we allocate for
        // them; every step is checked so a hostile u64::MAX prefix
        // errors instead of wrapping past the bounds test.
        let fits = n
            .and_then(|n| n.checked_mul(8))
            .and_then(|b| self.pos.checked_add(b))
            .is_some_and(|end| end <= self.bytes.len());
        if !fits {
            return Err(Error::Protocol(
                "persisted plan: slice length exceeds file size".into(),
            ));
        }
        Ok(n.unwrap())
    }

    fn u64_slice(&mut self) -> Result<Vec<u64>> {
        let n = self.len_prefix()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    fn usize_slice(&mut self) -> Result<Vec<usize>> {
        let n = self.len_prefix()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()? as usize);
        }
        Ok(out)
    }

    fn done(&self) -> bool {
        self.pos == self.bytes.len()
    }
}

/// Parse + validate a persisted plan.  Validation is layered: header
/// (magic, version, fingerprint, length, checksum), then structural
/// invariants (every rank bounded by the recorded `nprocs`, aggregator
/// lists consistent with the domain partition, every `MyReqs` CSR
/// passing [`MyReqs::validate`]) — a file that decodes is safe to
/// execute, never a panic source.
pub fn decode_plan(bytes: &[u8], expect: Fp128) -> Result<CollectivePlan> {
    let header = 8 + 4 + 16 + 8;
    if bytes.len() < header + 8 {
        return Err(Error::Protocol("persisted plan: file too short".into()));
    }
    if bytes[..8] != PLAN_MAGIC {
        return Err(Error::Protocol("persisted plan: bad magic".into()));
    }
    let mut head = Cursor { bytes, pos: 8 };
    let version = head.u32()?;
    if version != PLAN_FORMAT_VERSION {
        return Err(Error::Protocol(format!(
            "persisted plan: format version {version} (this build reads {PLAN_FORMAT_VERSION})"
        )));
    }
    let fp = Fp128 { lo: head.u64()?, hi: head.u64()? };
    if fp != expect {
        return Err(Error::Protocol(format!(
            "persisted plan: fingerprint {fp} does not match expected {expect}"
        )));
    }
    let body_len = usize::try_from(head.u64()?).ok();
    // Checked sum: a hostile body_len near u64::MAX must not wrap into
    // a passing equality.
    let expected_total = body_len
        .and_then(|b| header.checked_add(b))
        .and_then(|t| t.checked_add(8));
    if expected_total != Some(bytes.len()) {
        return Err(Error::Protocol("persisted plan: body length mismatch".into()));
    }
    let body_len = body_len.unwrap();
    let body = &bytes[header..header + body_len];
    let stored_cks =
        u64::from_le_bytes(bytes[header + body_len..].try_into().map_err(|_| {
            Error::Protocol("persisted plan: truncated checksum".into())
        })?);
    if fnv1a(body) != stored_cks {
        return Err(Error::Protocol("persisted plan: checksum mismatch".into()));
    }

    let mut cur = Cursor { bytes: body, pos: 0 };
    let nprocs = cur.u64()? as usize;
    let n_levels = cur.u32()? as usize;
    if n_levels > 3 {
        return Err(Error::Protocol(format!(
            "persisted plan: {n_levels} tree levels (at most 3 exist)"
        )));
    }
    let mut levels = Vec::with_capacity(n_levels);
    for _ in 0..n_levels {
        let kind = level_kind_from(cur.u8()?)?;
        let ranks = cur.usize_slice()?;
        let assignment = cur.usize_slice()?;
        if assignment.len() != nprocs {
            return Err(Error::Protocol(format!(
                "persisted plan: {} level assigns {} ranks, topology has {nprocs}",
                kind,
                assignment.len()
            )));
        }
        if ranks.windows(2).any(|w| w[0] >= w[1]) || ranks.iter().any(|&r| r >= nprocs) {
            return Err(Error::Protocol(format!(
                "persisted plan: {kind} level aggregator ranks not ascending in-range"
            )));
        }
        // Non-member slots hold usize::MAX; member slots must point at
        // one of this level's aggregators.
        if assignment
            .iter()
            .any(|&a| a != usize::MAX && ranks.binary_search(&a).is_err())
        {
            return Err(Error::Protocol(format!(
                "persisted plan: {kind} level assignment targets a non-aggregator"
            )));
        }
        levels.push(LevelAggregators { kind, ranks, assignment });
    }

    let stripe_size = cur.u64()?;
    let stripe_count = cur.u64()? as usize;
    if stripe_size == 0 || stripe_count == 0 {
        return Err(Error::Protocol("persisted plan: zero striping".into()));
    }
    let first_stripe = cur.u64()?;
    let end_stripe = cur.u64()?;
    let n_agg = cur.u64()? as usize;
    if n_agg == 0 {
        return Err(Error::Protocol("persisted plan: zero aggregators".into()));
    }
    if end_stripe < first_stripe {
        return Err(Error::Protocol("persisted plan: inverted stripe range".into()));
    }
    let domains = FileDomains {
        lustre: LustreConfig::new(stripe_size, stripe_count),
        first_stripe,
        end_stripe,
        n_agg,
    };
    let agg_ranks = cur.usize_slice()?;
    if agg_ranks.len() != n_agg || agg_ranks.iter().any(|&r| r >= nprocs) {
        return Err(Error::Protocol(format!(
            "persisted plan: {} aggregator ranks for {n_agg} domains over {nprocs} ranks",
            agg_ranks.len()
        )));
    }
    let n_rounds = cur.u64()?;
    if n_rounds != domains.n_rounds() {
        return Err(Error::Protocol(format!(
            "persisted plan: {n_rounds} rounds, domain partition implies {}",
            domains.n_rounds()
        )));
    }
    let n_reqs = cur.u64()? as usize;
    // One requester record is ≥ 13 u64-sized fields; a hostile count
    // cannot claim more records than bytes remain.
    if n_reqs > body_len / 13 {
        return Err(Error::Protocol("persisted plan: requester count exceeds file".into()));
    }
    let mut reqs = Vec::with_capacity(n_reqs);
    for _ in 0..n_reqs {
        let rank = cur.u64()? as usize;
        if rank >= nprocs {
            return Err(Error::Protocol(format!(
                "persisted plan: requester rank {rank} outside topology ({nprocs} ranks)"
            )));
        }
        let view_len = cur.u64()? as usize;
        let view_bytes = cur.u64()?;
        let pieces = cur.u64()?;
        let req_n_agg = cur.u64()? as usize;
        if req_n_agg != n_agg {
            return Err(Error::Protocol(format!(
                "persisted plan: requester classified against {req_n_agg} domains, plan has {n_agg}"
            )));
        }
        let mr = MyReqs {
            offsets: cur.u64_slice()?,
            lengths: cur.u64_slice()?,
            payload: Vec::new(),
            payload_src: cur.u64_slice()?,
            dest_round: cur.u64_slice()?,
            dest_agg: cur.usize_slice()?,
            dest_req_start: cur.usize_slice()?,
            dest_byte_start: cur.u64_slice()?,
            round_starts: cur.usize_slice()?,
            n_agg: req_n_agg,
            pieces,
        };
        mr.validate(view_bytes)?;
        reqs.push(PlannedRequester { rank, view_len, view_bytes, reqs: mr });
    }
    if !cur.done() {
        return Err(Error::Protocol("persisted plan: trailing bytes after body".into()));
    }
    Ok(CollectivePlan {
        fingerprint: fp,
        nprocs,
        agg: AggregationPlan { levels },
        exchange: ExchangePlan { domains, agg_ranks, n_rounds, reqs },
    })
}

// ---------------------------------------------------------------------------
// Cached entry points
// ---------------------------------------------------------------------------

fn check_topology(plan: &CollectivePlan, topo: &Topology) -> Result<()> {
    if plan.nprocs != topo.nprocs() {
        return Err(Error::Protocol(format!(
            "cached plan spans {} ranks, topology has {}",
            plan.nprocs,
            topo.nprocs()
        )));
    }
    Ok(())
}

/// Cached twin of
/// [`run_collective_write_with`](crate::coordinator::collective::run_collective_write_with):
/// fingerprint the call, reuse (or build once) its [`CollectivePlan`],
/// and execute the tree over the borrowed plan.  The result is
/// bit-identical to the uncached entry point — all simulated times come
/// from `ctx` at execution time — so only wall-clock and
/// [`PlanCacheStats`] reveal whether the plan was warm.
pub fn run_collective_write_cached(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
    cache: &mut PlanCache,
) -> Result<CollectiveOutcome> {
    let file_cfg = *file.config();
    let fp = fingerprint_collective(
        ctx,
        &algo,
        Direction::Write,
        &file_cfg,
        ranks.iter().map(|(r, b)| (*r, &b.view)),
    );
    let plan = cache.get_or_build(fp, || {
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        build_collective_plan(ctx, &algo, Direction::Write, &views, &file_cfg, fp)
    })?;
    check_topology(plan, ctx.topo)?;
    let out = tree_write_with(ctx, &plan.agg, Some(&plan.exchange), ranks, file, arena)?;
    Ok(CollectiveOutcome { breakdown: out.breakdown, counters: out.counters })
}

/// Cached twin of
/// [`run_collective_read_with`](crate::coordinator::collective::run_collective_read_with)
/// (see [`run_collective_write_cached`] for the contract).
pub fn run_collective_read_cached(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
    cache: &mut PlanCache,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    let file_cfg = *file.config();
    let fp = fingerprint_collective(
        ctx,
        &algo,
        Direction::Read,
        &file_cfg,
        views.iter().map(|(r, v)| (*r, v)),
    );
    let plan = cache.get_or_build(fp, || {
        build_collective_plan(ctx, &algo, Direction::Read, &views, &file_cfg, fp)
    })?;
    check_topology(plan, ctx.topo)?;
    tree_read_with(ctx, &plan.agg, Some(&plan.exchange), views, file, arena)
}

// ---------------------------------------------------------------------------
// Plan repair (aggregator dropout) + degraded entry points
// ---------------------------------------------------------------------------

/// Repair a [`CollectivePlan`] after aggregator dropouts: for every
/// `agg_drop` clause a surviving peer adopts the dropped rank's role, so
/// the degraded collective completes with byte-identical file content.
///
/// * **Global dropout** (`agg_drop=<rank>`, no level): the first
///   surviving global aggregator adopts the dropped rank's file domains
///   — `exchange.agg_ranks` entries are rewritten while the
///   [`FileDomains`] partition never moves, so every domain still
///   receives exactly its fault-free bytes.
/// * **Tree-level dropout** (`agg_drop=<rank>@level:<l>`): a
///   deterministically-chosen non-aggregator member `S` of the dropped
///   rank `R`'s group at level `l` is promoted in `R`'s place.  `R`'s
///   members (including `R` itself, demoted to a plain member) re-point
///   at `S`, while `S`'s own view keeps flowing to its old parent —
///   every merged view in the repaired tree is therefore exactly a
///   fault-free merged view, which is what lets a cached exchange plan's
///   shape validation keep holding.  At level `l+1` (or the top-tier
///   exchange, when `l` is the outermost level) `S` inherits `R`'s seat.
///
/// `?` selectors resolve from `seed`, forked per drop index — the same
/// determinism discipline as [`FaultPlan::resolve_osts`].  Returns the
/// number of drops applied; an unrepairable drop (no surviving peer, a
/// level the plan does not have) is a loud error, never a silent no-op.
pub fn repair_plan(
    plan: &mut CollectivePlan,
    topo: &Topology,
    drops: &[(Sel, Option<usize>)],
    seed: u64,
) -> Result<u64> {
    let mut root = SplitMix64::new(seed);
    for (di, (sel, level)) in drops.iter().enumerate() {
        let mut rng = root.fork(di as u64);
        match level {
            None => repair_global_drop(plan, *sel, &mut rng)?,
            Some(l) => repair_level_drop(plan, topo, *sel, *l, &mut rng)?,
        }
    }
    Ok(drops.len() as u64)
}

/// Rewrite `exchange.agg_ranks` so a surviving rank serves the dropped
/// rank's file domains (the domain partition itself is immutable).
fn repair_global_drop(
    plan: &mut CollectivePlan,
    sel: Sel,
    rng: &mut SplitMix64,
) -> Result<()> {
    // Distinct serving ranks, ascending (duplicates appear once an
    // earlier drop has been repaired on this plan).
    let mut serving: Vec<usize> = plan.exchange.agg_ranks.clone();
    serving.sort_unstable();
    serving.dedup();
    let dropped = match sel {
        Sel::Fixed(r) => {
            if !serving.contains(&r) {
                return Err(Error::config(format!(
                    "faults: agg_drop rank {r} is not a serving global aggregator \
                     (serving ranks: {serving:?})"
                )));
            }
            r
        }
        Sel::Random => serving[rng.gen_range(serving.len() as u64) as usize],
    };
    let survivor =
        plan.exchange.agg_ranks.iter().copied().find(|&a| a != dropped).ok_or_else(|| {
            Error::config(format!(
                "faults: dropping aggregator rank {dropped} leaves no survivor to adopt \
                 its file domains"
            ))
        })?;
    for a in plan.exchange.agg_ranks.iter_mut() {
        if *a == dropped {
            *a = survivor;
        }
    }
    Ok(())
}

/// Promote a group peer into a dropped tree-level aggregator's seat (see
/// [`repair_plan`] for the invariants this preserves).
fn repair_level_drop(
    plan: &mut CollectivePlan,
    topo: &Topology,
    sel: Sel,
    l: usize,
    rng: &mut SplitMix64,
) -> Result<()> {
    let depth = plan.agg.levels.len();
    if l >= depth {
        return Err(Error::config(format!(
            "faults: agg_drop level {l} out of range — this plan has {depth} tree \
             level{} (two-phase has none; level drops need a tam/tree algorithm)",
            if depth == 1 { "" } else { "s" }
        )));
    }
    let (kind, dropped) = {
        let level = &plan.agg.levels[l];
        let dropped = match sel {
            Sel::Fixed(r) => {
                if level.ranks.binary_search(&r).is_err() {
                    return Err(Error::config(format!(
                        "faults: agg_drop rank {r} is not an aggregator at tree level {l} \
                         (aggregators: {:?})",
                        level.ranks
                    )));
                }
                r
            }
            Sel::Random => level.ranks[rng.gen_range(level.ranks.len() as u64) as usize],
        };
        (level.kind, dropped)
    };
    // The substitute: the lowest-ranked member of the dropped rank's
    // group at this level that is not itself an aggregator here.
    let group = topo.group_of(kind, dropped);
    let substitute = {
        let level = &plan.agg.levels[l];
        (0..plan.nprocs)
            .find(|&m| {
                m != dropped
                    && level.assignment.get(m).is_some_and(|&a| a != usize::MAX)
                    && topo.group_of(kind, m) == group
                    && level.ranks.binary_search(&m).is_err()
            })
            .ok_or_else(|| {
                Error::config(format!(
                    "faults: agg_drop rank {dropped} at level {l} has no surviving \
                     non-aggregator peer in its {kind} group to promote"
                ))
            })?
    };
    {
        let level = &mut plan.agg.levels[l];
        let pos = level.ranks.binary_search(&dropped).map_err(|_| {
            Error::Protocol(format!("plan repair: rank {dropped} vanished from level {l}"))
        })?;
        level.ranks.remove(pos);
        let ins = match level.ranks.binary_search(&substitute) {
            Ok(i) | Err(i) => i,
        };
        level.ranks.insert(ins, substitute);
        // The dropped rank's members — and the dropped rank itself, now a
        // plain member — re-point at the substitute.  The substitute's
        // own assignment is only rewritten when its parent WAS the
        // dropped rank; otherwise its view keeps flowing to its old
        // parent, whose merged view must not change shape.
        for a in level.assignment.iter_mut() {
            if *a == dropped {
                *a = substitute;
            }
        }
    }
    if l + 1 < depth {
        // The substitute inherits the dropped rank's upstream seat.
        let up = &mut plan.agg.levels[l + 1];
        up.assignment[substitute] = up.assignment[dropped];
        up.assignment[dropped] = usize::MAX;
    } else {
        // Top level: the dropped rank was a top-tier requester of the
        // inter-node exchange; the substitute inherits its classified
        // request slabs (identical merged view ⇒ identical bytes), and
        // the requester list returns to rank order to match the
        // executor's slot ordering.
        for pr in plan.exchange.reqs.iter_mut() {
            if pr.rank == dropped {
                pr.rank = substitute;
            }
        }
        plan.exchange.reqs.sort_by_key(|pr| pr.rank);
    }
    Ok(())
}

/// Degraded twin of [`run_collective_write_cached`]: the plan is built
/// (or reused) under a fault-epoch-salted fingerprint
/// ([`Fp128::salted`], [`FaultPlan::cache_salt`]), the schedule's
/// aggregator drops are repaired into it, and the repaired plan
/// executes.  With `cache: None` the repaired plan is built fresh per
/// call.  `counters.repaired_plans` reports the drops applied —
/// identical for warm and cold executions.
#[allow(clippy::too_many_arguments)]
pub fn run_collective_write_degraded(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
    cache: Option<&mut PlanCache>,
    faults: &FaultPlan,
    fault_seed: u64,
) -> Result<CollectiveOutcome> {
    let file_cfg = *file.config();
    let fp = fingerprint_collective(
        ctx,
        &algo,
        Direction::Write,
        &file_cfg,
        ranks.iter().map(|(r, b)| (*r, &b.view)),
    )
    .salted(faults.cache_salt(fault_seed));
    let drops = faults.drops();
    let build = || -> Result<CollectivePlan> {
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let mut plan =
            build_collective_plan(ctx, &algo, Direction::Write, &views, &file_cfg, fp)?;
        repair_plan(&mut plan, ctx.topo, &drops, fault_seed)?;
        Ok(plan)
    };
    let owned;
    let plan: &CollectivePlan = match cache {
        Some(c) => c.get_or_build(fp, build)?,
        None => {
            owned = build()?;
            &owned
        }
    };
    check_topology(plan, ctx.topo)?;
    let out = tree_write_with(ctx, &plan.agg, Some(&plan.exchange), ranks, file, arena)?;
    let mut out = CollectiveOutcome { breakdown: out.breakdown, counters: out.counters };
    out.counters.repaired_plans = drops.len() as u64;
    Ok(out)
}

/// Degraded twin of [`run_collective_read_cached`] (see
/// [`run_collective_write_degraded`] for the contract).
#[allow(clippy::too_many_arguments)]
pub fn run_collective_read_degraded(
    ctx: &CollectiveCtx,
    algo: Algorithm,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
    cache: Option<&mut PlanCache>,
    faults: &FaultPlan,
    fault_seed: u64,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    let file_cfg = *file.config();
    let fp = fingerprint_collective(
        ctx,
        &algo,
        Direction::Read,
        &file_cfg,
        views.iter().map(|(r, v)| (*r, v)),
    )
    .salted(faults.cache_salt(fault_seed));
    let drops = faults.drops();
    let build = || -> Result<CollectivePlan> {
        let mut plan =
            build_collective_plan(ctx, &algo, Direction::Read, &views, &file_cfg, fp)?;
        repair_plan(&mut plan, ctx.topo, &drops, fault_seed)?;
        Ok(plan)
    };
    let owned;
    let plan: &CollectivePlan = match cache {
        Some(c) => c.get_or_build(fp, build)?,
        None => {
            owned = build()?;
            &owned
        }
    };
    check_topology(plan, ctx.topo)?;
    let (bytes, mut out) =
        tree_read_with(ctx, &plan.agg, Some(&plan.exchange), views, file, arena)?;
    out.counters.repaired_plans = drops.len() as u64;
    Ok((bytes, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::breakdown::CpuModel;
    use crate::lustre::IoModel;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    fn fixture() -> (Topology, NetParams, CpuModel, IoModel, NativeEngine) {
        (
            Topology::new(2, 4),
            NetParams::default(),
            CpuModel::default(),
            IoModel::default(),
            NativeEngine,
        )
    }

    fn views(topo: &Topology) -> Vec<(usize, FlatView)> {
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * 100;
                (r, FlatView::from_pairs(vec![(base, 30), (base + 50, 20)]).unwrap())
            })
            .collect()
    }

    fn fp_of(
        ctx: &CollectiveCtx,
        algo: &Algorithm,
        direction: Direction,
        cfg: &LustreConfig,
        vs: &[(usize, FlatView)],
    ) -> Fp128 {
        fingerprint_collective(ctx, algo, direction, cfg, vs.iter().map(|(r, v)| (*r, v)))
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let cfg = LustreConfig::new(4096, 4);
        let vs = views(&topo);
        let a = fp_of(&ctx, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs);
        let b = fp_of(&ctx, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs);
        assert_eq!(a, b, "same inputs must fingerprint identically");

        // Direction, algorithm, striping, views and topology all key in.
        assert_ne!(a, fp_of(&ctx, &Algorithm::TwoPhase, Direction::Read, &cfg, &vs));
        assert_ne!(
            a,
            fp_of(&ctx, &"tam:2".parse().unwrap(), Direction::Write, &cfg, &vs)
        );
        assert_ne!(
            a,
            fp_of(&ctx, &Algorithm::TwoPhase, Direction::Write, &LustreConfig::new(8192, 4), &vs)
        );
        let mut vs2 = vs.clone();
        vs2[0].1 = FlatView::from_pairs(vec![(0, 31), (50, 20)]).unwrap();
        assert_ne!(a, fp_of(&ctx, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs2));
        let topo2 = Topology::new(4, 2);
        let ctx2 = CollectiveCtx { topo: &topo2, ..ctx };
        assert_ne!(a, fp_of(&ctx2, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs));
        // Cost models are deliberately not part of the key.
        let net2 = NetParams { alpha_inter: net.alpha_inter * 2.0, ..net };
        let ctx3 = CollectiveCtx { net: &net2, ..ctx };
        assert_eq!(a, fp_of(&ctx3, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs));
    }

    #[test]
    fn plan_round_trips_through_disk_format() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let cfg = LustreConfig::new(64, 4);
        let vs = views(&topo);
        let algo: Algorithm = "tam:2".parse().unwrap();
        let fp = fp_of(&ctx, &algo, Direction::Write, &cfg, &vs);
        let plan =
            build_collective_plan(&ctx, &algo, Direction::Write, &vs, &cfg, fp).unwrap();
        let bytes = encode_plan(&plan);
        let back = decode_plan(&bytes, fp).unwrap();
        assert_eq!(back.fingerprint, fp);
        assert_eq!(back.nprocs, plan.nprocs);
        assert_eq!(back.agg.levels.len(), plan.agg.levels.len());
        for (a, b) in back.agg.levels.iter().zip(&plan.agg.levels) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.ranks, b.ranks);
            assert_eq!(a.assignment, b.assignment);
        }
        assert_eq!(back.exchange.n_rounds, plan.exchange.n_rounds);
        assert_eq!(back.exchange.agg_ranks, plan.exchange.agg_ranks);
        assert_eq!(back.exchange.domains.n_agg, plan.exchange.domains.n_agg);
        assert_eq!(back.exchange.reqs.len(), plan.exchange.reqs.len());
        for (a, b) in back.exchange.reqs.iter().zip(&plan.exchange.reqs) {
            assert_eq!(a.rank, b.rank);
            assert_eq!(a.view_len, b.view_len);
            assert_eq!(a.view_bytes, b.view_bytes);
            assert_eq!(a.reqs.pieces, b.reqs.pieces);
        }
    }

    #[test]
    fn decoder_rejects_corruption_gracefully() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let cfg = LustreConfig::new(64, 4);
        let vs = views(&topo);
        let fp = fp_of(&ctx, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs);
        let plan =
            build_collective_plan(&ctx, &Algorithm::TwoPhase, Direction::Write, &vs, &cfg, fp)
                .unwrap();
        let good = encode_plan(&plan);
        assert!(decode_plan(&good, fp).is_ok());

        // Truncation at every prefix length must error, never panic.
        for cut in [0, 7, 8, 12, 20, good.len() / 2, good.len() - 1] {
            assert!(decode_plan(&good[..cut], fp).is_err(), "cut at {cut}");
        }
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decode_plan(&bad, fp).is_err());
        // Future format version.
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&(PLAN_FORMAT_VERSION + 1).to_le_bytes());
        assert!(decode_plan(&bad, fp).is_err());
        // Fingerprint mismatch (stale key).
        let other = Fp128 { lo: fp.lo ^ 1, hi: fp.hi };
        assert!(decode_plan(&good, other).is_err());
        // Body bit-flip trips the checksum.
        let mut bad = good.clone();
        let mid = 36 + (good.len() - 44) / 2;
        bad[mid] ^= 0x40;
        assert!(decode_plan(&bad, fp).is_err());
    }

    #[test]
    fn salted_fingerprints_separate_fault_epochs() {
        let fp = Fp128 { lo: 0x1111, hi: 0x2222 };
        // Salt 0 is the fault-free identity; any real salt moves both
        // lanes deterministically.
        assert_eq!(fp.salted(0), fp);
        let s = fp.salted(0x1234);
        assert_ne!(s, fp);
        assert_eq!(s, fp.salted(0x1234));
        assert_ne!(fp.salted(1), fp.salted(2));
    }

    #[test]
    fn global_drop_repair_reassigns_file_domains() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let cfg = LustreConfig::new(64, 4);
        let vs = views(&topo);
        let fp = fp_of(&ctx, &Algorithm::TwoPhase, Direction::Write, &cfg, &vs);
        let mut plan =
            build_collective_plan(&ctx, &Algorithm::TwoPhase, Direction::Write, &vs, &cfg, fp)
                .unwrap();
        let before = plan.exchange.agg_ranks.clone();
        let dropped = before[0];
        let n = repair_plan(&mut plan, &topo, &[(Sel::Fixed(dropped), None)], 7).unwrap();
        assert_eq!(n, 1);
        // A survivor adopted the dropped rank's domains: same domain
        // count, dropped rank no longer serves, partition untouched.
        assert_eq!(plan.exchange.agg_ranks.len(), before.len());
        assert!(plan.exchange.agg_ranks.iter().all(|&a| a != dropped));
        assert_eq!(plan.exchange.domains.n_agg, before.len());
        // A rank that never served is a loud error, as is a level drop
        // on a depth-0 plan.
        assert!(repair_plan(&mut plan, &topo, &[(Sel::Fixed(9999), None)], 7).is_err());
        assert!(repair_plan(&mut plan, &topo, &[(Sel::Fixed(before[1]), Some(0))], 7)
            .is_err());
    }

    #[test]
    fn level_drop_repair_promotes_a_group_peer() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let cfg = LustreConfig::new(64, 4);
        let vs = views(&topo);
        let algo: Algorithm = "tam:2".parse().unwrap();
        let fp = fp_of(&ctx, &algo, Direction::Write, &cfg, &vs);
        let mut plan =
            build_collective_plan(&ctx, &algo, Direction::Write, &vs, &cfg, fp).unwrap();
        let dropped = plan.agg.levels[0].ranks[0];
        let kind = plan.agg.levels[0].kind;
        repair_plan(&mut plan, &topo, &[(Sel::Fixed(dropped), Some(0))], 3).unwrap();
        let level = &plan.agg.levels[0];
        // The dropped rank left the aggregator set; its seat went to a
        // same-group peer and the set stayed ascending.
        assert!(level.ranks.binary_search(&dropped).is_err());
        assert!(level.ranks.windows(2).all(|w| w[0] < w[1]));
        let substitute = level.assignment[dropped];
        assert_ne!(substitute, dropped);
        assert_eq!(topo.group_of(kind, substitute), topo.group_of(kind, dropped));
        assert!(level.ranks.binary_search(&substitute).is_ok());
        // Depth 1 ⇒ the top-tier requester list inherited the seat too,
        // back in rank order.
        assert!(plan.exchange.reqs.iter().all(|pr| pr.rank != dropped));
        assert!(plan.exchange.reqs.iter().any(|pr| pr.rank == substitute));
        assert!(plan.exchange.reqs.windows(2).all(|w| w[0].rank < w[1].rank));
        // `?` drops resolve deterministically from the seed.
        let mut p1 =
            build_collective_plan(&ctx, &algo, Direction::Write, &vs, &cfg, fp).unwrap();
        let mut p2 =
            build_collective_plan(&ctx, &algo, Direction::Write, &vs, &cfg, fp).unwrap();
        repair_plan(&mut p1, &topo, &[(Sel::Random, Some(0))], 42).unwrap();
        repair_plan(&mut p2, &topo, &[(Sel::Random, Some(0))], 42).unwrap();
        assert_eq!(p1.agg.levels[0].ranks, p2.agg.levels[0].ranks);
        assert_eq!(p1.agg.levels[0].assignment, p2.agg.levels[0].assignment);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let (topo, net, cpu, io, eng) = fixture();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let cfg = LustreConfig::new(64, 4);
        let vs = views(&topo);
        let mut cache = PlanCache::in_memory(2);
        let algos: Vec<Algorithm> =
            vec![Algorithm::TwoPhase, "tam:2".parse().unwrap(), "tree:node=1".parse().unwrap()];
        let fps: Vec<Fp128> = algos
            .iter()
            .map(|a| fp_of(&ctx, a, Direction::Write, &cfg, &vs))
            .collect();
        for (a, &fp) in algos.iter().zip(&fps).take(2) {
            cache
                .get_or_build(fp, || {
                    build_collective_plan(&ctx, a, Direction::Write, &vs, &cfg, fp)
                })
                .unwrap();
        }
        assert_eq!(cache.len(), 2);
        // Touch the first so the second becomes LRU.
        cache.get_or_build(fps[0], || unreachable!("warm entry must hit")).unwrap();
        assert_eq!(cache.stats.hits, 1);
        cache
            .get_or_build(fps[2], || {
                build_collective_plan(&ctx, &algos[2], Direction::Write, &vs, &cfg, fps[2])
            })
            .unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.contains(fps[0]), "recently-used entry survived");
        assert!(!cache.contains(fps[1]), "LRU entry evicted");
        assert!(cache.contains(fps[2]));
        assert_eq!(cache.stats.builds, 3);
        assert!(cache.stats.build_nanos > 0);
    }
}
