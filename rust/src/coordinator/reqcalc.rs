//! Request calculation: the `ADIOI_LUSTRE_Calc_my_req` /
//! `ADIOI_Calc_others_req` equivalents.
//!
//! `calc_my_req` classifies a requester's flattened view against the file
//! domains: which bytes go to which global aggregator in which round
//! (stripe-aligned, so requests are additionally split at stripe
//! boundaries).  `calc_others_req` is, in ROMIO, the metadata exchange in
//! which aggregators learn the offset-length lists they will receive; the
//! simulator performs it as an accounted message exchange
//! (16 bytes per offset-length entry, matching ROMIO's packing).
//!
//! Storage is a CSR-style slab (§Perf tentpole, DESIGN.md §Memory
//! layout): one flat `offsets`/`lengths`/`payload` arena per rank holds
//! every classified piece, grouped by destination, with two index layers
//! on top — a per-destination span table sorted by `(round, aggregator)`
//! and a per-round CSR over that table.  No per-destination `Vec`s exist
//! at all (the pre-slab `Vec<Vec<(u64, ReqBatch)>>` allocated one
//! three-`Vec` batch per destination, which dominated setup at the
//! paper's 16384-rank point); [`RoundDrain`] hands out [`ReqSlice`]
//! borrows into the slab instead of moving owned batches.
//!
//! Construction is two passes over the same inline stripe split: pass 1
//! counts pieces and bytes per destination (building the span table),
//! pass 2 fills the slabs through per-destination cursors.  For a
//! non-overlapping view the pieces arrive in nondecreasing
//! `(round, aggregator)` order (offsets nondecreasing ⇒ stripes
//! nondecreasing ⇒ `(round, agg)` lexicographically nondecreasing, since
//! the stripe → `(round, agg)` mapping is monotone), so pass 1 almost
//! always extends the tail destination; overlapping requests (legal on
//! the read side) revisit an earlier destination, found by binary search
//! over the span table — which stays sorted by construction because new
//! destinations are provably created in ascending `(round, agg)` order
//! even then.  The `#[cfg(test)]` `HashMap` implementation remains the
//! golden oracle.

use crate::error::{Error, Result};
use crate::mpisim::FlatView;

use super::filedomain::FileDomains;
use super::merge::ReqBatch;

/// Destination slot of one classified piece.
pub type DestKey = (u64, usize); // (round, aggregator index)

/// One destination's classified requests: borrowed spans of the owning
/// [`MyReqs`] slab (what [`RoundDrain`] hands out — nothing is moved or
/// cloned on the round loop's hot path).
#[derive(Clone, Copy, Debug)]
pub struct ReqSlice<'a> {
    /// Piece offsets, ascending (inherited from the source view).
    pub offsets: &'a [u64],
    /// Piece lengths, parallel to `offsets`.
    pub lengths: &'a [u64],
    /// Payload bytes in piece order (empty on the metadata-only read
    /// side).
    pub payload: &'a [u8],
    /// Total bytes covered (precomputed — `O(1)`, not a length sum).
    pub bytes: u64,
}

impl<'a> ReqSlice<'a> {
    /// Number of pieces.
    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    /// True when the slice holds no pieces.
    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Iterate `(offset, length)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + 'a {
        // Copy the `&'a` slices out so the iterator borrows the slab,
        // not this `ReqSlice` value.
        let (offsets, lengths) = (self.offsets, self.lengths);
        offsets.iter().copied().zip(lengths.iter().copied())
    }
}

/// Classified requests of one requester: flat piece slabs plus a
/// `(round, aggregator)`-sorted destination span table and a per-round
/// CSR index over it.
#[derive(Debug, Default)]
pub struct MyReqs {
    /// Piece offset slab, grouped by destination in table order.
    /// Fields are `pub(crate)` so the plan cache can serialize /
    /// reconstruct the slabs without an intermediate copy.
    pub(crate) offsets: Vec<u64>,
    /// Piece length slab, parallel to `offsets`.
    pub(crate) lengths: Vec<u64>,
    /// Payload slab in slab order (empty for metadata-only batches).
    pub(crate) payload: Vec<u8>,
    /// Source byte position of each piece in the requester's original
    /// payload buffer, parallel to `offsets` — lets a cached structural
    /// plan restage a fresh payload into slab order
    /// ([`Self::stage_payload`]) without reclassifying the view.
    pub(crate) payload_src: Vec<u64>,
    /// Destination round keys, ascending `(round, agg)`.
    pub(crate) dest_round: Vec<u64>,
    /// Destination aggregator keys, parallel to `dest_round`.
    pub(crate) dest_agg: Vec<usize>,
    /// Piece-span CSR: destination `d` owns slab rows
    /// `dest_req_start[d]..dest_req_start[d + 1]` (`n_dests + 1` entries).
    pub(crate) dest_req_start: Vec<usize>,
    /// Byte-span CSR: destination `d` owns payload bytes
    /// `dest_byte_start[d]..dest_byte_start[d + 1]` (`n_dests + 1`
    /// entries; also the `O(1)` per-destination byte totals).
    pub(crate) dest_byte_start: Vec<u64>,
    /// Round CSR: the destinations of round `r` are table rows
    /// `round_starts[r]..round_starts[r + 1]`.  `max_round + 2` entries
    /// (empty when no pieces).
    pub(crate) round_starts: Vec<usize>,
    /// Aggregator count of the classifying domain set.
    pub(crate) n_agg: usize,
    /// Number of flattened request pieces classified (cost accounting).
    pub pieces: u64,
}

impl MyReqs {
    /// Destinations for a given round, ascending by aggregator — a
    /// CSR slice of the span table (no per-round filter + sort).
    pub fn dests_in_round(&self, round: u64) -> &[usize] {
        let (lo, hi) = self.round_range(round);
        &self.dest_agg[lo..hi]
    }

    /// Span-table row range of a round.
    fn round_range(&self, round: u64) -> (usize, usize) {
        let r = round as usize;
        if r + 1 < self.round_starts.len() {
            (self.round_starts[r], self.round_starts[r + 1])
        } else {
            (0, 0)
        }
    }

    /// Highest round index present.
    pub fn max_round(&self) -> Option<u64> {
        // `round_starts` is empty or has `max_round + 2` entries.
        self.round_starts.len().checked_sub(2).map(|r| r as u64)
    }

    /// Total number of `(round, aggregator)` destinations.
    pub fn n_dests(&self) -> usize {
        self.dest_agg.len()
    }

    /// Slab spans of destination-table row `d`.
    fn slice_of(&self, d: usize) -> ReqSlice<'_> {
        self.slice_of_with(d, &self.payload)
    }

    /// Slab spans of destination-table row `d`, with the payload slab
    /// supplied externally (a caller-staged buffer for cached structural
    /// plans, or `&self.payload` for the owned slab).
    fn slice_of_with<'a>(&'a self, d: usize, payload: &'a [u8]) -> ReqSlice<'a> {
        let (r0, r1) = (self.dest_req_start[d], self.dest_req_start[d + 1]);
        let (b0, b1) = (self.dest_byte_start[d], self.dest_byte_start[d + 1]);
        ReqSlice {
            offsets: &self.offsets[r0..r1],
            lengths: &self.lengths[r0..r1],
            payload: if payload.is_empty() { &[] } else { &payload[b0 as usize..b1 as usize] },
            bytes: b1 - b0,
        }
    }

    /// Borrow the slab span for `(round, agg)`, if present (binary search
    /// within the round's table rows; off the hot path).
    pub fn get(&self, round: u64, agg: usize) -> Option<ReqSlice<'_>> {
        let (lo, hi) = self.round_range(round);
        self.dest_agg[lo..hi]
            .binary_search(&agg)
            .ok()
            .map(|i| self.slice_of(lo + i))
    }

    /// Iterate all `(dest, slice)` pairs in span-table order (ascending
    /// `(round, aggregator)`).
    pub fn iter(&self) -> impl Iterator<Item = (DestKey, ReqSlice<'_>)> + '_ {
        (0..self.n_dests())
            .map(|d| ((self.dest_round[d], self.dest_agg[d]), self.slice_of(d)))
    }

    /// Add this requester's per-aggregator request totals into a dense
    /// accumulator (`acc.len() >= n_agg`) — sizes the `calc_others_req`
    /// metadata messages without a per-rank hash map or a fresh `Vec`
    /// (the caller's arena owns `acc`).
    pub fn reqs_per_agg_into(&self, acc: &mut [u64]) {
        for d in 0..self.n_dests() {
            acc[self.dest_agg[d]] +=
                (self.dest_req_start[d + 1] - self.dest_req_start[d]) as u64;
        }
    }

    /// Per-aggregator total request count across all rounds, ascending by
    /// aggregator, skipping aggregators with no data (allocating
    /// convenience wrapper over [`Self::reqs_per_agg_into`]).
    pub fn reqs_per_agg(&self) -> impl Iterator<Item = (usize, u64)> {
        let mut acc = vec![0u64; self.n_agg];
        self.reqs_per_agg_into(&mut acc);
        acc.into_iter().enumerate().filter(|&(_, n)| n > 0)
    }

    /// Hand out round `round`'s `(aggregator, slice)` pairs in
    /// ascending-aggregator order — slab borrows, nothing moved, so the
    /// same `MyReqs` serves any number of passes (the exchange loop makes
    /// exactly one per round).
    pub fn slices_in_round(&self, round: u64) -> RoundDrain<'_> {
        self.slices_in_round_with(round, &self.payload)
    }

    /// [`Self::slices_in_round`] with an externally staged payload slab:
    /// the executor of a cached structural plan stages the caller's fresh
    /// payload into slab order once per exchange ([`Self::stage_payload`])
    /// and drains rounds against it.  Pass an empty slice for
    /// metadata-only reads.
    pub fn slices_in_round_with<'a>(&'a self, round: u64, payload: &'a [u8]) -> RoundDrain<'a> {
        let (lo, hi) = self.round_range(round);
        RoundDrain { reqs: self, payload, next: lo, end: hi }
    }

    /// Copy a requester's fresh payload buffer into destination-slab
    /// order, reusing `out`'s capacity.  `src` is indexed through the
    /// recorded per-piece source positions, so a structural plan built
    /// without payload re-stages any later payload in `O(bytes)` without
    /// reclassifying the view.  An empty `src` (read side) clears `out`.
    pub fn stage_payload(&self, src: &[u8], out: &mut Vec<u8>) {
        out.clear();
        if src.is_empty() {
            return;
        }
        out.reserve(self.dest_byte_start.last().copied().unwrap_or(0) as usize);
        for i in 0..self.offsets.len() {
            let s = self.payload_src[i] as usize;
            let l = self.lengths[i] as usize;
            out.extend_from_slice(&src[s..s + l]);
        }
    }

    /// Structural integrity check for plans deserialized from disk: CSR
    /// monotonicity and bounds, strictly ascending `(round, agg)` keys,
    /// aggregator indexes inside `n_agg`, round CSR consistency, and
    /// payload-source spans inside `source_bytes` (the requester's view
    /// total, so [`Self::stage_payload`] cannot index out of bounds).
    pub fn validate(&self, source_bytes: u64) -> Result<()> {
        let corrupt = |what: &str| Error::Protocol(format!("corrupt request plan: {what}"));
        let n = self.offsets.len();
        if self.lengths.len() != n || self.payload_src.len() != n || self.pieces != n as u64 {
            return Err(corrupt("piece slab lengths disagree"));
        }
        let nd = self.dest_agg.len();
        if self.dest_round.len() != nd {
            return Err(corrupt("span table lengths disagree"));
        }
        // A constructed plan always carries `n_dests + 1` CSR entries;
        // `MyReqs::default()` (all-empty) is also structurally sound.
        let default_empty = nd == 0
            && n == 0
            && self.dest_req_start.is_empty()
            && self.dest_byte_start.is_empty();
        if !default_empty {
            if self.dest_req_start.len() != nd + 1 || self.dest_byte_start.len() != nd + 1 {
                return Err(corrupt("span CSR must have n_dests + 1 entries"));
            }
            if self.dest_req_start[0] != 0
                || self.dest_byte_start[0] != 0
                || self.dest_req_start[nd] != n
            {
                return Err(corrupt("span CSR endpoints"));
            }
        }
        if nd == 0 && n != 0 {
            return Err(corrupt("pieces without destinations"));
        }
        for d in 0..nd {
            if self.dest_req_start[d] > self.dest_req_start[d + 1]
                || self.dest_byte_start[d] > self.dest_byte_start[d + 1]
            {
                return Err(corrupt("span CSR not monotone"));
            }
            if self.dest_agg[d] >= self.n_agg {
                return Err(corrupt("aggregator index out of range"));
            }
            if d + 1 < nd
                && (self.dest_round[d], self.dest_agg[d])
                    >= (self.dest_round[d + 1], self.dest_agg[d + 1])
            {
                return Err(corrupt("span table keys not strictly ascending"));
            }
        }
        if !self.round_starts.is_empty() {
            if nd == 0 {
                return Err(corrupt("round CSR without destinations"));
            }
            if *self.round_starts.last().unwrap() != nd {
                return Err(corrupt("round CSR endpoint"));
            }
            if self.round_starts.windows(2).any(|w| w[0] > w[1]) {
                return Err(corrupt("round CSR not monotone"));
            }
        } else if nd > 0 {
            return Err(corrupt("destinations without round CSR"));
        }
        if !self.payload.is_empty()
            && self.payload.len() as u64 != self.dest_byte_start.last().copied().unwrap_or(0)
        {
            return Err(corrupt("payload slab length"));
        }
        for i in 0..n {
            let end = self.payload_src[i].checked_add(self.lengths[i]);
            match end {
                Some(e) if e <= source_bytes => {}
                _ => return Err(corrupt("payload source span outside the view")),
            }
        }
        Ok(())
    }
}

/// Iterator over one round's `(aggregator, slice)` pairs — see
/// [`MyReqs::slices_in_round`].  Successor of the batch-moving drain: it
/// hands out [`ReqSlice`] borrows into the slab instead of owned
/// `ReqBatch`es.
pub struct RoundDrain<'a> {
    reqs: &'a MyReqs,
    /// Payload slab the slices borrow from (the owned slab, or a
    /// caller-staged buffer when executing a cached structural plan).
    payload: &'a [u8],
    next: usize,
    end: usize,
}

impl<'a> Iterator for RoundDrain<'a> {
    type Item = (usize, ReqSlice<'a>);

    fn next(&mut self) -> Option<(usize, ReqSlice<'a>)> {
        if self.next >= self.end {
            return None;
        }
        let d = self.next;
        self.next += 1;
        Some((self.reqs.dest_agg[d], self.reqs.slice_of_with(d, self.payload)))
    }
}

/// Drive `f(piece_offset, piece_length, payload_source_position)` over
/// every stripe-split piece of `view` — the single classification walk
/// both construction passes (and the oracle) share.  Zero-length requests
/// produce no pieces; the inline split allocates nothing.
#[inline]
fn for_each_piece(view: &FlatView, stripe_size: u64, mut f: impl FnMut(u64, u64, u64)) {
    let mut payload_cursor = 0u64;
    for (off, len) in view.iter() {
        if len == 0 {
            continue;
        }
        let mut cur = off;
        let end = off + len;
        loop {
            let stripe_end = (cur / stripe_size + 1) * stripe_size;
            let piece_end = end.min(stripe_end);
            f(cur, piece_end - cur, payload_cursor + (cur - off));
            if piece_end >= end {
                break;
            }
            cur = piece_end;
        }
        payload_cursor += len;
    }
}

/// Classify one requester's batch against the file domains.
///
/// Splits requests at stripe boundaries (a request can span several
/// domains/rounds) and slices the payload accordingly.  Within each
/// destination the pieces keep source order (ascending offsets), so
/// aggregators can heap-merge the slab spans directly.
pub fn calc_my_req(domains: &FileDomains, batch: &ReqBatch) -> Result<MyReqs> {
    calc_my_req_inner(domains, &batch.view, &batch.payload)
}

/// Structure-only classification: identical span tables and piece slabs,
/// but no payload slab.  This is the form plan construction caches — an
/// executor re-stages each call's fresh payload into slab order through
/// [`MyReqs::stage_payload`] instead of reclassifying the view.
pub fn calc_my_req_structure(domains: &FileDomains, view: &FlatView) -> Result<MyReqs> {
    calc_my_req_inner(domains, view, &[])
}

fn calc_my_req_inner(
    domains: &FileDomains,
    view: &FlatView,
    src_payload: &[u8],
) -> Result<MyReqs> {
    let n_agg = domains.n_agg;
    let stripe_size = domains.lustre.stripe_size;
    let has_payload = !src_payload.is_empty();

    // ---- Pass 1: build the destination span table (counts + bytes).
    let mut dest_round: Vec<u64> = Vec::new();
    let mut dest_agg: Vec<usize> = Vec::new();
    let mut dest_count: Vec<usize> = Vec::new();
    let mut dest_bytes: Vec<u64> = Vec::new();
    let mut round_starts: Vec<usize> = Vec::new();
    let mut pieces = 0u64;
    let mut bad_revisit = false;
    for_each_piece(view, stripe_size, |off, len, _| {
        if bad_revisit {
            return;
        }
        let key = (domains.round_of(off), domains.aggregator_of(off));
        let n = dest_agg.len();
        let d = match n.checked_sub(1).map(|l| (dest_round[l], dest_agg[l])) {
            Some(last) if last == key => n - 1,
            Some(last) if last > key => {
                // Overlapping request revisiting an earlier destination:
                // the covering request already created it (a request's
                // pieces walk a contiguous stripe range, and overlap
                // implies an earlier request covered this stripe).  The
                // round's table rows are complete except possibly the
                // still-growing tail round.
                let r = key.0 as usize;
                let lo = round_starts[r];
                let hi = if r + 1 < round_starts.len() { round_starts[r + 1] } else { n };
                match dest_agg[lo..hi].binary_search(&key.1) {
                    Ok(i) => lo + i,
                    Err(_) => {
                        // Unreachable for any view with nondecreasing
                        // offsets; surfaced as an error (not a panic) so a
                        // corrupt persisted plan or adversarial view fails
                        // the collective gracefully.
                        bad_revisit = true;
                        n - 1
                    }
                }
            }
            _ => {
                // New destination — created in ascending (round, agg)
                // order even for overlapping views, so the table stays
                // sorted by construction.
                while round_starts.len() <= key.0 as usize {
                    round_starts.push(n);
                }
                dest_round.push(key.0);
                dest_agg.push(key.1);
                dest_count.push(0);
                dest_bytes.push(0);
                n
            }
        };
        dest_count[d] += 1;
        dest_bytes[d] += len;
        pieces += 1;
    });
    if bad_revisit {
        return Err(Error::Protocol(
            "overlapping request revisits an unknown destination (corrupt view)".into(),
        ));
    }
    let n_dests = dest_agg.len();
    if !round_starts.is_empty() {
        round_starts.push(n_dests);
    }

    // Exclusive prefix sums turn the counts into slab spans.
    let mut dest_req_start = Vec::with_capacity(n_dests + 1);
    let mut dest_byte_start = Vec::with_capacity(n_dests + 1);
    let (mut racc, mut bacc) = (0usize, 0u64);
    for d in 0..n_dests {
        dest_req_start.push(racc);
        dest_byte_start.push(bacc);
        racc += dest_count[d];
        bacc += dest_bytes[d];
    }
    dest_req_start.push(racc);
    dest_byte_start.push(bacc);

    // ---- Pass 2: fill the slabs through per-destination cursors.
    let mut offsets = vec![0u64; pieces as usize];
    let mut lengths = vec![0u64; pieces as usize];
    let mut payload_src = vec![0u64; pieces as usize];
    let mut payload = if has_payload { vec![0u8; bacc as usize] } else { Vec::new() };
    // `dest_count`/`dest_bytes` are done counting — reuse them as the
    // fill cursors (piece slot / payload byte position per destination).
    let fill = &mut dest_count;
    let bfill = &mut dest_bytes;
    for d in 0..n_dests {
        fill[d] = dest_req_start[d];
        bfill[d] = dest_byte_start[d];
    }
    let mut cur = 0usize; // last destination written (monotone fast path)
    for_each_piece(view, stripe_size, |off, len, src| {
        let key = (domains.round_of(off), domains.aggregator_of(off));
        let d = if cur < n_dests && (dest_round[cur], dest_agg[cur]) == key {
            cur
        } else if cur + 1 < n_dests && (dest_round[cur + 1], dest_agg[cur + 1]) == key {
            cur + 1
        } else {
            // Revisit (or first piece): the table is sorted — search it.
            let mut lo = 0usize;
            let mut hi = n_dests;
            while lo < hi {
                let m = (lo + hi) / 2;
                if (dest_round[m], dest_agg[m]) < key {
                    lo = m + 1;
                } else {
                    hi = m;
                }
            }
            debug_assert!(
                lo < n_dests && (dest_round[lo], dest_agg[lo]) == key,
                "pass 2 key must exist in the span table"
            );
            lo
        };
        cur = d;
        let slot = fill[d];
        fill[d] = slot + 1;
        offsets[slot] = off;
        lengths[slot] = len;
        payload_src[slot] = src;
        if has_payload {
            let b = bfill[d] as usize;
            bfill[d] += len;
            payload[b..b + len as usize]
                .copy_from_slice(&src_payload[src as usize..(src + len) as usize]);
        }
    });
    debug_assert!((0..n_dests).all(|d| fill[d] == dest_req_start[d + 1]));

    Ok(MyReqs {
        offsets,
        lengths,
        payload,
        payload_src,
        dest_round,
        dest_agg,
        dest_req_start,
        dest_byte_start,
        round_starts,
        n_agg,
        pieces,
    })
}

/// Bytes on the wire for the `calc_others_req` metadata describing `n`
/// offset-length entries (ROMIO packs two 8-byte words per entry).
pub fn metadata_bytes(n: u64) -> u64 {
    16 * n
}

/// The pre-tentpole `HashMap` implementation, kept verbatim as the golden
/// oracle for the CSR-slab rewrite (same pattern as the binary-search
/// `scatter_into_binary_search` reference).
#[cfg(test)]
pub(crate) fn calc_my_req_hashmap(
    domains: &FileDomains,
    batch: &ReqBatch,
) -> (std::collections::HashMap<DestKey, ReqBatch>, u64) {
    #[derive(Default)]
    struct DestAccum {
        offsets: Vec<u64>,
        lengths: Vec<u64>,
        payload: Vec<u8>,
    }
    let mut accum: std::collections::HashMap<DestKey, DestAccum> = Default::default();
    let mut pieces = 0u64;
    let has_payload = !batch.payload.is_empty();
    for_each_piece(&batch.view, domains.lustre.stripe_size, |off, len, src| {
        let agg = domains.aggregator_of(off);
        let round = domains.round_of(off);
        let a = accum.entry((round, agg)).or_default();
        a.offsets.push(off);
        a.lengths.push(len);
        if has_payload {
            a.payload
                .extend_from_slice(&batch.payload[src as usize..(src + len) as usize]);
        }
        pieces += 1;
    });
    let by_dest = accum
        .into_iter()
        .map(|(k, a)| {
            (
                k,
                ReqBatch::new(FlatView::from_pairs_unchecked(a.offsets, a.lengths), a.payload),
            )
        })
        .collect();
    (by_dest, pieces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lustre::LustreConfig;
    use crate::util::SplitMix64;

    fn domains(n_agg: usize) -> FileDomains {
        // stripe 100 bytes, 4 OSTs, region [0, 1200)
        FileDomains::new(LustreConfig::new(100, 4), 0, 1200, n_agg)
    }

    fn batch(pairs: &[(u64, u64)]) -> ReqBatch {
        let view = FlatView::from_pairs(pairs.to_vec()).unwrap();
        let total = view.total_bytes();
        let payload: Vec<u8> = (0..total).map(|i| i as u8).collect();
        ReqBatch::new(view, payload)
    }

    /// Full dense-vs-oracle comparison of one classification.
    fn assert_matches_oracle(d: &FileDomains, b: &ReqBatch, what: &str) {
        let dense = calc_my_req(d, b).unwrap();
        let (oracle, oracle_pieces) = calc_my_req_hashmap(d, b);
        assert_eq!(dense.pieces, oracle_pieces, "{what}: pieces");
        assert_eq!(dense.n_dests(), oracle.len(), "{what}: dest count");
        for (key, want) in &oracle {
            let got = dense
                .get(key.0, key.1)
                .unwrap_or_else(|| panic!("{what}: missing dest {key:?}"));
            assert_eq!(
                got.iter().collect::<Vec<_>>(),
                want.view.iter().collect::<Vec<_>>(),
                "{what}: dest {key:?} view"
            );
            assert_eq!(got.payload, &want.payload[..], "{what}: dest {key:?} payload");
            assert_eq!(got.bytes, want.view.total_bytes(), "{what}: dest {key:?} bytes");
        }
        // dests_in_round must equal the sorted oracle key projection, and
        // the round drain must walk the table in (round, agg) order.
        if let Some(max) = dense.max_round() {
            for round in 0..=max {
                let mut want_aggs: Vec<usize> = oracle
                    .keys()
                    .filter(|(r, _)| *r == round)
                    .map(|&(_, a)| a)
                    .collect();
                want_aggs.sort_unstable();
                assert_eq!(dense.dests_in_round(round), &want_aggs[..], "{what}: r{round}");
                let drained: Vec<usize> =
                    dense.slices_in_round(round).map(|(a, _)| a).collect();
                assert_eq!(drained, want_aggs, "{what}: drain r{round}");
            }
        }
        assert_eq!(
            dense.max_round(),
            oracle.keys().map(|&(r, _)| r).max(),
            "{what}: max_round"
        );
    }

    #[test]
    fn single_request_single_dest() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(10, 20)])).unwrap();
        assert_eq!(r.pieces, 1);
        assert_eq!(r.n_dests(), 1);
        let b = r.get(0, 0).unwrap();
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![(10, 20)]);
        assert_eq!(b.payload, (0..20).map(|i| i as u8).collect::<Vec<_>>());
        assert_eq!(b.bytes, 20);
    }

    #[test]
    fn request_split_at_stripe_boundary() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(90, 20)])).unwrap();
        assert_eq!(r.pieces, 2);
        let a = r.get(0, 0).unwrap();
        let b = r.get(0, 1).unwrap();
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![(90, 10)]);
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![(100, 10)]);
        // Payload split preserves byte identity.
        assert_eq!(a.payload, (0..10).map(|i| i as u8).collect::<Vec<_>>());
        assert_eq!(b.payload, (10..20).map(|i| i as u8).collect::<Vec<_>>());
    }

    #[test]
    fn rounds_assigned_beyond_first_cycle() {
        let d = domains(4);
        // Offset 450 → stripe 4 → round 1, aggregator 0.
        let r = calc_my_req(&d, &batch(&[(450, 10)])).unwrap();
        assert!(r.get(1, 0).is_some());
        assert_eq!(r.max_round(), Some(1));
        assert_eq!(r.dests_in_round(0), &[] as &[usize]);
        assert_eq!(r.dests_in_round(1), &[0]);
    }

    #[test]
    fn per_dest_spans_stay_sorted() {
        let d = domains(2);
        let r = calc_my_req(&d, &batch(&[(0, 10), (200, 10), (410, 10), (600, 10)])).unwrap();
        for (_, s) in r.iter() {
            assert!(s.offsets.windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(s.bytes, s.lengths.iter().sum::<u64>());
        }
    }

    #[test]
    fn empty_batch_empty_result() {
        let d = domains(4);
        let r = calc_my_req(&d, &ReqBatch::default()).unwrap();
        assert_eq!(r.n_dests(), 0);
        assert_eq!(r.pieces, 0);
        assert_eq!(r.max_round(), None);
        assert_eq!(r.dests_in_round(0), &[] as &[usize]);
        assert_eq!(r.reqs_per_agg().count(), 0);
        assert_eq!(r.slices_in_round(0).count(), 0);
    }

    #[test]
    fn dests_in_round_sorted() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(50, 10), (250, 10), (350, 10)])).unwrap();
        assert_eq!(r.dests_in_round(0), &[0, 2, 3]);
    }

    #[test]
    fn payload_bytes_conserved() {
        let d = domains(3);
        let b = batch(&[(95, 120), (700, 33)]);
        let total_in = b.view.total_bytes();
        let r = calc_my_req(&d, &b).unwrap();
        let total_out: u64 = r.iter().map(|(_, s)| s.bytes).sum();
        assert_eq!(total_in, total_out);
        let payload_out: usize = r.iter().map(|(_, s)| s.payload.len()).sum();
        assert_eq!(payload_out as u64, total_in);
    }

    #[test]
    fn reqs_per_agg_totals_match_spans() {
        let d = domains(2);
        let r = calc_my_req(&d, &batch(&[(0, 10), (150, 10), (390, 20), (800, 10)])).unwrap();
        let mut acc = vec![0u64; 2];
        r.reqs_per_agg_into(&mut acc);
        assert_eq!(acc.iter().sum::<u64>(), r.pieces);
        let from_iter: Vec<(usize, u64)> = r.reqs_per_agg().collect();
        for (a, n) in &from_iter {
            assert_eq!(acc[*a], *n);
        }
        assert!(from_iter.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn round_slices_concatenate_to_source_payload() {
        let d = domains(2);
        let src = batch(&[(0, 10), (150, 10), (390, 20), (800, 10)]);
        let r = calc_my_req(&d, &src).unwrap();
        let mut drained: Vec<(u64, usize)> = Vec::new();
        let mut payload_cat: Vec<u8> = Vec::new();
        for round in 0..=r.max_round().unwrap() {
            for (agg, s) in r.slices_in_round(round) {
                drained.push((round, agg));
                payload_cat.extend_from_slice(s.payload);
            }
        }
        // Lexicographically ascending keys, every dest exactly once.
        assert!(drained.windows(2).all(|w| w[0] < w[1]), "{drained:?}");
        assert_eq!(drained.len(), r.n_dests());
        // Concatenation in (round, agg) order reproduces the source payload
        // — the invariant the read path's reply assembly relies on.
        assert_eq!(payload_cat, src.payload);
        // Slices borrow — a second pass sees identical content.
        let again: Vec<u8> = (0..=r.max_round().unwrap())
            .flat_map(|round| {
                r.slices_in_round(round)
                    .flat_map(|(_, s)| s.payload.iter().copied())
                    .collect::<Vec<u8>>()
            })
            .collect();
        assert_eq!(again, src.payload);
    }

    #[test]
    fn metadata_bytes_packing() {
        assert_eq!(metadata_bytes(0), 0);
        assert_eq!(metadata_bytes(10), 160);
    }

    /// Random view with gaps, zero-length requests, single-byte requests
    /// straddling stripe boundaries (offset ≡ -1 mod stripe), and
    /// occasional overlapping requests (legal on the read side).
    fn random_batch(rng: &mut SplitMix64, stripe: u64, with_payload: bool) -> ReqBatch {
        random_batch_sized(rng, stripe, with_payload, 60)
    }

    fn random_batch_sized(
        rng: &mut SplitMix64,
        stripe: u64,
        with_payload: bool,
        max_reqs: u64,
    ) -> ReqBatch {
        let n = rng.gen_range(max_reqs) as usize;
        let mut pairs = Vec::with_capacity(n);
        let mut cursor = rng.gen_range(stripe * 3);
        for _ in 0..n {
            if rng.gen_bool(0.4) {
                cursor += rng.gen_range(stripe * 2);
            }
            let len = match rng.gen_range(4) {
                0 => 0,                              // zero-length request
                1 => {
                    // Single-byte request straddler setup: jump to the last
                    // byte of a stripe so the *next* request straddles.
                    cursor = (cursor / stripe + 1) * stripe - 1;
                    1
                }
                2 => 1 + rng.gen_range(2 * stripe),  // may span stripes
                _ => 1 + rng.gen_range(stripe / 2),
            };
            let off = cursor;
            pairs.push((off, len));
            if rng.gen_bool(0.15) {
                // Rewind inside the request just pushed: the next request
                // overlaps it (offsets stay nondecreasing).
                cursor = off + rng.gen_range(len.max(1));
            } else {
                cursor += len;
            }
        }
        let view = FlatView::from_pairs(pairs).unwrap();
        let payload = if with_payload {
            (0..view.total_bytes()).map(|i| (i as u8).wrapping_mul(167)).collect()
        } else {
            Vec::new()
        };
        ReqBatch::new(view, payload)
    }

    #[test]
    fn dense_matches_hashmap_oracle_randomized() {
        let mut rng = SplitMix64::new(0xD0_5E);
        for case in 0..200 {
            let stripe = [16u64, 100, 256][rng.gen_range(3) as usize];
            let n_agg = 1 + rng.gen_range(8) as usize;
            let with_payload = rng.gen_bool(0.7);
            let b = random_batch(&mut rng, stripe, with_payload);
            let lo = b.view.min_offset().unwrap_or(0);
            let hi = b.view.max_end().unwrap_or(0);
            let d = FileDomains::new(LustreConfig::new(stripe, 4), lo, hi, n_agg);
            if d.n_stripes() == 0 {
                continue;
            }
            assert_matches_oracle(&d, &b, &format!("case {case}"));
        }
    }

    /// §Satellite: CSR slab vs HashMap oracle at the sweep's rank counts
    /// under randomized round schedules.  Two layers per rank count:
    ///
    /// * a *collective* strided pattern — every rank's view classified
    ///   against ONE shared domain set whose geometry (stripe size,
    ///   aggregator count, and therefore the round schedule) is sampled
    ///   per rank count, with per-rank oracle equality plus a global
    ///   byte-conservation check over the whole schedule;
    /// * randomized straddler views (zero-length requests, single-byte
    ///   stripe straddlers, overlapping reads) — both directions
    ///   (payload-carrying write batches and metadata-only read batches).
    #[test]
    fn csr_slab_matches_oracle_across_rank_counts() {
        for &n_ranks in &[64usize, 1024, 4096] {
            let mut rng = SplitMix64::new(0x5CA1E ^ n_ranks as u64);
            // Collective strided layer: rank r owns element r of every
            // P-wide group; elem NOT a stripe divisor so requests
            // straddle boundaries.
            let elem = [24u64, 32, 56][rng.gen_range(3) as usize];
            let groups = 1 + rng.gen_range(8);
            let stripe = [64u64, 100, 4096][rng.gen_range(3) as usize];
            let n_agg = 1 + rng.gen_range(64) as usize;
            let extent = n_ranks as u64 * elem * groups;
            let d = FileDomains::new(LustreConfig::new(stripe, 4), 0, extent, n_agg);
            let mut total_pieces = 0u64;
            let mut total_bytes = 0u64;
            for r in 0..n_ranks {
                let pairs: Vec<(u64, u64)> = (0..groups)
                    .map(|g| ((g * n_ranks as u64 + r as u64) * elem, elem))
                    .collect();
                let view = FlatView::from_pairs(pairs).unwrap();
                let payload = (0..view.total_bytes())
                    .map(|i| (i as u8) ^ r as u8)
                    .collect();
                let b = ReqBatch::new(view, payload);
                // Oracle-check a deterministic sample of ranks (first,
                // last, and a stride in between) — all ranks share the
                // same classification code, and the conservation sums
                // below cover everyone.
                if r < 8 || r == n_ranks - 1 || r % 97 == 0 {
                    assert_matches_oracle(&d, &b, &format!("P={n_ranks} strided rank {r}"));
                }
                let mr = calc_my_req(&d, &b).unwrap();
                total_pieces += mr.pieces;
                total_bytes += mr.iter().map(|(_, s)| s.bytes).sum::<u64>();
            }
            // Every byte of the global schedule lands exactly once.
            assert_eq!(total_bytes, extent, "P={n_ranks}: bytes not conserved");
            assert!(total_pieces >= n_ranks as u64 * groups, "P={n_ranks}");

            // Randomized straddler layer, both directions.
            for (direction, with_payload) in [("write", true), ("read", false)] {
                for i in 0..32 {
                    let b = random_batch_sized(&mut rng, stripe, with_payload, 20);
                    let lo = b.view.min_offset().unwrap_or(0);
                    let hi = b.view.max_end().unwrap_or(0);
                    let dd = FileDomains::new(LustreConfig::new(stripe, 4), lo, hi, n_agg);
                    if dd.n_stripes() == 0 {
                        continue;
                    }
                    assert_matches_oracle(
                        &dd,
                        &b,
                        &format!("P={n_ranks} {direction} sample {i}"),
                    );
                }
            }
        }
    }

    #[test]
    fn overlapping_view_revisits_earlier_round() {
        // A 300-byte request followed by a nested 10-byte request: with
        // stripe 100 and 2 aggregators the nested request lands back in
        // (round 0, agg 0) *after* (round 1, agg 0) was created.
        let d = FileDomains::new(LustreConfig::new(100, 4), 0, 300, 2);
        let b = batch(&[(0, 300), (50, 10)]);
        assert_matches_oracle(&d, &b, "overlap");
        let r = calc_my_req(&d, &b).unwrap();
        assert_eq!(r.get(0, 0).unwrap().iter().collect::<Vec<_>>(), vec![(0, 100), (50, 10)]);
    }

    #[test]
    fn single_byte_request_straddling_stripe_boundary() {
        // Two single-byte requests around the 100-byte stripe boundary and
        // one two-byte request straddling it.
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(99, 1), (100, 1), (199, 2)])).unwrap();
        assert_eq!(r.pieces, 4);
        assert_eq!(r.get(0, 0).unwrap().iter().collect::<Vec<_>>(), vec![(99, 1)]);
        assert_eq!(
            r.get(0, 1).unwrap().iter().collect::<Vec<_>>(),
            vec![(100, 1), (199, 1)]
        );
        assert_eq!(r.get(0, 2).unwrap().iter().collect::<Vec<_>>(), vec![(200, 1)]);
    }

    /// §Plan cache: a structural plan plus [`MyReqs::stage_payload`]
    /// reproduces the payload slab the direct (payload-carrying)
    /// classification builds, byte for byte, across randomized views —
    /// the invariant that lets a cached plan skip reclassification.
    #[test]
    fn structure_plus_stage_payload_matches_direct() {
        let mut rng = SplitMix64::new(0x57A6E);
        for case in 0..100 {
            let stripe = [16u64, 100, 256][rng.gen_range(3) as usize];
            let b = random_batch(&mut rng, stripe, true);
            let lo = b.view.min_offset().unwrap_or(0);
            let hi = b.view.max_end().unwrap_or(0);
            let d = FileDomains::new(LustreConfig::new(stripe, 4), lo, hi, 3);
            if d.n_stripes() == 0 {
                continue;
            }
            let direct = calc_my_req(&d, &b).unwrap();
            let structure = calc_my_req_structure(&d, &b.view).unwrap();
            assert!(structure.payload.is_empty(), "case {case}");
            assert_eq!(structure.offsets, direct.offsets, "case {case}");
            assert_eq!(structure.lengths, direct.lengths, "case {case}");
            let mut staged = Vec::new();
            structure.stage_payload(&b.payload, &mut staged);
            assert_eq!(staged, direct.payload, "case {case}: staged slab");
            // Round drains over the staged slab hand out the same slices
            // the owned slab does.
            if let Some(max) = direct.max_round() {
                for round in 0..=max {
                    let from_staged: Vec<Vec<u8>> = structure
                        .slices_in_round_with(round, &staged)
                        .map(|(_, s)| s.payload.to_vec())
                        .collect();
                    let from_owned: Vec<Vec<u8>> = direct
                        .slices_in_round(round)
                        .map(|(_, s)| s.payload.to_vec())
                        .collect();
                    assert_eq!(from_staged, from_owned, "case {case} round {round}");
                }
            }
            // A freshly built plan always validates against its view size.
            structure.validate(b.view.total_bytes()).unwrap();
            direct.validate(b.view.total_bytes()).unwrap();
        }
    }

    #[test]
    fn validate_rejects_corrupt_plans() {
        let d = domains(4);
        let good = calc_my_req(&d, &batch(&[(90, 20), (300, 5)])).unwrap();
        let total = 25u64;
        good.validate(total).unwrap();
        MyReqs::default().validate(0).unwrap();

        let mut bad = calc_my_req(&d, &batch(&[(90, 20), (300, 5)])).unwrap();
        bad.dest_agg[0] = 99; // aggregator out of range
        assert!(bad.validate(total).is_err());

        let mut bad = calc_my_req(&d, &batch(&[(90, 20), (300, 5)])).unwrap();
        bad.payload_src[0] = u64::MAX; // source span overflows the view
        assert!(bad.validate(total).is_err());

        let mut bad = calc_my_req(&d, &batch(&[(90, 20), (300, 5)])).unwrap();
        bad.dest_req_start.pop(); // truncated CSR
        assert!(bad.validate(total).is_err());

        let mut bad = calc_my_req(&d, &batch(&[(90, 20), (300, 5)])).unwrap();
        if let Some(l) = bad.round_starts.last_mut() {
            *l += 1; // dangling round CSR
        }
        assert!(bad.validate(total).is_err());
    }
}
