//! Request calculation: the `ADIOI_LUSTRE_Calc_my_req` /
//! `ADIOI_Calc_others_req` equivalents.
//!
//! `calc_my_req` classifies a requester's flattened view against the file
//! domains: which bytes go to which global aggregator in which round
//! (stripe-aligned, so requests are additionally split at stripe
//! boundaries).  `calc_others_req` is, in ROMIO, the metadata exchange in
//! which aggregators learn the offset-length lists they will receive; the
//! simulator performs it as an accounted message exchange
//! (16 bytes per offset-length entry, matching ROMIO's packing).

use std::collections::HashMap;

use crate::mpisim::FlatView;

use super::filedomain::FileDomains;
use super::merge::ReqBatch;

/// Destination slot of one classified piece.
pub type DestKey = (u64, usize); // (round, aggregator index)

/// Builder for per-destination request batches.
#[derive(Debug, Default)]
struct DestAccum {
    offsets: Vec<u64>,
    lengths: Vec<u64>,
    payload: Vec<u8>,
}

/// Classified requests of one requester: per (round, aggregator) batches.
#[derive(Debug, Default)]
pub struct MyReqs {
    /// Per-destination sorted request batches.
    pub by_dest: HashMap<DestKey, ReqBatch>,
    /// Number of flattened request pieces classified (cost accounting).
    pub pieces: u64,
}

impl MyReqs {
    /// Destinations for a given round, ascending by aggregator.
    pub fn dests_in_round(&self, round: u64) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .by_dest
            .keys()
            .filter(|(r, _)| *r == round)
            .map(|&(_, a)| a)
            .collect();
        v.sort_unstable();
        v
    }

    /// Highest round index present.
    pub fn max_round(&self) -> Option<u64> {
        self.by_dest.keys().map(|&(r, _)| r).max()
    }
}

/// Classify one requester's batch against the file domains.
///
/// Splits requests at stripe boundaries (a request can span several
/// domains/rounds) and slices the payload accordingly.  The per-destination
/// lists inherit the source's ascending order, so aggregators can heap-merge
/// them directly.
pub fn calc_my_req(domains: &FileDomains, batch: &ReqBatch) -> MyReqs {
    let mut accum: HashMap<DestKey, DestAccum> = HashMap::new();
    let mut pieces = 0u64;
    let has_payload = !batch.payload.is_empty();
    let mut payload_cursor = 0u64;
    let stripe_size = domains.lustre.stripe_size;
    for (off, len) in batch.view.iter() {
        // Zero-length requests write nothing; skip (split_by_stripe
        // semantics).
        if len == 0 {
            continue;
        }
        // Inline stripe split (§Perf change 3): no per-request Vec from
        // split_by_stripe on this path — it dominates allocation volume
        // for the paper's hundreds of millions of small requests.
        let mut cur = off;
        let end = off + len;
        loop {
            let stripe_end = (cur / stripe_size + 1) * stripe_size;
            let piece_end = end.min(stripe_end);
            let (piece_off, piece_len) = (cur, piece_end - cur);
            let agg = domains.aggregator_of(piece_off);
            let round = domains.round_of(piece_off);
            let a = accum.entry((round, agg)).or_default();
            a.offsets.push(piece_off);
            a.lengths.push(piece_len);
            if has_payload {
                let start = (payload_cursor + (piece_off - off)) as usize;
                a.payload
                    .extend_from_slice(&batch.payload[start..start + piece_len as usize]);
            }
            pieces += 1;
            if piece_end >= end {
                break;
            }
            cur = piece_end;
        }
        payload_cursor += len;
    }
    let by_dest = accum
        .into_iter()
        .map(|(k, a)| {
            (
                k,
                ReqBatch::new(FlatView::from_pairs_unchecked(a.offsets, a.lengths), a.payload),
            )
        })
        .collect();
    MyReqs { by_dest, pieces }
}

/// Bytes on the wire for the `calc_others_req` metadata describing `n`
/// offset-length entries (ROMIO packs two 8-byte words per entry).
pub fn metadata_bytes(n: u64) -> u64 {
    16 * n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lustre::LustreConfig;

    fn domains(n_agg: usize) -> FileDomains {
        // stripe 100 bytes, 4 OSTs, region [0, 1200)
        FileDomains::new(LustreConfig::new(100, 4), 0, 1200, n_agg)
    }

    fn batch(pairs: &[(u64, u64)]) -> ReqBatch {
        let view = FlatView::from_pairs(pairs.to_vec()).unwrap();
        let total = view.total_bytes();
        let payload: Vec<u8> = (0..total).map(|i| i as u8).collect();
        ReqBatch::new(view, payload)
    }

    #[test]
    fn single_request_single_dest() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(10, 20)]));
        assert_eq!(r.pieces, 1);
        assert_eq!(r.by_dest.len(), 1);
        let b = &r.by_dest[&(0, 0)];
        assert_eq!(b.view.iter().collect::<Vec<_>>(), vec![(10, 20)]);
        assert_eq!(b.payload, (0..20).map(|i| i as u8).collect::<Vec<_>>());
    }

    #[test]
    fn request_split_at_stripe_boundary() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(90, 20)]));
        assert_eq!(r.pieces, 2);
        let a = &r.by_dest[&(0, 0)];
        let b = &r.by_dest[&(0, 1)];
        assert_eq!(a.view.iter().collect::<Vec<_>>(), vec![(90, 10)]);
        assert_eq!(b.view.iter().collect::<Vec<_>>(), vec![(100, 10)]);
        // Payload split preserves byte identity.
        assert_eq!(a.payload, (0..10).map(|i| i as u8).collect::<Vec<_>>());
        assert_eq!(b.payload, (10..20).map(|i| i as u8).collect::<Vec<_>>());
    }

    #[test]
    fn rounds_assigned_beyond_first_cycle() {
        let d = domains(4);
        // Offset 450 → stripe 4 → round 1, aggregator 0.
        let r = calc_my_req(&d, &batch(&[(450, 10)]));
        assert!(r.by_dest.contains_key(&(1, 0)));
        assert_eq!(r.max_round(), Some(1));
    }

    #[test]
    fn per_dest_lists_stay_sorted() {
        let d = domains(2);
        let r = calc_my_req(&d, &batch(&[(0, 10), (200, 10), (410, 10), (600, 10)]));
        for b in r.by_dest.values() {
            assert!(b.view.validate().is_ok());
        }
    }

    #[test]
    fn empty_batch_empty_result() {
        let d = domains(4);
        let r = calc_my_req(&d, &ReqBatch::default());
        assert!(r.by_dest.is_empty());
        assert_eq!(r.pieces, 0);
        assert_eq!(r.max_round(), None);
    }

    #[test]
    fn dests_in_round_sorted() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(50, 10), (250, 10), (350, 10)]));
        assert_eq!(r.dests_in_round(0), vec![0, 2, 3]);
    }

    #[test]
    fn payload_bytes_conserved() {
        let d = domains(3);
        let b = batch(&[(95, 120), (700, 33)]);
        let total_in = b.view.total_bytes();
        let r = calc_my_req(&d, &b);
        let total_out: u64 = r.by_dest.values().map(|b| b.view.total_bytes()).sum();
        assert_eq!(total_in, total_out);
        let payload_out: usize = r.by_dest.values().map(|b| b.payload.len()).sum();
        assert_eq!(payload_out as u64, total_in);
    }

    #[test]
    fn metadata_bytes_packing() {
        assert_eq!(metadata_bytes(0), 0);
        assert_eq!(metadata_bytes(10), 160);
    }
}
