//! Request calculation: the `ADIOI_LUSTRE_Calc_my_req` /
//! `ADIOI_Calc_others_req` equivalents.
//!
//! `calc_my_req` classifies a requester's flattened view against the file
//! domains: which bytes go to which global aggregator in which round
//! (stripe-aligned, so requests are additionally split at stripe
//! boundaries).  `calc_others_req` is, in ROMIO, the metadata exchange in
//! which aggregators learn the offset-length lists they will receive; the
//! simulator performs it as an accounted message exchange
//! (16 bytes per offset-length entry, matching ROMIO's packing).
//!
//! Storage is dense (§Perf tentpole 2): aggregators are `0..n_agg` by
//! construction — the same trick as `cost_phase_with_pending`'s
//! rank-indexed accumulators.  For a non-overlapping view the pieces
//! arrive in nondecreasing `(round, aggregator)` order (offsets
//! nondecreasing ⇒ stripes nondecreasing ⇒ `(round, agg)`
//! lexicographically nondecreasing, since the stripe → `(round, agg)`
//! mapping is monotone), so almost every piece appends to the *tail*
//! batch of its aggregator's list and no per-destination `HashMap` is
//! needed; overlapping requests (legal on the read side) revisit an
//! earlier round of the same aggregator, found by binary search.  New
//! destinations are provably created in ascending `(round, agg)` order
//! even then, so the per-round destination lists come out presorted —
//! `dests_in_round` returns a precomputed CSR slice instead of filtering
//! + sorting the key set per round.

use crate::mpisim::FlatView;

use super::filedomain::FileDomains;
use super::merge::ReqBatch;

/// Destination slot of one classified piece.
pub type DestKey = (u64, usize); // (round, aggregator index)

/// Builder for per-destination request batches.
#[derive(Debug, Default)]
struct DestAccum {
    offsets: Vec<u64>,
    lengths: Vec<u64>,
    payload: Vec<u8>,
}

/// Classified requests of one requester: per `(round, aggregator)` batches
/// stored densely by aggregator id, with a CSR round index.
#[derive(Debug, Default)]
pub struct MyReqs {
    /// Per-aggregator `(round, batch)` lists, ascending by round
    /// (aggregators are `0..n_agg` — the dense-destination invariant).
    per_agg: Vec<Vec<(u64, ReqBatch)>>,
    /// Per-aggregator drain cursor for the in-order round loop.
    cursor: Vec<usize>,
    /// CSR round index: the aggregators with data in round `r` are
    /// `round_aggs[round_starts[r]..round_starts[r + 1]]`, ascending.
    /// `round_starts` has `max_round + 2` entries (empty when no batches).
    round_aggs: Vec<usize>,
    round_starts: Vec<usize>,
    /// Number of flattened request pieces classified (cost accounting).
    pub pieces: u64,
}

impl MyReqs {
    /// Destinations for a given round, ascending by aggregator — a
    /// precomputed slice (no per-round filter + sort).
    pub fn dests_in_round(&self, round: u64) -> &[usize] {
        let r = round as usize;
        if r + 1 < self.round_starts.len() {
            &self.round_aggs[self.round_starts[r]..self.round_starts[r + 1]]
        } else {
            &[]
        }
    }

    /// Highest round index present.
    pub fn max_round(&self) -> Option<u64> {
        // `round_starts` is empty or has `max_round + 2` entries.
        self.round_starts.len().checked_sub(2).map(|r| r as u64)
    }

    /// Total number of `(round, aggregator)` destinations.
    pub fn n_dests(&self) -> usize {
        self.round_aggs.len()
    }

    /// Borrow the batch for `(round, agg)`, if present (binary search over
    /// the aggregator's round-sorted list; off the hot path).
    pub fn get(&self, round: u64, agg: usize) -> Option<&ReqBatch> {
        let list = self.per_agg.get(agg)?;
        list.binary_search_by_key(&round, |(r, _)| *r).ok().map(|i| &list[i].1)
    }

    /// Iterate all `(dest, batch)` pairs, grouped by aggregator and
    /// ascending by round within each.
    pub fn iter(&self) -> impl Iterator<Item = (DestKey, &ReqBatch)> + '_ {
        self.per_agg
            .iter()
            .enumerate()
            .flat_map(|(a, list)| list.iter().map(move |(r, b)| ((*r, a), b)))
    }

    /// Per-aggregator total request count across all rounds, ascending by
    /// aggregator, skipping aggregators with no data — sizes the
    /// `calc_others_req` metadata messages without a per-rank hash map.
    pub fn reqs_per_agg(&self) -> impl Iterator<Item = (usize, u64)> + '_ {
        self.per_agg.iter().enumerate().filter_map(|(a, list)| {
            if list.is_empty() {
                None
            } else {
                Some((a, list.iter().map(|(_, b)| b.view.len() as u64).sum()))
            }
        })
    }

    /// Drain round `round`'s batches in ascending-aggregator order.
    ///
    /// Rounds must be drained in ascending order (the exchange loop's
    /// access pattern); each batch is yielded exactly once, moved out of
    /// the per-aggregator storage.
    pub fn take_round(&mut self, round: u64) -> RoundDrain<'_> {
        RoundDrain { reqs: self, round, idx: 0 }
    }
}

/// Draining iterator over one round's `(aggregator, batch)` pairs — see
/// [`MyReqs::take_round`].
pub struct RoundDrain<'a> {
    reqs: &'a mut MyReqs,
    round: u64,
    idx: usize,
}

impl Iterator for RoundDrain<'_> {
    type Item = (usize, ReqBatch);

    fn next(&mut self) -> Option<(usize, ReqBatch)> {
        let agg = *self.reqs.dests_in_round(self.round).get(self.idx)?;
        self.idx += 1;
        let cur = self.reqs.cursor[agg];
        self.reqs.cursor[agg] = cur + 1;
        let (r, batch) = &mut self.reqs.per_agg[agg][cur];
        debug_assert_eq!(*r, self.round, "rounds must be drained in ascending order");
        Some((agg, std::mem::take(batch)))
    }
}

/// Classify one requester's batch against the file domains.
///
/// Splits requests at stripe boundaries (a request can span several
/// domains/rounds) and slices the payload accordingly.  The per-destination
/// lists inherit the source's ascending order, so aggregators can heap-merge
/// them directly.
pub fn calc_my_req(domains: &FileDomains, batch: &ReqBatch) -> MyReqs {
    let n_agg = domains.n_agg;
    let mut per_agg: Vec<Vec<(u64, DestAccum)>> = (0..n_agg).map(|_| Vec::new()).collect();
    let mut round_aggs: Vec<usize> = Vec::new();
    let mut round_starts: Vec<usize> = Vec::new();
    let mut pieces = 0u64;
    let has_payload = !batch.payload.is_empty();
    let mut payload_cursor = 0u64;
    let stripe_size = domains.lustre.stripe_size;
    for (off, len) in batch.view.iter() {
        // Zero-length requests write nothing; skip (split_by_stripe
        // semantics).
        if len == 0 {
            continue;
        }
        // Inline stripe split (§Perf change 3): no per-request Vec from
        // split_by_stripe on this path — it dominates allocation volume
        // for the paper's hundreds of millions of small requests.
        let mut cur = off;
        let end = off + len;
        loop {
            let stripe_end = (cur / stripe_size + 1) * stripe_size;
            let piece_end = end.min(stripe_end);
            let (piece_off, piece_len) = (cur, piece_end - cur);
            let agg = domains.aggregator_of(piece_off);
            let round = domains.round_of(piece_off);
            // Destination lookup: the tail batch for the common
            // (non-overlapping) case; an overlapping request revisits an
            // earlier round of this aggregator, which must already exist
            // (a view that reaches round r of an aggregator has covered
            // every earlier stripe of it that a later request can touch).
            let list = &mut per_agg[agg];
            let last_round = list.last().map(|(r, _)| *r);
            let idx = match last_round {
                Some(r) if r == round => list.len() - 1,
                Some(r) if r > round => list
                    .binary_search_by_key(&round, |(r, _)| *r)
                    .expect("overlapping request revisits a known round"),
                _ => {
                    // New destination.  These are created in ascending
                    // (round, agg) order even for overlapping views, so
                    // the CSR round index stays sorted by construction.
                    while round_starts.len() <= round as usize {
                        round_starts.push(round_aggs.len());
                    }
                    round_aggs.push(agg);
                    list.push((round, DestAccum::default()));
                    list.len() - 1
                }
            };
            let acc = &mut list[idx].1;
            acc.offsets.push(piece_off);
            acc.lengths.push(piece_len);
            if has_payload {
                let start = (payload_cursor + (piece_off - off)) as usize;
                acc.payload
                    .extend_from_slice(&batch.payload[start..start + piece_len as usize]);
            }
            pieces += 1;
            if piece_end >= end {
                break;
            }
            cur = piece_end;
        }
        payload_cursor += len;
    }
    if !round_starts.is_empty() {
        round_starts.push(round_aggs.len());
    }
    MyReqs {
        per_agg: per_agg
            .into_iter()
            .map(|list| {
                list.into_iter()
                    .map(|(r, a)| {
                        (
                            r,
                            ReqBatch::new(
                                FlatView::from_pairs_unchecked(a.offsets, a.lengths),
                                a.payload,
                            ),
                        )
                    })
                    .collect()
            })
            .collect(),
        cursor: vec![0; n_agg],
        round_aggs,
        round_starts,
        pieces,
    }
}

/// Bytes on the wire for the `calc_others_req` metadata describing `n`
/// offset-length entries (ROMIO packs two 8-byte words per entry).
pub fn metadata_bytes(n: u64) -> u64 {
    16 * n
}

/// The pre-tentpole `HashMap` implementation, kept verbatim as the golden
/// oracle for the dense rewrite (same pattern as the binary-search
/// `scatter_into_binary_search` reference).
#[cfg(test)]
pub(crate) fn calc_my_req_hashmap(
    domains: &FileDomains,
    batch: &ReqBatch,
) -> (std::collections::HashMap<DestKey, ReqBatch>, u64) {
    let mut accum: std::collections::HashMap<DestKey, DestAccum> = Default::default();
    let mut pieces = 0u64;
    let has_payload = !batch.payload.is_empty();
    let mut payload_cursor = 0u64;
    let stripe_size = domains.lustre.stripe_size;
    for (off, len) in batch.view.iter() {
        if len == 0 {
            continue;
        }
        let mut cur = off;
        let end = off + len;
        loop {
            let stripe_end = (cur / stripe_size + 1) * stripe_size;
            let piece_end = end.min(stripe_end);
            let (piece_off, piece_len) = (cur, piece_end - cur);
            let agg = domains.aggregator_of(piece_off);
            let round = domains.round_of(piece_off);
            let a = accum.entry((round, agg)).or_default();
            a.offsets.push(piece_off);
            a.lengths.push(piece_len);
            if has_payload {
                let start = (payload_cursor + (piece_off - off)) as usize;
                a.payload
                    .extend_from_slice(&batch.payload[start..start + piece_len as usize]);
            }
            pieces += 1;
            if piece_end >= end {
                break;
            }
            cur = piece_end;
        }
        payload_cursor += len;
    }
    let by_dest = accum
        .into_iter()
        .map(|(k, a)| {
            (
                k,
                ReqBatch::new(FlatView::from_pairs_unchecked(a.offsets, a.lengths), a.payload),
            )
        })
        .collect();
    (by_dest, pieces)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lustre::LustreConfig;
    use crate::util::SplitMix64;

    fn domains(n_agg: usize) -> FileDomains {
        // stripe 100 bytes, 4 OSTs, region [0, 1200)
        FileDomains::new(LustreConfig::new(100, 4), 0, 1200, n_agg)
    }

    fn batch(pairs: &[(u64, u64)]) -> ReqBatch {
        let view = FlatView::from_pairs(pairs.to_vec()).unwrap();
        let total = view.total_bytes();
        let payload: Vec<u8> = (0..total).map(|i| i as u8).collect();
        ReqBatch::new(view, payload)
    }

    #[test]
    fn single_request_single_dest() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(10, 20)]));
        assert_eq!(r.pieces, 1);
        assert_eq!(r.n_dests(), 1);
        let b = r.get(0, 0).unwrap();
        assert_eq!(b.view.iter().collect::<Vec<_>>(), vec![(10, 20)]);
        assert_eq!(b.payload, (0..20).map(|i| i as u8).collect::<Vec<_>>());
    }

    #[test]
    fn request_split_at_stripe_boundary() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(90, 20)]));
        assert_eq!(r.pieces, 2);
        let a = r.get(0, 0).unwrap();
        let b = r.get(0, 1).unwrap();
        assert_eq!(a.view.iter().collect::<Vec<_>>(), vec![(90, 10)]);
        assert_eq!(b.view.iter().collect::<Vec<_>>(), vec![(100, 10)]);
        // Payload split preserves byte identity.
        assert_eq!(a.payload, (0..10).map(|i| i as u8).collect::<Vec<_>>());
        assert_eq!(b.payload, (10..20).map(|i| i as u8).collect::<Vec<_>>());
    }

    #[test]
    fn rounds_assigned_beyond_first_cycle() {
        let d = domains(4);
        // Offset 450 → stripe 4 → round 1, aggregator 0.
        let r = calc_my_req(&d, &batch(&[(450, 10)]));
        assert!(r.get(1, 0).is_some());
        assert_eq!(r.max_round(), Some(1));
        assert_eq!(r.dests_in_round(0), &[] as &[usize]);
        assert_eq!(r.dests_in_round(1), &[0]);
    }

    #[test]
    fn per_dest_lists_stay_sorted() {
        let d = domains(2);
        let r = calc_my_req(&d, &batch(&[(0, 10), (200, 10), (410, 10), (600, 10)]));
        for (_, b) in r.iter() {
            assert!(b.view.validate().is_ok());
        }
    }

    #[test]
    fn empty_batch_empty_result() {
        let d = domains(4);
        let r = calc_my_req(&d, &ReqBatch::default());
        assert_eq!(r.n_dests(), 0);
        assert_eq!(r.pieces, 0);
        assert_eq!(r.max_round(), None);
        assert_eq!(r.dests_in_round(0), &[] as &[usize]);
        assert_eq!(r.reqs_per_agg().count(), 0);
    }

    #[test]
    fn dests_in_round_sorted() {
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(50, 10), (250, 10), (350, 10)]));
        assert_eq!(r.dests_in_round(0), &[0, 2, 3]);
    }

    #[test]
    fn payload_bytes_conserved() {
        let d = domains(3);
        let b = batch(&[(95, 120), (700, 33)]);
        let total_in = b.view.total_bytes();
        let r = calc_my_req(&d, &b);
        let total_out: u64 = r.iter().map(|(_, b)| b.view.total_bytes()).sum();
        assert_eq!(total_in, total_out);
        let payload_out: usize = r.iter().map(|(_, b)| b.payload.len()).sum();
        assert_eq!(payload_out as u64, total_in);
    }

    #[test]
    fn take_round_drains_in_dest_order() {
        let d = domains(2);
        let src = batch(&[(0, 10), (150, 10), (390, 20), (800, 10)]);
        let mut r = calc_my_req(&d, &src);
        let mut drained: Vec<(u64, usize)> = Vec::new();
        let mut payload_cat: Vec<u8> = Vec::new();
        for round in 0..=r.max_round().unwrap() {
            for (agg, b) in r.take_round(round) {
                drained.push((round, agg));
                payload_cat.extend_from_slice(&b.payload);
            }
        }
        // Lexicographically ascending keys, every dest exactly once.
        assert!(drained.windows(2).all(|w| w[0] < w[1]), "{drained:?}");
        assert_eq!(drained.len(), r.n_dests());
        // Concatenation in (round, agg) order reproduces the source payload
        // — the invariant the read path's reply assembly relies on.
        assert_eq!(payload_cat, src.payload);
    }

    #[test]
    fn metadata_bytes_packing() {
        assert_eq!(metadata_bytes(0), 0);
        assert_eq!(metadata_bytes(10), 160);
    }

    /// Random view with gaps, zero-length requests, single-byte requests
    /// straddling stripe boundaries (offset ≡ -1 mod stripe), and
    /// occasional overlapping requests (legal on the read side).
    fn random_batch(rng: &mut SplitMix64, stripe: u64, with_payload: bool) -> ReqBatch {
        let n = rng.gen_range(60) as usize;
        let mut pairs = Vec::with_capacity(n);
        let mut cursor = rng.gen_range(stripe * 3);
        for _ in 0..n {
            if rng.gen_bool(0.4) {
                cursor += rng.gen_range(stripe * 2);
            }
            let len = match rng.gen_range(4) {
                0 => 0,                              // zero-length request
                1 => {
                    // Single-byte request straddler setup: jump to the last
                    // byte of a stripe so the *next* request straddles.
                    cursor = (cursor / stripe + 1) * stripe - 1;
                    1
                }
                2 => 1 + rng.gen_range(2 * stripe),  // may span stripes
                _ => 1 + rng.gen_range(stripe / 2),
            };
            let off = cursor;
            pairs.push((off, len));
            if rng.gen_bool(0.15) {
                // Rewind inside the request just pushed: the next request
                // overlaps it (offsets stay nondecreasing).
                cursor = off + rng.gen_range(len.max(1));
            } else {
                cursor += len;
            }
        }
        let view = FlatView::from_pairs(pairs).unwrap();
        let payload = if with_payload {
            (0..view.total_bytes()).map(|i| (i as u8).wrapping_mul(167)).collect()
        } else {
            Vec::new()
        };
        ReqBatch::new(view, payload)
    }

    #[test]
    fn dense_matches_hashmap_oracle_randomized() {
        let mut rng = SplitMix64::new(0xD0_5E);
        for case in 0..200 {
            let stripe = [16u64, 100, 256][rng.gen_range(3) as usize];
            let n_agg = 1 + rng.gen_range(8) as usize;
            let with_payload = rng.gen_bool(0.7);
            let b = random_batch(&mut rng, stripe, with_payload);
            let lo = b.view.min_offset().unwrap_or(0);
            let hi = b.view.max_end().unwrap_or(0);
            let d = FileDomains::new(LustreConfig::new(stripe, 4), lo, hi, n_agg);
            if d.n_stripes() == 0 {
                continue;
            }
            let dense = calc_my_req(&d, &b);
            let (oracle, oracle_pieces) = calc_my_req_hashmap(&d, &b);
            assert_eq!(dense.pieces, oracle_pieces, "case {case}");
            assert_eq!(dense.n_dests(), oracle.len(), "case {case}");
            for (key, want) in &oracle {
                let got = dense
                    .get(key.0, key.1)
                    .unwrap_or_else(|| panic!("case {case}: missing dest {key:?}"));
                assert_eq!(
                    got.view.iter().collect::<Vec<_>>(),
                    want.view.iter().collect::<Vec<_>>(),
                    "case {case} dest {key:?} view"
                );
                assert_eq!(got.payload, want.payload, "case {case} dest {key:?} payload");
            }
            // dests_in_round must equal the sorted oracle key projection.
            if let Some(max) = dense.max_round() {
                for round in 0..=max {
                    let mut want: Vec<usize> = oracle
                        .keys()
                        .filter(|(r, _)| *r == round)
                        .map(|&(_, a)| a)
                        .collect();
                    want.sort_unstable();
                    assert_eq!(dense.dests_in_round(round), &want[..], "case {case} r{round}");
                }
            }
            assert_eq!(
                dense.max_round(),
                oracle.keys().map(|&(r, _)| r).max(),
                "case {case}"
            );
        }
    }

    #[test]
    fn overlapping_view_revisits_earlier_round() {
        // A 300-byte request followed by a nested 10-byte request: with
        // stripe 100 and 2 aggregators the nested request lands back in
        // (round 0, agg 0) *after* (round 1, agg 0) was created.
        let d = FileDomains::new(LustreConfig::new(100, 4), 0, 300, 2);
        let b = batch(&[(0, 300), (50, 10)]);
        let r = calc_my_req(&d, &b);
        let (oracle, oracle_pieces) = calc_my_req_hashmap(&d, &b);
        assert_eq!(r.pieces, oracle_pieces);
        assert_eq!(r.n_dests(), oracle.len());
        for (key, want) in &oracle {
            let got = r.get(key.0, key.1).unwrap();
            assert_eq!(
                got.view.iter().collect::<Vec<_>>(),
                want.view.iter().collect::<Vec<_>>(),
                "dest {key:?}"
            );
            assert_eq!(got.payload, want.payload, "dest {key:?}");
            got.view.validate().unwrap();
        }
        assert_eq!(r.get(0, 0).unwrap().view.iter().collect::<Vec<_>>(), vec![(0, 100), (50, 10)]);
    }

    #[test]
    fn single_byte_request_straddling_stripe_boundary() {
        // Two single-byte requests around the 100-byte stripe boundary and
        // one two-byte request straddling it.
        let d = domains(4);
        let r = calc_my_req(&d, &batch(&[(99, 1), (100, 1), (199, 2)]));
        assert_eq!(r.pieces, 4);
        assert_eq!(r.get(0, 0).unwrap().view.iter().collect::<Vec<_>>(), vec![(99, 1)]);
        assert_eq!(
            r.get(0, 1).unwrap().view.iter().collect::<Vec<_>>(),
            vec![(100, 1), (199, 1)]
        );
        assert_eq!(r.get(0, 2).unwrap().view.iter().collect::<Vec<_>>(), vec![(200, 1)]);
    }
}
