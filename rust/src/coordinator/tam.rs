//! The Two-layer Aggregation Method (§IV): intra-node aggregation to local
//! aggregators, then the two-phase exchange with only local aggregators as
//! requesters.
//!
//! Since the N-level refactor this module is a thin binding of the
//! depth-1 (node-level) [`AggregationPlan`]: [`tam_write`] delegates to
//! [`crate::coordinator::tree::tree_write`], and the intra-node stage
//! functions kept here ([`intra_node_aggregate`],
//! [`intra_node_read_views`]) are the node-level instantiations of the
//! generic per-level stages — preserved as the §IV-A API (and its tests)
//! while the pipeline itself lives in [`crate::coordinator::tree`].

use crate::coordinator::collective::ExchangeArena;
use crate::coordinator::merge::ReqBatch;
use crate::coordinator::tree::{
    aggregate_level_read_views, aggregate_level_write, tree_write, AggregationPlan,
};
use crate::coordinator::twophase::{CollectiveCtx, ExchangeOutcome};
use crate::error::Result;
use crate::lustre::LustreFile;
use crate::mpisim::FlatView;

/// TAM tuning knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TamConfig {
    /// Target total number of local aggregators `P_L` (the paper sweeps
    /// this; 256 is the empirically good value on Theta, §V-A).  Totals
    /// that do not divide evenly across nodes are distributed — the first
    /// `P_L mod nodes` nodes get one extra local aggregator.
    pub total_local_aggregators: usize,
}

impl Default for TamConfig {
    fn default() -> Self {
        TamConfig { total_local_aggregators: 256 }
    }
}

/// Result of the intra-node aggregation stage.
pub struct IntraOutcome {
    /// One aggregated batch per local aggregator `(rank, batch)`.
    pub local_batches: Vec<(usize, ReqBatch)>,
    /// Simulated gather-communication time.
    pub comm: f64,
    /// Simulated merge-sort time (max over local aggregators).
    pub sort: f64,
    /// Simulated contiguous-buffer memory-movement time.
    pub memcpy: f64,
    /// Gather messages (non-aggregators → local aggregators).
    pub msgs: usize,
    /// Requests before intra-node coalescing.
    pub reqs_before: u64,
    /// Requests after intra-node coalescing.
    pub reqs_after: u64,
}

/// Run intra-node aggregation: gather every rank's batch to its local
/// aggregator, merge-sort and coalesce there, and move payloads into
/// contiguous buffers (§IV-A).  Node-level instantiation of
/// [`aggregate_level_write`].
pub fn intra_node_aggregate(
    ctx: &CollectiveCtx,
    tam: &TamConfig,
    ranks: Vec<(usize, ReqBatch)>,
) -> Result<IntraOutcome> {
    let plan = AggregationPlan::for_tam(ctx.topo, tam);
    let reqs_before: u64 = ranks.iter().map(|(_, b)| b.view.len() as u64).sum();
    let mut slots = Vec::new();
    let stage = aggregate_level_write(ctx, &plan.levels[0], ranks, &mut slots)?;
    Ok(IntraOutcome {
        local_batches: stage.batches,
        comm: stage.comm,
        sort: stage.sort,
        memcpy: stage.memcpy,
        msgs: stage.msgs,
        reqs_before,
        reqs_after: stage.reqs_after,
    })
}

/// Result of the read-side intra-node stage (§IV-A in reverse).
pub struct IntraReadOutcome {
    /// One merged view per local aggregator `(rank, view)`, ascending by
    /// rank — the requester set of the inter-node read exchange.
    pub agg_views: Vec<(usize, FlatView)>,
    /// rank → its local aggregator (the reply-scatter plan).
    pub assignment: Vec<usize>,
    /// Simulated gather-communication time (metadata only).
    pub comm: f64,
    /// Simulated merge time (max over local aggregators).
    pub sort: f64,
    /// Gather messages (non-aggregators → local aggregators).
    pub msgs: usize,
}

/// Read-side intra-node stage: every rank sends its view *metadata* to its
/// local aggregator (no payload travels on the request side of a read),
/// which merges the member views through the engine into one sorted,
/// coalesced view per local aggregator.  Node-level instantiation of
/// [`aggregate_level_read_views`].
pub fn intra_node_read_views(
    ctx: &CollectiveCtx,
    tam: &TamConfig,
    views: &[(usize, FlatView)],
) -> Result<IntraReadOutcome> {
    let mut plan = AggregationPlan::for_tam(ctx.topo, tam);
    let mut slots = Vec::new();
    let stage = aggregate_level_read_views(ctx, &plan.levels[0], views, &mut slots)?;
    let assignment = std::mem::take(&mut plan.levels[0].assignment);
    Ok(IntraReadOutcome {
        agg_views: stage.agg_views,
        assignment,
        comm: stage.comm,
        sort: stage.sort,
        msgs: stage.msgs,
    })
}

/// Full TAM collective write: intra-node aggregation, then the inter-node
/// two-phase exchange over local aggregators, then the (unchanged) I/O
/// phase.  Thin binding of the depth-1 plan through [`tree_write`].
pub fn tam_write(
    ctx: &CollectiveCtx,
    tam: &TamConfig,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<ExchangeOutcome> {
    let plan = AggregationPlan::for_tam(ctx.topo, tam);
    tree_write(ctx, &plan, ranks, file, arena)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::breakdown::CpuModel;
    use crate::coordinator::placement::GlobalPlacement;
    use crate::lustre::{IoModel, LustreConfig};
    use crate::mpisim::rank::deterministic_payload;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    struct Fixture {
        topo: Topology,
        net: NetParams,
        cpu: CpuModel,
        io: IoModel,
        eng: NativeEngine,
    }

    impl Fixture {
        fn new(nodes: usize, ppn: usize) -> Self {
            Fixture {
                topo: Topology::new(nodes, ppn),
                net: NetParams::default(),
                cpu: CpuModel::default(),
                io: IoModel::default(),
                eng: NativeEngine,
            }
        }

        fn ctx(&self, n_agg: usize) -> CollectiveCtx<'_> {
            CollectiveCtx {
                topo: &self.topo,
                net: &self.net,
                cpu: &self.cpu,
                io: &self.io,
                engine: &self.eng,
                placement: GlobalPlacement::Spread,
                n_global_agg: n_agg,
            }
        }
    }

    fn block_ranks(topo: &Topology, block: u64, pieces: u64) -> Vec<(usize, ReqBatch)> {
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * block;
                let q = block / pieces;
                let view = FlatView::from_pairs(
                    (0..pieces).map(|i| (base + i * q, q)).collect(),
                )
                .unwrap();
                let payload = deterministic_payload(11, r, block);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn intra_aggregation_coalesces_block_pattern() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 2 }; // 1 per node
        let intra = intra_node_aggregate(&ctx, &tam, block_ranks(&f.topo, 64, 4)).unwrap();
        assert_eq!(intra.local_batches.len(), 2);
        assert_eq!(intra.reqs_before, 32);
        // Per node, 4 ranks × 64B contiguous → a single segment.
        assert_eq!(intra.reqs_after, 2);
        assert_eq!(intra.msgs, 6); // 3 non-aggregators per node
        assert!(intra.comm > 0.0 && intra.sort > 0.0 && intra.memcpy > 0.0);
    }

    #[test]
    fn intra_read_views_merge_members_through_engine() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 2 }; // 1 per node
        let views: Vec<(usize, FlatView)> = block_ranks(&f.topo, 64, 4)
            .into_iter()
            .map(|(r, b)| (r, b.view))
            .collect();
        let intra = intra_node_read_views(&ctx, &tam, &views).unwrap();
        assert_eq!(intra.agg_views.len(), 2);
        // Per node, 4 ranks × 64B contiguous → a single coalesced segment.
        assert!(intra.agg_views.iter().all(|(_, v)| v.len() == 1));
        assert_eq!(intra.msgs, 6); // 3 non-aggregators per node
        assert!(intra.comm > 0.0 && intra.sort > 0.0);
        for (r, _) in &views {
            assert!(f.topo.same_node(*r, intra.assignment[*r]));
        }
    }

    #[test]
    fn tam_write_lands_correct_bytes() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 4 };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let mut arena = ExchangeArena::default();
        tam_write(&ctx, &tam, block_ranks(&f.topo, 256, 4), &mut file, &mut arena).unwrap();
        for r in 0..f.topo.nprocs() {
            let want = deterministic_payload(11, r, 256);
            assert_eq!(file.read_at(r as u64 * 256, 256), want, "rank {r}");
        }
    }

    #[test]
    fn tam_equals_twophase_file_contents() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let mut f1 = LustreFile::new(LustreConfig::new(64, 4));
        let mut f2 = LustreFile::new(LustreConfig::new(64, 4));
        crate::coordinator::twophase::two_phase_write(
            &ctx,
            block_ranks(&f.topo, 128, 2),
            &mut f1,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        tam_write(
            &ctx,
            &TamConfig { total_local_aggregators: 2 },
            block_ranks(&f.topo, 128, 2),
            &mut f2,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        let total = 8 * 128;
        assert_eq!(f1.read_at(0, total), f2.read_at(0, total));
    }

    #[test]
    fn tam_with_pl_equal_p_matches_twophase_message_structure() {
        // §IV-D: two-phase I/O is the special case P_L == P (intra-node
        // stage degenerates: every rank is its own local aggregator).
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: f.topo.nprocs() };
        let intra =
            intra_node_aggregate(&ctx, &tam, block_ranks(&f.topo, 64, 4)).unwrap();
        assert_eq!(intra.msgs, 0, "no gather when P_L == P");
        assert_eq!(intra.comm, 0.0);
        assert_eq!(intra.local_batches.len(), f.topo.nprocs());
    }

    #[test]
    fn uneven_total_distributes_local_aggregators() {
        // §Satellite regression: P_L = 5 over 3 nodes of 4 must yield
        // exactly 5 local aggregators (2 + 2 + 1), not ceil-rounded 6.
        let f = Fixture::new(3, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 5 };
        let intra = intra_node_aggregate(&ctx, &tam, block_ranks(&f.topo, 64, 4)).unwrap();
        assert_eq!(intra.local_batches.len(), 5);
        let per_node: Vec<usize> = (0..3)
            .map(|n| {
                intra
                    .local_batches
                    .iter()
                    .filter(|(a, _)| f.topo.node_of(*a) == n)
                    .count()
            })
            .collect();
        assert_eq!(per_node, vec![2, 2, 1]);
    }

    #[test]
    fn tam_reduces_inter_node_in_degree() {
        let f = Fixture::new(4, 8);
        let ctx = f.ctx(2);
        let ranks = block_ranks(&f.topo, 128, 4);
        let mut f1 = LustreFile::new(LustreConfig::new(256, 2));
        let two = crate::coordinator::twophase::two_phase_write(
            &ctx,
            ranks.clone(),
            &mut f1,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        let mut f2 = LustreFile::new(LustreConfig::new(256, 2));
        let tam = tam_write(
            &ctx,
            &TamConfig { total_local_aggregators: 4 },
            ranks,
            &mut f2,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        assert!(
            tam.counters.max_in_degree < two.counters.max_in_degree,
            "TAM {} vs 2P {}",
            tam.counters.max_in_degree,
            two.counters.max_in_degree
        );
    }
}
