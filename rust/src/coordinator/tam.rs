//! The Two-layer Aggregation Method (§IV): intra-node aggregation to local
//! aggregators, then the two-phase exchange with only local aggregators as
//! requesters.

use crate::coordinator::breakdown::Counters;
use crate::coordinator::collective::ExchangeArena;
use crate::coordinator::merge::{scatter_into, ReqBatch};
use crate::coordinator::placement::{per_node_count_for_total, select_local_aggregators};
use crate::coordinator::reqcalc::metadata_bytes;
use crate::coordinator::twophase::{write_exchange, CollectiveCtx, ExchangeOutcome};
use crate::error::Result;
use crate::lustre::LustreFile;
use crate::mpisim::FlatView;
use crate::netmodel::phase::{cost_phase, Message};
use crate::util::par_map;

/// TAM tuning knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TamConfig {
    /// Target total number of local aggregators `P_L` (the paper sweeps
    /// this; 256 is the empirically good value on Theta, §V-A).
    pub total_local_aggregators: usize,
}

impl Default for TamConfig {
    fn default() -> Self {
        TamConfig { total_local_aggregators: 256 }
    }
}

/// Result of the intra-node aggregation stage.
pub struct IntraOutcome {
    /// One aggregated batch per local aggregator `(rank, batch)`.
    pub local_batches: Vec<(usize, ReqBatch)>,
    /// Simulated gather-communication time.
    pub comm: f64,
    /// Simulated merge-sort time (max over local aggregators).
    pub sort: f64,
    /// Simulated contiguous-buffer memory-movement time.
    pub memcpy: f64,
    /// Gather messages (non-aggregators → local aggregators).
    pub msgs: usize,
    /// Requests before intra-node coalescing.
    pub reqs_before: u64,
    /// Requests after intra-node coalescing.
    pub reqs_after: u64,
}

/// Run intra-node aggregation: gather every rank's batch to its local
/// aggregator, merge-sort and coalesce there, and move payloads into
/// contiguous buffers (§IV-A).
pub fn intra_node_aggregate(
    ctx: &CollectiveCtx,
    tam: &TamConfig,
    ranks: Vec<(usize, ReqBatch)>,
    ) -> Result<IntraOutcome> {
    let topo = ctx.topo;
    let c = per_node_count_for_total(topo, tam.total_local_aggregators);
    let locals = select_local_aggregators(topo, c);
    let reqs_before: u64 = ranks.iter().map(|(_, b)| b.view.len() as u64).sum();

    // Gather messages: every non-aggregator sends metadata + payload to its
    // local aggregator (many-to-one within each node, §IV-A).  Grouping is
    // dense by rank — local aggregators are rank ids (the dense-rank
    // invariant), so no hash map and no key sort, same as the read side.
    let mut msgs: Vec<Message> = Vec::new();
    let mut per_agg: Vec<Vec<ReqBatch>> = Vec::new();
    per_agg.resize_with(topo.nprocs(), Vec::new);
    for (rank, batch) in ranks {
        let agg = locals.assignment[rank];
        if rank != agg {
            // 16 bytes of metadata per request + the payload bytes.
            let bytes = batch.view.total_bytes() + 16 * batch.view.len() as u64;
            msgs.push(Message::new(rank, agg, bytes));
        }
        per_agg[agg].push(batch);
    }
    let comm_cost = cost_phase(ctx.net, ctx.topo, &msgs);

    // Local aggregators merge-sort + coalesce concurrently (engine hot
    // path) and build contiguous payload buffers.  Aggregators with at
    // least one member batch, ascending by rank.
    let mut items: Vec<(usize, Vec<ReqBatch>)> = Vec::with_capacity(locals.ranks.len());
    for &a in &locals.ranks {
        let batches = std::mem::take(&mut per_agg[a]);
        if !batches.is_empty() {
            items.push((a, batches));
        }
    }
    // The engine streams each member's already-sorted view (no flatten +
    // full re-sort on the native path); engine errors propagate as `Err`
    // instead of aborting the worker thread.
    let merged: Vec<Result<(usize, ReqBatch, f64, f64)>> = par_map(items, |(agg, batches)| {
        let k = batches.len();
        let n_items: u64 = batches.iter().map(|b| b.view.len() as u64).sum();
        let views: Vec<&FlatView> = batches.iter().map(|b| &b.view).collect();
        let view = ctx.engine.merge_sorted(&views)?;
        let (payload, moved) = scatter_into(&view, &batches);
        let sort_t = ctx.cpu.merge_time(n_items, k.max(1));
        let memcpy_t = ctx.cpu.memcpy_time(moved);
        Ok((agg, ReqBatch { view, payload }, sort_t, memcpy_t))
    });
    let merged: Vec<(usize, ReqBatch, f64, f64)> =
        merged.into_iter().collect::<Result<Vec<_>>>()?;

    let sort = merged.iter().map(|m| m.2).fold(0.0, f64::max);
    let memcpy = merged.iter().map(|m| m.3).fold(0.0, f64::max);
    let reqs_after: u64 = merged.iter().map(|m| m.1.view.len() as u64).sum();
    Ok(IntraOutcome {
        local_batches: merged.into_iter().map(|(a, b, _, _)| (a, b)).collect(),
        comm: comm_cost.time,
        sort,
        memcpy,
        msgs: msgs.len(),
        reqs_before,
        reqs_after,
    })
}

/// Result of the read-side intra-node stage (§IV-A in reverse).
pub struct IntraReadOutcome {
    /// One merged view per local aggregator `(rank, view)`, ascending by
    /// rank — the requester set of the inter-node read exchange.
    pub agg_views: Vec<(usize, FlatView)>,
    /// rank → its local aggregator (the reply-scatter plan).
    pub assignment: Vec<usize>,
    /// Simulated gather-communication time (metadata only).
    pub comm: f64,
    /// Simulated merge time (max over local aggregators).
    pub sort: f64,
    /// Gather messages (non-aggregators → local aggregators).
    pub msgs: usize,
}

/// Read-side intra-node stage: every rank sends its view *metadata* to its
/// local aggregator (no payload travels on the request side of a read),
/// which merges the member views through the engine into one sorted,
/// coalesced view per local aggregator.
///
/// Grouping is dense by rank (local aggregators are rank ids —
/// the dense-rank invariant), and the merge runs through
/// [`crate::runtime::engine::SortEngine::merge_sorted`] so reads and
/// writes share one engine entry point; engine errors propagate as `Err`.
pub fn intra_node_read_views(
    ctx: &CollectiveCtx,
    tam: &TamConfig,
    views: &[(usize, FlatView)],
) -> Result<IntraReadOutcome> {
    let topo = ctx.topo;
    let c = per_node_count_for_total(topo, tam.total_local_aggregators);
    let locals = select_local_aggregators(topo, c);

    let mut msgs: Vec<Message> = Vec::new();
    let mut per_agg: Vec<Vec<&FlatView>> = vec![Vec::new(); topo.nprocs()];
    for (rank, v) in views {
        let agg = locals.assignment[*rank];
        if *rank != agg {
            msgs.push(Message::new(*rank, agg, metadata_bytes(v.len() as u64)));
        }
        per_agg[agg].push(v);
    }
    let comm = cost_phase(ctx.net, ctx.topo, &msgs).time;

    // Local aggregators with at least one member view, ascending by rank.
    let mut items: Vec<(usize, Vec<&FlatView>)> = Vec::with_capacity(locals.ranks.len());
    for &a in &locals.ranks {
        let vs = std::mem::take(&mut per_agg[a]);
        if !vs.is_empty() {
            items.push((a, vs));
        }
    }
    let merged: Vec<Result<(usize, FlatView, f64)>> = par_map(items, |(agg, vs)| {
        let k = vs.len();
        let n: u64 = vs.iter().map(|v| v.len() as u64).sum();
        let view = ctx.engine.merge_sorted(&vs)?;
        Ok((agg, view, ctx.cpu.merge_time(n, k.max(1))))
    });
    let merged: Vec<(usize, FlatView, f64)> = merged.into_iter().collect::<Result<Vec<_>>>()?;

    let sort = merged.iter().map(|m| m.2).fold(0.0, f64::max);
    Ok(IntraReadOutcome {
        agg_views: merged.into_iter().map(|(a, v, _)| (a, v)).collect(),
        assignment: locals.assignment,
        comm,
        sort,
        msgs: msgs.len(),
    })
}

/// Full TAM collective write: intra-node aggregation, then the inter-node
/// two-phase exchange over local aggregators, then the (unchanged) I/O
/// phase.
pub fn tam_write(
    ctx: &CollectiveCtx,
    tam: &TamConfig,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<ExchangeOutcome> {
    let mut intra = intra_node_aggregate(ctx, tam, ranks)?;
    let local_batches = std::mem::take(&mut intra.local_batches);
    let mut out = write_exchange(ctx, local_batches, file, arena)?;
    out.breakdown.intra_comm = intra.comm;
    out.breakdown.intra_sort = intra.sort;
    out.breakdown.intra_memcpy = intra.memcpy;
    merge_counters(&mut out.counters, &intra);
    Ok(out)
}

fn merge_counters(c: &mut Counters, intra: &IntraOutcome) {
    c.reqs_posted = intra.reqs_before;
    c.reqs_after_intra = intra.reqs_after;
    c.msgs_intra = intra.msgs;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::breakdown::CpuModel;
    use crate::coordinator::placement::GlobalPlacement;
    use crate::lustre::{IoModel, LustreConfig};
    use crate::mpisim::rank::deterministic_payload;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    struct Fixture {
        topo: Topology,
        net: NetParams,
        cpu: CpuModel,
        io: IoModel,
        eng: NativeEngine,
    }

    impl Fixture {
        fn new(nodes: usize, ppn: usize) -> Self {
            Fixture {
                topo: Topology::new(nodes, ppn),
                net: NetParams::default(),
                cpu: CpuModel::default(),
                io: IoModel::default(),
                eng: NativeEngine,
            }
        }

        fn ctx(&self, n_agg: usize) -> CollectiveCtx<'_> {
            CollectiveCtx {
                topo: &self.topo,
                net: &self.net,
                cpu: &self.cpu,
                io: &self.io,
                engine: &self.eng,
                placement: GlobalPlacement::Spread,
                n_global_agg: n_agg,
            }
        }
    }

    fn block_ranks(topo: &Topology, block: u64, pieces: u64) -> Vec<(usize, ReqBatch)> {
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * block;
                let q = block / pieces;
                let view = FlatView::from_pairs(
                    (0..pieces).map(|i| (base + i * q, q)).collect(),
                )
                .unwrap();
                let payload = deterministic_payload(11, r, block);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn intra_aggregation_coalesces_block_pattern() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 2 }; // 1 per node
        let intra = intra_node_aggregate(&ctx, &tam, block_ranks(&f.topo, 64, 4)).unwrap();
        assert_eq!(intra.local_batches.len(), 2);
        assert_eq!(intra.reqs_before, 32);
        // Per node, 4 ranks × 64B contiguous → a single segment.
        assert_eq!(intra.reqs_after, 2);
        assert_eq!(intra.msgs, 6); // 3 non-aggregators per node
        assert!(intra.comm > 0.0 && intra.sort > 0.0 && intra.memcpy > 0.0);
    }

    #[test]
    fn intra_read_views_merge_members_through_engine() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 2 }; // 1 per node
        let views: Vec<(usize, FlatView)> = block_ranks(&f.topo, 64, 4)
            .into_iter()
            .map(|(r, b)| (r, b.view))
            .collect();
        let intra = intra_node_read_views(&ctx, &tam, &views).unwrap();
        assert_eq!(intra.agg_views.len(), 2);
        // Per node, 4 ranks × 64B contiguous → a single coalesced segment.
        assert!(intra.agg_views.iter().all(|(_, v)| v.len() == 1));
        assert_eq!(intra.msgs, 6); // 3 non-aggregators per node
        assert!(intra.comm > 0.0 && intra.sort > 0.0);
        for (r, _) in &views {
            assert!(f.topo.same_node(*r, intra.assignment[*r]));
        }
    }

    #[test]
    fn tam_write_lands_correct_bytes() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: 4 };
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let mut arena = ExchangeArena::default();
        tam_write(&ctx, &tam, block_ranks(&f.topo, 256, 4), &mut file, &mut arena).unwrap();
        for r in 0..f.topo.nprocs() {
            let want = deterministic_payload(11, r, 256);
            assert_eq!(file.read_at(r as u64 * 256, 256), want, "rank {r}");
        }
    }

    #[test]
    fn tam_equals_twophase_file_contents() {
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let mut f1 = LustreFile::new(LustreConfig::new(64, 4));
        let mut f2 = LustreFile::new(LustreConfig::new(64, 4));
        crate::coordinator::twophase::two_phase_write(
            &ctx,
            block_ranks(&f.topo, 128, 2),
            &mut f1,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        tam_write(
            &ctx,
            &TamConfig { total_local_aggregators: 2 },
            block_ranks(&f.topo, 128, 2),
            &mut f2,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        let total = 8 * 128;
        assert_eq!(f1.read_at(0, total), f2.read_at(0, total));
    }

    #[test]
    fn tam_with_pl_equal_p_matches_twophase_message_structure() {
        // §IV-D: two-phase I/O is the special case P_L == P (intra-node
        // stage degenerates: every rank is its own local aggregator).
        let f = Fixture::new(2, 4);
        let ctx = f.ctx(4);
        let tam = TamConfig { total_local_aggregators: f.topo.nprocs() };
        let intra =
            intra_node_aggregate(&ctx, &tam, block_ranks(&f.topo, 64, 4)).unwrap();
        assert_eq!(intra.msgs, 0, "no gather when P_L == P");
        assert_eq!(intra.comm, 0.0);
        assert_eq!(intra.local_batches.len(), f.topo.nprocs());
    }

    #[test]
    fn tam_reduces_inter_node_in_degree() {
        let f = Fixture::new(4, 8);
        let ctx = f.ctx(2);
        let ranks = block_ranks(&f.topo, 128, 4);
        let mut f1 = LustreFile::new(LustreConfig::new(256, 2));
        let two = crate::coordinator::twophase::two_phase_write(
            &ctx,
            ranks.clone(),
            &mut f1,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        let mut f2 = LustreFile::new(LustreConfig::new(256, 2));
        let tam = tam_write(
            &ctx,
            &TamConfig { total_local_aggregators: 4 },
            ranks,
            &mut f2,
            &mut ExchangeArena::default(),
        )
        .unwrap();
        assert!(
            tam.counters.max_in_degree < two.counters.max_in_degree,
            "TAM {} vs 2P {}",
            tam.counters.max_in_degree,
            two.counters.max_in_degree
        );
    }
}
