//! N-level aggregation trees: one hierarchical pipeline subsuming
//! two-phase I/O (depth 0) and the paper's TAM (depth 1).
//!
//! The paper's core idea — insert one intra-node aggregation layer in
//! front of two-phase redistribution — is the depth-1 special case of
//! multi-level aggregation over the machine hierarchy (hybrid MPI+MPI and
//! PiP-style collectives generalize exactly this).  An
//! [`AggregationPlan`] is a chain of
//! [`LevelAggregators`] computed once per collective from the hierarchical
//! [`Topology`] (socket → node → switch group): at each level, the
//! previous tier's participants gather their requests to that level's
//! aggregators, which merge and coalesce them through the same
//! `SortEngine` CSR merge + [`RoundScratch`] arena machinery the
//! inter-node exchange uses (arena slots are per-(level, aggregator) —
//! `ExchangeArena::levels`).  The top tier becomes the requester set of
//! the direction-generic round exchange
//! ([`crate::coordinator::collective::run_exchange`]); on reads the
//! replies scatter back down the same tree in reverse.
//!
//! * depth 0 (`AggregationPlan::flat`) — every rank is a requester:
//!   classic two-phase I/O, bit-for-bit.
//! * depth 1 at the node level ([`AggregationPlan::for_tam`]) — the
//!   paper's TAM, bit-for-bit (`tam.rs` is a thin binding of this plan).
//! * deeper trees (`tree:socket=4,node=2,switch=1`) — socket-level
//!   pre-aggregation and switch-group fan-in, priced by the per-tier link
//!   table ([`crate::netmodel::NetParams::msg_cost_tier`]).

use crate::cluster::{LevelKind, Topology};
use crate::coordinator::breakdown::LevelTime;
use crate::coordinator::collective::{
    exchange_read_with_plan, execute_exchange, CollectiveOutcome, ExchangeArena, ExchangeIo,
    ExchangePlan, ReadReply,
};
use crate::coordinator::merge::{gather_from_buf, ReqBatch, RoundScratch};
use crate::coordinator::placement::{
    per_node_counts_for_total, select_level_aggregators, LevelAggregators,
};
use crate::coordinator::reqcalc::metadata_bytes;
use crate::coordinator::tam::TamConfig;
use crate::coordinator::twophase::{write_exchange, CollectiveCtx, ExchangeOutcome};
use crate::error::{Error, Result};
use crate::lustre::LustreFile;
use crate::mpisim::FlatView;
use crate::netmodel::phase::{cost_phase, Message};
use crate::util::par_map;
use crate::util::runtime;

/// Per-group aggregator counts of an N-level tree — the
/// `--algorithm tree:socket=4,node=2,switch=1` knob.  A zero count
/// disables that level; all-zero is the depth-0 (two-phase) tree.  The
/// group geometry itself (sockets per node, nodes per switch, rank
/// placement) is a property of the [`Topology`], not of the algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TreeSpec {
    /// Aggregators per socket group (0 = no socket level).
    pub per_socket: usize,
    /// Aggregators per node (0 = no node level).
    pub per_node: usize,
    /// Aggregators per switch group (0 = no switch level).
    pub per_switch: usize,
}

impl Default for TreeSpec {
    /// Bare `tree`: a node-level tree with 4 aggregators per node.
    fn default() -> Self {
        TreeSpec { per_socket: 0, per_node: 4, per_switch: 0 }
    }
}

impl TreeSpec {
    /// The depth-0 tree (no aggregation levels — two-phase I/O).
    pub fn flat() -> Self {
        TreeSpec { per_socket: 0, per_node: 0, per_switch: 0 }
    }

    /// Number of active aggregation levels.
    pub fn depth(&self) -> usize {
        usize::from(self.per_socket > 0)
            + usize::from(self.per_node > 0)
            + usize::from(self.per_switch > 0)
    }

    /// Active `(level, per-group count)` pairs, innermost first.
    pub fn levels(&self) -> Vec<(LevelKind, usize)> {
        let mut out = Vec::with_capacity(3);
        if self.per_socket > 0 {
            out.push((LevelKind::Socket, self.per_socket));
        }
        if self.per_node > 0 {
            out.push((LevelKind::Node, self.per_node));
        }
        if self.per_switch > 0 {
            out.push((LevelKind::Switch, self.per_switch));
        }
        out
    }
}

impl std::fmt::Display for TreeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.depth() == 0 {
            return write!(f, "flat");
        }
        let mut first = true;
        for (kind, count) in self.levels() {
            if !first {
                write!(f, ",")?;
            }
            write!(f, "{kind}={count}")?;
            first = false;
        }
        Ok(())
    }
}

impl std::str::FromStr for TreeSpec {
    type Err = crate::Error;

    /// Parse the `tree:` argument list: comma-separated
    /// `socket=<n>`/`node=<n>`/`switch=<n>` pairs, or the literal `flat`
    /// for the depth-0 tree.
    fn from_str(s: &str) -> Result<Self> {
        if s == "flat" {
            return Ok(TreeSpec::flat());
        }
        if s.is_empty() {
            return Err(crate::Error::config(
                "empty tree spec (expected e.g. tree:socket=4,node=2)".to_string(),
            ));
        }
        let mut spec = TreeSpec::flat();
        let mut seen = [false; 3];
        for pair in s.split(',') {
            let (key, value) = pair.split_once('=').ok_or_else(|| {
                crate::Error::config(format!("bad tree level '{pair}' (expected level=count)"))
            })?;
            let count: usize = value.parse().map_err(|_| {
                crate::Error::config(format!("bad count in tree level '{pair}'"))
            })?;
            if count == 0 {
                return Err(crate::Error::config(format!(
                    "zero aggregator count in tree level '{pair}' \
                     (omit the level to disable it)"
                )));
            }
            let slot = match key {
                "socket" => 0,
                "node" => 1,
                "switch" => 2,
                other => {
                    return Err(crate::Error::config(format!(
                        "unknown tree level '{other}' (expected socket|node|switch)"
                    )))
                }
            };
            if seen[slot] {
                return Err(crate::Error::config(format!("duplicate tree level '{key}'")));
            }
            seen[slot] = true;
            match key {
                "socket" => spec.per_socket = count,
                "node" => spec.per_node = count,
                _ => spec.per_switch = count,
            }
        }
        Ok(spec)
    }
}

/// A fully-resolved N-level aggregation tree: one [`LevelAggregators`]
/// per level, innermost first.  Level 0's members are all ranks; level
/// `ℓ+1`'s members are level `ℓ`'s aggregators, so every rank reaches the
/// top tier through exactly one parent chain.
#[derive(Clone, Debug)]
pub struct AggregationPlan {
    /// Per-level selections, innermost first.
    pub levels: Vec<LevelAggregators>,
}

impl AggregationPlan {
    /// The depth-0 plan: no aggregation levels (two-phase I/O).
    pub fn flat() -> Self {
        AggregationPlan { levels: Vec::new() }
    }

    /// Number of aggregation levels.
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    /// Build the tree for a [`TreeSpec`]: each active level elects its
    /// per-group count among the previous tier's participants.
    pub fn from_spec(topo: &Topology, spec: &TreeSpec) -> Self {
        let mut members: Vec<usize> = (0..topo.nprocs()).collect();
        let mut levels = Vec::with_capacity(spec.depth());
        for (kind, per_group) in spec.levels() {
            let counts = vec![per_group; topo.n_groups(kind)];
            let level = select_level_aggregators(topo, kind, &members, &counts);
            members = level.ranks.clone();
            levels.push(level);
        }
        AggregationPlan { levels }
    }

    /// The paper's TAM as a depth-1 plan: node-level aggregators with the
    /// total `P_L` distributed across nodes
    /// ([`per_node_counts_for_total`]).
    pub fn for_tam(topo: &Topology, tam: &TamConfig) -> Self {
        let members: Vec<usize> = (0..topo.nprocs()).collect();
        let counts = per_node_counts_for_total(topo, tam.total_local_aggregators);
        AggregationPlan {
            levels: vec![select_level_aggregators(topo, LevelKind::Node, &members, &counts)],
        }
    }

    /// The plan for an [`Algorithm`](crate::coordinator::collective::Algorithm):
    /// depth 0 for two-phase, depth 1 for TAM, the spec's tree otherwise.
    ///
    /// # Panics
    ///
    /// `Algorithm::Auto` has no plan of its own — drivers resolve it to
    /// `Tree(spec)` via the auto-tuner before any plan is built, and
    /// the fallible entry points reject it with an error first.
    /// Reaching this match arm with `Auto` is therefore a caller bug.
    pub fn for_algorithm(
        topo: &Topology,
        algo: &crate::coordinator::collective::Algorithm,
    ) -> Self {
        use crate::coordinator::collective::Algorithm;
        match algo {
            Algorithm::TwoPhase => AggregationPlan::flat(),
            Algorithm::Tam(tam) => AggregationPlan::for_tam(topo, tam),
            Algorithm::Tree(spec) => AggregationPlan::from_spec(topo, spec),
            Algorithm::Auto => {
                panic!("Algorithm::Auto must be resolved to a Tree spec before planning")
            }
        }
    }

    /// `rank`'s parent chain through the tree, innermost level first —
    /// the aggregator it forwards to at each level (entry `ℓ` is the
    /// tier-`ℓ+1` representative of `rank`'s subtree).
    pub fn parent_chain(&self, rank: usize) -> Vec<usize> {
        let mut chain = Vec::with_capacity(self.depth());
        let mut rep = rank;
        for level in &self.levels {
            rep = level.parent_of(rep);
            chain.push(rep);
        }
        chain
    }
}

/// Dense rank → slot-position map over a rank list in slot order
/// (`usize::MAX` for ranks not present) — the addressing every tier stage
/// uses to route a member to its aggregator's scratch slot / parent
/// reply.
fn slot_index(ranks_in_slot_order: impl Iterator<Item = usize>, nprocs: usize) -> Vec<usize> {
    let mut slot_of = vec![usize::MAX; nprocs];
    for (i, r) in ranks_in_slot_order.enumerate() {
        slot_of[r] = i;
    }
    slot_of
}

/// Outcome of one level's write-direction aggregation stage.
pub struct LevelWriteOutcome {
    /// One merged batch per active aggregator `(rank, batch)`, ascending
    /// by rank — the next tier's participant set.
    pub batches: Vec<(usize, ReqBatch)>,
    /// Simulated gather-communication time (tier-priced).
    pub comm: f64,
    /// Simulated merge-sort time (max over this level's aggregators).
    pub sort: f64,
    /// Simulated contiguous-buffer movement time (max over aggregators).
    pub memcpy: f64,
    /// Gather messages (non-aggregator members → aggregators).
    pub msgs: usize,
    /// Requests remaining after this level's coalescing.
    pub reqs_after: u64,
}

/// Run one write-direction aggregation level: gather every member's batch
/// to its aggregator, merge-sort + coalesce there through the engine's
/// CSR path, and move payloads into contiguous buffers (§IV-A generalized
/// to any hierarchy level).  `slots` are this level's per-aggregator
/// [`RoundScratch`] arena slots (`ExchangeArena::levels[ℓ]`): staging
/// slabs, merged views and payload buffers keep their capacity across
/// collectives.
pub fn aggregate_level_write(
    ctx: &CollectiveCtx,
    level: &LevelAggregators,
    batches: Vec<(usize, ReqBatch)>,
    slots: &mut Vec<RoundScratch>,
) -> Result<LevelWriteOutcome> {
    let n_agg = level.ranks.len();
    if slots.len() < n_agg {
        slots.resize_with(n_agg, RoundScratch::default);
    }
    for slot in slots.iter_mut() {
        slot.reset_exchange(0);
    }
    let slot_of = slot_index(level.ranks.iter().copied(), ctx.topo.nprocs());

    // Gather messages: every non-aggregator member sends metadata +
    // payload to its aggregator (many-to-one within each group), priced
    // at the link tier the pair shares.  The batch itself is staged into
    // the aggregator's CSR slab — the simulator's stand-in for the
    // message landing in the receive buffer.
    let mut msgs: Vec<Message> = Vec::new();
    for (rank, batch) in &batches {
        let agg = level.parent_of(*rank);
        if *rank != agg {
            // 16 bytes of metadata per request + the payload bytes.
            let bytes = batch.view.total_bytes() + 16 * batch.view.len() as u64;
            msgs.push(Message::new(*rank, agg, bytes));
        }
        slots[slot_of[agg]].stage_batch(*rank, batch);
    }
    let comm = cost_phase(ctx.net, ctx.topo, &msgs).time;
    drop(batches);

    // Aggregators merge + scatter concurrently (engine hot path) — one
    // fine-grained task per slot on the persistent pool, mutated in
    // place so the level's arena capacity never moves; engine errors
    // and panics surface with the level kind + aggregator identity.
    let mut moved_bytes = vec![0u64; slots.len()];
    {
        let mut work: Vec<(&mut RoundScratch, &mut u64)> =
            slots.iter_mut().zip(moved_bytes.iter_mut()).collect();
        runtime::current().try_for_each_mut(
            &mut work,
            &|i| format!("write gather at {:?} level, aggregator slot {i}", level.kind),
            |_, (slot, moved)| {
                **moved = slot.merge_scatter(ctx.engine)?;
                Ok(())
            },
        )?;
    }

    let mut sort = 0.0f64;
    let mut memcpy = 0.0f64;
    let mut reqs_after = 0u64;
    let mut out_batches: Vec<(usize, ReqBatch)> = Vec::new();
    for (i, slot) in slots.iter().enumerate() {
        // Surplus slots from a larger earlier level stay warm and idle
        // (`k == 0`); only aggregators that received a member batch emit
        // a tier batch.
        if slot.k > 0 {
            sort = sort.max(ctx.cpu.merge_time(slot.n_items, slot.k));
            memcpy = memcpy.max(ctx.cpu.memcpy_time(moved_bytes[i]));
            reqs_after += slot.merged.len() as u64;
            // Deliberate copy-out: the outgoing batch is cloned from the
            // slot so the slot's buffers stay warm in the arena (a swap
            // would drain its capacity every collective).  This runs once
            // per level per collective — off the round loop the
            // allocation-free contract covers — and costs one memcpy of
            // the aggregated data, same order as the pre-refactor
            // scatter-into-fresh-buffer intra stage.
            out_batches
                .push((level.ranks[i], ReqBatch::new(slot.merged.clone(), slot.payload.clone())));
        }
    }
    Ok(LevelWriteOutcome {
        batches: out_batches,
        comm,
        sort,
        memcpy,
        msgs: msgs.len(),
        reqs_after,
    })
}

/// Outcome of one level's read-direction gather stage (§IV-A in reverse).
pub struct LevelReadOutcome {
    /// One merged view per active aggregator `(rank, view)`, ascending by
    /// rank — the next tier's participant set.
    pub agg_views: Vec<(usize, FlatView)>,
    /// Simulated gather-communication time (metadata only, tier-priced).
    pub comm: f64,
    /// Simulated merge time (max over this level's aggregators).
    pub sort: f64,
    /// Gather messages (non-aggregator members → aggregators).
    pub msgs: usize,
}

/// Run one read-direction gather level: every member sends its view
/// *metadata* to its aggregator (no payload travels on the request side
/// of a read), which merges the member views through the engine's CSR
/// path into one sorted, coalesced view per aggregator.
pub fn aggregate_level_read_views(
    ctx: &CollectiveCtx,
    level: &LevelAggregators,
    views: &[(usize, FlatView)],
    slots: &mut Vec<RoundScratch>,
) -> Result<LevelReadOutcome> {
    let n_agg = level.ranks.len();
    if slots.len() < n_agg {
        slots.resize_with(n_agg, RoundScratch::default);
    }
    for slot in slots.iter_mut() {
        slot.reset_exchange(0);
    }
    let slot_of = slot_index(level.ranks.iter().copied(), ctx.topo.nprocs());
    let mut msgs: Vec<Message> = Vec::new();
    for (rank, v) in views {
        let agg = level.parent_of(*rank);
        if *rank != agg {
            msgs.push(Message::new(*rank, agg, metadata_bytes(v.len() as u64)));
        }
        slots[slot_of[agg]].stage(*rank, v.offsets(), v.lengths(), &[], v.total_bytes());
    }
    let comm = cost_phase(ctx.net, ctx.topo, &msgs).time;

    // One task per slot on the persistent pool, mutated in place (see
    // aggregate_level_write); failures carry the level + slot identity.
    runtime::current().try_for_each_mut(
        slots.as_mut_slice(),
        &|i| format!("read gather at {:?} level, aggregator slot {i}", level.kind),
        |_, slot| {
            slot.merge_meta(ctx.engine)?;
            Ok(())
        },
    )?;

    let mut sort = 0.0f64;
    let mut agg_views: Vec<(usize, FlatView)> = Vec::new();
    for (i, slot) in slots.iter().enumerate() {
        if slot.k > 0 {
            sort = sort.max(ctx.cpu.merge_time(slot.n_items, slot.k));
            agg_views.push((level.ranks[i], slot.merged.clone()));
        }
    }
    Ok(LevelReadOutcome { agg_views, comm, sort, msgs: msgs.len() })
}

/// Collective write through an N-level aggregation tree: fold every
/// level's gather/merge stage, then run the direction-generic round
/// exchange with the top tier as the requester set.  Depth 0 is two-phase
/// I/O and depth 1 with a node-level plan is the paper's TAM
/// (equivalence pinned by `tests/read_write_roundtrip.rs` and the
/// carried-over 2P/TAM suites — see DESIGN.md §Aggregation tree for what
/// each pin covers).
pub fn tree_write(
    ctx: &CollectiveCtx,
    plan: &AggregationPlan,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<ExchangeOutcome> {
    tree_write_with(ctx, plan, None, ranks, file, arena)
}

/// [`tree_write`] over an optional cached [`ExchangePlan`] for the final
/// inter-node exchange: with `Some`, the top tier executes the borrowed
/// plan directly (zero plan construction —
/// [`crate::coordinator::plancache`]); with `None`, a fresh plan is built
/// inline.  The intra-node tiers always execute (payload must physically
/// move up the tree); only the structural classification work is cached.
pub fn tree_write_with(
    ctx: &CollectiveCtx,
    plan: &AggregationPlan,
    xplan: Option<&ExchangePlan>,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<ExchangeOutcome> {
    let reqs_posted: u64 = ranks.iter().map(|(_, b)| b.view.len() as u64).sum();
    if arena.levels.len() < plan.depth() {
        arena.levels.resize_with(plan.depth(), Vec::new);
    }
    let mut batches = ranks;
    let mut level_times: Vec<LevelTime> = Vec::with_capacity(plan.depth());
    let mut msgs_intra = 0usize;
    for (li, level) in plan.levels.iter().enumerate() {
        let stage = aggregate_level_write(ctx, level, batches, &mut arena.levels[li])?;
        batches = stage.batches;
        msgs_intra += stage.msgs;
        level_times.push(LevelTime {
            label: level.kind.label(),
            comm: stage.comm,
            sort: stage.sort,
            memcpy: stage.memcpy,
        });
    }
    let mut out = match xplan {
        Some(xp) => execute_exchange(ctx, xp, batches, ExchangeIo::Write(file), arena)?.1,
        None => write_exchange(ctx, batches, file, arena)?,
    };
    out.breakdown.intra_comm = level_times.iter().map(|l| l.comm).sum();
    out.breakdown.intra_sort = level_times.iter().map(|l| l.sort).sum();
    out.breakdown.intra_memcpy = level_times.iter().map(|l| l.memcpy).sum();
    out.breakdown.levels = level_times;
    out.counters.reqs_posted = reqs_posted;
    out.counters.msgs_intra = msgs_intra;
    Ok(out)
}

/// Collective read through an N-level aggregation tree: view metadata
/// merges *up* the tree level by level, the top tier drives the round
/// exchange ([`exchange_read_with_plan`]), and the reply bytes scatter back *down*
/// the same tree — each member gathers its bytes out of its parent's
/// reply with the two-pointer walk both directions share.  The top tier's
/// replies stay in the arena's pooled reply slab
/// ([`crate::coordinator::collective::ReplySlab`], `ExchangeArena::reply`);
/// only the per-member buffers handed to the caller are owned.
pub fn tree_read(
    ctx: &CollectiveCtx,
    plan: &AggregationPlan,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    tree_read_with(ctx, plan, None, views, file, arena)
}

/// [`tree_read`] over an optional cached [`ExchangePlan`] for the
/// top-tier exchange: with `Some`, the plan (built over the same
/// metadata-merged, overlap-prepared top tier —
/// [`crate::coordinator::plancache::build_collective_plan`]) executes
/// directly; with `None`, a fresh plan is built inline.
pub fn tree_read_with(
    ctx: &CollectiveCtx,
    plan: &AggregationPlan,
    xplan: Option<&ExchangePlan>,
    views: Vec<(usize, FlatView)>,
    file: &LustreFile,
    arena: &mut ExchangeArena,
) -> Result<(Vec<(usize, Vec<u8>)>, CollectiveOutcome)> {
    let posted: u64 = views.iter().map(|(_, v)| v.len() as u64).sum();
    if arena.levels.len() < plan.depth() {
        arena.levels.resize_with(plan.depth(), Vec::new);
    }

    // ---- Up the tree: merge view metadata level by level.
    let mut tiers: Vec<Vec<(usize, FlatView)>> = vec![views];
    let mut level_times: Vec<LevelTime> = Vec::with_capacity(plan.depth());
    let mut msgs_intra = 0usize;
    for (li, level) in plan.levels.iter().enumerate() {
        let tier = tiers.last().ok_or_else(|| {
            Error::Protocol("corrupt aggregation tree: missing tier 0 view set".into())
        })?;
        let stage = aggregate_level_read_views(ctx, level, tier, &mut arena.levels[li])?;
        msgs_intra += stage.msgs;
        level_times.push(LevelTime {
            label: level.kind.label(),
            comm: stage.comm,
            sort: stage.sort,
            memcpy: 0.0,
        });
        tiers.push(stage.agg_views);
    }

    // ---- Inter-node exchange at the top tier.
    let top = tiers.pop().ok_or_else(|| {
        Error::Protocol("corrupt aggregation tree: missing top-tier view set".into())
    })?;
    let (filled, out) = exchange_read_with_plan(ctx, xplan, top, file, arena)?;
    let mut bd = out.breakdown;
    let mut counters = out.counters;
    counters.reqs_posted = posted;

    // ---- Down the tree: scatter replies level by level.  Members are
    // independent (each reads only its parent's immutable reply), so the
    // gathers run concurrently like every other per-member stage.
    let mut parents: Vec<(usize, FlatView, ReadReply)> = filled;
    for (li, level) in plan.levels.iter().enumerate().rev() {
        let members = tiers.pop().ok_or_else(|| {
            Error::Protocol(format!(
                "corrupt aggregation tree: no member tier below level {li}"
            ))
        })?;
        let slot_of =
            slot_index(parents.iter().map(|(agg, _, _)| *agg), ctx.topo.nprocs());
        let parents_ref = &parents;
        let arena_ref = &*arena;
        let gathered: Vec<(usize, FlatView, ReadReply, u64, Option<Message>)> =
            par_map(members, |(rank, view)| {
                let agg = level.parent_of(rank);
                let total = view.total_bytes();
                let mut payload = vec![0u8; total as usize];
                if !view.is_empty() {
                    let j = slot_of[agg];
                    debug_assert_ne!(j, usize::MAX, "member view without aggregator");
                    let (_, pview, preply) = &parents_ref[j];
                    gather_from_buf(pview, preply.bytes(arena_ref), &view, &mut payload);
                }
                let msg = if rank != agg {
                    Some(Message::new(agg, rank, total))
                } else {
                    None
                };
                (rank, view, ReadReply::Owned(payload), total, msg)
            });
        let scatter_msgs: Vec<Message> =
            gathered.iter().filter_map(|(_, _, _, _, m)| *m).collect();
        let scattered_bytes: u64 = gathered.iter().map(|(_, _, _, b, _)| *b).sum();
        level_times[li].comm += cost_phase(ctx.net, ctx.topo, &scatter_msgs).time;
        level_times[li].memcpy += ctx.cpu.memcpy_time(scattered_bytes);
        msgs_intra += scatter_msgs.len();
        parents = gathered.into_iter().map(|(r, v, p, _, _)| (r, v, p)).collect();
    }

    bd.intra_comm = level_times.iter().map(|l| l.comm).sum();
    bd.intra_sort = level_times.iter().map(|l| l.sort).sum();
    bd.intra_memcpy = level_times.iter().map(|l| l.memcpy).sum();
    bd.levels = level_times;
    counters.msgs_intra = msgs_intra;

    // ---- Hand the caller owned buffers (the user-facing result); the
    // slab keeps everything else pooled.
    let reply_slab = &arena.reply;
    let result: Vec<(usize, Vec<u8>)> = parents
        .into_iter()
        .map(|(rank, _, reply)| {
            let bytes = match reply {
                ReadReply::Owned(v) => v,
                ReadReply::Slab(i) => reply_slab.of(i).to_vec(),
            };
            (rank, bytes)
        })
        .collect();
    Ok((result, CollectiveOutcome { breakdown: bd, counters }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RankPlacement;
    use crate::coordinator::breakdown::CpuModel;
    use crate::coordinator::placement::GlobalPlacement;
    use crate::lustre::{IoModel, LustreConfig};
    use crate::mpisim::rank::deterministic_payload;
    use crate::netmodel::NetParams;
    use crate::runtime::engine::NativeEngine;

    #[test]
    fn tree_spec_parses_and_displays() {
        let s: TreeSpec = "socket=4,node=2".parse().unwrap();
        assert_eq!(s, TreeSpec { per_socket: 4, per_node: 2, per_switch: 0 });
        assert_eq!(s.depth(), 2);
        assert_eq!(s.to_string(), "socket=4,node=2");
        let full: TreeSpec = "socket=4,node=2,switch=1".parse().unwrap();
        assert_eq!(full.depth(), 3);
        assert_eq!(
            full.levels(),
            vec![(LevelKind::Socket, 4), (LevelKind::Node, 2), (LevelKind::Switch, 1)]
        );
        assert_eq!("flat".parse::<TreeSpec>().unwrap(), TreeSpec::flat());
        assert_eq!(TreeSpec::flat().to_string(), "flat");
        assert_eq!(TreeSpec::flat().depth(), 0);
        assert!("".parse::<TreeSpec>().is_err());
        assert!("rack=2".parse::<TreeSpec>().is_err());
        assert!("node".parse::<TreeSpec>().is_err());
        assert!("node=x".parse::<TreeSpec>().is_err());
        let zero = "node=0".parse::<TreeSpec>().unwrap_err().to_string();
        assert!(zero.contains("zero aggregator count"), "{zero}");
        let dup = "socket=1,socket=2".parse::<TreeSpec>().unwrap_err().to_string();
        assert!(dup.contains("duplicate tree level 'socket'"), "{dup}");
    }

    #[test]
    fn plan_depth1_node_level_matches_tam_selection() {
        use crate::coordinator::placement::select_local_aggregators;
        let topo = Topology::new(2, 8);
        let plan =
            AggregationPlan::for_tam(&topo, &TamConfig { total_local_aggregators: 4 });
        assert_eq!(plan.depth(), 1);
        let local = select_local_aggregators(&topo, 2);
        assert_eq!(plan.levels[0].ranks, local.ranks);
        assert_eq!(plan.levels[0].assignment, local.assignment);
    }

    #[test]
    fn plan_chains_members_through_levels() {
        // 2 switch groups × 2 nodes × 8 ppn, 2 sockets per node.
        let topo = Topology::hierarchical(4, 8, 2, 2, RankPlacement::Block);
        let spec: TreeSpec = "socket=2,node=1,switch=1".parse().unwrap();
        let plan = AggregationPlan::from_spec(&topo, &spec);
        assert_eq!(plan.depth(), 3);
        // Level 0: 2 aggs per socket × 8 sockets = 16.
        assert_eq!(plan.levels[0].ranks.len(), 16);
        // Level 1: 1 per node × 4 nodes.
        assert_eq!(plan.levels[1].ranks.len(), 4);
        // Level 2: 1 per switch group × 2 groups.
        assert_eq!(plan.levels[2].ranks.len(), 2);
        for rank in 0..topo.nprocs() {
            let chain = plan.parent_chain(rank);
            assert_eq!(chain.len(), 3);
            // Each hop stays inside the level's group and lands on one of
            // that level's aggregators.
            let mut rep = rank;
            for (level, &parent) in plan.levels.iter().zip(&chain) {
                assert_eq!(
                    topo.group_of(level.kind, rep),
                    topo.group_of(level.kind, parent),
                    "rank {rank}: parent {parent} left the {} group",
                    level.kind
                );
                assert!(level.ranks.binary_search(&parent).is_ok());
                assert!(parent <= rep, "parent rank must not exceed member");
                rep = parent;
            }
        }
        // Each level's members are exactly the previous level's ranks.
        for w in plan.levels.windows(2) {
            for &r in &w[1].ranks {
                assert!(w[0].ranks.binary_search(&r).is_ok());
            }
        }
    }

    #[test]
    fn depth2_tree_write_and_read_round_trip() {
        let topo = Topology::hierarchical(2, 8, 2, 0, RankPlacement::Block);
        let net = NetParams::default();
        let cpu = CpuModel::default();
        let io = IoModel::default();
        let eng = NativeEngine;
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        };
        let spec: TreeSpec = "socket=2,node=1".parse().unwrap();
        let plan = AggregationPlan::from_spec(&topo, &spec);
        assert_eq!(plan.depth(), 2);
        let ranks: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * 200;
                let view =
                    FlatView::from_pairs(vec![(base, 120), (base + 150, 30)]).unwrap();
                (r, ReqBatch::new(view, deterministic_payload(21, r, 150)))
            })
            .collect();
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let mut arena = ExchangeArena::default();
        let out = tree_write(&ctx, &plan, ranks.clone(), &mut file, &mut arena).unwrap();
        assert_eq!(out.breakdown.levels.len(), 2);
        assert_eq!(out.breakdown.levels[0].label, "socket");
        assert_eq!(out.breakdown.levels[1].label, "node");
        assert!(out.breakdown.intra_comm > 0.0);
        assert!(out.counters.msgs_intra > 0);
        // Per-level split sums to the intra totals.
        let comm_split: f64 = out.breakdown.levels.iter().map(|l| l.comm).sum();
        assert!((comm_split - out.breakdown.intra_comm).abs() < 1e-15);

        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let (got, read_out) = tree_read(&ctx, &plan, views, &file, &mut arena).unwrap();
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "rank {r} depth-2 read-back");
        }
        assert_eq!(read_out.breakdown.levels.len(), 2);
        assert!(read_out.breakdown.intra_memcpy > 0.0);
        assert_eq!(read_out.counters.reqs_posted, out.counters.reqs_posted);
    }

    #[test]
    fn level_write_stage_reduces_participants() {
        let topo = Topology::hierarchical(1, 8, 2, 0, RankPlacement::Block);
        let net = NetParams::default();
        let cpu = CpuModel::default();
        let io = IoModel::default();
        let eng = NativeEngine;
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &net,
            cpu: &cpu,
            io: &io,
            engine: &eng,
            placement: GlobalPlacement::Spread,
            n_global_agg: 2,
        };
        let spec: TreeSpec = "socket=1".parse().unwrap();
        let plan = AggregationPlan::from_spec(&topo, &spec);
        let ranks: Vec<(usize, ReqBatch)> = (0..8)
            .map(|r| {
                let view = FlatView::from_pairs(vec![(r as u64 * 64, 64)]).unwrap();
                (r, ReqBatch::new(view, vec![r as u8; 64]))
            })
            .collect();
        let mut slots = Vec::new();
        let stage =
            aggregate_level_write(&ctx, &plan.levels[0], ranks, &mut slots).unwrap();
        // 2 sockets → 2 aggregators; each merges 4 contiguous blocks into
        // one segment.
        assert_eq!(stage.batches.len(), 2);
        assert_eq!(stage.reqs_after, 2);
        assert_eq!(stage.msgs, 6); // 3 non-aggregator members per socket
        assert!(stage.comm > 0.0 && stage.sort > 0.0 && stage.memcpy > 0.0);
        // The stage's aggregators are the plan's, in ascending order.
        let aggs: Vec<usize> = stage.batches.iter().map(|(a, _)| *a).collect();
        assert_eq!(aggs, plan.levels[0].ranks);
        for (_, b) in &stage.batches {
            assert_eq!(b.view.len(), 1);
            assert_eq!(b.view.total_bytes(), 256);
        }
    }
}
