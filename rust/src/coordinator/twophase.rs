//! ROMIO-style two-phase collective write — the baseline, and the
//! inter-node stage TAM reuses (§IV-B).
//!
//! The exchange is factored over an arbitrary *requester* set: for classic
//! two-phase I/O every rank is a requester; for TAM only the local
//! aggregators are.  All data movement is executed for real (payload bytes
//! land in the simulated Lustre file and can be read back); simulated time
//! is accounted per component exactly as the paper instruments ROMIO:
//! `calc_my_req`, `calc_others_req`, offset sort, datatype creation,
//! communication, and the I/O phase.
//!
//! The round loop itself lives in
//! [`crate::coordinator::collective::run_exchange`] — one
//! direction-generic engine shared with the collective read;
//! [`write_exchange`] binds it to the write direction.

use crate::cluster::Topology;
use crate::coordinator::breakdown::{Breakdown, Counters, CpuModel};
use crate::coordinator::collective::{run_exchange, ExchangeArena, ExchangeIo};
use crate::coordinator::merge::ReqBatch;
use crate::coordinator::placement::GlobalPlacement;
use crate::error::Result;
use crate::lustre::{IoModel, LustreFile};
use crate::netmodel::NetParams;
use crate::runtime::engine::SortEngine;

/// Shared context for one collective operation.
pub struct CollectiveCtx<'a> {
    /// Cluster topology.
    pub topo: &'a Topology,
    /// Network cost model.
    pub net: &'a NetParams,
    /// CPU cost model for the computation components.
    pub cpu: &'a CpuModel,
    /// I/O-phase cost model.
    pub io: &'a IoModel,
    /// Aggregator hot-path engine (native or XLA).
    pub engine: &'a dyn SortEngine,
    /// Global-aggregator placement policy.
    pub placement: GlobalPlacement,
    /// Number of global aggregators `P_G` (ROMIO-on-Lustre default:
    /// the stripe count).
    pub n_global_agg: usize,
}

/// Outcome of the inter-node exchange + I/O phase.
pub struct ExchangeOutcome {
    /// Component times (only the inter/I-O fields are filled here).
    pub breakdown: Breakdown,
    /// Volume counters.
    pub counters: Counters,
}

/// Run the two-phase exchange + I/O phase for a requester set.
///
/// `requesters` are `(rank, batch)` pairs with sorted views; payloads are
/// written byte-accurately into `file`.  Global aggregators are selected
/// from the full topology regardless of the requester set (ROMIO selects
/// at open time).  Thin write-direction binding of the shared
/// [`run_exchange`] round engine; `arena` carries the persistent round
/// buffers (sweeps thread one arena through every collective).
pub fn write_exchange(
    ctx: &CollectiveCtx,
    requesters: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<ExchangeOutcome> {
    let (_, out) = run_exchange(ctx, requesters, ExchangeIo::Write(file), arena)?;
    Ok(out)
}

/// Classic two-phase collective write: every rank is a requester.
pub fn two_phase_write(
    ctx: &CollectiveCtx,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
    arena: &mut ExchangeArena,
) -> Result<ExchangeOutcome> {
    let posted: u64 = ranks.iter().map(|(_, b)| b.view.len() as u64).sum();
    let mut out = write_exchange(ctx, ranks, file, arena)?;
    out.counters.reqs_posted = posted;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lustre::LustreConfig;
    use crate::mpisim::rank::deterministic_payload;
    use crate::mpisim::FlatView;
    use crate::runtime::engine::NativeEngine;

    fn ctx<'a>(
        topo: &'a Topology,
        net: &'a NetParams,
        cpu: &'a CpuModel,
        io: &'a IoModel,
        engine: &'a NativeEngine,
    ) -> CollectiveCtx<'a> {
        CollectiveCtx {
            topo,
            net,
            cpu,
            io,
            engine,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        }
    }

    fn requesters(topo: &Topology, block: u64) -> Vec<(usize, ReqBatch)> {
        // Rank r writes [r*block, (r+1)*block) split into 4 pieces.
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * block;
                let q = block / 4;
                let view = FlatView::from_pairs(vec![
                    (base, q),
                    (base + q, q),
                    (base + 2 * q, q),
                    (base + 3 * q, q),
                ])
                .unwrap();
                let payload = deterministic_payload(7, r, block);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn write_lands_correct_bytes() {
        let topo = Topology::new(2, 4);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let c = ctx(&topo, &net, &cpu, &io, &eng);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let reqs = requesters(&topo, 256);
        two_phase_write(&c, reqs, &mut file, &mut ExchangeArena::default()).unwrap();
        for r in 0..topo.nprocs() {
            let want = deterministic_payload(7, r, 256);
            let got = file.read_at(r as u64 * 256, 256);
            assert_eq!(got, want, "rank {r} bytes corrupted");
        }
    }

    #[test]
    fn multi_round_and_no_lock_conflicts() {
        let topo = Topology::new(2, 4);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let c = ctx(&topo, &net, &cpu, &io, &eng);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let mut arena = ExchangeArena::default();
        let out = two_phase_write(&c, requesters(&topo, 256), &mut file, &mut arena).unwrap();
        // 8 ranks × 256B = 2048B = 32 stripes of 64B over 4 aggs → 8 rounds.
        assert_eq!(out.counters.rounds, 8);
        assert_eq!(out.counters.lock_conflicts, 0, "stripe-aligned domains must not conflict");
        assert_eq!(out.counters.bytes, 2048);
        assert!(out.breakdown.total() > 0.0);
    }

    #[test]
    fn contiguous_pattern_coalesces_at_aggregators() {
        let topo = Topology::new(1, 4);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let mut c = ctx(&topo, &net, &cpu, &io, &eng);
        c.n_global_agg = 2;
        let mut file = LustreFile::new(LustreConfig::new(1 << 16, 2));
        let mut arena = ExchangeArena::default();
        let out = two_phase_write(&c, requesters(&topo, 256), &mut file, &mut arena).unwrap();
        // All 4 ranks' pieces are contiguous → one segment per agg/round.
        assert_eq!(out.counters.reqs_posted, 16);
        assert!(out.counters.reqs_at_io <= 2);
    }

    #[test]
    fn empty_requesters_noop() {
        let topo = Topology::new(1, 2);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let c = ctx(&topo, &net, &cpu, &io, &eng);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let out = two_phase_write(&c, vec![], &mut file, &mut ExchangeArena::default()).unwrap();
        assert_eq!(out.counters.rounds, 0);
        assert_eq!(file.total_bytes_written(), 0);
        assert_eq!(out.breakdown.total(), 0.0);
    }
}
