//! ROMIO-style two-phase collective write — the baseline, and the
//! inter-node stage TAM reuses (§IV-B).
//!
//! The exchange is factored over an arbitrary *requester* set: for classic
//! two-phase I/O every rank is a requester; for TAM only the local
//! aggregators are.  All data movement is executed for real (payload bytes
//! land in the simulated Lustre file and can be read back); simulated time
//! is accounted per component exactly as the paper instruments ROMIO:
//! `calc_my_req`, `calc_others_req`, offset sort, datatype creation,
//! communication, and the I/O phase.

use crate::cluster::Topology;
use crate::coordinator::breakdown::{Breakdown, Counters, CpuModel};
use crate::coordinator::filedomain::FileDomains;
use crate::coordinator::merge::{AggScratch, ReqBatch};
use crate::coordinator::placement::{select_global_aggregators, GlobalPlacement};
use crate::coordinator::reqcalc::{calc_my_req, metadata_bytes, MyReqs};
use crate::error::Result;
use crate::lustre::{IoModel, LustreFile};
use crate::netmodel::phase::{cost_phase, Message, PendingQueue};
use crate::netmodel::NetParams;
use crate::runtime::engine::SortEngine;
use crate::util::par_map;

/// Shared context for one collective operation.
pub struct CollectiveCtx<'a> {
    /// Cluster topology.
    pub topo: &'a Topology,
    /// Network cost model.
    pub net: &'a NetParams,
    /// CPU cost model for the computation components.
    pub cpu: &'a CpuModel,
    /// I/O-phase cost model.
    pub io: &'a IoModel,
    /// Aggregator hot-path engine (native or XLA).
    pub engine: &'a dyn SortEngine,
    /// Global-aggregator placement policy.
    pub placement: GlobalPlacement,
    /// Number of global aggregators `P_G` (ROMIO-on-Lustre default:
    /// the stripe count).
    pub n_global_agg: usize,
}

/// Outcome of the inter-node exchange + I/O phase.
pub struct ExchangeOutcome {
    /// Component times (only the inter/I-O fields are filled here).
    pub breakdown: Breakdown,
    /// Volume counters.
    pub counters: Counters,
}

/// Run the two-phase exchange + I/O phase for a requester set.
///
/// `requesters` are `(rank, batch)` pairs with sorted views; payloads are
/// written byte-accurately into `file`.  Global aggregators are selected
/// from the full topology regardless of the requester set (ROMIO selects
/// at open time).
pub fn write_exchange(
    ctx: &CollectiveCtx,
    requesters: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
) -> Result<ExchangeOutcome> {
    let mut bd = Breakdown::default();
    let mut counters = Counters::default();

    // Aggregate access region across requesters.
    let lo = requesters
        .iter()
        .filter_map(|(_, b)| b.view.min_offset())
        .min()
        .unwrap_or(0);
    let hi = requesters
        .iter()
        .filter_map(|(_, b)| b.view.max_end())
        .max()
        .unwrap_or(0);
    let n_agg = ctx.n_global_agg.min(ctx.topo.nprocs()).max(1);
    let domains = FileDomains::new(*file.config(), lo, hi, n_agg);
    let agg_ranks = select_global_aggregators(ctx.topo, n_agg, ctx.placement);

    counters.reqs_after_intra = requesters.iter().map(|(_, b)| b.view.len() as u64).sum();
    counters.bytes = requesters.iter().map(|(_, b)| b.view.total_bytes()).sum();

    // ---- ADIOI_LUSTRE_Calc_my_req: classify every requester's view.
    // Runs concurrently on all requesters → simulated time is the max.
    let my_reqs: Vec<(usize, MyReqs)> = par_map(requesters, |(rank, batch)| {
        let mr = calc_my_req(&domains, &batch);
        (rank, mr)
    });
    bd.calc_my_req = my_reqs
        .iter()
        .map(|(_, mr)| ctx.cpu.calc_req_time(mr.pieces))
        .fold(0.0, f64::max);

    // ---- ADIOI_Calc_others_req: metadata exchange (offset-length lists
    // travel to the aggregators once, covering all rounds).  Per-agg
    // totals come straight off the dense destination lists.
    let mut meta_msgs: Vec<Message> = Vec::new();
    for (rank, mr) in &my_reqs {
        for (agg, n) in mr.reqs_per_agg() {
            meta_msgs.push(Message::new(*rank, agg_ranks[agg], metadata_bytes(n)));
        }
    }
    let meta_cost = cost_phase(ctx.net, ctx.topo, &meta_msgs);
    bd.calc_others_req = meta_cost.time;
    counters.msgs_inter += meta_msgs.len();
    counters.max_in_degree = counters.max_in_degree.max(meta_cost.max_in_degree);

    let n_rounds = domains.n_rounds();
    counters.rounds = n_rounds;

    // ---- Rounds: data exchange, aggregator merge, datatype, I/O.
    let mut pending = PendingQueue::new();
    let mut my_reqs = my_reqs;
    // Per-aggregator scratch slots survive the round loop: the batch
    // staging Vec and the contiguous payload buffer keep their capacity
    // across rounds, eliminating the old per-round per_agg/payload
    // allocations (§Perf tentpole).
    let mut scratch: Vec<AggScratch> = (0..n_agg).map(|_| AggScratch::default()).collect();
    let mut data_msgs: Vec<Message> = Vec::new();
    for round in 0..n_rounds {
        // Collect this round's messages: requester → aggregator batches.
        // Batches are MOVED out of the requester state (no payload clone
        // on the hot path — §Perf change 1).
        data_msgs.clear();
        for slot in scratch.iter_mut() {
            slot.reset();
        }
        for (rank, mr) in my_reqs.iter_mut() {
            for (agg, b) in mr.take_round(round) {
                data_msgs.push(Message::new(*rank, agg_ranks[agg], b.view.total_bytes()));
                scratch[agg].batches.push(b);
            }
        }
        let comm = pending.cost_round(ctx.net, ctx.topo, &data_msgs);
        bd.inter_comm += comm.time;
        counters.msgs_inter += data_msgs.len();
        counters.max_in_degree = counters.max_in_degree.max(comm.max_in_degree);

        // Aggregator-side merge + datatype + write, concurrent across
        // aggregators → max for time, real bytes into the file.  The
        // engine streams the already-sorted peer views (no flatten + full
        // re-sort), and an engine failure propagates as `Err` instead of
        // aborting a worker thread.
        let merged: Vec<Result<AggScratch>> =
            par_map(std::mem::take(&mut scratch), |mut slot| {
                slot.merge_with(ctx.engine)?;
                Ok(slot)
            });
        scratch = merged.into_iter().collect::<Result<Vec<_>>>()?;

        let mut sort_t: f64 = 0.0;
        let mut dt_t: f64 = 0.0;
        file.begin_round();
        for (agg, slot) in scratch.iter().enumerate() {
            if slot.k == 0 {
                continue;
            }
            sort_t = sort_t.max(ctx.cpu.merge_time(slot.n_items, slot.k));
            dt_t = dt_t.max(ctx.cpu.datatype_time(slot.n_items, slot.k));
            counters.reqs_at_io += slot.merged.len() as u64;
            // The merged batch lies inside this aggregator's round domain
            // by construction; land the whole coalesced batch in one
            // vectored call.
            file.write_view(agg_ranks[agg], &slot.merged, &slot.payload)?;
        }
        bd.inter_sort += sort_t;
        bd.inter_datatype += dt_t;
    }

    // ---- I/O phase time from accumulated OST stats.
    bd.io_phase = ctx.io.phase_time(file.stats());
    counters.lock_conflicts = file.total_lock_conflicts();

    Ok(ExchangeOutcome { breakdown: bd, counters })
}

/// Classic two-phase collective write: every rank is a requester.
pub fn two_phase_write(
    ctx: &CollectiveCtx,
    ranks: Vec<(usize, ReqBatch)>,
    file: &mut LustreFile,
) -> Result<ExchangeOutcome> {
    let posted: u64 = ranks.iter().map(|(_, b)| b.view.len() as u64).sum();
    let mut out = write_exchange(ctx, ranks, file)?;
    out.counters.reqs_posted = posted;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lustre::LustreConfig;
    use crate::mpisim::rank::deterministic_payload;
    use crate::mpisim::FlatView;
    use crate::runtime::engine::NativeEngine;

    fn ctx<'a>(
        topo: &'a Topology,
        net: &'a NetParams,
        cpu: &'a CpuModel,
        io: &'a IoModel,
        engine: &'a NativeEngine,
    ) -> CollectiveCtx<'a> {
        CollectiveCtx {
            topo,
            net,
            cpu,
            io,
            engine,
            placement: GlobalPlacement::Spread,
            n_global_agg: 4,
        }
    }

    fn requesters(topo: &Topology, block: u64) -> Vec<(usize, ReqBatch)> {
        // Rank r writes [r*block, (r+1)*block) split into 4 pieces.
        (0..topo.nprocs())
            .map(|r| {
                let base = r as u64 * block;
                let q = block / 4;
                let view = FlatView::from_pairs(vec![
                    (base, q),
                    (base + q, q),
                    (base + 2 * q, q),
                    (base + 3 * q, q),
                ])
                .unwrap();
                let payload = deterministic_payload(7, r, block);
                (r, ReqBatch::new(view, payload))
            })
            .collect()
    }

    #[test]
    fn write_lands_correct_bytes() {
        let topo = Topology::new(2, 4);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let c = ctx(&topo, &net, &cpu, &io, &eng);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let reqs = requesters(&topo, 256);
        two_phase_write(&c, reqs, &mut file).unwrap();
        for r in 0..topo.nprocs() {
            let want = deterministic_payload(7, r, 256);
            let got = file.read_at(r as u64 * 256, 256);
            assert_eq!(got, want, "rank {r} bytes corrupted");
        }
    }

    #[test]
    fn multi_round_and_no_lock_conflicts() {
        let topo = Topology::new(2, 4);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let c = ctx(&topo, &net, &cpu, &io, &eng);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let out = two_phase_write(&c, requesters(&topo, 256), &mut file).unwrap();
        // 8 ranks × 256B = 2048B = 32 stripes of 64B over 4 aggs → 8 rounds.
        assert_eq!(out.counters.rounds, 8);
        assert_eq!(out.counters.lock_conflicts, 0, "stripe-aligned domains must not conflict");
        assert_eq!(out.counters.bytes, 2048);
        assert!(out.breakdown.total() > 0.0);
    }

    #[test]
    fn contiguous_pattern_coalesces_at_aggregators() {
        let topo = Topology::new(1, 4);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let mut c = ctx(&topo, &net, &cpu, &io, &eng);
        c.n_global_agg = 2;
        let mut file = LustreFile::new(LustreConfig::new(1 << 16, 2));
        let out = two_phase_write(&c, requesters(&topo, 256), &mut file).unwrap();
        // All 4 ranks' pieces are contiguous → one segment per agg/round.
        assert_eq!(out.counters.reqs_posted, 16);
        assert!(out.counters.reqs_at_io <= 2);
    }

    #[test]
    fn empty_requesters_noop() {
        let topo = Topology::new(1, 2);
        let (net, cpu, io, eng) =
            (NetParams::default(), CpuModel::default(), IoModel::default(), NativeEngine);
        let c = ctx(&topo, &net, &cpu, &io, &eng);
        let mut file = LustreFile::new(LustreConfig::new(64, 4));
        let out = two_phase_write(&c, vec![], &mut file).unwrap();
        assert_eq!(out.counters.rounds, 0);
        assert_eq!(file.total_bytes_written(), 0);
        assert_eq!(out.breakdown.total(), 0.0);
    }
}
