//! Crate error type.

/// Unified error type for the tamio pipeline.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Configuration errors (bad CLI flags, config files, topologies).
    #[error("config error: {0}")]
    Config(String),

    /// Workload-generation errors (invalid decompositions etc.).
    #[error("workload error: {0}")]
    Workload(String),

    /// Collective-I/O protocol violations (unsorted views, overlap rules…).
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Simulated-storage errors (OST bounds, lock conflicts in strict mode).
    #[error("storage error: {0}")]
    Storage(String),

    /// PJRT/XLA runtime errors (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Data verification mismatches (read-back != expected image).
    #[error("verification failed: {0}")]
    Verify(String),

    /// Underlying I/O errors (artifact files, report output).
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for formatted config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
