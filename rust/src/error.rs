//! Crate error type (hand-rolled Display/Error impls — `thiserror` is not
//! available in the offline image).

/// Unified error type for the tamio pipeline.
#[derive(Debug)]
pub enum Error {
    /// Configuration errors (bad CLI flags, config files, topologies).
    Config(String),

    /// Workload-generation errors (invalid decompositions etc.).
    Workload(String),

    /// Collective-I/O protocol violations (unsorted views, overlap rules…).
    Protocol(String),

    /// Simulated-storage errors (OST bounds, lock conflicts in strict mode).
    Storage(String),

    /// Persistent (fatal) OST failure: the faulting extent can never be
    /// served again.  Structured so tests and retry policy match on the
    /// variant, not message substrings.
    StorageFailed {
        /// Failing OST index.
        ost: usize,
        /// File offset of the faulting piece.
        offset: u64,
        /// Length of the faulting piece.
        len: u64,
        /// Accumulated `with_context` prefixes (empty = none).
        ctx: String,
    },

    /// Transient OST failure: retry-with-backoff is expected to succeed
    /// once the fault heals (`Error::is_transient` returns true).
    StorageTransient {
        /// Failing OST index.
        ost: usize,
        /// File offset of the faulting piece.
        offset: u64,
        /// Length of the faulting piece.
        len: u64,
        /// Accumulated `with_context` prefixes (empty = none).
        ctx: String,
    },

    /// PJRT/XLA runtime errors (artifact load, compile, execute).
    Runtime(String),

    /// Data verification mismatches (read-back != expected image).
    Verify(String),

    /// Underlying I/O errors (artifact files, report output).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Workload(msg) => write!(f, "workload error: {msg}"),
            Error::Protocol(msg) => write!(f, "protocol error: {msg}"),
            Error::Storage(msg) => write!(f, "storage error: {msg}"),
            Error::StorageFailed { ost, offset, len, ctx } => {
                let pre = if ctx.is_empty() { String::new() } else { format!("{ctx}: ") };
                write!(
                    f,
                    "storage error: {pre}OST {ost} failed (persistent) at offset {offset} len {len}"
                )
            }
            Error::StorageTransient { ost, offset, len, ctx } => {
                let pre = if ctx.is_empty() { String::new() } else { format!("{ctx}: ") };
                write!(
                    f,
                    "storage error: {pre}OST {ost} failed (transient) at offset {offset} len {len}"
                )
            }
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Verify(msg) => write!(f, "verification failed: {msg}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for formatted config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Persistent OST failure at a faulting extent.
    pub fn storage_failed(ost: usize, offset: u64, len: u64) -> Self {
        Error::StorageFailed { ost, offset, len, ctx: String::new() }
    }

    /// Transient (retryable) OST failure at a faulting extent.
    pub fn storage_transient(ost: usize, offset: u64, len: u64) -> Self {
        Error::StorageTransient { ost, offset, len, ctx: String::new() }
    }

    /// Whether a bounded retry-with-backoff may clear this error.  Only
    /// transient storage faults qualify; everything else is fatal and
    /// must surface immediately.
    pub fn is_transient(&self) -> bool {
        matches!(self, Error::StorageTransient { .. })
    }

    /// Prepend context (e.g. a failing task's identity) to the message
    /// while PRESERVING the variant — callers and tests match on the
    /// variant, so context must never rewrap a `Storage` error as
    /// something else.
    pub fn with_context(self, ctx: impl std::fmt::Display) -> Self {
        match self {
            Error::Config(m) => Error::Config(format!("{ctx}: {m}")),
            Error::Workload(m) => Error::Workload(format!("{ctx}: {m}")),
            Error::Protocol(m) => Error::Protocol(format!("{ctx}: {m}")),
            Error::Storage(m) => Error::Storage(format!("{ctx}: {m}")),
            Error::StorageFailed { ost, offset, len, ctx: c } => Error::StorageFailed {
                ost,
                offset,
                len,
                ctx: if c.is_empty() { ctx.to_string() } else { format!("{ctx}: {c}") },
            },
            Error::StorageTransient { ost, offset, len, ctx: c } => Error::StorageTransient {
                ost,
                offset,
                len,
                ctx: if c.is_empty() { ctx.to_string() } else { format!("{ctx}: {c}") },
            },
            Error::Runtime(m) => Error::Runtime(format!("{ctx}: {m}")),
            Error::Verify(m) => Error::Verify(format!("{ctx}: {m}")),
            Error::Io(e) => {
                Error::Io(std::io::Error::new(e.kind(), format!("{ctx}: {e}")))
            }
        }
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_kind() {
        assert_eq!(Error::config("bad").to_string(), "config error: bad");
        assert_eq!(Error::Storage("OST 3".into()).to_string(), "storage error: OST 3");
    }

    #[test]
    fn with_context_preserves_variant() {
        let e = Error::Storage("OST 3 down".into()).with_context("round 2, aggregator 7");
        assert!(matches!(e, Error::Storage(_)));
        assert_eq!(e.to_string(), "storage error: round 2, aggregator 7: OST 3 down");
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        let io = io.with_context("ctx");
        assert!(matches!(io, Error::Io(_)));
        assert_eq!(io.to_string(), "ctx: gone");
    }

    #[test]
    fn structured_storage_variants_format_and_keep_identity() {
        let e = Error::storage_failed(3, 128, 64);
        assert_eq!(
            e.to_string(),
            "storage error: OST 3 failed (persistent) at offset 128 len 64"
        );
        assert!(!e.is_transient());
        let t = Error::storage_transient(5, 0, 32);
        assert_eq!(
            t.to_string(),
            "storage error: OST 5 failed (transient) at offset 0 len 32"
        );
        assert!(t.is_transient());
        // Context nests outermost-first and preserves the variant + fields.
        let t = t.with_context("round 2, aggregator 7").with_context("read");
        assert!(matches!(t, Error::StorageTransient { ost: 5, offset: 0, len: 32, .. }));
        assert_eq!(
            t.to_string(),
            "storage error: read: round 2, aggregator 7: OST 5 failed (transient) at offset 0 len 32"
        );
    }

    #[test]
    fn io_errors_are_transparent() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert_eq!(e.to_string(), "gone");
        assert!(std::error::Error::source(&e).is_some());
    }
}
