//! Experiment drivers shared by the CLI and the bench harnesses — one
//! function per paper artifact (see DESIGN.md §4 experiment index).

use crate::cluster::Topology;
use crate::config::RunConfig;
use crate::coordinator::collective::{run_collective_write, Algorithm, CollectiveOutcome};
use crate::coordinator::tam::TamConfig;
use crate::coordinator::twophase::CollectiveCtx;
use crate::error::{Error, Result};
use crate::lustre::LustreFile;
use crate::metrics::{LabelledRun, ScalingSeries};
use crate::mpisim::rank::deterministic_payload;
use crate::netmodel::phase::in_degree_by_rank;
use crate::runtime::engine::{build_engine, SortEngine};
use crate::workloads::WorkloadKind;

/// Verification result of a collective write.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// Ranks whose read-back matched.
    pub ok: usize,
    /// Ranks checked.
    pub total: usize,
}

impl VerifyReport {
    /// All ranks verified.
    pub fn passed(&self) -> bool {
        self.ok == self.total
    }
}

/// Build the collective context pieces from a config (engine is returned
/// separately because `CollectiveCtx` borrows it).
pub fn build_engine_for(cfg: &RunConfig) -> Result<std::sync::Arc<dyn SortEngine>> {
    build_engine(cfg.engine)
}

/// Run one collective write per `cfg`; returns the labelled outcome and,
/// when `cfg.verify`, the byte-accurate read-back report.
pub fn run_once(cfg: &RunConfig) -> Result<(LabelledRun, Option<VerifyReport>)> {
    let engine = build_engine_for(cfg)?;
    run_once_with_engine(cfg, engine.as_ref())
}

/// [`run_once`] with a caller-provided engine (avoids reloading XLA
/// artifacts inside sweeps).
pub fn run_once_with_engine(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
) -> Result<(LabelledRun, Option<VerifyReport>)> {
    let topo = cfg.topology();
    let workload = cfg.workload.build(cfg.scale);
    let ranks = workload.generate(&topo, cfg.seed)?;
    let views: Vec<_> = ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();

    let ctx = CollectiveCtx {
        topo: &topo,
        net: &cfg.net,
        cpu: &cfg.cpu,
        io: &cfg.io,
        engine,
        placement: cfg.placement,
        n_global_agg: cfg.lustre.stripe_count,
    };
    let mut file = LustreFile::new(cfg.lustre);
    let outcome = run_collective_write(&ctx, cfg.algorithm, ranks, &mut file)?;

    let verify = if cfg.verify {
        let mut ok = 0;
        for (rank, view) in &views {
            let want = deterministic_payload(cfg.seed, *rank, view.total_bytes());
            let mut got = Vec::with_capacity(want.len());
            for (off, len) in view.iter() {
                got.extend_from_slice(&file.read_at(off, len));
            }
            if got == want {
                ok += 1;
            }
        }
        Some(VerifyReport { ok, total: views.len() })
    } else {
        None
    };

    Ok((
        LabelledRun {
            label: cfg.algorithm.name(),
            breakdown: outcome.breakdown,
            counters: outcome.counters,
        },
        verify,
    ))
}

/// Pick a workload scale divisor so the run materializes roughly
/// `budget_reqs` requests (the figures compare algorithms at identical
/// scale, so shapes are preserved — DESIGN.md §Substitutions).
pub fn auto_scale(kind: WorkloadKind, p: usize, budget_reqs: u64) -> u64 {
    let (paper_reqs, _) = kind.build(1).paper_scale(p);
    ((paper_reqs / budget_reqs as f64).ceil() as u64).max(1)
}

/// Figures 4–7: breakdown sweep over `P_L` values, final bar = two-phase.
pub fn breakdown_sweep(base: &RunConfig, pl_values: &[usize]) -> Result<Vec<LabelledRun>> {
    let engine = build_engine_for(base)?;
    let mut runs = Vec::new();
    for &pl in pl_values {
        let mut cfg = base.clone();
        cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: pl });
        let (mut run, _) = run_once_with_engine(&cfg, engine.as_ref())?;
        run.label = format!("P_L={pl}");
        runs.push(run);
    }
    let mut cfg = base.clone();
    cfg.algorithm = Algorithm::TwoPhase;
    let (mut run, _) = run_once_with_engine(&cfg, engine.as_ref())?;
    run.label = "two-phase".into();
    runs.push(run);
    Ok(runs)
}

/// Figure 3: strong-scaling bandwidth for one workload; returns the
/// TAM(P_L=256) and two-phase series.
pub fn fig3_series(
    base: &RunConfig,
    kind: WorkloadKind,
    proc_counts: &[usize],
    budget_reqs: u64,
) -> Result<Vec<ScalingSeries>> {
    let engine = build_engine_for(base)?;
    let mut tam_points = Vec::new();
    let mut two_points = Vec::new();
    for &p in proc_counts {
        if p % base.ppn != 0 {
            return Err(Error::config(format!("P={p} not divisible by ppn={}", base.ppn)));
        }
        let mut cfg = base.clone();
        cfg.workload = kind;
        cfg.nodes = p / base.ppn;
        cfg.scale = auto_scale(kind, p, budget_reqs);
        cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 256 });
        let (tam, _) = run_once_with_engine(&cfg, engine.as_ref())?;
        cfg.algorithm = Algorithm::TwoPhase;
        let (two, _) = run_once_with_engine(&cfg, engine.as_ref())?;
        tam_points.push((p, tam.breakdown.bandwidth(tam.counters.bytes)));
        two_points.push((p, two.breakdown.bandwidth(two.counters.bytes)));
    }
    Ok(vec![
        ScalingSeries { label: "TAM(P_L=256)".into(), points: tam_points },
        ScalingSeries { label: "two-phase".into(), points: two_points },
    ])
}

/// Figure 2: per-global-aggregator in-degree (congestion) for two-phase
/// vs TAM on the same workload.  Returns `(label, max_in_degree,
/// mean_in_degree, n_messages)` rows.
pub fn fig2_congestion(base: &RunConfig) -> Result<Vec<(String, usize, f64, usize)>> {
    let engine = build_engine_for(base)?;
    let mut rows = Vec::new();
    for algo in [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 256.min(base.nodes * base.ppn) }),
    ] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        let (run, _) = run_once_with_engine(&cfg, engine.as_ref())?;
        let c = &run.counters;
        let mean = if c.msgs_inter == 0 {
            0.0
        } else {
            c.msgs_inter as f64 / cfg.lustre.stripe_count.min(cfg.nodes * cfg.ppn) as f64
        };
        rows.push((algo.name(), c.max_in_degree, mean, c.msgs_inter));
    }
    Ok(rows)
}

/// Table I rows at a given topology + budget.
pub fn table1_rows(topo: &Topology, budget_reqs: u64) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    for kind in WorkloadKind::paper_set() {
        let scale = auto_scale(kind, topo.nprocs(), budget_reqs);
        let w = kind.build(scale);
        let stats = w.table_stats(topo)?;
        rows.push(vec![
            kind.to_string(),
            format!("{:.3e}", stats.paper_requests),
            crate::util::human_bytes(stats.paper_bytes),
            format!("{}", stats.n_requests),
            crate::util::human_bytes(stats.write_bytes),
            format!("1/{scale}"),
        ]);
    }
    Ok(rows)
}

/// Figures 4–7 driver: for each node count, sweep `P_L` (powers of four
/// up to `P`, always including 256 when it fits) plus the two-phase bar,
/// and print the breakdown table.  Shared by the fig4–fig7 benches and
/// the CLI.
pub fn run_breakdown_grid(
    kind: WorkloadKind,
    nodes_list: &[usize],
    ppn: usize,
    budget: u64,
) -> Result<()> {
    for &nodes in nodes_list {
        let p = nodes * ppn;
        let mut pls: Vec<usize> = [16usize, 64, 256, 1024, 4096]
            .into_iter()
            .filter(|&x| x >= nodes && x < p)
            .collect();
        if pls.is_empty() {
            pls.push(nodes);
        }
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        cfg.ppn = ppn;
        cfg.workload = kind;
        cfg.scale = auto_scale(kind, p, budget);
        println!(
            "\n{kind} @ {nodes} nodes x {ppn} ppn (P={p}), scale 1/{}, P_L sweep {pls:?} + two-phase:",
            cfg.scale
        );
        match breakdown_sweep(&cfg, &pls) {
            Ok(runs) => {
                print!("{}", crate::metrics::breakdown_table(&runs));
                // §IV-D crossover: report the best P_L.
                let best = runs
                    .iter()
                    .min_by(|a, b| {
                        a.breakdown.total().partial_cmp(&b.breakdown.total()).unwrap()
                    })
                    .unwrap();
                println!(
                    "best end-to-end: {} ({:.3} ms)  [paper: P_L=256 minimizes f(P_L)+g(P_L)]",
                    best.label,
                    best.breakdown.total() * 1e3
                );
                // Coalescing progression (paper §V-B quotes these counts).
                if let Some(r) = runs.first() {
                    println!(
                        "requests posted={} after-intra={} at-io={} (first bar)",
                        r.counters.reqs_posted, r.counters.reqs_after_intra, r.counters.reqs_at_io
                    );
                }
            }
            Err(e) => println!("skipped: {e}"),
        }
    }
    Ok(())
}

/// Message-matrix summary used by the Fig-2 bench: in-degree histogram of
/// an explicit message list (re-exported convenience).
pub fn in_degree_summary(msgs: &[crate::netmodel::Message]) -> (usize, f64) {
    let h = in_degree_by_rank(msgs);
    let max = h.values().copied().max().unwrap_or(0);
    let mean = if h.is_empty() {
        0.0
    } else {
        h.values().sum::<usize>() as f64 / h.len() as f64
    };
    (max, mean)
}

/// Convenience accessor for outcome totals in benches.
pub fn outcome_summary(o: &CollectiveOutcome) -> (f64, f64, f64, f64) {
    (
        o.breakdown.intra_total(),
        o.breakdown.inter_total(),
        o.breakdown.io_phase,
        o.breakdown.total(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.nodes = 2;
        cfg.ppn = 8;
        cfg.workload = WorkloadKind::Strided;
        cfg.lustre = crate::lustre::LustreConfig::new(1 << 16, 4);
        cfg.verify = true;
        cfg
    }

    #[test]
    fn run_once_verifies() {
        let cfg = small_cfg();
        let (run, verify) = run_once(&cfg).unwrap();
        let v = verify.unwrap();
        assert!(v.passed(), "verify failed: {}/{}", v.ok, v.total);
        assert!(run.breakdown.total() > 0.0);
        assert!(run.counters.bytes > 0);
    }

    #[test]
    fn run_once_tam_verifies() {
        let mut cfg = small_cfg();
        cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });
        let (_, verify) = run_once(&cfg).unwrap();
        assert!(verify.unwrap().passed());
    }

    #[test]
    fn breakdown_sweep_shapes() {
        let mut cfg = small_cfg();
        cfg.verify = false;
        let runs = breakdown_sweep(&cfg, &[2, 4, 8]).unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[3].label, "two-phase");
        // §IV-D: intra time decreases with more local aggregators.
        assert!(runs[0].breakdown.intra_total() >= runs[2].breakdown.intra_total());
    }

    #[test]
    fn auto_scale_reasonable() {
        let s = auto_scale(WorkloadKind::E3smF, 16384, 1_000_000);
        assert!(s >= 1000, "F case must scale down heavily, got {s}");
        assert_eq!(auto_scale(WorkloadKind::Contig, 64, 1_000_000), 1);
    }

    #[test]
    fn fig2_congestion_tam_lower() {
        let mut cfg = small_cfg();
        cfg.verify = false;
        let rows = fig2_congestion(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        // Row 0: two-phase; row 1: TAM — TAM's in-degree must not exceed.
        assert!(rows[1].1 <= rows[0].1);
    }
}
