//! Experiment drivers shared by the CLI and the bench harnesses — one
//! function per paper artifact (see DESIGN.md §4 experiment index).
//!
//! Every driver honours the [`RunConfig::direction`] axis: write runs
//! execute the collective write (optionally verifying the file by vectored
//! read-back), read runs pre-populate the file with the workload's image,
//! drive `run_collective_read`, and **always** verify the gathered bytes
//! against `deterministic_payload` — so a read panel that prints is a read
//! panel that round-tripped.

use crate::cluster::Topology;
use crate::config::RunConfig;
use crate::coordinator::autotune::{fingerprint_autotune, score_candidates, tune_collective};
use crate::coordinator::collective::{
    run_collective_read_with, run_collective_write_with, Algorithm, CollectiveOutcome,
    Direction, DirectionSpec, ExchangeArena,
};
use crate::coordinator::plancache::{
    run_collective_read_cached, run_collective_read_degraded, run_collective_write_cached,
    run_collective_write_degraded, PlanCache, PlanCacheStats,
};
use crate::coordinator::tam::TamConfig;
use crate::coordinator::twophase::CollectiveCtx;
use crate::error::{Error, Result};
use crate::faults::{self, FaultPlan};
use crate::lustre::{LustreFile, OstStats};
use crate::metrics::{LabelledRun, ScalingSeries, TunerValidation, TunerValidationRow};
use crate::mpisim::rank::deterministic_payload;
use crate::netmodel::phase::in_degree_by_rank;
use crate::runtime::engine::{build_engine, SortEngine};
use crate::workloads::WorkloadKind;

/// Verification result of a collective operation (file read-back for
/// writes, gathered-byte comparison for reads).
#[derive(Clone, Debug)]
pub struct VerifyReport {
    /// Ranks whose bytes matched.
    pub ok: usize,
    /// Ranks checked.
    pub total: usize,
}

impl VerifyReport {
    /// All ranks verified.
    pub fn passed(&self) -> bool {
        self.ok == self.total
    }
}

/// Build the collective context pieces from a config (engine is returned
/// separately because `CollectiveCtx` borrows it).
pub fn build_engine_for(cfg: &RunConfig) -> Result<std::sync::Arc<dyn SortEngine>> {
    build_engine(cfg.engine)
}

/// Run the collective(s) selected by `cfg` — one labelled outcome per
/// direction in `cfg.direction`, in execution order (write first).
pub fn run_once(cfg: &RunConfig) -> Result<Vec<(LabelledRun, Option<VerifyReport>)>> {
    let engine = build_engine_for(cfg)?;
    run_once_with_engine(cfg, engine.as_ref())
}

/// The run's plan cache per its config: directory-backed when
/// `--plan-cache` is set (plans persist across invocations), memory-only
/// otherwise.
pub fn plan_cache_for(cfg: &RunConfig) -> Result<PlanCache> {
    match &cfg.plan_cache {
        Some(dir) => PlanCache::with_dir(cfg.plan_cache_size, dir.as_str()),
        None => Ok(PlanCache::in_memory(cfg.plan_cache_size)),
    }
}

/// [`run_once`] with a caller-provided engine (avoids reloading XLA
/// artifacts inside sweeps).  One [`ExchangeArena`] and one [`PlanCache`]
/// serve every direction of the run.
pub fn run_once_with_engine(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
) -> Result<Vec<(LabelledRun, Option<VerifyReport>)>> {
    Ok(run_once_with_stats(cfg, engine)?.0)
}

/// [`run_once_with_engine`] also returning the run's plan-cache
/// statistics — what the CLI's `run` subcommand prints.
pub fn run_once_with_stats(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
) -> Result<(Vec<(LabelledRun, Option<VerifyReport>)>, PlanCacheStats)> {
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(cfg)?;
    let runs = cfg
        .direction
        .runs()
        .iter()
        .map(|&dir| run_direction_cached(cfg, engine, dir, &mut arena, &mut cache))
        .collect::<Result<Vec<_>>>()?;
    Ok((runs, cache.stats.clone()))
}

/// [`run_direction_with_arena`] with a one-shot arena (kept for callers
/// outside the sweep loops).
pub fn run_direction_with_engine(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
    direction: Direction,
) -> Result<(LabelledRun, Option<VerifyReport>)> {
    run_direction_with_arena(cfg, engine, direction, &mut ExchangeArena::default())
}

/// Run one collective in one direction per `cfg`; returns the labelled
/// outcome and the verification report (`Some` whenever `cfg.verify`, and
/// always for reads — the gathered bytes are already in memory, so the
/// comparison is nearly free and keeps read panels honest).  `arena` is
/// the persistent exchange-buffer set the sweep drivers thread through
/// every collective they run (§Perf tentpole: capacity reuse across
/// `run_once` invocations, not just across rounds).
pub fn run_direction_with_arena(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
    direction: Direction,
    arena: &mut ExchangeArena,
) -> Result<(LabelledRun, Option<VerifyReport>)> {
    run_direction_impl(cfg, engine, direction, arena, None)
}

/// [`run_direction_with_arena`] through a [`PlanCache`]: repeated calls
/// with the same structural inputs (checkpoint loops, sweep bars, both
/// directions of one pattern) reuse the collective plan instead of
/// rebuilding it.  Results are bit-identical to the uncached path — the
/// cache win is wall-clock only, visible in [`PlanCache::stats`].
pub fn run_direction_cached(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
    direction: Direction,
    arena: &mut ExchangeArena,
    cache: &mut PlanCache,
) -> Result<(LabelledRun, Option<VerifyReport>)> {
    run_direction_impl(cfg, engine, direction, arena, Some(cache))
}

/// Install the run's fault schedule on a freshly-created file: resolved
/// OST failures, the per-OST service-rate table, and the retry bound.
/// The round clock restarts so `@round:r` clauses count collective I/O
/// rounds from here (read runs call this *after* pre-population, so the
/// setup writes never consume transient countdowns).  A no-op when the
/// run is fault-free.
fn install_faults(cfg: &RunConfig, file: &mut LustreFile) -> Result<()> {
    let Some(plan) = &cfg.faults else { return Ok(()) };
    let resolved = plan.resolve_osts(file.config().stripe_count, cfg.fault_seed)?;
    for f in resolved.fails {
        file.faults_mut().install(f)?;
    }
    file.faults_mut().set_rates(resolved.rates)?;
    file.faults_mut().set_max_retries(cfg.max_retries);
    file.reset_fault_rounds();
    Ok(())
}

fn run_direction_impl(
    cfg: &RunConfig,
    engine: &dyn SortEngine,
    direction: Direction,
    arena: &mut ExchangeArena,
    mut cache: Option<&mut PlanCache>,
) -> Result<(LabelledRun, Option<VerifyReport>)> {
    let mut topo = cfg.topology();
    let workload = cfg.workload.build(cfg.scale);
    let ranks = workload.generate(&topo, cfg.seed)?;

    // Round pipelining is an execution-time property carried on the
    // arena: plans and their cache fingerprints never see it.
    arena.overlap = cfg.overlap;

    // `--algorithm auto`: resolve to a concrete tree + rank placement
    // before dispatch.  The tuner memo in the plan cache short-circuits
    // the candidate sweep on repeated structurally-identical runs; the
    // winner's executable plan then warms through the normal plan path.
    let mut algo = cfg.algorithm;
    let mut label = algo.name();
    if matches!(algo, Algorithm::Auto) {
        let (spec, placement) = {
            let tune_ctx = CollectiveCtx {
                topo: &topo,
                net: &cfg.net,
                cpu: &cfg.cpu,
                io: &cfg.io,
                engine,
                placement: cfg.placement,
                n_global_agg: cfg.lustre.stripe_count,
            };
            let fp = fingerprint_autotune(
                &tune_ctx,
                direction,
                &cfg.lustre,
                cfg.overlap,
                ranks.iter().map(|(r, b)| (*r, &b.view)),
            );
            match cache.as_deref().and_then(|c| c.tuner_choice(fp)) {
                Some(choice) => choice,
                None => {
                    let views: Vec<_> =
                        ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
                    let choice =
                        tune_collective(&tune_ctx, direction, &views, &cfg.lustre, cfg.overlap)?;
                    if let Some(c) = cache.as_deref_mut() {
                        c.remember_tuner_choice(fp, choice.spec, choice.placement);
                    }
                    (choice.spec, choice.placement)
                }
            }
        };
        algo = Algorithm::Tree(spec);
        label = format!("auto[{}]", algo.name());
        topo = Topology::hierarchical(
            cfg.nodes,
            cfg.ppn,
            cfg.sockets_per_node,
            cfg.nodes_per_switch,
            placement,
        );
    }

    let ctx = CollectiveCtx {
        topo: &topo,
        net: &cfg.net,
        cpu: &cfg.cpu,
        io: &cfg.io,
        engine,
        placement: cfg.placement,
        n_global_agg: cfg.lustre.stripe_count,
    };
    match direction {
        Direction::Write => {
            let views: Vec<_> = ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
            let mut file = LustreFile::new(cfg.lustre);
            install_faults(cfg, &mut file)?;
            let outcome = match (&cfg.faults, cache) {
                (Some(plan), cache) => run_collective_write_degraded(
                    &ctx,
                    algo,
                    ranks,
                    &mut file,
                    arena,
                    cache,
                    plan,
                    cfg.fault_seed,
                )?,
                (None, Some(cache)) => {
                    run_collective_write_cached(&ctx, algo, ranks, &mut file, arena, cache)?
                }
                (None, None) => run_collective_write_with(&ctx, algo, ranks, &mut file, arena)?,
            };
            let verify = if cfg.verify {
                // Vectored read-back through the same storage entry point
                // the read direction drives (no per-request read_at loop).
                // Retried like the collective itself: leftover transient
                // countdowns must not fail an otherwise-correct file.
                let mut ok = 0;
                let mut got = Vec::new();
                let mut stats = vec![OstStats::default(); file.config().stripe_count];
                for (rank, view) in &views {
                    let want = deterministic_payload(cfg.seed, *rank, view.total_bytes());
                    let (out, _) = faults::retrying(file.max_retries(), || {
                        file.read_view(view, &mut got, &mut stats)
                    });
                    out?;
                    if got == want {
                        ok += 1;
                    }
                }
                Some(VerifyReport { ok, total: views.len() })
            } else {
                None
            };
            Ok((
                LabelledRun {
                    label,
                    direction,
                    breakdown: outcome.breakdown,
                    counters: outcome.counters,
                },
                verify,
            ))
        }
        Direction::Read => {
            // Pre-populate the shared file with the workload's image —
            // plain per-rank vectored writes, not a collective: the
            // operation under measurement is the read.
            let mut file = LustreFile::new(cfg.lustre);
            file.begin_round();
            for (rank, batch) in &ranks {
                if !batch.view.is_empty() {
                    file.write_view(*rank, &batch.view, &batch.payload)?;
                }
            }
            install_faults(cfg, &mut file)?;
            let views: Vec<_> = ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
            let (got, outcome) = match (&cfg.faults, cache) {
                (Some(plan), cache) => run_collective_read_degraded(
                    &ctx,
                    algo,
                    views,
                    &file,
                    arena,
                    cache,
                    plan,
                    cfg.fault_seed,
                )?,
                (None, Some(cache)) => {
                    run_collective_read_cached(&ctx, algo, views, &file, arena, cache)?
                }
                (None, None) => run_collective_read_with(&ctx, algo, views, &file, arena)?,
            };
            let mut ok = 0;
            for ((_, payload), (_, want)) in got.iter().zip(ranks.iter()) {
                if payload == &want.payload {
                    ok += 1;
                }
            }
            let verify = Some(VerifyReport { ok, total: got.len() });
            Ok((
                LabelledRun {
                    label,
                    direction,
                    breakdown: outcome.breakdown,
                    counters: outcome.counters,
                },
                verify,
            ))
        }
    }
}

/// Fail loudly when a driver-level run carried a verification report that
/// did not pass (sweeps must not print panels over corrupt bytes).
fn ensure_verified(run: &LabelledRun, verify: &Option<VerifyReport>) -> Result<()> {
    match verify {
        Some(v) if !v.passed() => Err(Error::Verify(format!(
            "{} [{}]: {}/{} ranks",
            run.label, run.direction, v.ok, v.total
        ))),
        _ => Ok(()),
    }
}

/// Direction selector for the bench harnesses: `TAMIO_BENCH_DIRECTION`
/// (`write|read|both`), defaulting to both panels — shared by the fig4–7
/// benches so the env contract cannot drift between them.
pub fn bench_direction_from_env() -> DirectionSpec {
    std::env::var("TAMIO_BENCH_DIRECTION")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DirectionSpec::Both)
}

/// Pick a workload scale divisor so the run materializes roughly
/// `budget_reqs` requests (the figures compare algorithms at identical
/// scale, so shapes are preserved — DESIGN.md §Substitutions).
pub fn auto_scale(kind: WorkloadKind, p: usize, budget_reqs: u64) -> u64 {
    let (paper_reqs, _) = kind.build(1).paper_scale(p);
    ((paper_reqs / budget_reqs as f64).ceil() as u64).max(1)
}

/// Figures 4–7: breakdown sweep over `P_L` values, final bar = two-phase.
///
/// Runs every direction in `base.direction`, write bars first, then read
/// bars (read bars verified against `deterministic_payload` — see
/// [`run_direction_with_engine`]); group with
/// [`crate::metrics::breakdown_panels`] for per-direction tables.
pub fn breakdown_sweep(base: &RunConfig, pl_values: &[usize]) -> Result<Vec<LabelledRun>> {
    let engine = build_engine_for(base)?;
    // One arena + one plan cache for every bar of the sweep — the round
    // buffers stay warm across collectives and each bar's plan is built
    // at most once per direction (the plan-oracle's cross-bar reuse).
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(base)?;
    let mut runs = Vec::new();
    for &dir in base.direction.runs() {
        for &pl in pl_values {
            let mut cfg = base.clone();
            cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: pl });
            let (mut run, verify) =
                run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
            ensure_verified(&run, &verify)?;
            run.label = format!("P_L={pl}");
            runs.push(run);
        }
        let mut cfg = base.clone();
        cfg.algorithm = Algorithm::TwoPhase;
        let (mut run, verify) =
            run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
        ensure_verified(&run, &verify)?;
        run.label = "two-phase".into();
        runs.push(run);
    }
    Ok(runs)
}

/// `sweep --faults`: the degradation-curve panel.  For each direction, a
/// fault-free baseline bar followed by one bar per *cumulative prefix* of
/// the fault schedule, so each clause's marginal penalty is visible in
/// the label (`+<clause> (<slowdown>x)`).  Every bar goes through the
/// normal driver — degraded bars take the retry/repair path and are
/// verified whenever `base.verify` (reads always), so a panel that prints
/// is a panel whose degraded bytes matched the fault-free ones.
/// Schedules with a *persistent, never-healing* OST failure fail loudly
/// instead of producing a panel — there is no degraded completion to
/// chart.
pub fn degradation_sweep(base: &RunConfig) -> Result<Vec<LabelledRun>> {
    let plan = base
        .faults
        .clone()
        .ok_or_else(|| Error::config("degradation sweep needs --faults <schedule>"))?;
    let engine = build_engine_for(base)?;
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(base)?;
    let mut runs = Vec::new();
    for &dir in base.direction.runs() {
        let mut cfg = base.clone();
        cfg.faults = None;
        let (mut run, verify) =
            run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
        ensure_verified(&run, &verify)?;
        let baseline = run.breakdown.total();
        run.label = "fault-free".into();
        runs.push(run);
        for n in 1..=plan.clauses.len() {
            let mut cfg = base.clone();
            cfg.faults = Some(FaultPlan { clauses: plan.clauses[..n].to_vec() });
            let (mut run, verify) =
                run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
            ensure_verified(&run, &verify)?;
            let clause = FaultPlan { clauses: vec![plan.clauses[n - 1].clone()] };
            let slowdown = run.breakdown.total() / baseline.max(f64::MIN_POSITIVE);
            run.label = format!("+{clause} ({slowdown:.2}x)");
            runs.push(run);
        }
    }
    Ok(runs)
}

/// Figure 3: strong-scaling bandwidth for one workload; returns the
/// TAM(P_L=256) and two-phase series per direction in `base.direction`
/// (read series are suffixed `(read)`).
pub fn fig3_series(
    base: &RunConfig,
    kind: WorkloadKind,
    proc_counts: &[usize],
    budget_reqs: u64,
) -> Result<Vec<ScalingSeries>> {
    let engine = build_engine_for(base)?;
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(base)?;
    let mut out = Vec::new();
    for &dir in base.direction.runs() {
        let mut tam_points = Vec::new();
        let mut two_points = Vec::new();
        for &p in proc_counts {
            if p % base.ppn != 0 {
                return Err(Error::config(format!("P={p} not divisible by ppn={}", base.ppn)));
            }
            let mut cfg = base.clone();
            cfg.workload = kind;
            cfg.nodes = p / base.ppn;
            cfg.scale = auto_scale(kind, p, budget_reqs);
            cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 256 });
            let (tam, tam_verify) =
                run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
            ensure_verified(&tam, &tam_verify)?;
            cfg.algorithm = Algorithm::TwoPhase;
            let (two, two_verify) =
                run_direction_cached(&cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
            ensure_verified(&two, &two_verify)?;
            tam_points.push((p, tam.breakdown.bandwidth(tam.counters.bytes)));
            two_points.push((p, two.breakdown.bandwidth(two.counters.bytes)));
        }
        let suffix = match dir {
            Direction::Write => "",
            Direction::Read => " (read)",
        };
        out.push(ScalingSeries { label: format!("TAM(P_L=256){suffix}"), points: tam_points });
        out.push(ScalingSeries { label: format!("two-phase{suffix}"), points: two_points });
    }
    Ok(out)
}

/// Figure 2: per-global-aggregator in-degree (congestion) for two-phase
/// vs TAM on the same workload.  Returns `(label, max_in_degree,
/// mean_in_degree, n_messages)` rows (write direction — the
/// request-redistribution structure is the figure's subject).
pub fn fig2_congestion(base: &RunConfig) -> Result<Vec<(String, usize, f64, usize)>> {
    let engine = build_engine_for(base)?;
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(base)?;
    let mut rows = Vec::new();
    for algo in [
        Algorithm::TwoPhase,
        Algorithm::Tam(TamConfig { total_local_aggregators: 256.min(base.nodes * base.ppn) }),
    ] {
        let mut cfg = base.clone();
        cfg.algorithm = algo;
        let (run, _) = run_direction_cached(
            &cfg,
            engine.as_ref(),
            Direction::Write,
            &mut arena,
            &mut cache,
        )?;
        let c = &run.counters;
        let mean = if c.msgs_inter == 0 {
            0.0
        } else {
            c.msgs_inter as f64 / cfg.lustre.stripe_count.min(cfg.nodes * cfg.ppn) as f64
        };
        rows.push((algo.name(), c.max_in_degree, mean, c.msgs_inter));
    }
    Ok(rows)
}

/// Table I rows at a given topology + budget.
pub fn table1_rows(topo: &Topology, budget_reqs: u64) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    for kind in WorkloadKind::paper_set() {
        let scale = auto_scale(kind, topo.nprocs(), budget_reqs);
        let w = kind.build(scale);
        let stats = w.table_stats(topo)?;
        rows.push(vec![
            kind.to_string(),
            format!("{:.3e}", stats.paper_requests),
            crate::util::human_bytes(stats.paper_bytes),
            format!("{}", stats.n_requests),
            crate::util::human_bytes(stats.write_bytes),
            format!("1/{scale}"),
        ]);
    }
    Ok(rows)
}

/// Figures 4–7 driver: for each node count, sweep `P_L` (powers of four
/// up to `P`, always including 256 when it fits) plus the two-phase bar,
/// and print one breakdown panel per direction.  Shared by the fig4–fig7
/// benches and the CLI.
pub fn run_breakdown_grid(
    kind: WorkloadKind,
    nodes_list: &[usize],
    ppn: usize,
    budget: u64,
    direction: DirectionSpec,
) -> Result<()> {
    for &nodes in nodes_list {
        let p = nodes * ppn;
        let mut pls: Vec<usize> = [16usize, 64, 256, 1024, 4096]
            .into_iter()
            .filter(|&x| x >= nodes && x < p)
            .collect();
        if pls.is_empty() {
            pls.push(nodes);
        }
        let mut cfg = RunConfig::default();
        cfg.nodes = nodes;
        cfg.ppn = ppn;
        cfg.workload = kind;
        cfg.scale = auto_scale(kind, p, budget);
        cfg.direction = direction;
        println!(
            "\n{kind} @ {nodes} nodes x {ppn} ppn (P={p}), scale 1/{}, direction {direction}, P_L sweep {pls:?} + two-phase:",
            cfg.scale
        );
        match breakdown_sweep(&cfg, &pls) {
            Ok(runs) => {
                print!("{}", crate::metrics::breakdown_panels(&runs));
                for &dir in direction.runs() {
                    let panel: Vec<&LabelledRun> =
                        runs.iter().filter(|r| r.direction == dir).collect();
                    if panel.is_empty() {
                        continue;
                    }
                    // §IV-D crossover: report the best P_L per direction.
                    let best = panel
                        .iter()
                        .min_by(|a, b| {
                            a.breakdown.total().partial_cmp(&b.breakdown.total()).unwrap()
                        })
                        .unwrap();
                    println!(
                        "best end-to-end [{dir}]: {} ({:.3} ms)  [paper: P_L=256 minimizes f(P_L)+g(P_L)]",
                        best.label,
                        best.breakdown.total() * 1e3
                    );
                    // Coalescing progression (paper §V-B quotes these counts).
                    if let Some(r) = panel.first() {
                        println!(
                            "requests posted={} after-intra={} at-io={} (first {dir} bar)",
                            r.counters.reqs_posted,
                            r.counters.reqs_after_intra,
                            r.counters.reqs_at_io
                        );
                    }
                }
            }
            Err(e) => println!("skipped: {e}"),
        }
    }
    Ok(())
}

/// Spearman rank correlation between the predicted ordering (rows are
/// already in predicted order, so predicted ranks are `0..n`) and the
/// measured ordering.  `1.0` means the predictor ranked every candidate
/// exactly as measurement did; fewer than two rows correlate trivially.
fn spearman_from_predicted_order(measured: &[f64]) -> f64 {
    let n = measured.len();
    if n < 2 {
        return 1.0;
    }
    let mut by_measure: Vec<usize> = (0..n).collect();
    by_measure.sort_by(|&a, &b| measured[a].partial_cmp(&measured[b]).unwrap());
    let mut measured_rank = vec![0usize; n];
    for (pos, &i) in by_measure.iter().enumerate() {
        measured_rank[i] = pos;
    }
    let d2: f64 = measured_rank
        .iter()
        .enumerate()
        .map(|(predicted_rank, &m)| {
            let d = predicted_rank as f64 - m as f64;
            d * d
        })
        .sum();
    1.0 - 6.0 * d2 / (n as f64 * (n * n - 1) as f64)
}

/// `--validate-tuner`: the tuner's honesty check.  Score the full
/// candidate grid, then run the top-`k` *predicted* candidates for real
/// (verified) and report, per direction: each candidate's predicted vs
/// measured end-to-end time and relative error, the Spearman rank
/// correlation between the two orderings, and whether the predicted
/// winner landed in the measured top-2.
pub fn validate_tuner(cfg: &RunConfig, k: usize) -> Result<Vec<TunerValidation>> {
    let engine = build_engine_for(cfg)?;
    let mut arena = ExchangeArena::default();
    let mut cache = plan_cache_for(cfg)?;
    let mut out = Vec::new();
    for &dir in cfg.direction.runs() {
        let topo = cfg.topology();
        let workload = cfg.workload.build(cfg.scale);
        let ranks = workload.generate(&topo, cfg.seed)?;
        let views: Vec<_> = ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        let ctx = CollectiveCtx {
            topo: &topo,
            net: &cfg.net,
            cpu: &cfg.cpu,
            io: &cfg.io,
            engine: engine.as_ref(),
            placement: cfg.placement,
            n_global_agg: cfg.lustre.stripe_count,
        };
        let mut scored = score_candidates(&ctx, dir, &views, &cfg.lustre, cfg.overlap)?;
        // Stable sort keeps the tuner's first-in-grid tie-break, so
        // row 0 is exactly what `--algorithm auto` would execute.
        scored.sort_by(|a, b| a.cost.total().partial_cmp(&b.cost.total()).unwrap());
        scored.truncate(k.max(2));
        let mut rows = Vec::new();
        for c in &scored {
            let mut run_cfg = cfg.clone();
            run_cfg.algorithm = Algorithm::Tree(c.spec);
            run_cfg.rank_placement = c.placement;
            let (run, verify) =
                run_direction_cached(&run_cfg, engine.as_ref(), dir, &mut arena, &mut cache)?;
            ensure_verified(&run, &verify)?;
            let predicted = c.cost.total();
            let measured = run.breakdown.total();
            rows.push(TunerValidationRow {
                spec: c.spec,
                placement: c.placement,
                predicted,
                measured,
                rel_error: (predicted - measured).abs() / measured.max(f64::MIN_POSITIVE),
            });
        }
        let measured: Vec<f64> = rows.iter().map(|r| r.measured).collect();
        let spearman = spearman_from_predicted_order(&measured);
        let winner_measured_rank =
            measured.iter().filter(|&&m| m < measured[0]).count();
        out.push(TunerValidation {
            direction: dir,
            rows,
            spearman,
            winner_in_top2: winner_measured_rank <= 1,
        });
    }
    Ok(out)
}

/// Message-matrix summary used by the Fig-2 bench: in-degree histogram of
/// an explicit message list (re-exported convenience).
pub fn in_degree_summary(msgs: &[crate::netmodel::Message]) -> (usize, f64) {
    let h = in_degree_by_rank(msgs);
    let max = h.values().copied().max().unwrap_or(0);
    let mean = if h.is_empty() {
        0.0
    } else {
        h.values().sum::<usize>() as f64 / h.len() as f64
    };
    (max, mean)
}

/// Convenience accessor for outcome totals in benches.
pub fn outcome_summary(o: &CollectiveOutcome) -> (f64, f64, f64, f64) {
    (
        o.breakdown.intra_total(),
        o.breakdown.inter_total(),
        o.breakdown.io_phase,
        o.breakdown.total(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.nodes = 2;
        cfg.ppn = 8;
        cfg.workload = WorkloadKind::Strided;
        cfg.lustre = crate::lustre::LustreConfig::new(1 << 16, 4);
        cfg.verify = true;
        cfg
    }

    #[test]
    fn run_once_verifies() {
        let cfg = small_cfg();
        let mut out = run_once(&cfg).unwrap();
        assert_eq!(out.len(), 1);
        let (run, verify) = out.remove(0);
        let v = verify.unwrap();
        assert!(v.passed(), "verify failed: {}/{}", v.ok, v.total);
        assert_eq!(run.direction, Direction::Write);
        assert!(run.breakdown.total() > 0.0);
        assert!(run.counters.bytes > 0);
    }

    #[test]
    fn run_once_tam_verifies() {
        let mut cfg = small_cfg();
        cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });
        let mut out = run_once(&cfg).unwrap();
        assert!(out.remove(0).1.unwrap().passed());
    }

    #[test]
    fn run_once_read_direction_verifies_gathered_bytes() {
        let mut cfg = small_cfg();
        cfg.direction = DirectionSpec::Read;
        cfg.verify = false; // read runs verify regardless
        for algo in [
            Algorithm::TwoPhase,
            Algorithm::Tam(TamConfig { total_local_aggregators: 4 }),
        ] {
            cfg.algorithm = algo;
            let mut out = run_once(&cfg).unwrap();
            assert_eq!(out.len(), 1);
            let (run, verify) = out.remove(0);
            assert_eq!(run.direction, Direction::Read);
            let v = verify.expect("read runs always verify");
            assert!(v.passed(), "{}: {}/{}", run.label, v.ok, v.total);
            assert!(run.breakdown.total() > 0.0);
            assert!(run.counters.bytes > 0);
        }
    }

    #[test]
    fn run_once_both_directions_orders_write_then_read() {
        let mut cfg = small_cfg();
        cfg.direction = DirectionSpec::Both;
        let out = run_once(&cfg).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].0.direction, Direction::Write);
        assert_eq!(out[1].0.direction, Direction::Read);
        for (run, verify) in &out {
            assert!(verify.as_ref().unwrap().passed(), "{} [{}]", run.label, run.direction);
        }
        // Same exchange skeleton both ways: identical round structure.
        assert_eq!(out[0].0.counters.rounds, out[1].0.counters.rounds);
    }

    #[test]
    fn breakdown_sweep_shapes() {
        let mut cfg = small_cfg();
        cfg.verify = false;
        let runs = breakdown_sweep(&cfg, &[2, 4, 8]).unwrap();
        assert_eq!(runs.len(), 4);
        assert_eq!(runs[3].label, "two-phase");
        // §IV-D: intra time decreases with more local aggregators.
        assert!(runs[0].breakdown.intra_total() >= runs[2].breakdown.intra_total());
    }

    #[test]
    fn breakdown_sweep_both_directions_doubles_bars() {
        let mut cfg = small_cfg();
        cfg.verify = false;
        cfg.direction = DirectionSpec::Both;
        let runs = breakdown_sweep(&cfg, &[2, 4]).unwrap();
        assert_eq!(runs.len(), 6);
        assert!(runs[..3].iter().all(|r| r.direction == Direction::Write));
        assert!(runs[3..].iter().all(|r| r.direction == Direction::Read));
        assert_eq!(runs[2].label, "two-phase");
        assert_eq!(runs[5].label, "two-phase");
    }

    #[test]
    fn degraded_run_retries_and_repairs_yet_verifies() {
        let mut cfg = small_cfg();
        cfg.direction = DirectionSpec::Both;
        cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });
        // OST 0 backs the first stripe, so the transient countdown is
        // guaranteed to fire on either direction's first touch.
        cfg.faults =
            Some("ost_fail=0@transient:2,ost_slow=0.5x:0-1,agg_drop=?@level:0".parse().unwrap());
        cfg.fault_seed = 42;
        let out = run_once(&cfg).unwrap();
        assert_eq!(out.len(), 2);
        for (run, verify) in &out {
            assert!(
                verify.as_ref().unwrap().passed(),
                "degraded {} [{}] must still round-trip bytes",
                run.label,
                run.direction
            );
            assert!(run.counters.repaired_plans == 1, "one agg_drop clause = one repair");
        }
        // The transient countdown sits on a live OST, so at least one
        // direction pays retries (the strided pattern touches every OST).
        assert!(out.iter().any(|(r, _)| r.counters.retries > 0));
    }

    #[test]
    fn degraded_runs_are_bit_identical_under_a_fixed_seed() {
        let mut cfg = small_cfg();
        cfg.faults = Some("ost_fail=?@transient:1,agg_drop=?".parse().unwrap());
        cfg.fault_seed = 7;
        let a = run_once(&cfg).unwrap().remove(0).0;
        let b = run_once(&cfg).unwrap().remove(0).0;
        assert_eq!(a.breakdown, b.breakdown, "fault schedule must be a pure function of seed");
        assert_eq!(a.counters.retries, b.counters.retries);
        assert_eq!(a.counters.backoff_units, b.counters.backoff_units);
    }

    #[test]
    fn degradation_sweep_charts_cumulative_prefixes() {
        let mut cfg = small_cfg();
        cfg.faults =
            Some("ost_fail=0@transient:2,ost_slow=0.25x:0-1,agg_drop=?".parse().unwrap());
        cfg.fault_seed = 42;
        let runs = degradation_sweep(&cfg).unwrap();
        assert_eq!(runs.len(), 4, "baseline + one bar per clause");
        assert_eq!(runs[0].label, "fault-free");
        let baseline = runs[0].breakdown.total();
        assert!(runs[1].label.starts_with("+ost_fail="), "{}", runs[1].label);
        assert!(runs[1].counters.retries > 0, "transient clause must cost retries");
        assert!(
            runs[1].breakdown.total() > baseline,
            "backoff penalty must show in the curve"
        );
        assert!(runs[2].label.starts_with("+ost_slow=0.25x:0-1"), "{}", runs[2].label);
        assert!(
            runs[2].breakdown.total() > runs[1].breakdown.total(),
            "a 4x-slower OST must stretch the I/O phase further"
        );
        assert_eq!(runs[3].counters.repaired_plans, 1);
        // No faults configured → loud error, not an empty panel.
        cfg.faults = None;
        assert!(degradation_sweep(&cfg).is_err());
    }

    #[test]
    fn run_once_overlap_on_is_verified_and_no_slower() {
        use crate::coordinator::collective::OverlapMode;
        let mut cfg = small_cfg();
        cfg.direction = DirectionSpec::Both;
        cfg.algorithm = Algorithm::Tam(TamConfig { total_local_aggregators: 4 });
        let serial = run_once(&cfg).unwrap();
        cfg.overlap = OverlapMode::On;
        let piped = run_once(&cfg).unwrap();
        assert_eq!(serial.len(), piped.len());
        for ((s, _), (p, pv)) in serial.iter().zip(piped.iter()) {
            // Pipelining is a schedule, not a result: bytes still verify
            // and every structural counter matches the serial run.
            assert!(pv.as_ref().unwrap().passed(), "{} [{}]", p.label, p.direction);
            assert_eq!(s.counters.rounds, p.counters.rounds);
            assert_eq!(s.counters.bytes, p.counters.bytes);
            assert_eq!(s.counters.reqs_at_io, p.counters.reqs_at_io);
            assert_eq!(s.breakdown.io_phase, p.breakdown.io_phase);
            assert_eq!(s.breakdown.overlap_saved, 0.0, "serial runs earn no credit");
            if p.counters.rounds >= 2 {
                assert!(
                    p.breakdown.overlap_saved > 0.0,
                    "multi-round pipelined run must hide some I/O [{}]",
                    p.direction
                );
            }
            assert!(p.breakdown.total() <= s.breakdown.total());
        }
    }

    #[test]
    fn auto_scale_reasonable() {
        let s = auto_scale(WorkloadKind::E3smF, 16384, 1_000_000);
        assert!(s >= 1000, "F case must scale down heavily, got {s}");
        assert_eq!(auto_scale(WorkloadKind::Contig, 64, 1_000_000), 1);
    }

    #[test]
    fn run_once_auto_resolves_and_verifies() {
        let mut cfg = small_cfg();
        cfg.algorithm = Algorithm::Auto;
        cfg.direction = DirectionSpec::Both;
        let out = run_once(&cfg).unwrap();
        assert_eq!(out.len(), 2);
        for (run, verify) in &out {
            assert!(
                run.label.starts_with("auto["),
                "auto runs must carry the resolved spec in the label, got '{}'",
                run.label
            );
            assert!(verify.as_ref().unwrap().passed(), "{} [{}]", run.label, run.direction);
            assert!(run.breakdown.total() > 0.0);
        }
    }

    #[test]
    fn run_once_auto_is_deterministic() {
        let mut cfg = small_cfg();
        cfg.algorithm = Algorithm::Auto;
        let a = run_once(&cfg).unwrap().remove(0).0;
        let b = run_once(&cfg).unwrap().remove(0).0;
        assert_eq!(a.label, b.label, "the tuner's choice must be a pure function");
        assert_eq!(a.breakdown.total(), b.breakdown.total());
    }

    #[test]
    fn validate_tuner_reports_per_direction_rows() {
        let mut cfg = small_cfg();
        cfg.algorithm = Algorithm::Auto;
        cfg.direction = DirectionSpec::Both;
        let reports = validate_tuner(&cfg, 3).unwrap();
        assert_eq!(reports.len(), 2);
        for rep in &reports {
            assert!(rep.rows.len() >= 2, "need at least two candidates to rank");
            assert!(rep.rows.len() <= 3);
            // Rows arrive in predicted order.
            assert!(
                rep.rows.windows(2).all(|w| w[0].predicted <= w[1].predicted),
                "[{}] rows must be sorted by predicted cost",
                rep.direction
            );
            for row in &rep.rows {
                assert!(row.predicted.is_finite() && row.predicted > 0.0);
                assert!(row.measured.is_finite() && row.measured > 0.0);
                assert!(row.rel_error.is_finite() && row.rel_error >= 0.0);
            }
            assert!((-1.0..=1.0).contains(&rep.spearman), "{}", rep.spearman);
        }
    }

    #[test]
    fn spearman_helper_matches_hand_cases() {
        assert_eq!(spearman_from_predicted_order(&[1.0, 2.0, 3.0]), 1.0);
        assert_eq!(spearman_from_predicted_order(&[3.0, 2.0, 1.0]), -1.0);
        assert_eq!(spearman_from_predicted_order(&[5.0]), 1.0);
    }

    #[test]
    fn fig2_congestion_tam_lower() {
        let mut cfg = small_cfg();
        cfg.verify = false;
        let rows = fig2_congestion(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        // Row 0: two-phase; row 1: TAM — TAM's in-degree must not exceed.
        assert!(rows[1].1 <= rows[0].1);
    }

    #[test]
    fn fig3_series_direction_both_emits_read_series() {
        let mut cfg = small_cfg();
        cfg.verify = false;
        cfg.direction = DirectionSpec::Both;
        let series = fig3_series(&cfg, WorkloadKind::Strided, &[16], 10_000).unwrap();
        assert_eq!(series.len(), 4);
        assert!(series[0].label.starts_with("TAM"));
        assert!(series[2].label.ends_with("(read)"), "{}", series[2].label);
        assert!(series[3].label.ends_with("(read)"), "{}", series[3].label);
        for s in &series {
            assert!(s.points[0].1 > 0.0, "{} bandwidth must be positive", s.label);
        }
    }
}
