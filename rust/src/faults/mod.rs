//! Seeded fault injection + degraded-execution policy (ROADMAP item 5).
//!
//! A [`FaultPlan`] is parsed from the `--faults` clause list and covers
//! three fault classes:
//!
//! | clause                      | class                          | effect |
//! |-----------------------------|--------------------------------|--------|
//! | `ost_fail=<ost\|?>[@round:<r>][@transient:<n>]` | OST failure | persistent (fatal) or transient (heals after `n` errors, retried with backoff), optionally armed at round `r` |
//! | `ost_slow=<f>x:<lo>[-<hi>]` | service-rate skew              | OSTs `lo..=hi` serve at `f`× nominal rate; the I/O phase stretches via [`crate::lustre::IoModel::phase_time_skewed`] |
//! | `agg_drop=<rank\|?>[@level:<l>]` | aggregator dropout        | the rank's aggregator role at tree level `l` (or the global exchange when absent) is adopted by a survivor via `repair_plan` |
//!
//! `?` selectors resolve deterministically from `--fault-seed` through
//! [`SplitMix64`]: the whole schedule is a pure function of the seed, so a
//! repeat run is bit-identical (pinned by `tests/degraded_mode.rs`).
//!
//! Execution-side state lives in [`OstFaultState`] (owned by
//! `LustreFile`): persistent flags, transient countdowns (atomic — the
//! read path probes them concurrently from pool workers), per-OST rate
//! multipliers and round-armed faults.  The retry policy is
//! [`retrying`]: bounded attempts with an exponential simulated backoff
//! penalty ([`backoff_penalty`]) charged to the I/O phase.

use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::util::rng::SplitMix64;

/// Simulated backoff penalty (seconds) for the first retry; attempt `i`
/// waits `2^i ×` this, so a site that retried `a` times accrues
/// `(2^a - 1)` [`backoff_units`].
pub const RETRY_BACKOFF_BASE: f64 = 1.0e-3;

/// Default `--max-retries`: bounded attempts per storage call site.
pub const DEFAULT_MAX_RETRIES: u32 = 4;

/// An OST or rank selector: a fixed index, or `?` = pick deterministically
/// from the fault seed at resolve/repair time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sel {
    /// Explicit index.
    Fixed(usize),
    /// Seeded random pick (`?` in the clause).
    Random,
}

impl Sel {
    /// Resolve against `n` candidates using `rng` (Random) or bounds-check
    /// the fixed index.  `what` names the domain for error messages.
    pub fn resolve(self, n: usize, rng: &mut SplitMix64, what: &str) -> Result<usize> {
        if n == 0 {
            return Err(Error::config(format!("faults: no {what} to select from")));
        }
        match self {
            Sel::Fixed(i) if i < n => Ok(i),
            Sel::Fixed(i) => Err(Error::config(format!(
                "faults: {what} index {i} out of range (have {n})"
            ))),
            Sel::Random => Ok(rng.gen_range(n as u64) as usize),
        }
    }
}

/// One parsed `--faults` clause.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultClause {
    /// `ost_fail=<ost|?>[@round:<r>][@transient:<n>]`.
    OstFail {
        /// Which OST fails.
        ost: Sel,
        /// Arm at the start of this 0-based I/O round (None = immediately).
        round: Option<u64>,
        /// Heal after this many errors (None = persistent/fatal).
        transient: Option<u64>,
    },
    /// `ost_slow=<f>x:<lo>[-<hi>]` — rate multiplier for an OST range.
    OstSlow {
        /// Service-rate multiplier (0 < f; < 1 slows the OST down).
        rate: f64,
        /// First OST of the range.
        lo: usize,
        /// Last OST of the range (inclusive).
        hi: usize,
    },
    /// `agg_drop=<rank|?>[@level:<l>]` — aggregator dropout.
    AggDrop {
        /// Which aggregator drops (`?` = seeded pick among the actual
        /// aggregators of the target level at repair time).
        rank: Sel,
        /// Tree level index (None = a global-exchange aggregator slot).
        level: Option<usize>,
    },
}

/// The parsed `--faults` schedule (order-preserving clause list).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Clauses in spec order.
    pub clauses: Vec<FaultClause>,
}

impl FaultPlan {
    /// Whether any clause drops an aggregator (forces the plan-repair path).
    pub fn has_drops(&self) -> bool {
        self.clauses.iter().any(|c| matches!(c, FaultClause::AggDrop { .. }))
    }

    /// Aggregator-drop clauses in spec order.
    pub fn drops(&self) -> Vec<(Sel, Option<usize>)> {
        self.clauses
            .iter()
            .filter_map(|c| match c {
                FaultClause::AggDrop { rank, level } => Some((*rank, *level)),
                _ => None,
            })
            .collect()
    }

    /// A fingerprint salt for this schedule + seed: degraded plans are
    /// cached under a fault-epoch-salted key so they can never collide
    /// with (or pollute) fault-free entries.  Stable across runs — a pure
    /// function of the clause list and seed.
    pub fn cache_salt(&self, seed: u64) -> u64 {
        // FNV-1a over the canonical clause debug forms, then mix the seed.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for c in &self.clauses {
            for b in format!("{c:?}").bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        let mut rng = SplitMix64::new(h ^ seed);
        rng.next_u64() | 1 // never 0: salt 0 is reserved for "no faults"
    }

    /// Resolve OST-class clauses against `n_osts` OSTs into installable
    /// form.  `?` OST selectors draw from a [`SplitMix64`] forked per
    /// clause index, so the schedule is a pure function of `seed`.
    /// Aggregator drops resolve later (at plan-repair time, when the
    /// aggregator sets are known) from the same seed.
    pub fn resolve_osts(&self, n_osts: usize, seed: u64) -> Result<ResolvedOstFaults> {
        let mut root = SplitMix64::new(seed);
        let mut out = ResolvedOstFaults { fails: Vec::new(), rates: Vec::new() };
        for (i, clause) in self.clauses.iter().enumerate() {
            let mut rng = root.fork(i as u64);
            match clause {
                FaultClause::OstFail { ost, round, transient } => {
                    let ost = ost.resolve(n_osts, &mut rng, "OST")?;
                    out.fails.push(OstFailure { ost, round: *round, transient: *transient });
                }
                FaultClause::OstSlow { rate, lo, hi } => {
                    if *hi >= n_osts {
                        return Err(Error::config(format!(
                            "faults: ost_slow range {lo}-{hi} exceeds OST count {n_osts}"
                        )));
                    }
                    if out.rates.is_empty() {
                        out.rates = vec![1.0; n_osts];
                    }
                    for r in out.rates.iter_mut().take(*hi + 1).skip(*lo) {
                        *r = *rate;
                    }
                }
                FaultClause::AggDrop { .. } => {}
            }
        }
        Ok(out)
    }
}

/// One resolved OST failure ready to install.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OstFailure {
    /// Failing OST index.
    pub ost: usize,
    /// Arm at the start of this round (None = immediately).
    pub round: Option<u64>,
    /// Heal after this many errors (None = persistent).
    pub transient: Option<u64>,
}

/// OST-class faults resolved against a concrete OST count.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResolvedOstFaults {
    /// Failures to install.
    pub fails: Vec<OstFailure>,
    /// Per-OST service-rate multipliers (empty = uniform 1.0).
    pub rates: Vec<f64>,
}

fn bad(clause: &str, why: &str) -> Error {
    Error::config(format!(
        "faults: bad clause '{clause}': {why} \
         (e.g. ost_fail=3@round:2, ost_fail=?@transient:5, ost_slow=0.25x:0-7, agg_drop=?@level:1)"
    ))
}

fn parse_sel(s: &str, clause: &str) -> Result<Sel> {
    if s == "?" {
        return Ok(Sel::Random);
    }
    s.parse::<usize>()
        .map(Sel::Fixed)
        .map_err(|_| bad(clause, &format!("'{s}' is not an index or '?'")))
}

impl FromStr for FaultPlan {
    type Err = Error;

    fn from_str(s: &str) -> Result<Self> {
        let mut clauses = Vec::new();
        for clause in s.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (name, spec) = clause
                .split_once('=')
                .ok_or_else(|| bad(clause, "expected <name>=<spec>"))?;
            match name.trim() {
                "ost_fail" => {
                    let mut parts = spec.split('@');
                    let ost = parse_sel(parts.next().unwrap_or("").trim(), clause)?;
                    let (mut round, mut transient) = (None, None);
                    for part in parts {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| bad(clause, "expected @key:value"))?;
                        let v: u64 = v
                            .trim()
                            .parse()
                            .map_err(|_| bad(clause, &format!("'{v}' is not an integer")))?;
                        match k.trim() {
                            "round" => round = Some(v),
                            "transient" => {
                                if v == 0 {
                                    return Err(bad(clause, "transient count must be >= 1"));
                                }
                                transient = Some(v);
                            }
                            other => {
                                return Err(bad(clause, &format!("unknown modifier '@{other}:'")))
                            }
                        }
                    }
                    clauses.push(FaultClause::OstFail { ost, round, transient });
                }
                "ost_slow" => {
                    let (rate, range) = spec
                        .split_once('x')
                        .and_then(|(r, rest)| Some((r, rest.strip_prefix(':')?)))
                        .ok_or_else(|| bad(clause, "expected <factor>x:<lo>[-<hi>]"))?;
                    let rate: f64 = rate
                        .trim()
                        .parse()
                        .map_err(|_| bad(clause, &format!("'{rate}' is not a number")))?;
                    if !(rate > 0.0) || !rate.is_finite() {
                        return Err(bad(clause, "rate factor must be finite and > 0"));
                    }
                    let (lo, hi) = match range.split_once('-') {
                        Some((lo, hi)) => (lo, hi),
                        None => (range, range),
                    };
                    let lo: usize = lo
                        .trim()
                        .parse()
                        .map_err(|_| bad(clause, &format!("'{lo}' is not an OST index")))?;
                    let hi: usize = hi
                        .trim()
                        .parse()
                        .map_err(|_| bad(clause, &format!("'{hi}' is not an OST index")))?;
                    if hi < lo {
                        return Err(bad(clause, "range must be <lo>-<hi> with lo <= hi"));
                    }
                    clauses.push(FaultClause::OstSlow { rate, lo, hi });
                }
                "agg_drop" => {
                    let mut parts = spec.split('@');
                    let rank = parse_sel(parts.next().unwrap_or("").trim(), clause)?;
                    let mut level = None;
                    for part in parts {
                        let (k, v) = part
                            .split_once(':')
                            .ok_or_else(|| bad(clause, "expected @level:<l>"))?;
                        if k.trim() != "level" {
                            return Err(bad(clause, &format!("unknown modifier '@{k}:'")));
                        }
                        level = Some(v.trim().parse::<usize>().map_err(|_| {
                            bad(clause, &format!("'{v}' is not a level index"))
                        })?);
                    }
                    clauses.push(FaultClause::AggDrop { rank, level });
                }
                other => return Err(bad(clause, &format!("unknown fault class '{other}'"))),
            }
        }
        if clauses.is_empty() {
            return Err(Error::config(
                "faults: empty spec (expected a comma list of ost_fail/ost_slow/agg_drop clauses)",
            ));
        }
        Ok(FaultPlan { clauses })
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let sel = |s: &Sel| match s {
            Sel::Fixed(i) => i.to_string(),
            Sel::Random => "?".to_string(),
        };
        for (i, c) in self.clauses.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            match c {
                FaultClause::OstFail { ost, round, transient } => {
                    write!(f, "ost_fail={}", sel(ost))?;
                    if let Some(r) = round {
                        write!(f, "@round:{r}")?;
                    }
                    if let Some(n) = transient {
                        write!(f, "@transient:{n}")?;
                    }
                }
                FaultClause::OstSlow { rate, lo, hi } => {
                    write!(f, "ost_slow={rate}x:{lo}-{hi}")?;
                }
                FaultClause::AggDrop { rank, level } => {
                    write!(f, "agg_drop={}", sel(rank))?;
                    if let Some(l) = level {
                        write!(f, "@level:{l}")?;
                    }
                }
            }
        }
        Ok(())
    }
}

/// Per-OST execution-side fault state, owned by `LustreFile`.
///
/// All probes take `&self`: the read path checks faults concurrently from
/// pool workers, so transient countdowns are atomic and round-armed
/// faults sit behind a mutex (touched once per round, off the per-piece
/// hot path).  The `active` flag keeps the fault-free hot path at a
/// single branch.
#[derive(Debug)]
pub struct OstFaultState {
    persistent: Vec<AtomicBool>,
    transient: Vec<AtomicU64>,
    rates: Vec<f64>,
    armed: Mutex<Vec<OstFailure>>,
    rounds_started: AtomicU64,
    max_retries: u32,
    active: bool,
}

impl OstFaultState {
    /// All-clear state for `n_osts` OSTs.
    pub fn new(n_osts: usize) -> Self {
        OstFaultState {
            persistent: (0..n_osts).map(|_| AtomicBool::new(false)).collect(),
            transient: (0..n_osts).map(|_| AtomicU64::new(0)).collect(),
            rates: Vec::new(),
            armed: Mutex::new(Vec::new()),
            rounds_started: AtomicU64::new(0),
            max_retries: DEFAULT_MAX_RETRIES,
            active: false,
        }
    }

    fn bounds(&self, ost: usize) -> Result<()> {
        let n = self.persistent.len();
        if ost >= n {
            return Err(Error::config(format!(
                "fail_ost: OST index {ost} out of range — this file stripes over {n} OST{} \
                 (valid indices 0..{n})",
                if n == 1 { "" } else { "s" }
            )));
        }
        Ok(())
    }

    /// Install one resolved failure (immediate or round-armed).
    pub fn install(&mut self, f: OstFailure) -> Result<()> {
        self.bounds(f.ost)?;
        self.active = true;
        if f.round.is_some() {
            self.armed.get_mut().expect("faults mutex").push(f);
            return Ok(());
        }
        match f.transient {
            Some(n) => {
                self.transient[f.ost].fetch_add(n, Ordering::Relaxed);
            }
            None => self.persistent[f.ost].store(true, Ordering::Relaxed),
        }
        Ok(())
    }

    /// Set one OST's service-rate multiplier.
    pub fn set_rate(&mut self, ost: usize, rate: f64) -> Result<()> {
        self.bounds(ost)?;
        if !(rate > 0.0) || !rate.is_finite() {
            return Err(Error::config(format!(
                "set_ost_rate: rate {rate} must be finite and > 0"
            )));
        }
        if self.rates.is_empty() {
            self.rates = vec![1.0; self.persistent.len()];
        }
        self.rates[ost] = rate;
        self.active = true;
        Ok(())
    }

    /// Replace the whole rate table (empty = uniform 1.0).
    pub fn set_rates(&mut self, rates: Vec<f64>) -> Result<()> {
        if !rates.is_empty() && rates.len() != self.persistent.len() {
            return Err(Error::config(format!(
                "set_ost_rates: {} rates for {} OSTs",
                rates.len(),
                self.persistent.len()
            )));
        }
        if rates.iter().any(|r| !(*r > 0.0) || !r.is_finite()) {
            return Err(Error::config("set_ost_rates: rates must be finite and > 0"));
        }
        self.active = self.active || !rates.is_empty();
        self.rates = rates;
        Ok(())
    }

    /// Bound on retry attempts per storage call site.
    pub fn max_retries(&self) -> u32 {
        self.max_retries
    }

    /// Set the per-site retry bound.
    pub fn set_max_retries(&mut self, n: u32) {
        self.max_retries = n;
    }

    /// Per-OST service rates (empty = uniform 1.0).
    pub fn rates(&self) -> &[f64] {
        &self.rates
    }

    /// Reset the round counter (faults armed `@round:r` count I/O rounds
    /// from the moment the schedule is installed).
    pub fn reset_rounds(&mut self) {
        *self.rounds_started.get_mut() = 0;
    }

    /// A new I/O round is starting: arm any faults scheduled for it.
    /// `&self` — the read path calls this without exclusive file access.
    pub fn tick_round(&self) {
        let started = self.rounds_started.fetch_add(1, Ordering::Relaxed);
        if !self.active {
            return;
        }
        let mut armed = self.armed.lock().expect("faults mutex");
        let mut i = 0;
        while i < armed.len() {
            if armed[i].round == Some(started) {
                let f = armed.swap_remove(i);
                match f.transient {
                    Some(n) => {
                        self.transient[f.ost].fetch_add(n, Ordering::Relaxed);
                    }
                    None => self.persistent[f.ost].store(true, Ordering::Relaxed),
                }
            } else {
                i += 1;
            }
        }
    }

    /// Probe `ost` before serving a `len`-byte piece at `offset`.
    /// Persistent failures are fatal; a transient failure consumes one
    /// countdown tick and returns a retryable error.
    #[inline]
    pub fn check(&self, ost: usize, offset: u64, len: u64) -> Result<()> {
        if !self.active {
            return Ok(());
        }
        if self.persistent[ost].load(Ordering::Relaxed) {
            return Err(Error::storage_failed(ost, offset, len));
        }
        let c = &self.transient[ost];
        loop {
            let cur = c.load(Ordering::Relaxed);
            if cur == 0 {
                return Ok(());
            }
            if c.compare_exchange(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
                return Err(Error::storage_transient(ost, offset, len));
            }
        }
    }
}

/// Sum of `2^i` for `i in 0..retries` — the exponential-backoff weight a
/// call site accrues for `retries` retries (saturating far above any
/// sane `--max-retries`).
pub fn backoff_units(retries: u32) -> u64 {
    (1u64 << retries.min(62)) - 1
}

/// Simulated backoff penalty (seconds) for accumulated [`backoff_units`].
pub fn backoff_penalty(units: u64) -> f64 {
    units as f64 * RETRY_BACKOFF_BASE
}

/// Run `f`, retrying up to `max_retries` times while it returns a
/// transient error ([`Error::is_transient`]).  Returns the result plus
/// the number of retries consumed; a fatal error or retry exhaustion
/// propagates the last error unchanged (variant intact for callers that
/// match on it).
pub fn retrying<T>(
    max_retries: u32,
    mut f: impl FnMut() -> Result<T>,
) -> (Result<T>, u32) {
    let mut retries = 0u32;
    loop {
        match f() {
            Err(e) if e.is_transient() && retries < max_retries => retries += 1,
            out => return (out, retries),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let p: FaultPlan = "ost_fail=3@round:2,ost_slow=0.25x:0-7,agg_drop=17@level:1"
            .parse()
            .unwrap();
        assert_eq!(
            p.clauses,
            vec![
                FaultClause::OstFail { ost: Sel::Fixed(3), round: Some(2), transient: None },
                FaultClause::OstSlow { rate: 0.25, lo: 0, hi: 7 },
                FaultClause::AggDrop { rank: Sel::Fixed(17), level: Some(1) },
            ]
        );
        assert!(p.has_drops());
        assert_eq!(p.drops(), vec![(Sel::Fixed(17), Some(1))]);
        // Display round-trips.
        let back: FaultPlan = p.to_string().parse().unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn parses_selectors_and_modifiers() {
        let p: FaultPlan = "ost_fail=?@transient:5,agg_drop=?,ost_slow=2x:3".parse().unwrap();
        assert_eq!(
            p.clauses,
            vec![
                FaultClause::OstFail { ost: Sel::Random, round: None, transient: Some(5) },
                FaultClause::AggDrop { rank: Sel::Random, level: None },
                FaultClause::OstSlow { rate: 2.0, lo: 3, hi: 3 },
            ]
        );
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "",
            "ost_fail",
            "ost_fail=x",
            "ost_fail=3@round",
            "ost_fail=3@bogus:1",
            "ost_fail=3@transient:0",
            "ost_slow=0.25:0-7",
            "ost_slow=-1x:0-7",
            "ost_slow=0x:0-7",
            "ost_slow=0.5x:7-0",
            "agg_drop=3@depth:1",
            "quake=1",
        ] {
            assert!(bad.parse::<FaultPlan>().is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn resolve_is_deterministic_and_bounds_checked() {
        let p: FaultPlan = "ost_fail=?,ost_slow=0.5x:1-2".parse().unwrap();
        let a = p.resolve_osts(4, 42).unwrap();
        let b = p.resolve_osts(4, 42).unwrap();
        assert_eq!(a, b, "same seed must resolve identically");
        assert!(a.fails[0].ost < 4);
        assert_eq!(a.rates, vec![1.0, 0.5, 0.5, 1.0]);
        // A different seed may pick differently but stays in range.
        let c = p.resolve_osts(4, 7).unwrap();
        assert!(c.fails[0].ost < 4);
        // Fixed out-of-range OST / slow range reject loudly.
        let oob: FaultPlan = "ost_fail=9".parse().unwrap();
        assert!(oob.resolve_osts(4, 0).is_err());
        let oob: FaultPlan = "ost_slow=0.5x:0-9".parse().unwrap();
        assert!(oob.resolve_osts(4, 0).is_err());
    }

    #[test]
    fn cache_salt_tracks_schedule_and_seed() {
        let p: FaultPlan = "ost_fail=1".parse().unwrap();
        let q: FaultPlan = "ost_fail=2".parse().unwrap();
        assert_eq!(p.cache_salt(1), p.cache_salt(1));
        assert_ne!(p.cache_salt(1), p.cache_salt(2));
        assert_ne!(p.cache_salt(1), q.cache_salt(1));
        assert_ne!(p.cache_salt(1), 0, "salt 0 is reserved for fault-free");
    }

    #[test]
    fn state_persistent_vs_transient() {
        let mut st = OstFaultState::new(4);
        assert!(st.check(0, 0, 8).is_ok(), "all-clear state passes");
        st.install(OstFailure { ost: 1, round: None, transient: Some(2) }).unwrap();
        st.install(OstFailure { ost: 2, round: None, transient: None }).unwrap();
        // Transient heals after 2 errors.
        assert!(st.check(1, 0, 8).unwrap_err().is_transient());
        assert!(st.check(1, 8, 8).unwrap_err().is_transient());
        assert!(st.check(1, 16, 8).is_ok());
        // Persistent never heals and is not transient.
        for _ in 0..3 {
            let e = st.check(2, 0, 8).unwrap_err();
            assert!(matches!(e, Error::StorageFailed { ost: 2, .. }));
            assert!(!e.is_transient());
        }
        // Untouched OSTs stay clear.
        assert!(st.check(0, 0, 8).is_ok());
        assert!(st.install(OstFailure { ost: 9, round: None, transient: None }).is_err());
    }

    #[test]
    fn round_armed_faults_wait_for_their_round() {
        let mut st = OstFaultState::new(2);
        st.install(OstFailure { ost: 0, round: Some(1), transient: Some(1) }).unwrap();
        st.tick_round(); // round 0 starts
        assert!(st.check(0, 0, 8).is_ok(), "not armed before round 1");
        st.tick_round(); // round 1 starts
        assert!(st.check(0, 0, 8).unwrap_err().is_transient());
        assert!(st.check(0, 0, 8).is_ok(), "healed after one error");
        // reset_rounds restarts the clock for a new schedule.
        st.install(OstFailure { ost: 1, round: Some(0), transient: None }).unwrap();
        st.reset_rounds();
        st.tick_round();
        assert!(st.check(1, 0, 8).is_err());
    }

    #[test]
    fn rates_install_and_validate() {
        let mut st = OstFaultState::new(4);
        assert!(st.rates().is_empty());
        st.set_rate(2, 0.25).unwrap();
        assert_eq!(st.rates(), &[1.0, 1.0, 0.25, 1.0]);
        assert!(st.set_rate(9, 0.5).is_err());
        assert!(st.set_rate(0, 0.0).is_err());
        assert!(st.set_rates(vec![0.5; 3]).is_err(), "length mismatch");
        st.set_rates(vec![0.5; 4]).unwrap();
        assert_eq!(st.rates(), &[0.5; 4]);
    }

    #[test]
    fn retrying_bounds_and_counts() {
        // Succeeds on the 3rd attempt: 2 retries consumed.
        let mut left = 2u32;
        let (out, retries) = retrying(4, || {
            if left > 0 {
                left -= 1;
                Err(Error::storage_transient(0, 0, 8))
            } else {
                Ok(7u32)
            }
        });
        assert_eq!(out.unwrap(), 7);
        assert_eq!(retries, 2);
        // Exhaustion propagates the transient error unchanged.
        let (out, retries) = retrying(3, || -> Result<()> {
            Err(Error::storage_transient(1, 0, 8))
        });
        assert!(out.unwrap_err().is_transient());
        assert_eq!(retries, 3);
        // Fatal errors never retry.
        let (out, retries) = retrying(3, || -> Result<()> {
            Err(Error::storage_failed(1, 0, 8))
        });
        assert!(matches!(out.unwrap_err(), Error::StorageFailed { .. }));
        assert_eq!(retries, 0);
    }

    #[test]
    fn backoff_math() {
        assert_eq!(backoff_units(0), 0);
        assert_eq!(backoff_units(1), 1);
        assert_eq!(backoff_units(3), 7);
        assert_eq!(backoff_penalty(0), 0.0);
        assert!((backoff_penalty(7) - 7.0 * RETRY_BACKOFF_BASE).abs() < 1e-15);
    }

    #[test]
    fn sel_resolve() {
        let mut rng = SplitMix64::new(1);
        assert_eq!(Sel::Fixed(2).resolve(4, &mut rng, "OST").unwrap(), 2);
        assert!(Sel::Fixed(4).resolve(4, &mut rng, "OST").is_err());
        assert!(Sel::Random.resolve(4, &mut rng, "OST").unwrap() < 4);
        assert!(Sel::Random.resolve(0, &mut rng, "OST").is_err());
    }
}
