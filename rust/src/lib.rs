//! # tamio — Two-layer Aggregation Method for MPI collective I/O
//!
//! A full reproduction of Kang et al., *"Improving MPI Collective I/O
//! Performance With Intra-node Request Aggregation"* (TPDS 2020 /
//! DOI 10.1109/TPDS.2020.3000458), built as a data-pipeline framework:
//!
//! * [`cluster`] — compute-machine topology (ranks ↔ nodes, plus the
//!   socket/NUMA and switch-group hierarchy levels the aggregation tree
//!   and the per-tier link table are built over).
//! * [`netmodel`] — α–β network cost model with receiver congestion and the
//!   paper's Isend/Issend pending-queue effect (§V).
//! * [`mpisim`] — MPI-like substrate: flattened file views, subarray
//!   datatype flattening, rank state, phase-structured message exchange.
//! * [`lustre`] — striped object-store simulator: OSTs, extent locks,
//!   byte-accurate storage for read-back verification, I/O cost model.
//! * [`faults`] — seeded fault injection and degraded-execution policy:
//!   `--faults` schedules (transient/persistent OST failures, per-OST
//!   service-rate skew, aggregator dropout), bounded retry-with-backoff,
//!   and the per-OST runtime fault state the storage layer probes.
//! * [`coordinator`] — the paper's contribution, generalized: N-level
//!   aggregation trees ([`coordinator::tree`]) of which ROMIO-style
//!   two-phase I/O ([`coordinator::twophase`], depth 0) and the two-layer
//!   aggregation method ([`coordinator::tam`], depth 1) are thin
//!   bindings, with per-level aggregator selection/placement policies,
//!   request calculation, k-way merge and request coalescing, multi-round
//!   scheduling and breakdown timers.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas
//!   aggregation pipeline (`artifacts/agg_*.hlo.txt`); the
//!   [`runtime::engine::SortEngine`] trait abstracts native-Rust vs XLA
//!   execution of the aggregator hot path.
//! * [`workloads`] — E3SM F/G, BTIO and S3D-IO I/O-pattern generators
//!   (Table I) plus synthetic patterns.
//! * [`metrics`] — simulated-time clocks, per-phase breakdowns matching
//!   the paper's Figures 4–7, report emitters.
//! * [`config`] — run configuration + a small TOML-subset parser and CLI
//!   argument handling (the image has no clap/serde).
//! * [`benchkit`] / [`propmini`] — in-repo micro-benchmark harness and
//!   property-testing helpers (no criterion/proptest in the image).
//!
//! Python/JAX runs only at build time (`make artifacts`); the Rust binary
//! is self-contained afterwards — see `DESIGN.md` for the three-layer
//! architecture and the experiment index.

// `--features simd` swaps the chunked merge/scatter primitives onto
// `std::simd` (nightly-only; the scalar fallback is always compiled and
// oracle-tested — DESIGN.md §SIMD kernels).  The gate lives here so the
// feature is a no-op on stable *builds of the default feature set*.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod benchkit;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod faults;
pub mod lustre;
pub mod metrics;
pub mod mpisim;
pub mod netmodel;
pub mod propmini;
pub mod runtime;
pub mod util;
pub mod workloads;

pub use error::{Error, Result};

/// Crate-wide prelude for examples and benches.
pub mod prelude {
    pub use crate::cluster::{LevelKind, LinkTier, RankPlacement, Topology};
    pub use crate::config::RunConfig;
    pub use crate::coordinator::autotune::{
        candidate_specs, fingerprint_autotune, tune_collective, AutoChoice, PredictedCost,
    };
    pub use crate::coordinator::breakdown::Breakdown;
    pub use crate::coordinator::collective::{
        run_collective_read, run_collective_read_with, run_collective_write,
        run_collective_write_with, Algorithm, CollectiveOutcome, Direction, DirectionSpec,
        ExchangeArena,
    };
    pub use crate::coordinator::plancache::{
        fingerprint_collective, repair_plan, run_collective_read_cached,
        run_collective_read_degraded, run_collective_write_cached,
        run_collective_write_degraded, CollectivePlan, Fp128, PlanCache, PlanCacheStats,
    };
    pub use crate::coordinator::tam::TamConfig;
    pub use crate::coordinator::tree::{AggregationPlan, TreeSpec};
    pub use crate::faults::{FaultPlan, OstFaultState};
    pub use crate::lustre::LustreConfig;
    pub use crate::netmodel::{NetParams, SendMode};
    pub use crate::runtime::engine::{EngineKind, SortEngine};
    pub use crate::workloads::{Workload, WorkloadKind};
}
