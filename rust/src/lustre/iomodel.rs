//! I/O-phase cost model: parallel OSTs with seek + bandwidth + lock terms.
//!
//! The paper keeps the I/O phase identical between two-phase I/O and TAM
//! (§IV-C) and its experiments show it roughly constant under strong
//! scaling (total bytes fixed, aggregator count fixed).  The model captures
//! that: OSTs drain in parallel; each OST's time is `extents · seek +
//! bytes / bandwidth` plus a serialization penalty per lock conflict.

use super::storage::OstStats;

/// Cost parameters for one OST (all OSTs identical, as on Theta).
#[derive(Clone, Copy, Debug)]
pub struct IoModel {
    /// Seconds per noncontiguous extent (seek/RPC setup).
    pub seek: f64,
    /// OST streaming bandwidth, bytes/second.
    pub ost_bandwidth: f64,
    /// Serialization penalty per extent-lock conflict (seconds).
    pub lock_penalty: f64,
}

impl Default for IoModel {
    /// Order-of-magnitude Theta Lustre (sonexion) per-OST figures; the
    /// aggregate (56 OSTs) peaks at a few hundred GB/s of streaming writes.
    fn default() -> Self {
        IoModel {
            seek: 4.0e-4,
            ost_bandwidth: 7.5e8, // 750 MB/s per OST
            lock_penalty: 1.0e-3,
        }
    }
}

impl IoModel {
    /// Time for one OST's accumulated work.
    pub fn ost_time(&self, s: &OstStats) -> f64 {
        s.extents as f64 * self.seek
            + s.bytes as f64 / self.ost_bandwidth
            + s.lock_conflicts as f64 * self.lock_penalty
    }

    /// I/O-phase time: OSTs work in parallel → max over OSTs.
    pub fn phase_time(&self, stats: &[OstStats]) -> f64 {
        stats.iter().map(|s| self.ost_time(s)).fold(0.0, f64::max)
    }

    /// Aggregate achieved bandwidth for a phase (bytes, time).
    pub fn bandwidth(total_bytes: u64, time: f64) -> f64 {
        if time <= 0.0 {
            0.0
        } else {
            total_bytes as f64 / time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(bytes: u64, extents: u64, conflicts: u64) -> OstStats {
        OstStats { bytes, extents, lock_acquisitions: extents, lock_conflicts: conflicts }
    }

    #[test]
    fn parallel_osts_take_max() {
        let m = IoModel::default();
        let a = st(1 << 30, 1, 0);
        let b = st(1 << 20, 1, 0);
        let phase = m.phase_time(&[a.clone(), b]);
        assert!((phase - m.ost_time(&a)).abs() < 1e-12);
    }

    #[test]
    fn seeks_dominate_fragmented_io() {
        let m = IoModel::default();
        let frag = st(1 << 20, 10_000, 0);
        let contig = st(1 << 20, 1, 0);
        assert!(m.ost_time(&frag) > 100.0 * m.ost_time(&contig));
    }

    #[test]
    fn lock_conflicts_penalized() {
        let m = IoModel::default();
        assert!(m.ost_time(&st(0, 0, 5)) > m.ost_time(&st(0, 0, 0)));
    }

    #[test]
    fn bandwidth_math() {
        assert_eq!(IoModel::bandwidth(1000, 2.0), 500.0);
        assert_eq!(IoModel::bandwidth(1000, 0.0), 0.0);
    }
}
