//! I/O-phase cost model: parallel OSTs with seek + bandwidth + lock terms.
//!
//! The paper keeps the I/O phase identical between two-phase I/O and TAM
//! (§IV-C) and its experiments show it roughly constant under strong
//! scaling (total bytes fixed, aggregator count fixed).  The model captures
//! that: OSTs drain in parallel; each OST's time is `extents · seek +
//! bytes / bandwidth` plus a serialization penalty per lock conflict.

use super::storage::OstStats;

/// Cost parameters for one OST (all OSTs identical, as on Theta).
#[derive(Clone, Copy, Debug)]
pub struct IoModel {
    /// Seconds per noncontiguous extent (seek/RPC setup).
    pub seek: f64,
    /// OST streaming bandwidth, bytes/second.
    pub ost_bandwidth: f64,
    /// Serialization penalty per extent-lock conflict (seconds).
    pub lock_penalty: f64,
}

impl Default for IoModel {
    /// Order-of-magnitude Theta Lustre (sonexion) per-OST figures; the
    /// aggregate (56 OSTs) peaks at a few hundred GB/s of streaming writes.
    fn default() -> Self {
        IoModel {
            seek: 4.0e-4,
            ost_bandwidth: 7.5e8, // 750 MB/s per OST
            lock_penalty: 1.0e-3,
        }
    }
}

impl IoModel {
    /// Time for one OST's accumulated work.
    pub fn ost_time(&self, s: &OstStats) -> f64 {
        s.extents as f64 * self.seek
            + s.bytes as f64 / self.ost_bandwidth
            + s.lock_conflicts as f64 * self.lock_penalty
    }

    /// I/O-phase time: OSTs work in parallel → max over OSTs.
    pub fn phase_time(&self, stats: &[OstStats]) -> f64 {
        stats.iter().map(|s| self.ost_time(s)).fold(0.0, f64::max)
    }

    /// Time for one OST serving at `rate`× its nominal service rate
    /// (fault injection: `ost_slow=0.25x` → 4× the nominal time).  Rate
    /// 1.0 is bit-identical to [`Self::ost_time`].
    pub fn ost_time_at_rate(&self, s: &OstStats, rate: f64) -> f64 {
        if rate == 1.0 {
            self.ost_time(s)
        } else {
            self.ost_time(s) / rate
        }
    }

    /// I/O-phase time under per-OST service-rate skew: the slowest
    /// (rate-stretched) OST sets the phase.  An empty `rates` slice means
    /// uniform 1.0 and is bit-identical to [`Self::phase_time`] — the
    /// fault-free path costs nothing extra.
    pub fn phase_time_skewed(&self, stats: &[OstStats], rates: &[f64]) -> f64 {
        if rates.is_empty() {
            return self.phase_time(stats);
        }
        stats
            .iter()
            .enumerate()
            .map(|(i, s)| self.ost_time_at_rate(s, rates.get(i).copied().unwrap_or(1.0)))
            .fold(0.0, f64::max)
    }

    /// Aggregate achieved bandwidth for a phase (bytes, time).
    pub fn bandwidth(total_bytes: u64, time: f64) -> f64 {
        if time <= 0.0 {
            0.0
        } else {
            total_bytes as f64 / time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn st(bytes: u64, extents: u64, conflicts: u64) -> OstStats {
        OstStats { bytes, extents, lock_acquisitions: extents, lock_conflicts: conflicts }
    }

    #[test]
    fn parallel_osts_take_max() {
        let m = IoModel::default();
        let a = st(1 << 30, 1, 0);
        let b = st(1 << 20, 1, 0);
        let phase = m.phase_time(&[a.clone(), b]);
        assert!((phase - m.ost_time(&a)).abs() < 1e-12);
    }

    #[test]
    fn seeks_dominate_fragmented_io() {
        let m = IoModel::default();
        let frag = st(1 << 20, 10_000, 0);
        let contig = st(1 << 20, 1, 0);
        assert!(m.ost_time(&frag) > 100.0 * m.ost_time(&contig));
    }

    #[test]
    fn lock_conflicts_penalized() {
        let m = IoModel::default();
        assert!(m.ost_time(&st(0, 0, 5)) > m.ost_time(&st(0, 0, 0)));
    }

    #[test]
    fn rate_skew_stretches_the_slow_ost() {
        let m = IoModel::default();
        let stats = [st(1 << 20, 4, 0), st(1 << 20, 4, 0)];
        // Uniform rates (or an empty table) are bit-identical to phase_time.
        assert_eq!(m.phase_time_skewed(&stats, &[]), m.phase_time(&stats));
        assert_eq!(m.phase_time_skewed(&stats, &[1.0, 1.0]), m.phase_time(&stats));
        // A 0.25x OST takes exactly 4x its nominal time and sets the phase.
        let skewed = m.phase_time_skewed(&stats, &[1.0, 0.25]);
        assert!((skewed - 4.0 * m.ost_time(&stats[1])).abs() < 1e-12);
        assert!(skewed > m.phase_time(&stats));
        // A short rate table treats missing entries as 1.0.
        assert_eq!(m.phase_time_skewed(&stats, &[0.5]), m.ost_time(&stats[0]) / 0.5);
    }

    #[test]
    fn bandwidth_math() {
        assert_eq!(IoModel::bandwidth(1000, 2.0), 500.0);
        assert_eq!(IoModel::bandwidth(1000, 0.0), 0.0);
    }
}
