//! Lustre file-system simulator: striping, OSTs, extent locks, storage.
//!
//! The paper's I/O phase depends on Lustre specifics: the file is striped
//! round-robin across `stripe_count` OSTs in `stripe_size` units; ROMIO
//! picks one global aggregator per OST so every aggregator only ever
//! touches "its" OST (no extent-lock conflicts, §II/§IV-C), and each
//! two-phase round writes at most one stripe per aggregator.
//!
//! * [`LustreConfig`] — stripe geometry + the stripe↔OST/offset math.
//! * [`storage`] — byte-accurate in-memory OST stores (read-back
//!   verification) + per-OST I/O accounting.
//! * [`iomodel`] — the I/O-phase cost model (seek + bandwidth per OST,
//!   parallel across OSTs, lock-conflict serialization penalty).

pub mod iomodel;
pub mod storage;

pub use iomodel::IoModel;
pub use storage::{LustreFile, OstStats};

/// Stripe geometry of a shared file.
#[derive(Clone, Copy, Debug)]
pub struct LustreConfig {
    /// Bytes per stripe unit (Theta experiments: 1 MiB).
    pub stripe_size: u64,
    /// Number of OSTs the file is striped over (Theta: 56).
    pub stripe_count: usize,
}

impl Default for LustreConfig {
    fn default() -> Self {
        LustreConfig { stripe_size: 1 << 20, stripe_count: 56 }
    }
}

impl LustreConfig {
    /// New geometry; panics on zeros (config-layer invariant).
    pub fn new(stripe_size: u64, stripe_count: usize) -> Self {
        assert!(stripe_size > 0 && stripe_count > 0);
        LustreConfig { stripe_size, stripe_count }
    }

    /// Stripe index containing a byte offset.
    pub fn stripe_of(&self, offset: u64) -> u64 {
        offset / self.stripe_size
    }

    /// OST serving a byte offset (round-robin striping).
    pub fn ost_of(&self, offset: u64) -> usize {
        (self.stripe_of(offset) % self.stripe_count as u64) as usize
    }

    /// Byte range `[start, end)` of stripe `s`.
    pub fn stripe_range(&self, s: u64) -> (u64, u64) {
        (s * self.stripe_size, (s + 1) * self.stripe_size)
    }

    /// Split `[offset, offset+len)` at stripe boundaries, yielding
    /// `(ost, offset, len)` pieces — the unit of OST I/O and locking.
    pub fn split_by_stripe(&self, offset: u64, len: u64) -> Vec<(usize, u64, u64)> {
        let mut out = Vec::new();
        let mut cur = offset;
        let end = offset + len;
        while cur < end {
            let stripe = self.stripe_of(cur);
            let (_, sup) = self.stripe_range(stripe);
            let piece_end = end.min(sup);
            out.push((self.ost_of(cur), cur, piece_end - cur));
            cur = piece_end;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stripe_and_ost_math() {
        let c = LustreConfig::new(1024, 4);
        assert_eq!(c.stripe_of(0), 0);
        assert_eq!(c.stripe_of(1023), 0);
        assert_eq!(c.stripe_of(1024), 1);
        assert_eq!(c.ost_of(0), 0);
        assert_eq!(c.ost_of(1024), 1);
        assert_eq!(c.ost_of(4096), 0); // wraps at stripe_count
    }

    #[test]
    fn stripe_range_bounds() {
        let c = LustreConfig::new(100, 3);
        assert_eq!(c.stripe_range(2), (200, 300));
    }

    #[test]
    fn split_by_stripe_single_piece() {
        let c = LustreConfig::new(1024, 4);
        assert_eq!(c.split_by_stripe(10, 100), vec![(0, 10, 100)]);
    }

    #[test]
    fn split_by_stripe_crosses_boundaries() {
        let c = LustreConfig::new(100, 2);
        let pieces = c.split_by_stripe(50, 200);
        assert_eq!(
            pieces,
            vec![(0, 50, 50), (1, 100, 100), (0, 200, 50)]
        );
        let total: u64 = pieces.iter().map(|p| p.2).sum();
        assert_eq!(total, 200);
    }

    #[test]
    fn split_zero_len_empty() {
        let c = LustreConfig::default();
        assert!(c.split_by_stripe(5, 0).is_empty());
    }
}
