//! Byte-accurate striped storage + per-OST accounting.
//!
//! Stores written bytes per OST in stripe-sized blocks so correctness can be
//! verified by reading the shared file back; tracks per-OST extent counts,
//! byte totals and lock acquisitions for the I/O cost model and the
//! lock-conflict statistics.

use std::collections::HashMap;

use crate::error::Result;
use crate::faults::{OstFailure, OstFaultState};
use crate::mpisim::FlatView;

use super::LustreConfig;

/// Per-OST accounting for one collective operation.
#[derive(Clone, Debug, Default)]
pub struct OstStats {
    /// Bytes written to / read from this OST.
    pub bytes: u64,
    /// Noncontiguous extents touched (≈ seeks).
    pub extents: u64,
    /// Extent-lock acquisitions by distinct writers within a round; values
    /// above 1 for the same stripe in the same round mark a lock conflict.
    pub lock_acquisitions: u64,
    /// Lock conflicts detected (two writers on one stripe in one round).
    pub lock_conflicts: u64,
}

/// A shared file striped across simulated OSTs.
#[derive(Debug)]
pub struct LustreFile {
    cfg: LustreConfig,
    /// stripe index -> stripe payload (lazily allocated, sparse file).
    stripes: HashMap<u64, Vec<u8>>,
    /// stripe index -> writer rank holding its extent lock this round.
    round_locks: HashMap<u64, usize>,
    stats: Vec<OstStats>,
    /// Fault-injection state: persistent/transient OST failures, per-OST
    /// service rates, round-armed faults (`crate::faults`).
    faults: OstFaultState,
}

impl LustreFile {
    /// Create an empty striped file.
    pub fn new(cfg: LustreConfig) -> Self {
        LustreFile {
            cfg,
            stripes: HashMap::new(),
            round_locks: HashMap::new(),
            stats: vec![OstStats::default(); cfg.stripe_count],
            faults: OstFaultState::new(cfg.stripe_count),
        }
    }

    /// Stripe geometry.
    pub fn config(&self) -> &LustreConfig {
        &self.cfg
    }

    /// Mark an OST as persistently failed (failure injection).  Rejects
    /// out-of-range indices with an actionable message instead of
    /// panicking.
    pub fn fail_ost(&mut self, ost: usize) -> Result<()> {
        self.faults.install(OstFailure { ost, round: None, transient: None })
    }

    /// Mark an OST transiently failed: the next `count` touches error
    /// with [`crate::error::Error::StorageTransient`], then the OST heals.
    pub fn fail_ost_transient(&mut self, ost: usize, count: u64) -> Result<()> {
        self.faults.install(OstFailure { ost, round: None, transient: Some(count) })
    }

    /// Arm a failure at the start of I/O round `round` (0-based, counted
    /// from the last [`Self::reset_fault_rounds`] / file creation):
    /// persistent when `transient` is `None`, else healing after that
    /// many errors.
    pub fn arm_ost_fault(
        &mut self,
        round: u64,
        ost: usize,
        transient: Option<u64>,
    ) -> Result<()> {
        self.faults.install(OstFailure { ost, round: Some(round), transient })
    }

    /// Set one OST's service-rate multiplier (consumed by
    /// [`super::IoModel::phase_time_skewed`] via [`Self::ost_rates`]).
    pub fn set_ost_rate(&mut self, ost: usize, rate: f64) -> Result<()> {
        self.faults.set_rate(ost, rate)
    }

    /// Per-OST service-rate multipliers (empty = uniform 1.0).
    pub fn ost_rates(&self) -> &[f64] {
        self.faults.rates()
    }

    /// Mutable fault state (bulk installation by the experiments driver).
    pub fn faults_mut(&mut self) -> &mut OstFaultState {
        &mut self.faults
    }

    /// Per-site retry bound for transient storage errors.
    pub fn max_retries(&self) -> u32 {
        self.faults.max_retries()
    }

    /// Restart the fault-round clock (round-armed faults count from 0).
    pub fn reset_fault_rounds(&mut self) {
        self.faults.reset_rounds();
    }

    /// Read-side round boundary: arms round-scheduled faults.  `&self` —
    /// the read path has no exclusive file access (and takes no locks, so
    /// there is nothing else to reset).
    pub fn tick_fault_round(&self) {
        self.faults.tick_round();
    }

    /// Begin a new I/O round: extent locks from the previous round drop
    /// and round-scheduled faults arm.
    pub fn begin_round(&mut self) {
        self.round_locks.clear();
        self.faults.tick_round();
    }

    /// Write `data` at `offset` on behalf of `writer` (an aggregator rank).
    ///
    /// Splits at stripe boundaries, performs the byte-accurate store, and
    /// accounts extents/locks per OST.  Returns an error if an OST has been
    /// failed via [`Self::fail_ost`].
    pub fn write_at(&mut self, writer: usize, offset: u64, data: &[u8]) -> Result<()> {
        self.write_extent(writer, offset, data)
    }

    /// Vectored write: land a whole coalesced batch — `view` segments with
    /// their contiguous `payload` in view order — in one call, instead of a
    /// per-segment cursor loop at the call site (§Perf tentpole).
    ///
    /// Byte-identical to calling [`Self::write_at`] per segment, including
    /// extent/lock accounting order.
    pub fn write_view(&mut self, writer: usize, view: &FlatView, payload: &[u8]) -> Result<()> {
        debug_assert_eq!(payload.len() as u64, view.total_bytes());
        let mut cursor = 0usize;
        for (off, len) in view.iter() {
            self.write_extent(writer, off, &payload[cursor..cursor + len as usize])?;
            cursor += len as usize;
        }
        Ok(())
    }

    /// One contiguous extent: inlined stripe walk (no per-call `Vec` from
    /// `split_by_stripe` — this is the innermost I/O loop).
    fn write_extent(&mut self, writer: usize, offset: u64, data: &[u8]) -> Result<()> {
        let stripe_size = self.cfg.stripe_size as usize;
        let mut cursor = 0usize;
        let mut cur = offset;
        let end = offset + data.len() as u64;
        while cur < end {
            let stripe = self.cfg.stripe_of(cur);
            let (stripe_lo, stripe_hi) = self.cfg.stripe_range(stripe);
            let piece_end = end.min(stripe_hi);
            let piece_len = (piece_end - cur) as usize;
            let ost = self.cfg.ost_of(cur);
            self.faults.check(ost, cur, piece_len as u64)?;
            // Extent-lock accounting (Lustre locks per OST object; with
            // stripe-aligned file domains each stripe has one writer).
            match self.round_locks.get(&stripe) {
                Some(&holder) if holder != writer => {
                    self.stats[ost].lock_conflicts += 1;
                    self.round_locks.insert(stripe, writer);
                    self.stats[ost].lock_acquisitions += 1;
                }
                Some(_) => {}
                None => {
                    self.round_locks.insert(stripe, writer);
                    self.stats[ost].lock_acquisitions += 1;
                }
            }
            let within = (cur - stripe_lo) as usize;
            let buf = self
                .stripes
                .entry(stripe)
                .or_insert_with(|| vec![0u8; stripe_size]);
            buf[within..within + piece_len].copy_from_slice(&data[cursor..cursor + piece_len]);
            cursor += piece_len;
            self.stats[ost].bytes += piece_len as u64;
            self.stats[ost].extents += 1;
            cur = piece_end;
        }
        Ok(())
    }

    /// Vectored read: fill `out` with the bytes of `view`'s segments in
    /// view order (zero-filled where never written), mirroring
    /// [`Self::write_view`]'s inlined stripe walk — no per-segment `Vec`
    /// from [`LustreConfig::split_by_stripe`] and no per-segment result
    /// allocation on the read hot path.
    ///
    /// `out` is cleared and resized to `view.total_bytes()` (capacity is
    /// reused across calls — the read scratch-arena hot path).  Reads take
    /// `&self`, so per-OST accounting accumulates into the caller-owned
    /// `stats` (one slot per OST).  Returns an error if a covered OST has
    /// been failed via [`Self::fail_ost`], mirroring the write side.
    pub fn read_view(
        &self,
        view: &FlatView,
        out: &mut Vec<u8>,
        stats: &mut [OstStats],
    ) -> Result<()> {
        debug_assert_eq!(stats.len(), self.cfg.stripe_count);
        out.clear();
        out.resize(view.total_bytes() as usize, 0);
        let mut cursor = 0usize;
        for (off, len) in view.iter() {
            let mut cur = off;
            let end = off + len;
            while cur < end {
                let stripe = self.cfg.stripe_of(cur);
                let (stripe_lo, stripe_hi) = self.cfg.stripe_range(stripe);
                let piece_end = end.min(stripe_hi);
                let piece_len = (piece_end - cur) as usize;
                let ost = self.cfg.ost_of(cur);
                self.faults.check(ost, cur, piece_len as u64)?;
                if let Some(buf) = self.stripes.get(&stripe) {
                    let within = (cur - stripe_lo) as usize;
                    out[cursor..cursor + piece_len]
                        .copy_from_slice(&buf[within..within + piece_len]);
                }
                stats[ost].bytes += piece_len as u64;
                stats[ost].extents += 1;
                cursor += piece_len;
                cur = piece_end;
            }
        }
        Ok(())
    }

    /// Read `len` bytes at `offset` (zero-filled where never written).
    pub fn read_at(&self, offset: u64, len: u64) -> Vec<u8> {
        let mut out = vec![0u8; len as usize];
        let mut cursor = 0usize;
        for (_, piece_off, piece_len) in self.cfg.split_by_stripe(offset, len) {
            let stripe = self.cfg.stripe_of(piece_off);
            if let Some(buf) = self.stripes.get(&stripe) {
                let (stripe_lo, _) = self.cfg.stripe_range(stripe);
                let within = (piece_off - stripe_lo) as usize;
                out[cursor..cursor + piece_len as usize]
                    .copy_from_slice(&buf[within..within + piece_len as usize]);
            }
            cursor += piece_len as usize;
        }
        out
    }

    /// Per-OST statistics so far.
    pub fn stats(&self) -> &[OstStats] {
        &self.stats
    }

    /// Total bytes stored (sum over OSTs).
    pub fn total_bytes_written(&self) -> u64 {
        self.stats.iter().map(|s| s.bytes).sum()
    }

    /// Total lock conflicts across OSTs.
    pub fn total_lock_conflicts(&self) -> u64 {
        self.stats.iter().map(|s| s.lock_conflicts).sum()
    }

    /// Size of the written region (max end offset touched).
    pub fn extent_end(&self) -> u64 {
        self.stripes
            .keys()
            .map(|&s| self.cfg.stripe_range(s).1)
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LustreConfig {
        LustreConfig::new(64, 4)
    }

    #[test]
    fn write_read_round_trip() {
        let mut f = LustreFile::new(cfg());
        let data: Vec<u8> = (0..200).map(|i| i as u8).collect();
        f.begin_round();
        f.write_at(0, 10, &data).unwrap();
        assert_eq!(f.read_at(10, 200), data);
    }

    #[test]
    fn unwritten_reads_zero() {
        let f = LustreFile::new(cfg());
        assert_eq!(f.read_at(100, 8), vec![0u8; 8]);
    }

    #[test]
    fn cross_stripe_write_accounts_extents() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 60, &[1u8; 10]).unwrap(); // crosses 64-boundary
        assert_eq!(f.stats()[0].extents, 1);
        assert_eq!(f.stats()[1].extents, 1);
        assert_eq!(f.total_bytes_written(), 10);
    }

    #[test]
    fn lock_conflict_detected_same_round() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 0, &[1u8; 8]).unwrap();
        f.write_at(1, 8, &[2u8; 8]).unwrap(); // same stripe, different writer
        assert_eq!(f.total_lock_conflicts(), 1);
    }

    #[test]
    fn no_conflict_across_rounds() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 0, &[1u8; 8]).unwrap();
        f.begin_round();
        f.write_at(1, 8, &[2u8; 8]).unwrap();
        assert_eq!(f.total_lock_conflicts(), 0);
    }

    #[test]
    fn same_writer_no_conflict() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(3, 0, &[1u8; 8]).unwrap();
        f.write_at(3, 8, &[2u8; 8]).unwrap();
        assert_eq!(f.total_lock_conflicts(), 0);
    }

    #[test]
    fn failed_ost_rejects() {
        let mut f = LustreFile::new(cfg());
        f.fail_ost(0).unwrap();
        f.begin_round();
        let err = f.write_at(0, 0, &[0u8; 4]).unwrap_err();
        assert!(matches!(err, crate::Error::StorageFailed { ost: 0, offset: 0, len: 4, .. }));
        assert!(!err.is_transient());
        assert!(f.write_at(0, 64, &[0u8; 4]).is_ok()); // OST 1 fine
    }

    #[test]
    fn fail_ost_out_of_range_errors_instead_of_panicking() {
        let mut f = LustreFile::new(cfg());
        let err = f.fail_ost(99).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("99") && msg.contains("4"), "unhelpful message: {msg}");
        assert!(f.fail_ost_transient(99, 1).is_err());
        assert!(f.set_ost_rate(99, 0.5).is_err());
        assert!(f.arm_ost_fault(0, 99, None).is_err());
    }

    #[test]
    fn transient_ost_heals_after_countdown() {
        let mut f = LustreFile::new(cfg());
        f.fail_ost_transient(0, 2).unwrap();
        f.begin_round();
        for _ in 0..2 {
            let err = f.write_at(0, 0, &[1u8; 4]).unwrap_err();
            assert!(err.is_transient(), "got {err}");
            assert!(matches!(err, crate::Error::StorageTransient { ost: 0, .. }));
        }
        // Healed: the same write now lands.
        f.write_at(0, 0, &[1u8; 4]).unwrap();
        assert_eq!(f.read_at(0, 4), vec![1u8; 4]);
    }

    #[test]
    fn round_armed_fault_triggers_at_its_round() {
        let mut f = LustreFile::new(cfg());
        f.arm_ost_fault(1, 0, Some(1)).unwrap();
        f.reset_fault_rounds();
        f.begin_round(); // round 0
        f.write_at(0, 0, &[1u8; 4]).unwrap();
        f.begin_round(); // round 1: fault arms
        assert!(f.write_at(0, 0, &[1u8; 4]).unwrap_err().is_transient());
        f.write_at(0, 0, &[2u8; 4]).unwrap(); // healed
        assert_eq!(f.read_at(0, 4), vec![2u8; 4]);
    }

    #[test]
    fn ost_rates_default_uniform_and_install() {
        let mut f = LustreFile::new(cfg());
        assert!(f.ost_rates().is_empty());
        f.set_ost_rate(2, 0.25).unwrap();
        assert_eq!(f.ost_rates(), &[1.0, 1.0, 0.25, 1.0]);
        // Rate skew never rejects I/O — it only stretches simulated time.
        f.begin_round();
        f.write_at(0, 128, &[3u8; 8]).unwrap(); // OST 2
        assert_eq!(f.read_at(128, 8), vec![3u8; 8]);
    }

    #[test]
    fn overwrite_last_writer_wins() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 0, &[1u8; 8]).unwrap();
        f.write_at(0, 4, &[9u8; 2]).unwrap();
        assert_eq!(f.read_at(0, 8), vec![1, 1, 1, 1, 9, 9, 1, 1]);
    }

    #[test]
    fn write_view_matches_per_segment_write_at() {
        let view = FlatView::from_pairs(vec![(10, 30), (60, 10), (70, 0), (200, 5)]).unwrap();
        let payload: Vec<u8> = (0..45).map(|i| i as u8).collect();

        let mut a = LustreFile::new(cfg());
        a.begin_round();
        a.write_view(3, &view, &payload).unwrap();

        let mut b = LustreFile::new(cfg());
        b.begin_round();
        let mut cursor = 0usize;
        for (off, len) in view.iter() {
            b.write_at(3, off, &payload[cursor..cursor + len as usize]).unwrap();
            cursor += len as usize;
        }

        assert_eq!(a.read_at(0, 256), b.read_at(0, 256));
        assert_eq!(a.total_bytes_written(), b.total_bytes_written());
        for (sa, sb) in a.stats().iter().zip(b.stats()) {
            assert_eq!(sa.extents, sb.extents);
            assert_eq!(sa.lock_acquisitions, sb.lock_acquisitions);
            assert_eq!(sa.lock_conflicts, sb.lock_conflicts);
        }
    }

    #[test]
    fn write_view_failed_ost_rejects() {
        let mut f = LustreFile::new(cfg());
        f.fail_ost(1).unwrap();
        f.begin_round();
        let view = FlatView::from_pairs(vec![(0, 8), (64, 8)]).unwrap();
        assert!(matches!(
            f.write_view(0, &view, &[1u8; 16]).unwrap_err(),
            crate::Error::StorageFailed { ost: 1, offset: 64, len: 8, .. }
        ));
        // The piece before the failed OST landed (same as sequential
        // write_at semantics).
        assert_eq!(f.read_at(0, 8), vec![1u8; 8]);
    }

    #[test]
    fn read_view_matches_per_segment_read_at() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        let data: Vec<u8> = (0..200).map(|i| (i as u8).wrapping_mul(7)).collect();
        f.write_at(0, 30, &data).unwrap();

        // Segments crossing stripe boundaries, a zero-length request, and
        // a never-written tail.
        let view = FlatView::from_pairs(vec![(10, 30), (60, 70), (130, 0), (500, 20)]).unwrap();
        let mut out = vec![0xFFu8; 3]; // stale buffer must be fully replaced
        let mut stats = vec![OstStats::default(); f.config().stripe_count];
        f.read_view(&view, &mut out, &mut stats).unwrap();

        let mut want = Vec::new();
        for (off, len) in view.iter() {
            want.extend_from_slice(&f.read_at(off, len));
        }
        assert_eq!(out, want);

        // Per-OST accounting matches the split_by_stripe reference.
        let mut want_bytes = vec![0u64; f.config().stripe_count];
        let mut want_extents = vec![0u64; f.config().stripe_count];
        for (off, len) in view.iter() {
            for (ost, _, piece_len) in f.config().split_by_stripe(off, len) {
                want_bytes[ost] += piece_len;
                want_extents[ost] += 1;
            }
        }
        for (ost, s) in stats.iter().enumerate() {
            assert_eq!(s.bytes, want_bytes[ost], "OST {ost} bytes");
            assert_eq!(s.extents, want_extents[ost], "OST {ost} extents");
        }
    }

    #[test]
    fn read_view_reuses_buffer_without_stale_bytes() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 0, &[9u8; 16]).unwrap();
        let mut out = Vec::new();
        let mut stats = vec![OstStats::default(); f.config().stripe_count];
        let big = FlatView::from_pairs(vec![(0, 16)]).unwrap();
        f.read_view(&big, &mut out, &mut stats).unwrap();
        assert_eq!(out, vec![9u8; 16]);
        // Smaller view over unwritten space: must come back all zero.
        let small = FlatView::from_pairs(vec![(1000, 4)]).unwrap();
        f.read_view(&small, &mut out, &mut stats).unwrap();
        assert_eq!(out, vec![0u8; 4]);
    }

    #[test]
    fn read_view_failed_ost_rejects() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 0, &[1u8; 128]).unwrap();
        f.fail_ost(1).unwrap();
        let view = FlatView::from_pairs(vec![(0, 8), (64, 8)]).unwrap();
        let mut out = Vec::new();
        let mut stats = vec![OstStats::default(); f.config().stripe_count];
        assert!(matches!(
            f.read_view(&view, &mut out, &mut stats).unwrap_err(),
            crate::Error::StorageFailed { ost: 1, .. }
        ));
        // OST 0 alone is fine.
        let ok = FlatView::from_pairs(vec![(0, 8)]).unwrap();
        f.read_view(&ok, &mut out, &mut stats).unwrap();
        assert_eq!(out, vec![1u8; 8]);
    }

    #[test]
    fn extent_end_tracks_highest_stripe() {
        let mut f = LustreFile::new(cfg());
        f.begin_round();
        f.write_at(0, 1000, &[1u8; 4]).unwrap();
        assert!(f.extent_end() >= 1004);
    }
}
