//! `tamio` CLI — the coordinator launcher.
//!
//! ```text
//! tamio run      [--config file.toml] [--nodes N --ppn Q --workload W
//!                 --algorithm two-phase|tam|tam:<P_L> --engine native|xla
//!                 --direction write|read|both --scale S --verify ...]
//! tamio sweep    [--pl 16,64,256,...] <run flags>    # Figures 4–7 panels
//! tamio scaling  [--procs 256,1024,...] <run flags>  # Figure 3 series
//! tamio table1   [--budget-reqs N]                   # Table I
//! tamio congest  <run flags>                         # Figure 2 stats
//! tamio info                                         # engine/platform
//! ```
//!
//! All `--key value` flags map onto [`tamio::config::RunConfig`] keys; a
//! `--config` TOML-subset file is applied first, CLI flags override.

use tamio::config::{KvMap, RunConfig};
use tamio::coordinator::collective::Algorithm;
use tamio::error::Result;
use tamio::experiments;
use tamio::metrics::{
    breakdown_panels, breakdown_table, degraded_summary, plan_cache_summary, render_table,
    scaling_table, tuner_validation_table,
};
use tamio::util::{human_bytes, human_secs};
use tamio::workloads::WorkloadKind;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let (mut kv, positional) = KvMap::from_cli(args)?;
    let cmd = positional.first().map(String::as_str).unwrap_or("help");

    // Flags consumed by subcommands rather than RunConfig.
    let config_file = kv.take("config");
    let pl_list = kv.take("pl");
    let procs_list = kv.take("procs");
    let validate_tuner = kv.take("validate-tuner").is_some();
    // A typo'd budget must fail loudly: silently substituting the
    // default would size every workload off the wrong request count.
    let budget: u64 = match kv.take("budget-reqs") {
        Some(s) => s.parse().map_err(|_| {
            tamio::Error::config(format!(
                "--budget-reqs: '{s}' is not a positive integer (e.g. --budget-reqs 200000)"
            ))
        })?,
        None => 200_000,
    };

    let mut cfg = RunConfig::default();
    if let Some(path) = config_file {
        cfg.apply(&KvMap::from_file(path)?)?;
    }
    cfg.apply(&kv)?;

    // Pin the worker-pool width before any collective touches the pool
    // (the width is fixed at first use; a late conflicting request is a
    // hard error rather than a silently ignored flag).
    if let Some(n) = cfg.threads {
        tamio::util::runtime::configure_global_threads(n)?;
    }

    match cmd {
        "run" => cmd_run(&cfg),
        "sweep" => cmd_sweep(&cfg, pl_list.as_deref(), validate_tuner),
        "scaling" => cmd_scaling(&cfg, procs_list.as_deref(), budget),
        "table1" => cmd_table1(&cfg, budget),
        "congest" => cmd_congest(&cfg),
        "info" => cmd_info(&cfg),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
tamio — Two-layer Aggregation Method for MPI collective I/O (paper repro)

USAGE: tamio <run|sweep|scaling|table1|congest|info> [--key value ...]

Common flags (RunConfig keys):
  --nodes N --ppn Q --workload e3sm-g|e3sm-f|btio|s3d|contig|strided
  --algorithm two-phase|tam|tam:<P_L>|tree|tree:<levels>|auto
                                        tree:<levels> is a comma list of
                                        socket=<n>,node=<n>,switch=<n>
                                        aggregators per group (absent =
                                        level off; 'tree:flat' = depth 0 =
                                        two-phase, 'tree:node=c' = TAM
                                        with c aggregators per node);
                                        'auto' prices a bounded candidate
                                        grid (depth 0-3 x placements) with
                                        the metadata-only cost predictor
                                        and runs the cheapest
  --engine native|xla
  --direction write|read|both           collective direction(s); read runs
                                        pre-populate the file and always
                                        verify the gathered bytes (default
                                        write)
  --sockets_per_node S                  NUMA domains per node (default 1;
                                        enables the tree's socket level)
  --nodes_per_switch N                  nodes per leaf switch (default 0 =
                                        flat; enables the switch level)
  --rank_placement block|round-robin    rank->socket / node->switch layout
  --scale S --stripe_size B --stripe_count K --send_mode isend|issend
  --placement spread|cray --seed S --verify --config file.toml
  --overlap on|off|auto                 double-buffered round pipelining:
                                        round r+1's exchange/merge runs
                                        while round r's storage call
                                        executes, so the steady-state
                                        round costs max(exchange, io)
                                        instead of the sum (issend bounds
                                        the win: a round's sends cannot
                                        complete before its receivers
                                        post).  Bytes and verification
                                        are bit-identical to serial;
                                        default off
  --plan-cache DIR                      persist aggregation plans to DIR;
                                        repeat invocations with the same
                                        shape skip plan construction
  --plan-cache-size N                   warm plans kept in memory (LRU,
                                        default 8)
  --threads N                           worker-pool width for the merge/
                                        scatter hot path (default: the
                                        TAMIO_THREADS env var, else all
                                        available cores; results are
                                        bit-identical for any width)
  --faults SPEC                         seeded fault schedule: comma list
                                        of ost_fail=<ost|?>[@round:<r>]
                                        [@transient:<n>] (persistent, or
                                        healing after n errors, optionally
                                        armed at I/O round r),
                                        ost_slow=<f>x:<lo>[-<hi>] (OST
                                        range serves at f x nominal rate),
                                        agg_drop=<rank|?>[@level:<l>]
                                        (aggregator dropout repaired by
                                        promoting a survivor; bytes stay
                                        identical to the fault-free run)
  --fault-seed N                        resolves '?' selectors; the whole
                                        schedule is a pure function of the
                                        seed (default 0)
  --max-retries N                       transient-error retry bound per
                                        storage call site; each retry
                                        costs exponential simulated
                                        backoff in io_phase (default 4)
  net tier table: --net.alpha_socket/--net.beta_socket and
  --net.alpha_switch/--net.beta_switch price the extra hierarchy tiers

Subcommand flags:
  sweep:   --pl 16,64,256          breakdown panels (Figures 4-7)
           --faults SPEC           degradation-curve panel instead: a
                                   fault-free baseline bar, then one bar
                                   per cumulative clause prefix with its
                                   slowdown factor in the label
           --validate-tuner        with --algorithm auto: run the top-4
                                   predicted candidates for real, report
                                   predicted-vs-measured relative error
                                   and Spearman rank correlation
  scaling: --procs 256,1024,4096   Figure 3 series; --budget-reqs N
  table1:  --budget-reqs N
";

fn cmd_run(cfg: &RunConfig) -> Result<()> {
    let topo = cfg.topology();
    println!(
        "run: {} on {} nodes x {} ppn (P={}), algo={}, engine={}, direction={}, stripes {}x{}, overlap={}",
        cfg.workload,
        cfg.nodes,
        cfg.ppn,
        topo.nprocs(),
        cfg.algorithm.name(),
        cfg.engine,
        cfg.direction,
        cfg.lustre.stripe_count,
        human_bytes(cfg.lustre.stripe_size),
        cfg.overlap,
    );
    let t0 = std::time::Instant::now();
    let engine = experiments::build_engine_for(cfg)?;
    let (results, cache_stats) = experiments::run_once_with_stats(cfg, engine.as_ref())?;
    let wall = t0.elapsed();
    let mut failed: Option<String> = None;
    for (run, verify) in &results {
        print!("{}", breakdown_table(std::slice::from_ref(run)));
        let c = &run.counters;
        println!(
            "requests: posted={} after-intra={} at-io={}  msgs: intra={} inter={} max-indegree={}",
            c.reqs_posted, c.reqs_after_intra, c.reqs_at_io, c.msgs_intra, c.msgs_inter,
            c.max_in_degree
        );
        println!(
            "bytes={}  rounds={}  lock-conflicts={}  sim-time={}",
            human_bytes(c.bytes),
            c.rounds,
            c.lock_conflicts,
            human_secs(run.breakdown.total()),
        );
        if cfg.faults.is_some() {
            println!("{}", degraded_summary(c));
        }
        if let Some(v) = verify {
            println!(
                "verify[{}]: {}/{} ranks OK{}",
                run.direction,
                v.ok,
                v.total,
                if v.passed() { "" } else { "  <-- MISMATCH" }
            );
            if !v.passed() && failed.is_none() {
                failed = Some(format!(
                    "{} [{}]: {}/{} ranks",
                    run.label, run.direction, v.ok, v.total
                ));
            }
        }
    }
    println!("{}", plan_cache_summary(&cache_stats));
    println!("wall={wall:?} (all directions)");
    if let Some(msg) = failed {
        return Err(tamio::Error::Verify(msg));
    }
    Ok(())
}

/// Parse a `--<flag> a,b,c` integer list, or fall back to `default` when
/// the flag is absent.  Every entry must parse: silently dropping a
/// typo'd entry (the old `filter_map(.ok())`) would sweep or scale over
/// a different grid than the one the user asked for.
fn parse_list(flag: &str, s: Option<&str>, default: &[usize]) -> Result<Vec<usize>> {
    let Some(s) = s else { return Ok(default.to_vec()) };
    let out = s
        .split(',')
        .map(|x| {
            let x = x.trim();
            x.parse::<usize>().map_err(|_| {
                tamio::Error::config(format!(
                    "--{flag}: '{x}' is not a positive integer (in list '{s}')"
                ))
            })
        })
        .collect::<Result<Vec<usize>>>()?;
    if out.is_empty() {
        return Err(tamio::Error::config(format!("--{flag}: empty list")));
    }
    Ok(out)
}

fn cmd_sweep(cfg: &RunConfig, pl: Option<&str>, validate_tuner: bool) -> Result<()> {
    let p = cfg.topology().nprocs();
    if validate_tuner {
        if cfg.algorithm != Algorithm::Auto {
            return Err(tamio::Error::config(
                "--validate-tuner requires --algorithm auto (it checks the tuner's predictions)",
            ));
        }
        println!(
            "tuner validation: {} P={} direction={} (top-4 predicted candidates run for real)",
            cfg.workload, p, cfg.direction
        );
        let reports = experiments::validate_tuner(cfg, 4)?;
        print!("{}", tuner_validation_table(&reports));
        return Ok(());
    }
    if let Some(plan) = &cfg.faults {
        println!(
            "degradation sweep: {} P={} algo={} direction={} faults='{plan}' seed={}",
            cfg.workload,
            p,
            cfg.algorithm.name(),
            cfg.direction,
            cfg.fault_seed
        );
        let runs = experiments::degradation_sweep(cfg)?;
        print!("{}", breakdown_panels(&runs));
        for run in &runs {
            println!("{} [{}]: {}", run.label, run.direction, degraded_summary(&run.counters));
        }
        return Ok(());
    }
    let defaults: Vec<usize> = [16, 64, 256, 1024]
        .into_iter()
        .filter(|&x| x <= p)
        .collect();
    let pls = parse_list("pl", pl, &defaults)?;
    println!(
        "breakdown sweep: {} P={} pl={:?} direction={} (last bar = two-phase)",
        cfg.workload, p, pls, cfg.direction
    );
    let runs = experiments::breakdown_sweep(cfg, &pls)?;
    print!("{}", breakdown_panels(&runs));
    Ok(())
}

fn cmd_scaling(cfg: &RunConfig, procs: Option<&str>, budget: u64) -> Result<()> {
    let procs = parse_list("procs", procs, &[256, 1024, 4096])?;
    println!(
        "strong scaling: {} procs={:?} ppn={} direction={} budget={budget} reqs",
        cfg.workload, procs, cfg.ppn, cfg.direction
    );
    let series = experiments::fig3_series(cfg, cfg.workload, &procs, budget)?;
    print!("{}", scaling_table(&cfg.workload.to_string(), &series));
    Ok(())
}

fn cmd_table1(cfg: &RunConfig, budget: u64) -> Result<()> {
    let topo = cfg.topology();
    let rows = experiments::table1_rows(&topo, budget)?;
    let headers: Vec<String> = [
        "dataset",
        "paper #reqs",
        "paper bytes",
        "run #reqs",
        "run bytes",
        "scale",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    print!("{}", render_table(&headers, &rows));
    Ok(())
}

fn cmd_congest(cfg: &RunConfig) -> Result<()> {
    let rows = experiments::fig2_congestion(cfg)?;
    let headers: Vec<String> = ["algorithm", "max in-degree", "mean msgs/agg", "total msgs"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let rows: Vec<Vec<String>> = rows
        .into_iter()
        .map(|(a, max, mean, n)| vec![a, max.to_string(), format!("{mean:.1}"), n.to_string()])
        .collect();
    print!("{}", render_table(&headers, &rows));
    Ok(())
}

fn cmd_info(cfg: &RunConfig) -> Result<()> {
    println!("tamio {} — TAM collective-I/O reproduction", env!("CARGO_PKG_VERSION"));
    println!(
        "worker pool: {} threads (override: --threads / TAMIO_THREADS)",
        tamio::util::runtime::default_threads()
    );
    println!(
        "simd kernels: {}",
        if cfg!(feature = "simd") { "std::simd (u64x8 lanes)" } else { "scalar fallback" }
    );
    println!("send_mode: {} (override: --send_mode isend|issend)", cfg.net.send_mode);
    println!("overlap: {} (override: --overlap on|off|auto)", cfg.overlap);
    match tamio::runtime::PjrtRuntime::load_default() {
        Ok(rt) => {
            println!("artifacts: {} (platform {})", rt.artifacts_dir().display(), rt.platform());
            println!("batch sizes: {:?}", rt.batch_sizes());
        }
        Err(e) => println!("xla engine unavailable: {e}"),
    }
    for k in WorkloadKind::paper_set() {
        println!("workload available: {k}");
    }
    Ok(())
}
