//! Reporting: breakdown tables, scaling series, CSV/JSON emitters.
//!
//! The bench harnesses print the same rows/series the paper's figures
//! plot; these helpers keep the formatting consistent and provide CSV
//! output for external plotting.

pub mod report;

pub use report::{render_table, write_csv, JsonWriter};

use crate::cluster::RankPlacement;
use crate::coordinator::breakdown::{Breakdown, Counters, LevelTime};
use crate::coordinator::collective::Direction;
use crate::coordinator::plancache::PlanCacheStats;
use crate::coordinator::tree::TreeSpec;
use crate::util::{human_bytes, human_secs};

/// One labelled run (e.g. one bar of a Figure 4–7 panel).
#[derive(Clone, Debug)]
pub struct LabelledRun {
    /// Bar label (e.g. "P_L=256" or "two-phase").
    pub label: String,
    /// Collective direction this run drove (the paper reports write and
    /// read panels separately).
    pub direction: Direction,
    /// Component times.
    pub breakdown: Breakdown,
    /// Volume counters.
    pub counters: Counters,
}

/// Render a Figures-4–7-style breakdown table: one column per run, one
/// row per component, plus one `intra[<level>]` row per aggregation-tree
/// level any run carries (the per-level split of the intra sums; runs
/// without that level print zero).  Columns are labelled with their
/// direction.
pub fn breakdown_table(runs: &[LabelledRun]) -> String {
    let mut headers = vec!["component".to_string()];
    headers.extend(runs.iter().map(|r| format!("{} [{}]", r.label, r.direction)));
    let comp_names: Vec<&'static str> =
        Breakdown::default().rows().iter().map(|(n, _)| *n).collect();
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (i, name) in comp_names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for r in runs {
            row.push(human_secs(r.breakdown.rows()[i].1));
        }
        rows.push(row);
    }
    // Per-level rows are matched by *label*, not level index: runs of
    // different depths share a table (e.g. tam's [node] next to a tree's
    // [socket, node]), and positional matching would print one run's
    // socket cost in another's node row.  Canonical innermost-first
    // order, then any other labels by first appearance.
    let has_label = |label: &str| {
        runs.iter().any(|r| r.breakdown.levels.iter().any(|l| l.label == label))
    };
    let mut level_labels: Vec<&'static str> = ["socket", "node", "switch"]
        .into_iter()
        .filter(|label| has_label(label))
        .collect();
    for r in runs {
        for l in &r.breakdown.levels {
            if !level_labels.contains(&l.label) {
                level_labels.push(l.label);
            }
        }
    }
    for label in level_labels {
        let mut row = vec![format!("intra[{label}]")];
        for r in runs {
            let t = r
                .breakdown
                .levels
                .iter()
                .find(|l| l.label == label)
                .map(LevelTime::total)
                .unwrap_or(0.0);
            row.push(human_secs(t));
        }
        rows.push(row);
    }
    for (name, f) in [
        ("intra_total", Breakdown::intra_total as fn(&Breakdown) -> f64),
        ("inter_total", Breakdown::inter_total as fn(&Breakdown) -> f64),
        ("end_to_end", Breakdown::total as fn(&Breakdown) -> f64),
    ] {
        let mut row = vec![name.to_string()];
        for r in runs {
            row.push(human_secs(f(&r.breakdown)));
        }
        rows.push(row);
    }
    let mut row = vec!["bandwidth".to_string()];
    for r in runs {
        row.push(format!("{}/s", human_bytes(r.breakdown.bandwidth(r.counters.bytes) as u64)));
    }
    rows.push(row);
    render_table(&headers, &rows)
}

/// Render one breakdown panel per direction present in `runs` (write
/// first), each introduced by a `-- <direction> panel --` title — the
/// Figures 4–7 write/read panel pair when a sweep ran `--direction both`.
pub fn breakdown_panels(runs: &[LabelledRun]) -> String {
    let mut out = String::new();
    for dir in [Direction::Write, Direction::Read] {
        let panel: Vec<LabelledRun> =
            runs.iter().filter(|r| r.direction == dir).cloned().collect();
        if panel.is_empty() {
            continue;
        }
        out.push_str(&format!("-- {dir} panel --\n"));
        out.push_str(&breakdown_table(&panel));
    }
    out
}

/// One-line plan-oracle summary for run reports.  The three lookup
/// outcomes partition (warm hit / disk load / fresh build), so the
/// printed counts sum to total lookups; `rejected` counts corrupt or
/// stale files that fell back to a build.  Build time is real `Instant`
/// time — the only wall-clock the cache exposes; all simulated times
/// stay in [`Breakdown`].
pub fn plan_cache_summary(stats: &PlanCacheStats) -> String {
    format!(
        "plan-cache: {} warm hit{}, {} build{} ({:.3} ms building), disk {} loaded / {} stored, {} rejected",
        stats.hits,
        if stats.hits == 1 { "" } else { "s" },
        stats.builds,
        if stats.builds == 1 { "" } else { "s" },
        stats.build_nanos as f64 / 1e6,
        stats.disk_loads,
        stats.disk_stores,
        stats.rejects,
    )
}

/// One-line degraded-execution summary for run reports: retries absorbed,
/// the simulated backoff they cost (folded into `io_phase`), and plans
/// rewritten by the aggregator-dropout repair pass.  Counters come from
/// [`Counters`], so the line always agrees with the breakdown table it
/// prints next to.
pub fn degraded_summary(counters: &Counters) -> String {
    format!(
        "degraded: {} retr{}, {} backoff unit{} ({:.3} ms penalty), {} repaired plan{}",
        counters.retries,
        if counters.retries == 1 { "y" } else { "ies" },
        counters.backoff_units,
        if counters.backoff_units == 1 { "" } else { "s" },
        crate::faults::backoff_penalty(counters.backoff_units) * 1e3,
        counters.repaired_plans,
        if counters.repaired_plans == 1 { "" } else { "s" },
    )
}

/// One row of a tuner-validation report: a candidate the predictor
/// ranked in its top-k, run for real.
#[derive(Clone, Copy, Debug)]
pub struct TunerValidationRow {
    /// The candidate tree spec.
    pub spec: TreeSpec,
    /// Rank placement the candidate was priced and run under.
    pub placement: RankPlacement,
    /// Predicted end-to-end time (seconds).
    pub predicted: f64,
    /// Measured (simulated) end-to-end time (seconds).
    pub measured: f64,
    /// `|predicted - measured| / measured`.
    pub rel_error: f64,
}

/// One direction's tuner-validation report: the top-k predicted
/// candidates in predicted order, plus the ordering agreement summary.
#[derive(Clone, Debug)]
pub struct TunerValidation {
    /// Direction the candidates ran in.
    pub direction: Direction,
    /// Candidates in predicted order (row 0 = the tuner's choice).
    pub rows: Vec<TunerValidationRow>,
    /// Spearman rank correlation between predicted and measured order.
    pub spearman: f64,
    /// Whether the predicted winner measured within the top 2.
    pub winner_in_top2: bool,
}

/// Render `--validate-tuner` reports: one table per direction with
/// predicted/measured/relative-error columns, followed by the rank
/// correlation and the winner-in-measured-top-2 verdict.
pub fn tuner_validation_table(reports: &[TunerValidation]) -> String {
    let mut out = String::new();
    for rep in reports {
        out.push_str(&format!("-- tuner validation [{}] --\n", rep.direction));
        let headers = [
            "candidate".to_string(),
            "placement".to_string(),
            "predicted".to_string(),
            "measured".to_string(),
            "rel-err".to_string(),
        ];
        let rows: Vec<Vec<String>> = rep
            .rows
            .iter()
            .map(|r| {
                vec![
                    format!("tree:{}", r.spec),
                    match r.placement {
                        RankPlacement::Block => "block".to_string(),
                        RankPlacement::RoundRobin => "round-robin".to_string(),
                    },
                    human_secs(r.predicted),
                    human_secs(r.measured),
                    format!("{:.1}%", r.rel_error * 100.0),
                ]
            })
            .collect();
        out.push_str(&render_table(&headers, &rows));
        out.push_str(&format!(
            "rank-correlation (spearman) = {:.3}; predicted winner in measured top-2: {}\n",
            rep.spearman,
            if rep.winner_in_top2 { "yes" } else { "NO" },
        ));
    }
    out
}

/// A strong-scaling series (Figure 3): `(P, bandwidth_bytes_per_s)`.
#[derive(Clone, Debug)]
pub struct ScalingSeries {
    /// Series label (algorithm).
    pub label: String,
    /// Points `(nprocs, bandwidth B/s)`.
    pub points: Vec<(usize, f64)>,
}

/// Render Figure-3-style series side by side.
pub fn scaling_table(title: &str, series: &[ScalingSeries]) -> String {
    let mut headers = vec![format!("{title} P")];
    headers.extend(series.iter().map(|s| format!("{} (MiB/s)", s.label)));
    let ps: Vec<usize> = series
        .first()
        .map(|s| s.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    let mut rows = Vec::new();
    for (i, p) in ps.iter().enumerate() {
        let mut row = vec![p.to_string()];
        for s in series {
            row.push(format!("{:.1}", s.points[i].1 / (1024.0 * 1024.0)));
        }
        rows.push(row);
    }
    render_table(&headers, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_table_has_all_components() {
        let run = LabelledRun {
            label: "P_L=4".into(),
            direction: Direction::Write,
            breakdown: Breakdown { intra_comm: 0.5, ..Default::default() },
            counters: Counters { bytes: 1 << 20, ..Default::default() },
        };
        let t = breakdown_table(&[run]);
        for name in ["intra_comm", "io_phase", "plan", "overlap_saved", "end_to_end", "bandwidth"]
        {
            assert!(t.contains(name), "missing {name} in:\n{t}");
        }
        assert!(t.contains("P_L=4"));
        assert!(t.contains("[write]"), "direction label missing:\n{t}");
    }

    #[test]
    fn plan_cache_summary_reports_all_counters() {
        let stats = PlanCacheStats {
            hits: 3,
            builds: 2,
            disk_loads: 1,
            disk_stores: 1,
            rejects: 2,
            build_nanos: 1_500_000,
        };
        let s = plan_cache_summary(&stats);
        assert!(s.contains("3 warm hits"), "{s}");
        assert!(s.contains("2 builds ("), "{s}");
        assert!(s.contains("1.500 ms"), "{s}");
        assert!(s.contains("1 loaded / 1 stored"), "{s}");
        assert!(s.contains("2 rejected"), "{s}");
        // Singular forms stay grammatical.
        let one = plan_cache_summary(&PlanCacheStats {
            hits: 1,
            builds: 1,
            ..Default::default()
        });
        assert!(one.contains("1 warm hit,"), "{one}");
        assert!(one.contains("1 build ("), "{one}");
    }

    #[test]
    fn degraded_summary_reports_retry_and_repair_counters() {
        let c = Counters {
            retries: 3,
            backoff_units: 7,
            repaired_plans: 2,
            ..Default::default()
        };
        let s = degraded_summary(&c);
        assert!(s.contains("3 retries"), "{s}");
        assert!(s.contains("7 backoff units"), "{s}");
        assert!(s.contains("7.000 ms penalty"), "{s}");
        assert!(s.contains("2 repaired plans"), "{s}");
        // Singular forms stay grammatical.
        let one = degraded_summary(&Counters {
            retries: 1,
            backoff_units: 1,
            repaired_plans: 1,
            ..Default::default()
        });
        assert!(one.contains("1 retry,"), "{one}");
        assert!(one.contains("1 backoff unit ("), "{one}");
        assert!(one.contains("1 repaired plan"), "{one}");
    }

    #[test]
    fn tuner_validation_table_renders_rows_and_verdict() {
        let rep = TunerValidation {
            direction: Direction::Write,
            rows: vec![
                TunerValidationRow {
                    spec: TreeSpec { per_socket: 0, per_node: 2, per_switch: 0 },
                    placement: RankPlacement::Block,
                    predicted: 0.010,
                    measured: 0.012,
                    rel_error: 2.0 / 12.0,
                },
                TunerValidationRow {
                    spec: TreeSpec::flat(),
                    placement: RankPlacement::RoundRobin,
                    predicted: 0.020,
                    measured: 0.011,
                    rel_error: 9.0 / 11.0,
                },
            ],
            spearman: -1.0,
            winner_in_top2: true,
        };
        let t = tuner_validation_table(&[rep]);
        assert!(t.contains("-- tuner validation [write] --"), "{t}");
        assert!(t.contains("tree:node=2"), "{t}");
        assert!(t.contains("tree:flat"), "{t}");
        assert!(t.contains("block"), "{t}");
        assert!(t.contains("round-robin"), "{t}");
        assert!(t.contains("16.7%"), "{t}");
        assert!(t.contains("rank-correlation (spearman) = -1.000"), "{t}");
        assert!(t.contains("top-2: yes"), "{t}");
    }

    #[test]
    fn breakdown_table_emits_per_level_rows_matched_by_label() {
        let mut tree = Breakdown { intra_comm: 0.4, ..Default::default() };
        tree.levels.push(LevelTime { label: "socket", comm: 0.3, sort: 0.0, memcpy: 0.0 });
        tree.levels.push(LevelTime { label: "node", comm: 0.1, sort: 0.0, memcpy: 0.0 });
        // A depth-1 run whose ONLY level is "node" (at level index 0):
        // index-based matching would print its node cost in the socket
        // row — the rows must match by label instead.
        let mut tam = Breakdown { intra_comm: 7.0, ..Default::default() };
        tam.levels.push(LevelTime { label: "node", comm: 7.0, sort: 0.0, memcpy: 0.0 });
        let runs = vec![
            LabelledRun {
                label: "tam-bar".into(),
                direction: Direction::Write,
                breakdown: tam,
                counters: Counters::default(),
            },
            LabelledRun {
                label: "tree-bar".into(),
                direction: Direction::Write,
                breakdown: tree,
                counters: Counters::default(),
            },
            LabelledRun {
                label: "two-phase".into(),
                direction: Direction::Write,
                breakdown: Breakdown::default(),
                counters: Counters::default(),
            },
        ];
        let t = breakdown_table(&runs);
        assert!(t.contains("intra[socket]"), "missing socket row:\n{t}");
        assert!(t.contains("intra[node]"), "missing node row:\n{t}");
        // Exactly one row per label (no duplicate positional rows), and
        // the socket row (canonically innermost) precedes the node row.
        assert_eq!(t.matches("intra[socket]").count(), 1, "{t}");
        assert_eq!(t.matches("intra[node]").count(), 1, "{t}");
        assert!(t.find("intra[socket]").unwrap() < t.find("intra[node]").unwrap(), "{t}");
        // The tam bar's 7s lands in the node row, not the socket row.
        let socket_row = t.lines().find(|l| l.contains("intra[socket]")).unwrap();
        assert!(!socket_row.contains("7.00"), "tam cost misattributed:\n{t}");
        let node_row = t.lines().find(|l| l.contains("intra[node]")).unwrap();
        assert!(node_row.contains("7.00"), "tam cost missing from node row:\n{t}");
        // Level-less runs render without per-level rows of their own.
        let flat_only = breakdown_table(&runs[2..]);
        assert!(!flat_only.contains("intra["), "{flat_only}");
    }

    #[test]
    fn breakdown_panels_split_by_direction_write_first() {
        let mk = |label: &str, direction| LabelledRun {
            label: label.into(),
            direction,
            breakdown: Breakdown::default(),
            counters: Counters::default(),
        };
        let runs = vec![
            mk("rd-bar", Direction::Read),
            mk("wr-bar", Direction::Write),
        ];
        let t = breakdown_panels(&runs);
        let w = t.find("-- write panel --").expect("write panel");
        let r = t.find("-- read panel --").expect("read panel");
        assert!(w < r, "write panel must come first:\n{t}");
        assert!(t.contains("wr-bar") && t.contains("rd-bar"));
        // Single-direction input produces a single panel.
        let only = breakdown_panels(&runs[1..]);
        assert!(only.contains("-- write panel --"));
        assert!(!only.contains("-- read panel --"));
    }

    #[test]
    fn scaling_table_lists_points() {
        let s = ScalingSeries {
            label: "tam".into(),
            points: vec![(256, 1e9), (1024, 2e9)],
        };
        let t = scaling_table("e3sm-g", &[s]);
        assert!(t.contains("256"));
        assert!(t.contains("1024"));
        assert!(t.contains("tam"));
    }
}
