//! Reporting: breakdown tables, scaling series, CSV/JSON emitters.
//!
//! The bench harnesses print the same rows/series the paper's figures
//! plot; these helpers keep the formatting consistent and provide CSV
//! output for external plotting.

pub mod report;

pub use report::{render_table, write_csv, JsonWriter};

use crate::coordinator::breakdown::{Breakdown, Counters};
use crate::util::{human_bytes, human_secs};

/// One labelled run (e.g. one bar of a Figure 4–7 panel).
#[derive(Clone, Debug)]
pub struct LabelledRun {
    /// Bar label (e.g. "P_L=256" or "two-phase").
    pub label: String,
    /// Component times.
    pub breakdown: Breakdown,
    /// Volume counters.
    pub counters: Counters,
}

/// Render a Figures-4–7-style breakdown table: one column per run, one
/// row per component.
pub fn breakdown_table(runs: &[LabelledRun]) -> String {
    let mut headers = vec!["component".to_string()];
    headers.extend(runs.iter().map(|r| r.label.clone()));
    let comp_names: Vec<&'static str> =
        Breakdown::default().rows().iter().map(|(n, _)| *n).collect();
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (i, name) in comp_names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for r in runs {
            row.push(human_secs(r.breakdown.rows()[i].1));
        }
        rows.push(row);
    }
    for (name, f) in [
        ("intra_total", Breakdown::intra_total as fn(&Breakdown) -> f64),
        ("inter_total", Breakdown::inter_total as fn(&Breakdown) -> f64),
        ("end_to_end", Breakdown::total as fn(&Breakdown) -> f64),
    ] {
        let mut row = vec![name.to_string()];
        for r in runs {
            row.push(human_secs(f(&r.breakdown)));
        }
        rows.push(row);
    }
    let mut row = vec!["bandwidth".to_string()];
    for r in runs {
        row.push(format!("{}/s", human_bytes(r.breakdown.bandwidth(r.counters.bytes) as u64)));
    }
    rows.push(row);
    render_table(&headers, &rows)
}

/// A strong-scaling series (Figure 3): `(P, bandwidth_bytes_per_s)`.
#[derive(Clone, Debug)]
pub struct ScalingSeries {
    /// Series label (algorithm).
    pub label: String,
    /// Points `(nprocs, bandwidth B/s)`.
    pub points: Vec<(usize, f64)>,
}

/// Render Figure-3-style series side by side.
pub fn scaling_table(title: &str, series: &[ScalingSeries]) -> String {
    let mut headers = vec![format!("{title} P")];
    headers.extend(series.iter().map(|s| format!("{} (MiB/s)", s.label)));
    let ps: Vec<usize> = series
        .first()
        .map(|s| s.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    let mut rows = Vec::new();
    for (i, p) in ps.iter().enumerate() {
        let mut row = vec![p.to_string()];
        for s in series {
            row.push(format!("{:.1}", s.points[i].1 / (1024.0 * 1024.0)));
        }
        rows.push(row);
    }
    render_table(&headers, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_table_has_all_components() {
        let run = LabelledRun {
            label: "P_L=4".into(),
            breakdown: Breakdown { intra_comm: 0.5, ..Default::default() },
            counters: Counters { bytes: 1 << 20, ..Default::default() },
        };
        let t = breakdown_table(&[run]);
        for name in ["intra_comm", "io_phase", "end_to_end", "bandwidth"] {
            assert!(t.contains(name), "missing {name} in:\n{t}");
        }
        assert!(t.contains("P_L=4"));
    }

    #[test]
    fn scaling_table_lists_points() {
        let s = ScalingSeries {
            label: "tam".into(),
            points: vec![(256, 1e9), (1024, 2e9)],
        };
        let t = scaling_table("e3sm-g", &[s]);
        assert!(t.contains("256"));
        assert!(t.contains("1024"));
        assert!(t.contains("tam"));
    }
}
