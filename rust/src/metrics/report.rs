//! Plain-text table rendering, CSV output, and a tiny JSON writer
//! (serde is not available in the image).

use std::fmt::Write as _;
use std::path::Path;

use crate::error::Result;

/// Render an aligned ASCII table.
pub fn render_table(headers: &[String], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        let _ = write!(out, "| {h:width$} ", width = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            let _ = write!(out, "| {cell:width$} ", width = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Write rows as CSV (no quoting needed for our numeric content; commas
/// in cells are replaced defensively).
pub fn write_csv(path: impl AsRef<Path>, headers: &[String], rows: &[Vec<String>]) -> Result<()> {
    let clean = |s: &String| s.replace(',', ";");
    let mut out = String::new();
    out.push_str(&headers.iter().map(clean).collect::<Vec<_>>().join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(clean).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Minimal JSON object writer for structured reports.
#[derive(Debug, Default)]
pub struct JsonWriter {
    fields: Vec<(String, String)>,
}

impl JsonWriter {
    /// New empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a numeric field.
    pub fn num(mut self, key: &str, v: f64) -> Self {
        self.fields.push((key.to_string(), format!("{v}")));
        self
    }

    /// Add an integer field.
    pub fn int(mut self, key: &str, v: u64) -> Self {
        self.fields.push((key.to_string(), format!("{v}")));
        self
    }

    /// Add a string field (escapes quotes and backslashes).
    pub fn str(mut self, key: &str, v: &str) -> Self {
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"");
        self.fields.push((key.to_string(), format!("\"{escaped}\"")));
        self
    }

    /// Add a raw (pre-serialized) field, e.g. a nested object.
    pub fn raw(mut self, key: &str, v: String) -> Self {
        self.fields.push((key.to_string(), v));
        self
    }

    /// Serialize.
    pub fn finish(self) -> String {
        let body = self
            .fields
            .into_iter()
            .map(|(k, v)| format!("\"{k}\": {v}"))
            .collect::<Vec<_>>()
            .join(", ");
        format!("{{{body}}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["a".into(), "long-header".into()],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        assert!(t.contains("| a   | long-header |"));
        assert!(t.contains("| 333 | 4           |"));
    }

    #[test]
    fn csv_round_trip() {
        let dir = std::env::temp_dir().join("tamio_csv_test.csv");
        write_csv(&dir, &["x".into(), "y".into()], &[vec!["1".into(), "2,3".into()]]).unwrap();
        let s = std::fs::read_to_string(&dir).unwrap();
        assert_eq!(s, "x,y\n1,2;3\n");
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn json_writer_escapes() {
        let j = JsonWriter::new()
            .str("name", "a\"b")
            .int("n", 3)
            .num("t", 1.5)
            .finish();
        assert_eq!(j, "{\"name\": \"a\\\"b\", \"n\": 3, \"t\": 1.5}");
    }
}
