//! Flattened MPI file views: nondecreasing `(offset, length)` lists.

use crate::error::{Error, Result};

/// A flattened file view: parallel `offsets`/`lengths` arrays, offsets
/// monotonically nondecreasing (the MPI file-view requirement the paper's
/// heap merge relies on).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FlatView {
    offsets: Vec<u64>,
    lengths: Vec<u64>,
}

impl FlatView {
    /// Empty view.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Build from pairs, validating the nondecreasing-offset invariant.
    pub fn from_pairs(pairs: Vec<(u64, u64)>) -> Result<Self> {
        let mut v = FlatView {
            offsets: Vec::with_capacity(pairs.len()),
            lengths: Vec::with_capacity(pairs.len()),
        };
        let mut prev = 0u64;
        for (i, (off, len)) in pairs.into_iter().enumerate() {
            if i > 0 && off < prev {
                return Err(Error::Protocol(format!(
                    "file view offsets must be nondecreasing: pair {i} has offset {off} < {prev}"
                )));
            }
            prev = off;
            v.offsets.push(off);
            v.lengths.push(len);
        }
        Ok(v)
    }

    /// Build without validation (generator-internal use; debug-asserted).
    pub fn from_pairs_unchecked(offsets: Vec<u64>, lengths: Vec<u64>) -> Self {
        debug_assert_eq!(offsets.len(), lengths.len());
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]));
        FlatView { offsets, lengths }
    }

    /// Append one request; must keep offsets nondecreasing.
    pub fn push(&mut self, offset: u64, length: u64) {
        debug_assert!(self.offsets.last().is_none_or(|&last| offset >= last));
        self.offsets.push(offset);
        self.lengths.push(length);
    }

    /// Remove every request, keeping the allocated capacity — the
    /// scratch-arena entry point for views rebuilt each exchange round
    /// (e.g. the engine's merged output).
    pub fn clear(&mut self) {
        self.offsets.clear();
        self.lengths.clear();
    }

    /// Number of noncontiguous requests.
    pub fn len(&self) -> usize {
        self.offsets.len()
    }

    /// True when there are no requests.
    pub fn is_empty(&self) -> bool {
        self.offsets.is_empty()
    }

    /// Total bytes covered.
    pub fn total_bytes(&self) -> u64 {
        self.lengths.iter().sum()
    }

    /// Offsets slice.
    pub fn offsets(&self) -> &[u64] {
        &self.offsets
    }

    /// Lengths slice.
    pub fn lengths(&self) -> &[u64] {
        &self.lengths
    }

    /// Iterate `(offset, length)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.offsets.iter().copied().zip(self.lengths.iter().copied())
    }

    /// First byte offset covered (None when empty).
    pub fn min_offset(&self) -> Option<u64> {
        self.offsets.first().copied()
    }

    /// One-past-last byte offset covered (None when empty).
    pub fn max_end(&self) -> Option<u64> {
        self.iter().map(|(o, l)| o + l).max()
    }

    /// Coalesce adjacent exactly-contiguous requests in place
    /// (`off[i] == off[i-1] + len[i-1]`), the paper's coalescing rule.
    pub fn coalesce(&mut self) {
        if self.offsets.len() < 2 {
            return;
        }
        let mut w = 0usize;
        for r in 1..self.offsets.len() {
            if self.offsets[w] + self.lengths[w] == self.offsets[r] {
                self.lengths[w] += self.lengths[r];
            } else {
                w += 1;
                self.offsets[w] = self.offsets[r];
                self.lengths[w] = self.lengths[r];
            }
        }
        self.offsets.truncate(w + 1);
        self.lengths.truncate(w + 1);
    }

    /// Whether any two nonzero requests overlap in file space.  MPI
    /// permits overlapping filetypes for *reads* (erroneous for writes);
    /// the read exchange uses this to decide whether a requester view can
    /// be exchanged as-is or must go through [`Self::disjoint_union`].
    /// Zero-length requests occupy no bytes and never overlap.
    pub fn has_overlap(&self) -> bool {
        let mut end = 0u64;
        let mut first = true;
        for (off, len) in self.iter() {
            if len == 0 {
                continue;
            }
            if !first && off < end {
                return true;
            }
            end = end.max(off + len);
            first = false;
        }
        false
    }

    /// The disjoint union of this view's requests: sorted, maximal
    /// segments covering exactly the bytes touched, with overlapping and
    /// exactly-contiguous requests merged (zero-length requests dropped).
    pub fn disjoint_union(&self) -> FlatView {
        let mut out = FlatView::empty();
        let (mut lo, mut hi, mut have) = (0u64, 0u64, false);
        for (off, len) in self.iter() {
            if len == 0 {
                continue;
            }
            if have && off <= hi {
                hi = hi.max(off + len);
            } else {
                if have {
                    out.push(lo, hi - lo);
                }
                lo = off;
                hi = off + len;
                have = true;
            }
        }
        if have {
            out.push(lo, hi - lo);
        }
        out
    }

    /// Intersect this view with the byte range `[lo, hi)`, returning the
    /// contained (possibly clipped) requests and, for each, the byte offset
    /// *within this view's payload* where the clipped piece starts — needed
    /// to slice a rank's write buffer per file domain.
    pub fn clip_to_range(&self, lo: u64, hi: u64) -> Vec<ClippedReq> {
        let mut out = Vec::new();
        let mut payload_cursor = 0u64;
        for (off, len) in self.iter() {
            let end = off + len;
            let s = off.max(lo);
            let e = end.min(hi);
            if s < e {
                out.push(ClippedReq {
                    offset: s,
                    length: e - s,
                    payload_offset: payload_cursor + (s - off),
                });
            }
            payload_cursor += len;
        }
        out
    }

    /// Validate the invariant (used by property tests / failure injection).
    pub fn validate(&self) -> Result<()> {
        if self.offsets.len() != self.lengths.len() {
            return Err(Error::Protocol("offsets/lengths length mismatch".into()));
        }
        for w in self.offsets.windows(2) {
            if w[0] > w[1] {
                return Err(Error::Protocol(format!(
                    "offsets decrease: {} > {}",
                    w[0], w[1]
                )));
            }
        }
        for (o, l) in self.iter() {
            if o.checked_add(l).is_none() {
                return Err(Error::Protocol(format!("request [{o}, +{l}) overflows u64")));
            }
        }
        Ok(())
    }
}

/// A request clipped to a file-domain range, carrying the location of its
/// bytes within the owning rank's payload buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClippedReq {
    /// Absolute file offset of the clipped piece.
    pub offset: u64,
    /// Length of the clipped piece.
    pub length: u64,
    /// Byte position within the owner's payload where the piece starts.
    pub payload_offset: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_pairs_validates_order() {
        assert!(FlatView::from_pairs(vec![(0, 4), (4, 4), (4, 2)]).is_ok());
        assert!(FlatView::from_pairs(vec![(8, 4), (0, 4)]).is_err());
    }

    #[test]
    fn totals() {
        let v = FlatView::from_pairs(vec![(0, 4), (10, 6)]).unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(v.total_bytes(), 10);
        assert_eq!(v.min_offset(), Some(0));
        assert_eq!(v.max_end(), Some(16));
    }

    #[test]
    fn coalesce_merges_contiguous_runs() {
        let mut v = FlatView::from_pairs(vec![(0, 4), (4, 4), (8, 2), (20, 4), (24, 1)]).unwrap();
        v.coalesce();
        assert_eq!(
            v.iter().collect::<Vec<_>>(),
            vec![(0, 10), (20, 5)]
        );
    }

    #[test]
    fn coalesce_keeps_noncontiguous() {
        let mut v = FlatView::from_pairs(vec![(0, 4), (5, 4)]).unwrap();
        v.coalesce();
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn coalesce_zero_length_same_offset() {
        let mut v = FlatView::from_pairs(vec![(0, 4), (4, 0), (4, 4)]).unwrap();
        v.coalesce();
        assert_eq!(v.iter().collect::<Vec<_>>(), vec![(0, 8)]);
    }

    #[test]
    fn clip_to_range_clips_and_tracks_payload() {
        let v = FlatView::from_pairs(vec![(0, 10), (20, 10)]).unwrap();
        let c = v.clip_to_range(5, 25);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0], ClippedReq { offset: 5, length: 5, payload_offset: 5 });
        assert_eq!(c[1], ClippedReq { offset: 20, length: 5, payload_offset: 10 });
    }

    #[test]
    fn clip_to_range_empty_outside() {
        let v = FlatView::from_pairs(vec![(0, 10)]).unwrap();
        assert!(v.clip_to_range(100, 200).is_empty());
        assert!(v.clip_to_range(10, 10).is_empty());
    }

    #[test]
    fn clip_full_range_identity() {
        let v = FlatView::from_pairs(vec![(3, 4), (9, 2)]).unwrap();
        let c = v.clip_to_range(0, u64::MAX);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].payload_offset, 0);
        assert_eq!(c[1].payload_offset, 4);
    }

    #[test]
    fn has_overlap_detects_nested_and_partial() {
        assert!(!FlatView::from_pairs(vec![(0, 4), (4, 4), (10, 2)]).unwrap().has_overlap());
        assert!(FlatView::from_pairs(vec![(0, 8), (2, 4)]).unwrap().has_overlap());
        // Nested: a later short request inside an earlier long one.
        assert!(FlatView::from_pairs(vec![(0, 300), (50, 10)]).unwrap().has_overlap());
        // Zero-length requests never overlap anything.
        assert!(!FlatView::from_pairs(vec![(0, 8), (4, 0), (8, 2)]).unwrap().has_overlap());
        assert!(!FlatView::empty().has_overlap());
    }

    #[test]
    fn disjoint_union_merges_overlaps_and_contiguity() {
        let v = FlatView::from_pairs(vec![(0, 8), (2, 4), (8, 2), (20, 5), (40, 0)]).unwrap();
        let u = v.disjoint_union();
        assert_eq!(u.iter().collect::<Vec<_>>(), vec![(0, 10), (20, 5)]);
        assert!(!u.has_overlap());
        // Nested requests collapse into the covering segment.
        let n = FlatView::from_pairs(vec![(0, 300), (50, 10), (320, 4)]).unwrap();
        assert_eq!(n.disjoint_union().iter().collect::<Vec<_>>(), vec![(0, 300), (320, 4)]);
        assert!(FlatView::empty().disjoint_union().is_empty());
    }

    #[test]
    fn clear_keeps_capacity_and_empties() {
        let mut v = FlatView::from_pairs(vec![(0, 4), (10, 6)]).unwrap();
        v.clear();
        assert!(v.is_empty());
        assert_eq!(v.total_bytes(), 0);
        v.push(5, 3);
        assert_eq!(v.iter().collect::<Vec<_>>(), vec![(5, 3)]);
    }

    #[test]
    fn validate_catches_overflow() {
        let v = FlatView::from_pairs_unchecked(vec![u64::MAX - 1], vec![10]);
        assert!(v.validate().is_err());
    }
}
