//! MPI-like substrate: flattened file views, datatype flattening, rank
//! state.
//!
//! MPI collective I/O describes each process's access with a *file view*; an
//! implementation flattens the view into a monotonically nondecreasing list
//! of `(offset, length)` pairs (the MPI standard requires nondecreasing
//! offsets within one collective call — §IV-A of the paper relies on this
//! for the heap-merge).  This module provides:
//!
//! * [`FlatView`] — the flattened request list + invariant checking,
//! * [`subarray`] — flattening of N-dimensional subarray datatypes (the
//!   file views BTIO and S3D-IO construct),
//! * [`RankState`] — a simulated MPI process: its view and write payload.

pub mod flatview;
pub mod rank;
pub mod subarray;

pub use flatview::FlatView;
pub use rank::RankState;
pub use subarray::subarray_flatten;
