//! Simulated MPI process state for one collective I/O call.

use crate::error::{Error, Result};
use crate::util::SplitMix64;

use super::FlatView;

/// One MPI process's contribution to a collective write/read: its file view
/// and (for writes) the payload bytes, laid out in view order.
#[derive(Clone, Debug, Default)]
pub struct RankState {
    /// Global MPI rank.
    pub rank: usize,
    /// Flattened file view.
    pub view: FlatView,
    /// Write payload, `view.total_bytes()` long, in view order.
    pub payload: Vec<u8>,
}

impl RankState {
    /// Build a rank with a deterministic pseudo-random payload derived from
    /// `(seed, rank)` — verification recomputes the same bytes.
    pub fn with_random_payload(rank: usize, view: FlatView, seed: u64) -> Self {
        let payload = deterministic_payload(seed, rank, view.total_bytes());
        RankState { rank, view, payload }
    }

    /// Build a rank with an explicit payload; validates the length.
    pub fn with_payload(rank: usize, view: FlatView, payload: Vec<u8>) -> Result<Self> {
        if payload.len() as u64 != view.total_bytes() {
            return Err(Error::Protocol(format!(
                "rank {rank}: payload {} bytes but view covers {}",
                payload.len(),
                view.total_bytes()
            )));
        }
        Ok(RankState { rank, view, payload })
    }

    /// Bytes this rank writes.
    pub fn bytes(&self) -> u64 {
        self.view.total_bytes()
    }
}

/// The deterministic payload function shared by generators and verifiers:
/// byte `i` of rank `r` under `seed` is reproducible anywhere.
pub fn deterministic_payload(seed: u64, rank: usize, nbytes: u64) -> Vec<u8> {
    let mut rng = SplitMix64::new(seed ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // Word-at-a-time fill (§Perf change 2): identical byte stream to the
    // original byte-loop (little-endian word layout), ~8x fewer rng calls
    // and bulk writes instead of per-byte push.
    let n = nbytes as usize;
    let mut out = vec![0u8; n];
    let mut chunks = out.chunks_exact_mut(8);
    for chunk in &mut chunks {
        chunk.copy_from_slice(&rng.next_u64().to_le_bytes());
    }
    let rem = chunks.into_remainder();
    if !rem.is_empty() {
        let word = rng.next_u64().to_le_bytes();
        rem.copy_from_slice(&word[..rem.len()]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_matches_view_size() {
        let v = FlatView::from_pairs(vec![(0, 5), (10, 3)]).unwrap();
        let r = RankState::with_random_payload(2, v, 42);
        assert_eq!(r.payload.len(), 8);
        assert_eq!(r.bytes(), 8);
    }

    #[test]
    fn payload_deterministic() {
        assert_eq!(
            deterministic_payload(1, 3, 100),
            deterministic_payload(1, 3, 100)
        );
        assert_ne!(
            deterministic_payload(1, 3, 100),
            deterministic_payload(1, 4, 100)
        );
    }

    #[test]
    fn explicit_payload_length_checked() {
        let v = FlatView::from_pairs(vec![(0, 4)]).unwrap();
        assert!(RankState::with_payload(0, v.clone(), vec![0; 3]).is_err());
        assert!(RankState::with_payload(0, v, vec![0; 4]).is_ok());
    }
}
