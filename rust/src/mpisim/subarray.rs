//! Flattening of N-dimensional subarray datatypes
//! (`MPI_Type_create_subarray` semantics, C order).
//!
//! BTIO and S3D-IO construct their file views as subarrays of a global
//! array: each process owns a hyper-rectangle, and the flattened view is
//! one contiguous run per innermost-dimension line.  The run count is the
//! product of the non-innermost local sizes — this is exactly where the
//! paper's Table I request counts come from.

use crate::error::{Error, Result};

use super::FlatView;

/// Flatten a subarray datatype into a [`FlatView`].
///
/// * `global` — global array dimension sizes, C order (last dim contiguous).
/// * `sub` — local hyper-rectangle sizes.
/// * `start` — local hyper-rectangle origin.
/// * `elem_size` — bytes per element.
/// * `file_base` — byte offset of the array within the file.
///
/// Contiguous runs that happen to be exactly adjacent in the file (e.g.
/// when the subarray spans a full innermost dimension) are *not* coalesced
/// here: flattening reproduces what `MPI_Type_create_subarray` +
/// `ADIOI_Flatten` yield; coalescing is the aggregators' job.
pub fn subarray_flatten(
    global: &[usize],
    sub: &[usize],
    start: &[usize],
    elem_size: usize,
    file_base: u64,
) -> Result<FlatView> {
    let ndims = global.len();
    if sub.len() != ndims || start.len() != ndims {
        return Err(Error::Workload(format!(
            "subarray dims mismatch: global {ndims}, sub {}, start {}",
            sub.len(),
            start.len()
        )));
    }
    if ndims == 0 {
        return Ok(FlatView::empty());
    }
    for d in 0..ndims {
        if start[d] + sub[d] > global[d] {
            return Err(Error::Workload(format!(
                "subarray out of bounds in dim {d}: start {} + sub {} > global {}",
                start[d], sub[d], global[d]
            )));
        }
    }
    if sub.iter().any(|&s| s == 0) {
        return Ok(FlatView::empty());
    }

    // Row-major strides in elements.
    let mut stride = vec![1u64; ndims];
    for d in (0..ndims.saturating_sub(1)).rev() {
        stride[d] = stride[d + 1] * global[d + 1] as u64;
    }

    let inner = ndims - 1;
    let run_len = (sub[inner] * elem_size) as u64;
    let n_runs: usize = sub[..inner].iter().product();

    let mut offsets = Vec::with_capacity(n_runs);
    let mut lengths = Vec::with_capacity(n_runs);
    // Iterate the outer dims odometer-style; offsets come out ascending
    // because strides are positive and we count up in row-major order.
    let mut idx = vec![0usize; inner];
    loop {
        let mut elem_off = start[inner] as u64 * stride[inner];
        for d in 0..inner {
            elem_off += (start[d] + idx[d]) as u64 * stride[d];
        }
        offsets.push(file_base + elem_off * elem_size as u64);
        lengths.push(run_len);
        // Advance odometer.
        let mut d = inner;
        loop {
            if d == 0 {
                return Ok(FlatView::from_pairs_unchecked(offsets, lengths));
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < sub[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// Balanced 1-D block decomposition: bounds `[start, end)` of part `i`
/// of `n` points split into `parts` near-equal blocks (the MPI_Cart
/// convention when sizes don't divide evenly).
pub fn balanced_bounds(n: usize, parts: usize, i: usize) -> (usize, usize) {
    debug_assert!(parts > 0 && i < parts);
    (i * n / parts, (i + 1) * n / parts)
}

/// Number of flattened runs of a subarray without materializing it.
pub fn subarray_run_count(sub: &[usize]) -> u64 {
    if sub.is_empty() || sub.contains(&0) {
        return 0;
    }
    sub[..sub.len() - 1].iter().map(|&s| s as u64).product()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_dim_is_single_run() {
        let v = subarray_flatten(&[100], &[10], &[5], 8, 0).unwrap();
        assert_eq!(v.iter().collect::<Vec<_>>(), vec![(40, 80)]);
    }

    #[test]
    fn two_dim_rows() {
        // global 4x6, sub 2x3 at (1,2), elem 1 byte.
        let v = subarray_flatten(&[4, 6], &[2, 3], &[1, 2], 1, 0).unwrap();
        assert_eq!(v.iter().collect::<Vec<_>>(), vec![(8, 3), (14, 3)]);
    }

    #[test]
    fn three_dim_run_count_matches_formula() {
        let v = subarray_flatten(&[8, 8, 8], &[2, 4, 3], &[0, 0, 0], 4, 0).unwrap();
        assert_eq!(v.len() as u64, subarray_run_count(&[2, 4, 3]));
        assert_eq!(v.len(), 8);
        assert_eq!(v.total_bytes(), (2 * 4 * 3 * 4) as u64);
    }

    #[test]
    fn full_inner_dim_stays_unmerged_runs() {
        // sub spans the full innermost dim: physically contiguous rows,
        // but flattening must still emit one run per row (coalescing is
        // the aggregator's job).
        let v = subarray_flatten(&[4, 4], &[2, 4], &[0, 0], 1, 0).unwrap();
        assert_eq!(v.len(), 2);
        let mut w = v.clone();
        w.coalesce();
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn file_base_shifts_offsets() {
        let a = subarray_flatten(&[4, 4], &[1, 2], &[0, 0], 1, 0).unwrap();
        let b = subarray_flatten(&[4, 4], &[1, 2], &[0, 0], 1, 1000).unwrap();
        assert_eq!(b.min_offset().unwrap(), a.min_offset().unwrap() + 1000);
    }

    #[test]
    fn out_of_bounds_rejected() {
        assert!(subarray_flatten(&[4, 4], &[2, 3], &[3, 0], 1, 0).is_err());
    }

    #[test]
    fn dim_mismatch_rejected() {
        assert!(subarray_flatten(&[4, 4], &[2], &[0, 0], 1, 0).is_err());
    }

    #[test]
    fn zero_extent_empty() {
        let v = subarray_flatten(&[4, 4], &[0, 4], &[0, 0], 1, 0).unwrap();
        assert!(v.is_empty());
    }

    #[test]
    fn offsets_ascending_4d() {
        let v = subarray_flatten(&[3, 4, 5, 6], &[2, 2, 2, 3], &[1, 1, 1, 1], 8, 64).unwrap();
        assert!(v.offsets().windows(2).all(|w| w[0] < w[1]));
        assert_eq!(v.len(), 2 * 2 * 2);
    }
}
