//! Network cost model: α–β links with receiver-side congestion and the
//! Isend/Issend pending-queue effect.
//!
//! The paper's observation (§III–§IV-D) is that two-phase I/O's all-to-many
//! exchange congests the `P_G` global aggregators: each aggregator posts
//! `P/P_G` receives per round, and receive processing serializes at the
//! receiver.  TAM reduces the in-degree to `P_L/P_G`.  This module models
//! exactly that effect so paper-scale figures can be regenerated without an
//! Aries interconnect:
//!
//! * each message costs `α(link) + bytes · β(link)` with one parameter
//!   row per [`LinkTier`] — same-socket, same-node, same-switch-group and
//!   global links — so cost attribution follows the aggregation tree's
//!   hierarchy; on a flat topology only the node and global rows apply,
//!   which is the paper's binary intra/inter split;
//! * a receiver serializes the per-message overhead of everything addressed
//!   to it within a phase (the congestion term: `in_degree · α_recv` plus
//!   byte drain at the link bandwidth);
//! * a sender serializes injection of its own messages;
//! * the phase time is the max over participants (BSP-style bound);
//! * with [`SendMode::Isend`], unreceived sends from earlier rounds pile up
//!   in the match queue and add a per-pending-message processing penalty —
//!   the effect the paper fixed in ROMIO by switching to `MPI_Issend` (§V).
//!
//! The defaults approximate a Cray XC40/Aries + KNL system at the order-of-
//! magnitude level (µs-scale latencies, ~10 GB/s inter-node links, ~0.3 µs
//! match-queue processing); EXPERIMENTS.md records the calibration. Shapes,
//! not absolute numbers, are the reproduction target.

pub mod phase;

pub use crate::cluster::LinkTier;
pub use phase::{ExchangeStats, Message, PhaseCost};

/// Asynchronous-send semantics used by the aggregation communication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendMode {
    /// `MPI_Isend`: non-aggregators may race ahead into later rounds while
    /// earlier small sends are still queued; pending messages inflate the
    /// receiver's match-queue processing cost.
    Isend,
    /// `MPI_Issend`: synchronous completion — a round's sends must be
    /// matched before `MPI_Waitall` returns, so no pending-queue buildup.
    Issend,
}

impl std::fmt::Display for SendMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SendMode::Isend => write!(f, "isend"),
            SendMode::Issend => write!(f, "issend"),
        }
    }
}

/// α–β + congestion parameters for the simulated interconnect.
///
/// The four `alpha_*`/`beta_*` pairs form the per-[`LinkTier`] table
/// (`socket` ≤ `intra` ≤ `switch` ≤ `inter` in latency): a message is
/// priced by the innermost hierarchy level containing both endpoints
/// ([`crate::cluster::Topology::tier_of`]).  Flat topologies use only the
/// `intra` (node) and `inter` (global) rows.
#[derive(Clone, Copy, Debug)]
pub struct NetParams {
    /// Per-message latency between switch groups (seconds) — the global
    /// tier.
    pub alpha_inter: f64,
    /// Per-message latency within a node / shared memory (seconds).
    pub alpha_intra: f64,
    /// Per-message latency within a socket / NUMA domain (seconds).
    pub alpha_socket: f64,
    /// Per-message latency between nodes behind one leaf switch (seconds).
    pub alpha_switch: f64,
    /// Global-tier inverse bandwidth (seconds per byte).
    pub beta_inter: f64,
    /// Intra-node inverse bandwidth (seconds per byte).
    pub beta_intra: f64,
    /// Intra-socket inverse bandwidth (seconds per byte).
    pub beta_socket: f64,
    /// Same-leaf-switch inverse bandwidth (seconds per byte).
    pub beta_switch: f64,
    /// Receiver-side per-message processing (matching, unpacking) —
    /// serializes at the receiver; this term carries the congestion effect.
    pub recv_overhead: f64,
    /// Sender-side per-message injection overhead (serializes at sender).
    pub send_overhead: f64,
    /// Extra receiver match-queue processing per *pending* unmatched send
    /// when [`SendMode::Isend`] lets rounds overlap (seconds per pending
    /// message per posted receive).
    pub pending_penalty: f64,
    /// Per-node NIC ingestion, seconds per byte of *inter-node* traffic
    /// arriving at one node.  This is what distinguishes placement
    /// policies: stacking several global aggregators on one node (Cray
    /// round-robin) funnels their combined traffic through one NIC.
    pub nic_ingest: f64,
    /// Send mode for the aggregation phases.
    pub send_mode: SendMode,
}

impl Default for NetParams {
    /// Order-of-magnitude Cray XC40 (Aries, KNL) calibration; see
    /// EXPERIMENTS.md §Calibration.
    fn default() -> Self {
        NetParams {
            alpha_inter: 2.0e-6,
            alpha_intra: 4.0e-7,
            alpha_socket: 2.0e-7,
            alpha_switch: 1.8e-6,
            beta_inter: 1.0 / 8.0e9,
            beta_intra: 1.0 / 20.0e9,
            beta_socket: 1.0 / 30.0e9,
            beta_switch: 1.0 / 9.0e9,
            recv_overhead: 3.0e-7,
            send_overhead: 1.5e-7,
            pending_penalty: 6.0e-10,
            nic_ingest: 1.0 / 10.0e9,
            send_mode: SendMode::Issend,
        }
    }
}

impl NetParams {
    /// Per-message latency of a link tier.
    pub fn tier_alpha(&self, tier: LinkTier) -> f64 {
        match tier {
            LinkTier::Socket => self.alpha_socket,
            LinkTier::Node => self.alpha_intra,
            LinkTier::Switch => self.alpha_switch,
            LinkTier::Global => self.alpha_inter,
        }
    }

    /// Inverse bandwidth of a link tier (seconds per byte).
    pub fn tier_beta(&self, tier: LinkTier) -> f64 {
        match tier {
            LinkTier::Socket => self.beta_socket,
            LinkTier::Node => self.beta_intra,
            LinkTier::Switch => self.beta_switch,
            LinkTier::Global => self.beta_inter,
        }
    }

    /// Point-to-point cost of one message of `bytes` on a link tier
    /// (no congestion).
    pub fn msg_cost_tier(&self, tier: LinkTier, bytes: u64) -> f64 {
        self.tier_alpha(tier) + bytes as f64 * self.tier_beta(tier)
    }

    /// Point-to-point cost under the binary intra/inter split — the
    /// flat-topology view (`intra` = node tier, `inter` = global tier).
    pub fn msg_cost(&self, intra_node: bool, bytes: u64) -> f64 {
        self.msg_cost_tier(if intra_node { LinkTier::Node } else { LinkTier::Global }, bytes)
    }

    /// With this mode, do unmatched sends from previous rounds persist?
    pub fn carries_pending(&self) -> bool {
        matches!(self.send_mode, SendMode::Isend)
    }

    /// Synchronization bound of the double-buffered round pipeline: the
    /// part of round r+1's exchange that can NOT be hidden behind round
    /// r's I/O phase.  Under [`SendMode::Issend`] a send completes only
    /// once its receive is posted, and an aggregator still draining
    /// round r posts round r+1's receives late — so the pipeline eats
    /// at least the receiver's serialized per-message matching,
    /// `in_degree · recv_overhead` (§V of the paper: synchronous sends
    /// order the rounds).  `Isend` buffers eagerly and has no such
    /// bound (it pays through the pending-queue penalty instead).
    pub fn overlap_sync_bound(&self, in_degree: usize) -> f64 {
        match self.send_mode {
            SendMode::Isend => 0.0,
            SendMode::Issend => self.recv_overhead * in_degree as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intra_cheaper_than_inter() {
        let p = NetParams::default();
        assert!(p.msg_cost(true, 4096) < p.msg_cost(false, 4096));
    }

    #[test]
    fn msg_cost_scales_with_bytes() {
        let p = NetParams::default();
        let small = p.msg_cost(false, 1024);
        let big = p.msg_cost(false, 1024 * 1024);
        assert!(big > small * 10.0);
    }

    #[test]
    fn tier_table_orders_latency_and_bandwidth() {
        let p = NetParams::default();
        // Latency grows outward through the hierarchy.
        assert!(p.tier_alpha(LinkTier::Socket) < p.tier_alpha(LinkTier::Node));
        assert!(p.tier_alpha(LinkTier::Node) < p.tier_alpha(LinkTier::Switch));
        assert!(p.tier_alpha(LinkTier::Switch) < p.tier_alpha(LinkTier::Global));
        // Bandwidth shrinks outward (inverse bandwidth grows).
        assert!(p.tier_beta(LinkTier::Socket) < p.tier_beta(LinkTier::Node));
        assert!(p.tier_beta(LinkTier::Node) < p.tier_beta(LinkTier::Switch));
        assert!(p.tier_beta(LinkTier::Switch) < p.tier_beta(LinkTier::Global));
        for bytes in [0u64, 1 << 20] {
            assert!(
                p.msg_cost_tier(LinkTier::Socket, bytes) < p.msg_cost_tier(LinkTier::Node, bytes)
            );
            assert!(
                p.msg_cost_tier(LinkTier::Switch, bytes)
                    < p.msg_cost_tier(LinkTier::Global, bytes)
            );
        }
    }

    #[test]
    fn binary_split_is_the_node_and_global_rows() {
        let p = NetParams::default();
        assert_eq!(p.msg_cost(true, 4096), p.msg_cost_tier(LinkTier::Node, 4096));
        assert_eq!(p.msg_cost(false, 4096), p.msg_cost_tier(LinkTier::Global, 4096));
    }

    #[test]
    fn issend_default_has_no_pending() {
        let p = NetParams::default();
        assert!(!p.carries_pending());
        let mut p2 = p;
        p2.send_mode = SendMode::Isend;
        assert!(p2.carries_pending());
    }

    #[test]
    fn overlap_sync_bound_follows_send_mode() {
        let p = NetParams::default(); // Issend
        assert_eq!(p.overlap_sync_bound(0), 0.0);
        assert_eq!(p.overlap_sync_bound(64), p.recv_overhead * 64.0);
        let mut p2 = p;
        p2.send_mode = SendMode::Isend;
        // Eager sends never block on the next round's receives.
        assert_eq!(p2.overlap_sync_bound(64), 0.0);
    }
}
