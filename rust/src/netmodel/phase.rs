//! Phase-level cost evaluation: BSP-style max over per-rank timelines.
//!
//! A *phase* is one bulk message exchange (e.g. one round of request
//! redistribution).  The simulator executes the data movement for real and
//! hands this module the message list `(src, dst, bytes)`; the model returns
//! the simulated phase time and congestion statistics.

use std::collections::HashMap;

use crate::cluster::Topology;

use super::NetParams;

/// One simulated message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Message {
    /// Sending rank.
    pub src: usize,
    /// Receiving rank.
    pub dst: usize,
    /// Payload size in bytes.
    pub bytes: u64,
}

impl Message {
    /// Convenience constructor.
    pub fn new(src: usize, dst: usize, bytes: u64) -> Self {
        Self { src, dst, bytes }
    }
}

/// Result of costing one exchange phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseCost {
    /// Simulated wall time of the phase (seconds).
    pub time: f64,
    /// Time of the most loaded receiver (the congestion bound).
    pub recv_bound: f64,
    /// Time of the most loaded sender (the injection bound).
    pub send_bound: f64,
    /// Time of the most loaded node NIC (inter-node ingestion bound).
    pub nic_bound: f64,
    /// Maximum receiver in-degree (messages addressed to one rank).
    pub max_in_degree: usize,
    /// Total messages in the phase.
    pub n_messages: usize,
    /// Total bytes moved in the phase.
    pub total_bytes: u64,
}

/// Aggregate statistics over a multi-phase exchange (e.g. all rounds).
#[derive(Clone, Debug, Default)]
pub struct ExchangeStats {
    /// Total simulated time.
    pub time: f64,
    /// Total messages.
    pub n_messages: usize,
    /// Total bytes.
    pub total_bytes: u64,
    /// Max in-degree observed in any phase.
    pub max_in_degree: usize,
}

impl ExchangeStats {
    /// Fold one phase into the totals.
    pub fn absorb(&mut self, c: &PhaseCost) {
        self.time += c.time;
        self.n_messages += c.n_messages;
        self.total_bytes += c.total_bytes;
        self.max_in_degree = self.max_in_degree.max(c.max_in_degree);
    }
}

/// Cost one exchange phase.
///
/// `pending_per_receiver` carries the unmatched-send count from previous
/// rounds for the [`super::SendMode::Isend`] pending-queue model, indexed
/// densely by receiver rank.  Ranks beyond the end of the slice count as
/// zero pending, so an empty slice (or [`cost_phase`]) gives Issend
/// semantics.
///
/// Accumulators are dense `Vec`s indexed by rank / node — ranks are
/// `0..topo.nprocs()` by construction, and this function runs once per
/// round per phase, where `HashMap` churn dominated at high rank counts
/// (§Perf tentpole).
///
/// # Panics
///
/// Every `Message` must carry `src`/`dst` ranks inside `0..topo.nprocs()`
/// (the dense-rank invariant, DESIGN.md §Hot path); an out-of-range rank
/// is a caller bug and panics on the slice index.
pub fn cost_phase_with_pending(
    params: &NetParams,
    topo: &Topology,
    msgs: &[Message],
    pending_per_receiver: &[u64],
) -> PhaseCost {
    let mut scratch = PhaseScratch::default();
    cost_phase_into(params, topo, msgs, pending_per_receiver, &mut scratch)
}

/// Reusable dense accumulators for [`cost_phase_into`] — the per-round
/// scratch of the exchange loops.  Capacity survives across rounds
/// (scratch-arena treatment of the cost path: one phase evaluation per
/// round otherwise re-allocates four rank/node-sized `Vec`s).
#[derive(Debug, Default)]
pub struct PhaseScratch {
    recv_time: Vec<f64>,
    send_time: Vec<f64>,
    nic_time: Vec<f64>,
    in_degree: Vec<usize>,
}

/// [`cost_phase_with_pending`] into caller-owned scratch accumulators
/// (cleared and re-zeroed each call, capacity reused).
pub fn cost_phase_into(
    params: &NetParams,
    topo: &Topology,
    msgs: &[Message],
    pending_per_receiver: &[u64],
    scratch: &mut PhaseScratch,
) -> PhaseCost {
    let nprocs = topo.nprocs();
    scratch.recv_time.clear();
    scratch.recv_time.resize(nprocs, 0.0);
    scratch.send_time.clear();
    scratch.send_time.resize(nprocs, 0.0);
    scratch.nic_time.clear();
    scratch.nic_time.resize(topo.nodes, 0.0);
    scratch.in_degree.clear();
    scratch.in_degree.resize(nprocs, 0);
    let recv_time = &mut scratch.recv_time;
    let send_time = &mut scratch.send_time;
    let nic_time = &mut scratch.nic_time;
    let in_degree = &mut scratch.in_degree;
    let mut total_bytes = 0u64;

    for m in msgs {
        debug_assert!(m.src < nprocs && m.dst < nprocs, "rank outside 0..nprocs");
        let intra = topo.same_node(m.src, m.dst);
        let wire = params.msg_cost(intra, m.bytes);
        // Receiver serializes matching + draining of everything addressed
        // to it: this is where all-to-many congestion shows up.
        let pending = pending_per_receiver.get(m.dst).copied().unwrap_or(0) as f64;
        recv_time[m.dst] += params.recv_overhead + wire + pending * params.pending_penalty;
        // Sender serializes injection but overlaps transfer completion.
        send_time[m.src] +=
            params.send_overhead + if intra { 0.0 } else { m.bytes as f64 * params.beta_inter };
        // Inter-node traffic shares the destination node's NIC: stacking
        // aggregators on a node concentrates this bound.
        if !intra {
            nic_time[topo.node_of(m.dst)] += m.bytes as f64 * params.nic_ingest;
        }
        in_degree[m.dst] += 1;
        total_bytes += m.bytes;
    }

    let recv_bound = recv_time.iter().copied().fold(0.0, f64::max);
    let send_bound = send_time.iter().copied().fold(0.0, f64::max);
    let nic_bound = nic_time.iter().copied().fold(0.0, f64::max);
    PhaseCost {
        time: recv_bound.max(send_bound).max(nic_bound),
        recv_bound,
        send_bound,
        nic_bound,
        max_in_degree: in_degree.iter().copied().max().unwrap_or(0),
        n_messages: msgs.len(),
        total_bytes,
    }
}

/// Cost one exchange phase with no pending-queue carry-over.
pub fn cost_phase(params: &NetParams, topo: &Topology, msgs: &[Message]) -> PhaseCost {
    cost_phase_with_pending(params, topo, msgs, &[])
}

/// Tracks unmatched sends across rounds for the Isend model.
///
/// Under `MPI_Isend`, non-aggregators post sends and immediately continue
/// into the next round; the receiver's match queue grows with every round
/// still in flight.  Under `MPI_Issend` the queue drains each round.
/// Counts are dense per rank (grown lazily to `topo.nprocs()`).
#[derive(Debug, Default)]
pub struct PendingQueue {
    pending: Vec<u64>,
    /// Reused phase accumulators (one allocation for the whole exchange).
    scratch: PhaseScratch,
}

impl PendingQueue {
    /// New empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cost a round and update the queue according to the send mode.
    pub fn cost_round(
        &mut self,
        params: &NetParams,
        topo: &Topology,
        msgs: &[Message],
    ) -> PhaseCost {
        if self.pending.len() < topo.nprocs() {
            self.pending.resize(topo.nprocs(), 0);
        }
        let cost = cost_phase_into(params, topo, msgs, &self.pending, &mut self.scratch);
        if params.carries_pending() {
            // A fraction of this round's small sends stay unmatched when the
            // senders race ahead; accumulate them on the receivers.
            for m in msgs {
                self.pending[m.dst] += 1;
            }
        } else {
            self.pending.fill(0);
        }
        cost
    }

    /// Current pending count for a rank (tests/diagnostics).
    pub fn pending_for(&self, rank: usize) -> u64 {
        self.pending.get(rank).copied().unwrap_or(0)
    }
}

/// Per-receiver in-degree histogram for an exchange — the data behind the
/// paper's Figure 2 congestion illustration.
pub fn in_degree_by_rank(msgs: &[Message]) -> HashMap<usize, usize> {
    let mut h = HashMap::new();
    for m in msgs {
        *h.entry(m.dst).or_default() += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new(2, 4)
    }

    #[test]
    fn empty_phase_costs_nothing() {
        let c = cost_phase(&NetParams::default(), &topo(), &[]);
        assert_eq!(c.time, 0.0);
        assert_eq!(c.n_messages, 0);
    }

    #[test]
    fn congestion_grows_with_in_degree() {
        let p = NetParams::default();
        let t = Topology::new(4, 4);
        // 15 senders -> 1 receiver vs 15 senders -> 15 receivers.
        let fan_in: Vec<Message> =
            (1..16).map(|s| Message::new(s, 0, 1024)).collect();
        let spread: Vec<Message> =
            (1..16).map(|s| Message::new(s, (s + 1) % 16, 1024)).collect();
        let c1 = cost_phase(&p, &t, &fan_in);
        let c2 = cost_phase(&p, &t, &spread);
        assert!(c1.time > c2.time * 4.0, "fan-in must congest: {} vs {}", c1.time, c2.time);
        assert_eq!(c1.max_in_degree, 15);
    }

    #[test]
    fn intra_node_phase_cheaper() {
        let p = NetParams::default();
        let t = Topology::new(2, 4);
        let intra: Vec<Message> = (1..4).map(|s| Message::new(s, 0, 1 << 20)).collect();
        let inter: Vec<Message> = (1..4).map(|s| Message::new(4 + s, 0, 1 << 20)).collect();
        assert!(cost_phase(&p, &t, &intra).time < cost_phase(&p, &t, &inter).time);
    }

    #[test]
    fn isend_pending_queue_inflates_later_rounds() {
        let mut p = NetParams::default();
        p.send_mode = super::super::SendMode::Isend;
        let t = Topology::new(4, 4);
        let msgs: Vec<Message> = (1..16).map(|s| Message::new(s, 0, 64)).collect();
        let mut q = PendingQueue::new();
        let first = q.cost_round(&p, &t, &msgs).time;
        for _ in 0..200 {
            q.cost_round(&p, &t, &msgs);
        }
        let late = q.cost_round(&p, &t, &msgs).time;
        assert!(late > first, "pending queue must grow round cost");
        assert!(q.pending_for(0) > 0);
    }

    #[test]
    fn issend_rounds_stay_flat() {
        let p = NetParams::default(); // Issend default
        let t = Topology::new(4, 4);
        let msgs: Vec<Message> = (1..16).map(|s| Message::new(s, 0, 64)).collect();
        let mut q = PendingQueue::new();
        let first = q.cost_round(&p, &t, &msgs).time;
        for _ in 0..200 {
            q.cost_round(&p, &t, &msgs);
        }
        let late = q.cost_round(&p, &t, &msgs).time;
        assert!((late - first).abs() < 1e-12);
        assert_eq!(q.pending_for(0), 0);
    }

    #[test]
    fn nic_bound_punishes_stacked_receivers() {
        // Same message set, receivers on one node vs spread across nodes:
        // the single-node case saturates that node's NIC.
        let p = NetParams::default();
        let t = Topology::new(4, 4);
        let stacked: Vec<Message> =
            (4..16).map(|s| Message::new(s, s % 4, 1 << 20)).collect();
        let spread: Vec<Message> =
            (0..12).map(|s| Message::new(s, (s + 4) % 16, 1 << 20)).collect();
        let c1 = cost_phase(&p, &t, &stacked);
        let c2 = cost_phase(&p, &t, &spread);
        assert!(c1.nic_bound > c2.nic_bound * 2.0, "{} vs {}", c1.nic_bound, c2.nic_bound);
    }

    #[test]
    fn intra_messages_skip_the_nic() {
        let p = NetParams::default();
        let t = Topology::new(2, 4);
        let intra = vec![Message::new(1, 0, 1 << 20)];
        assert_eq!(cost_phase(&p, &t, &intra).nic_bound, 0.0);
    }

    #[test]
    fn reused_scratch_matches_fresh_evaluation() {
        // The same PhaseScratch across phases of different shapes (and
        // different topology sizes) must not leak accumulator state.
        let p = NetParams::default();
        let mut scratch = PhaseScratch::default();
        let big = Topology::new(4, 8);
        let small = Topology::new(2, 2);
        let phases = [
            (big, (1..30).map(|s| Message::new(s, s % 7, 512)).collect::<Vec<_>>()),
            (small, vec![Message::new(0, 3, 64), Message::new(1, 3, 64)]),
            (big, vec![Message::new(31, 0, 1 << 20)]),
        ];
        for (topo, msgs) in &phases {
            let fresh = cost_phase_with_pending(&p, topo, msgs, &[]);
            let reused = cost_phase_into(&p, topo, msgs, &[], &mut scratch);
            assert_eq!(reused.time, fresh.time);
            assert_eq!(reused.recv_bound, fresh.recv_bound);
            assert_eq!(reused.send_bound, fresh.send_bound);
            assert_eq!(reused.nic_bound, fresh.nic_bound);
            assert_eq!(reused.max_in_degree, fresh.max_in_degree);
            assert_eq!(reused.total_bytes, fresh.total_bytes);
        }
    }

    #[test]
    fn stats_absorb_accumulates() {
        let p = NetParams::default();
        let t = topo();
        let msgs = vec![Message::new(1, 0, 10), Message::new(2, 0, 20)];
        let c = cost_phase(&p, &t, &msgs);
        let mut s = ExchangeStats::default();
        s.absorb(&c);
        s.absorb(&c);
        assert_eq!(s.n_messages, 4);
        assert_eq!(s.total_bytes, 60);
        assert!(s.time > 0.0);
    }

    #[test]
    fn in_degree_histogram() {
        let msgs = vec![
            Message::new(1, 0, 1),
            Message::new(2, 0, 1),
            Message::new(3, 5, 1),
        ];
        let h = in_degree_by_rank(&msgs);
        assert_eq!(h[&0], 2);
        assert_eq!(h[&5], 1);
    }
}
