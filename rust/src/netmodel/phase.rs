//! Phase-level cost evaluation: BSP-style max over per-rank timelines.
//!
//! A *phase* is one bulk message exchange (e.g. one round of request
//! redistribution).  The simulator executes the data movement for real and
//! hands this module the message list `(src, dst, bytes)`; the model returns
//! the simulated phase time and congestion statistics.

use std::collections::HashMap;

use crate::cluster::Topology;

use super::NetParams;

/// One simulated message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Message {
    /// Sending rank.
    pub src: usize,
    /// Receiving rank.
    pub dst: usize,
    /// Payload size in bytes.
    pub bytes: u64,
}

impl Message {
    /// Convenience constructor.
    pub fn new(src: usize, dst: usize, bytes: u64) -> Self {
        Self { src, dst, bytes }
    }
}

/// Result of costing one exchange phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseCost {
    /// Simulated wall time of the phase (seconds).
    pub time: f64,
    /// Time of the most loaded receiver (the congestion bound).
    pub recv_bound: f64,
    /// Time of the most loaded sender (the injection bound).
    pub send_bound: f64,
    /// Time of the most loaded node NIC (inter-node ingestion bound).
    pub nic_bound: f64,
    /// Maximum receiver in-degree (messages addressed to one rank).
    pub max_in_degree: usize,
    /// Total messages in the phase.
    pub n_messages: usize,
    /// Total bytes moved in the phase.
    pub total_bytes: u64,
}

/// Aggregate statistics over a multi-phase exchange (e.g. all rounds).
#[derive(Clone, Debug, Default)]
pub struct ExchangeStats {
    /// Total simulated time.
    pub time: f64,
    /// Total messages.
    pub n_messages: usize,
    /// Total bytes.
    pub total_bytes: u64,
    /// Max in-degree observed in any phase.
    pub max_in_degree: usize,
}

impl ExchangeStats {
    /// Fold one phase into the totals.
    pub fn absorb(&mut self, c: &PhaseCost) {
        self.time += c.time;
        self.n_messages += c.n_messages;
        self.total_bytes += c.total_bytes;
        self.max_in_degree = self.max_in_degree.max(c.max_in_degree);
    }
}

/// Cost one exchange phase.
///
/// `pending_per_receiver` carries the unmatched-send count from previous
/// rounds for the [`super::SendMode::Isend`] pending-queue model, indexed
/// densely by receiver rank.  Ranks beyond the end of the slice count as
/// zero pending, so an empty slice (or [`cost_phase`]) gives Issend
/// semantics.
///
/// Accumulators are dense `Vec`s indexed by rank / node — ranks are
/// `0..topo.nprocs()` by construction, and this function runs once per
/// round per phase, where `HashMap` churn dominated at high rank counts
/// (§Perf tentpole).
///
/// # Panics
///
/// Every `Message` must carry `src`/`dst` ranks inside `0..topo.nprocs()`
/// (the dense-rank invariant, DESIGN.md §Hot path); an out-of-range rank
/// is a caller bug and panics on the slice index.
pub fn cost_phase_with_pending(
    params: &NetParams,
    topo: &Topology,
    msgs: &[Message],
    pending_per_receiver: &[u64],
) -> PhaseCost {
    let mut scratch = PhaseScratch::default();
    cost_phase_into(params, topo, msgs, pending_per_receiver, &mut scratch)
}

/// Reusable dense accumulators for [`cost_phase_into`] — the per-round
/// scratch of the exchange loops.  Capacity survives across rounds
/// (scratch-arena treatment of the cost path: one phase evaluation per
/// round otherwise re-allocates four rank/node-sized `Vec`s per shard).
///
/// Accumulation is *sharded* for large phases (ROADMAP: parallelize
/// `cost_phase` at 128k+ messages/round): the message list is split into
/// contiguous shards whose count depends **only on the message count**
/// (never on the host's thread count), each shard accumulates into its
/// own dense vectors on a scoped thread, and the per-rank/per-node
/// partials are reduced in shard-index order — so results are
/// deterministic across machines and schedules.  Small phases take a
/// single-shard path that is the plain serial loop.
#[derive(Debug, Default)]
pub struct PhaseScratch {
    shards: Vec<PhaseShard>,
    /// Shards used by the most recent [`cost_phase_into`] call (older,
    /// larger phases may have left extra shards allocated behind it).
    active: usize,
}

impl PhaseScratch {
    /// Fold the most recently costed phase's per-receiver in-degree into
    /// `pending` — the sharded twin of the serial `pending[m.dst] += 1`
    /// walk (ROADMAP item): the per-shard `in_degree` accumulators were
    /// already filled (in parallel, for large phases) during costing, so
    /// the post-cost update is a dense vector add instead of a second
    /// serial pass over the message list.  Integer counts are exact, so
    /// no tolerance is involved (unlike the float reductions).
    pub fn add_in_degree_to(&self, pending: &mut [u64]) {
        for sh in &self.shards[..self.active] {
            for (p, &d) in pending.iter_mut().zip(&sh.in_degree) {
                *p += d as u64;
            }
        }
    }
}

/// One shard's dense accumulators (rank/node indexed).
#[derive(Debug, Default)]
struct PhaseShard {
    recv_time: Vec<f64>,
    send_time: Vec<f64>,
    nic_time: Vec<f64>,
    in_degree: Vec<usize>,
    total_bytes: u64,
}

impl PhaseShard {
    /// Re-zero for a new phase, keeping allocated capacity.
    fn reset(&mut self, nprocs: usize, nodes: usize) {
        self.recv_time.clear();
        self.recv_time.resize(nprocs, 0.0);
        self.send_time.clear();
        self.send_time.resize(nprocs, 0.0);
        self.nic_time.clear();
        self.nic_time.resize(nodes, 0.0);
        self.in_degree.clear();
        self.in_degree.resize(nprocs, 0);
        self.total_bytes = 0;
    }

    /// Fold one contiguous message slice into the accumulators.
    fn accumulate(
        &mut self,
        params: &NetParams,
        topo: &Topology,
        msgs: &[Message],
        pending_per_receiver: &[u64],
    ) {
        let nprocs = topo.nprocs();
        for m in msgs {
            debug_assert!(m.src < nprocs && m.dst < nprocs, "rank outside 0..nprocs");
            // Price the message at its link tier — the innermost hierarchy
            // level containing both endpoints (socket < node < switch <
            // global); flat topologies see only the node/global rows, the
            // old binary intra/inter split.
            let tier = topo.tier_of(m.src, m.dst);
            let local = tier.is_local();
            let wire = params.msg_cost_tier(tier, m.bytes);
            // Receiver serializes matching + draining of everything
            // addressed to it: this is where all-to-many congestion
            // shows up.
            let pending = pending_per_receiver.get(m.dst).copied().unwrap_or(0) as f64;
            self.recv_time[m.dst] +=
                params.recv_overhead + wire + pending * params.pending_penalty;
            // Sender serializes injection but overlaps transfer completion.
            self.send_time[m.src] += params.send_overhead
                + if local { 0.0 } else { m.bytes as f64 * params.tier_beta(tier) };
            // Off-node traffic shares the destination node's NIC
            // regardless of tier: stacking aggregators on a node
            // concentrates this bound.
            if !local {
                self.nic_time[topo.node_of(m.dst)] += m.bytes as f64 * params.nic_ingest;
            }
            self.in_degree[m.dst] += 1;
            self.total_bytes += m.bytes;
        }
    }
}

/// Messages per shard; below two shards' worth the serial path wins.
const SHARD_TARGET_MSGS: usize = 16_384;
/// Cap on the thread fan-out of one phase evaluation.
const MAX_SHARDS: usize = 16;

/// Shard count for a phase — a pure function of the message count so the
/// floating-point reduction order (and hence the simulated time) is
/// machine-independent.
fn shard_count(n_msgs: usize) -> usize {
    if n_msgs < 2 * SHARD_TARGET_MSGS {
        1
    } else {
        (n_msgs / SHARD_TARGET_MSGS).min(MAX_SHARDS)
    }
}

/// [`cost_phase_with_pending`] into caller-owned scratch accumulators
/// (cleared and re-zeroed each call, capacity reused; sharded across
/// scoped threads for large phases — see [`PhaseScratch`]).
pub fn cost_phase_into(
    params: &NetParams,
    topo: &Topology,
    msgs: &[Message],
    pending_per_receiver: &[u64],
    scratch: &mut PhaseScratch,
) -> PhaseCost {
    let nprocs = topo.nprocs();
    let n_shards = shard_count(msgs.len());
    if scratch.shards.len() < n_shards {
        scratch.shards.resize_with(n_shards, PhaseShard::default);
    }
    scratch.active = n_shards;
    let shards = &mut scratch.shards[..n_shards];
    for sh in shards.iter_mut() {
        sh.reset(nprocs, topo.nodes);
    }
    if n_shards == 1 {
        shards[0].accumulate(params, topo, msgs, pending_per_receiver);
    } else {
        let chunk_len = msgs.len().div_ceil(n_shards);
        crate::util::parallel::par_chunks_mut(&mut *shards, 1, |i, sh| {
            let lo = (i * chunk_len).min(msgs.len());
            let hi = ((i + 1) * chunk_len).min(msgs.len());
            sh[0].accumulate(params, topo, &msgs[lo..hi], pending_per_receiver);
        });
    }

    // Reduce in shard-index order (deterministic association).
    let mut recv_bound = 0.0f64;
    let mut send_bound = 0.0f64;
    let mut max_in_degree = 0usize;
    for r in 0..nprocs {
        let mut rt = 0.0f64;
        let mut st = 0.0f64;
        let mut deg = 0usize;
        for sh in shards.iter() {
            rt += sh.recv_time[r];
            st += sh.send_time[r];
            deg += sh.in_degree[r];
        }
        recv_bound = recv_bound.max(rt);
        send_bound = send_bound.max(st);
        max_in_degree = max_in_degree.max(deg);
    }
    let mut nic_bound = 0.0f64;
    for nd in 0..topo.nodes {
        let mut nt = 0.0f64;
        for sh in shards.iter() {
            nt += sh.nic_time[nd];
        }
        nic_bound = nic_bound.max(nt);
    }
    let total_bytes = shards.iter().map(|sh| sh.total_bytes).sum();
    PhaseCost {
        time: recv_bound.max(send_bound).max(nic_bound),
        recv_bound,
        send_bound,
        nic_bound,
        max_in_degree,
        n_messages: msgs.len(),
        total_bytes,
    }
}

/// The pre-sharding serial accumulation, kept verbatim as the golden
/// oracle for the sharded rewrite.  Floating-point sums may differ from
/// the sharded path by association only (the randomized equivalence test
/// compares with a relative tolerance; integer fields are exact).
#[cfg(test)]
pub(crate) fn cost_phase_serial(
    params: &NetParams,
    topo: &Topology,
    msgs: &[Message],
    pending_per_receiver: &[u64],
) -> PhaseCost {
    let nprocs = topo.nprocs();
    let mut recv_time = vec![0.0f64; nprocs];
    let mut send_time = vec![0.0f64; nprocs];
    let mut nic_time = vec![0.0f64; topo.nodes];
    let mut in_degree = vec![0usize; nprocs];
    let mut total_bytes = 0u64;
    for m in msgs {
        let tier = topo.tier_of(m.src, m.dst);
        let local = tier.is_local();
        let wire = params.msg_cost_tier(tier, m.bytes);
        let pending = pending_per_receiver.get(m.dst).copied().unwrap_or(0) as f64;
        recv_time[m.dst] += params.recv_overhead + wire + pending * params.pending_penalty;
        send_time[m.src] += params.send_overhead
            + if local { 0.0 } else { m.bytes as f64 * params.tier_beta(tier) };
        if !local {
            nic_time[topo.node_of(m.dst)] += m.bytes as f64 * params.nic_ingest;
        }
        in_degree[m.dst] += 1;
        total_bytes += m.bytes;
    }
    let recv_bound = recv_time.iter().copied().fold(0.0, f64::max);
    let send_bound = send_time.iter().copied().fold(0.0, f64::max);
    let nic_bound = nic_time.iter().copied().fold(0.0, f64::max);
    PhaseCost {
        time: recv_bound.max(send_bound).max(nic_bound),
        recv_bound,
        send_bound,
        nic_bound,
        max_in_degree: in_degree.iter().copied().max().unwrap_or(0),
        n_messages: msgs.len(),
        total_bytes,
    }
}

/// Cost one exchange phase with no pending-queue carry-over.
pub fn cost_phase(params: &NetParams, topo: &Topology, msgs: &[Message]) -> PhaseCost {
    cost_phase_with_pending(params, topo, msgs, &[])
}

/// Tracks unmatched sends across rounds for the Isend model.
///
/// Under `MPI_Isend`, non-aggregators post sends and immediately continue
/// into the next round; the receiver's match queue grows with every round
/// still in flight.  Under `MPI_Issend` the queue drains each round.
/// Counts are dense per rank (grown lazily to `topo.nprocs()`).
#[derive(Debug, Default)]
pub struct PendingQueue {
    pending: Vec<u64>,
    /// Reused phase accumulators (one allocation for the whole exchange).
    scratch: PhaseScratch,
}

impl PendingQueue {
    /// New empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Re-zero the pending counts, keeping every allocation (the queue
    /// lives in the persistent `ExchangeArena` and must start each
    /// exchange empty — a collective's unmatched sends do not leak into
    /// the next collective of a sweep).
    pub fn reset(&mut self) {
        self.pending.fill(0);
    }

    /// Cost a round and update the queue according to the send mode.
    ///
    /// The pending update reuses the per-shard `in_degree` accumulators
    /// the costing pass just filled ([`PhaseScratch::add_in_degree_to`])
    /// instead of a second serial walk over the message list — the
    /// `#[cfg(test)]` [`pending_update_serial`] walk is the oracle.
    pub fn cost_round(
        &mut self,
        params: &NetParams,
        topo: &Topology,
        msgs: &[Message],
    ) -> PhaseCost {
        if self.pending.len() < topo.nprocs() {
            self.pending.resize(topo.nprocs(), 0);
        }
        let cost = cost_phase_into(params, topo, msgs, &self.pending, &mut self.scratch);
        if params.carries_pending() {
            // A fraction of this round's small sends stay unmatched when the
            // senders race ahead; accumulate them on the receivers.
            self.scratch.add_in_degree_to(&mut self.pending);
        } else {
            self.pending.fill(0);
        }
        cost
    }

    /// Current pending count for a rank (tests/diagnostics).
    pub fn pending_for(&self, rank: usize) -> u64 {
        self.pending.get(rank).copied().unwrap_or(0)
    }
}

/// Critical-path ledger of the double-buffered round pipeline
/// (`--overlap on|auto`): one row per executed round, folded into the
/// `overlap_saved` breakdown credit at end of exchange.
///
/// Per steady round the pipeline hides round r's I/O phase behind round
/// r+1's exchange (staging + merge + the costed communication), so the
/// hidden time is `min(io_r, exchange_{r+1} − sync_{r+1})`: `io_r` is
/// round r's share of the exchange's I/O phase (apportioned by the
/// bytes its storage call moved — the I/O model prices the phase as a
/// whole, per OST, not per round), and `sync_{r+1}` is the send-mode
/// synchronization bound
/// ([`crate::netmodel::NetParams::overlap_sync_bound`]) that keeps
/// Issend rounds partially ordered.  The last round's I/O has no next
/// exchange to hide behind and is never credited.  All three columns
/// keep their capacity in the persistent `ExchangeArena`.
#[derive(Debug, Default)]
pub struct OverlapAccount {
    /// Per-round exchange time (communication + merge sort + datatype).
    exchange: Vec<f64>,
    /// Per-round synchronization bound (0 under Isend).
    sync: Vec<f64>,
    /// Per-round I/O weight (bytes the round's storage call moved).
    weight: Vec<f64>,
}

impl OverlapAccount {
    /// Clear the rows for a new exchange, keeping capacity.
    pub fn reset(&mut self) {
        self.exchange.clear();
        self.sync.clear();
        self.weight.clear();
    }

    /// Record one executed round.
    pub fn push_round(&mut self, exchange: f64, sync: f64, weight: f64) {
        self.exchange.push(exchange);
        self.sync.push(sync);
        self.weight.push(weight);
    }

    /// Rounds recorded since the last [`Self::reset`].
    pub fn rounds(&self) -> usize {
        self.exchange.len()
    }

    /// The critical-path credit for an exchange whose I/O phase summed
    /// to `io_phase` seconds: Σ over steady rounds of
    /// `min(io_r, max(0, exchange_{r+1} − sync_{r+1}))`.  Bounded above
    /// by `io_phase` (each round's I/O share is credited at most once),
    /// and 0 for serial or single-round exchanges.
    pub fn finish(&self, io_phase: f64) -> f64 {
        let total_w: f64 = self.weight.iter().sum();
        if self.exchange.len() < 2 || total_w <= 0.0 || io_phase <= 0.0 {
            return 0.0;
        }
        let mut saved = 0.0;
        for r in 0..self.exchange.len() - 1 {
            let io_r = io_phase * self.weight[r] / total_w;
            let hideable = (self.exchange[r + 1] - self.sync[r + 1]).max(0.0);
            saved += io_r.min(hideable);
        }
        saved
    }
}

/// The pre-sharding pending update, kept verbatim as the golden oracle
/// for [`PhaseScratch::add_in_degree_to`].
#[cfg(test)]
pub(crate) fn pending_update_serial(msgs: &[Message], pending: &mut [u64]) {
    for m in msgs {
        pending[m.dst] += 1;
    }
}

/// Per-receiver in-degree histogram for an exchange — the data behind the
/// paper's Figure 2 congestion illustration.
pub fn in_degree_by_rank(msgs: &[Message]) -> HashMap<usize, usize> {
    let mut h = HashMap::new();
    for m in msgs {
        *h.entry(m.dst).or_default() += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::new(2, 4)
    }

    #[test]
    fn empty_phase_costs_nothing() {
        let c = cost_phase(&NetParams::default(), &topo(), &[]);
        assert_eq!(c.time, 0.0);
        assert_eq!(c.n_messages, 0);
    }

    #[test]
    fn congestion_grows_with_in_degree() {
        let p = NetParams::default();
        let t = Topology::new(4, 4);
        // 15 senders -> 1 receiver vs 15 senders -> 15 receivers.
        let fan_in: Vec<Message> =
            (1..16).map(|s| Message::new(s, 0, 1024)).collect();
        let spread: Vec<Message> =
            (1..16).map(|s| Message::new(s, (s + 1) % 16, 1024)).collect();
        let c1 = cost_phase(&p, &t, &fan_in);
        let c2 = cost_phase(&p, &t, &spread);
        assert!(c1.time > c2.time * 4.0, "fan-in must congest: {} vs {}", c1.time, c2.time);
        assert_eq!(c1.max_in_degree, 15);
    }

    #[test]
    fn intra_node_phase_cheaper() {
        let p = NetParams::default();
        let t = Topology::new(2, 4);
        let intra: Vec<Message> = (1..4).map(|s| Message::new(s, 0, 1 << 20)).collect();
        let inter: Vec<Message> = (1..4).map(|s| Message::new(4 + s, 0, 1 << 20)).collect();
        assert!(cost_phase(&p, &t, &intra).time < cost_phase(&p, &t, &inter).time);
    }

    #[test]
    fn isend_pending_queue_inflates_later_rounds() {
        let mut p = NetParams::default();
        p.send_mode = super::super::SendMode::Isend;
        let t = Topology::new(4, 4);
        let msgs: Vec<Message> = (1..16).map(|s| Message::new(s, 0, 64)).collect();
        let mut q = PendingQueue::new();
        let first = q.cost_round(&p, &t, &msgs).time;
        for _ in 0..200 {
            q.cost_round(&p, &t, &msgs);
        }
        let late = q.cost_round(&p, &t, &msgs).time;
        assert!(late > first, "pending queue must grow round cost");
        assert!(q.pending_for(0) > 0);
    }

    #[test]
    fn issend_rounds_stay_flat() {
        let p = NetParams::default(); // Issend default
        let t = Topology::new(4, 4);
        let msgs: Vec<Message> = (1..16).map(|s| Message::new(s, 0, 64)).collect();
        let mut q = PendingQueue::new();
        let first = q.cost_round(&p, &t, &msgs).time;
        for _ in 0..200 {
            q.cost_round(&p, &t, &msgs);
        }
        let late = q.cost_round(&p, &t, &msgs).time;
        assert!((late - first).abs() < 1e-12);
        assert_eq!(q.pending_for(0), 0);
    }

    #[test]
    fn nic_bound_punishes_stacked_receivers() {
        // Same message set, receivers on one node vs spread across nodes:
        // the single-node case saturates that node's NIC.
        let p = NetParams::default();
        let t = Topology::new(4, 4);
        let stacked: Vec<Message> =
            (4..16).map(|s| Message::new(s, s % 4, 1 << 20)).collect();
        let spread: Vec<Message> =
            (0..12).map(|s| Message::new(s, (s + 4) % 16, 1 << 20)).collect();
        let c1 = cost_phase(&p, &t, &stacked);
        let c2 = cost_phase(&p, &t, &spread);
        assert!(c1.nic_bound > c2.nic_bound * 2.0, "{} vs {}", c1.nic_bound, c2.nic_bound);
    }

    #[test]
    fn intra_messages_skip_the_nic() {
        let p = NetParams::default();
        let t = Topology::new(2, 4);
        let intra = vec![Message::new(1, 0, 1 << 20)];
        assert_eq!(cost_phase(&p, &t, &intra).nic_bound, 0.0);
    }

    #[test]
    fn reused_scratch_matches_fresh_evaluation() {
        // The same PhaseScratch across phases of different shapes (and
        // different topology sizes) must not leak accumulator state.
        let p = NetParams::default();
        let mut scratch = PhaseScratch::default();
        let big = Topology::new(4, 8);
        let small = Topology::new(2, 2);
        let phases = [
            (big, (1..30).map(|s| Message::new(s, s % 7, 512)).collect::<Vec<_>>()),
            (small, vec![Message::new(0, 3, 64), Message::new(1, 3, 64)]),
            (big, vec![Message::new(31, 0, 1 << 20)]),
        ];
        for (topo, msgs) in &phases {
            let fresh = cost_phase_with_pending(&p, topo, msgs, &[]);
            let reused = cost_phase_into(&p, topo, msgs, &[], &mut scratch);
            assert_eq!(reused.time, fresh.time);
            assert_eq!(reused.recv_bound, fresh.recv_bound);
            assert_eq!(reused.send_bound, fresh.send_bound);
            assert_eq!(reused.nic_bound, fresh.nic_bound);
            assert_eq!(reused.max_in_degree, fresh.max_in_degree);
            assert_eq!(reused.total_bytes, fresh.total_bytes);
        }
    }

    /// Relative comparison for sums that may associate differently across
    /// shard boundaries.
    fn assert_close(got: f64, want: f64, what: &str) {
        let tol = 1e-9 * got.abs().max(want.abs()).max(1e-300);
        assert!((got - want).abs() <= tol, "{what}: {got} vs {want}");
    }

    #[test]
    fn sharded_matches_serial_oracle() {
        use crate::util::SplitMix64;
        let p = NetParams::default();
        let t = Topology::new(8, 16); // 128 ranks
        let mut rng = SplitMix64::new(0xC057_0AC1);
        // Sizes straddling the shard threshold: 1-shard, and multi-shard.
        for &n in &[0usize, 1, 1000, 40_000, 120_000] {
            let msgs: Vec<Message> = (0..n)
                .map(|i| {
                    Message::new(
                        rng.gen_range(128) as usize,
                        (i * 7 + rng.gen_range(3) as usize) % 128,
                        1 + rng.gen_range(1 << 14),
                    )
                })
                .collect();
            let pending: Vec<u64> = (0..128).map(|_| rng.gen_range(4)).collect();
            let want = cost_phase_serial(&p, &t, &msgs, &pending);
            let got = cost_phase_with_pending(&p, &t, &msgs, &pending);
            assert_eq!(got.n_messages, want.n_messages, "n={n}");
            assert_eq!(got.total_bytes, want.total_bytes, "n={n}");
            assert_eq!(got.max_in_degree, want.max_in_degree, "n={n}");
            assert_close(got.time, want.time, "time");
            assert_close(got.recv_bound, want.recv_bound, "recv_bound");
            assert_close(got.send_bound, want.send_bound, "send_bound");
            assert_close(got.nic_bound, want.nic_bound, "nic_bound");
        }
    }

    #[test]
    fn sharded_pending_update_matches_serial_oracle() {
        use crate::util::SplitMix64;
        let mut p = NetParams::default();
        p.send_mode = super::super::SendMode::Isend;
        let t = Topology::new(8, 16); // 128 ranks
        let mut rng = SplitMix64::new(0x9E_4D1);
        // Round sizes straddling the shard threshold, driven through the
        // same queue so carried counts compound across rounds.
        let rounds: Vec<Vec<Message>> = [3usize, 40_000, 0, 1000, 70_000]
            .iter()
            .map(|&n| {
                (0..n)
                    .map(|i| {
                        Message::new(
                            rng.gen_range(128) as usize,
                            (i * 11 + rng.gen_range(5) as usize) % 128,
                            1 + rng.gen_range(1 << 10),
                        )
                    })
                    .collect()
            })
            .collect();
        let mut q = PendingQueue::new();
        let mut oracle = vec![0u64; 128];
        for (i, msgs) in rounds.iter().enumerate() {
            let want_cost = cost_phase_serial(&p, &t, msgs, &oracle);
            let got_cost = q.cost_round(&p, &t, msgs);
            pending_update_serial(msgs, &mut oracle);
            // Integer pending counts are exact (no float association).
            for r in 0..128 {
                assert_eq!(q.pending_for(r), oracle[r], "round {i} rank {r}");
            }
            assert_eq!(got_cost.max_in_degree, want_cost.max_in_degree, "round {i}");
            assert_eq!(got_cost.total_bytes, want_cost.total_bytes, "round {i}");
            assert_close(got_cost.time, want_cost.time, "time");
        }
        // reset() re-zeroes the counts without dropping capacity.
        q.reset();
        assert!((0..128).all(|r| q.pending_for(r) == 0));
    }

    #[test]
    fn shard_count_is_deterministic_in_message_count() {
        assert_eq!(shard_count(0), 1);
        assert_eq!(shard_count(2 * SHARD_TARGET_MSGS - 1), 1);
        assert_eq!(shard_count(2 * SHARD_TARGET_MSGS), 2);
        assert_eq!(shard_count(10_000_000), MAX_SHARDS);
    }

    #[test]
    fn hierarchical_topology_prices_messages_by_tier() {
        use crate::cluster::RankPlacement;
        let p = NetParams::default();
        // 4 nodes × 4 ppn, 2 sockets per node, 2 nodes per switch.
        let h = Topology::hierarchical(4, 4, 2, 2, RankPlacement::Block);
        let flat = Topology::new(4, 4);
        let same_socket = vec![Message::new(0, 1, 1 << 16)];
        let cross_socket = vec![Message::new(0, 2, 1 << 16)];
        let same_switch = vec![Message::new(0, 4, 1 << 16)];
        let cross_switch = vec![Message::new(0, 8, 1 << 16)];
        let t_socket = cost_phase(&p, &h, &same_socket).time;
        let t_node = cost_phase(&p, &h, &cross_socket).time;
        let t_switch = cost_phase(&p, &h, &same_switch).time;
        let t_global = cost_phase(&p, &h, &cross_switch).time;
        assert!(t_socket < t_node, "{t_socket} vs {t_node}");
        assert!(t_node < t_switch, "{t_node} vs {t_switch}");
        assert!(t_switch < t_global, "{t_switch} vs {t_global}");
        // The flat topology collapses every same-node pair to the node row
        // and every cross-node pair to the global row.
        assert_eq!(
            cost_phase(&p, &flat, &same_socket).time,
            cost_phase(&p, &flat, &cross_socket).time
        );
        assert_eq!(
            cost_phase(&p, &flat, &same_switch).time,
            cost_phase(&p, &flat, &cross_switch).time
        );
        assert_eq!(cost_phase(&p, &flat, &cross_switch).time, t_global);
        // Off-node messages hit the NIC whatever their tier; on-node never.
        assert!(cost_phase(&p, &h, &same_switch).nic_bound > 0.0);
        assert_eq!(cost_phase(&p, &h, &cross_socket).nic_bound, 0.0);
    }

    #[test]
    fn sharded_matches_serial_oracle_on_hierarchical_topology() {
        use crate::cluster::RankPlacement;
        use crate::util::SplitMix64;
        let p = NetParams::default();
        let t = Topology::hierarchical(8, 16, 4, 2, RankPlacement::RoundRobin);
        let mut rng = SplitMix64::new(0x7133_D001);
        for &n in &[500usize, 40_000] {
            let msgs: Vec<Message> = (0..n)
                .map(|i| {
                    Message::new(
                        rng.gen_range(128) as usize,
                        (i * 13 + rng.gen_range(7) as usize) % 128,
                        1 + rng.gen_range(1 << 12),
                    )
                })
                .collect();
            let want = cost_phase_serial(&p, &t, &msgs, &[]);
            let got = cost_phase(&p, &t, &msgs);
            assert_eq!(got.max_in_degree, want.max_in_degree, "n={n}");
            assert_eq!(got.total_bytes, want.total_bytes, "n={n}");
            assert_close(got.time, want.time, "time");
            assert_close(got.recv_bound, want.recv_bound, "recv_bound");
            assert_close(got.send_bound, want.send_bound, "send_bound");
            assert_close(got.nic_bound, want.nic_bound, "nic_bound");
        }
    }

    #[test]
    fn stats_absorb_accumulates() {
        let p = NetParams::default();
        let t = topo();
        let msgs = vec![Message::new(1, 0, 10), Message::new(2, 0, 20)];
        let c = cost_phase(&p, &t, &msgs);
        let mut s = ExchangeStats::default();
        s.absorb(&c);
        s.absorb(&c);
        assert_eq!(s.n_messages, 4);
        assert_eq!(s.total_bytes, 60);
        assert!(s.time > 0.0);
    }

    #[test]
    fn in_degree_histogram() {
        let msgs = vec![
            Message::new(1, 0, 1),
            Message::new(2, 0, 1),
            Message::new(3, 5, 1),
        ];
        let h = in_degree_by_rank(&msgs);
        assert_eq!(h[&0], 2);
        assert_eq!(h[&5], 1);
    }

    #[test]
    fn overlap_account_credits_hidden_io_only() {
        let mut a = OverlapAccount::default();
        // Fewer than two rounds: nothing to pipeline.
        a.push_round(1.0, 0.0, 100.0);
        assert_eq!(a.finish(5.0), 0.0);
        // Two equal-weight rounds, exchange longer than each round's
        // I/O share: round 0's whole share (2.5 s) hides behind round
        // 1's 4.0 s exchange; round 1's share has no next round.
        a.push_round(4.0, 0.0, 100.0);
        assert_eq!(a.rounds(), 2);
        assert!((a.finish(5.0) - 2.5).abs() < 1e-12);
        // The sync bound shrinks what round 1's exchange can hide.
        a.reset();
        a.push_round(1.0, 0.0, 100.0);
        a.push_round(4.0, 3.0, 100.0);
        assert!((a.finish(5.0) - 1.0).abs() < 1e-12);
        // A sync bound exceeding the exchange clamps to zero, never
        // goes negative.
        a.reset();
        a.push_round(1.0, 0.0, 100.0);
        a.push_round(2.0, 9.0, 100.0);
        assert_eq!(a.finish(5.0), 0.0);
        // Degenerate ledgers credit nothing.
        a.reset();
        assert_eq!(a.finish(5.0), 0.0);
        a.push_round(1.0, 0.0, 0.0);
        a.push_round(1.0, 0.0, 0.0);
        assert_eq!(a.finish(5.0), 0.0);
    }
}
