//! Tiny property-testing harness (proptest is not in the image).
//!
//! [`forall`] runs a property over `cases` deterministic random cases; on
//! failure it retries with progressively simpler size hints (shrink-lite)
//! and reports the failing seed so the case can be replayed exactly.

use crate::util::SplitMix64;

/// Size hint passed to generators; shrinks on failure.
#[derive(Debug)]
pub struct Gen<'a> {
    /// PRNG for this case.
    pub rng: &'a mut SplitMix64,
    /// Soft upper bound for collection sizes.
    pub size: usize,
}

impl Gen<'_> {
    /// Uniform usize in `[lo, hi]` scaled into the current size budget.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi = hi.min(lo + self.size);
        if hi <= lo {
            lo
        } else {
            lo + self.rng.gen_range((hi - lo + 1) as u64) as usize
        }
    }

    /// Random u64 below `bound`.
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        self.rng.gen_range(bound.max(1))
    }

    /// Random bool with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }
}

/// Run `prop` over `cases` random cases derived from `seed`.
///
/// `prop` returns `Err(description)` to fail.  On failure the harness
/// retries the same case seed at smaller size hints to report the
/// simplest reproduction it can find, then panics with seed + message.
pub fn forall<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut root = SplitMix64::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let run = |size: usize, prop: &mut F| -> Result<(), String> {
            let mut rng = SplitMix64::new(case_seed);
            let mut g = Gen { rng: &mut rng, size };
            prop(&mut g)
        };
        if let Err(first_msg) = run(64, &mut prop) {
            // Shrink-lite: find the smallest size hint that still fails.
            let mut msg = first_msg;
            let mut failing_size = 64;
            for size in [1usize, 2, 4, 8, 16, 32] {
                if let Err(m) = run(size, &mut prop) {
                    msg = m;
                    failing_size = size;
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 size {failing_size}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("always-true", 1, 25, |g| {
            count += 1;
            let n = g.usize_in(0, 100);
            if n <= 100 { Ok(()) } else { Err("impossible".into()) }
        });
        assert!(count >= 25);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        forall("always-false", 2, 5, |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Vec::new();
        forall("collect", 3, 5, |g| {
            a.push(g.u64_below(1000));
            Ok(())
        });
        let mut b = Vec::new();
        forall("collect", 3, 5, |g| {
            b.push(g.u64_below(1000));
            Ok(())
        });
        assert_eq!(a, b);
    }
}
