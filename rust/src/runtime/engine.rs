//! The aggregator hot path behind a trait: native Rust vs the AOT-compiled
//! XLA pipeline, interchangeable and bit-identical.
//!
//! The coordinator calls [`SortEngine::merge_coalesce`] wherever an
//! aggregator must sort + coalesce gathered offset/length lists (§IV-A
//! intra-node, §IV-B inter-node).  [`NativeEngine`] is the pure-Rust
//! implementation; [`XlaEngine`] executes the `artifacts/agg_*.hlo.txt`
//! pipeline (bitonic sort + coalesce Pallas kernels) via PJRT.
//!
//! The `xla` crate's PJRT handles are `Rc`-based (`!Send`), while the
//! coordinator fans merges out over scoped threads — so [`XlaEngine`]
//! owns a dedicated worker thread that constructs and exclusively owns
//! the [`PjrtRuntime`]; requests cross over an mpsc channel.  This also
//! matches how a real deployment would pin a PJRT context to one core.

use std::sync::mpsc;
use std::sync::Mutex;

use crate::coordinator::merge::{
    merge_csr_into, merge_views_into, sort_coalesce_pairs, MergeScratch,
};
use crate::error::{Error, Result};
use crate::mpisim::FlatView;

use super::pjrt::PjrtRuntime;

/// Engine selector for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust k-way merge / sort+coalesce.
    Native,
    /// AOT-compiled JAX/Pallas pipeline via PJRT.
    Xla,
}

impl std::str::FromStr for EngineKind {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(EngineKind::Native),
            "xla" => Ok(EngineKind::Xla),
            other => Err(crate::Error::config(format!(
                "unknown engine '{other}' (expected native|xla)"
            ))),
        }
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Native => write!(f, "native"),
            EngineKind::Xla => write!(f, "xla"),
        }
    }
}

/// Sort + coalesce of an aggregator's gathered request metadata.
pub trait SortEngine: Send + Sync {
    /// Sort `pairs` ascending by offset and coalesce exactly-contiguous
    /// neighbours.  Input order is arbitrary (it is a concatenation of the
    /// peers' sorted lists); output is ascending and minimal.
    fn merge_coalesce(&self, pairs: Vec<(u64, u64)>) -> Result<Vec<(u64, u64)>>;

    /// Merge *already-sorted* peer streams into one ascending, coalesced
    /// view — the streaming entry point of the aggregator hot path.
    ///
    /// Each element of `views` is one peer's flattened file view, sorted by
    /// construction (the MPI file-view guarantee).  The default
    /// implementation concatenates and reuses [`Self::merge_coalesce`]
    /// (what the batched XLA pipeline does, with
    /// [`crate::coordinator::merge::combine_coalesced_partials`] absorbing
    /// chunk seams); [`NativeEngine`] overrides it with the `O(n log k)`
    /// heap merge so no flatten + full re-sort happens on the native path.
    /// Both produce bit-identical output.
    fn merge_sorted(&self, views: &[&FlatView]) -> Result<FlatView> {
        let pairs: Vec<(u64, u64)> = views.iter().flat_map(|v| v.iter()).collect();
        let merged = self.merge_coalesce(pairs)?;
        Ok(FlatView::from_pairs_unchecked(
            merged.iter().map(|p| p.0).collect(),
            merged.iter().map(|p| p.1).collect(),
        ))
    }

    /// [`Self::merge_sorted`] into a caller-owned view (cleared first;
    /// capacity reused across calls) — the merged-view arena entry point
    /// of the exchange round loops, where a fresh per-round `FlatView`
    /// was the last steady-state allocation.  The default delegates to
    /// [`Self::merge_sorted`] and moves the result in (the batched XLA
    /// pipeline materializes a fresh list anyway); [`NativeEngine`]
    /// overrides it to stream directly into `out`.  Output is
    /// bit-identical to [`Self::merge_sorted`] on every input.
    fn merge_sorted_into(&self, views: &[&FlatView], out: &mut FlatView) -> Result<()> {
        *out = self.merge_sorted(views)?;
        Ok(())
    }

    /// [`Self::merge_sorted_into`] over CSR-staged streams — the form the
    /// exchange round loop holds its peer requests in (stream `s` is rows
    /// `starts[s]..starts[s + 1]` of one flat slab; see
    /// [`crate::coordinator::merge::RoundScratch`]): no per-stream
    /// `FlatView` is materialized on the hot path, and `scratch` carries
    /// the reused heap storage so a steady-state call allocates nothing.
    /// The default flattens and reuses [`Self::merge_coalesce`] (the
    /// batched XLA pipeline re-sorts the concatenation anyway);
    /// [`NativeEngine`] overrides it with the direct CSR heap merge.
    /// Output is bit-identical to [`Self::merge_sorted_into`] over the
    /// per-stream views on every input.
    fn merge_sorted_csr_into(
        &self,
        offsets: &[u64],
        lengths: &[u64],
        _starts: &[usize],
        _scratch: &mut MergeScratch,
        out: &mut FlatView,
    ) -> Result<()> {
        let pairs: Vec<(u64, u64)> =
            offsets.iter().copied().zip(lengths.iter().copied()).collect();
        let merged = self.merge_coalesce(pairs)?;
        out.clear();
        for (o, l) in merged {
            out.push(o, l);
        }
        Ok(())
    }

    /// Engine name for reports.
    fn name(&self) -> &'static str;
}

/// Pure-Rust engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeEngine;

impl SortEngine for NativeEngine {
    fn merge_coalesce(&self, pairs: Vec<(u64, u64)>) -> Result<Vec<(u64, u64)>> {
        Ok(sort_coalesce_pairs(pairs))
    }

    fn merge_sorted(&self, views: &[&FlatView]) -> Result<FlatView> {
        // Thin allocating wrapper over the arena entry point.
        let mut out = FlatView::empty();
        merge_views_into(views, &mut out);
        Ok(out)
    }

    fn merge_sorted_into(&self, views: &[&FlatView], out: &mut FlatView) -> Result<()> {
        merge_views_into(views, out);
        Ok(())
    }

    fn merge_sorted_csr_into(
        &self,
        offsets: &[u64],
        lengths: &[u64],
        starts: &[usize],
        scratch: &mut MergeScratch,
        out: &mut FlatView,
    ) -> Result<()> {
        merge_csr_into(offsets, lengths, starts, scratch, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

type Job = (Vec<(u64, u64)>, mpsc::Sender<Result<Vec<(u64, u64)>>>);

/// XLA engine: a worker thread owns the PJRT runtime; callers submit
/// batches over a channel (PJRT handles are `!Send`).
pub struct XlaEngine {
    tx: Mutex<mpsc::Sender<Job>>,
    /// Batch sizes reported by the worker at startup (diagnostics).
    batch_sizes: Vec<usize>,
    /// Largest compiled batch.
    max_batch: usize,
}

impl XlaEngine {
    /// Spawn the worker and load artifacts from `dir`.
    pub fn load(dir: std::path::PathBuf) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (init_tx, init_rx) = mpsc::channel::<Result<Vec<usize>>>();
        std::thread::Builder::new()
            .name("xla-engine".into())
            .spawn(move || {
                let rt = match PjrtRuntime::load(&dir) {
                    Ok(rt) => {
                        let _ = init_tx.send(Ok(rt.batch_sizes()));
                        rt
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                // Serve until every sender is dropped.
                while let Ok((pairs, reply)) = rx.recv() {
                    let _ = reply.send(run_batched(&rt, pairs));
                }
            })
            .map_err(|e| Error::Runtime(format!("cannot spawn xla worker: {e}")))?;
        let batch_sizes = init_rx
            .recv()
            .map_err(|_| Error::Runtime("xla worker died during init".into()))??;
        let max_batch = *batch_sizes.last().expect("nonempty artifact set");
        Ok(XlaEngine { tx: Mutex::new(tx), batch_sizes, max_batch })
    }

    /// Load artifacts from the default location.
    pub fn load_default() -> Result<Self> {
        let dir = super::find_artifacts_dir().ok_or_else(|| {
            Error::Runtime("artifacts/manifest.txt not found — run `make artifacts`".into())
        })?;
        Self::load(dir)
    }

    /// Compiled batch sizes.
    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

/// Chunk oversize inputs, run each chunk through the artifact, combine.
fn run_batched(rt: &PjrtRuntime, pairs: Vec<(u64, u64)>) -> Result<Vec<(u64, u64)>> {
    if pairs.is_empty() {
        return Ok(Vec::new());
    }
    let max = rt.max_batch();
    if pairs.len() <= max {
        return rt.aggregate_batch(&pairs);
    }
    // Chunk outputs are sorted+coalesced; the final combine must absorb
    // zero-length segments that fall inside another chunk's segment —
    // see combine_coalesced_partials.
    let mut partials: Vec<(u64, u64)> = Vec::new();
    for chunk in pairs.chunks(max) {
        partials.extend(rt.aggregate_batch(chunk)?);
    }
    Ok(crate::coordinator::merge::combine_coalesced_partials(partials))
}

impl SortEngine for XlaEngine {
    fn merge_coalesce(&self, pairs: Vec<(u64, u64)>) -> Result<Vec<(u64, u64)>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().map_err(|_| Error::Runtime("engine lock poisoned".into()))?;
            tx.send((pairs, reply_tx))
                .map_err(|_| Error::Runtime("xla worker gone".into()))?;
        }
        reply_rx
            .recv()
            .map_err(|_| Error::Runtime("xla worker dropped reply".into()))?
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("batch_sizes", &self.batch_sizes)
            .finish()
    }
}

/// Build an engine by kind; `Xla` loads the default artifacts.
pub fn build_engine(kind: EngineKind) -> Result<std::sync::Arc<dyn SortEngine>> {
    match kind {
        EngineKind::Native => Ok(std::sync::Arc::new(NativeEngine)),
        EngineKind::Xla => Ok(std::sync::Arc::new(XlaEngine::load_default()?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_sorts_and_coalesces() {
        let e = NativeEngine;
        let out = e
            .merge_coalesce(vec![(8, 4), (0, 4), (4, 4), (100, 2)])
            .unwrap();
        assert_eq!(out, vec![(0, 12), (100, 2)]);
        assert_eq!(e.name(), "native");
    }

    #[test]
    fn engine_kind_parses() {
        assert_eq!("native".parse::<EngineKind>().unwrap(), EngineKind::Native);
        assert_eq!("xla".parse::<EngineKind>().unwrap(), EngineKind::Xla);
        assert!("cuda".parse::<EngineKind>().is_err());
    }

    #[test]
    fn native_engine_empty() {
        assert!(NativeEngine.merge_coalesce(vec![]).unwrap().is_empty());
        assert!(NativeEngine.merge_sorted(&[]).unwrap().is_empty());
    }

    /// Exercises the trait's default `merge_sorted` (the concat + coalesce
    /// fallback the XLA engine inherits) against the native override.
    struct ConcatFallback;

    impl SortEngine for ConcatFallback {
        fn merge_coalesce(&self, pairs: Vec<(u64, u64)>) -> Result<Vec<(u64, u64)>> {
            Ok(sort_coalesce_pairs(pairs))
        }

        fn name(&self) -> &'static str {
            "concat-fallback"
        }
    }

    #[test]
    fn merge_sorted_native_matches_default_fallback() {
        let a = FlatView::from_pairs(vec![(0, 4), (8, 4), (16, 0)]).unwrap();
        let b = FlatView::from_pairs(vec![(4, 4), (12, 4), (100, 2)]).unwrap();
        let views = [&a, &b];
        let native = NativeEngine.merge_sorted(&views).unwrap();
        let fallback = ConcatFallback.merge_sorted(&views).unwrap();
        assert_eq!(native, fallback);
        assert_eq!(
            native.iter().collect::<Vec<_>>(),
            vec![(0, 16), (100, 2)]
        );
    }

    #[test]
    fn merge_sorted_csr_native_matches_default_fallback() {
        // Two streams staged CSR-style; native override vs the trait's
        // flatten + re-sort default must agree bit-for-bit.
        let offsets = [0u64, 8, 16, 4, 12, 100];
        let lengths = [4u64, 4, 0, 4, 4, 2];
        let starts = [0usize, 3, 6];
        let mut scratch = MergeScratch::default();
        let mut native_out = FlatView::from_pairs(vec![(900, 3)]).unwrap();
        NativeEngine
            .merge_sorted_csr_into(&offsets, &lengths, &starts, &mut scratch, &mut native_out)
            .unwrap();
        let mut fallback_out = FlatView::from_pairs(vec![(900, 3), (903, 1)]).unwrap();
        ConcatFallback
            .merge_sorted_csr_into(&offsets, &lengths, &starts, &mut scratch, &mut fallback_out)
            .unwrap();
        assert_eq!(native_out, fallback_out);
        assert_eq!(
            native_out.iter().collect::<Vec<_>>(),
            vec![(0, 16), (100, 2)]
        );
        // Both must also match the per-stream-views entry point.
        let a = FlatView::from_pairs(vec![(0, 4), (8, 4), (16, 0)]).unwrap();
        let b = FlatView::from_pairs(vec![(4, 4), (12, 4), (100, 2)]).unwrap();
        assert_eq!(native_out, NativeEngine.merge_sorted(&[&a, &b]).unwrap());
    }

    #[test]
    fn merge_sorted_into_reuses_buffer_and_matches_allocating_path() {
        let a = FlatView::from_pairs(vec![(0, 4), (8, 4)]).unwrap();
        let b = FlatView::from_pairs(vec![(4, 4), (100, 2)]).unwrap();
        let views = [&a, &b];
        // Arena pre-filled with stale segments: both the native override
        // and the default (delegating) impl must fully replace it.
        let mut native_out = FlatView::from_pairs(vec![(900, 3), (901, 3)]).unwrap();
        NativeEngine.merge_sorted_into(&views, &mut native_out).unwrap();
        let mut fallback_out = FlatView::from_pairs(vec![(900, 3)]).unwrap();
        ConcatFallback.merge_sorted_into(&views, &mut fallback_out).unwrap();
        let want = NativeEngine.merge_sorted(&views).unwrap();
        assert_eq!(native_out, want);
        assert_eq!(fallback_out, want);
        assert_eq!(want.iter().collect::<Vec<_>>(), vec![(0, 12), (100, 2)]);
    }
}
