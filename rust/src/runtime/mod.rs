//! Runtime layer: PJRT execution of the AOT-compiled aggregation pipeline.
//!
//! `make artifacts` lowers the L2 JAX pipeline (which calls the L1 Pallas
//! kernels) to HLO text; [`pjrt::PjrtRuntime`] loads those artifacts with
//! the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile` → `execute`), and [`engine`] exposes the aggregator
//! hot path behind the [`engine::SortEngine`] trait with interchangeable
//! native-Rust and XLA implementations.  Python never runs here.

pub mod engine;

// The real PJRT wrapper needs the `xla` crate, which must be vendored into
// the build image; without the `xla` feature a stub with the same API
// reports the runtime as unavailable so every XLA path skips gracefully.
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use engine::{EngineKind, NativeEngine, SortEngine, XlaEngine};
pub use pjrt::PjrtRuntime;

/// Default artifacts directory, relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `$TAMIO_ARTIFACTS` override, else walk
/// up from the current directory looking for `artifacts/manifest.txt`.
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("TAMIO_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.txt").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(DEFAULT_ARTIFACTS_DIR);
        if cand.join("manifest.txt").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
