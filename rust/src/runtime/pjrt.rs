//! PJRT client wrapper: load `artifacts/agg_*.hlo.txt`, compile once,
//! execute batches of the aggregation pipeline on the request path.
//!
//! The artifact contract (see `python/compile/model.py`):
//! inputs `(offsets: s64[N], lengths: s64[N])` padded with [`SENTINEL`],
//! output tuple `(coal_off: s64[N], coal_len: s64[N], nseg: s64[1])`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Sentinel offset marking padding slots (i64::MAX, matching
/// `kernels.bitonic.SENTINEL`).
pub const SENTINEL: i64 = i64::MAX;

/// A compiled aggregation executable for one batch size.
struct SizedExec {
    n: usize,
    exec: xla::PjRtLoadedExecutable,
}

/// PJRT CPU runtime holding one compiled executable per artifact size.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    execs: BTreeMap<usize, SizedExec>,
    artifacts_dir: PathBuf,
}

impl PjrtRuntime {
    /// Load every artifact listed in `<dir>/manifest.txt` and compile it
    /// on the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let listing = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                manifest.display()
            ))
        })?;
        let client = xla::PjRtClient::cpu()?;
        let mut execs = BTreeMap::new();
        for line in listing.lines() {
            let mut parts = line.split_whitespace();
            let (Some(file), Some(n)) = (parts.next(), parts.next()) else {
                continue;
            };
            let n: usize = n
                .parse()
                .map_err(|_| Error::Runtime(format!("bad manifest line: {line}")))?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exec = client.compile(&comp)?;
            execs.insert(n, SizedExec { n, exec });
        }
        if execs.is_empty() {
            return Err(Error::Runtime(format!(
                "no artifacts found in {}",
                dir.display()
            )));
        }
        Ok(PjrtRuntime { client, execs, artifacts_dir: dir })
    }

    /// Convenience: locate the artifacts dir and load it.
    pub fn load_default() -> Result<Self> {
        let dir = super::find_artifacts_dir().ok_or_else(|| {
            Error::Runtime("artifacts/manifest.txt not found — run `make artifacts`".into())
        })?;
        Self::load(dir)
    }

    /// Available batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.execs.keys().copied().collect()
    }

    /// Largest supported batch size.
    pub fn max_batch(&self) -> usize {
        *self.execs.keys().next_back().expect("nonempty")
    }

    /// Directory the artifacts were loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run the aggregation pipeline on ≤ `max_batch()` pairs: returns the
    /// coalesced `(offset, length)` list.
    ///
    /// Picks the smallest artifact size ≥ `pairs.len()` and pads with
    /// SENTINEL; the trailing sentinel segment is dropped on output.
    pub fn aggregate_batch(&self, pairs: &[(u64, u64)]) -> Result<Vec<(u64, u64)>> {
        let need = pairs.len().max(1);
        let sized = self
            .execs
            .values()
            .find(|s| s.n >= need)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "batch of {} exceeds largest artifact {}",
                    pairs.len(),
                    self.max_batch()
                ))
            })?;
        let n = sized.n;
        let mut offsets = vec![SENTINEL; n];
        let mut lengths = vec![0i64; n];
        for (i, &(o, l)) in pairs.iter().enumerate() {
            offsets[i] = i64::try_from(o)
                .map_err(|_| Error::Runtime(format!("offset {o} exceeds i64 range")))?;
            lengths[i] = i64::try_from(l)
                .map_err(|_| Error::Runtime(format!("length {l} exceeds i64 range")))?;
        }
        let off_lit = xla::Literal::vec1(&offsets);
        let len_lit = xla::Literal::vec1(&lengths);
        let result = sized.exec.execute::<xla::Literal>(&[off_lit, len_lit])?[0][0]
            .to_literal_sync()?;
        let (co, cl, nseg) = result.to_tuple3()?;
        let co = co.to_vec::<i64>()?;
        let cl = cl.to_vec::<i64>()?;
        let nseg = nseg.to_vec::<i64>()?[0] as usize;
        let mut out = Vec::with_capacity(nseg);
        for i in 0..nseg.min(n) {
            if co[i] == SENTINEL {
                break; // trailing sentinel segment (padding)
            }
            out.push((co[i] as u64, cl[i] as u64));
        }
        Ok(out)
    }
}

impl std::fmt::Debug for PjrtRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtRuntime")
            .field("artifacts_dir", &self.artifacts_dir)
            .field("batch_sizes", &self.batch_sizes())
            .finish()
    }
}
