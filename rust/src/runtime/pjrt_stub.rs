//! Stub PJRT runtime, compiled when the `xla` cargo feature is off.
//!
//! The offline image does not ship the `xla` crate, so this module mirrors
//! the public API of `runtime/pjrt.rs` and fails at [`PjrtRuntime::load`]
//! with a descriptive error.  Everything downstream (the engine worker,
//! `tamio info`, the XLA tests and examples) already treats "artifacts
//! unavailable" as a skip condition, so the stub makes the whole crate
//! buildable and testable without PJRT while keeping call sites identical.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Sentinel offset marking padding slots (i64::MAX, matching
/// `kernels.bitonic.SENTINEL`).
pub const SENTINEL: i64 = i64::MAX;

/// Stub runtime: construction always fails; methods exist only so the
/// engine layer type-checks identically with and without the feature.
#[derive(Debug)]
pub struct PjrtRuntime {
    artifacts_dir: PathBuf,
}

fn unavailable() -> Error {
    Error::Runtime(
        "XLA/PJRT support not compiled in — build with `--features xla` \
         (requires the vendored `xla` crate) to run the AOT pipeline"
            .into(),
    )
}

impl PjrtRuntime {
    /// Always fails: the PJRT runtime is not compiled in.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let _ = dir.as_ref();
        Err(unavailable())
    }

    /// Always fails: the PJRT runtime is not compiled in.
    pub fn load_default() -> Result<Self> {
        Err(unavailable())
    }

    /// Available batch sizes, ascending (stub: none).
    pub fn batch_sizes(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Largest supported batch size (stub: zero).
    pub fn max_batch(&self) -> usize {
        0
    }

    /// Directory the artifacts were loaded from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Always fails: the PJRT runtime is not compiled in.
    pub fn aggregate_batch(&self, pairs: &[(u64, u64)]) -> Result<Vec<(u64, u64)>> {
        let _ = pairs;
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_load_fails_with_runtime_error() {
        let err = PjrtRuntime::load("/nonexistent").unwrap_err();
        assert!(matches!(err, Error::Runtime(_)));
        assert!(PjrtRuntime::load_default().is_err());
    }
}
