//! Small shared utilities: deterministic PRNG, parallel map over OS
//! threads, byte formatting.  (No rand/rayon in the offline image.)

pub mod parallel;
pub mod rng;
pub mod runtime;

pub use parallel::par_map;
pub use rng::SplitMix64;

/// Format a byte count in human units (GiB/MiB/KiB/B).
pub fn human_bytes(bytes: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.2} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.2} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Format a simulated-seconds value with sensible precision.
pub fn human_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} us", s * 1e6)
    }
}

/// Integer ceiling division.
pub const fn ceil_div(a: u64, b: u64) -> u64 {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(human_bytes(85 * 1024 * 1024 * 1024), "85.00 GiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(2.5), "2.500 s");
        assert_eq!(human_secs(0.0025), "2.500 ms");
        assert_eq!(human_secs(0.0000025), "2.500 us");
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
    }
}
