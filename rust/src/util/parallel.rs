//! Scoped-thread parallel map (rayon is not available in the image).
//!
//! Deterministic: results are returned in input order regardless of
//! scheduling; work is chunked contiguously over `min(items, cores)`
//! threads.

use std::num::NonZeroUsize;

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
}

/// Parallel map preserving input order.
///
/// `f` must be `Sync` (called from multiple scoped threads); items are
/// processed by contiguous chunks so cache behaviour matches the serial
/// loop.  Falls back to a serial map for small inputs.
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    let threads = default_threads().min(n.max(1));
    if threads <= 1 || n < 2 {
        return items.into_iter().map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<U>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let mut item_chunks: Vec<Vec<T>> = Vec::new();
    {
        let mut it = items.into_iter();
        loop {
            let c: Vec<T> = it.by_ref().take(chunk).collect();
            if c.is_empty() {
                break;
            }
            item_chunks.push(c);
        }
    }
    let fref = &f;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (ci, c) in item_chunks.into_iter().enumerate() {
            handles.push((ci, s.spawn(move || c.into_iter().map(fref).collect::<Vec<U>>())));
        }
        for (ci, h) in handles {
            let res = h.join().expect("par_map worker panicked");
            for (j, v) in res.into_iter().enumerate() {
                out[ci * chunk + j] = Some(v);
            }
        }
    });
    out.into_iter().map(|v| v.expect("par_map slot unfilled")).collect()
}

/// Parallel for-each over mutable chunks of a slice.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || chunk == 0 {
        return;
    }
    let fref = &f;
    std::thread::scope(|s| {
        for (i, c) in data.chunks_mut(chunk).enumerate() {
            s.spawn(move || fref(i, c));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(items, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_non_divisible_chunks() {
        let items: Vec<usize> = (0..17).collect();
        let out = par_map(items, |x| x + 100);
        assert_eq!(out, (100..117).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut data = vec![0u32; 97];
        par_chunks_mut(&mut data, 10, |_, c| {
            for v in c {
                *v = 1;
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }
}
