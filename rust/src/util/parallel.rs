//! Order-preserving parallel helpers over the persistent pool.
//!
//! Thin wrappers around [`crate::util::runtime`]: `par_map` and
//! `par_chunks_mut` keep their original signatures but now submit
//! fine-grained one-item tasks to the shared work-stealing pool instead
//! of spawning OS threads per call (and, for `par_chunks_mut`, per
//! chunk — formerly unbounded).  Results land in pre-assigned slots, so
//! output order is input order for any pool width.

use crate::util::runtime;

pub use crate::util::runtime::default_threads;

/// Parallel map preserving input order.
///
/// Runs on the current pool ([`runtime::current`]); each item is one
/// stealable task, so uneven per-item cost no longer idles workers the
/// way the old contiguous-chunk split did.  Panics inside `f` are
/// re-raised with the failing item's index.
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let mut slots: Vec<(Option<T>, Option<U>)> =
        items.into_iter().map(|t| (Some(t), None)).collect();
    runtime::current().for_each_mut(&mut slots, &|i| format!("par_map item {i}"), |_, slot| {
        let item = slot.0.take().expect("par_map slot taken twice");
        slot.1 = Some(f(item));
    });
    slots.into_iter().map(|(_, u)| u.expect("par_map slot unfilled")).collect()
}

/// Parallel for-each over mutable chunks of a slice.
///
/// Concurrency is capped at the pool width: chunks are tasks on the
/// shared pool, not one OS thread per chunk (a small `chunk` over a
/// large slice used to spawn thousands of threads).
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || chunk == 0 {
        return;
    }
    let mut chunks: Vec<&mut [T]> = data.chunks_mut(chunk).collect();
    runtime::current().for_each_mut(
        &mut chunks,
        &|i| format!("par_chunks_mut chunk {i}"),
        |i, c| f(i, c),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::runtime::{with_runtime, Runtime};

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(items, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn par_map_non_divisible_chunks() {
        let items: Vec<usize> = (0..17).collect();
        let out = par_map(items, |x| x + 100);
        assert_eq!(out, (100..117).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_order_is_pool_width_invariant() {
        let items: Vec<u64> = (0..500).collect();
        let expect: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for width in [1, 2, 5] {
            let rt = Runtime::new(width);
            let got = with_runtime(&rt, || par_map(items.clone(), |x| x * x + 1));
            assert_eq!(got, expect, "width {width}");
        }
    }

    #[test]
    fn par_chunks_mut_writes_all() {
        let mut data = vec![0u32; 97];
        par_chunks_mut(&mut data, 10, |_, c| {
            for v in c {
                *v = 1;
            }
        });
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn par_chunks_mut_many_tiny_chunks_bounded() {
        // 4096 chunks of 1 element: the old implementation spawned 4096
        // OS threads here; the pool runs them on its fixed lanes.
        let rt = Runtime::new(4);
        let mut data = vec![0u8; 4096];
        with_runtime(&rt, || {
            par_chunks_mut(&mut data, 1, |i, c| c[0] = (i % 251) as u8);
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == (i % 251) as u8));
    }
}
