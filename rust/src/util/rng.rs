//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//!
//! Used by workload generators and the property-test harness.  SplitMix64
//! passes BigCrush for these purposes and is trivially reproducible from a
//! printed seed.

/// A SplitMix64 pseudo-random generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero.
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (generator use, not crypto): map the 64-bit value into the range.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn gen_between(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (decorrelated stream) for parallel use.
    pub fn fork(&mut self, tag: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
        }
    }

    #[test]
    fn gen_range_covers_values() {
        let mut r = SplitMix64::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
