//! Persistent work-stealing task runtime.
//!
//! The repo's original `par_map` spawned fresh OS threads per call and
//! chunked work per-aggregator, which leaves cores idle on deep trees
//! where a level has fewer aggregators than cores.  This module replaces
//! that with ONE lazily-initialized global pool of
//! `available_parallelism()` workers (overridable via `TAMIO_THREADS` or
//! `--threads`) fed fine-grained index tasks through per-worker deques:
//! the submitting thread round-robins task indices over all lanes, each
//! worker pops its own lane LIFO and steals FIFO from other lanes when
//! its lane runs dry (chase-lev style, lock-based since the image has no
//! crossbeam).
//!
//! Determinism: stealing only reorders *execution*; every task writes to
//! the slot pre-assigned by its index (`for_each_mut` hands task `i`
//! item `i`), so results are bit-identical for any thread count,
//! including 1.  The serial path is the same closure called in index
//! order.
//!
//! Warm-path allocation: lanes are `VecDeque<usize>` that are cleared
//! (capacity retained) each batch, the batch descriptor is a thin
//! pointer pair on the submitter's stack, and panic/error labels are
//! lazy closures only invoked on failure — a warm batch performs no
//! heap allocation, preserving the `alloc_steady_state` invariant.
//!
//! Panics inside tasks are caught per-task; the lowest-index failure is
//! re-raised on the submitting thread with the task's identity (from the
//! lazy label) prepended, so a panic at (level, aggregator, round) says
//! so instead of `expect("par_map worker panicked")`.

use std::any::Any;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::num::NonZeroUsize;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::error::{Error, Result};

/// A batch's task function, type-erased to a thin pointer plus a
/// monomorphized trampoline so it can sit in the shared pool state
/// without fat-pointer lifetime gymnastics.  Validity: the submitter
/// keeps the closure alive on its stack until every worker has left the
/// batch (`active == 0`), and clears the descriptor before returning.
#[derive(Clone, Copy)]
struct TaskRef {
    ptr: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: TaskRef is only dereferenced while the submitting thread is
// blocked in `run_batch`, which guarantees the pointee outlives use.
unsafe impl Send for TaskRef {}

unsafe fn call_closure<F: Fn(usize) + Sync>(ptr: *const (), idx: usize) {
    // SAFETY: `ptr` was created from an `&F` in `run_batch` and is live
    // for the duration of the batch (see TaskRef).
    unsafe { (*(ptr as *const F))(idx) }
}

/// Pool state shared by workers and submitters.  Workers hold only an
/// `Arc<PoolCore>` (never an `Arc<PoolOwner>`), so dropping the last
/// `Runtime` clone triggers shutdown with no Arc cycle.
struct PoolCore {
    /// Total lanes, including lane 0 (the submitting thread helps).
    width: usize,
    /// Per-lane task queues: owner pops back, thieves pop front.
    lanes: Vec<Mutex<VecDeque<usize>>>,
    shared: Mutex<Shared>,
    /// Workers sleep here between batches.
    work_cv: Condvar,
    /// The submitter sleeps here while workers drain the batch.
    idle_cv: Condvar,
    /// Tasks not yet finished in the current batch.
    remaining: AtomicUsize,
    /// Lowest-index panic payload from the current batch, if any.
    panic_slot: Mutex<Option<(usize, Box<dyn Any + Send>)>>,
    /// Serializes batches so one pool services all call sites.
    submit: Mutex<()>,
}

struct Shared {
    /// Bumped per batch; a worker joins a batch at most once.
    epoch: u64,
    batch: Option<TaskRef>,
    /// Workers currently executing tasks of the current batch.
    active: usize,
    shutdown: bool,
}

impl PoolCore {
    /// Pop one task: own lane from the back (LIFO keeps the hot tail
    /// cache-resident), then sweep other lanes from the front (FIFO
    /// steals take the coldest work).  `None` means every lane looked
    /// empty in one sweep — in-flight tasks may still be running on
    /// other lanes, but there is nothing left to claim.
    fn pop_task(&self, lane: usize) -> Option<usize> {
        if let Some(i) = self.lanes[lane].lock().unwrap().pop_back() {
            return Some(i);
        }
        for k in 1..self.width {
            let victim = (lane + k) % self.width;
            if let Some(i) = self.lanes[victim].lock().unwrap().pop_front() {
                return Some(i);
            }
        }
        None
    }

    /// Claim and run tasks until no lane has work left.
    fn run_tasks(&self, lane: usize, task: TaskRef) {
        while let Some(idx) = self.pop_task(lane) {
            let res = catch_unwind(AssertUnwindSafe(|| {
                // SAFETY: see TaskRef — the closure outlives the batch.
                unsafe { (task.call)(task.ptr, idx) }
            }));
            if let Err(payload) = res {
                let mut slot = self.panic_slot.lock().unwrap();
                match &*slot {
                    Some((prev, _)) if *prev <= idx => {}
                    _ => *slot = Some((idx, payload)),
                }
            }
            if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                // Last task done: wake the submitter.  Taking the shared
                // lock orders this notify against the submitter's
                // predicate check so the wakeup cannot be lost.
                let _sh = self.shared.lock().unwrap();
                self.idle_cv.notify_all();
            }
        }
    }
}

fn worker_loop(core: Arc<PoolCore>, lane: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let task;
        {
            let mut sh = core.shared.lock().unwrap();
            loop {
                if sh.shutdown {
                    return;
                }
                match sh.batch {
                    Some(t) if sh.epoch != seen_epoch => {
                        seen_epoch = sh.epoch;
                        sh.active += 1;
                        task = t;
                        break;
                    }
                    _ => sh = core.work_cv.wait(sh).unwrap(),
                }
            }
        }
        // Mark this thread so nested submissions from inside a task run
        // inline instead of deadlocking on the submit lock.
        let was_busy = RUNTIME_BUSY.with(|b| b.replace(true));
        core.run_tasks(lane, task);
        RUNTIME_BUSY.with(|b| b.set(was_busy));
        let mut sh = core.shared.lock().unwrap();
        sh.active -= 1;
        if sh.active == 0 {
            core.idle_cv.notify_all();
        }
    }
}

/// Owns the worker threads; dropping the last `Runtime` clone (each
/// holds an `Arc<PoolOwner>`) shuts the pool down and joins them.
struct PoolOwner {
    core: Arc<PoolCore>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Drop for PoolOwner {
    fn drop(&mut self) {
        {
            let mut sh = self.core.shared.lock().unwrap();
            sh.shutdown = true;
        }
        self.core.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle to a worker pool.  Cloning is cheap (two `Arc`s); all clones
/// share the same workers.  `Runtime::new(1)` spawns no threads and runs
/// every batch serially on the caller.
#[derive(Clone)]
pub struct Runtime {
    core: Arc<PoolCore>,
    _owner: Arc<PoolOwner>,
}

impl Runtime {
    /// Build a pool with `threads` total lanes (clamped to at least 1).
    /// Lane 0 belongs to whichever thread submits a batch, so only
    /// `threads - 1` OS threads are spawned.
    pub fn new(threads: usize) -> Runtime {
        let width = threads.max(1);
        let core = Arc::new(PoolCore {
            width,
            lanes: (0..width).map(|_| Mutex::new(VecDeque::new())).collect(),
            shared: Mutex::new(Shared { epoch: 0, batch: None, active: 0, shutdown: false }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            remaining: AtomicUsize::new(0),
            panic_slot: Mutex::new(None),
            submit: Mutex::new(()),
        });
        let mut handles = Vec::with_capacity(width.saturating_sub(1));
        for lane in 1..width {
            let c = Arc::clone(&core);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("tamio-worker-{lane}"))
                    .spawn(move || worker_loop(c, lane))
                    .expect("spawn pool worker"),
            );
        }
        let owner = Arc::new(PoolOwner { core: Arc::clone(&core), handles: Mutex::new(handles) });
        Runtime { core, _owner: owner }
    }

    /// Total lanes (submitting thread included).
    pub fn width(&self) -> usize {
        self.core.width
    }

    /// Run `f(0) .. f(n-1)`, each exactly once, with completion of all
    /// tasks guaranteed on return.  Execution order is unspecified under
    /// multiple lanes; callers must make task `i` write only to slot
    /// `i`-owned state (that is what keeps results deterministic).
    ///
    /// If any task panics, the lowest-index panic is re-raised here with
    /// `label(i)` prepended.  `label` is only invoked on that failure
    /// path, so it may allocate freely.
    pub fn for_each_index<F>(&self, n: usize, label: &dyn Fn(usize) -> String, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let nested = RUNTIME_BUSY.with(|b| b.get());
        if self.core.width <= 1 || n == 1 || nested {
            for i in 0..n {
                if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                    raise_task_panic(label, i, payload);
                }
            }
            return;
        }
        self.run_batch(n, label, &f);
    }

    fn run_batch<F>(&self, n: usize, label: &dyn Fn(usize) -> String, f: &F)
    where
        F: Fn(usize) + Sync,
    {
        let core = &*self.core;
        // Serialize batches: one batch owns the lanes at a time.
        let _submit = core.submit.lock().unwrap();
        // Mark busy AFTER acquiring submit, so concurrent submitters
        // queue up rather than degrade to serial; nested calls from our
        // own tasks (which would self-deadlock) run inline instead.
        let was_busy = RUNTIME_BUSY.with(|b| b.replace(true));
        // Round-robin indices over lanes; lane capacity is retained
        // across batches so warm submissions do not allocate.
        for (lane, q) in core.lanes.iter().enumerate() {
            let mut q = q.lock().unwrap();
            q.clear();
            let mut i = lane;
            while i < n {
                q.push_back(i);
                i += core.width;
            }
        }
        *core.panic_slot.lock().unwrap() = None;
        core.remaining.store(n, Ordering::Release);
        let task = TaskRef { ptr: f as *const F as *const (), call: call_closure::<F> };
        {
            let mut sh = core.shared.lock().unwrap();
            sh.epoch = sh.epoch.wrapping_add(1);
            sh.batch = Some(task);
            core.work_cv.notify_all();
        }
        // The submitter helps from lane 0.
        core.run_tasks(0, task);
        // Wait until every task has finished AND every worker has left
        // the batch: `active == 0` is what makes it safe to drop `f`
        // (no worker still holds the TaskRef), and clearing the batch
        // under the same lock hold means a late-waking worker can never
        // observe a stale descriptor.
        {
            let mut sh = core.shared.lock().unwrap();
            while core.remaining.load(Ordering::Acquire) != 0 || sh.active != 0 {
                sh = core.idle_cv.wait(sh).unwrap();
            }
            sh.batch = None;
        }
        RUNTIME_BUSY.with(|b| b.set(was_busy));
        let failed = core.panic_slot.lock().unwrap().take();
        if let Some((idx, payload)) = failed {
            raise_task_panic(label, idx, payload);
        }
    }

    /// Parallel in-place for-each: task `i` gets `&mut items[i]`.
    /// Items stay where they are — no draining into per-thread Vecs —
    /// so arena-resident slots keep their warm capacity.
    pub fn for_each_mut<T, F>(&self, items: &mut [T], label: &dyn Fn(usize) -> String, f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let base = SendPtr(items.as_mut_ptr());
        let n = items.len();
        self.for_each_index(n, label, move |i| {
            debug_assert!(i < n);
            // SAFETY: for_each_index hands out each index exactly once,
            // so every `&mut items[i]` is disjoint; `items` outlives the
            // batch because for_each_index does not return until all
            // tasks complete.
            let item = unsafe { &mut *base.0.add(i) };
            f(i, item);
        });
    }

    /// Fallible variant of [`for_each_mut`]: if any task errors, the
    /// lowest-index error is returned (deterministic regardless of
    /// which lane saw its error first).  Tasks that error leave their
    /// item in whatever state `f` left it.
    pub fn try_for_each_mut<T, F>(
        &self,
        items: &mut [T],
        label: &dyn Fn(usize) -> String,
        f: F,
    ) -> Result<()>
    where
        T: Send,
        F: Fn(usize, &mut T) -> Result<()> + Sync,
    {
        let first_err: Mutex<Option<(usize, Error)>> = Mutex::new(None);
        self.for_each_mut(items, label, |i, item| {
            if let Err(e) = f(i, item) {
                let mut slot = first_err.lock().unwrap();
                match &*slot {
                    Some((prev, _)) if *prev <= i => {}
                    _ => *slot = Some((i, e)),
                }
            }
        });
        match first_err.into_inner().unwrap() {
            Some((i, e)) => Err(e.with_context(label(i))),
            None => Ok(()),
        }
    }
}

/// Raw-pointer wrapper so disjoint `&mut` projections can cross the
/// closure's `Sync` bound.  Soundness argument lives at the use sites.
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

fn raise_task_panic(label: &dyn Fn(usize) -> String, idx: usize, payload: Box<dyn Any + Send>) -> ! {
    let what = label(idx);
    if let Some(msg) = payload.downcast_ref::<&str>() {
        panic!("{what}: {msg}");
    }
    if let Some(msg) = payload.downcast_ref::<String>() {
        panic!("{what}: {msg}");
    }
    eprintln!("task panicked with non-string payload: {what}");
    resume_unwind(payload)
}

thread_local! {
    /// Set while this thread is executing pool tasks (worker or helping
    /// submitter).  Nested submissions run inline-serial: re-entering
    /// the pool would deadlock on the submit lock, and the outer batch
    /// already owns all lanes anyway.
    static RUNTIME_BUSY: Cell<bool> = const { Cell::new(false) };

    /// Test hook: `with_runtime` pushes an override consulted by
    /// `current()` before the global pool, so one process can exercise
    /// several pool widths (the global pool's width is fixed at first
    /// use).
    static RUNTIME_OVERRIDE: RefCell<Vec<Runtime>> = const { RefCell::new(Vec::new()) };
}

/// Requested global pool width (0 = unset), set by `--threads` before
/// first pool use.
static REQUESTED_THREADS: AtomicUsize = AtomicUsize::new(0);

static GLOBAL: OnceLock<Runtime> = OnceLock::new();

/// Number of lanes the global pool will use (or uses, once built):
/// `--threads` > `TAMIO_THREADS` > `available_parallelism()`.
pub fn default_threads() -> usize {
    let req = REQUESTED_THREADS.load(Ordering::Acquire);
    if req > 0 {
        return req;
    }
    if let Ok(s) = std::env::var("TAMIO_THREADS") {
        match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => return n,
            _ => eprintln!("warn: ignoring invalid TAMIO_THREADS={s:?} (want integer >= 1)"),
        }
    }
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// Record the `--threads` CLI/KV/TOML choice.  Must happen before the
/// global pool is first used; afterwards the width is fixed and a
/// conflicting request is a hard error (silently running with the wrong
/// width would be the kind of silent failure PR 7 removed).
pub fn configure_global_threads(threads: usize) -> Result<()> {
    if threads == 0 {
        return Err(Error::config("--threads must be >= 1"));
    }
    if let Some(rt) = GLOBAL.get() {
        if rt.width() != threads {
            return Err(Error::config(format!(
                "--threads {threads} requested but the worker pool is already running with {} threads",
                rt.width()
            )));
        }
        return Ok(());
    }
    REQUESTED_THREADS.store(threads, Ordering::Release);
    // Settle the race where the pool initialized between the `get`
    // above and the store: the built width wins; mismatch is an error.
    if let Some(rt) = GLOBAL.get() {
        if rt.width() != threads {
            return Err(Error::config(format!(
                "--threads {threads} requested but the worker pool is already running with {} threads",
                rt.width()
            )));
        }
    }
    Ok(())
}

/// The pool serving this thread: the innermost `with_runtime` override
/// if one is active, else the lazily-built global pool.
pub fn current() -> Runtime {
    let over = RUNTIME_OVERRIDE.with(|o| o.borrow().last().cloned());
    match over {
        Some(rt) => rt,
        None => GLOBAL.get_or_init(|| Runtime::new(default_threads())).clone(),
    }
}

/// Run `f` with `rt` as this thread's pool (nestable; restored on exit,
/// including by panic).  Test hook for the determinism matrix: the
/// global pool's width is process-wide, but overrides let one test body
/// compare widths 1/2/default directly.
pub fn with_runtime<R>(rt: &Runtime, f: impl FnOnce() -> R) -> R {
    struct PopGuard;
    impl Drop for PopGuard {
        fn drop(&mut self) {
            RUNTIME_OVERRIDE.with(|o| {
                o.borrow_mut().pop();
            });
        }
    }
    RUNTIME_OVERRIDE.with(|o| o.borrow_mut().push(rt.clone()));
    let _guard = PopGuard;
    f()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn for_each_index_runs_every_task_once() {
        for width in [1, 2, 3, 8] {
            let rt = Runtime::new(width);
            let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
            rt.for_each_index(hits.len(), &|i| format!("task {i}"), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "width {width}: every index exactly once"
            );
        }
    }

    #[test]
    fn for_each_mut_slots_match_indices() {
        let rt = Runtime::new(4);
        let mut data = vec![0usize; 1000];
        rt.for_each_mut(&mut data, &|i| format!("slot {i}"), |i, v| *v = i * 3);
        assert!(data.iter().enumerate().all(|(i, &v)| v == i * 3));
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let rt = Runtime::new(3);
        let mut data = vec![0u64; 50];
        for round in 1..=20u64 {
            rt.for_each_mut(&mut data, &|i| format!("round {round} item {i}"), |_, v| *v += 1);
        }
        assert!(data.iter().all(|&v| v == 20));
    }

    #[test]
    fn nested_submission_runs_inline() {
        let rt = Runtime::new(4);
        let total = AtomicU64::new(0);
        rt.for_each_index(8, &|i| format!("outer {i}"), |_| {
            // Re-entering the pool from a task must not deadlock.
            let inner = current();
            inner.for_each_index(16, &|j| format!("inner {j}"), |_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 16);
    }

    #[test]
    fn panic_carries_task_identity() {
        let rt = Runtime::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            rt.for_each_index(64, &|i| format!("level 1, aggregator {i}, round 2"), |i| {
                if i == 37 {
                    panic!("boom");
                }
            });
        }))
        .expect_err("must propagate the task panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("aggregator 37") && msg.contains("boom"),
            "panic message must carry task identity + payload, got: {msg}"
        );
    }

    #[test]
    fn panic_reports_lowest_index() {
        let rt = Runtime::new(4);
        for _ in 0..10 {
            let err = catch_unwind(AssertUnwindSafe(|| {
                rt.for_each_index(128, &|i| format!("task {i}"), |i| {
                    if i % 3 == 1 {
                        panic!("fail {i}");
                    }
                });
            }))
            .expect_err("must propagate");
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default();
            assert!(
                msg.contains("task 1:") && msg.contains("fail 1"),
                "lowest failing index (1) must win deterministically, got: {msg}"
            );
        }
    }

    #[test]
    fn try_for_each_mut_returns_lowest_index_error() {
        let rt = Runtime::new(4);
        let mut data = vec![0u32; 100];
        let res = rt.try_for_each_mut(&mut data, &|i| format!("item {i}"), |i, _| {
            if i >= 5 && i % 5 == 0 {
                Err(Error::Protocol(format!("bad {i}")))
            } else {
                Ok(())
            }
        });
        let msg = res.expect_err("must surface the error").to_string();
        assert!(msg.contains("item 5") && msg.contains("bad 5"), "lowest error wins: {msg}");
    }

    #[test]
    fn with_runtime_overrides_and_restores() {
        let one = Runtime::new(1);
        let two = Runtime::new(2);
        with_runtime(&one, || {
            assert_eq!(current().width(), 1);
            with_runtime(&two, || assert_eq!(current().width(), 2));
            assert_eq!(current().width(), 1);
        });
    }

    #[test]
    fn width_one_spawns_no_workers_and_still_works() {
        let rt = Runtime::new(1);
        let mut data = vec![0u8; 17];
        rt.for_each_mut(&mut data, &|i| format!("x {i}"), |_, v| *v = 1);
        assert!(data.iter().all(|&v| v == 1));
    }

    #[test]
    fn dropping_runtime_joins_workers() {
        // Regression guard for shutdown: building and dropping pools in
        // a loop must neither hang nor leak threads that panic later.
        for _ in 0..8 {
            let rt = Runtime::new(3);
            let mut data = vec![0u32; 64];
            rt.for_each_mut(&mut data, &|i| format!("d {i}"), |i, v| *v = i as u32);
            drop(rt);
        }
    }
}
