//! NPB BTIO block-tridiagonal I/O pattern (§V-B).
//!
//! BTIO requires a square process count `P = q²`.  The global solution
//! array is 3-D (`N³` grid cells) with 5 doubles per cell and 40 written
//! "variables" (time steps in the benchmark); each process owns `q` cells
//! of size `(N/q)³` arranged along a block diagonal, so adjacent ranks own
//! z-adjacent cells — the pattern that coalesces extremely well under
//! intra-node aggregation (§V-B reports 335 M → 84 M requests at 16
//! nodes).
//!
//! Noncontiguous run count: per cell, one run per (x, y) line =
//! `(N/q)²` runs of `(N/q)·5·8` bytes; per rank per variable `q` cells →
//! `N²/q` runs per rank per variable; total `40·N²·q = 40·N²·√P` —
//! the paper's `512²·40·√P` formula at `N = 512`.

use crate::cluster::Topology;
use crate::error::{Error, Result};
use crate::mpisim::subarray::subarray_flatten;
use crate::mpisim::FlatView;
use crate::workloads::Workload;

/// BTIO generator.
#[derive(Clone, Debug)]
pub struct Btio {
    /// Grid points per dimension (paper: 512).
    pub n: usize,
    /// Written variables / time steps (paper: 40).
    pub vars: usize,
    /// Solution-vector components per cell (paper: 5).
    pub comps: usize,
    /// Bytes per scalar (double).
    pub elem: usize,
}

impl Btio {
    /// Paper configuration: 512³ × 40 × 5 doubles = 200 GiB.
    pub fn paper() -> Self {
        Btio { n: 512, vars: 40, comps: 5, elem: 8 }
    }

    /// Scaled-down configuration: shrinks the grid (and the variable
    /// count for large divisors) while keeping the decomposition shape.
    pub fn scaled(scale: u64) -> Self {
        // Volume scales with n³·vars; take the cube root for the grid.
        let mut cfg = Self::paper();
        let mut s = scale.max(1);
        while s >= 8 && cfg.n > 32 {
            cfg.n /= 2;
            s /= 8;
        }
        while s >= 2 && cfg.vars > 5 {
            cfg.vars /= 2;
            s /= 2;
        }
        cfg
    }

    /// Side of the process grid: `q = √P` (P must be square).
    pub fn q(&self, p: usize) -> Result<usize> {
        let q = (p as f64).sqrt().round() as usize;
        if q * q != p {
            return Err(Error::Workload(format!(
                "BTIO requires a square process count, got {p}"
            )));
        }
        Ok(q)
    }

    /// Bytes of one variable's full 3-D array.
    fn var_bytes(&self) -> u64 {
        (self.n as u64).pow(3) * (self.comps * self.elem) as u64
    }
}

impl Workload for Btio {
    fn name(&self) -> String {
        format!("btio(n={},vars={})", self.n, self.vars)
    }

    fn view(&self, topo: &Topology, rank: usize) -> Result<FlatView> {
        let p = topo.nprocs();
        let q = self.q(p)?;
        let (i, j) = (rank / q, rank % q);
        // The solution array is treated as a 3-D grid of cells; the
        // element record is the 5-component solution vector, so the
        // flattened global dims are (x, y, z·comps·elem bytes handled via
        // elem_size).  Balanced cell bounds per axis handle grids not
        // divisible by q.
        let global = [self.n, self.n, self.n];
        let elem_size = self.comps * self.elem;
        let bounds = |b: usize| crate::mpisim::subarray::balanced_bounds(self.n, q, b);
        let mut pairs: Vec<(u64, u64)> = Vec::new();
        for var in 0..self.vars {
            let base = var as u64 * self.var_bytes();
            for c in 0..q {
                // Diagonal cell placement: cell c of rank (i, j) sits at
                // x-slab c, y-block (i + c) mod q, z-block (j + c) mod q —
                // the BT multi-partition scheme.
                let (x0, x1) = bounds(c);
                let (y0, y1) = bounds((i + c) % q);
                let (z0, z1) = bounds((j + c) % q);
                let start = [x0, y0, z0];
                let sub = [x1 - x0, y1 - y0, z1 - z0];
                let v = subarray_flatten(&global, &sub, &start, elem_size, base)?;
                pairs.extend(v.iter());
            }
        }
        // Runs from successive cells within one variable ascend (x-slab
        // major), and variables ascend by base; the whole list is sorted.
        pairs.sort_unstable();
        Ok(FlatView::from_pairs_unchecked(
            pairs.iter().map(|p| p.0).collect(),
            pairs.iter().map(|p| p.1).collect(),
        ))
    }

    fn paper_scale(&self, p: usize) -> (f64, u64) {
        // 512² · 40 · √P requests; 200 GiB.
        let paper = Btio::paper();
        (
            (paper.n * paper.n * paper.vars) as f64 * (p as f64).sqrt(),
            paper.var_bytes() * paper.vars as u64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_count_matches_formula() {
        // N=64, q=4 (P=16), vars=5: per the formula vars·N²·q runs total.
        let w = Btio { n: 64, vars: 5, comps: 5, elem: 8 };
        let topo = Topology::new(4, 4);
        let views = w.generate_views(&topo).unwrap();
        let total: u64 = views.iter().map(|(_, v)| v.len() as u64).sum();
        assert_eq!(total, (5 * 64 * 64 * 4) as u64);
    }

    #[test]
    fn write_amount_matches_grid_volume() {
        let w = Btio { n: 32, vars: 4, comps: 5, elem: 8 };
        let topo = Topology::new(1, 4);
        let views = w.generate_views(&topo).unwrap();
        let bytes: u64 = views.iter().map(|(_, v)| v.total_bytes()).sum();
        assert_eq!(bytes, 4 * 32u64.pow(3) * 40);
    }

    #[test]
    fn cells_tile_the_grid_exactly() {
        // Every byte of every variable written exactly once.
        let w = Btio { n: 16, vars: 1, comps: 1, elem: 1 };
        let topo = Topology::new(1, 16); // q = 4
        let views = w.generate_views(&topo).unwrap();
        let mut coverage = vec![0u32; 16 * 16 * 16];
        for (_, v) in &views {
            for (off, len) in v.iter() {
                for b in off..off + len {
                    coverage[b as usize] += 1;
                }
            }
        }
        assert!(coverage.iter().all(|&c| c == 1), "grid not tiled exactly once");
    }

    #[test]
    fn rejects_non_square_process_count() {
        let w = Btio::scaled(512);
        let topo = Topology::new(2, 4);
        assert!(w.view(&topo, 0).is_err());
    }

    #[test]
    fn scaled_shrinks_volume() {
        let paper = Btio::paper();
        let small = Btio::scaled(4096);
        assert!(small.n < paper.n);
        let paper_vol = paper.var_bytes() * paper.vars as u64;
        let small_vol = small.var_bytes() * small.vars as u64;
        assert!(small_vol < paper_vol / 100);
    }

    #[test]
    fn paper_formula_at_16384() {
        // §V-B: 1,342,177,280 requests at 256 nodes × 64 ppn.
        let w = Btio::paper();
        let (reqs, bytes) = w.paper_scale(16384);
        assert_eq!(reqs, 512.0 * 512.0 * 40.0 * 128.0);
        assert_eq!(reqs as u64, 1_342_177_280);
        assert_eq!(bytes, 200 * (1 << 30));
    }
}
