//! E3SM F/G-case I/O pattern generator.
//!
//! The production decompositions (cubed-sphere atmosphere for F, MPAS
//! ocean grid for G) assign each MPI process a long list of small
//! noncontiguous records scattered across the shared file; per-rank
//! request counts are nearly uniform (Table I notes the variation is
//! small).  The generator reproduces that statistical shape:
//!
//! * the file is a sequence of fixed-size records;
//! * record ownership is pseudo-randomly interleaved across ranks (a hash
//!   of the record index), so adjacent records rarely share an owner —
//!   little intra-rank contiguity, exactly the pattern that makes the
//!   two-phase communication phase dominate (§V-A);
//! * per-rank offsets are naturally ascending.
//!
//! Paper-scale parameters (Table I): F — 1.36 G requests / 14 GiB;
//! G — 180 M requests / 85 GiB.  A `scale` divisor shrinks the record
//! count for simulation runs while preserving the record size and
//! interleaving statistics.

use crate::cluster::Topology;
use crate::error::Result;
use crate::mpisim::FlatView;
use crate::workloads::Workload;

/// E3SM-like decomposition generator.
#[derive(Clone, Debug)]
pub struct E3sm {
    /// Case label ("F" or "G").
    pub case: &'static str,
    /// Paper-scale total request count.
    pub paper_requests: f64,
    /// Paper-scale write amount (bytes).
    pub paper_bytes: u64,
    /// Scale divisor applied to the record count.
    pub scale: u64,
}

impl E3sm {
    /// G case: 180 M noncontiguous requests, 85 GiB.
    pub fn g_case(scale: u64) -> Self {
        E3sm {
            case: "G",
            paper_requests: 1.74e8,
            paper_bytes: 85 * (1 << 30),
            scale: scale.max(1),
        }
    }

    /// F case: 1.36 G noncontiguous requests, 14 GiB.
    pub fn f_case(scale: u64) -> Self {
        E3sm {
            case: "F",
            paper_requests: 1.36e9,
            paper_bytes: 14 * (1 << 30),
            scale: scale.max(1),
        }
    }

    /// Record payload size (paper bytes / paper requests): ~524 B for G,
    /// ~11 B for F — the F case's tiny-request flood is the point.
    pub fn record_size(&self) -> u64 {
        ((self.paper_bytes as f64 / self.paper_requests).round() as u64).max(1)
    }

    /// Total records at this scale.
    pub fn n_records(&self) -> u64 {
        ((self.paper_requests / self.scale as f64).round() as u64).max(1)
    }

    /// Owner of record `i` among `p` ranks: a splitmix-style hash, so
    /// ownership interleaves pseudo-randomly but deterministically.
    fn owner(i: u64, p: u64) -> u64 {
        let mut z = i.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        (z ^ (z >> 31)) % p
    }
}

impl Workload for E3sm {
    fn name(&self) -> String {
        format!("e3sm-{}(1/{})", self.case.to_lowercase(), self.scale)
    }

    fn view(&self, topo: &Topology, rank: usize) -> Result<FlatView> {
        let p = topo.nprocs() as u64;
        let n = self.n_records();
        let rec = self.record_size();
        let mut offsets = Vec::new();
        let mut lengths = Vec::new();
        for i in 0..n {
            if Self::owner(i, p) == rank as u64 {
                offsets.push(i * rec);
                lengths.push(rec);
            }
        }
        Ok(FlatView::from_pairs_unchecked(offsets, lengths))
    }

    // One O(n_records) pass distributing records to all ranks — the
    // per-rank `view` is O(n_records) each, quadratic over a whole
    // cluster at paper process counts.
    fn generate_views(&self, topo: &Topology) -> Result<Vec<(usize, FlatView)>> {
        let p = topo.nprocs() as u64;
        let n = self.n_records();
        let rec = self.record_size();
        let mut offsets: Vec<Vec<u64>> = vec![Vec::new(); p as usize];
        for i in 0..n {
            offsets[Self::owner(i, p) as usize].push(i * rec);
        }
        Ok(offsets
            .into_iter()
            .enumerate()
            .map(|(r, offs)| {
                let lens = vec![rec; offs.len()];
                (r, FlatView::from_pairs_unchecked(offs, lens))
            })
            .collect())
    }

    fn paper_scale(&self, _p: usize) -> (f64, u64) {
        (self.paper_requests, self.paper_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_sizes_match_paper_ratio() {
        // G: 85 GiB / 174 M ≈ 524 B; F: 14 GiB / 1.36 G ≈ 11 B.
        assert_eq!(E3sm::g_case(1).record_size(), 525);
        assert_eq!(E3sm::f_case(1).record_size(), 11);
    }

    #[test]
    fn all_records_covered_exactly_once() {
        let w = E3sm::g_case(100_000);
        let topo = Topology::new(2, 4);
        let views = w.generate_views(&topo).unwrap();
        let total: u64 = views.iter().map(|(_, v)| v.len() as u64).sum();
        assert_eq!(total, w.n_records());
        // Disjoint coverage: total bytes == records × record size.
        let bytes: u64 = views.iter().map(|(_, v)| v.total_bytes()).sum();
        assert_eq!(bytes, w.n_records() * w.record_size());
    }

    #[test]
    fn per_rank_counts_nearly_uniform() {
        let w = E3sm::f_case(100_000);
        let topo = Topology::new(4, 4);
        let views = w.generate_views(&topo).unwrap();
        let counts: Vec<u64> = views.iter().map(|(_, v)| v.len() as u64).collect();
        let avg = counts.iter().sum::<u64>() as f64 / counts.len() as f64;
        for c in counts {
            assert!((c as f64 - avg).abs() < avg * 0.25, "count {c} vs avg {avg}");
        }
    }

    #[test]
    fn interleaving_defeats_intra_rank_contiguity() {
        let w = E3sm::g_case(200_000);
        let topo = Topology::new(2, 4);
        let v = w.view(&topo, 0).unwrap();
        let mut coalesced = v.clone();
        coalesced.coalesce();
        // Pseudo-random ownership: almost nothing merges within one rank.
        assert!(coalesced.len() as f64 > v.len() as f64 * 0.7);
    }

    #[test]
    fn deterministic_across_calls() {
        let w = E3sm::g_case(500_000);
        let topo = Topology::new(1, 8);
        assert_eq!(w.view(&topo, 3).unwrap(), w.view(&topo, 3).unwrap());
    }
}
