//! I/O-pattern generators for the paper's evaluation workloads (Table I).
//!
//! Each generator reproduces the *access-pattern structure* of its
//! benchmark — request counts, sizes, per-rank ordering, cross-rank
//! adjacency — at a configurable scale (the paper's full datasets are up
//! to 200 GiB / 1.4 G requests; see DESIGN.md §Substitutions):
//!
//! * [`e3sm`] — E3SM F and G production decompositions: very long lists
//!   of small noncontiguous requests, interleaved across ranks.
//! * [`btio`] — NPB BTIO block-tridiagonal 3D decomposition
//!   (`512² · 40 · √P` noncontiguous requests at paper scale).
//! * [`s3d`] — S3D-IO checkpoint: block-block-block 3D partitioning,
//!   four variables (mass 11, velocity 3, pressure 1, temperature 1).
//! * [`synthetic`] — contiguous/strided micro-patterns for tests.

pub mod btio;
pub mod e3sm;
pub mod s3d;
pub mod synthetic;

use crate::cluster::Topology;
use crate::coordinator::merge::ReqBatch;
use crate::error::Result;
use crate::mpisim::rank::deterministic_payload;
use crate::mpisim::FlatView;
use crate::util::par_map;

/// Table I row: dataset statistics.
#[derive(Clone, Debug)]
pub struct TableStats {
    /// Workload name.
    pub name: String,
    /// Total noncontiguous requests across all ranks (this run's scale).
    pub n_requests: u64,
    /// Total write amount in bytes (this run's scale).
    pub write_bytes: u64,
    /// Paper-scale request count (analytic, for the Table I comparison).
    pub paper_requests: f64,
    /// Paper-scale write amount in bytes.
    pub paper_bytes: u64,
}

/// A workload generates one flattened file view per rank.
pub trait Workload: Send + Sync {
    /// Human-readable name.
    fn name(&self) -> String;

    /// The flattened view of `rank` under `topo`.
    fn view(&self, topo: &Topology, rank: usize) -> Result<FlatView>;

    /// Paper-scale analytic statistics for Table I (requests, bytes).
    fn paper_scale(&self, p: usize) -> (f64, u64);

    /// Generate all ranks' views with deterministic payloads.
    fn generate(&self, topo: &Topology, seed: u64) -> Result<Vec<(usize, ReqBatch)>> {
        let views = self.generate_views(topo)?;
        Ok(views
            .into_iter()
            .map(|(r, view)| {
                let payload = deterministic_payload(seed, r, view.total_bytes());
                (r, ReqBatch::new(view, payload))
            })
            .collect())
    }

    /// Generate views only (read path, stats).
    fn generate_views(&self, topo: &Topology) -> Result<Vec<(usize, FlatView)>> {
        let views = par_map((0..topo.nprocs()).collect::<Vec<_>>(), |r| {
            self.view(topo, r).map(|v| (r, v))
        });
        views.into_iter().collect()
    }

    /// Table I statistics at this run's scale + paper scale.
    fn table_stats(&self, topo: &Topology) -> Result<TableStats> {
        let views = self.generate_views(topo)?;
        let n_requests = views.iter().map(|(_, v)| v.len() as u64).sum();
        let write_bytes = views.iter().map(|(_, v)| v.total_bytes()).sum();
        let (paper_requests, paper_bytes) = self.paper_scale(topo.nprocs());
        Ok(TableStats {
            name: self.name(),
            n_requests,
            write_bytes,
            paper_requests,
            paper_bytes,
        })
    }
}

/// Workload selector for configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// E3SM G case (ocean/sea-ice; 180 M requests, 85 GiB at paper scale).
    E3smG,
    /// E3SM F case (atmosphere; 1.36 G requests, 14 GiB at paper scale).
    E3smF,
    /// NPB BTIO block-tridiagonal.
    Btio,
    /// S3D-IO checkpoint.
    S3d,
    /// Synthetic contiguous blocks.
    Contig,
    /// Synthetic strided interleave.
    Strided,
}

impl std::str::FromStr for WorkloadKind {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "e3sm-g" | "e3sm_g" => Ok(WorkloadKind::E3smG),
            "e3sm-f" | "e3sm_f" => Ok(WorkloadKind::E3smF),
            "btio" => Ok(WorkloadKind::Btio),
            "s3d" | "s3d-io" => Ok(WorkloadKind::S3d),
            "contig" => Ok(WorkloadKind::Contig),
            "strided" => Ok(WorkloadKind::Strided),
            other => Err(crate::Error::config(format!(
                "unknown workload '{other}' (e3sm-g|e3sm-f|btio|s3d|contig|strided)"
            ))),
        }
    }
}

impl WorkloadKind {
    /// Instantiate the workload at a scale divisor (1 = paper scale;
    /// `scale` shrinks request counts and byte volumes ~linearly).
    pub fn build(self, scale: u64) -> Box<dyn Workload> {
        match self {
            WorkloadKind::E3smG => Box::new(e3sm::E3sm::g_case(scale)),
            WorkloadKind::E3smF => Box::new(e3sm::E3sm::f_case(scale)),
            WorkloadKind::Btio => Box::new(btio::Btio::scaled(scale)),
            WorkloadKind::S3d => Box::new(s3d::S3dIo::scaled(scale)),
            WorkloadKind::Contig => Box::new(synthetic::Contig::new(1 << 20)),
            WorkloadKind::Strided => Box::new(synthetic::Strided::new(1 << 16, 64)),
        }
    }

    /// All paper workloads (Figure 3 order).
    pub fn paper_set() -> [WorkloadKind; 4] {
        [WorkloadKind::E3smG, WorkloadKind::E3smF, WorkloadKind::Btio, WorkloadKind::S3d]
    }
}

impl std::fmt::Display for WorkloadKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            WorkloadKind::E3smG => "e3sm-g",
            WorkloadKind::E3smF => "e3sm-f",
            WorkloadKind::Btio => "btio",
            WorkloadKind::S3d => "s3d",
            WorkloadKind::Contig => "contig",
            WorkloadKind::Strided => "strided",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing_round_trips() {
        for k in [
            WorkloadKind::E3smG,
            WorkloadKind::E3smF,
            WorkloadKind::Btio,
            WorkloadKind::S3d,
            WorkloadKind::Contig,
            WorkloadKind::Strided,
        ] {
            let s = k.to_string();
            assert_eq!(s.parse::<WorkloadKind>().unwrap(), k);
        }
        assert!("nope".parse::<WorkloadKind>().is_err());
    }

    #[test]
    fn every_workload_generates_valid_views() {
        let topo = Topology::new(2, 8);
        for k in [
            WorkloadKind::E3smG,
            WorkloadKind::E3smF,
            WorkloadKind::Btio,
            WorkloadKind::S3d,
            WorkloadKind::Contig,
            WorkloadKind::Strided,
        ] {
            let w = k.build(4096);
            let views = w.generate_views(&topo).unwrap();
            assert_eq!(views.len(), 16);
            for (r, v) in views {
                v.validate().unwrap_or_else(|e| panic!("{k} rank {r}: {e}"));
                assert!(!v.is_empty(), "{k} rank {r} generated empty view");
            }
        }
    }

    #[test]
    fn payloads_match_views() {
        let topo = Topology::new(1, 4);
        let w = WorkloadKind::Strided.build(1);
        let ranks = w.generate(&topo, 3).unwrap();
        for (_, b) in ranks {
            assert_eq!(b.payload.len() as u64, b.view.total_bytes());
        }
    }
}
