//! S3D-IO checkpoint I/O pattern (§V-C).
//!
//! S3D checkpoints four variables over a 3-D Cartesian mesh partitioned
//! block-block-block: pressure and temperature are 3-D arrays, mass and
//! velocity are 4-D with component counts 11 and 3.  Every variable
//! component is a full 3-D array in the file, written by each rank as a
//! subarray — so each rank contributes `ny_l · nz_l` noncontiguous runs
//! per component, 16 components total.  Paper scale: 800³ grid, 61 GiB.
//!
//! Block partitioning puts x-adjacent ranks on contiguous file ranges, so
//! intra-node aggregation coalesces most requests (the paper's
//! `(1/2)^(P/P_L)` reduction bound).

use crate::cluster::Topology;
use crate::error::{Error, Result};
use crate::mpisim::subarray::subarray_flatten;
use crate::mpisim::FlatView;
use crate::workloads::Workload;

/// S3D-IO generator.
#[derive(Clone, Debug)]
pub struct S3dIo {
    /// Grid points per dimension (paper: 800).
    pub n: usize,
    /// Bytes per scalar (double).
    pub elem: usize,
}

impl S3dIo {
    /// Paper configuration: 800³ × 16 components × 8 B = 61 GiB.
    pub fn paper() -> Self {
        S3dIo { n: 800, elem: 8 }
    }

    /// Scaled-down grid preserving the decomposition shape.
    pub fn scaled(scale: u64) -> Self {
        let mut cfg = Self::paper();
        let mut s = scale.max(1);
        while s >= 8 && cfg.n > 40 {
            cfg.n /= 2;
            s /= 8;
        }
        cfg
    }

    /// Component count: mass 11 + velocity 3 + pressure 1 + temperature 1.
    pub const COMPONENTS: usize = 16;

    /// Near-cubic factorization of `p` into `(px, py, pz)` with
    /// `px·py·pz == p` (px ≥ py ≥ pz as balanced as possible).
    pub fn factorize(p: usize) -> (usize, usize, usize) {
        let mut best = (p, 1, 1);
        let mut best_score = usize::MAX;
        let mut x = 1;
        while x * x * x <= p {
            if p % x == 0 {
                let rem = p / x;
                let mut y = x;
                while y * y <= rem {
                    if rem % y == 0 {
                        let z = rem / y;
                        let score = z - x; // spread: smaller is more cubic
                        if score < best_score {
                            best_score = score;
                            best = (z, y, x);
                        }
                    }
                    y += 1;
                }
            }
            x += 1;
        }
        best
    }

    fn comp_bytes(&self) -> u64 {
        (self.n as u64).pow(3) * self.elem as u64
    }
}

impl Workload for S3dIo {
    fn name(&self) -> String {
        format!("s3d-io(n={})", self.n)
    }

    fn view(&self, topo: &Topology, rank: usize) -> Result<FlatView> {
        let p = topo.nprocs();
        let (px, py, pz) = Self::factorize(p);
        if self.n < px || self.n < py || self.n < pz {
            return Err(Error::Workload(format!(
                "S3D grid {} smaller than process grid {px}x{py}x{pz}",
                self.n
            )));
        }
        // Rank → (ix, iy, iz) block coordinates, x-major (x fastest in
        // rank order so x-adjacent ranks are rank-adjacent — the S3D
        // MPI_Cart_create layout that makes intra-node coalescing work).
        let ix = rank % px;
        let iy = (rank / px) % py;
        let iz = rank / (px * py);
        // File layout per component: C-order global dims (z, y, x), x
        // innermost/contiguous.  Balanced block bounds per axis (MPI_Cart
        // convention) so any grid/process combination decomposes.
        let global = [self.n, self.n, self.n];
        let (z0, z1) = crate::mpisim::subarray::balanced_bounds(self.n, pz, iz);
        let (y0, y1) = crate::mpisim::subarray::balanced_bounds(self.n, py, iy);
        let (x0, x1) = crate::mpisim::subarray::balanced_bounds(self.n, px, ix);
        let sub = [z1 - z0, y1 - y0, x1 - x0];
        let start = [z0, y0, x0];
        let mut pairs: Vec<(u64, u64)> = Vec::new();
        for comp in 0..Self::COMPONENTS {
            let base = comp as u64 * self.comp_bytes();
            let v = subarray_flatten(&global, &sub, &start, self.elem, base)?;
            pairs.extend(v.iter());
        }
        Ok(FlatView::from_pairs_unchecked(
            pairs.iter().map(|p| p.0).collect(),
            pairs.iter().map(|p| p.1).collect(),
        ))
    }

    fn paper_scale(&self, p: usize) -> (f64, u64) {
        // Requests: 16 comps · P · ny_l · nz_l = 16 · n² · px; paper
        // quotes the py·pz form for its Fortran layout — same structure.
        let paper = Self::paper();
        let (px, _, _) = Self::factorize(p);
        (
            (Self::COMPONENTS as f64) * (paper.n as f64).powi(2) * px as f64,
            paper.comp_bytes() * Self::COMPONENTS as u64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factorize_balanced() {
        assert_eq!(S3dIo::factorize(8), (2, 2, 2));
        assert_eq!(S3dIo::factorize(64), (4, 4, 4));
        let (x, y, z) = S3dIo::factorize(16);
        assert_eq!(x * y * z, 16);
        assert!(x >= y && y >= z);
        let (x, y, z) = S3dIo::factorize(7); // prime
        assert_eq!((x, y, z), (7, 1, 1));
    }

    #[test]
    fn request_count_matches_formula() {
        let w = S3dIo { n: 40, elem: 8 };
        let topo = Topology::new(2, 4); // P=8 → 2x2x2
        let views = w.generate_views(&topo).unwrap();
        let total: u64 = views.iter().map(|(_, v)| v.len() as u64).sum();
        // per rank per comp: (40/2)·(40/2) = 400 runs; ×16 comps ×8 ranks.
        assert_eq!(total, 400 * 16 * 8);
    }

    #[test]
    fn write_amount_is_61gib_shape() {
        let w = S3dIo::paper();
        let (_, bytes) = w.paper_scale(16384);
        // 8 × 16 × 800³ = 65,536,000,000 B ≈ 61 GiB (paper Table I).
        assert_eq!(bytes, 8 * 16 * 800u64.pow(3));
        assert!((bytes as f64 / (1u64 << 30) as f64 - 61.0).abs() < 0.5);
    }

    #[test]
    fn components_tile_each_array_exactly() {
        let w = S3dIo { n: 16, elem: 1 };
        let topo = Topology::new(1, 8);
        let views = w.generate_views(&topo).unwrap();
        let comp_bytes = 16u64.pow(3);
        let mut coverage = vec![0u32; (comp_bytes * 16) as usize];
        for (_, v) in &views {
            for (off, len) in v.iter() {
                for b in off..off + len {
                    coverage[b as usize] += 1;
                }
            }
        }
        assert!(coverage.iter().all(|&c| c == 1));
    }

    #[test]
    fn x_adjacent_ranks_are_file_adjacent() {
        // Rank 0 and rank 1 (x-neighbours) own contiguous x-runs: rank 1's
        // first run starts exactly where rank 0's first run ends.
        let w = S3dIo { n: 16, elem: 8 };
        let topo = Topology::new(1, 8);
        let v0 = w.view(&topo, 0).unwrap();
        let v1 = w.view(&topo, 1).unwrap();
        let (o0, l0) = v0.iter().next().unwrap();
        let (o1, _) = v1.iter().next().unwrap();
        assert_eq!(o0 + l0, o1);
    }
}
