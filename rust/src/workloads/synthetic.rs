//! Synthetic micro-patterns for tests, examples and ablations.

use crate::cluster::Topology;
use crate::error::Result;
use crate::mpisim::FlatView;
use crate::workloads::Workload;

/// Each rank writes one contiguous block: `[r·block, (r+1)·block)`.
#[derive(Clone, Copy, Debug)]
pub struct Contig {
    /// Block bytes per rank.
    pub block: u64,
}

impl Contig {
    /// New contiguous workload.
    pub fn new(block: u64) -> Self {
        Contig { block }
    }
}

impl Workload for Contig {
    fn name(&self) -> String {
        format!("contig(block={})", self.block)
    }

    fn view(&self, _topo: &Topology, rank: usize) -> Result<FlatView> {
        FlatView::from_pairs(vec![(rank as u64 * self.block, self.block)])
    }

    fn paper_scale(&self, p: usize) -> (f64, u64) {
        (p as f64, p as u64 * self.block)
    }
}

/// Classic strided interleave: the file is a sequence of `P`-wide element
/// groups; rank `r` owns element `r` of every group.  The canonical
/// "every rank noncontiguous, globally dense" pattern: after aggregation
/// the whole file is contiguous.
#[derive(Clone, Copy, Debug)]
pub struct Strided {
    /// Number of groups (requests per rank).
    pub groups: u64,
    /// Element bytes.
    pub elem: u64,
}

impl Strided {
    /// New strided workload.
    pub fn new(groups: u64, elem: u64) -> Self {
        Strided { groups, elem }
    }
}

impl Workload for Strided {
    fn name(&self) -> String {
        format!("strided(groups={},elem={})", self.groups, self.elem)
    }

    fn view(&self, topo: &Topology, rank: usize) -> Result<FlatView> {
        let p = topo.nprocs() as u64;
        let stride = p * self.elem;
        let pairs = (0..self.groups)
            .map(|g| (g * stride + rank as u64 * self.elem, self.elem))
            .collect();
        FlatView::from_pairs(pairs)
    }

    fn paper_scale(&self, p: usize) -> (f64, u64) {
        (
            p as f64 * self.groups as f64,
            p as u64 * self.groups * self.elem,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contig_blocks_disjoint_and_ordered() {
        let topo = Topology::new(1, 4);
        let w = Contig::new(100);
        for r in 0..4 {
            let v = w.view(&topo, r).unwrap();
            assert_eq!(v.iter().collect::<Vec<_>>(), vec![(r as u64 * 100, 100)]);
        }
    }

    #[test]
    fn strided_tiles_file_densely() {
        let topo = Topology::new(1, 4);
        let w = Strided::new(8, 16);
        let views = w.generate_views(&topo).unwrap();
        let total: u64 = views.iter().map(|(_, v)| v.total_bytes()).sum();
        assert_eq!(total, 4 * 8 * 16);
        // Union of all views covers [0, total) with no gaps: merge check.
        let refs: Vec<&FlatView> = views.iter().map(|(_, v)| v).collect();
        let merged = crate::coordinator::merge::merge_views(&refs);
        assert_eq!(merged.iter().collect::<Vec<_>>(), vec![(0, total)]);
    }

    #[test]
    fn strided_request_count() {
        let topo = Topology::new(2, 2);
        let w = Strided::new(5, 8);
        let v = w.view(&topo, 3).unwrap();
        assert_eq!(v.len(), 5);
        assert_eq!(v.min_offset(), Some(3 * 8));
    }
}
