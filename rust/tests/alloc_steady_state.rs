//! Counting-allocator guard for the arena-backed round loop (§Perf
//! tentpole): after a warm-up round, steady-state exchange rounds must
//! perform (near-)zero heap allocations, and a warm `ExchangeArena` must
//! make a repeat collective strictly cheaper than its cold run — the
//! property that makes the paper's 16384-rank sweep point tractable.
//!
//! The whole file is ONE `#[test]` on purpose: the global allocator's
//! counter is process-wide, and concurrent sibling tests would pollute
//! the deltas.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tamio::cluster::Topology;
use tamio::coordinator::breakdown::CpuModel;
use tamio::coordinator::collective::{
    run_collective_read_with, run_collective_write_with, Algorithm, Direction, ExchangeArena,
    OverlapMode, ReplySlab,
};
use tamio::coordinator::filedomain::FileDomains;
use tamio::coordinator::merge::{gather_slices_from_buf, ReqBatch, RoundScratch};
use tamio::coordinator::placement::GlobalPlacement;
use tamio::coordinator::plancache::{build_collective_plan, fingerprint_collective, PlanCache};
use tamio::coordinator::reqcalc::{calc_my_req, MyReqs};
use tamio::coordinator::twophase::CollectiveCtx;
use tamio::lustre::{IoModel, LustreConfig, LustreFile};
use tamio::mpisim::rank::deterministic_payload;
use tamio::mpisim::FlatView;
use tamio::netmodel::phase::{Message, PendingQueue};
use tamio::netmodel::NetParams;
use tamio::runtime::engine::NativeEngine;
use tamio::util::runtime::Runtime;

/// Allocation-counting wrapper over the system allocator.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Single-threaded replica of the `run_exchange` round loop's staging +
/// costing + merge/scatter core (no `par_map` threads, whose spawn-time
/// allocations are outside the arena's contract and would drown the
/// signal).  Uniform per-round work so round 0 sizes every buffer.
fn steady_state_rounds_allocate_nothing() {
    const N_AGG: usize = 4;
    const STRIPE: u64 = 64;
    const RANKS: usize = 8;
    const BLOCK: u64 = 4096; // per rank, contiguous ⇒ 16 uniform rounds each
    let topo = Topology::new(1, RANKS);
    let net = NetParams::default();
    let engine = NativeEngine;
    let domains = FileDomains::new(
        LustreConfig::new(STRIPE, N_AGG),
        0,
        RANKS as u64 * BLOCK,
        N_AGG,
    );
    let n_rounds = domains.n_rounds();
    assert!(n_rounds >= 16, "need enough rounds to measure, got {n_rounds}");

    let my_reqs: Vec<MyReqs> = (0..RANKS)
        .map(|r| {
            let view = FlatView::from_pairs(vec![(r as u64 * BLOCK, BLOCK)]).unwrap();
            let payload = deterministic_payload(7, r, BLOCK);
            calc_my_req(&domains, &ReqBatch::new(view, payload)).unwrap()
        })
        .collect();

    let mut scratch: Vec<RoundScratch> = (0..N_AGG).map(|_| RoundScratch::default()).collect();
    for slot in &mut scratch {
        slot.reset_exchange(0);
    }
    let mut pending = PendingQueue::new();
    let mut data_msgs: Vec<Message> = Vec::new();

    const WARMUP: u64 = 2;
    let mut base = 0u64;
    for round in 0..n_rounds {
        if round == WARMUP {
            base = allocs();
        }
        data_msgs.clear();
        for slot in &mut scratch {
            slot.reset_round();
        }
        for (i, mr) in my_reqs.iter().enumerate() {
            for (agg, s) in mr.slices_in_round(round) {
                data_msgs.push(Message::new(i, agg, s.bytes));
                scratch[agg].stage(i, s.offsets, s.lengths, s.payload, s.bytes);
            }
        }
        pending.cost_round(&net, &topo, &data_msgs);
        for slot in &mut scratch {
            slot.merge_scatter(&engine).unwrap();
        }
    }
    let steady = allocs() - base;
    let measured_rounds = n_rounds - WARMUP;
    // The threshold exists so the arena cannot silently regress: a return
    // to per-batch staging would cost ~3 allocations per peer stream per
    // round (hundreds here).  Zero is the expectation; a tiny slack
    // absorbs allocator-internal noise.
    assert!(
        steady <= 8,
        "steady-state rounds allocated {steady} times over {measured_rounds} rounds \
         (expected ~0: the arena regressed)"
    );
}

/// The same staging + merge/scatter core, but with the per-round
/// merge_scatter fan-out running on a live worker pool (the §Perf
/// tentpole's production shape, see `run_exchange`): after the pool and
/// the arena are warm, pooled rounds must stay (near-)allocation-free.
/// The batch descriptor lives on the submitter's stack, lane queues keep
/// their capacity, and failure labels are rendered lazily — so a warm
/// batch submission itself costs zero heap traffic.
fn warm_pool_rounds_allocate_nothing() {
    const N_AGG: usize = 4;
    const STRIPE: u64 = 64;
    const RANKS: usize = 8;
    const BLOCK: u64 = 4096;
    let topo = Topology::new(1, RANKS);
    let net = NetParams::default();
    let engine = NativeEngine;
    let domains = FileDomains::new(
        LustreConfig::new(STRIPE, N_AGG),
        0,
        RANKS as u64 * BLOCK,
        N_AGG,
    );
    let n_rounds = domains.n_rounds();
    assert!(n_rounds >= 16, "need enough rounds to measure, got {n_rounds}");

    let my_reqs: Vec<MyReqs> = (0..RANKS)
        .map(|r| {
            let view = FlatView::from_pairs(vec![(r as u64 * BLOCK, BLOCK)]).unwrap();
            let payload = deterministic_payload(11, r, BLOCK);
            calc_my_req(&domains, &ReqBatch::new(view, payload)).unwrap()
        })
        .collect();

    // Pool construction (thread spawn, lane queues) happens before the
    // measured region; warm-up rounds then size the lane capacities.
    let rt = Runtime::new(2);
    let mut scratch: Vec<RoundScratch> = (0..N_AGG).map(|_| RoundScratch::default()).collect();
    for slot in &mut scratch {
        slot.reset_exchange(0);
    }
    let mut pending = PendingQueue::new();
    let mut data_msgs: Vec<Message> = Vec::new();

    const WARMUP: u64 = 2;
    let mut base = 0u64;
    for round in 0..n_rounds {
        if round == WARMUP {
            base = allocs();
        }
        data_msgs.clear();
        for slot in &mut scratch {
            slot.reset_round();
        }
        for (i, mr) in my_reqs.iter().enumerate() {
            for (agg, s) in mr.slices_in_round(round) {
                data_msgs.push(Message::new(i, agg, s.bytes));
                scratch[agg].stage(i, s.offsets, s.lengths, s.payload, s.bytes);
            }
        }
        pending.cost_round(&net, &topo, &data_msgs);
        rt.try_for_each_mut(
            &mut scratch,
            &|agg| format!("warm-pool round {round}, aggregator {agg}"),
            |_, slot| {
                slot.merge_scatter(&engine)?;
                Ok(())
            },
        )
        .unwrap();
    }
    let steady = allocs() - base;
    let measured_rounds = n_rounds - WARMUP;
    assert!(
        steady <= 8,
        "warm pooled rounds allocated {steady} times over {measured_rounds} rounds \
         (expected ~0: batch submission or the arena regressed)"
    );
}

/// Single-threaded replica of the read direction's staging + merge +
/// vectored read + reply assembly, with replies pooled in a [`ReplySlab`]
/// (the satellite pin: the slab replaces the per-requester reply `Vec`s —
/// the last per-exchange allocation that scaled with `P`).  Two complete
/// read "exchanges" run through the same warm state; the second —
/// *including* its `ReplySlab::reset` and every per-round assembly — must
/// allocate (near-)zero.
fn steady_state_read_exchanges_allocate_nothing() {
    const N_AGG: usize = 4;
    const STRIPE: u64 = 64;
    const RANKS: usize = 8;
    const BLOCK: u64 = 2048; // per rank, contiguous ⇒ uniform rounds
    let topo = Topology::new(1, RANKS);
    let net = NetParams::default();
    let engine = NativeEngine;
    let lustre = LustreConfig::new(STRIPE, N_AGG);
    let domains = FileDomains::new(lustre, 0, RANKS as u64 * BLOCK, N_AGG);
    let n_rounds = domains.n_rounds();
    assert!(n_rounds >= 8, "need enough rounds to measure, got {n_rounds}");

    // Pre-populate the file image (outside the measured region).
    let mut file = LustreFile::new(lustre);
    file.begin_round();
    let views: Vec<FlatView> = (0..RANKS)
        .map(|r| FlatView::from_pairs(vec![(r as u64 * BLOCK, BLOCK)]).unwrap())
        .collect();
    for (r, view) in views.iter().enumerate() {
        file.write_view(r, view, &deterministic_payload(5, r, BLOCK)).unwrap();
    }
    let file = file; // reads only from here on

    let my_reqs: Vec<MyReqs> = views
        .iter()
        .map(|v| calc_my_req(&domains, &ReqBatch::new(v.clone(), Vec::new())).unwrap())
        .collect();

    let mut scratch: Vec<RoundScratch> =
        (0..N_AGG).map(|_| RoundScratch::default()).collect();
    let mut pending = PendingQueue::new();
    let mut data_msgs: Vec<Message> = Vec::new();
    let mut reply = ReplySlab::default();

    let mut run_exchange_replica = || {
        pending.reset();
        reply.reset(views.iter().map(|v| v.total_bytes() as usize));
        for slot in scratch.iter_mut() {
            slot.reset_exchange(N_AGG);
        }
        for round in 0..n_rounds {
            data_msgs.clear();
            for slot in scratch.iter_mut() {
                slot.reset_round();
            }
            for (i, mr) in my_reqs.iter().enumerate() {
                for (agg, s) in mr.slices_in_round(round) {
                    data_msgs.push(Message::new(agg, i, s.bytes));
                    scratch[agg].stage(i, s.offsets, s.lengths, s.payload, s.bytes);
                }
            }
            pending.cost_round(&net, &topo, &data_msgs);
            for slot in scratch.iter_mut() {
                slot.merge_meta(&engine).unwrap();
                if !slot.merged.is_empty() {
                    file.read_view(&slot.merged, &mut slot.payload, &mut slot.stats).unwrap();
                }
                for s in 0..slot.k {
                    let i = slot.owners[s];
                    let (vo, vl) = slot.stream(s);
                    let n = slot.stream_bytes(s);
                    gather_slices_from_buf(
                        &slot.merged,
                        &slot.payload,
                        vo,
                        vl,
                        reply.append_slot(i, n),
                    );
                }
            }
        }
        assert!(reply.fully_assembled(), "every reply span must fill exactly");
    };

    // Cold exchange grows every buffer (slabs, merged arenas, the slab).
    run_exchange_replica();
    // Warm repeat: the whole exchange — reply slab included — reuses it.
    let base = allocs();
    run_exchange_replica();
    let steady = allocs() - base;
    assert!(
        steady <= 8,
        "warm read exchange allocated {steady} times \
         (expected ~0: the reply slab or the round arena regressed)"
    );
    // The assembled bytes are the written image, per requester span.
    for (r, _) in views.iter().enumerate() {
        assert_eq!(
            reply.of(r),
            &deterministic_payload(5, r, BLOCK)[..],
            "rank {r} reply bytes"
        );
    }
}

/// The plan oracle's warm path (plan-cache satellite pin): computing the
/// structural fingerprint over borrowed views and looking up a warm plan
/// must itself be (near-)allocation-free — a hit deletes plan
/// construction, and the lookup must not reintroduce per-call heap
/// traffic of its own.
fn warm_plan_lookup_allocates_nothing() {
    let topo = Topology::new(2, 8);
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let file_cfg = LustreConfig::new(256, 4);
    let algo =
        Algorithm::Tam(tamio::coordinator::tam::TamConfig { total_local_aggregators: 4 });
    let views: Vec<(usize, FlatView)> = (0..topo.nprocs())
        .map(|r| {
            let base = r as u64 * 2048;
            let view = FlatView::from_pairs(
                (0..8).map(|i| (base + i * 256, 200)).collect(),
            )
            .unwrap();
            (r, view)
        })
        .collect();
    let fp = fingerprint_collective(
        &ctx,
        &algo,
        Direction::Write,
        &file_cfg,
        views.iter().map(|(r, v)| (*r, v)),
    );
    let mut cache = PlanCache::in_memory(2);
    cache
        .get_or_build(fp, || {
            build_collective_plan(&ctx, &algo, Direction::Write, &views, &file_cfg, fp)
        })
        .unwrap();

    let base = allocs();
    let fp2 = fingerprint_collective(
        &ctx,
        &algo,
        Direction::Write,
        &file_cfg,
        views.iter().map(|(r, v)| (*r, v)),
    );
    let plan = cache.get_or_build(fp2, || unreachable!("warm lookup must hit")).unwrap();
    assert_eq!(plan.fingerprint, fp, "fingerprint must be deterministic");
    let lookup = allocs() - base;
    assert!(
        lookup <= 8,
        "warm plan lookup allocated {lookup} times \
         (expected ~0: streaming fingerprint + LRU probe)"
    );
    assert_eq!(cache.stats.hits, 1, "second lookup must be a hit");
}

/// End-to-end: the second collective through a warm arena must allocate
/// strictly less than the cold first one (both pay the same per-call
/// costs — rank clones, `calc_my_req` slabs, thread spawns — so the
/// difference isolates the arena's buffers).
fn warm_arena_beats_cold(algo: Algorithm, label: &str) {
    let topo = Topology::new(2, 8);
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let ranks: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
        .map(|r| {
            let base = r as u64 * 2048;
            let view = FlatView::from_pairs(
                (0..8).map(|i| (base + i * 256, 200)).collect(),
            )
            .unwrap();
            (r, ReqBatch::new(view, deterministic_payload(13, r, 1600)))
        })
        .collect();

    let mut arena = ExchangeArena::default();
    let mut file = LustreFile::new(LustreConfig::new(256, 4));

    let t0 = allocs();
    run_collective_write_with(&ctx, algo, ranks.clone(), &mut file, &mut arena).unwrap();
    let cold = allocs() - t0;
    let t1 = allocs();
    run_collective_write_with(&ctx, algo, ranks.clone(), &mut file, &mut arena).unwrap();
    let warm = allocs() - t1;
    assert!(
        warm < cold,
        "{label} write: warm arena saved nothing (cold={cold} allocs, warm={warm})"
    );

    // Read direction through the same arena: cold read (first read-shaped
    // exchange, stats + reply staging grow) vs warm repeat.
    let views: Vec<(usize, FlatView)> =
        ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
    let t2 = allocs();
    let (got, _) =
        run_collective_read_with(&ctx, algo, views.clone(), &file, &mut arena).unwrap();
    let cold_read = allocs() - t2;
    for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
        assert_eq!(payload, &want.payload, "{label} rank {r} read-back");
    }
    let t3 = allocs();
    run_collective_read_with(&ctx, algo, views, &file, &mut arena).unwrap();
    let warm_read = allocs() - t3;
    assert!(
        warm_read < cold_read,
        "{label} read: warm arena saved nothing (cold={cold_read}, warm={warm_read})"
    );
}

/// Double-bank satellite pin: with overlap on the arena carries two
/// ping/pong `RoundScratch` banks per aggregator slot.  A cold pipelined
/// exchange sizes both banks; a warm repeat must then allocate no more
/// than the warm serial loop does (within a small slack) — the second
/// bank is capacity reuse across collectives, never per-round heap
/// traffic, in both directions.
fn warm_double_bank_pipeline_allocates_like_serial() {
    let topo = Topology::new(2, 8);
    let net = NetParams::default();
    let cpu = CpuModel::default();
    let io = IoModel::default();
    let eng = NativeEngine;
    let ctx = CollectiveCtx {
        topo: &topo,
        net: &net,
        cpu: &cpu,
        io: &io,
        engine: &eng,
        placement: GlobalPlacement::Spread,
        n_global_agg: 4,
    };
    let algo =
        Algorithm::Tam(tamio::coordinator::tam::TamConfig { total_local_aggregators: 4 });
    let ranks: Vec<(usize, ReqBatch)> = (0..topo.nprocs())
        .map(|r| {
            let base = r as u64 * 2048;
            let view = FlatView::from_pairs(
                (0..8).map(|i| (base + i * 256, 200)).collect(),
            )
            .unwrap();
            (r, ReqBatch::new(view, deterministic_payload(17, r, 1600)))
        })
        .collect();

    // Identical measurement closure for both modes, so per-call costs
    // (rank clones, calc_my_req slabs, plan build) cancel out and the
    // comparison isolates the pipeline's own steady-state traffic.
    let measure = |overlap: OverlapMode| {
        let mut arena = ExchangeArena::default();
        arena.overlap = overlap;
        let mut file = LustreFile::new(LustreConfig::new(256, 4));
        run_collective_write_with(&ctx, algo, ranks.clone(), &mut file, &mut arena).unwrap();
        let t = allocs();
        let out =
            run_collective_write_with(&ctx, algo, ranks.clone(), &mut file, &mut arena)
                .unwrap();
        let warm_write = allocs() - t;
        assert!(out.counters.rounds >= 2, "need a multi-round exchange to pipeline");
        let views: Vec<(usize, FlatView)> =
            ranks.iter().map(|(r, b)| (*r, b.view.clone())).collect();
        run_collective_read_with(&ctx, algo, views.clone(), &file, &mut arena).unwrap();
        let t = allocs();
        let (got, _) =
            run_collective_read_with(&ctx, algo, views, &file, &mut arena).unwrap();
        let warm_read = allocs() - t;
        for ((r, payload), (_, want)) in got.iter().zip(ranks.iter()) {
            assert_eq!(payload, &want.payload, "{overlap} rank {r} read-back");
        }
        (warm_write, warm_read)
    };
    let (serial_write, serial_read) = measure(OverlapMode::Off);
    let (pipe_write, pipe_read) = measure(OverlapMode::On);
    assert!(
        pipe_write <= serial_write + 16,
        "warm pipelined write allocated {pipe_write} vs serial {serial_write} \
         (the double bank must be capacity reuse, not per-round traffic)"
    );
    assert!(
        pipe_read <= serial_read + 16,
        "warm pipelined read allocated {pipe_read} vs serial {serial_read} \
         (the double bank must be capacity reuse, not per-round traffic)"
    );
}

#[test]
fn arena_keeps_steady_state_rounds_allocation_free() {
    steady_state_rounds_allocate_nothing();
    warm_pool_rounds_allocate_nothing();
    steady_state_read_exchanges_allocate_nothing();
    warm_plan_lookup_allocates_nothing();
    warm_arena_beats_cold(Algorithm::TwoPhase, "two-phase");
    warm_arena_beats_cold(
        Algorithm::Tam(tamio::coordinator::tam::TamConfig { total_local_aggregators: 4 }),
        "tam",
    );
    warm_arena_beats_cold(
        Algorithm::Tree(
            "socket=2,node=1".parse().expect("valid tree spec"),
        ),
        "tree",
    );
    warm_double_bank_pipeline_allocates_like_serial();
}
